//! Auto-scaling demo: the paper's headline claim, as a timeline.
//!
//! An 8-machine plant starts with one compute node. A burst of jobs
//! arrives; the autoscaler powers up machines, the new containers
//! self-register, the hostfile grows, jobs drain, then sustained
//! idleness shrinks the pool back to the minimum.
//!
//! Run with: `cargo run --release --example autoscale_demo`

use vhpc::cluster::head::JobKind;
use vhpc::cluster::vcluster::{NodeState, VirtualCluster};
use vhpc::config::ClusterSpec;
use vhpc::sim::SimTime;

fn print_row(vc: &VirtualCluster, label: &str) {
    let states: String = (1..vc.state.spec.machines)
        .map(|i| match vc.node_state(vhpc::util::ids::MachineId::new(i)) {
            NodeState::Off => '.',
            NodeState::Booting => 'b',
            NodeState::StartingEngine => 'e',
            NodeState::Deploying => 'd',
            NodeState::Ready => 'R',
        })
        .collect();
    println!(
        "t={:>9}  nodes=[{states}]  ready={}  queued={}  done={}   {label}",
        vc.now().to_string(),
        vc.ready_compute_nodes(),
        vc.state.head.queue.len(),
        vc.completed_jobs().len(),
    );
}

fn main() -> anyhow::Result<()> {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = 8;
    spec.machine_spec.boot_time = SimTime::from_secs(60);
    spec.autoscale.min_nodes = 1;
    spec.autoscale.max_nodes = 7;
    spec.autoscale.interval = SimTime::from_secs(5);
    spec.autoscale.cooldown = SimTime::from_secs(20);
    spec.autoscale.idle_timeout = SimTime::from_secs(180);

    let mut vc = VirtualCluster::new(spec)?;
    vc.start();
    vc.advance_until(SimTime::from_secs(600), |st| {
        st.node_states.iter().skip(1).any(|s| *s == NodeState::Ready)
    });
    print_row(&vc, "<- initial node up");

    // burst: 5 jobs of 24 ranks each (2 nodes' worth apiece)
    for i in 0..5 {
        vc.submit(
            &format!("burst-{i}"),
            24,
            JobKind::Synthetic { duration: SimTime::from_secs(45) },
        );
    }
    print_row(&vc, "<- burst of 5x24-rank jobs submitted");

    let mut last_ready = vc.ready_compute_nodes();
    let mut last_done = 0;
    for _ in 0..400 {
        vc.advance(SimTime::from_secs(10));
        let ready = vc.ready_compute_nodes();
        let done = vc.completed_jobs().len();
        if ready != last_ready || done != last_done {
            let label = if ready > last_ready {
                "<- scaled up"
            } else if ready < last_ready {
                "<- scaled down"
            } else {
                "<- job completed"
            };
            print_row(&vc, label);
            last_ready = ready;
            last_done = done;
        }
        if done == 5 && ready == 1 {
            break;
        }
    }
    print_row(&vc, "<- final state");
    anyhow::ensure!(vc.completed_jobs().len() == 5, "not all jobs finished");
    anyhow::ensure!(vc.ready_compute_nodes() == 1, "did not scale back to min");

    println!("\nscale actions taken:");
    for (t, a) in &vc.state.autoscaler.actions {
        println!("  t={t}  {a:?}");
    }
    println!("\nmetrics:\n{}", vc.metrics().render());
    println!("autoscale_demo OK");
    Ok(())
}
