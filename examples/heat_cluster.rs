//! End-to-end driver (EXPERIMENTS.md §E2E): the full system on a real
//! workload.
//!
//! Brings up the auto-scaling virtual cluster, submits a 16-domain
//! Jacobi heat-diffusion solve (the paper's Fig. 8 job, 256×256 global
//! grid), and prints the residual curve plus the comm/compute breakdown.
//! Every layer is exercised: Pallas kernel → JAX model → HLO artifact →
//! PJRT execution from the Rust MPI ranks → virtual fabric → consul
//! discovery → autoscaled provisioning.
//!
//! Run with: `cargo run --release --example heat_cluster`

use std::collections::HashMap;
use std::sync::Arc;
use vhpc::cluster::vcluster::VirtualCluster;
use vhpc::config::ClusterSpec;
use vhpc::mpi::launcher::LaunchPlan;
use vhpc::runtime::Runtime;
use vhpc::sim::SimTime;
use vhpc::workloads::jacobi::{run_jacobi, serial_jacobi, stitch, JacobiSpec};

fn main() -> anyhow::Result<()> {
    let spec = ClusterSpec::paper_testbed();
    let mut vc = VirtualCluster::new(spec)?;
    vc.start();
    anyhow::ensure!(
        vc.advance_until(SimTime::from_secs(600), |st| st.head.slots_available() >= 16),
        "cluster never offered 16 slots"
    );
    println!("cluster up at t={}; hostfile:\n{}", vc.now(), vc.hostfile());

    // Build the launch plan straight from the rendered hostfile.
    let hostfile = vc.state.head.hostfile().expect("hostfile");
    let plan = LaunchPlan {
        hostfile,
        n_ranks: 16,
        ip_to_container: HashMap::from_iter(
            vc.state.ip_to_container.iter().map(|(k, v)| (*k, *v)),
        ),
        fabric: Arc::clone(&vc.state.fabric),
        eager_threshold: 64 * 1024,
    };
    let jspec = JacobiSpec {
        px: 4,
        py: 4,
        tile: 64,
        steps: 400,
        check_every: 20,
        tol: 1e-4,
        artifacts: Runtime::default_dir(),
    };
    let (gh, gw) = jspec.global_shape();
    println!(
        "running 16-domain Jacobi: global {gh}x{gw}, tiles {}x{}, up to {} steps",
        jspec.tile, jspec.tile, jspec.steps
    );
    let report = run_jacobi(&plan, &jspec)?;

    println!("\nresidual curve (step, global squared residual):");
    for (step, res) in &report.residual_curve {
        println!("  {step:>5}  {res:.6e}");
    }
    println!("\nsteps run:            {}", report.steps_run);
    println!("final residual:       {:.6e}", report.final_residual);
    println!("wall clock:           {:.3}s", report.wall.as_secs_f64());
    println!("compute (max rank):   {:.3}s", report.compute_wall_max.as_secs_f64());
    println!("virtual comm time:    {}", report.comm_time);
    println!("MPI traffic:          {} msgs, {}",
        report.total_msgs, vhpc::util::format_bytes(report.total_bytes));
    let steps = report.steps_run as f64;
    println!("steps/sec (wall):     {:.1}", steps / report.wall.as_secs_f64());

    // Validate against the serial oracle on the same global grid.
    print!("\nvalidating against serial oracle... ");
    let got = stitch(&report.ranks, 4, 4, 64);
    let (want, _) = serial_jacobi(gh, gw, report.steps_run);
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0f32, f32::max);
    anyhow::ensure!(max_err < 1e-4, "max |err| = {max_err}");
    println!("OK (max |err| = {max_err:.2e})");
    println!("heat_cluster OK");
    Ok(())
}
