//! Quickstart: the paper's workflow in ~80 lines.
//!
//! 1. Parse the Fig. 2 Dockerfile and build the compute-node image.
//! 2. Bring up the Fig. 4 deployment: head on blade01, node02/node03 on
//!    blade02/blade03, all self-registering through consul.
//! 3. Watch consul-template render the MPI hostfile (Fig. 5).
//! 4. Run a 16-rank MPI job (Fig. 8) — real PJRT compute per rank.
//!
//! Run with: `cargo run --release --example quickstart`

use vhpc::cluster::head::{JobKind, JobState};
use vhpc::cluster::vcluster::VirtualCluster;
use vhpc::config::ClusterSpec;
use vhpc::dockyard::{Dockerfile, ImageStore};
use vhpc::sim::SimTime;

fn main() -> anyhow::Result<()> {
    // --- 1. the image (Fig. 2) ---
    let df = Dockerfile::parse(Dockerfile::paper_compute_node())?;
    let mut store = ImageStore::with_base_images();
    let image = store.build(&df, "nchc/mpi-computenode:latest")?;
    println!("[1] built {} — {} layers:", image.reference, image.layers.len());
    for l in &image.layers {
        println!("      {}  {}", l.digest().short(), l.created_by);
    }

    // --- 2. the cluster (Fig. 4: 3 blades, bridge0, 3 consul servers) ---
    let spec = ClusterSpec::paper_testbed();
    println!(
        "\n[2] powering up '{}': {}x {} ({} cores, {}), bridge={}",
        spec.name,
        spec.machines,
        spec.machine_spec.model,
        spec.machine_spec.total_cores(),
        vhpc::util::format_bytes(spec.machine_spec.memory_bytes),
        spec.bridge.name()
    );
    let mut vc = VirtualCluster::new(spec)?;
    vc.start();
    let up = vc.advance_until(SimTime::from_secs(600), |st| {
        st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
    });
    anyhow::ensure!(up, "cluster did not come up");
    println!("    cluster ready at t={} (virtual)", vc.now());

    // --- 3. the hostfile (Fig. 5) ---
    println!("\n[3] consul-template rendered hostfile:\n{}", vc.hostfile());

    // --- 4. the MPI job (Fig. 8: 16 domains on 2 containers) ---
    println!("[4] submitting 16-rank Jacobi job (4x4 domains, 64^2 tiles)...");
    vc.submit("fig8", 16, JobKind::Jacobi { px: 4, py: 4, tile: 64, steps: 100 });
    let done = vc.advance_until(SimTime::from_secs(3600), |st| !st.head.completed.is_empty());
    anyhow::ensure!(done, "job did not finish");
    let rec = &vc.completed_jobs()[0];
    match (&rec.state, rec.result) {
        (JobState::Done { started, finished }, Some((steps, residual))) => {
            println!(
                "    done: {steps} steps, residual {residual:.3e}, ran {} (virtual)",
                finished.saturating_sub(*started)
            );
        }
        other => anyhow::bail!("unexpected job outcome: {other:?}"),
    }
    println!("\nmetrics:\n{}", vc.metrics().render());
    println!("quickstart OK");
    Ok(())
}
