//! Job-mix scenario: a bursty mix of wide and narrow jobs through the
//! slot-aware concurrent scheduler.
//!
//! The same 10-job trace (1..24 ranks) runs twice on an 8-machine
//! cluster: once with the head capped at one job at a time (the seed's
//! serial scheduler, for comparison) and once with slot-limited
//! concurrency + conservative backfill. The concurrent head must run
//! >= 3 jobs at once without double-booking a single hostfile slot, and
//! the mean queue wait must drop.
//!
//! Run with: `cargo run --release --example job_mix`

use vhpc::cluster::mix::{bursty_trace, mix_spec, run_job_trace};
use vhpc::config::ClusterSpec;
use vhpc::sim::SimTime;

fn spec() -> ClusterSpec {
    mix_spec(SimTime::from_secs(20))
}

fn main() -> anyhow::Result<()> {
    // wide 24-rank jobs bracket a stream of narrow ones — the shape
    // that starves a strict-FIFO head
    let trace = bursty_trace(24, 10);
    let (serial, _) = run_job_trace(spec(), &trace, 1, 36, 3600)?;
    let (concurrent, _) = run_job_trace(spec(), &trace, usize::MAX, 36, 3600)?;

    println!("job mix: {} jobs, widths 1..24 ranks, 8-machine cluster\n", trace.len());
    let row = |name: &str, s: String, c: String| println!("{name:<22} {s:>14} {c:>14}");
    row("metric", "serial (seed)".into(), "concurrent".into());
    row("------", "-------------".into(), "----------".into());
    let secs = |v: f64| format!("{v:.1}s");
    row("mean queue wait", secs(serial.mean_wait), secs(concurrent.mean_wait));
    row("max queue wait", secs(serial.max_wait), secs(concurrent.max_wait));
    row("makespan", secs(serial.makespan), secs(concurrent.makespan));
    row(
        "peak concurrency",
        serial.peak_concurrency.to_string(),
        concurrent.peak_concurrency.to_string(),
    );
    row(
        "backfill starts",
        serial.backfill_starts.to_string(),
        concurrent.backfill_starts.to_string(),
    );

    anyhow::ensure!(serial.peak_concurrency == 1, "serial head must cap at 1 running job");
    anyhow::ensure!(
        concurrent.peak_concurrency >= 3,
        "concurrent head must overlap >= 3 jobs, got {}",
        concurrent.peak_concurrency
    );
    anyhow::ensure!(
        concurrent.mean_wait < serial.mean_wait,
        "mean queue wait must drop: serial {:.1}s vs concurrent {:.1}s",
        serial.mean_wait,
        concurrent.mean_wait
    );
    anyhow::ensure!(
        concurrent.makespan < serial.makespan,
        "makespan must drop with overlap"
    );
    println!(
        "\njob_mix OK ({}x concurrency, mean wait {:.1}s -> {:.1}s)",
        concurrent.peak_concurrency, serial.mean_wait, concurrent.mean_wait
    );
    Ok(())
}
