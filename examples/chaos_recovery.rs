//! Chaos-recovery scenario: watch the cluster heal itself.
//!
//! A 4-machine cluster runs a 16-rank job; 30 seconds in, the machine
//! hosting part of its reservation loses power. The timeline shows the
//! paper's Fig. 5 removal pipeline (TTL expiry -> hostfile shrink) plus
//! this repo's recovery pipeline: immediate job failure + requeue with
//! progress credit, a replacement machine booting, and the job running
//! to completion — with MTTR reported at the end.
//!
//! Run with: `cargo run --release --example chaos_recovery`

use vhpc::cluster::head::JobKind;
use vhpc::cluster::vcluster::VirtualCluster;
use vhpc::config::ClusterSpec;
use vhpc::faults::{FaultEvent, FaultKind, FaultPlan};
use vhpc::sim::SimTime;

fn main() -> anyhow::Result<()> {
    let mut spec = ClusterSpec::paper_testbed();
    spec.machines = 4;
    spec.machine_spec.boot_time = SimTime::from_secs(30);
    spec.autoscale.min_nodes = 2;
    spec.autoscale.max_nodes = 3;
    spec.autoscale.interval = SimTime::from_secs(5);
    spec.autoscale.cooldown = SimTime::from_secs(10);
    spec.autoscale.idle_timeout = SimTime::from_secs(300);

    let mut vc = VirtualCluster::new(spec)?;
    vc.start();
    anyhow::ensure!(
        vc.advance_until(SimTime::from_secs(600), |st| st.head.slots_available() >= 24),
        "cluster never reached 24 slots"
    );
    println!("t={}  cluster up, hostfile:\n{}", vc.now(), vc.hostfile());

    // one wide job spanning both compute nodes, then pull the plug on
    // machine 2 thirty seconds into the run
    vc.submit("survivor", 16, JobKind::Synthetic { duration: SimTime::from_secs(180) });
    vc.inject_faults(&FaultPlan::scripted(vec![FaultEvent {
        at: SimTime::from_secs(30),
        kind: FaultKind::Crash { machine: 2 },
    }]));

    // narrate the interesting transitions
    let mut said_killed = false;
    let mut said_requeued = false;
    let mut said_shrunk = false;
    let mut said_replaced = false;
    let deadline = vc.now() + SimTime::from_secs(900);
    while vc.now() < deadline && vc.completed_jobs().is_empty() {
        vc.advance(SimTime::from_secs(1));
        let m = vc.metrics();
        if !said_killed && m.counter("machines_killed") > 0 {
            println!("t={}  machine m2 lost power (chaos injector)", vc.now());
            said_killed = true;
        }
        if !said_requeued && m.counter("jobs_requeued") > 0 {
            println!(
                "t={}  job failed fast and was requeued with progress credit",
                vc.now()
            );
            said_requeued = true;
        }
        if !said_shrunk
            && said_killed
            && vc.state.head.hostfile().map(|h| h.hosts.len()) == Some(1)
        {
            println!("t={}  hostfile shrank to the surviving node", vc.now());
            said_shrunk = true;
        }
        if !said_replaced && m.counter("machines_powered_on") > 3 {
            println!("t={}  autoscaler booting a replacement machine", vc.now());
            said_replaced = true;
        }
    }
    anyhow::ensure!(!vc.completed_jobs().is_empty(), "job never completed");
    let rec = &vc.completed_jobs()[0];
    println!("t={}  job '{}' -> {:?}", vc.now(), rec.spec.name, rec.state);

    let m = vc.metrics();
    let mttr = m.histogram("job_mttr_seconds").map(|h| h.max()).unwrap_or(0.0);
    println!(
        "\nrecovery: {} requeue(s), {} machine(s) killed, MTTR {:.1}s",
        m.counter("jobs_requeued"),
        m.counter("machines_killed"),
        mttr
    );
    anyhow::ensure!(m.counter("jobs_requeued") >= 1, "the crash must requeue the job");
    anyhow::ensure!(mttr > 0.0, "MTTR must be recorded");
    anyhow::ensure!(
        m.counter("machines_powered_on") > 3,
        "a replacement machine must boot"
    );
    println!("\nchaos_recovery OK (self-healing end to end)");
    Ok(())
}
