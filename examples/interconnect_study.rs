//! Interconnect study — the performance investigation the paper's
//! conclusion promises ("the influence of the interconnect between HPC
//! containers").
//!
//! Sweeps the 16-rank Jacobi job across bridge modes (docker0-NAT vs
//! bridge0 vs host) and NIC technologies (1GbE / 10GbE / IB-FDR),
//! reporting virtual communication time per step and the comm share of
//! the total. Real PJRT compute, modeled interconnect.
//!
//! Run with: `cargo run --release --example interconnect_study`

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use vhpc::hw::rack::Plant;
use vhpc::hw::{MachineSpec, NicSpec};
use vhpc::mpi::hostfile::Hostfile;
use vhpc::mpi::launcher::LaunchPlan;
use vhpc::runtime::Runtime;
use vhpc::util::ids::{ContainerId, MachineId};
use vhpc::vnet::addr::Ipv4;
use vhpc::vnet::bridge::BridgeMode;
use vhpc::vnet::fabric::Fabric;
use vhpc::workloads::jacobi::{run_jacobi, JacobiSpec};

fn plan_for(mode: BridgeMode, nic: NicSpec) -> LaunchPlan {
    let mut spec = MachineSpec::dell_m620();
    spec.nic = nic;
    let plant = Plant::uniform(3, spec, 3);
    let mut fabric = Fabric::from_plant(&plant, mode);
    let c2 = ContainerId::new(0);
    let c3 = ContainerId::new(1);
    fabric.place(c2, MachineId::new(1));
    fabric.place(c3, MachineId::new(2));
    let mut ip_to_container = HashMap::new();
    ip_to_container.insert(Ipv4::parse("10.10.0.2").unwrap(), c2);
    ip_to_container.insert(Ipv4::parse("10.10.0.3").unwrap(), c3);
    LaunchPlan {
        hostfile: Hostfile::parse("10.10.0.2 slots=12\n10.10.0.3 slots=12\n").unwrap(),
        n_ranks: 16,
        ip_to_container,
        fabric: Arc::new(Mutex::new(fabric)),
        eager_threshold: 64 * 1024,
    }
}

fn main() -> anyhow::Result<()> {
    let jspec = JacobiSpec {
        px: 4,
        py: 4,
        tile: 64,
        steps: 100,
        check_every: 25,
        tol: 0.0,
        artifacts: Runtime::default_dir(),
    };
    println!("16-rank Jacobi, 100 steps, 2 containers on 2 blades\n");
    println!(
        "{:<22} {:>14} {:>14} {:>12} {:>10}",
        "configuration", "comm total", "comm/step", "compute", "comm share"
    );
    let configs: Vec<(String, BridgeMode, NicSpec)> = vec![
        ("docker0 + 1GbE".into(), BridgeMode::Docker0, NicSpec::one_gbe()),
        ("bridge0 + 1GbE".into(), BridgeMode::Bridge0, NicSpec::one_gbe()),
        ("docker0 + 10GbE".into(), BridgeMode::Docker0, NicSpec::ten_gbe()),
        ("bridge0 + 10GbE".into(), BridgeMode::Bridge0, NicSpec::ten_gbe()),
        ("host    + 10GbE".into(), BridgeMode::Host, NicSpec::ten_gbe()),
        ("bridge0 + IB-FDR".into(), BridgeMode::Bridge0, NicSpec::infiniband_fdr()),
    ];
    let mut rows = Vec::new();
    for (name, mode, nic) in configs {
        let plan = plan_for(mode, nic);
        let report = run_jacobi(&plan, &jspec)?;
        let comm = report.comm_time;
        let comp = report.compute_wall_max;
        let per_step = comm.as_secs_f64() / report.steps_run as f64;
        let share = comm.as_secs_f64() / (comm.as_secs_f64() + comp.as_secs_f64());
        println!(
            "{:<22} {:>14} {:>13.1}us {:>11.3}s {:>9.1}%",
            name,
            comm.to_string(),
            per_step * 1e6,
            comp.as_secs_f64(),
            share * 100.0
        );
        rows.push((name, comm));
    }

    // sanity: the paper's design (bridge0) must beat docker0 per NIC
    let get = |n: &str| rows.iter().find(|(name, _)| name.starts_with(n)).unwrap().1;
    anyhow::ensure!(get("bridge0 + 10GbE") < get("docker0 + 10GbE"));
    anyhow::ensure!(get("bridge0 + 1GbE") < get("docker0 + 1GbE"));
    anyhow::ensure!(get("bridge0 + IB-FDR") < get("bridge0 + 10GbE"));
    println!("\ninterconnect_study OK (bridge0 < docker0 on every NIC)");
    Ok(())
}
