"""Kernel-vs-reference correctness: the CORE numeric signal.

Pallas (interpret=True) kernels must match the pure-jnp oracles in
``compile.kernels.ref`` to float32 tolerance across shapes, and the
hypothesis sweeps hammer odd shapes/values.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import matmul as matmul_kernel
from compile.kernels import ref, stencil


def rand_grid(rng, n, lo=-10.0, hi=10.0):
    return jnp.asarray(
        rng.uniform(lo, hi, size=(n + 2, n + 2)).astype(np.float32)
    )


# ---------------------------------------------------------------- jacobi

@pytest.mark.parametrize("n", [4, 8, 32, 64, 96, 128])
def test_jacobi_step_matches_ref(n):
    rng = np.random.default_rng(n)
    padded = rand_grid(rng, n)
    got, partials = stencil.jacobi_step(padded)
    want, res = ref.jacobi_step_ref(padded)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        jnp.sum(partials), res, rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("block", [8, 16, 32, 64])
def test_jacobi_step_block_invariance(block):
    """Tile size must not change the numerics."""
    rng = np.random.default_rng(7)
    padded = rand_grid(rng, 64)
    base, p0 = stencil.jacobi_step(padded, block=64)
    got, p1 = stencil.jacobi_step(padded, block=block)
    np.testing.assert_allclose(got, base, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        jnp.sum(p0), jnp.sum(p1), rtol=1e-5, atol=1e-5
    )


def test_jacobi_step_nonsquare():
    rng = np.random.default_rng(3)
    padded = jnp.asarray(
        rng.uniform(-1, 1, size=(34, 130)).astype(np.float32)
    )
    got, _ = stencil.jacobi_step(padded)
    want, _ = ref.jacobi_step_ref(padded)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_jacobi_model_residual_scalar():
    rng = np.random.default_rng(5)
    padded = rand_grid(rng, 32)
    new, res = model.jacobi_step(padded)
    _, res_ref = ref.jacobi_step_ref(padded)
    assert new.shape == (32, 32)
    np.testing.assert_allclose(res, res_ref, rtol=1e-5, atol=1e-5)


def test_jacobi_sweep_matches_iterated_ref():
    rng = np.random.default_rng(11)
    padded = rand_grid(rng, 32)
    got, res = model.jacobi_sweep(padded.copy(), steps=5)
    # iterate the reference with the same fixed-boundary rule
    cur = np.array(padded)
    for _ in range(5):
        new, r = ref.jacobi_step_ref(jnp.asarray(cur))
        cur[1:-1, 1:-1] = np.array(new)
        last = r
    np.testing.assert_allclose(got, cur, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(res, last, rtol=1e-4, atol=1e-5)


def test_jacobi_sweep_residual_decreases():
    """Physics sanity: fixed-boundary Jacobi relaxation converges."""
    n = 32
    grid = np.zeros((n + 2, n + 2), dtype=np.float32)
    grid[0, :] = 1.0  # hot north wall
    g = jnp.asarray(grid)
    _, r10 = model.jacobi_sweep(g, steps=10)
    g = jnp.asarray(grid)
    _, r200 = model.jacobi_sweep(g, steps=200)
    assert float(r200) < float(r10)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([4, 6, 8, 12, 16, 24]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 1e3),
)
def test_jacobi_hypothesis_shapes_and_values(n, seed, scale):
    rng = np.random.default_rng(seed)
    padded = jnp.asarray(
        (rng.standard_normal((n + 2, n + 2)) * scale).astype(np.float32)
    )
    got, partials = stencil.jacobi_step(padded)
    want, res = ref.jacobi_step_ref(padded)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * scale)
    np.testing.assert_allclose(
        jnp.sum(partials), res, rtol=1e-4, atol=1e-4 * scale * scale
    )


# ---------------------------------------------------------------- matmul

@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (32, 16, 8), (128, 64, 32), (256, 256, 256)])
def test_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k + n)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    got = matmul_kernel.matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tile", [8, 16, 64, 128])
def test_matmul_tile_invariance(tile):
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((128, 128)).astype(np.float32))
    got = matmul_kernel.matmul(a, b, tile=tile)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([4, 8, 12, 16]),
    k=st.sampled_from([4, 8, 12, 16]),
    n=st.sampled_from([4, 8, 12, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    got = matmul_kernel.matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ vmem model

def test_vmem_estimates_within_budget():
    """DESIGN.md TPU-viability claim: blocks fit VMEM (~16 MB)."""
    for b in [32, 64, 128, 256, 512]:
        assert stencil.vmem_bytes(b) < 16 * 2**20
    assert matmul_kernel.vmem_bytes(128) < 16 * 2**20
