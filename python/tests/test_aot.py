"""AOT pipeline tests: HLO text is produced, parseable-looking, and the
manifest matches the artifact set."""

import os
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import aot, model


def test_to_hlo_text_smoke():
    spec = jax.ShapeDtypeStruct((10, 10), jnp.float32)
    lowered = jax.jit(model.jacobi_step).lower(spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # tuple return (return_tuple=True): entry root should be a tuple
    assert "(f32[8,8]" in text  # new interior appears in the signature


def test_entries_cover_manifest_kinds():
    kinds = {kind for _, _, kind, _ in aot.entries()}
    assert kinds == {"jacobi_step", "jacobi_sweep", "gemm"}


def test_full_pipeline_writes_artifacts(tmp_path):
    # Monkeypatch the size tables down so the test is fast.
    old_j, old_s, old_g = aot.JACOBI_SIZES, aot.SWEEPS, aot.GEMM_SIZES
    aot.JACOBI_SIZES, aot.SWEEPS, aot.GEMM_SIZES = [8], [(8, 3)], [8]
    try:
        sys.argv = ["aot", "--out-dir", str(tmp_path)]
        aot.main()
    finally:
        aot.JACOBI_SIZES, aot.SWEEPS, aot.GEMM_SIZES = old_j, old_s, old_g
    names = sorted(os.listdir(tmp_path))
    assert "manifest.txt" in names
    assert "jacobi_step_8.hlo.txt" in names
    assert "jacobi_sweep_8_k3.hlo.txt" in names
    assert "gemm_8.hlo.txt" in names
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    # header + 3 entries
    assert len(manifest) == 4
    for line in manifest[1:]:
        name, fname, kind, *dims = line.split()
        assert (tmp_path / fname).exists()
        assert kind in ("jacobi_step", "jacobi_sweep", "gemm")
        assert all(d.isdigit() for d in dims)
