"""L1: Pallas 2-D Jacobi stencil kernel.

The per-rank compute hot-spot of the virtual cluster's MPI workload
(Fig. 8's "16-domain MPI job"): one Jacobi relaxation step of the 2-D
Laplace/heat equation on a halo-padded local domain.

    u'[i, j] = 0.25 * (u[i-1, j] + u[i+1, j] + u[i, j-1] + u[i, j+1])

The kernel runs over a (H/bh, W/bw) grid of output tiles. The padded
input stays un-blocked (whole-array ref) and each program loads its
(bh+2, bw+2) window — the canonical halo pattern. Per-tile squared
residual partial sums come out as a (H/bh, W/bw) array so the scalar
reduction can be fused at L2 without cross-program accumulation.

TPU adaptation (DESIGN.md §Hardware-Adaptation): each block is sized so
tile + halo fits comfortably in VMEM (bh=bw=64 → 66*66*4 B ≈ 17 KB input
window + 16 KB output, far under the ~16 MB budget; larger tiles up to
512 still fit). The 5-point stencil is VPU element-wise work; interpret
mode is mandatory on CPU (Mosaic custom-calls cannot run on the CPU
plugin).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edge. Local domains in the benches are multiples of 32.
DEFAULT_BLOCK = 64


def _jacobi_kernel(padded_ref, out_ref, res_ref, *, bh: int, bw: int):
    """One output tile: load the (bh+2, bw+2) halo window, relax, and
    emit the tile plus its squared-residual partial sum."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    window = padded_ref[pl.dslice(i * bh, bh + 2), pl.dslice(j * bw, bw + 2)]
    center = window[1:-1, 1:-1]
    north = window[:-2, 1:-1]
    south = window[2:, 1:-1]
    west = window[1:-1, :-2]
    east = window[1:-1, 2:]
    new = 0.25 * (north + south + west + east)
    out_ref[...] = new
    diff = new - center
    res_ref[0, 0] = jnp.sum(diff * diff)


def _pick_block(n: int, prefer: int) -> int:
    """Largest divisor of n that is <= prefer (tiles must cover exactly)."""
    b = min(prefer, n)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def jacobi_step(padded: jax.Array, block: int = DEFAULT_BLOCK):
    """One Jacobi step on a halo-padded (H+2, W+2) f32 grid.

    Returns ``(new_interior, residual_partials)`` where ``new_interior``
    is (H, W) and ``residual_partials`` is the per-tile squared-residual
    sums of shape (H/bh, W/bw).
    """
    hp, wp = padded.shape
    h, w = hp - 2, wp - 2
    bh = _pick_block(h, block)
    bw = _pick_block(w, block)
    gh, gw = h // bh, w // bw
    kernel = functools.partial(_jacobi_kernel, bh=bh, bw=bw)
    return pl.pallas_call(
        kernel,
        grid=(gh, gw),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=[
            pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), jnp.float32),
            jax.ShapeDtypeStruct((gh, gw), jnp.float32),
        ],
        interpret=True,
    )(padded)


def vmem_bytes(block: int) -> int:
    """Estimated per-program VMEM footprint (input window + output tile +
    residual cell), for DESIGN.md's TPU-viability estimate."""
    win = (block + 2) * (block + 2) * 4
    out = block * block * 4
    return win + out + 4
