"""L1: Pallas blocked matmul kernel (the MXU-path workload).

Used by the GEMM/"linpack-proxy" MPI workload: each rank multiplies its
local panel. Tiles are 128x128 to match the MXU systolic array shape;
the K reduction is the innermost grid dimension with an accumulator
revisited across k steps (standard Pallas pattern).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pick_tile(n: int, prefer: int) -> int:
    t = min(prefer, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tile",))
def matmul(a: jax.Array, b: jax.Array, tile: int = DEFAULT_TILE):
    """C = A @ B with (tm, tk) x (tk, tn) Pallas tiles, f32 accumulate."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    tm = _pick_tile(m, tile)
    tk = _pick_tile(k, tile)
    tn = _pick_tile(n, tile)
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def vmem_bytes(tile: int) -> int:
    """Per-program VMEM estimate: A tile + B tile + C accumulator."""
    return 3 * tile * tile * 4
