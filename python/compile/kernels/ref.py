"""Pure-jnp correctness oracles for the Pallas kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def jacobi_step_ref(padded: jax.Array):
    """Reference Jacobi step on a halo-padded grid.

    Returns ``(new_interior, residual_sq_scalar)``.
    """
    center = padded[1:-1, 1:-1]
    new = 0.25 * (
        padded[:-2, 1:-1]
        + padded[2:, 1:-1]
        + padded[1:-1, :-2]
        + padded[1:-1, 2:]
    )
    diff = new - center
    return new, jnp.sum(diff * diff)


@jax.jit
def matmul_ref(a: jax.Array, b: jax.Array):
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)
