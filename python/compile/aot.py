"""AOT pipeline: lower the L2 entry points to HLO **text** artifacts.

Interchange format is HLO text, NOT serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published ``xla`` 0.1.6 crate links) rejects with
``proto.id() <= INT_MAX``. The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts are written to ``artifacts/`` together with ``manifest.txt``:

    # name  file  kind  dims...
    jacobi_step_64   jacobi_step_64.hlo.txt   jacobi_step 64 64
    jacobi_sweep_256_k50 ...                  jacobi_sweep 256 256 50
    gemm_256         gemm_256.hlo.txt         gemm 256 256 256

The Rust runtime (`runtime::artifacts`) parses the manifest and compiles
each module once on the PJRT CPU client.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile import model  # noqa: E402

# (name, lower_fn, kind, dims) table. Domain sizes cover the per-rank
# local domains used by the benches: fig8 runs 16 ranks on a 1024x256
# global grid -> 64x256 local domains are padded to squares via the
# closest artifact; we ship the sizes the workloads actually request.
JACOBI_SIZES = [32, 64, 128, 256]
SWEEPS = [(256, 50), (128, 100)]
GEMM_SIZES = [128, 256]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entries():
    for n in JACOBI_SIZES:
        spec = jax.ShapeDtypeStruct((n + 2, n + 2), jnp.float32)
        yield (
            f"jacobi_step_{n}",
            jax.jit(model.jacobi_step).lower(spec),
            "jacobi_step",
            [n, n],
        )
    for n, k in SWEEPS:
        spec = jax.ShapeDtypeStruct((n + 2, n + 2), jnp.float32)
        yield (
            f"jacobi_sweep_{n}_k{k}",
            jax.jit(model.jacobi_sweep, static_argnames=("steps",)).lower(
                spec, steps=k
            ),
            "jacobi_sweep",
            [n, n, k],
        )
    for n in GEMM_SIZES:
        spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
        yield (
            f"gemm_{n}",
            jax.jit(model.gemm).lower(spec, spec),
            "gemm",
            [n, n, n],
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=None, help="artifacts directory")
    # legacy single-file flag kept for the original Makefile shape
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    out_dir = args.out_dir
    if out_dir is None and args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    if out_dir is None:
        out_dir = os.path.join(
            os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
            "artifacts",
        )
    os.makedirs(out_dir, exist_ok=True)

    manifest_lines = ["# name file kind dims..."]
    total = 0
    for name, lowered, kind, dims in entries():
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest_lines.append(
            f"{name} {fname} {kind} {' '.join(str(d) for d in dims)}"
        )
        total += len(text)
        print(f"  lowered {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote {len(manifest_lines) - 1} artifacts ({total} chars) to {out_dir}")


if __name__ == "__main__":
    main()
