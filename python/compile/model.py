"""L2: the JAX compute graphs the Rust MPI ranks execute via PJRT.

These are the functions `aot.py` lowers to HLO text. Each calls the L1
Pallas kernels, so kernel + glue lower into a single HLO module that the
`xla` crate's CPU PJRT client can compile and run.

Entry points
------------
``jacobi_step(padded)``  -> (new_interior, residual_sq)
    One distributed-solver step on a rank's halo-padded local domain.
    The Rust side performs the halo exchange between calls (MPI over the
    virtual fabric), so the artifact is exchange-agnostic.

``jacobi_sweep(padded, steps=K)``  -> (final_padded, residual_sq)
    K fused steps on a *single* domain with fixed (Dirichlet) boundary —
    used by the serial oracle and by perf measurements to amortize
    dispatch. Boundary rows/cols are preserved each step.

``gemm(a, b)`` -> C
    Local panel multiply for the GEMM workload.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels import matmul as matmul_kernel
from compile.kernels import stencil


@functools.partial(jax.jit, static_argnames=("block",))
def jacobi_step(padded: jax.Array, block: int = stencil.DEFAULT_BLOCK):
    """One Jacobi step: Pallas tile sweep + fused residual reduction."""
    new, partials = stencil.jacobi_step(padded, block=block)
    return new, jnp.sum(partials)


def _repad(padded: jax.Array, interior: jax.Array) -> jax.Array:
    """Write a new interior back into the fixed boundary frame."""
    return padded.at[1:-1, 1:-1].set(interior)


@functools.partial(jax.jit, static_argnames=("steps", "block"), donate_argnums=0)
def jacobi_sweep(
    padded: jax.Array, steps: int, block: int = stencil.DEFAULT_BLOCK
):
    """K fused Jacobi steps with fixed boundary; returns last residual."""

    def body(_, carry):
        grid, _res = carry
        new, res = jacobi_step(grid, block=block)
        return _repad(grid, new), res

    init = (padded, jnp.float32(0.0))
    final, res = jax.lax.fori_loop(0, steps, body, init)
    return final, res


@functools.partial(jax.jit, static_argnames=("tile",))
def gemm(a: jax.Array, b: jax.Array, tile: int = matmul_kernel.DEFAULT_TILE):
    return matmul_kernel.matmul(a, b, tile=tile)
