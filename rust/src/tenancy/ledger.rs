//! Per-tenant usage ledger: slot-second accounting with exponential
//! half-life decay, plus the quota knobs the head enforces.
//!
//! The ledger is the memory behind fair-share scheduling: every second a
//! tenant's jobs hold reserved slots, the tenant is charged that many
//! slot-seconds; the balance then decays with a configurable half-life,
//! so a tenant that burned the cluster yesterday outranks one that
//! burned it an hour ago, and both eventually forget. Accounts are
//! created lazily on first charge — a population of 100k mostly-idle
//! tenants costs memory only for the tenants that actually ran.

use crate::sim::SimTime;
use std::collections::HashMap;

/// What happens to a submission that would push its tenant over the
/// queued-job quota.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuotaAction {
    /// Record the job as permanently failed with a quota reason.
    Reject,
    /// Park the job in a per-tenant holding pen; it is admitted (FIFO
    /// within the tenant, tenants in id order) as soon as the tenant is
    /// back under quota. Deferred jobs are *not* demand: they do not
    /// count toward the autoscaler's queued-slot signal.
    Defer,
}

/// Per-tenant limits, enforced uniformly for every tenant (including
/// the untenanted id 0). The defaults are unlimited, which reproduces
/// the pre-tenancy cluster exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantQuotas {
    /// Most slots one tenant's running jobs may hold at once. A queued
    /// job whose start would exceed this is invisible to the dispatch
    /// policy until enough of the tenant's work finishes — it never
    /// blocks other tenants' jobs behind it.
    pub max_running_slots: u32,
    /// Most jobs one tenant may have waiting in the queue. Submissions
    /// past the cap are rejected or deferred per [`QuotaAction`].
    pub max_queued_jobs: usize,
    /// Over-quota disposition for submissions.
    pub over_quota: QuotaAction,
}

impl Default for TenantQuotas {
    fn default() -> Self {
        Self {
            max_running_slots: u32::MAX,
            max_queued_jobs: usize::MAX,
            over_quota: QuotaAction::Reject,
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Account {
    /// Decayed slot-seconds as of `as_of`.
    usage: f64,
    as_of: SimTime,
}

/// Decay multiplier for a balance left untouched for `dt`.
fn decay(half_life: SimTime, dt: SimTime) -> f64 {
    if half_life == SimTime::ZERO {
        return 0.0;
    }
    (-(dt.as_secs_f64() / half_life.as_secs_f64())).exp2()
}

/// The ledger: lazily-created per-tenant accounts of decayed
/// slot-second usage, plus per-tenant share-weight multipliers.
#[derive(Debug, Clone)]
pub struct UsageLedger {
    /// Time for an untouched balance to halve. `ZERO` means no memory
    /// at all (every read sees 0 — fair-share degenerates to FIFO).
    pub half_life: SimTime,
    accounts: HashMap<u64, Account>,
    /// Per-tenant share multipliers (absent = 1.0). A weight-2 tenant's
    /// usage normalizes to half, so fair-share grants it twice the
    /// service, and the autoscaler's share cap scales the same way.
    weights: HashMap<u64, f64>,
    /// Bumped on every mutation (charge, weight change, gc, restore).
    /// Caches built over ledger reads — the head's policy queue view —
    /// compare versions instead of subscribing to each mutator.
    version: u64,
}

impl Default for UsageLedger {
    /// One-hour half-life: long enough to remember a burst, short
    /// enough that an hour of idleness roughly clears the slate.
    fn default() -> Self {
        Self::new(SimTime::from_secs(3600))
    }
}

impl UsageLedger {
    pub fn new(half_life: SimTime) -> Self {
        Self {
            half_life,
            accounts: HashMap::new(),
            weights: HashMap::new(),
            version: 0,
        }
    }

    /// The ledger's mutation counter: changes if and only if a read at
    /// a fixed `now` could return something different than before.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Set a tenant's fair-share weight multiplier (must be positive;
    /// non-positive values are ignored). Weight 2.0 earns the tenant
    /// twice the fair share of an unweighted tenant.
    pub fn set_weight(&mut self, tenant: u64, weight: f64) {
        if weight > 0.0 && weight.is_finite() {
            self.weights.insert(tenant, weight);
            self.version += 1;
        }
    }

    /// The tenant's share weight (1.0 unless configured otherwise).
    pub fn weight(&self, tenant: u64) -> f64 {
        self.weights.get(&tenant).copied().unwrap_or(1.0)
    }

    /// Decayed usage divided by the tenant's share weight — what the
    /// fair-share policy actually orders by. With no weights configured
    /// this is exactly [`UsageLedger::usage_at`].
    pub fn normalized_usage_at(&self, tenant: u64, now: SimTime) -> f64 {
        self.usage_at(tenant, now) / self.weight(tenant)
    }

    /// A fresh ledger carrying this one's configuration (half-life and
    /// weights) but no balances — the HA takeover shape: balances come
    /// from snapshot + WAL replay, config from the deployment.
    pub fn config_clone(&self) -> UsageLedger {
        UsageLedger {
            half_life: self.half_life,
            accounts: HashMap::new(),
            weights: self.weights.clone(),
            version: 0,
        }
    }

    /// Export all accounts `(tenant, decayed balance, as-of)`, sorted by
    /// tenant — the HA snapshot shape.
    pub fn export_accounts(&self) -> Vec<(u64, f64, SimTime)> {
        let mut v: Vec<(u64, f64, SimTime)> = self
            .accounts
            .iter() // lint: sorted
            .map(|(&t, a)| (t, a.usage, a.as_of))
            .collect();
        v.sort_by_key(|e| e.0);
        v
    }

    /// Replace all accounts from an [`UsageLedger::export_accounts`]
    /// dump (weights and half-life are untouched).
    pub fn restore_accounts(&mut self, accounts: &[(u64, f64, SimTime)]) {
        self.accounts = accounts
            .iter()
            .map(|&(t, usage, as_of)| (t, Account { usage, as_of }))
            .collect();
        self.version += 1;
    }

    /// Add `slot_seconds` of usage for a tenant at `now`, decaying the
    /// existing balance first. Negative charges are ignored.
    pub fn charge(&mut self, tenant: u64, slot_seconds: f64, now: SimTime) {
        let hl = self.half_life;
        let acct = self
            .accounts
            .entry(tenant)
            .or_insert(Account { usage: 0.0, as_of: now });
        let dt = now.saturating_sub(acct.as_of);
        acct.usage = acct.usage * decay(hl, dt) + slot_seconds.max(0.0);
        acct.as_of = now;
        self.version += 1;
    }

    /// The tenant's decayed usage as seen at `now` (0 for tenants that
    /// never ran). Pure read: nothing is mutated, so policies can
    /// consult it freely mid-decision.
    pub fn usage_at(&self, tenant: u64, now: SimTime) -> f64 {
        match self.accounts.get(&tenant) {
            Some(a) => a.usage * decay(self.half_life, now.saturating_sub(a.as_of)),
            None => 0.0,
        }
    }

    /// How many tenants currently hold an account (ran at least once
    /// since the last [`UsageLedger::gc`]).
    pub fn active_accounts(&self) -> usize {
        self.accounts.len()
    }

    /// Drop accounts whose decayed balance at `now` has fallen to
    /// `threshold` slot-seconds or below — the memory bound that keeps
    /// a 100k-tenant population from accreting dead accounts forever.
    pub fn gc(&mut self, now: SimTime, threshold: f64) {
        let hl = self.half_life;
        self.accounts.retain(|_, a| {
            a.usage * decay(hl, now.saturating_sub(a.as_of)) > threshold
        });
        self.version += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_halves_after_one_half_life() {
        let mut l = UsageLedger::new(SimTime::from_secs(600));
        l.charge(1, 100.0, SimTime::ZERO);
        assert_eq!(l.usage_at(1, SimTime::ZERO), 100.0);
        let half = l.usage_at(1, SimTime::from_secs(600));
        assert!((half - 50.0).abs() < 1e-9, "one half-life must halve: {half}");
        let quarter = l.usage_at(1, SimTime::from_secs(1200));
        assert!((quarter - 25.0).abs() < 1e-9, "two half-lives must quarter: {quarter}");
    }

    #[test]
    fn charge_decays_the_prior_balance_first() {
        let mut l = UsageLedger::new(SimTime::from_secs(600));
        l.charge(7, 100.0, SimTime::ZERO);
        l.charge(7, 10.0, SimTime::from_secs(600));
        let got = l.usage_at(7, SimTime::from_secs(600));
        assert!((got - 60.0).abs() < 1e-9, "50 decayed + 10 fresh: {got}");
    }

    #[test]
    fn unknown_tenants_read_zero_and_negative_charges_are_ignored() {
        let mut l = UsageLedger::default();
        assert_eq!(l.usage_at(42, SimTime::from_secs(5)), 0.0);
        l.charge(42, -10.0, SimTime::ZERO);
        assert_eq!(l.usage_at(42, SimTime::ZERO), 0.0);
    }

    #[test]
    fn zero_half_life_forgets_instantly() {
        let mut l = UsageLedger::new(SimTime::ZERO);
        l.charge(1, 100.0, SimTime::ZERO);
        assert_eq!(l.usage_at(1, SimTime::from_secs(1)), 0.0);
    }

    #[test]
    fn gc_drops_decayed_accounts() {
        let mut l = UsageLedger::new(SimTime::from_secs(10));
        l.charge(1, 100.0, SimTime::ZERO);
        l.charge(2, 1e6, SimTime::ZERO);
        assert_eq!(l.active_accounts(), 2);
        // after 20 half-lives tenant 1 is below a 0.01 threshold
        l.gc(SimTime::from_secs(200), 0.01);
        assert_eq!(l.active_accounts(), 1);
        assert_eq!(l.usage_at(1, SimTime::from_secs(200)), 0.0);
        assert!(l.usage_at(2, SimTime::from_secs(200)) > 0.0);
    }

    #[test]
    fn share_weights_normalize_usage() {
        let mut l = UsageLedger::new(SimTime::from_secs(600));
        l.set_weight(1, 2.0);
        l.set_weight(2, 0.0); // ignored: weights must be positive
        l.set_weight(3, f64::NAN); // ignored: weights must be finite
        assert_eq!(l.weight(1), 2.0);
        assert_eq!(l.weight(2), 1.0);
        assert_eq!(l.weight(3), 1.0);
        l.charge(1, 100.0, SimTime::ZERO);
        l.charge(2, 100.0, SimTime::ZERO);
        // same raw usage, but tenant 1's normalized view is halved: it
        // outranks tenant 2 in fair-share order
        assert_eq!(l.usage_at(1, SimTime::ZERO), l.usage_at(2, SimTime::ZERO));
        assert_eq!(l.normalized_usage_at(1, SimTime::ZERO), 50.0);
        assert_eq!(l.normalized_usage_at(2, SimTime::ZERO), 100.0);
    }

    #[test]
    fn export_restore_roundtrips_and_config_clone_keeps_weights() {
        let mut l = UsageLedger::new(SimTime::from_secs(600));
        l.set_weight(7, 3.0);
        l.charge(7, 123.456, SimTime::from_secs(10));
        l.charge(9, 0.125, SimTime::from_secs(20));
        let dump = l.export_accounts();
        assert_eq!(dump.len(), 2);
        assert!(dump[0].0 < dump[1].0, "export must be tenant-sorted");
        let mut fresh = l.config_clone();
        assert_eq!(fresh.active_accounts(), 0, "config clone carries no balances");
        assert_eq!(fresh.weight(7), 3.0, "config clone keeps weights");
        fresh.restore_accounts(&dump);
        for t in [7u64, 9] {
            assert_eq!(
                fresh.usage_at(t, SimTime::from_secs(30)),
                l.usage_at(t, SimTime::from_secs(30)),
                "restored balance must read bit-identically for tenant {t}"
            );
        }
    }

    #[test]
    fn version_bumps_on_every_mutation_and_only_mutations() {
        let mut l = UsageLedger::new(SimTime::from_secs(600));
        let v0 = l.version();
        // pure reads must not move the version
        let _ = l.usage_at(1, SimTime::from_secs(5));
        let _ = l.normalized_usage_at(1, SimTime::from_secs(5));
        let _ = l.export_accounts();
        assert_eq!(l.version(), v0);
        l.charge(1, 10.0, SimTime::ZERO);
        let v1 = l.version();
        assert_ne!(v1, v0, "charge must bump the version");
        l.set_weight(1, 2.0);
        let v2 = l.version();
        assert_ne!(v2, v1, "weight change must bump the version");
        l.set_weight(1, -1.0); // ignored weight: no observable change
        assert_eq!(l.version(), v2);
        l.gc(SimTime::from_secs(1_000_000), 0.0);
        let v3 = l.version();
        assert_ne!(v3, v2, "gc must bump the version");
        l.restore_accounts(&[(9, 5.0, SimTime::ZERO)]);
        assert_ne!(l.version(), v3, "restore must bump the version");
    }

    #[test]
    fn default_quotas_are_unlimited_reject() {
        let q = TenantQuotas::default();
        assert_eq!(q.max_running_slots, u32::MAX);
        assert_eq!(q.max_queued_jobs, usize::MAX);
        assert_eq!(q.over_quota, QuotaAction::Reject);
    }
}
