//! Multi-tenant workload layer: who submits jobs, how fast, and how the
//! scheduler keeps the cluster fair between them.
//!
//! The paper's virtual cluster exists so *many* scientists can share one
//! pool of hardware; this module supplies the missing notion of a user:
//!
//! * [`arrivals`] — a seeded, deterministic **open-loop workload
//!   source**: a tenant population with power-law-skewed per-tenant
//!   Poisson rates, diurnal load modulation and bursty "campaign"
//!   episodes. The generator samples the *mixture* (O(1) per arrival),
//!   never iterates the population, so it scales from 10 to 100k+
//!   tenants without materializing per-tenant state for idle users.
//! * [`ledger`] — per-tenant **slot-second accounting** with
//!   exponential half-life decay, plus per-tenant quotas (max running
//!   slots, max queued jobs; over-quota submissions are rejected or
//!   deferred deterministically).
//! * [`fairshare`] — the `fairshare`
//!   [`SchedulePolicy`](crate::cluster::policy::SchedulePolicy): the
//!   queue is ordered by decayed-usage fair-share factor (classic
//!   max-min style — lowest normalized usage first, FIFO within a
//!   tenant), composed with the EASY backfill shadow-time machinery,
//!   and the autoscaler's demand signal is share-capped so one heavy
//!   tenant cannot force unbounded scale-up.
//!
//! Jobs carry their tenant on [`JobSpec`](crate::cluster::head::JobSpec)
//! end to end: fault requeues and preemptions keep the attribution, so
//! reruns charge the right ledger account. Tenant id `0` is reserved
//! for untenanted (system/anonymous) work and behaves exactly like the
//! pre-tenancy cluster under the default unlimited quotas.

pub mod arrivals;
pub mod fairshare;
pub mod ledger;

pub use arrivals::{stream_fingerprint, ArrivalGen, JobArrival, PopulationSpec};
pub use fairshare::{decide_fairshare, share_weighted_demand};
pub use ledger::{QuotaAction, TenantQuotas, UsageLedger};
