//! Open-loop multi-tenant workload source: a seeded, deterministic
//! stream of job arrivals drawn from a tenant population.
//!
//! Three load shapes compose:
//!
//! * **Population mixture** — tenant `i`'s arrival rate is proportional
//!   to a power-law weight `i^-skew`, so a few heavy tenants dominate a
//!   long tail of light ones (the shape every shared cluster sees).
//!   The aggregate stream of independent per-tenant Poisson processes
//!   is itself Poisson at the summed rate, so the generator draws the
//!   *aggregate* arrival and then attributes it to a tenant by
//!   inverse-CDF sampling of the continuous power-law mixture — O(1)
//!   per arrival, no per-tenant state, which is what lets a population
//!   of 100k+ idle-mostly tenants cost nothing until they submit.
//! * **Diurnal modulation** — the aggregate rate swings sinusoidally
//!   around its mean (Lewis–Shedler thinning against the peak rate), so
//!   the autoscaler sees genuine peak/trough cycles.
//! * **Campaigns** — with a small probability an arrival kicks off a
//!   burst: the same tenant submits several follow-up jobs at short,
//!   fixed spacing (priority 2 — a scientist pushing a parameter sweep
//!   and hammering refresh). Campaigns are what make per-tenant
//!   fairness interesting: one tenant's burst must not starve the tail.
//!
//! Everything is drawn from one explicitly seeded [`Rng`], so the same
//! [`PopulationSpec`] always produces a byte-identical arrival stream —
//! the determinism the `ext_tenancy` bench fingerprints.

use crate::sim::SimTime;
use crate::util::Rng;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, BTreeMap};

/// Job widths the generator draws from (weighted toward narrow work,
/// the realistic mix for a 12-slot-per-node cluster).
const RANK_MENU: [u32; 8] = [1, 2, 4, 4, 8, 8, 12, 16];

/// The tenant population and its load shape. All rates are in jobs per
/// virtual second; the spec is plain data so drivers can tweak knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PopulationSpec {
    /// Population size. Tenant ids run 1..=tenants (0 is reserved for
    /// untenanted system work).
    pub tenants: u64,
    /// Aggregate mean arrival rate at the diurnal midpoint, jobs/sec.
    /// This is deliberately *not* per-tenant: growing the population
    /// spreads the same load over more users instead of multiplying it.
    pub rate_per_sec: f64,
    /// Power-law skew `s >= 0` of per-tenant rates (`weight ~ i^-s`).
    /// 0 = uniform population; ~1.1 = classic heavy-head Zipf.
    pub skew: f64,
    /// Relative amplitude of the sinusoidal diurnal swing, in [0, 0.95].
    pub diurnal_amplitude: f64,
    /// Period of one "day". Benches compress this so a short run still
    /// sees peaks and troughs.
    pub diurnal_period: SimTime,
    /// Probability that an arrival starts a campaign burst.
    pub campaign_prob: f64,
    /// Most follow-up jobs a campaign adds (the draw is uniform in
    /// 1..=campaign_jobs).
    pub campaign_jobs: u32,
    /// Gap between consecutive jobs of one campaign.
    pub campaign_spacing: SimTime,
    /// Mean synthetic job duration, seconds (exponential, clamped to
    /// [5, 240]).
    pub mean_duration_secs: f64,
    /// Stream seed: same seed, same arrivals, byte for byte.
    pub seed: u64,
}

impl PopulationSpec {
    /// Defaults tuned for the 8-machine mix cluster: ~60-70% mean
    /// utilization with peaks that force queueing and scale-up.
    pub fn new(tenants: u64, seed: u64) -> Self {
        Self {
            tenants: tenants.max(1),
            rate_per_sec: 0.15,
            skew: 1.1,
            diurnal_amplitude: 0.6,
            diurnal_period: SimTime::from_secs(3600),
            campaign_prob: 0.05,
            campaign_jobs: 8,
            campaign_spacing: SimTime::from_secs(10),
            mean_duration_secs: 45.0,
            seed,
        }
    }
}

/// One synthesized job arrival. Times are offsets from stream start
/// (the driver anchors them to whenever its warm-up finished).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobArrival {
    pub at: SimTime,
    /// Tenant id in 1..=population.
    pub tenant: u64,
    pub ranks: u32,
    pub duration: SimTime,
    /// Campaign jobs arrive at priority 2 (an impatient burst); base
    /// arrivals at batch priority 0.
    pub priority: i32,
    pub campaign: bool,
}

/// A scheduled campaign follow-up (min-heap entry; `seq` breaks ties so
/// interleaved campaigns stay in spawn order).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct Pending {
    at: SimTime,
    seq: u64,
    tenant: u64,
    ranks: u32,
    dur: SimTime,
}

/// The generator: pull [`JobArrival`]s one at a time, in time order.
#[derive(Debug, Clone)]
pub struct ArrivalGen {
    spec: PopulationSpec,
    rng: Rng,
    /// Time of the last base (non-campaign) arrival candidate.
    t: SimTime,
    /// The next base arrival, drawn but not yet emitted.
    next_base: Option<JobArrival>,
    /// Campaign follow-ups waiting for their timestamps.
    pending: BinaryHeap<Reverse<Pending>>,
    seq: u64,
}

impl ArrivalGen {
    pub fn new(mut spec: PopulationSpec) -> Self {
        spec.tenants = spec.tenants.max(1);
        spec.rate_per_sec = spec.rate_per_sec.max(1e-9);
        spec.diurnal_amplitude = spec.diurnal_amplitude.clamp(0.0, 0.95);
        let seed = spec.seed;
        Self {
            spec,
            rng: Rng::new(seed ^ 0x7E4A_4755),
            t: SimTime::ZERO,
            next_base: None,
            pending: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Aggregate arrival rate at time `t` (diurnal modulation applied).
    fn rate_at(&self, t: SimTime) -> f64 {
        let a = self.spec.diurnal_amplitude;
        if a == 0.0 || self.spec.diurnal_period == SimTime::ZERO {
            return self.spec.rate_per_sec;
        }
        let phase = 2.0 * std::f64::consts::PI * t.as_secs_f64()
            / self.spec.diurnal_period.as_secs_f64();
        self.spec.rate_per_sec * (1.0 + a * phase.sin())
    }

    /// Attribute an arrival to a tenant: inverse-CDF sample of the
    /// continuous power-law mixture on [1, tenants+1). O(1) — the
    /// population is never iterated or materialized.
    fn sample_tenant(&mut self) -> u64 {
        let n = self.spec.tenants;
        let s = self.spec.skew;
        let u = self.rng.gen_f64();
        if s <= 1e-9 {
            return 1 + (u * n as f64) as u64;
        }
        let top = (n + 1) as f64;
        let x = if (s - 1.0).abs() < 1e-9 {
            top.powf(u)
        } else {
            let a = 1.0 - s;
            (1.0 + u * (top.powf(a) - 1.0)).powf(1.0 / a)
        };
        (x as u64).clamp(1, n)
    }

    /// Draw a job's width and duration.
    fn sample_shape(&mut self) -> (u32, SimTime) {
        let ranks = RANK_MENU[self.rng.gen_range(RANK_MENU.len() as u64) as usize];
        let secs = self.rng.gen_exp(self.spec.mean_duration_secs).clamp(5.0, 240.0);
        (ranks, SimTime::from_secs_f64(secs))
    }

    /// Next base arrival via Lewis–Shedler thinning against the peak
    /// rate: candidates come at the peak-rate Poisson cadence and are
    /// accepted with probability `rate(t) / peak`.
    fn draw_base(&mut self) -> JobArrival {
        let peak = self.spec.rate_per_sec * (1.0 + self.spec.diurnal_amplitude);
        loop {
            self.t = self.t + SimTime::from_secs_f64(self.rng.gen_exp(1.0 / peak));
            if self.rng.gen_f64() < self.rate_at(self.t) / peak {
                break;
            }
        }
        let tenant = self.sample_tenant();
        let (ranks, duration) = self.sample_shape();
        JobArrival { at: self.t, tenant, ranks, duration, priority: 0, campaign: false }
    }

    /// The next arrival in time order (base arrivals merged with any
    /// campaign follow-ups already scheduled).
    pub fn next(&mut self) -> JobArrival {
        if self.next_base.is_none() {
            let base = self.draw_base();
            if self.rng.gen_bool(self.spec.campaign_prob) {
                let burst =
                    1 + self.rng.gen_range(self.spec.campaign_jobs.max(1) as u64) as u32;
                for i in 1..=burst {
                    let (ranks, dur) = self.sample_shape();
                    self.seq += 1;
                    self.pending.push(Reverse(Pending {
                        at: base.at
                            + SimTime::from_nanos(
                                self.spec.campaign_spacing.as_nanos() * i as u64,
                            ),
                        seq: self.seq,
                        tenant: base.tenant,
                        ranks,
                        dur,
                    }));
                }
            }
            self.next_base = Some(base);
        }
        let base_at = self.next_base.as_ref().expect("just ensured").at;
        if let Some(Reverse(p)) = self.pending.peek().copied() {
            if p.at <= base_at {
                self.pending.pop();
                return JobArrival {
                    at: p.at,
                    tenant: p.tenant,
                    ranks: p.ranks,
                    duration: p.dur,
                    priority: 2,
                    campaign: true,
                };
            }
        }
        self.next_base.take().expect("just ensured")
    }

    /// Convenience: the next `n` arrivals.
    pub fn take(&mut self, n: usize) -> Vec<JobArrival> {
        (0..n).map(|_| self.next()).collect()
    }

    /// Serialize the generator's mid-stream position — RNG state,
    /// thinning clock, drawn-but-unemitted base arrival and the
    /// campaign heap — into one line of text. Paired with
    /// [`ArrivalGen::restore`], a resumed generator emits exactly the
    /// arrivals the original would have emitted next. This is what the
    /// HA head journals after each pull, so a standby continues the
    /// tenant stream byte-identically after a takeover.
    pub fn cursor(&self) -> String {
        let mut out = format!("arr1 {} {} {}", self.rng.state(), self.t.as_nanos(), self.seq);
        match &self.next_base {
            Some(b) => out.push_str(&format!(
                " {}:{}:{}:{}:{}:{}",
                b.at.as_nanos(),
                b.tenant,
                b.ranks,
                b.duration.as_nanos(),
                b.priority,
                b.campaign as u8
            )),
            None => out.push_str(" -"),
        }
        // the heap's internal layout is unspecified: emit entries sorted
        // so identical positions always encode byte-identically
        let mut pend: Vec<Pending> = self.pending.iter().map(|&Reverse(p)| p).collect();
        pend.sort();
        out.push_str(&format!(" {}", pend.len()));
        for p in pend {
            out.push_str(&format!(
                " {}:{}:{}:{}:{}",
                p.at.as_nanos(),
                p.seq,
                p.tenant,
                p.ranks,
                p.dur.as_nanos()
            ));
        }
        out
    }

    /// Rebuild a generator at a [`cursor`](ArrivalGen::cursor) position.
    /// `spec` must be the population the cursor was taken from — the
    /// cursor carries only dynamic state; config comes from deployment,
    /// exactly like the HA snapshot's treatment of head config.
    pub fn restore(spec: PopulationSpec, cursor: &str) -> Result<Self, String> {
        fn field<'a>(
            it: &mut std::str::SplitWhitespace<'a>,
            what: &str,
        ) -> Result<&'a str, String> {
            it.next().ok_or_else(|| format!("truncated arrival cursor at {what}"))
        }
        fn num<T: std::str::FromStr>(tok: &str, what: &str) -> Result<T, String> {
            tok.parse().map_err(|_| format!("bad {what} in arrival cursor: {tok}"))
        }
        let mut it = cursor.split_whitespace();
        let version = field(&mut it, "version")?;
        if version != "arr1" {
            return Err(format!("unknown arrival cursor version: {version}"));
        }
        let mut gen = Self::new(spec);
        gen.rng = Rng::from_state(num(field(&mut it, "rng state")?, "rng state")?);
        gen.t = SimTime::from_nanos(num(field(&mut it, "thinning clock")?, "thinning clock")?);
        gen.seq = num(field(&mut it, "seq")?, "seq")?;
        let base = field(&mut it, "next_base")?;
        gen.next_base = if base == "-" {
            None
        } else {
            let parts: Vec<&str> = base.split(':').collect();
            if parts.len() != 6 {
                return Err(format!("bad next_base in arrival cursor: {base}"));
            }
            Some(JobArrival {
                at: SimTime::from_nanos(num(parts[0], "next_base at")?),
                tenant: num(parts[1], "next_base tenant")?,
                ranks: num(parts[2], "next_base ranks")?,
                duration: SimTime::from_nanos(num(parts[3], "next_base duration")?),
                priority: num(parts[4], "next_base priority")?,
                campaign: parts[5] == "1",
            })
        };
        let n: usize = num(field(&mut it, "pending count")?, "pending count")?;
        gen.pending = BinaryHeap::with_capacity(n);
        for _ in 0..n {
            let tok = field(&mut it, "pending entry")?;
            let parts: Vec<&str> = tok.split(':').collect();
            if parts.len() != 5 {
                return Err(format!("bad pending entry in arrival cursor: {tok}"));
            }
            gen.pending.push(Reverse(Pending {
                at: SimTime::from_nanos(num(parts[0], "pending at")?),
                seq: num(parts[1], "pending seq")?,
                tenant: num(parts[2], "pending tenant")?,
                ranks: num(parts[3], "pending ranks")?,
                dur: SimTime::from_nanos(num(parts[4], "pending dur")?),
            }));
        }
        if it.next().is_some() {
            return Err(format!("trailing tokens in arrival cursor: {cursor}"));
        }
        Ok(gen)
    }
}

/// Order-sensitive FNV-style fingerprint of an arrival stream — the
/// determinism check the tenancy bench and tests compare across
/// same-seed runs (as `ext_faults` does with metric counters).
pub fn stream_fingerprint(arrivals: &[JobArrival]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for a in arrivals {
        for v in [
            a.at.as_nanos(),
            a.tenant,
            a.ranks as u64,
            a.duration.as_nanos(),
            a.priority as u64,
            a.campaign as u64,
        ] {
            h ^= v;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Per-tenant arrival counts (stable order) — the coarse fingerprint
/// for population-shape assertions.
pub fn tenant_counts(arrivals: &[JobArrival]) -> BTreeMap<u64, u64> {
    let mut counts = BTreeMap::new();
    for a in arrivals {
        *counts.entry(a.tenant).or_insert(0) += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_time_ordered_and_in_population_range() {
        let mut g = ArrivalGen::new(PopulationSpec::new(50, 7));
        let xs = g.take(500);
        let mut last = SimTime::ZERO;
        for a in &xs {
            assert!(a.at >= last, "arrivals must be time-ordered");
            last = a.at;
            assert!((1..=50).contains(&a.tenant), "tenant {} out of range", a.tenant);
            assert!(a.ranks >= 1 && a.duration >= SimTime::from_secs(5));
        }
    }

    #[test]
    fn same_seed_same_stream_different_seed_differs() {
        let a = ArrivalGen::new(PopulationSpec::new(1000, 42)).take(400);
        let b = ArrivalGen::new(PopulationSpec::new(1000, 42)).take(400);
        assert_eq!(a, b, "same seed must replay byte-identically");
        assert_eq!(stream_fingerprint(&a), stream_fingerprint(&b));
        let c = ArrivalGen::new(PopulationSpec::new(1000, 43)).take(400);
        assert_ne!(stream_fingerprint(&a), stream_fingerprint(&c));
    }

    #[test]
    fn skewed_population_concentrates_load_on_the_head() {
        let mut spec = PopulationSpec::new(10_000, 5);
        spec.skew = 1.2;
        let xs = ArrivalGen::new(spec).take(2000);
        let counts = tenant_counts(&xs);
        let head: u64 = counts.iter().filter(|(t, _)| **t <= 10).map(|(_, c)| c).sum();
        assert!(
            head > 2000 / 10,
            "top-10 tenants of 10k must draw far more than their uniform share: {head}"
        );
    }

    #[test]
    fn uniform_population_spreads_load() {
        let mut spec = PopulationSpec::new(10, 5);
        spec.skew = 0.0;
        spec.campaign_prob = 0.0;
        let xs = ArrivalGen::new(spec).take(2000);
        let counts = tenant_counts(&xs);
        assert!(counts.len() >= 9, "a uniform 10-tenant draw must hit nearly all");
        for (_, c) in counts {
            assert!(c > 100, "uniform tenants must each get a real share: {c}");
        }
    }

    #[test]
    fn huge_population_is_cheap_and_stateless() {
        // 10M tenants: the generator must not allocate per tenant
        let mut g = ArrivalGen::new(PopulationSpec::new(10_000_000, 9));
        let xs = g.take(1000);
        assert_eq!(xs.len(), 1000);
        assert!(xs.iter().all(|a| a.tenant >= 1 && a.tenant <= 10_000_000));
    }

    #[test]
    fn campaigns_burst_on_one_tenant_at_priority_two() {
        let mut spec = PopulationSpec::new(100, 11);
        spec.campaign_prob = 1.0; // every arrival campaigns
        spec.campaign_jobs = 4;
        let xs = ArrivalGen::new(spec).take(50);
        let bursts: Vec<&JobArrival> = xs.iter().filter(|a| a.campaign).collect();
        assert!(!bursts.is_empty(), "campaign_prob 1.0 must produce bursts");
        for b in &bursts {
            assert_eq!(b.priority, 2, "campaign jobs arrive urgent");
        }
        // a campaign's jobs stick to the spawning tenant: every campaign
        // job's tenant must also appear as a base arrival's tenant
        for b in &bursts {
            assert!(
                xs.iter().any(|a| !a.campaign && a.tenant == b.tenant),
                "campaign job for tenant {} has no base arrival",
                b.tenant
            );
        }
    }

    #[test]
    fn cursor_resumes_the_exact_stream_mid_flight() {
        let mut spec = PopulationSpec::new(100, 17);
        spec.campaign_prob = 0.4; // keep the pending heap populated
        spec.campaign_jobs = 5;
        // checkpoint at several depths, including mid-campaign
        for consumed in [0usize, 1, 37, 200] {
            let mut g = ArrivalGen::new(spec);
            let _ = g.take(consumed);
            let cursor = g.cursor();
            let mut resumed = ArrivalGen::restore(spec, &cursor)
                .unwrap_or_else(|e| panic!("{cursor}: {e}"));
            assert_eq!(
                g.take(300),
                resumed.take(300),
                "resumed stream diverged after {consumed} consumed arrivals"
            );
        }
    }

    #[test]
    fn cursor_roundtrips_byte_identically() {
        let mut spec = PopulationSpec::new(50, 23);
        spec.campaign_prob = 1.0;
        let mut g = ArrivalGen::new(spec);
        let _ = g.take(40);
        let cursor = g.cursor();
        let resumed = ArrivalGen::restore(spec, &cursor).unwrap();
        assert_eq!(resumed.cursor(), cursor, "restore must reproduce the cursor exactly");
    }

    #[test]
    fn restore_rejects_garbage_cursors() {
        let spec = PopulationSpec::new(10, 1);
        assert!(ArrivalGen::restore(spec, "").is_err());
        assert!(ArrivalGen::restore(spec, "arr9 1 2 3 - 0").is_err(), "unknown version");
        assert!(ArrivalGen::restore(spec, "arr1 1 2").is_err(), "truncated");
        assert!(ArrivalGen::restore(spec, "arr1 1 2 3 nope 0").is_err(), "bad base");
        assert!(ArrivalGen::restore(spec, "arr1 1 2 3 - 2 1:2:3:4:5").is_err(), "short heap");
        assert!(ArrivalGen::restore(spec, "arr1 1 2 3 - 0 extra").is_err(), "trailing");
    }

    #[test]
    fn diurnal_modulation_shifts_density_between_half_periods() {
        let mut spec = PopulationSpec::new(100, 13);
        spec.diurnal_amplitude = 0.9;
        spec.diurnal_period = SimTime::from_secs(1000);
        spec.campaign_prob = 0.0;
        spec.rate_per_sec = 1.0;
        let xs = ArrivalGen::new(spec).take(2000);
        // first half-period (sin > 0) must be denser than the second
        let mut peak = 0u64;
        let mut trough = 0u64;
        for a in &xs {
            let t = a.at.as_secs_f64() % 1000.0;
            if t < 500.0 {
                peak += 1;
            } else {
                trough += 1;
            }
        }
        assert!(
            peak > trough + trough / 2,
            "peak half must clearly out-draw the trough: {peak} vs {trough}"
        );
    }
}
