//! Fair-share dispatch: order the queue by decayed per-tenant usage,
//! and cap the autoscaler's demand signal by tenant share.
//!
//! The `fairshare` [`PolicyKind`](crate::cluster::policy::PolicyKind)
//! is classic max-min fair queueing over the
//! [`UsageLedger`](crate::tenancy::ledger::UsageLedger): the queued job
//! whose tenant has the **lowest normalized decayed usage** is the
//! policy's head (FIFO within a tenant, since all of a tenant's queued
//! jobs see the same usage and ids break the tie in submit order). A
//! tenant that just burned a thousand slot-seconds sinks behind the
//! long tail of light tenants until the ledger's half-life forgets.
//!
//! Blocked heads compose with the **EASY shadow-time machinery**
//! (`cluster/policy.rs`): the fair-share head gets a reservation
//! computed from the running jobs' predicted finishes, and other jobs
//! (in fair-share order) may jump ahead only if they are predicted to
//! finish before that reservation or fit the slots it leaves spare. As
//! with EASY, the decision itself holds no state between calls, so a
//! fault that kills a prediction cannot wedge the head. The usage
//! figures the decision orders by arrive through the head's memoized
//! queue view: structural changes invalidate it outright, while ledger
//! drift (a charge, a weight change, decay with the clock) only
//! refreshes the per-tenant usage in place — computed by the same pure
//! [`UsageLedger::normalized_usage_at`](crate::tenancy::ledger::UsageLedger::normalized_usage_at)
//! call per distinct tenant, so the ordering is bit-identical to an
//! uncached rebuild.

use crate::cluster::policy::{shadow_time, Decision, QueuedJob, RunningJob};
use crate::sim::SimTime;
use std::cmp::Ordering;

/// Fair-share dispatch order: lowest decayed usage first, submit order
/// (job id) within a tenant and across exact ties.
pub fn fair_cmp(a: &QueuedJob, b: &QueuedJob) -> Ordering {
    a.usage.total_cmp(&b.usage).then(a.id.cmp(&b.id))
}

/// Pick the next action for the fair-share policy. Mirrors the EASY
/// decision procedure with the queue re-ordered by [`fair_cmp`].
pub fn decide_fairshare(
    now: SimTime,
    queue: &[QueuedJob],
    running: &[RunningJob],
    free: u32,
) -> Decision {
    let head_idx = (0..queue.len())
        .min_by(|&a, &b| fair_cmp(&queue[a], &queue[b]))
        .expect("caller checked queue non-empty");
    let head = &queue[head_idx];
    if head.ranks <= free {
        // the fair-share head is the policy's head of queue, not a
        // backfill, even when it overtakes older submissions
        return Decision::Start { idx: head_idx, backfilled: false };
    }
    let mut order: Vec<usize> = (0..queue.len()).filter(|&i| i != head_idx).collect();
    order.sort_by(|&a, &b| fair_cmp(&queue[a], &queue[b]));
    match shadow_time(now, head.ranks, running, free) {
        Some((shadow, extra)) => {
            for i in order {
                let j = &queue[i];
                if j.ranks <= free && (now + j.est <= shadow || j.ranks <= extra) {
                    return Decision::Start { idx: i, backfilled: true };
                }
            }
            Decision::Wait
        }
        // The head is waiting on scale-up (even a drained cluster cannot
        // seat it): keep the pool busy greedily, fair-share order.
        None => {
            for i in order {
                if queue[i].ranks <= free {
                    return Decision::Start { idx: i, backfilled: true };
                }
            }
            Decision::Wait
        }
    }
}

/// Share-capped aggregate queue demand for the autoscaler.
///
/// Input: one entry per tenant with queued work — `(weighted_slots,
/// widest_job_ranks, share_weight)`, where `weighted_slots` is the
/// tenant's priority-weighted queued-slot sum and `share_weight` is
/// its fair-share multiplier from the
/// [`UsageLedger`](crate::tenancy::ledger::UsageLedger) (1.0 when
/// unconfigured). Each tenant's contribution is capped at **twice its
/// weight-proportional share** of the aggregate — `2 · total · w_t /
/// Σw` — so one heavy tenant flooding the queue cannot force unbounded
/// scale-up (the pool provisions for at most 2x its fair slice, and a
/// weight-2 tenant's slice is twice an unweighted one's), but never
/// below the tenant's widest single job (that width is a hard
/// requirement for the job ever to start, capacity-wise). With equal
/// weights this reduces to twice the equal share, and with a single
/// active tenant the cap is `2 x total`, i.e. no cap — the pre-tenancy
/// signal, byte for byte.
pub fn share_weighted_demand(
    per_tenant: &std::collections::BTreeMap<u64, (f64, u32, f64)>,
) -> u32 {
    if per_tenant.is_empty() {
        return 0;
    }
    let total: f64 = per_tenant.values().map(|(w, _, _)| *w).sum();
    let weight_sum: f64 = per_tenant
        .values()
        .map(|(_, _, sw)| if *sw > 0.0 { *sw } else { 1.0 })
        .sum();
    per_tenant
        .values()
        .map(|&(w, widest, sw)| {
            let sw = if sw > 0.0 { sw } else { 1.0 };
            let cap = 2.0 * total * sw / weight_sum;
            w.min(cap).max(widest as f64).ceil() as u32
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::JobId;
    use std::collections::BTreeMap;

    fn qj(id: u32, ranks: u32, est_secs: u64, tenant: u64, usage: f64) -> QueuedJob {
        QueuedJob {
            id: JobId::new(id),
            ranks,
            priority: 0,
            est: SimTime::from_secs(est_secs),
            tenant,
            usage,
        }
    }

    fn rj(id: u32, ranks: u32, finish_secs: u64) -> RunningJob {
        RunningJob {
            id: JobId::new(id),
            ranks,
            priority: 0,
            predicted_finish: SimTime::from_secs(finish_secs),
            preempt_waste: SimTime::ZERO,
        }
    }

    #[test]
    fn lowest_usage_tenant_wins_fifo_within_tenant() {
        // tenant 1 burned the cluster; tenant 2 is fresh
        let queue = [
            qj(0, 8, 30, 1, 900.0),
            qj(1, 8, 30, 1, 900.0),
            qj(2, 8, 30, 2, 0.0),
        ];
        let d = decide_fairshare(SimTime::ZERO, &queue, &[], 8);
        assert_eq!(d, Decision::Start { idx: 2, backfilled: false });
        // within tenant 1, submit order holds
        let queue = [qj(5, 8, 30, 1, 900.0), qj(3, 8, 30, 1, 900.0)];
        let d = decide_fairshare(SimTime::ZERO, &queue, &[], 8);
        assert_eq!(d, Decision::Start { idx: 1, backfilled: false });
    }

    #[test]
    fn blocked_head_gets_an_easy_style_reservation() {
        // fair-share head (tenant 2, usage 0) needs 24 of 32; job9 frees
        // 20 at t=100 -> shadow t=100 with 8 spare
        let running = [rj(9, 20, 100)];
        // a 30s filler beats the reservation: admitted
        let queue = [qj(0, 24, 60, 2, 0.0), qj(1, 10, 30, 1, 500.0)];
        assert_eq!(
            decide_fairshare(SimTime::ZERO, &queue, &running, 12),
            Decision::Start { idx: 1, backfilled: true }
        );
        // a 200s filler outlives it and exceeds the 8 spare: must wait
        let queue = [qj(0, 24, 60, 2, 0.0), qj(1, 10, 200, 1, 500.0)];
        assert_eq!(
            decide_fairshare(SimTime::ZERO, &queue, &running, 12),
            Decision::Wait
        );
        // 8 ranks fits the spare slots even past the shadow: admitted
        let queue = [qj(0, 24, 60, 2, 0.0), qj(1, 8, 200, 1, 500.0)];
        assert_eq!(
            decide_fairshare(SimTime::ZERO, &queue, &running, 12),
            Decision::Start { idx: 1, backfilled: true }
        );
    }

    #[test]
    fn head_waiting_on_scale_up_does_not_idle_the_pool() {
        // head needs 48 but draining everything frees 32: no reservation
        let running = [rj(9, 20, 100)];
        let queue = [qj(0, 48, 60, 2, 0.0), qj(1, 8, 500, 1, 500.0)];
        assert_eq!(
            decide_fairshare(SimTime::ZERO, &queue, &running, 12),
            Decision::Start { idx: 1, backfilled: true }
        );
    }

    #[test]
    fn share_cap_bounds_a_flooding_tenant() {
        let mut per: BTreeMap<u64, (f64, u32, f64)> = BTreeMap::new();
        per.insert(1, (1000.0, 24, 1.0)); // the hog
        for t in 2..=10u64 {
            per.insert(t, (10.0, 8, 1.0));
        }
        // total 1090 over 10 equal-weight tenants -> cap 218: the hog
        // contributes 218
        let got = share_weighted_demand(&per);
        assert_eq!(got, 218 + 9 * 10);
        // a single tenant is never capped (2x its own total)
        let mut solo: BTreeMap<u64, (f64, u32, f64)> = BTreeMap::new();
        solo.insert(1, (1000.0, 24, 1.0));
        assert_eq!(share_weighted_demand(&solo), 1000);
        assert_eq!(share_weighted_demand(&BTreeMap::new()), 0);
    }

    #[test]
    fn share_cap_never_starves_a_single_wide_job() {
        // tenant 1's one 36-rank job among many light tenants: the cap
        // falls below 36 but the widest-job floor keeps it demandable
        let mut per: BTreeMap<u64, (f64, u32, f64)> = BTreeMap::new();
        per.insert(1, (36.0, 36, 1.0));
        for t in 2..=12u64 {
            per.insert(t, (2.0, 2, 1.0));
        }
        // total 58, cap ~9.7 — but tenant 1 still contributes its 36
        let got = share_weighted_demand(&per);
        assert_eq!(got, 36 + 11 * 2);
    }

    /// Weighted shares thread through the demand cap: a weight-2 tenant
    /// is provisioned for twice the slice of an equal-weight one, while
    /// the unweighted tenants keep exactly their old figures.
    #[test]
    fn share_cap_scales_with_tenant_weights() {
        // two identical hogs flood the queue alongside two light tenants
        let mut per: BTreeMap<u64, (f64, u32, f64)> = BTreeMap::new();
        per.insert(1, (400.0, 24, 2.0)); // weight-2 hog
        per.insert(2, (400.0, 24, 1.0)); // unweighted hog
        per.insert(3, (10.0, 8, 1.0));
        per.insert(4, (10.0, 8, 1.0));
        // total 820, Σw = 5: hog1 cap = 2·820·2/5 = 656 (uncapped at
        // 400), hog2 cap = 2·820/5 = 328
        let got = share_weighted_demand(&per);
        assert_eq!(got, 400 + 328 + 10 + 10);
        // all-equal weights reproduce the unweighted figure exactly
        let mut eq: BTreeMap<u64, (f64, u32, f64)> = BTreeMap::new();
        eq.insert(1, (400.0, 24, 1.0));
        eq.insert(2, (400.0, 24, 1.0));
        eq.insert(3, (10.0, 8, 1.0));
        eq.insert(4, (10.0, 8, 1.0));
        // cap 2·820/4 = 410: nobody capped
        assert_eq!(share_weighted_demand(&eq), 820);
    }
}
