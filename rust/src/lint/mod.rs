//! `vhpc lint` — a std-only determinism & race-safety static-analysis
//! pass over the crate's own source tree.
//!
//! Everything this reproduction ships rests on same-seed determinism:
//! WAL replay byte-matches a live head, fault plans replay, and the
//! planned sharded engine will merge partitions by timestamp. The five
//! rules here mechanically forbid the ways that property breaks:
//!
//! - **R1 `map-iter`** — no `HashMap`/`HashSet` iteration in
//!   replay-critical modules unless waived with `// lint: sorted`.
//! - **R2 `wall-clock`** — no `Instant`/`SystemTime`/`thread_rng`/
//!   `RandomState` in the library: time is virtual, randomness seeded.
//! - **R3 `threads`** — no `static mut`, `thread::spawn`, or `unsafe`
//!   outside the allowlist.
//! - **R4 `no-panic`** — no `unwrap`/`expect`/`panic!` in engine-event
//!   and WAL-replay hot paths.
//! - **R5 `float-sum`** — no f64 accumulation over unordered
//!   containers in ledger/metrics code.
//!
//! Waiver syntax: `// lint: sorted` (statement orders the collection
//! before use) or `// lint: allow(rule) reason` (reason mandatory).
//! Waivers that suppress nothing are warnings; `--fix-waivers` strips
//! them. Module allowlists live in the committed `rust/lint.toml`.
//! Self-test fixtures with deliberate violations sit in
//! `src/lint/fixtures/` — never compiled, excluded from the default
//! walk, and exercised by this module's tests plus the CI lint job.

pub mod lexer;
pub mod rules;

use rules::{FileScope, StaleWaiver, Violation};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// Resolved `lint.toml`: which rules apply to which paths.
#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Directories the default invocation walks.
    pub roots: Vec<String>,
    /// R1 replay-critical module prefixes.
    pub r1_modules: Vec<String>,
    /// R2 scope prefixes (the library).
    pub r2_roots: Vec<String>,
    /// R2 files allowed to touch the wall clock.
    pub r2_allow: Vec<String>,
    /// R3 files allowed threads/unsafe.
    pub r3_allow: Vec<String>,
    /// R4 engine/WAL hot-path files.
    pub r4_hot_paths: Vec<String>,
    /// R5 float-accounting files.
    pub r5_scope: Vec<String>,
}

impl Default for LintConfig {
    fn default() -> Self {
        Self {
            roots: vec!["src".into(), "tests".into(), "benches".into()],
            r1_modules: vec![
                "src/cluster/".into(),
                "src/sim/".into(),
                "src/ha/".into(),
                "src/tenancy/".into(),
                "src/faults/".into(),
                "src/consul/".into(),
            ],
            r2_roots: vec!["src/".into()],
            r2_allow: vec![
                "src/bench.rs".into(),
                "src/mpi/launcher.rs".into(),
                "src/workloads/gemm.rs".into(),
                "src/workloads/jacobi.rs".into(),
            ],
            r3_allow: vec![
                "src/runtime/client.rs".into(),
                "src/mpi/comm.rs".into(),
                "benches/perf_probe.rs".into(),
            ],
            r4_hot_paths: vec![
                "src/sim/engine.rs".into(),
                "src/ha/wal.rs".into(),
                "src/ha/snapshot.rs".into(),
                "src/ha/failover.rs".into(),
                "src/cluster/head.rs".into(),
                "src/cluster/vcluster.rs".into(),
            ],
            r5_scope: vec![
                "src/tenancy/ledger.rs".into(),
                "src/tenancy/fairshare.rs".into(),
                "src/cluster/metrics.rs".into(),
            ],
        }
    }
}

impl LintConfig {
    /// Parse a `lint.toml` text; absent sections/keys keep defaults.
    pub fn from_text(text: &str) -> Result<Self, String> {
        let raw = crate::config::parse(text).map_err(|e| e.to_string())?;
        let mut cfg = Self::default();
        let list = |section: &str, key: &str| -> Option<Vec<String>> {
            raw.get(section)?.get(key).and_then(|v| match v {
                crate::config::Value::List(xs) => Some(xs.clone()),
                _ => None,
            })
        };
        if let Some(v) = list("lint", "roots") {
            cfg.roots = v;
        }
        if let Some(v) = list("r1", "modules") {
            cfg.r1_modules = v;
        }
        if let Some(v) = list("r2", "roots") {
            cfg.r2_roots = v;
        }
        if let Some(v) = list("r2", "allow") {
            cfg.r2_allow = v;
        }
        if let Some(v) = list("r3", "allow") {
            cfg.r3_allow = v;
        }
        if let Some(v) = list("r4", "hot_paths") {
            cfg.r4_hot_paths = v;
        }
        if let Some(v) = list("r5", "scope") {
            cfg.r5_scope = v;
        }
        Ok(cfg)
    }

    /// Which rules apply to `rel` (a forward-slash path). Fixture files
    /// are in scope for every rule — they exist to prove each fires —
    /// and ignore the allowlists.
    pub fn scope_for(&self, rel: &str) -> FileScope {
        if rel.contains("lint/fixtures/") {
            return FileScope { r1: true, r2: true, r3: true, r4: true, r5: true };
        }
        let m = |pats: &[String]| pats.iter().any(|p| path_matches(rel, p));
        FileScope {
            r1: m(&self.r1_modules),
            r2: m(&self.r2_roots) && !m(&self.r2_allow),
            r3: !m(&self.r3_allow),
            r4: m(&self.r4_hot_paths),
            r5: m(&self.r5_scope),
        }
    }
}

/// Directory patterns (trailing `/`) match anywhere in the path; file
/// patterns match as a suffix.
fn path_matches(rel: &str, pat: &str) -> bool {
    if pat.ends_with('/') {
        rel.starts_with(pat) || rel.contains(&format!("/{pat}")[..])
    } else {
        rel == pat || rel.ends_with(&format!("/{pat}")[..])
    }
}

/// A completed lint run.
#[derive(Debug, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
    pub stale: Vec<StaleWaiver>,
    pub files: usize,
}

/// Recursively collect `.rs` files under `path` in sorted order (the
/// report must not depend on directory-entry order). The `fixtures`
/// directory under `lint` is skipped unless the root itself points
/// into it.
fn collect_rs(path: &Path, skip_fixtures: bool, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let meta = std::fs::metadata(path).map_err(|e| format!("{}: {e}", path.display()))?;
    if meta.is_file() {
        if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(path)
        .map_err(|e| format!("{}: {e}", path.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for entry in entries {
        if entry.is_dir() {
            let name = entry.file_name().and_then(|n| n.to_str()).unwrap_or("");
            let parent = entry
                .parent()
                .and_then(|p| p.file_name())
                .and_then(|n| n.to_str())
                .unwrap_or("");
            if skip_fixtures && name == "fixtures" && parent == "lint" {
                continue;
            }
            collect_rs(&entry, skip_fixtures, out)?;
        } else if entry.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(entry);
        }
    }
    Ok(())
}

/// Lint the given roots/files. Violations come back sorted by
/// (file, line).
pub fn run(cfg: &LintConfig, paths: &[PathBuf]) -> Result<Report, String> {
    let mut files = Vec::new();
    for p in paths {
        let into_fixtures = p.to_string_lossy().contains("fixtures");
        collect_rs(p, !into_fixtures, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut report = Report::default();
    for f in &files {
        let rel = f.to_string_lossy().replace('\\', "/");
        let rel = rel.strip_prefix("./").unwrap_or(&rel).to_string();
        let src =
            std::fs::read_to_string(f).map_err(|e| format!("{}: {e}", f.display()))?;
        let (mut vs, mut stale) = rules::analyze(&rel, &src, cfg.scope_for(&rel));
        report.violations.append(&mut vs);
        report.stale.append(&mut stale);
        report.files += 1;
    }
    report.violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    report.stale.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(report)
}

/// Remove stale waivers in place: strip each reported line's trailing
/// `// lint: …` comment (dropping the line if nothing else is on it).
/// Returns how many lines were rewritten.
pub fn fix_waivers(stale: &[StaleWaiver]) -> Result<usize, String> {
    let mut by_file: std::collections::BTreeMap<&str, BTreeSet<u32>> =
        std::collections::BTreeMap::new();
    for s in stale {
        by_file.entry(&s.file).or_default().insert(s.line);
    }
    let mut fixed = 0usize;
    for (file, lines) in by_file {
        let src = std::fs::read_to_string(file).map_err(|e| format!("{file}: {e}"))?;
        let (out, n) = strip_waiver_lines(&src, &lines);
        if n > 0 {
            std::fs::write(file, out).map_err(|e| format!("{file}: {e}"))?;
            fixed += n;
        }
    }
    Ok(fixed)
}

/// Pure text transform behind [`fix_waivers`], kept separate for
/// testability: `lines` are 1-based line numbers carrying stale
/// waivers.
fn strip_waiver_lines(src: &str, lines: &BTreeSet<u32>) -> (String, usize) {
    let mut out = Vec::new();
    let mut n = 0usize;
    for (i, line) in src.lines().enumerate() {
        let lineno = (i + 1) as u32;
        if lines.contains(&lineno) {
            if let Some(pos) = line.rfind("// lint:") {
                let head = line[..pos].trim_end();
                n += 1;
                if head.is_empty() {
                    continue; // the waiver was the whole line
                }
                out.push(head.to_string());
                continue;
            }
        }
        out.push(line.to_string());
    }
    let mut text = out.join("\n");
    if src.ends_with('\n') {
        text.push('\n');
    }
    (text, n)
}

/// `vhpc lint [--fix-waivers] [paths…]` — returns the process exit
/// code: 0 clean, 1 violations, 2 usage/IO error.
pub fn cli_main(args: &[String]) -> i32 {
    let mut fix = false;
    let mut paths: Vec<PathBuf> = Vec::new();
    for a in args {
        match a.as_str() {
            "--fix-waivers" => fix = true,
            s if s.starts_with("--") => {
                eprintln!("vhpc lint: unknown flag {s}");
                return 2;
            }
            s => paths.push(PathBuf::from(s)),
        }
    }
    // config: lint.toml beside the crate (cwd = rust/), or rust/lint.toml
    // when invoked from the repo root
    let (cfg, prefix) = match load_config() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("vhpc lint: {e}");
            return 2;
        }
    };
    if paths.is_empty() {
        paths = cfg
            .roots
            .iter()
            .map(|r| PathBuf::from(format!("{prefix}{r}")))
            .collect();
    }
    let report = match run(&cfg, &paths) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("vhpc lint: {e}");
            return 2;
        }
    };
    for v in &report.violations {
        println!("{}:{}: {} — {}", v.file, v.line, v.rule, v.msg);
    }
    for s in &report.stale {
        println!(
            "{}:{}: warning: stale lint waiver (suppresses nothing; --fix-waivers removes it)",
            s.file, s.line
        );
    }
    if fix && !report.stale.is_empty() {
        match fix_waivers(&report.stale) {
            Ok(n) => println!("vhpc lint: removed {n} stale waiver(s)"),
            Err(e) => {
                eprintln!("vhpc lint: --fix-waivers: {e}");
                return 2;
            }
        }
    }
    println!(
        "vhpc lint: {} file(s), {} violation(s), {} stale waiver(s)",
        report.files,
        report.violations.len(),
        report.stale.len()
    );
    if report.violations.is_empty() {
        0
    } else {
        1
    }
}

fn load_config() -> Result<(LintConfig, &'static str), String> {
    for (path, prefix) in [("lint.toml", ""), ("rust/lint.toml", "rust/")] {
        if let Ok(text) = std::fs::read_to_string(path) {
            return LintConfig::from_text(&text)
                .map(|c| (c, prefix))
                .map_err(|e| format!("{path}: {e}"));
        }
    }
    Ok((LintConfig::default(), ""))
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIXTURE_SCOPE: FileScope =
        FileScope { r1: true, r2: true, r3: true, r4: true, r5: true };

    fn count(vs: &[Violation], rule: &str) -> usize {
        vs.iter().filter(|v| v.rule == rule).count()
    }

    #[test]
    fn fixture_r1_map_iter_fires() {
        let src = include_str!("fixtures/r1_map_iter.rs");
        let (vs, stale) = rules::analyze("fx.rs", src, FIXTURE_SCOPE);
        assert_eq!(count(&vs, rules::RULE_MAP_ITER), 4, "{vs:?}");
        assert_eq!(vs.len(), 4, "only map-iter must fire: {vs:?}");
        assert!(stale.is_empty(), "the sorted waiver is load-bearing: {stale:?}");
    }

    #[test]
    fn fixture_r2_wall_clock_fires() {
        let src = include_str!("fixtures/r2_wall_clock.rs");
        let (vs, _) = rules::analyze("fx.rs", src, FIXTURE_SCOPE);
        assert_eq!(count(&vs, rules::RULE_WALL_CLOCK), 4, "{vs:?}");
        assert_eq!(vs.len(), 4, "{vs:?}");
    }

    #[test]
    fn fixture_r3_threads_fires() {
        let src = include_str!("fixtures/r3_threads.rs");
        let (vs, _) = rules::analyze("fx.rs", src, FIXTURE_SCOPE);
        assert_eq!(count(&vs, rules::RULE_THREADS), 3, "{vs:?}");
        assert_eq!(vs.len(), 3, "{vs:?}");
    }

    #[test]
    fn fixture_r4_panics_fires() {
        let src = include_str!("fixtures/r4_panics.rs");
        let (vs, _) = rules::analyze("fx.rs", src, FIXTURE_SCOPE);
        assert_eq!(count(&vs, rules::RULE_NO_PANIC), 3, "{vs:?}");
        assert_eq!(vs.len(), 3, "{vs:?}");
    }

    #[test]
    fn fixture_r5_float_sum_fires() {
        let src = include_str!("fixtures/r5_float_sum.rs");
        let (vs, _) = rules::analyze("fx.rs", src, FIXTURE_SCOPE);
        assert_eq!(count(&vs, rules::RULE_FLOAT_SUM), 1, "{vs:?}");
        assert_eq!(count(&vs, rules::RULE_MAP_ITER), 1, "{vs:?}");
    }

    #[test]
    fn fixture_waivers_malformed_and_stale() {
        let src = include_str!("fixtures/waivers.rs");
        let (vs, stale) = rules::analyze("fx.rs", src, FIXTURE_SCOPE);
        assert_eq!(count(&vs, rules::RULE_WAIVER), 2, "{vs:?}");
        assert_eq!(
            count(&vs, rules::RULE_MAP_ITER),
            2,
            "malformed waivers must not suppress: {vs:?}"
        );
        assert_eq!(stale.len(), 1, "{stale:?}");
    }

    /// The acceptance gate: the shipped tree must be clean. Cargo runs
    /// tests with cwd = the package root, so relative roots resolve.
    #[test]
    fn shipped_tree_is_clean() {
        let cfg = LintConfig::from_text(include_str!("../../lint.toml"))
            .expect("lint.toml parses");
        let paths: Vec<PathBuf> = cfg.roots.iter().map(PathBuf::from).collect();
        let report = run(&cfg, &paths).expect("walk succeeds");
        assert!(report.files > 30, "walk must see the tree: {}", report.files);
        assert!(
            report.violations.is_empty(),
            "shipped tree must lint clean:\n{}",
            report
                .violations
                .iter()
                .map(|v| format!("{}:{}: {} — {}", v.file, v.line, v.rule, v.msg))
                .collect::<Vec<_>>()
                .join("\n")
        );
        assert!(
            report.stale.is_empty(),
            "no stale waivers in the shipped tree:\n{}",
            report
                .stale
                .iter()
                .map(|s| format!("{}:{}", s.file, s.line))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    #[test]
    fn fixtures_are_excluded_from_the_default_walk_but_reachable_directly() {
        let cfg = LintConfig::default();
        let report = run(&cfg, &[PathBuf::from("src/lint")]).expect("walk");
        assert!(
            report.violations.is_empty(),
            "default walk must skip fixtures: {:?}",
            report.violations
        );
        let direct = run(&cfg, &[PathBuf::from("src/lint/fixtures")]).expect("walk");
        assert!(!direct.violations.is_empty(), "explicit fixture path must fire");
    }

    #[test]
    fn scope_resolution_matches_the_layout() {
        let cfg = LintConfig::default();
        let s = cfg.scope_for("src/cluster/head.rs");
        assert!(s.r1 && s.r2 && s.r3 && s.r4 && !s.r5);
        let s = cfg.scope_for("src/tenancy/ledger.rs");
        assert!(s.r1 && s.r2 && s.r3 && !s.r4 && s.r5);
        let s = cfg.scope_for("src/mpi/launcher.rs");
        assert!(!s.r1 && !s.r2 && s.r3 && !s.r4);
        let s = cfg.scope_for("src/runtime/client.rs");
        assert!(!s.r3, "client.rs is on the R3 allowlist");
        let s = cfg.scope_for("tests/determinism.rs");
        assert!(!s.r1 && !s.r2 && s.r3 && !s.r4);
        let s = cfg.scope_for("src/lint/fixtures/r1_map_iter.rs");
        assert!(s.r1 && s.r2 && s.r3 && s.r4 && s.r5, "fixtures see every rule");
    }

    #[test]
    fn strip_waiver_lines_removes_only_the_comment() {
        let src = "let x = 1; // lint: sorted\n// lint: sorted\nlet y = 2;\n";
        let mut lines = BTreeSet::new();
        lines.insert(1);
        lines.insert(2);
        let (out, n) = strip_waiver_lines(src, &lines);
        assert_eq!(n, 2);
        assert_eq!(out, "let x = 1;\nlet y = 2;\n");
    }

    #[test]
    fn config_text_overrides_and_bad_text_errors() {
        let cfg = LintConfig::from_text("[r1]\nmodules = [\"src/only/\"]\n").expect("parses");
        assert_eq!(cfg.r1_modules, vec!["src/only/".to_string()]);
        assert_eq!(cfg.roots, LintConfig::default().roots, "other keys keep defaults");
        assert!(LintConfig::from_text("not toml at all").is_err());
    }
}
