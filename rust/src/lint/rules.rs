//! The determinism rule set (R1–R5) over the token stream.
//!
//! The analyses are deliberately file-local and token-shaped: the pass
//! tracks which names are declared as `HashMap`/`HashSet` (struct
//! fields vs `let`/param locals), skips `#[cfg(test)]` regions by
//! brace-matching, and flags forbidden shapes with a waiver escape
//! hatch in comments. It is not a type checker — a map reached through
//! a cross-file field type (`other.inner.iter()`) is invisible — but
//! every in-repo nondeterminism incident to date has been the local
//! shape this catches, and the narrow scope keeps false positives near
//! zero, which is what lets the pass gate CI.

use super::lexer::{lex, LineComment, Tok, TokKind};
use std::collections::BTreeSet;

/// One reported violation, printed as `file:line: rule — message`.
#[derive(Debug, Clone)]
pub struct Violation {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// A well-formed waiver that suppressed nothing — reported as a
/// warning and stripped by `--fix-waivers`.
#[derive(Debug, Clone)]
pub struct StaleWaiver {
    pub file: String,
    pub line: u32,
}

/// Which rules apply to the file under analysis (resolved from
/// `lint.toml` by the caller).
#[derive(Debug, Clone, Copy, Default)]
pub struct FileScope {
    /// R1: map/set iteration needs an ordering waiver.
    pub r1: bool,
    /// R2: no wall-clock time or ambient entropy.
    pub r2: bool,
    /// R3: no `static mut` / `std::thread::spawn` / `unsafe`.
    pub r3: bool,
    /// R4: no unwrap/expect/panic (engine + WAL hot paths).
    pub r4: bool,
    /// R5: no float accumulation over unordered containers.
    pub r5: bool,
}

pub const RULE_MAP_ITER: &str = "map-iter";
pub const RULE_WALL_CLOCK: &str = "wall-clock";
pub const RULE_THREADS: &str = "threads";
pub const RULE_NO_PANIC: &str = "no-panic";
pub const RULE_FLOAT_SUM: &str = "float-sum";
pub const RULE_WAIVER: &str = "waiver";

const ALL_RULES: &[&str] = &[
    RULE_MAP_ITER,
    RULE_WALL_CLOCK,
    RULE_THREADS,
    RULE_NO_PANIC,
    RULE_FLOAT_SUM,
];

/// Iteration methods whose visit order is the per-process hash order.
const FORBIDDEN_ITER: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
];

/// Consuming adapters are only matched on `self.NAME` / bare-local
/// receivers: `x.NAME.into_iter()` is almost always a dump/restore
/// struct whose field happens to share a tracked name.
const CONSUMING: &[&str] = &["into_iter", "into_keys", "into_values"];

/// Identifiers R2 bans: wall-clock time and ambient entropy.
const R2_IDENTS: &[&str] = &["Instant", "SystemTime", "thread_rng", "RandomState"];

#[derive(Debug, Clone, PartialEq)]
enum WaiverKind {
    /// `// lint: sorted` — the statement orders the collection before
    /// use; waives R1 and R5.
    Sorted,
    /// `// lint: allow(rule) reason` — waives exactly that rule.
    Allow(String),
}

#[derive(Debug)]
struct Waiver {
    line: u32,
    kind: WaiverKind,
}

/// Parse lint directives out of the file's line comments. Malformed
/// directives (unknown rule, missing reason) become violations — a
/// waiver that doesn't say why is worse than none.
fn parse_waivers(
    file: &str,
    comments: &[LineComment],
    violations: &mut Vec<Violation>,
) -> Vec<Waiver> {
    let mut out = Vec::new();
    for c in comments {
        // `///` and `//!` doc comments arrive with leading `/`/`!`
        let body = c.text.trim_start_matches(['/', '!']).trim();
        let Some(rest) = body.strip_prefix("lint:") else {
            continue;
        };
        let rest = rest.trim();
        if rest == "sorted" || rest.starts_with("sorted ") {
            out.push(Waiver { line: c.line, kind: WaiverKind::Sorted });
            continue;
        }
        if let Some(after) = rest.strip_prefix("allow(") {
            let Some(close) = after.find(')') else {
                violations.push(Violation {
                    file: file.to_string(),
                    line: c.line,
                    rule: RULE_WAIVER,
                    msg: "unclosed allow(...) in lint directive".to_string(),
                });
                continue;
            };
            let rule = after[..close].trim().to_string();
            let reason = after[close + 1..].trim();
            if !ALL_RULES.contains(&rule.as_str()) {
                violations.push(Violation {
                    file: file.to_string(),
                    line: c.line,
                    rule: RULE_WAIVER,
                    msg: format!("allow({rule}): unknown rule (expected one of {ALL_RULES:?})"),
                });
                continue;
            }
            if reason.is_empty() {
                violations.push(Violation {
                    file: file.to_string(),
                    line: c.line,
                    rule: RULE_WAIVER,
                    msg: format!("allow({rule}) without a reason — say why the waiver is safe"),
                });
                continue;
            }
            out.push(Waiver { line: c.line, kind: WaiverKind::Allow(rule) });
            continue;
        }
        violations.push(Violation {
            file: file.to_string(),
            line: c.line,
            rule: RULE_WAIVER,
            msg: format!("unrecognized lint directive `{rest}` (use `sorted` or `allow(rule) reason`)"),
        });
    }
    out
}

fn is_punct(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

/// Token-index spans covered by `#[cfg(test)]` / `#[test]` items (the
/// attribute through the item's closing brace or semicolon).
fn test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(is_punct(&toks[i], "#") && i + 1 < toks.len() && is_punct(&toks[i + 1], "[")) {
            i += 1;
            continue;
        }
        let start = i;
        let (attr_end, mut has_test) = scan_attr(toks, i + 1);
        // swallow any further attributes on the same item
        let mut j = attr_end + 1;
        while j + 1 < toks.len() && is_punct(&toks[j], "#") && is_punct(&toks[j + 1], "[") {
            let (e, t) = scan_attr(toks, j + 1);
            has_test = has_test || t;
            j = e + 1;
        }
        if !has_test {
            i = j;
            continue;
        }
        // find the item's end: first `{` (then matching `}`) or `;` at
        // bracket/paren depth 0
        let mut depth = 0i32;
        let mut end = toks.len().saturating_sub(1);
        while j < toks.len() {
            let t = &toks[j];
            if is_punct(t, "(") || is_punct(t, "[") {
                depth += 1;
            } else if is_punct(t, ")") || is_punct(t, "]") {
                depth -= 1;
            } else if depth == 0 && is_punct(t, ";") {
                end = j;
                break;
            } else if depth == 0 && is_punct(t, "{") {
                end = matching_brace(toks, j);
                break;
            }
            j += 1;
        }
        spans.push((start, end));
        i = end + 1;
    }
    spans
}

/// Scan an attribute starting at its `[` token; return (index of the
/// matching `]`, whether the attribute mentions the ident `test`).
fn scan_attr(toks: &[Tok], open: usize) -> (usize, bool) {
    let mut depth = 0i32;
    let mut has_test = false;
    let mut j = open;
    while j < toks.len() {
        let t = &toks[j];
        if is_punct(t, "[") {
            depth += 1;
        } else if is_punct(t, "]") {
            depth -= 1;
            if depth == 0 {
                return (j, has_test);
            }
        } else if is_ident(t, "test") {
            has_test = true;
        }
        j += 1;
    }
    (toks.len().saturating_sub(1), has_test)
}

/// Index of the `}` matching the `{` at `open` (last token if
/// unbalanced).
fn matching_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if is_punct(t, "{") {
            depth += 1;
        } else if is_punct(t, "}") {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// If the `HashMap`/`HashSet` token at `i` is the type (or
/// constructor) of a declaration, return the declared name. Handles
/// `name: [&][mut] [std::collections::]HashMap<…>` and
/// `let [mut] name = HashMap::new()`.
fn decl_name_before(toks: &[Tok], i: usize) -> Option<String> {
    let mut j = i;
    loop {
        if j == 0 {
            return None;
        }
        let prev = &toks[j - 1];
        if is_punct(prev, ":") {
            if j >= 2 && is_punct(&toks[j - 2], ":") {
                j -= 2; // `::` path segment
                continue;
            }
            if j >= 2 && toks[j - 2].kind == TokKind::Ident {
                return Some(toks[j - 2].text.clone());
            }
            return None;
        }
        if is_punct(prev, "&")
            || prev.kind == TokKind::Lifetime
            || is_ident(prev, "mut")
            || is_ident(prev, "std")
            || is_ident(prev, "collections")
            || is_ident(prev, "hash_map")
            || is_ident(prev, "hash_set")
        {
            j -= 1;
            continue;
        }
        if is_punct(prev, "=") {
            // let [mut] NAME = HashMap::new()
            if j >= 3
                && toks[j - 2].kind == TokKind::Ident
                && (is_ident(&toks[j - 3], "let") || is_ident(&toks[j - 3], "mut"))
            {
                return Some(toks[j - 2].text.clone());
            }
            return None;
        }
        return None;
    }
}

/// Names declared as `HashMap`/`HashSet` struct (or enum-variant)
/// fields anywhere in the file, outside test regions.
fn collect_fields(toks: &[Tok], in_test: &dyn Fn(usize) -> bool) -> BTreeSet<String> {
    let mut fields = BTreeSet::new();
    let mut stack: Vec<bool> = Vec::new(); // true = struct/enum body
    let mut pending = false;
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Ident
            && (t.text == "struct" || t.text == "enum" || t.text == "union")
        {
            pending = true;
        } else if is_punct(t, "{") {
            let inherit = stack.last().copied().unwrap_or(false);
            stack.push(pending || inherit);
            pending = false;
        } else if is_punct(t, "}") {
            stack.pop();
        } else if is_punct(t, ";") {
            pending = false; // tuple / unit struct
        } else if t.kind == TokKind::Ident
            && (t.text == "HashMap" || t.text == "HashSet")
            && stack.last().copied().unwrap_or(false)
            && !in_test(i)
        {
            if let Some(name) = decl_name_before(toks, i) {
                fields.insert(name);
            }
        }
    }
    fields
}

/// The receiver of a `.method(` call ending at token index `m` (the
/// method ident), resolved far enough for the rules.
enum Receiver {
    /// `self.NAME.method(` or `x.y.NAME.method(` — `NAME`, its token
    /// index, and whether the path root is literally `self`.
    Field { name: String, idx: usize, via_self: bool },
    /// `NAME.method(` with no dot before NAME.
    Bare { name: String, idx: usize },
    /// Call/index/other expression — untrackable.
    Opaque,
}

fn receiver_of(toks: &[Tok], m: usize) -> Receiver {
    if m < 2 {
        return Receiver::Opaque;
    }
    let r = &toks[m - 2];
    if r.kind != TokKind::Ident {
        return Receiver::Opaque;
    }
    let dotted = m >= 3 && is_punct(&toks[m - 3], ".");
    if !dotted {
        return Receiver::Bare { name: r.text.clone(), idx: m - 2 };
    }
    let via_self = m >= 4 && is_ident(&toks[m - 4], "self");
    Receiver::Field { name: r.text.clone(), idx: m - 2, via_self }
}

/// Analyze one file. Returns (violations, stale waivers).
pub fn analyze(file: &str, src: &str, scope: FileScope) -> (Vec<Violation>, Vec<StaleWaiver>) {
    let mut violations = Vec::new();
    let lexed = lex(src);
    let toks = &lexed.toks;
    let waivers = parse_waivers(file, &lexed.comments, &mut violations);
    let mut waiver_used = vec![false; waivers.len()];

    let spans = test_spans(toks);
    let in_test = |i: usize| spans.iter().any(|&(a, b)| i >= a && i <= b);

    let fields = collect_fields(toks, &in_test);

    // waive(rule, lines): first matching unexpired waiver wins
    let waive = |rule: &str, lines: &[u32], used: &mut Vec<bool>| -> bool {
        for (wi, w) in waivers.iter().enumerate() {
            if !lines.contains(&w.line) {
                continue;
            }
            let hit = match &w.kind {
                WaiverKind::Sorted => rule == RULE_MAP_ITER || rule == RULE_FLOAT_SUM,
                WaiverKind::Allow(r) => r == rule,
            };
            if hit {
                used[wi] = true;
                return true;
            }
        }
        false
    };

    let mut locals: BTreeSet<String> = BTreeSet::new();
    let mut i = 0usize;
    while i < toks.len() {
        // skip test regions wholesale
        if let Some(&(_, b)) = spans.iter().find(|&&(a, b)| i >= a && i <= b) {
            i = b + 1;
            continue;
        }
        let t = &toks[i];

        // local-declaration tracking, reset per fn
        if is_ident(t, "fn") {
            locals.clear();
        } else if t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
            if let Some(name) = decl_name_before(toks, i) {
                // struct-body decls were collected as fields; a struct
                // literal in a fn re-registers the name as a local,
                // which is harmless (bare use of the same name in the
                // same fn really is the map)
                locals.insert(name);
            }
        }

        // R1 / R5: forbidden iteration methods on tracked receivers
        if (scope.r1 || scope.r5)
            && t.kind == TokKind::Ident
            && FORBIDDEN_ITER.contains(&t.text.as_str())
            && i + 1 < toks.len()
            && is_punct(&toks[i + 1], "(")
            && i >= 1
            && is_punct(&toks[i - 1], ".")
        {
            let consuming = CONSUMING.contains(&t.text.as_str());
            let tracked = match receiver_of(toks, i) {
                Receiver::Field { name, idx, via_self } => {
                    let applies = !consuming || via_self;
                    (applies && fields.contains(&name)).then_some(idx)
                }
                Receiver::Bare { name, idx } => locals.contains(&name).then_some(idx),
                Receiver::Opaque => None,
            };
            if let Some(ridx) = tracked {
                let rl = toks[ridx].line;
                let lines =
                    [t.line, rl, rl.saturating_sub(1), rl.saturating_sub(2)];
                if scope.r1 && !waive(RULE_MAP_ITER, &lines, &mut waiver_used) {
                    violations.push(Violation {
                        file: file.to_string(),
                        line: t.line,
                        rule: RULE_MAP_ITER,
                        msg: format!(
                            "hash-order iteration: `{}.{}()` visits entries in per-process \
                             RandomState order — sort before use (`// lint: sorted`) or waive",
                            toks[ridx].text, t.text
                        ),
                    });
                }
                if scope.r5 {
                    // `.sum()` / `.product()` later in the same statement
                    let mut k = i + 1;
                    let mut steps = 0;
                    while k < toks.len() && steps < 60 {
                        if is_punct(&toks[k], ";") || is_punct(&toks[k], "{") {
                            break;
                        }
                        if toks[k].kind == TokKind::Ident
                            && (toks[k].text == "sum" || toks[k].text == "product")
                            && is_punct(&toks[k - 1], ".")
                        {
                            let lines2 = [
                                t.line,
                                toks[k].line,
                                rl,
                                rl.saturating_sub(1),
                                rl.saturating_sub(2),
                            ];
                            if !waive(RULE_FLOAT_SUM, &lines2, &mut waiver_used) {
                                violations.push(Violation {
                                    file: file.to_string(),
                                    line: toks[k].line,
                                    rule: RULE_FLOAT_SUM,
                                    msg: format!(
                                        "float accumulation over unordered `{}` — summation \
                                         order changes the rounding; sort first",
                                        toks[ridx].text
                                    ),
                                });
                            }
                            break;
                        }
                        k += 1;
                        steps += 1;
                    }
                }
            }
        }

        // R1: `for … in [&][mut] map` loops
        if scope.r1 && is_ident(t, "for") {
            if let Some(v) = check_for_loop(toks, i, &fields, &locals) {
                let rl = toks[v].line;
                let lines = [t.line, rl, rl.saturating_sub(1), rl.saturating_sub(2)];
                if !waive(RULE_MAP_ITER, &lines, &mut waiver_used) {
                    violations.push(Violation {
                        file: file.to_string(),
                        line: rl,
                        rule: RULE_MAP_ITER,
                        msg: format!(
                            "hash-order iteration: `for … in {}` visits entries in \
                             per-process RandomState order — collect and sort first",
                            toks[v].text
                        ),
                    });
                }
            }
        }

        // R2: wall clock / ambient entropy
        if scope.r2 && t.kind == TokKind::Ident && R2_IDENTS.contains(&t.text.as_str()) {
            let lines = [t.line, t.line.saturating_sub(1)];
            if !waive(RULE_WALL_CLOCK, &lines, &mut waiver_used) {
                violations.push(Violation {
                    file: file.to_string(),
                    line: t.line,
                    rule: RULE_WALL_CLOCK,
                    msg: format!(
                        "`{}` in library code — all time is virtual (SimTime) and all \
                         randomness is seeded (util::Rng)",
                        t.text
                    ),
                });
            }
        }

        // R3: shared-mutable-state escape hatches
        if scope.r3 {
            let hit: Option<&str> = if is_ident(t, "unsafe") {
                Some("`unsafe` outside the allowlist")
            } else if is_ident(t, "static")
                && i + 1 < toks.len()
                && is_ident(&toks[i + 1], "mut")
            {
                Some("`static mut` — shared mutable state breaks replay and sharding")
            } else if is_ident(t, "spawn")
                && i >= 3
                && is_punct(&toks[i - 1], ":")
                && is_punct(&toks[i - 2], ":")
                && is_ident(&toks[i - 3], "thread")
            {
                Some("`thread::spawn` outside the allowlist — each engine is single-threaded; \
                      only the partition runtime (sim/partition.rs, comm/) may thread, and \
                      shards share state by message, never by memory")
            } else {
                None
            };
            if let Some(msg) = hit {
                let lines = [t.line, t.line.saturating_sub(1)];
                if !waive(RULE_THREADS, &lines, &mut waiver_used) {
                    violations.push(Violation {
                        file: file.to_string(),
                        line: t.line,
                        rule: RULE_THREADS,
                        msg: msg.to_string(),
                    });
                }
            }
        }

        // R4: panic-class calls in hot paths
        if scope.r4 && t.kind == TokKind::Ident {
            let is_method_panic = (t.text == "unwrap" || t.text == "expect")
                && i >= 1
                && is_punct(&toks[i - 1], ".")
                && i + 1 < toks.len()
                && is_punct(&toks[i + 1], "(");
            let is_macro_panic = (t.text == "panic"
                || t.text == "unreachable"
                || t.text == "todo"
                || t.text == "unimplemented")
                && i + 1 < toks.len()
                && is_punct(&toks[i + 1], "!");
            if is_method_panic || is_macro_panic {
                let lines = [t.line, t.line.saturating_sub(1)];
                if !waive(RULE_NO_PANIC, &lines, &mut waiver_used) {
                    violations.push(Violation {
                        file: file.to_string(),
                        line: t.line,
                        rule: RULE_NO_PANIC,
                        msg: format!(
                            "`{}` in an engine/WAL hot path — the head must degrade, not die",
                            t.text
                        ),
                    });
                }
            }
        }

        i += 1;
    }

    let stale = waivers
        .iter()
        .zip(&waiver_used)
        .filter(|(_, &used)| !used)
        .map(|(w, _)| StaleWaiver { file: file.to_string(), line: w.line })
        .collect();
    (violations, stale)
}

/// If the `for` at index `i` iterates a tracked map (`for x in &map`,
/// `for x in self.map`, `for x in st.map`), return the receiver-name
/// token index.
fn check_for_loop(
    toks: &[Tok],
    i: usize,
    fields: &BTreeSet<String>,
    locals: &BTreeSet<String>,
) -> Option<usize> {
    // find `in` at depth 0, bounded
    let mut depth = 0i32;
    let mut j = i + 1;
    let mut in_idx = None;
    while j < toks.len() && j - i < 60 {
        let t = &toks[j];
        if is_punct(t, "(") || is_punct(t, "[") {
            depth += 1;
        } else if is_punct(t, ")") || is_punct(t, "]") {
            depth -= 1;
        } else if depth == 0 && (is_punct(t, "{") || is_punct(t, ";")) {
            return None; // `impl Trait for Type {`, or not a for-loop
        } else if depth == 0 && is_ident(t, "in") {
            in_idx = Some(j);
            break;
        }
        j += 1;
    }
    let mut k = in_idx? + 1;
    while k < toks.len() && (is_punct(&toks[k], "&") || is_ident(&toks[k], "mut")) {
        k += 1;
    }
    // path: [self.]A[.B]… — walk the dotted path, remember the last ident
    let mut segs: Vec<usize> = Vec::new();
    loop {
        if k >= toks.len() || toks[k].kind != TokKind::Ident {
            return None;
        }
        segs.push(k);
        if k + 1 < toks.len() && is_punct(&toks[k + 1], ".") {
            // a method call in the chain (e.g. `.values()`) is handled
            // by the method rule, not here
            if k + 2 < toks.len()
                && toks[k + 2].kind == TokKind::Ident
                && k + 3 < toks.len()
                && is_punct(&toks[k + 3], "(")
            {
                return None;
            }
            k += 2;
            continue;
        }
        break;
    }
    // the loop body must open right after the path
    if k + 1 >= toks.len() || !is_punct(&toks[k + 1], "{") {
        return None;
    }
    let last = *segs.last()?;
    let name = &toks[last].text;
    if segs.len() == 1 {
        locals.contains(name).then_some(last)
    } else {
        fields.contains(name).then_some(last)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: FileScope =
        FileScope { r1: true, r2: true, r3: true, r4: true, r5: true };

    fn run(src: &str) -> Vec<Violation> {
        analyze("t.rs", src, ALL).0
    }

    fn rules_of(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn field_iteration_is_flagged_and_param_shadow_is_not() {
        // mirrors tenancy/ledger.rs: a slice param named like the field
        let src = r#"
use std::collections::HashMap;
struct L { accounts: HashMap<u64, f64> }
impl L {
    fn export(&self) -> usize { self.accounts.iter().count() }
    fn restore(&mut self, accounts: &[(u64, f64)]) -> usize {
        accounts.iter().count()
    }
}
"#;
        let vs = run(src);
        assert_eq!(rules_of(&vs), vec![RULE_MAP_ITER], "{vs:?}");
        assert_eq!(vs[0].line, 5);
    }

    #[test]
    fn local_and_for_loop_forms_are_flagged() {
        let src = r#"
use std::collections::HashMap;
struct S { m: HashMap<u32, u32> }
impl S {
    fn f(&self) {
        for _ in &self.m {}
        let loc: HashMap<u32, u32> = HashMap::new();
        for _ in loc.keys() {}
    }
}
"#;
        let vs = run(src);
        assert_eq!(rules_of(&vs), vec![RULE_MAP_ITER, RULE_MAP_ITER], "{vs:?}");
    }

    #[test]
    fn sorted_waiver_suppresses_and_is_not_stale() {
        let src = r#"
use std::collections::HashMap;
struct S { m: HashMap<u32, u32> }
impl S {
    fn f(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.m.keys().copied().collect(); // lint: sorted
        v.sort();
        v
    }
}
"#;
        let (vs, stale) = analyze("t.rs", src, ALL);
        assert!(vs.is_empty(), "{vs:?}");
        assert!(stale.is_empty(), "{stale:?}");
    }

    #[test]
    fn reasonless_or_unknown_allow_is_a_violation() {
        let src = "
fn a() {} // lint: allow(map-iter)
fn b() {} // lint: allow(nonsense) because reasons
fn c() {} // lint: frobnicate
";
        let vs = run(src);
        assert_eq!(
            rules_of(&vs),
            vec![RULE_WAIVER, RULE_WAIVER, RULE_WAIVER],
            "{vs:?}"
        );
    }

    #[test]
    fn stale_waiver_is_reported_but_not_fatal() {
        let (vs, stale) = analyze("t.rs", "fn a() {} // lint: sorted\n", ALL);
        assert!(vs.is_empty(), "{vs:?}");
        assert_eq!(stale.len(), 1);
        assert_eq!(stale[0].line, 1);
    }

    #[test]
    fn cfg_test_regions_are_skipped() {
        let src = r#"
use std::collections::HashMap;
struct S { m: HashMap<u32, u32> }
#[cfg(test)]
mod tests {
    fn f(s: &super::S) -> usize { s.m.iter().count() }
    fn g() { let x: Option<u32> = None; x.unwrap(); }
}
"#;
        let vs = run(src);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn wall_clock_and_entropy_are_flagged() {
        let vs = run("fn f() { let t = Instant::now(); }");
        assert_eq!(rules_of(&vs), vec![RULE_WALL_CLOCK]);
        let vs = run("fn f() { let s = RandomState::new(); }");
        assert_eq!(rules_of(&vs), vec![RULE_WALL_CLOCK]);
    }

    #[test]
    fn threads_static_mut_and_unsafe_are_flagged() {
        let vs = run("static mut X: u32 = 0;");
        assert_eq!(rules_of(&vs), vec![RULE_THREADS]);
        let vs = run("fn f() { std::thread::spawn(|| {}); }");
        assert_eq!(rules_of(&vs), vec![RULE_THREADS]);
        let vs = run("fn f() { unsafe { } }");
        assert_eq!(rules_of(&vs), vec![RULE_THREADS]);
    }

    #[test]
    fn hot_path_panics_are_flagged_but_degrading_calls_are_not() {
        let vs = run("fn f(v: Vec<u32>) -> u32 { v.first().copied().unwrap() }");
        assert_eq!(rules_of(&vs), vec![RULE_NO_PANIC]);
        let vs = run("fn f() { panic!(\"boom\"); }");
        assert_eq!(rules_of(&vs), vec![RULE_NO_PANIC]);
        let vs = run(
            "fn f(m: std::sync::Mutex<u32>) { m.lock().unwrap_or_else(|e| e.into_inner()); }",
        );
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn float_sum_over_tracked_map_is_flagged() {
        let src = r#"
use std::collections::HashMap;
struct L { bal: HashMap<u64, f64> }
impl L {
    fn total(&self) -> f64 { self.bal.values().sum() }
}
"#;
        let vs = run(src);
        assert_eq!(rules_of(&vs), vec![RULE_MAP_ITER, RULE_FLOAT_SUM], "{vs:?}");
    }

    #[test]
    fn dump_restore_shape_is_not_flagged() {
        // `d.running.into_iter()` where `running` is a tracked field of
        // another struct: consuming adapters only match self/bare paths
        let src = r#"
use std::collections::HashMap;
struct H { running: HashMap<u32, u32> }
struct Dump { running: Vec<(u32, u32)> }
impl H {
    fn restore(&mut self, d: Dump) {
        self.running = d.running.into_iter().collect();
    }
}
"#;
        let vs = run(src);
        assert!(vs.is_empty(), "{vs:?}");
    }

    #[test]
    fn opaque_receivers_are_skipped() {
        let src = r#"
use std::collections::HashMap;
struct S { m: HashMap<u32, u32> }
impl S {
    fn per_host(&self) -> HashMap<u32, u32> { self.m.clone() }
    fn f(&self) -> usize { self.per_host().into_iter().count() }
}
"#;
        let vs = run(src);
        assert!(vs.is_empty(), "{vs:?}");
    }
}
