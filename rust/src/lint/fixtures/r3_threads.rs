//! Lint self-test fixture: R3 shared-mutable-state escape hatches.
//! Never compiled — fed to the analyzer by the lint tests
//! (3 violations: `static mut`, `thread::spawn`, `unsafe`).

pub static mut COUNTER: u64 = 0;

pub fn run() -> u64 {
    let h = std::thread::spawn(|| 7u64);
    let v = h.join().unwrap_or(0);
    unsafe {
        COUNTER += v;
        COUNTER
    }
}
