//! Lint self-test fixture: waiver syntax enforcement. Never compiled —
//! fed to the analyzer by the lint tests (2 `waiver` violations for
//! malformed directives, which therefore do NOT suppress their 2
//! map-iter violations, plus 1 stale-waiver warning).

use std::collections::HashMap;

pub struct S {
    m: HashMap<u32, u32>,
}

impl S {
    /// a waiver without a reason is itself a violation, and suppresses
    /// nothing
    pub fn no_reason(&self) -> usize {
        self.m.keys().count() // lint: allow(map-iter)
    }

    /// unknown rule names are violations too
    pub fn unknown_rule(&self) -> usize {
        self.m.keys().count() // lint: allow(made-up-rule) because it felt right
    }
}

/// a well-formed waiver that suppresses nothing is a stale warning
pub fn stale() {} // lint: sorted
