//! Lint self-test fixture: R1 hash-order iteration. This file is never
//! compiled into the crate — the lint tests feed it to the analyzer
//! and assert each deliberate violation fires (4 in total).

use std::collections::HashMap;

pub struct Pool {
    jobs: HashMap<u64, u32>,
}

impl Pool {
    /// violation: `for … in self.jobs.iter()` visits in hash order
    pub fn total(&self) -> u32 {
        let mut t = 0;
        for (_, v) in self.jobs.iter() {
            t += v;
        }
        t
    }

    /// violation: `.keys()` on a tracked field
    pub fn ids(&self) -> Vec<u64> {
        self.jobs.keys().copied().collect()
    }

    /// violation: `for … in &map`
    pub fn sweep(&mut self) {
        for _ in &self.jobs {}
    }

    /// clean: the waiver proves the collection is ordered before use
    pub fn sorted_ids(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.jobs.keys().copied().collect(); // lint: sorted
        v.sort();
        v
    }
}

/// violation: iteration over a local map binding
pub fn locals() {
    let m: HashMap<u32, u32> = HashMap::new();
    for _ in m.values() {}
}
