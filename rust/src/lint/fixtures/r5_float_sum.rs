//! Lint self-test fixture: R5 float accumulation over unordered
//! containers. Never compiled — fed to the analyzer by the lint tests
//! (1 float-sum violation + the underlying map-iter; the sorted form
//! is clean).

use std::collections::HashMap;

pub struct Ledger {
    balances: HashMap<u64, f64>,
}

impl Ledger {
    /// violations: map-iter AND float-sum (rounding depends on order)
    pub fn total(&self) -> f64 {
        self.balances.values().sum()
    }

    /// clean: ordered before accumulation
    pub fn total_sorted(&self) -> f64 {
        let mut v: Vec<f64> = self.balances.values().copied().collect(); // lint: sorted
        v.sort_by(f64::total_cmp);
        v.iter().sum()
    }
}
