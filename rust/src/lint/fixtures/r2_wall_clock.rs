//! Lint self-test fixture: R2 wall-clock & ambient entropy. Never
//! compiled — fed to the analyzer by the lint tests (4 violations:
//! three `Instant` mentions, one `RandomState`).

use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}

pub fn entropy_seed() -> usize {
    let s = std::collections::hash_map::RandomState::new();
    std::mem::size_of_val(&s)
}
