//! Lint self-test fixture: R4 panic-class calls in hot paths. Never
//! compiled — fed to the analyzer by the lint tests (3 violations:
//! `unwrap`, `expect`, `panic!`; the degrading form is clean).

pub fn pop(v: &mut Vec<u32>) -> u32 {
    v.pop().unwrap()
}

pub fn first(v: &[u32]) -> u32 {
    *v.first().expect("non-empty")
}

pub fn boom() -> ! {
    panic!("engine event died")
}

/// clean: degrades instead of dying
pub fn degrade(v: &[u32]) -> u32 {
    v.first().copied().unwrap_or(0)
}
