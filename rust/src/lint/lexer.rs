//! A small Rust lexer for the lint pass: just enough of the language to
//! tokenize real source without being fooled by strings, comments,
//! lifetimes, or raw strings.
//!
//! The output is a flat token stream (identifiers, punctuation,
//! literals) plus the list of line comments — the rules engine matches
//! token shapes (`.` `iter` `(`), and waivers live in the comments.
//! This is deliberately not a parser: the rules only need local token
//! context, and a full grammar would be a liability in a std-only tool.

/// Token classes the rules engine distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`self`, `iter`, `HashMap`, `for`, ...).
    Ident,
    /// A lifetime such as `'a` (kept distinct from char literals).
    Lifetime,
    /// String literal of any flavor (`"…"`, `r#"…"#`, `b"…"`).
    Str,
    /// Character or byte literal (`'x'`, `b'\n'`).
    Char,
    /// Numeric literal (value is irrelevant to the rules).
    Num,
    /// Single punctuation byte (`.` `:` `(` `&` `!` ...). Multi-byte
    /// operators arrive as consecutive tokens (`::` is `:` `:`).
    Punct,
}

/// One token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A `//` comment (including `///` and `//!` doc comments). `text` is
/// everything after the leading slashes, untrimmed.
#[derive(Debug, Clone)]
pub struct LineComment {
    pub line: u32,
    pub text: String,
}

/// Lexer output: the token stream and the comments stripped from it.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<LineComment>,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_cont(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Tokenize `src`. Unterminated constructs consume to end of input
/// rather than erroring — the linter must keep going on odd files.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;

    // Count newlines in b[from..to] into `line`.
    fn advance_lines(b: &[u8], from: usize, to: usize, line: &mut u32) {
        for &c in &b[from..to.min(b.len())] {
            if c == b'\n' {
                *line += 1;
            }
        }
    }

    while i < b.len() {
        let c = b[i];
        // whitespace
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i + 2;
            let mut j = start;
            while j < b.len() && b[j] != b'\n' {
                j += 1;
            }
            out.comments.push(LineComment {
                line,
                text: src[start..j].to_string(),
            });
            i = j;
            continue;
        }
        // block comment (nested)
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let start = i;
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == b'/' && j + 1 < b.len() && b[j + 1] == b'*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && j + 1 < b.len() && b[j + 1] == b'/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            advance_lines(b, start, j, &mut line);
            i = j;
            continue;
        }
        // string literal
        if c == b'"' {
            let start = i;
            let mut j = i + 1;
            while j < b.len() {
                match b[j] {
                    b'\\' => j += 2,
                    b'"' => {
                        j += 1;
                        break;
                    }
                    _ => j += 1,
                }
            }
            out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
            advance_lines(b, start, j, &mut line);
            i = j;
            continue;
        }
        // lifetime or char literal
        if c == b'\'' {
            // 'a (lifetime) vs 'a' (char) vs '\n' (char)
            if i + 1 < b.len() && b[i + 1] == b'\\' {
                // escaped char literal: skip the escaped byte (it may
                // itself be a quote, as in '\''), then find the close
                let mut j = i + 3;
                while j < b.len() && b[j] != b'\'' {
                    j += 1;
                }
                out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i = (j + 1).min(b.len());
                continue;
            }
            let is_lifetime = i + 1 < b.len()
                && is_ident_start(b[i + 1])
                && !(i + 2 < b.len() && b[i + 2] == b'\'');
            if is_lifetime {
                let mut j = i + 1;
                while j < b.len() && is_ident_cont(b[j]) {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: src[i..j].to_string(),
                    line,
                });
                i = j;
                continue;
            }
            // char literal: consume to the closing quote
            let mut j = i + 1;
            while j < b.len() && b[j] != b'\'' {
                if b[j] == b'\\' {
                    j += 1;
                }
                j += 1;
            }
            out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
            i = (j + 1).min(b.len());
            continue;
        }
        // raw / byte strings starting with r or b
        if (c == b'r' || c == b'b') && i + 1 < b.len() {
            if let Some(j) = try_raw_or_byte(b, i) {
                let start = i;
                out.toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
                advance_lines(b, start, j, &mut line);
                i = j;
                continue;
            }
            if c == b'b' && b[i + 1] == b'\'' {
                // byte char literal b'x'
                let mut j = i + 2;
                while j < b.len() && b[j] != b'\'' {
                    if b[j] == b'\\' {
                        j += 1;
                    }
                    j += 1;
                }
                out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i = (j + 1).min(b.len());
                continue;
            }
        }
        // identifier / keyword
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < b.len() && is_ident_cont(b[j]) {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: src[i..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        // number
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < b.len() {
                if is_ident_cont(b[j]) {
                    j += 1;
                } else if b[j] == b'.'
                    && j + 1 < b.len()
                    && b[j + 1].is_ascii_digit()
                {
                    j += 1;
                } else {
                    break;
                }
            }
            out.toks.push(Tok { kind: TokKind::Num, text: String::new(), line });
            i = j;
            continue;
        }
        // punctuation: one byte per token
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: (c as char).to_string(),
            line,
        });
        i += 1;
    }
    out
}

/// If a raw or byte string literal starts at `b[i]`, return the index
/// one past its end. Handles `r"…"`, `r#"…"#` (any hash count),
/// `b"…"`, `br"…"`, `br#"…"#`.
fn try_raw_or_byte(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    let mut raw = false;
    if b[j] == b'b' {
        j += 1;
        if j < b.len() && b[j] == b'r' {
            raw = true;
            j += 1;
        }
    } else if b[j] == b'r' {
        raw = true;
        j += 1;
    }
    if raw {
        let mut hashes = 0usize;
        while j < b.len() && b[j] == b'#' {
            hashes += 1;
            j += 1;
        }
        if j >= b.len() || b[j] != b'"' {
            return None; // raw identifier like r#fn, or a bare `r` ident
        }
        j += 1;
        // scan for `"` followed by `hashes` hash marks
        while j < b.len() {
            if b[j] == b'"' {
                let mut k = j + 1;
                let mut n = 0usize;
                while k < b.len() && b[k] == b'#' && n < hashes {
                    k += 1;
                    n += 1;
                }
                if n == hashes {
                    return Some(k);
                }
            }
            j += 1;
        }
        return Some(b.len());
    }
    // b"…" byte string: escapes allowed
    if b[j] == b'"' {
        j += 1;
        while j < b.len() {
            match b[j] {
                b'\\' => j += 2,
                b'"' => return Some(j + 1),
                _ => j += 1,
            }
        }
        return Some(b.len());
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_do_not_leak_tokens() {
        let src = r##"
            let x = "self.map.iter() // not code";
            // real comment with iter()
            let y = r#"raw "quoted" iter()"#;
            /* block /* nested */ iter() */
            call();
        "##;
        let ids = idents(src);
        assert!(!ids.contains(&"iter".to_string()), "{ids:?}");
        assert!(ids.contains(&"call".to_string()));
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 1);
        assert!(lx.comments[0].text.contains("real comment"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lx = lex("fn f<'a>(x: &'a str) -> char { 'x' }");
        let lifetimes: Vec<_> =
            lx.toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        let chars: Vec<_> =
            lx.toks.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(chars.len(), 1);
    }

    #[test]
    fn escaped_char_literals_do_not_derail() {
        let lx = lex(r"let a = '\n'; let b = '\''; after();");
        assert!(lx.toks.iter().any(|t| t.text == "after"));
        assert_eq!(
            lx.toks.iter().filter(|t| t.kind == TokKind::Char).count(),
            2
        );
    }

    #[test]
    fn line_numbers_track_newlines_in_strings() {
        let src = "let s = \"a\nb\nc\";\nmarker();";
        let lx = lex(src);
        let m = lx.toks.iter().find(|t| t.text == "marker").expect("marker");
        assert_eq!(m.line, 4);
    }

    #[test]
    fn numbers_do_not_eat_range_dots() {
        let lx = lex("for i in 0..10 {}");
        let dots =
            lx.toks.iter().filter(|t| t.text == "." && t.kind == TokKind::Punct).count();
        assert_eq!(dots, 2, "0..10 must keep both range dots");
    }
}
