//! Hand-rolled CLI (no clap in the offline crate set).
//!
//! ```text
//! vhpc up         [--config F] [--machines N] [--sim-seconds S]
//! vhpc run        [--ranks N] [--tile T] [--steps K] [--bridge MODE]
//! vhpc mix        [--jobs N] [--machines M] [--max-concurrent K]
//!                 [--policy fifo|easy|priority|fairshare] [--racks N]
//!                 [--shards N] [--ticks T]   (partitioned run; T = drain
//!                 deadline in 1s scheduler ticks, like `vhpc ha`)
//! vhpc tenants    [--tenants N] [--policy fifo|easy|priority|fairshare]
//!                 [--duration S] [--rate JOBS_PER_SEC] [--skew S]
//!                 [--seed S] [--max-queued N] [--defer-over-quota B]
//!                 [--sim-seconds S]   (drain deadline; default 4x duration)
//!                 [--shards N] [--crash-at S]   (HA run: crash the head
//!                 mid-stream; the arrival cursor resumes from the WAL)
//! vhpc chaos      [--jobs N] [--machines M] [--seed S] [--mtbf SECS]
//!                 [--max-retries K] [--sim-seconds S] [--shards N]
//! vhpc ha         [--jobs N] [--machines M] [--crash-at S] [--lock-ttl S]
//!                 [--snapshot-every N] [--ticks T]   (drain deadline, 1s ticks)
//! vhpc acct       TRACE_FILE [--format json|table] [--tenant T]
//!                 [--state S] [--since SECS]   (sacct-style accounting
//!                 over a `--trace` event log)
//! vhpc trace      TRACE_FILE [--format json|table] [--job J]
//!                 [--series csv|json]   (per-job timelines, the
//!                 scale-decision audit and the sampled gauge
//!                 time-series from a `--trace` event log)
//! vhpc perf       [--jobs N] [--tenants N] [--machines M] [--shards N]
//!                 [--seed S] [--duration S] [--out F] [--trace F]
//!                 [--baseline F] [--gate PCT]   (large-trace throughput
//!                 harness; writes BENCH_perf.json, optionally gated
//!                 against a baseline's events/sec; --trace reruns the
//!                 cluster phase traced and records the overhead)
//! vhpc build      [--dockerfile F]
//! vhpc bench-net  [--bridge MODE]
//! vhpc lint       [--fix-waivers] [paths…]
//! vhpc version
//! ```
//!
//! The in-process drivers (`up`, `run`, `mix`, `tenants`, `chaos`,
//! `ha`) all take `--trace FILE` to stream the structured event log
//! ([`crate::obs`]) to a JSON-lines file. Sharded runs (`--shards N`)
//! trace too: each rank buffers locally and the conductor merges the
//! per-window batches in canonical order, so the file is byte-identical
//! at any shard count. Every traced driver reports the bus's
//! written/dropped counts at the end of the run.

use crate::cluster::head::JobKind;
use crate::cluster::policy::{PolicyKind, SchedulePolicy};
use crate::cluster::vcluster::VirtualCluster;
use crate::config::ClusterSpec;
use crate::dockyard::{Dockerfile, ImageStore};
use crate::sim::SimTime;
use std::collections::HashMap;

/// Parse `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>, String> {
    let mut flags = HashMap::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        let key = a
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --flag, got {a}"))?;
        let val = it.next().ok_or_else(|| format!("--{key} needs a value"))?;
        flags.insert(key.to_string(), val.clone());
    }
    Ok(flags)
}

fn flag<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T, String> {
    match flags.get(key) {
        Some(v) => v.parse().map_err(|_| format!("bad value for --{key}: {v}")),
        None => Ok(default),
    }
}

/// Order-stable 64-bit digest of a merged counter snapshot (FNV-1a over
/// the sorted entries), so `--shards` invariance can be eyeballed from
/// two CLI runs without diffing the whole metrics dump.
fn counter_digest(fp: &std::collections::BTreeMap<String, u64>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for (k, v) in fp {
        for b in k.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h ^= *v;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn load_spec(flags: &HashMap<String, String>) -> Result<ClusterSpec, String> {
    let mut spec = match flags.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            ClusterSpec::from_text(&text).map_err(|e| e.to_string())?
        }
        None => ClusterSpec::paper_testbed(),
    };
    if let Some(m) = flags.get("machines") {
        spec.machines = m.parse().map_err(|_| "bad --machines".to_string())?;
        spec.autoscale.max_nodes = spec.machines.saturating_sub(1).max(1);
        // keep the policy bounds ordered when the machine count shrinks
        spec.autoscale.min_nodes = spec.autoscale.min_nodes.min(spec.autoscale.max_nodes);
    }
    if let Some(b) = flags.get("bridge") {
        spec.bridge = match b.as_str() {
            "docker0" => crate::vnet::BridgeMode::Docker0,
            "bridge0" => crate::vnet::BridgeMode::Bridge0,
            "host" => crate::vnet::BridgeMode::Host,
            other => return Err(format!("unknown bridge mode {other}")),
        };
    }
    if let Some(path) = flags.get("trace") {
        spec.trace_path = Some(path.clone());
    }
    Ok(spec)
}

/// Finish the trace bus and print its end-of-run I/O counts (traced
/// runs only). A non-zero drop count means the sink failed mid-run and
/// the trace file is partial.
fn print_trace_summary(vc: &mut VirtualCluster) {
    vc.finish_trace();
    let (written, dropped) = vc.trace_io();
    if written > 0 || dropped > 0 {
        println!("trace: {written} events written, {dropped} events dropped");
    }
}

/// Sharded-run counterpart of [`print_trace_summary`]: the conductor
/// already finished the bus, so the counts ride on the outcome.
fn print_sharded_trace_summary(written: u64, dropped: u64) {
    if written > 0 || dropped > 0 {
        println!("trace: {written} events written, {dropped} events dropped");
    }
}

fn cmd_up(flags: HashMap<String, String>) -> Result<(), String> {
    let spec = load_spec(&flags)?;
    let sim_secs: u64 = flag(&flags, "sim-seconds", 300u64)?;
    println!("bringing up '{}' ({} machines, {} consul servers, {})",
        spec.name, spec.machines, spec.consul_servers, spec.bridge.name());
    let mut vc = VirtualCluster::new(spec).map_err(|e| e.to_string())?;
    vc.start();
    vc.advance(SimTime::from_secs(sim_secs));
    println!("t={} ready compute nodes: {}", vc.now(), vc.ready_compute_nodes());
    println!("--- hostfile ---\n{}", vc.hostfile());
    print_trace_summary(&mut vc);
    println!("--- metrics ---\n{}", vc.metrics().render());
    Ok(())
}

fn cmd_run(flags: HashMap<String, String>) -> Result<(), String> {
    let spec = load_spec(&flags)?;
    let ranks: usize = flag(&flags, "ranks", 16usize)?;
    let tile: usize = flag(&flags, "tile", 64usize)?;
    let steps: usize = flag(&flags, "steps", 200usize)?;
    // factor ranks into a near-square grid
    let mut px = (ranks as f64).sqrt() as usize;
    while ranks % px != 0 {
        px -= 1;
    }
    let py = ranks / px;
    let mut vc = VirtualCluster::new(spec).map_err(|e| e.to_string())?;
    vc.start();
    if !vc.advance_until(SimTime::from_secs(600), |st| {
        st.head.slots_available() >= ranks as u32
    }) {
        return Err(format!(
            "cluster never reached {ranks} slots (have {})",
            vc.state.head.slots_available()
        ));
    }
    println!("cluster up at t={}, hostfile:\n{}", vc.now(), vc.hostfile());
    vc.submit("cli-jacobi", ranks as u32, JobKind::Jacobi { px, py, tile, steps });
    if !vc.advance_until(SimTime::from_secs(3600), |st| !st.head.completed.is_empty()) {
        return Err("job did not complete".into());
    }
    let rec = &vc.completed_jobs()[0];
    println!("job {} -> {:?}", rec.spec.name, rec.state);
    if let Some((steps_run, residual)) = rec.result {
        println!("jacobi: {steps_run} steps, final residual {residual:.3e}");
    }
    print_trace_summary(&mut vc);
    println!("--- metrics ---\n{}", vc.metrics().render());
    Ok(())
}

/// Drive a bursty mix of wide and narrow synthetic jobs through the
/// scheduler under the chosen policy and report queue waits, overlap,
/// preemptions and rack spread.
fn cmd_mix(flags: HashMap<String, String>) -> Result<(), String> {
    let mut spec = load_spec(&flags)?;
    if !flags.contains_key("machines") && !flags.contains_key("config") {
        // no explicit topology: default to the same 8-machine mix
        // cluster the job_mix example runs on
        let boot = spec.machine_spec.boot_time;
        let bridge = spec.bridge;
        spec = crate::cluster::mix::mix_spec(boot);
        spec.bridge = bridge;
    }
    spec.autoscale.min_nodes = spec
        .autoscale
        .min_nodes
        .max(1)
        .min(spec.autoscale.max_nodes.max(1));
    let jobs: u32 = flag(&flags, "jobs", 10u32)?;
    let max_concurrent: usize = flag(&flags, "max-concurrent", 0usize)?;
    let sim_secs: u64 = flag(&flags, "sim-seconds", 3600u64)?;
    let kind: PolicyKind = flag(&flags, "policy", PolicyKind::Fifo)?;
    let racks: u32 = flag(&flags, "racks", 0u32)?;
    if racks > 0 {
        spec.racks = racks;
    }
    // more than one rack — whether from the flag or the config file —
    // packs reservations rack-aware so the spread metric has something
    // to minimize
    let policy = SchedulePolicy::new(kind).with_topo_aware(spec.racks > 1);

    // scale the canonical trace to what this cluster can actually
    // advertise, so a small --machines/--config runs a smaller mix
    // instead of queueing impossible jobs
    let cap_slots = spec.max_advertisable_slots();
    if cap_slots == 0 {
        return Err("cluster has no compute capacity (needs >= 2 machines)".into());
    }
    let trace: Vec<crate::cluster::mix::JobReq> =
        crate::cluster::mix::prioritized_trace(24.min(cap_slots), jobs as usize)
            .into_iter()
            .map(|j| crate::cluster::mix::JobReq { ranks: j.ranks.min(cap_slots), ..j })
            .collect();
    // wait for the minimum pool before submitting (same protocol as the
    // job_mix example / ext_autoscale bench)
    let warmup = (spec.autoscale.min_nodes * spec.slots_per_node).clamp(1, cap_slots);
    let cap = if max_concurrent == 0 { usize::MAX } else { max_concurrent };
    let shards: usize = flag(&flags, "shards", 0usize)?;
    // drain deadline in scheduler ticks (1 tick = 1 virtual second),
    // sharded runs only — mirrors `vhpc ha --ticks`
    let ticks: u64 = flag(&flags, "ticks", 0u64)?;
    if shards > 0 {
        let cfg = crate::cluster::ShardRunConfig {
            shards,
            warmup_slots: warmup,
            deadline_secs: if ticks > 0 { ticks } else { sim_secs },
            max_concurrent: cap,
            ..Default::default()
        };
        let o = crate::cluster::run_sharded_mix(spec, &trace, policy, &cfg)
            .map_err(|e| e.to_string())?;
        println!(
            "sharded mix: {} shards  {} windows  policy: {}  jobs done: {}/{}  makespan {:.1}s  events {}",
            o.shards, o.windows, kind.name(), o.jobs_completed, o.jobs_submitted,
            o.makespan_secs, o.events
        );
        print_sharded_trace_summary(o.trace_events_written, o.trace_events_dropped);
        println!(
            "counter fingerprint: {:016x} ({} counters) — identical for any --shards at this seed",
            counter_digest(&o.fingerprint),
            o.fingerprint.len()
        );
        return Ok(());
    }
    let (outcome, mut vc) =
        crate::cluster::mix::run_policy_trace(spec, &trace, policy, cap, warmup, sim_secs)
            .map_err(|e| e.to_string())?;
    println!(
        "t={}  policy: {}  jobs done: {jobs}/{jobs}  peak concurrency: {}  backfill starts: {}  preemptions: {}",
        vc.now(),
        kind.name(),
        outcome.peak_concurrency,
        outcome.backfill_starts,
        outcome.preemptions,
    );
    println!(
        "mean queue wait: {:.1}s  max queue wait: {:.1}s  makespan: {:.1}s  mean rack spread: {:.2}",
        outcome.mean_wait, outcome.max_wait, outcome.makespan, outcome.mean_rack_spread
    );
    print_trace_summary(&mut vc);
    println!("--- metrics ---\n{}", vc.metrics().render());
    Ok(())
}

/// Open-loop multi-tenant run: synthesize an arrival stream from a
/// tenant population (power-law rates, diurnal swing, campaign bursts)
/// and report per-tenant fairness under the chosen policy.
fn cmd_tenants(flags: HashMap<String, String>) -> Result<(), String> {
    let mut spec = load_spec(&flags)?;
    if !flags.contains_key("machines") && !flags.contains_key("config") {
        // no explicit topology: the same 8-machine cluster as `vhpc mix`
        let bridge = spec.bridge;
        spec = crate::cluster::mix::mix_spec(SimTime::from_secs(30));
        spec.bridge = bridge;
    }
    spec.autoscale.min_nodes = spec
        .autoscale
        .min_nodes
        .max(1)
        .min(spec.autoscale.max_nodes.max(1));
    let tenants: u64 = flag(&flags, "tenants", 100u64)?;
    let kind: PolicyKind = flag(&flags, "policy", PolicyKind::FairShare)?;
    let duration: u64 = flag(&flags, "duration", 1800u64)?;
    let deadline: u64 = flag(&flags, "sim-seconds", duration.saturating_mul(4).max(3600))?;
    let seed: u64 = flag(&flags, "seed", spec.seed)?;
    let rate: f64 = flag(&flags, "rate", 0.15f64)?;
    let skew: f64 = flag(&flags, "skew", 1.1f64)?;
    let max_queued: usize = flag(&flags, "max-queued", usize::MAX)?;
    let defer: bool = flag(&flags, "defer-over-quota", false)?;

    let mut pop = crate::tenancy::PopulationSpec::new(tenants, seed);
    pop.rate_per_sec = rate;
    pop.skew = skew;
    let quotas = crate::tenancy::TenantQuotas {
        max_queued_jobs: max_queued,
        over_quota: if defer {
            crate::tenancy::QuotaAction::Defer
        } else {
            crate::tenancy::QuotaAction::Reject
        },
        ..Default::default()
    };
    let policy = SchedulePolicy::new(kind);
    let shards: usize = flag(&flags, "shards", 0usize)?;
    if shards > 0 {
        let cap_slots = spec.max_advertisable_slots();
        if cap_slots == 0 {
            return Err("cluster has no compute capacity (needs >= 2 machines)".into());
        }
        let warmup = (spec.autoscale.min_nodes * spec.slots_per_node).clamp(1, cap_slots);
        let cfg = crate::cluster::ShardRunConfig {
            shards,
            warmup_slots: warmup,
            deadline_secs: deadline,
            ..Default::default()
        };
        let o = crate::cluster::run_sharded_tenants(spec, pop, policy, quotas, duration, &cfg)
            .map_err(|e| e.to_string())?;
        println!(
            "sharded tenants: {} shards  {} windows  policy: {}  jobs: {} submitted, {} done  makespan {:.0}s  events {}",
            o.shards, o.windows, kind.name(), o.jobs_submitted, o.jobs_completed,
            o.makespan_secs, o.events
        );
        print_sharded_trace_summary(o.trace_events_written, o.trace_events_dropped);
        println!("arrival-stream fingerprint: {:016x}", o.arrivals_fingerprint);
        println!(
            "counter fingerprint: {:016x} ({} counters) — identical for any --shards at this seed",
            counter_digest(&o.fingerprint),
            o.fingerprint.len()
        );
        return Ok(());
    }
    let crash_at: u64 = flag(&flags, "crash-at", 0u64)?;
    let (o, mut vc) = if crash_at > 0 {
        // HA run with a mid-stream head crash: the arrival cursor is
        // WAL-shipped, so the stream resumes byte-identically after the
        // standby takes over
        crate::cluster::mix::run_tenant_trace_ha(
            spec,
            pop,
            policy,
            quotas,
            duration,
            Some(SimTime::from_secs(crash_at)),
            deadline,
        )
        .map_err(|e| e.to_string())?
    } else {
        crate::cluster::mix::run_tenant_trace(spec, pop, policy, quotas, duration, deadline)
            .map_err(|e| e.to_string())?
    };
    if crash_at > 0 {
        println!(
            "head crash at +{crash_at}s: {} takeover(s), arrival stream resumed from the WAL-shipped cursor",
            vc.metrics().counter("ha_takeovers")
        );
    }
    println!(
        "t={}  policy: {}  tenants: {tenants} ({} active)  jobs: {} submitted, {} done, {} failed, {} deferred",
        vc.now(),
        kind.name(),
        o.tenants_seen,
        o.jobs_submitted,
        o.jobs_completed,
        o.jobs_failed,
        o.jobs_deferred,
    );
    println!(
        "wait: mean {:.1}s  p99 {:.1}s   slowdown: mean {:.2}   makespan {:.0}s",
        o.mean_wait, o.p99_wait, o.mean_slowdown, o.makespan
    );
    println!(
        "Jain fairness — per-tenant mean slowdown: {:.4}   per-tenant mean wait: {:.4}",
        o.fairness_slowdown, o.fairness_wait
    );
    println!("arrival-stream fingerprint: {:016x}", o.arrivals_fingerprint);
    print_trace_summary(&mut vc);
    println!("--- metrics ---\n{}", vc.metrics().render());
    Ok(())
}

/// Self-healing under a seeded crash schedule: run the canonical job
/// mix while machines die at MTBF-drawn times, and report recovery
/// metrics (requeues, replacements, MTTR, wasted work, goodput).
fn cmd_chaos(flags: HashMap<String, String>) -> Result<(), String> {
    let mut spec = load_spec(&flags)?;
    if !flags.contains_key("machines") && !flags.contains_key("config") {
        // no explicit topology: the same 8-machine cluster as `vhpc mix`
        let bridge = spec.bridge;
        spec = crate::cluster::mix::mix_spec(SimTime::from_secs(30));
        spec.bridge = bridge;
    }
    spec.autoscale.min_nodes = spec
        .autoscale
        .min_nodes
        .max(1)
        .min(spec.autoscale.max_nodes.max(1));
    let jobs: u32 = flag(&flags, "jobs", 10u32)?;
    let seed: u64 = flag(&flags, "seed", spec.seed)?;
    let mtbf: u64 = flag(&flags, "mtbf", 300u64)?;
    let max_retries: u32 = flag(&flags, "max-retries", 3u32)?;
    let sim_secs: u64 = flag(&flags, "sim-seconds", 3600u64)?;

    let cap_slots = spec.max_advertisable_slots();
    if cap_slots == 0 {
        return Err("cluster has no compute capacity (needs >= 2 machines)".into());
    }
    let trace: Vec<(u32, u64)> =
        crate::cluster::mix::bursty_trace(24.min(cap_slots), jobs as usize)
            .into_iter()
            .map(|(ranks, secs)| (ranks.min(cap_slots), secs))
            .collect();
    let warmup = (spec.autoscale.min_nodes * spec.slots_per_node).clamp(1, cap_slots);
    let shards: usize = flag(&flags, "shards", 0usize)?;
    if shards > 0 {
        // the sharded driver draws its own kill schedule from the spec seed
        spec.seed = seed;
        let reqs: Vec<crate::cluster::mix::JobReq> = trace
            .iter()
            .map(|&(ranks, secs)| crate::cluster::mix::JobReq { ranks, secs, priority: 0 })
            .collect();
        let cfg = crate::cluster::ShardRunConfig {
            shards,
            warmup_slots: warmup,
            deadline_secs: sim_secs,
            ..Default::default()
        };
        let o =
            crate::cluster::run_sharded_chaos(spec, &reqs, SchedulePolicy::default(), mtbf as f64, &cfg)
                .map_err(|e| e.to_string())?;
        println!(
            "sharded chaos: {} shards  {} windows  jobs done: {}/{}  makespan {:.1}s  events {}",
            o.shards, o.windows, o.jobs_completed, o.jobs_submitted, o.makespan_secs, o.events
        );
        print_sharded_trace_summary(o.trace_events_written, o.trace_events_dropped);
        println!(
            "counter fingerprint: {:016x} ({} counters) — identical for any --shards at this seed",
            counter_digest(&o.fingerprint),
            o.fingerprint.len()
        );
        return Ok(());
    }
    let plan = crate::faults::FaultPlan::from_mtbf(
        seed,
        spec.machines,
        SimTime::from_secs(mtbf),
        SimTime::from_secs(sim_secs),
    );
    println!(
        "chaos: {} crashes scheduled over {sim_secs}s (seed {seed}, per-machine mtbf {mtbf}s)",
        plan.len()
    );
    let (o, mut vc) =
        crate::faults::run_chaos_trace(spec, &trace, &plan, warmup, max_retries, sim_secs)
            .map_err(|e| e.to_string())?;
    println!(
        "jobs: {}/{} completed, {} abandoned, {} requeues",
        o.jobs_completed, o.jobs_submitted, o.jobs_abandoned, o.requeues
    );
    println!(
        "machines killed: {}  machines booted after injection: {}",
        o.machines_killed, o.replacements_booted
    );
    println!(
        "MTTR mean {:.1}s  max {:.1}s  wasted work {:.1}s  goodput {:.1} slot-s/s  makespan {:.1}s",
        o.mttr_mean, o.mttr_max, o.wasted_seconds, o.goodput, o.makespan
    );
    print_trace_summary(&mut vc);
    println!("--- metrics ---\n{}", vc.metrics().render());
    Ok(())
}

/// Head-node failover drill: run the canonical job mix on an
/// HA-enabled cluster, crash the head mid-trace, and report the
/// failover MTTR, WAL/snapshot activity and that nothing was lost.
fn cmd_ha(flags: HashMap<String, String>) -> Result<(), String> {
    let mut spec = load_spec(&flags)?;
    if !flags.contains_key("machines") && !flags.contains_key("config") {
        // no explicit topology: the same 8-machine cluster as `vhpc
        // mix`, fast boots so the quick-mode CI smoke stays quick
        let bridge = spec.bridge;
        spec = crate::cluster::mix::mix_spec(SimTime::from_secs(10));
        spec.bridge = bridge;
    }
    spec.autoscale.min_nodes = spec
        .autoscale
        .min_nodes
        .max(1)
        .min(spec.autoscale.max_nodes.max(1));
    let jobs: u32 = flag(&flags, "jobs", 6u32)?;
    let crash_at: u64 = flag(&flags, "crash-at", 40u64)?;
    let lock_ttl: u64 = flag(&flags, "lock-ttl", 5u64)?;
    let snapshot_every: u64 = flag(&flags, "snapshot-every", 64u64)?;
    // drain deadline in scheduler ticks (1 tick = 1 virtual second)
    let ticks: u64 = flag(&flags, "ticks", 900u64)?;
    spec.ha.enabled = true;
    spec.ha.lock_ttl = SimTime::from_secs(lock_ttl);
    spec.ha.snapshot_every = snapshot_every;

    let cap_slots = spec.max_advertisable_slots();
    if cap_slots == 0 {
        return Err("cluster has no compute capacity (needs >= 2 machines)".into());
    }
    let trace: Vec<(u32, u64)> =
        crate::cluster::mix::bursty_trace(24.min(cap_slots), jobs as usize)
            .into_iter()
            .map(|(ranks, secs)| (ranks.min(cap_slots), secs))
            .collect();
    let warmup = (spec.autoscale.min_nodes * spec.slots_per_node).clamp(1, cap_slots);
    println!(
        "ha drill: {jobs} jobs, head crash at +{crash_at}s, lock ttl {lock_ttl}s, \
         snapshot every {snapshot_every} wal appends"
    );
    let (o, mut vc) = crate::ha::run_ha_trace(
        spec,
        &trace,
        Some(SimTime::from_secs(crash_at)),
        warmup,
        ticks,
    )
    .map_err(|e| e.to_string())?;
    println!(
        "t={}  jobs done: {}/{}  head crashes: {}  takeovers: {}  requeues: {} (failover charges no retry budget)",
        vc.now(),
        o.jobs_completed,
        o.jobs_submitted,
        o.head_crashes,
        o.takeovers,
        o.requeues,
    );
    println!(
        "failover MTTR: mean {:.1}s  max {:.1}s   wal appends: {}  snapshots: {}  replayed at takeover: {}",
        o.failover_mean, o.failover_max, o.wal_appends, o.snapshots, o.replayed_events
    );
    println!("makespan {:.1}s", o.makespan);
    print_trace_summary(&mut vc);
    println!("--- metrics ---\n{}", vc.metrics().render());
    Ok(())
}

/// Large-trace throughput harness: synthesize the canonical arrival
/// stream, microbench the calendar engine against the reference heap,
/// run the sharded control-plane trace, and write `BENCH_perf.json`.
/// With `--baseline F`, fail (exit 2) if events/sec dropped more than
/// `--gate` percent below the baseline's.
fn cmd_perf(mut flags: HashMap<String, String>) -> Result<(), String> {
    // the perf fleet defaults to 32 machines; routing the default
    // through load_spec keeps its autoscale-bounds adjustment
    if !flags.contains_key("machines") && !flags.contains_key("config") {
        flags.insert("machines".to_string(), "32".to_string());
    }
    let spec = load_spec(&flags)?;
    let jobs: usize = flag(&flags, "jobs", 100_000usize)?;
    let tenants: u64 = flag(&flags, "tenants", 10_000u64)?;
    let shards: usize = flag(&flags, "shards", 4usize)?;
    let seed: u64 = flag(&flags, "seed", 42u64)?;
    let duration: u64 = flag(&flags, "duration", 1800u64)?;
    let out: String = flag(&flags, "out", "BENCH_perf.json".to_string())?;
    let gate: f64 = flag(&flags, "gate", 15.0f64)?;

    let machines = spec.machines;
    let spec = crate::cluster::perf::perf_spec(spec, machines, seed);
    println!(
        "perf: {jobs} jobs / {tenants} tenants over {duration}s virtual, {} machines, {shards} shards, seed {seed}",
        spec.machines
    );
    let o = crate::cluster::run_perf_trace(spec, jobs, tenants, shards, seed, duration)?;
    for p in &o.phases {
        println!(
            "phase {:<16} {:>10} units  {:>8.3}s wall  p50 {:.2}ms  p99 {:.2}ms  max {:.2}ms",
            p.name, p.units, p.wall_secs, p.latency.p50_ms, p.latency.p99_ms, p.latency.max_ms
        );
    }
    println!(
        "engine: calendar {:.0} ev/s vs heap {:.0} ev/s — {:.2}x",
        o.engine.calendar_events_per_sec, o.engine.heap_events_per_sec, o.engine.speedup
    );
    println!(
        "cluster: {} events in {:.2}s wall -> {:.0} events/sec  ({} submitted, {} done, makespan {:.0}s)",
        o.events,
        o.phases.last().map(|p| p.wall_secs).unwrap_or(0.0),
        o.events_per_sec,
        o.jobs_submitted,
        o.jobs_completed,
        o.makespan_secs
    );
    if o.traced_events_per_sec > 0.0 {
        println!(
            "traced rerun: {:.0} events/sec ({:+.2}% overhead)  trace: {} events written, {} events dropped",
            o.traced_events_per_sec,
            o.trace_overhead_pct,
            o.trace_events_written,
            o.trace_events_dropped
        );
    }
    println!("arrival-stream fingerprint: {:016x}", o.arrivals_fingerprint);
    println!(
        "counter fingerprint: {:016x} ({} counters) — identical for any --shards at this seed",
        o.counter_digest,
        o.counters.len()
    );
    let json = crate::cluster::perf::render_json(&o);
    std::fs::write(&out, &json).map_err(|e| format!("{out}: {e}"))?;
    println!("wrote {out}");
    if let Some(base_path) = flags.get("baseline") {
        let base_text =
            std::fs::read_to_string(base_path).map_err(|e| format!("{base_path}: {e}"))?;
        let base = crate::cluster::perf::parse_events_per_sec(&base_text)
            .ok_or_else(|| format!("{base_path}: no events_per_sec field"))?;
        let floor = base * (1.0 - gate / 100.0);
        println!(
            "gate: current {:.0} ev/s vs baseline {base:.0} ev/s (floor {floor:.0}, -{gate}%)",
            o.events_per_sec
        );
        if o.events_per_sec < floor {
            return Err(format!(
                "perf regression: {:.0} events/sec is more than {gate}% below the baseline's {base:.0}",
                o.events_per_sec
            ));
        }
    }
    Ok(())
}

/// `vhpc acct` — sacct-style accounting over a structured trace file
/// (written by any driver run with `--trace FILE`). Replays the event
/// log into per-job and per-tenant history: waits, runtimes,
/// slot-seconds, attempts, preemptions and final states. Unparseable
/// lines are counted and skipped — a truncated or corrupt trace
/// degrades to a partial report, never an error.
fn cmd_acct(rest: &[String]) -> Result<(), String> {
    // one positional operand (the trace file) plus --key value flags
    let mut positional: Vec<String> = Vec::new();
    let mut flag_args: Vec<String> = Vec::new();
    let mut it = rest.iter();
    while let Some(a) = it.next() {
        if a.starts_with("--") {
            flag_args.push(a.clone());
            match it.next() {
                Some(v) => flag_args.push(v.clone()),
                None => return Err(format!("{a} needs a value")),
            }
        } else {
            positional.push(a.clone());
        }
    }
    let flags = parse_flags(&flag_args)?;
    let path = match positional.as_slice() {
        [p] => p,
        _ => {
            return Err(
                "usage: vhpc acct TRACE_FILE [--format json|table] [--tenant T] \
                 [--state S] [--since SECS]"
                    .into(),
            )
        }
    };
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let report = crate::obs::acct::from_trace_lines(text.lines());
    let filter = crate::obs::acct::AcctFilter {
        tenant: match flags.get("tenant") {
            Some(v) => Some(v.parse().map_err(|_| format!("bad --tenant: {v}"))?),
            None => None,
        },
        state: flags.get("state").cloned(),
        since: match flags.get("since") {
            Some(v) => {
                let secs: u64 = v.parse().map_err(|_| format!("bad --since: {v}"))?;
                Some(SimTime::from_secs(secs))
            }
            None => None,
        },
    };
    let report = report.filtered(&filter);
    let format: String = flag(&flags, "format", "table".to_string())?;
    match format.as_str() {
        "json" => print!("{}", crate::obs::acct::render_json(&report)),
        "table" => print!("{}", crate::obs::acct::render_table(&report)),
        other => return Err(format!("unknown --format {other} (expected json or table)")),
    }
    Ok(())
}

/// `vhpc trace` — timeline analysis over a structured trace file:
/// per-job lifecycles (submit→dispatch→launch→terminal with the
/// wait/run/requeue breakdown and the critical attempt), the
/// scale-decision audit (every up/down/hold with its reason code and
/// the demand signal sampled around it), and the gauge time-series.
/// `--series csv|json` exports just the sampled time-series. Shares
/// `vhpc acct`'s torn-input posture: unparseable lines are counted and
/// skipped, so a truncated trace degrades to a partial report.
fn cmd_trace(rest: &[String]) -> Result<(), String> {
    // one positional operand (the trace file) plus --key value flags,
    // same shape as `vhpc acct`
    let mut positional: Vec<String> = Vec::new();
    let mut flag_args: Vec<String> = Vec::new();
    let mut it = rest.iter();
    while let Some(a) = it.next() {
        if a.starts_with("--") {
            flag_args.push(a.clone());
            match it.next() {
                Some(v) => flag_args.push(v.clone()),
                None => return Err(format!("{a} needs a value")),
            }
        } else {
            positional.push(a.clone());
        }
    }
    let flags = parse_flags(&flag_args)?;
    let path = match positional.as_slice() {
        [p] => p,
        _ => {
            return Err(
                "usage: vhpc trace TRACE_FILE [--format json|table] [--job J] \
                 [--series csv|json]"
                    .into(),
            )
        }
    };
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let mut report = crate::obs::analyze::from_trace_lines(text.lines());
    if let Some(series) = flags.get("series") {
        match series.as_str() {
            "csv" => print!("{}", crate::obs::analyze::render_series_csv(&report)),
            "json" => print!("{}", crate::obs::analyze::render_series_json(&report)),
            other => return Err(format!("unknown --series {other} (expected csv or json)")),
        }
        return Ok(());
    }
    if let Some(v) = flags.get("job") {
        let job: u64 = v.parse().map_err(|_| format!("bad --job: {v}"))?;
        report.retain_job(job);
    }
    let format: String = flag(&flags, "format", "table".to_string())?;
    match format.as_str() {
        "json" => print!("{}", crate::obs::analyze::render_json(&report)),
        "table" => print!("{}", crate::obs::analyze::render_table(&report)),
        other => return Err(format!("unknown --format {other} (expected json or table)")),
    }
    Ok(())
}

fn cmd_build(flags: HashMap<String, String>) -> Result<(), String> {
    let text = match flags.get("dockerfile") {
        Some(path) => std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?,
        None => Dockerfile::paper_compute_node().to_string(),
    };
    let df = Dockerfile::parse(&text).map_err(|e| e.to_string())?;
    let mut store = ImageStore::with_base_images();
    let image = store
        .build(&df, "nchc/mpi-computenode:latest")
        .map_err(|e| e.to_string())?;
    println!("built {} ({} layers, {} total)", image.reference, image.layers.len(),
        crate::util::format_bytes(image.total_size()));
    for l in &image.layers {
        println!("  {}  {:>10}  {}", l.digest().short(),
            crate::util::format_bytes(l.size_bytes()), l.created_by);
    }
    Ok(())
}

fn cmd_bench_net(flags: HashMap<String, String>) -> Result<(), String> {
    use crate::hw::rack::Plant;
    use crate::mpi::hostfile::Hostfile;
    use crate::mpi::launcher::LaunchPlan;
    use crate::util::ids::{ContainerId, MachineId};
    use crate::vnet::addr::Ipv4;
    use crate::vnet::fabric::Fabric;
    use crate::workloads::ring::ping_pong;
    use std::sync::{Arc, Mutex};

    let spec = load_spec(&flags)?;
    let plant = Plant::paper_testbed();
    let mut fabric = Fabric::from_plant(&plant, spec.bridge);
    fabric.place(ContainerId::new(0), MachineId::new(1));
    fabric.place(ContainerId::new(1), MachineId::new(2));
    let mut ip_to_container = std::collections::HashMap::new();
    ip_to_container.insert(Ipv4::parse("10.10.0.2").unwrap(), ContainerId::new(0));
    ip_to_container.insert(Ipv4::parse("10.10.0.3").unwrap(), ContainerId::new(1));
    let plan = LaunchPlan {
        hostfile: Hostfile::parse("10.10.0.2 slots=1\n10.10.0.3 slots=1\n").unwrap(),
        n_ranks: 2,
        ip_to_container,
        fabric: Arc::new(Mutex::new(fabric)),
        eager_threshold: 64 * 1024,
    };
    let sizes = [64usize, 1024, 16 * 1024, 256 * 1024, 4 << 20, 64 << 20];
    println!("mode={}  (cross-host rank0<->rank1)", spec.bridge.name());
    println!("{:>12} {:>14} {:>14}", "bytes", "one-way", "MB/s");
    for p in ping_pong(&plan, &sizes, 8).map_err(|e| e.to_string())? {
        println!("{:>12} {:>14} {:>14.1}", p.bytes, p.one_way.to_string(), p.bandwidth / 1e6);
    }
    Ok(())
}

/// Entry point used by the `vhpc` binary. Returns the process exit code.
pub fn main() -> i32 {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => ("help", &[][..]),
    };
    let result = match cmd {
        "version" | "--version" => {
            println!("vhpc {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        "up" => parse_flags(rest).and_then(cmd_up),
        "run" => parse_flags(rest).and_then(cmd_run),
        "mix" => parse_flags(rest).and_then(cmd_mix),
        "tenants" => parse_flags(rest).and_then(cmd_tenants),
        "chaos" => parse_flags(rest).and_then(cmd_chaos),
        "ha" => parse_flags(rest).and_then(cmd_ha),
        "acct" => cmd_acct(rest),
        "trace" => cmd_trace(rest),
        "perf" => parse_flags(rest).and_then(cmd_perf),
        "build" => parse_flags(rest).and_then(cmd_build),
        "bench-net" => parse_flags(rest).and_then(cmd_bench_net),
        "lint" => return crate::lint::cli_main(rest),
        "help" | "--help" | "-h" => {
            println!(
                "vhpc — virtual HPC cluster with auto-scaling (Yu & Huang 2015 reproduction)\n\n\
                 usage:\n  vhpc up        [--config F] [--machines N] [--sim-seconds S] [--bridge MODE]\n  \
                 vhpc run       [--ranks N] [--tile T] [--steps K] [--bridge MODE]\n  \
                 vhpc mix       [--jobs N] [--machines M] [--max-concurrent K] [--policy fifo|easy|priority|fairshare] [--racks N] [--sim-seconds S] [--shards N] [--ticks T]\n  \
                 vhpc tenants   [--tenants N] [--policy fifo|easy|priority|fairshare] [--duration S] [--rate R] [--skew S] [--seed S] [--max-queued N] [--defer-over-quota true|false] [--sim-seconds S] [--shards N] [--crash-at S]\n  \
                 vhpc chaos     [--jobs N] [--machines M] [--seed S] [--mtbf SECS] [--max-retries K] [--sim-seconds S] [--shards N]\n  \
                 vhpc ha        [--jobs N] [--machines M] [--crash-at S] [--lock-ttl S] [--snapshot-every N] [--ticks T]\n  \
                 vhpc acct      TRACE_FILE [--format json|table] [--tenant T] [--state S] [--since SECS]\n  \
                 vhpc trace     TRACE_FILE [--format json|table] [--job J] [--series csv|json]\n  \
                 vhpc perf      [--jobs N] [--tenants N] [--machines M] [--shards N] [--seed S] [--duration S] [--out F] [--trace F] [--baseline F] [--gate PCT]\n  \
                 vhpc build     [--dockerfile F]\n  \
                 vhpc bench-net [--bridge docker0|bridge0|host]\n  \
                 vhpc lint      [--fix-waivers] [paths…]   (determinism static analysis; see lint.toml)\n  \
                 vhpc version\n\n\
                 drivers (up/run/mix/tenants/chaos/ha, sharded or not) also take --trace FILE\n\
                 (JSON-lines event log, queryable with `vhpc acct` and `vhpc trace`;\n\
                 sharded traces are byte-identical at any --shards)"
            );
            Ok(())
        }
        other => Err(format!("unknown subcommand {other} (try `vhpc help`)")),
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_parser() {
        let flags = parse_flags(&["--a".into(), "1".into(), "--b".into(), "x".into()]).unwrap();
        assert_eq!(flags["a"], "1");
        assert_eq!(flag(&flags, "a", 0u32).unwrap(), 1);
        assert_eq!(flag(&flags, "missing", 7u32).unwrap(), 7);
        assert!(flag::<u32>(&flags, "b", 0).is_err());
        assert!(parse_flags(&["positional".into()]).is_err());
        assert!(parse_flags(&["--dangling".into()]).is_err());
    }

    #[test]
    fn policy_flag_parses() {
        let mut flags = HashMap::new();
        flags.insert("policy".to_string(), "easy".to_string());
        assert_eq!(flag(&flags, "policy", PolicyKind::Fifo).unwrap(), PolicyKind::Easy);
        flags.insert("policy".to_string(), "slurm".to_string());
        assert!(flag::<PolicyKind>(&flags, "policy", PolicyKind::Fifo).is_err());
    }

    #[test]
    fn load_spec_overrides() {
        let mut flags = HashMap::new();
        flags.insert("machines".to_string(), "6".to_string());
        flags.insert("bridge".to_string(), "docker0".to_string());
        let spec = load_spec(&flags).unwrap();
        assert_eq!(spec.machines, 6);
        assert_eq!(spec.bridge, crate::vnet::BridgeMode::Docker0);
        flags.insert("bridge".to_string(), "nope".to_string());
        assert!(load_spec(&flags).is_err());
    }
}
