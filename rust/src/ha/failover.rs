//! Standby head + leadership lock: who schedules, and how a standby
//! takes over when the active head dies.
//!
//! The active head holds a consul-session-style lease: a TTL health
//! check (`__vhpc-head`) it refreshes on every scheduler tick, plus the
//! `vhpc/ha/leader` KV record carrying its epoch. When a
//! [`FaultKind::HeadCrash`](crate::faults::FaultKind) kills the head
//! process, the refreshes stop; once the lease TTL runs out, the
//! standby's monitor loop observes the expired check, bumps the epoch,
//! rebuilds the head from snapshot + WAL tail, re-renders the hostfile
//! and re-arms completion timers for the still-running jobs.
//!
//! Crash-consistency invariants the takeover keeps:
//!
//! * **Running jobs keep running.** Their ranks live on compute nodes,
//!   not on the head; the replayed head knows each running attempt
//!   (logged at dispatch) and re-arms its completion at the original
//!   predicted finish (clamped to the takeover time when the finish
//!   fell inside the outage window).
//! * **The dead head's epoch is fenced.** Completion events carry the
//!   epoch they were scheduled under; events from a dead epoch are
//!   dropped, so a timer armed by the dead head can never race the
//!   replayed head's own timers — and the attempt generation still
//!   guards against stale attempts exactly as in the fault paths.
//! * **Failover is not a fault.** No retry budget is charged, nothing
//!   requeues, no attempt generation advances: the replayed head is the
//!   same head, one process later.

use crate::cluster::head::{Head, JobState};
use crate::cluster::vcluster::{ClusterEvent, ClusterState, VirtualCluster};
use crate::consul::health::CheckStatus;
use crate::consul::raft::Command;
use crate::consul::ConsulCluster;
use crate::ha::snapshot::HeadDump;
use crate::ha::wal::{WalEvent, LEADER_KEY, SNAPSHOT_KEY, WAL_PREFIX};
use crate::ha::HaConfig;
use crate::sim::{Engine, SimTime};
use crate::util::ids::{JobId, MachineId};

/// The health-registry node name of the active head's lease.
pub const HEAD_LEASE: &str = "__vhpc-head";

/// Runtime HA state carried by the cluster. Inert (and cost-free)
/// when `config.enabled` is false.
#[derive(Debug, Clone)]
pub struct HaState {
    pub config: HaConfig,
    /// Current head incarnation. Completion events carry the epoch they
    /// were scheduled under; a takeover bumps it, fencing the dead
    /// head's in-flight timers.
    pub epoch: u64,
    /// False between a head crash and the standby's takeover.
    pub head_alive: bool,
    /// When the active head died (cleared at takeover; feeds the
    /// `ha_failover_seconds` histogram).
    pub crashed_at: Option<SimTime>,
    /// Next WAL sequence number to allocate.
    pub(crate) next_seq: u64,
    /// Appends since the last snapshot (drives the snapshot cadence).
    pub(crate) appends_since_snapshot: u64,
    /// WAL entries below this seq have been truncated into a snapshot.
    pub(crate) truncated_below: u64,
    /// Events the most recent takeover replayed (snapshotting bounds
    /// this regardless of cluster age).
    pub last_replayed: u64,
    /// True while a multi-standby CAS claim round is in flight (between
    /// submitting the claims and reading the winner); stops the monitor
    /// loop from starting a second round.
    pub(crate) claiming: bool,
}

impl HaState {
    pub fn new(config: HaConfig) -> Self {
        Self {
            config,
            epoch: 0,
            head_alive: true,
            crashed_at: None,
            next_seq: 0,
            appends_since_snapshot: 0,
            truncated_below: 0,
            last_replayed: 0,
            claiming: false,
        }
    }

    /// True while the head process is down (standby not yet promoted).
    pub fn head_down(&self) -> bool {
        self.config.enabled && !self.head_alive
    }
}

/// Arm the HA machinery at cluster start: register the head's lease,
/// record epoch 0 in the KV leadership key, and start the standby
/// monitor loop.
pub(crate) fn install(st: &mut ClusterState, eng: &mut Engine<ClusterState, ClusterEvent>) {
    let now = st.consul.now();
    st.consul
        .health
        .register(HEAD_LEASE, st.ha.config.lock_ttl, now);
    st.consul.submit(Command::Set {
        key: LEADER_KEY.into(),
        value: format!("epoch 0 at {}", now.as_nanos()),
    });
    let poll = st.ha.config.standby_poll;
    eng.schedule_after(poll, ClusterEvent::StandbyMonitor);
}

/// The standby's monitor loop: watch the active head's lease; once the
/// head is down *and* the lease has expired, take over. The double
/// condition mirrors a real lock — a healthy head's lease never
/// expires, and a dead head cannot refresh.
pub(crate) fn standby_monitor(st: &mut ClusterState, eng: &mut Engine<ClusterState, ClusterEvent>) {
    if !st.ha.config.enabled {
        return;
    }
    st.consul.advance(eng.now());
    if !st.ha.head_alive && !st.ha.claiming {
        let lease = st.consul.health.status(HEAD_LEASE, eng.now());
        if lease != Some(CheckStatus::Passing) {
            // observed from the standby's side: the dead head's epoch
            st.trace.emit(crate::obs::TraceEvent::LeaseLost {
                at: eng.now(),
                epoch: st.ha.epoch,
            });
            if st.ha.config.standbys > 1 {
                start_claim(st, eng);
            } else {
                // a lone standby needs no lock: promote directly (the
                // original failover path, byte for byte)
                takeover(st, eng);
            }
        }
    }
    let poll = st.ha.config.standby_poll;
    eng.schedule_after(poll, ClusterEvent::StandbyMonitor);
}

fn claim_token(standby: u32, epoch: u64, now: SimTime) -> String {
    format!("claim standby{standby} epoch {epoch} at {}", now.as_nanos())
}

/// Which standby a claim token names, if the record holds one.
fn parse_claim(value: &str) -> Option<u32> {
    let rest = value.strip_prefix("claim standby")?;
    let end = rest.find(' ')?;
    rest[..end].parse().ok()
}

/// With more than one standby, takeover goes through the lock: every
/// standby compare-and-sets the `__vhpc-head` lease's leadership record
/// from the value it last observed to its own claim token. The raft log
/// totally orders the writes and the CAS applies only on an exact
/// match, so the first claim flips the record and every later one
/// no-ops — exactly one standby wins, on every replica, regardless of
/// arrival order.
pub(crate) fn start_claim(st: &mut ClusterState, eng: &mut Engine<ClusterState, ClusterEvent>) {
    let now = eng.now();
    let expected = st.consul.kv().get(LEADER_KEY).map(String::from);
    let epoch = st.ha.epoch + 1;
    for s in 0..st.ha.config.standbys {
        st.consul.submit(Command::Cas {
            key: LEADER_KEY.into(),
            expected: expected.clone(),
            value: claim_token(s, epoch, now),
        });
    }
    st.ha.claiming = true;
    st.metrics
        .add("ha_claims_submitted", st.ha.config.standbys as u64);
    let poll = st.ha.config.standby_poll;
    eng.schedule_after(poll, ClusterEvent::ConcludeClaim);
}

/// One poll after the claims went in: the raft quorum has committed
/// them, the leadership record names the winner. The winner promotes;
/// the losers count their loss and re-enter the monitor loop.
pub(crate) fn conclude_claim(st: &mut ClusterState, eng: &mut Engine<ClusterState, ClusterEvent>) {
    st.consul.advance(eng.now());
    st.ha.claiming = false;
    let standbys = st.ha.config.standbys;
    match st.consul.kv().get(LEADER_KEY).and_then(parse_claim) {
        Some(_winner) => {
            st.metrics.inc("ha_takeover_won");
            st.metrics
                .add("ha_takeover_lost", standbys.saturating_sub(1) as u64);
            takeover(st, eng);
        }
        None => {
            // the record moved between observe and claim (e.g. a
            // concurrent epoch publish): every claim lost; the monitor
            // loop keeps watching and will race again
            st.metrics.add("ha_takeover_lost", standbys as u64);
        }
    }
}

/// Read the snapshot (if any) and the WAL tail from the replicated KV
/// store. Returns owned data so the caller can mutate the state while
/// rebuilding.
fn read_log(consul: &ConsulCluster) -> (Option<HeadDump>, Vec<WalEvent>, u64) {
    let kv = consul.kv();
    let (dump, start_seq) = match kv.get(SNAPSHOT_KEY).map(crate::ha::snapshot::decode) {
        Some(Ok((dump, seq))) => (Some(dump), seq),
        Some(Err(e)) => {
            log::warn!("ha: discarding corrupt snapshot: {e}");
            (None, 0)
        }
        None => (None, 0),
    };
    let (events, decode_errors) = decode_wal_listing(&kv.list_prefix(WAL_PREFIX), start_seq);
    (dump, events, decode_errors)
}

/// Decode a key-sorted WAL listing into replayable events, skipping
/// entries below `start_seq` (covered by the snapshot but not yet
/// truncated). Returns the events plus a decode-error count.
///
/// One KV entry is one flush batch: the newline-joined mutations of a
/// single engine event (a lone event for the direct-append path), so
/// decoding walks line by line, in order. A corrupt record truncates
/// the log HERE — the bad line and everything after it, including
/// every later batch: replaying past a hole could resurrect state the
/// durable log cannot vouch for (e.g. re-dispatch a job whose
/// Dispatched entry was lost, double-running it). A batch torn
/// mid-write therefore replays as a clean prefix of one engine event's
/// mutations, never as a prefix with later events spliced behind the
/// tear. Nothing in the simulation corrupts the KV — this is the
/// recovery posture, not a live code path.
///
/// Factored out of [`read_log`] so the batch-boundary crash tests can
/// drive it against deliberately torn listings.
#[doc(hidden)]
pub fn decode_wal_listing(entries: &[(&str, &str)], start_seq: u64) -> (Vec<WalEvent>, u64) {
    let mut events: Vec<(u64, u64, WalEvent)> = Vec::new();
    let mut decode_errors = 0u64;
    // the caller's listing is key-sorted and keys are zero-padded, so
    // this walks the log in sequence order
    'entries: for (key, value) in entries {
        let seq: u64 = match key[WAL_PREFIX.len()..].parse() {
            Ok(s) => s,
            Err(_) => continue,
        };
        if seq < start_seq {
            continue;
        }
        for (line_no, line) in value.lines().enumerate() {
            match WalEvent::decode(line) {
                Ok(ev) => events.push((seq, line_no as u64, ev)),
                Err(e) => {
                    decode_errors += 1;
                    log::error!(
                        "ha: corrupt wal entry {key} line {line_no}, truncating replay: {e}"
                    );
                    break 'entries;
                }
            }
        }
    }
    events.sort_by_key(|&(seq, line, _)| (seq, line));
    (events.into_iter().map(|(_, _, ev)| ev).collect(), decode_errors)
}

/// Promote the standby: rebuild the head from snapshot + WAL, install
/// it, fence the dead epoch, re-render derived state and re-arm
/// completion timers for the work that kept running through the outage.
pub(crate) fn takeover(st: &mut ClusterState, eng: &mut Engine<ClusterState, ClusterEvent>) {
    let now = eng.now();
    st.consul.advance(now);
    let (dump, events, decode_errors) = read_log(&st.consul);

    // a standby inherits deployment config, never logged state: the
    // knobs come from the same spec the dead head was configured from
    let mut head = Head::new();
    {
        let old = &st.head;
        head.poll_interval = old.poll_interval;
        head.max_concurrent = old.max_concurrent;
        head.max_retries = old.max_retries;
        head.policy = old.policy;
        head.quotas = old.quotas;
        head.checkpoint_every_steps = old.checkpoint_every_steps;
        head.completed_retention = old.completed_retention;
        head.ledger = old.ledger.config_clone();
    }
    // derived topology state is re-learned from the live cluster, not
    // replayed: the plant and the containers survived the head
    for idx in 0..st.node_states.len() {
        if let Some(cid) = st.containers[idx] {
            if let Some(ip) = st.engines[idx].container(cid).and_then(|c| c.ip) {
                let rack = st.plant.rack_of(MachineId::new(idx as u32)).unwrap_or(0);
                head.rack_of.insert(ip, rack);
            }
        }
    }
    let had_snapshot = dump.is_some();
    if let Some(dump) = dump {
        head.restore(dump);
    }
    let replayed = crate::ha::wal::replay(&mut head, &events);
    head.enable_journal();
    st.head = head;

    // The autoscaler is part of the head process: the standby starts a
    // fresh policy from deployment config and re-arms the per-direction
    // cooldowns from the replayed marks, so a Down decided just before
    // the crash still holds the new head to its cooldown (and a recent
    // Up doesn't repeat). The low-utilization clock starts over — idle
    // time across an outage is not evidence of an idle cluster.
    let mut autoscaler =
        crate::cluster::autoscaler::Autoscaler::new(st.spec.autoscale.clone());
    autoscaler.restore_cooldowns(st.head.last_scale_up, st.head.last_scale_down);
    st.autoscaler = autoscaler;

    st.ha.epoch += 1;
    st.ha.head_alive = true;
    st.ha.last_replayed = replayed as u64;
    st.trace.emit(crate::obs::TraceEvent::Takeover {
        at: now,
        epoch: st.ha.epoch,
        replayed: replayed as u64,
    });
    st.metrics.inc("ha_takeovers");
    st.metrics.add("ha_replayed_events", replayed as u64);
    if had_snapshot {
        st.metrics.inc("ha_snapshot_restores");
    }
    if decode_errors > 0 {
        st.metrics.add("ha_wal_decode_errors", decode_errors);
    }
    if let Some(t0) = st.ha.crashed_at.take() {
        st.metrics
            .observe("ha_failover_seconds", now.saturating_sub(t0).as_secs_f64());
    }

    // re-acquire the lock: fresh lease plus the bumped epoch in the KV
    // leadership record
    st.consul.health.register(HEAD_LEASE, st.ha.config.lock_ttl, now);
    st.consul.submit(Command::Set {
        key: LEADER_KEY.into(),
        value: format!("epoch {} at {}", st.ha.epoch, now.as_nanos()),
    });

    // derived state: render the hostfile through the fresh watcher
    VirtualCluster::refresh_hostfile(st, now);

    // Re-arm completion timers for jobs that ran through the outage —
    // but first validate every replayed reservation against the live
    // container map. A machine that died *while the head was down* had
    // no head to fail its jobs (the live path does that the instant
    // mpirun's connections drop); re-arming such a job's completion
    // would re-create the phantom-completion-on-dead-slots bug the
    // recovery pipeline exists to prevent. Those jobs are failed over
    // right here, charging the same retry budget a live detection
    // would — the machine death is a real fault, unlike the failover.
    let epoch = st.ha.epoch;
    let mut ids: Vec<JobId> = st.head.running.keys().copied().collect();
    ids.sort();
    let mut rearm: Vec<(JobId, u32, SimTime)> = Vec::new();
    for id in ids.into_iter().rev() {
        // reversed: each requeue is a push_front, so processing
        // youngest first leaves the oldest lost job at the queue head
        // (same convention as the scheduler's reap)
        let lost = st
            .head
            .reserved_hosts(id)
            .iter()
            .any(|addr| !st.ip_to_container.contains_key(addr));
        if lost {
            VirtualCluster::job_lost(st, now, id, "machine died while the head was down");
            continue;
        }
        if let Some(r) = st.head.running.get(&id) {
            let started = match r.state {
                JobState::Running { started } => started,
                _ => now,
            };
            let dur = r
                .planned_duration
                .unwrap_or_else(|| r.spec.estimated_duration());
            rearm.push((id, r.attempt, (started + dur).max(now)));
        }
    }
    // the Lost entries from the validation above must reach the log
    crate::ha::wal::flush(st);
    st.trace.flush();
    rearm.sort_by_key(|&(id, _, _)| id);
    for (id, attempt, at) in rearm {
        eng.schedule_at(at, ClusterEvent::JobDone { id, attempt, epoch });
    }
    log::info!(
        "ha: standby took over at {now} (epoch {}, snapshot: {had_snapshot}, replayed {replayed} wal events)",
        st.ha.epoch
    );
}
