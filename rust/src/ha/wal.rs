//! The scheduler write-ahead log: every head state mutation as a
//! replayable event, serialized through the consul KV store.
//!
//! The head buffers [`WalEvent`]s in an in-memory journal as its
//! mutation methods run (`Head::submit`, `start_next`, `accrue_usage`,
//! `preempt`, `handle_lost_job`, …); the cluster drains the buffer at
//! the end of every engine event that touched the head and writes the
//! whole drain — one engine event's batch of mutations, newline-joined
//! — as a single entry under `vhpc/ha/wal/<seq>`. Because the KV store
//! is applied from the Raft log, the WAL survives exactly what the
//! server quorum survives — a head-process crash loses only the
//! in-memory `Head`, never the log. Batching per engine event (not per
//! mutation) cuts raft submissions by the average batch size and makes
//! the event boundary atomic on disk: replay applies all of an event's
//! mutations or, on a torn entry, stops cleanly at the hole.
//!
//! Replay ([`replay`]) rebuilds a `Head` by feeding the events back
//! through the *same* mutation methods (submissions re-run the quota
//! machinery, losses re-run the retry budget, accruals re-charge the
//! ledger at the original timestamps), so the replayed head is
//! behaviorally identical to the crashed one: same queue order, same
//! attempt generations, same deferral pens, same decayed usage charges.
//! Only dispatch is installed directly from the logged reservation —
//! re-running the placement policy would need the historical hostfile.
//!
//! Events carry their original virtual timestamps; nothing in the
//! format depends on wall-clock time, so a same-seed run replays
//! byte-identically.

use crate::cluster::head::{Head, JobKind, JobRecord, JobSpec, JobState, SubmitOutcome};
use crate::cluster::vcluster::ClusterState;
use crate::consul::raft::Command;
use crate::mpi::hostfile::HostSlot;
use crate::sim::SimTime;
use crate::util::ids::JobId;
use crate::vnet::addr::Ipv4;

/// KV prefix for WAL entries (zero-padded seq keeps the listing
/// time-ordered).
pub const WAL_PREFIX: &str = "vhpc/ha/wal/";
/// KV key of the most recent head snapshot.
pub const SNAPSHOT_KEY: &str = "vhpc/ha/snapshot";
/// KV key of the leadership record (epoch + takeover time).
pub const LEADER_KEY: &str = "vhpc/ha/leader";

/// The KV key for WAL sequence number `seq`.
pub fn wal_key(seq: u64) -> String {
    format!("{WAL_PREFIX}{seq:020}")
}

/// One logged head state mutation. Timestamps are the virtual time the
/// mutation happened at; replay re-applies at the same instants.
#[derive(Debug, Clone, PartialEq)]
pub enum WalEvent {
    /// A submission reached the head's queue/quota machinery.
    Submitted { at: SimTime, spec: JobSpec },
    /// A submission was rejected before reaching the queue (e.g. wider
    /// than the cluster can ever advertise): recorded as Failed.
    SubmitFailed { at: SimTime, spec: JobSpec, reason: String },
    /// Deferred jobs were re-admitted from the quota pens.
    Admitted { at: SimTime },
    /// Running reservations were charged into the tenant ledger.
    Accrued { at: SimTime },
    /// A queued job moved to the running pool on a reserved slice.
    Dispatched { at: SimTime, id: JobId, attempt: u32, slice: Vec<HostSlot> },
    /// The dispatcher pinned the attempt's planned duration (and, for
    /// Jacobi, the solver result computed at launch time).
    Launched {
        at: SimTime,
        id: JobId,
        attempt: u32,
        planned: SimTime,
        result: Option<(usize, f32)>,
    },
    /// A running job was checkpointed-and-requeued by the scheduler.
    Preempted { at: SimTime, id: JobId },
    /// A running job's reservation lost a node. Replay re-runs the
    /// retry budget, so requeue-vs-abandon is decided identically.
    Lost { at: SimTime, id: JobId, reason: String },
    /// A dispatched job never launched and went back to the queue head.
    Unlaunched { at: SimTime, id: JobId },
    /// A running attempt completed.
    Completed { at: SimTime, id: JobId, attempt: u32 },
    /// A running job failed terminally (launch error).
    Failed { at: SimTime, id: JobId, reason: String },
    /// The autoscaler powered machines up: the per-direction cooldown
    /// mark a takeover must keep honouring (a standby that forgot a
    /// recent `Up` would immediately scale again off stale demand).
    ScaleUp { at: SimTime },
    /// The autoscaler retired at least one node (no-op `Down`s are
    /// un-armed by the executor and never logged).
    ScaleDown { at: SimTime },
    /// The tenant arrival generator's mid-stream resume point
    /// ([`ArrivalGen::cursor`](crate::tenancy::arrivals::ArrivalGen)),
    /// journaled by the `vhpc tenants` driver after every pull so a
    /// takeover continues the synthesized stream byte-identically from
    /// wherever the dead head left it.
    ArrivalCursor { at: SimTime, cursor: String },
}

// ---------- text codec ----------
//
// One event per KV value, space-separated tokens, hex-armored free
// text (job names, failure reasons), `f32` results as exact bit
// patterns. No serde in the offline crate set — and the format doubles
// as a human-greppable trace of everything the head ever did.

pub(crate) fn hex_enc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() * 2);
    for b in s.bytes() {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

pub(crate) fn hex_dec(s: &str) -> Result<String, String> {
    if !s.is_ascii() {
        return Err(format!("non-ascii hex string: {s}"));
    }
    if s.len() % 2 != 0 {
        return Err(format!("odd-length hex string: {s}"));
    }
    let mut bytes = Vec::with_capacity(s.len() / 2);
    for i in (0..s.len()).step_by(2) {
        let b = u8::from_str_radix(&s[i..i + 2], 16)
            .map_err(|_| format!("bad hex byte in {s}"))?;
        bytes.push(b);
    }
    String::from_utf8(bytes).map_err(|_| format!("hex is not utf-8: {s}"))
}

pub(crate) fn enc_kind(kind: &JobKind) -> String {
    match kind {
        JobKind::Synthetic { duration } => format!("syn:{}", duration.as_nanos()),
        JobKind::Jacobi { px, py, tile, steps } => format!("jac:{px}:{py}:{tile}:{steps}"),
    }
}

pub(crate) fn dec_kind(tok: &str) -> Result<JobKind, String> {
    if let Some(rest) = tok.strip_prefix("syn:") {
        let ns: u64 = rest.parse().map_err(|_| format!("bad synthetic duration: {tok}"))?;
        return Ok(JobKind::Synthetic { duration: SimTime::from_nanos(ns) });
    }
    if let Some(rest) = tok.strip_prefix("jac:") {
        let parts: Vec<&str> = rest.split(':').collect();
        if parts.len() != 4 {
            return Err(format!("bad jacobi kind: {tok}"));
        }
        let mut vals = [0usize; 4];
        for (i, p) in parts.iter().enumerate() {
            vals[i] = p.parse().map_err(|_| format!("bad jacobi field: {tok}"))?;
        }
        return Ok(JobKind::Jacobi { px: vals[0], py: vals[1], tile: vals[2], steps: vals[3] });
    }
    Err(format!("unknown job kind: {tok}"))
}

pub(crate) fn enc_spec(s: &JobSpec) -> String {
    format!(
        "{} {} {} {} {} n{}",
        s.id.raw(),
        s.ranks,
        s.priority,
        s.tenant,
        enc_kind(&s.kind),
        hex_enc(&s.name)
    )
}

pub(crate) fn enc_slice(slice: &[HostSlot]) -> String {
    let mut out = format!("{}", slice.len());
    for h in slice {
        out.push_str(&format!(" {}:{}", h.addr, h.slots));
    }
    out
}

/// Token cursor over one encoded line.
pub(crate) struct Cur<'a> {
    toks: std::str::SplitWhitespace<'a>,
    line: &'a str,
}

impl<'a> Cur<'a> {
    pub(crate) fn new(line: &'a str) -> Self {
        Self { toks: line.split_whitespace(), line }
    }
    pub(crate) fn next(&mut self) -> Result<&'a str, String> {
        self.toks
            .next()
            .ok_or_else(|| format!("truncated entry: {}", self.line))
    }
    pub(crate) fn u64(&mut self) -> Result<u64, String> {
        let t = self.next()?;
        t.parse().map_err(|_| format!("bad u64 {t} in: {}", self.line))
    }
    pub(crate) fn u32(&mut self) -> Result<u32, String> {
        let t = self.next()?;
        t.parse().map_err(|_| format!("bad u32 {t} in: {}", self.line))
    }
    pub(crate) fn i32(&mut self) -> Result<i32, String> {
        let t = self.next()?;
        t.parse().map_err(|_| format!("bad i32 {t} in: {}", self.line))
    }
    pub(crate) fn usize(&mut self) -> Result<usize, String> {
        let t = self.next()?;
        t.parse().map_err(|_| format!("bad usize {t} in: {}", self.line))
    }
    pub(crate) fn time(&mut self) -> Result<SimTime, String> {
        Ok(SimTime::from_nanos(self.u64()?))
    }
    pub(crate) fn job_id(&mut self) -> Result<JobId, String> {
        Ok(JobId::new(self.u32()?))
    }
    /// A hex-armored string token with a one-letter tag (`n…`, `r…`).
    pub(crate) fn tagged_hex(&mut self, tag: char) -> Result<String, String> {
        let t = self.next()?;
        let rest = t
            .strip_prefix(tag)
            .ok_or_else(|| format!("expected {tag}-tagged token, got {t}"))?;
        hex_dec(rest)
    }
    pub(crate) fn spec(&mut self) -> Result<JobSpec, String> {
        let id = self.job_id()?;
        let ranks = self.u32()?;
        let priority = self.i32()?;
        let tenant = self.u64()?;
        let kind = dec_kind(self.next()?)?;
        let name = self.tagged_hex('n')?;
        Ok(JobSpec { id, name, ranks, kind, priority, tenant })
    }
    pub(crate) fn slice(&mut self) -> Result<Vec<HostSlot>, String> {
        let n = self.usize()?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let t = self.next()?;
            let (addr, slots) = t
                .split_once(':')
                .ok_or_else(|| format!("bad host slot {t}"))?;
            let addr = Ipv4::parse(addr).map_err(|e| e.to_string())?;
            let slots: u32 = slots.parse().map_err(|_| format!("bad slot count {t}"))?;
            out.push(HostSlot { addr, slots });
        }
        Ok(out)
    }
}

/// One-token codec for a launch-time Jacobi result (`steps:bits` or
/// `-`), shared verbatim by the WAL and snapshot formats so the two
/// can never drift.
pub(crate) fn enc_result(result: &Option<(usize, f32)>) -> String {
    match result {
        Some((steps, residual)) => format!("{steps}:{:08x}", residual.to_bits()),
        None => "-".into(),
    }
}

pub(crate) fn dec_result(tok: &str) -> Result<Option<(usize, f32)>, String> {
    if tok == "-" {
        return Ok(None);
    }
    let (steps, bits) = tok
        .split_once(':')
        .ok_or_else(|| format!("bad result {tok}"))?;
    let steps: usize = steps.parse().map_err(|_| format!("bad result steps {tok}"))?;
    let bits = u32::from_str_radix(bits, 16).map_err(|_| format!("bad residual bits {tok}"))?;
    Ok(Some((steps, f32::from_bits(bits))))
}

impl WalEvent {
    /// The event's timestamp (for reports; replay reads it per-variant).
    pub fn at(&self) -> SimTime {
        match self {
            WalEvent::Submitted { at, .. }
            | WalEvent::SubmitFailed { at, .. }
            | WalEvent::Admitted { at }
            | WalEvent::Accrued { at }
            | WalEvent::Dispatched { at, .. }
            | WalEvent::Launched { at, .. }
            | WalEvent::Preempted { at, .. }
            | WalEvent::Lost { at, .. }
            | WalEvent::Unlaunched { at, .. }
            | WalEvent::Completed { at, .. }
            | WalEvent::Failed { at, .. }
            | WalEvent::ScaleUp { at }
            | WalEvent::ScaleDown { at }
            | WalEvent::ArrivalCursor { at, .. } => *at,
        }
    }

    /// Serialize to one KV value.
    pub fn encode(&self) -> String {
        match self {
            WalEvent::Submitted { at, spec } => {
                format!("submit {} {}", at.as_nanos(), enc_spec(spec))
            }
            WalEvent::SubmitFailed { at, spec, reason } => format!(
                "sfail {} r{} {}",
                at.as_nanos(),
                hex_enc(reason),
                enc_spec(spec)
            ),
            WalEvent::Admitted { at } => format!("admit {}", at.as_nanos()),
            WalEvent::Accrued { at } => format!("accrue {}", at.as_nanos()),
            WalEvent::Dispatched { at, id, attempt, slice } => format!(
                "dispatch {} {} {} {}",
                at.as_nanos(),
                id.raw(),
                attempt,
                enc_slice(slice)
            ),
            WalEvent::Launched { at, id, attempt, planned, result } => format!(
                "launch {} {} {} {} {}",
                at.as_nanos(),
                id.raw(),
                attempt,
                planned.as_nanos(),
                enc_result(result)
            ),
            WalEvent::Preempted { at, id } => {
                format!("preempt {} {}", at.as_nanos(), id.raw())
            }
            WalEvent::Lost { at, id, reason } => format!(
                "lost {} {} r{}",
                at.as_nanos(),
                id.raw(),
                hex_enc(reason)
            ),
            WalEvent::Unlaunched { at, id } => {
                format!("unlaunch {} {}", at.as_nanos(), id.raw())
            }
            WalEvent::Completed { at, id, attempt } => {
                format!("complete {} {} {}", at.as_nanos(), id.raw(), attempt)
            }
            WalEvent::Failed { at, id, reason } => format!(
                "fail {} {} r{}",
                at.as_nanos(),
                id.raw(),
                hex_enc(reason)
            ),
            WalEvent::ScaleUp { at } => format!("scaleup {}", at.as_nanos()),
            WalEvent::ScaleDown { at } => format!("scaledown {}", at.as_nanos()),
            WalEvent::ArrivalCursor { at, cursor } => {
                format!("arrcur {} c{}", at.as_nanos(), hex_enc(cursor))
            }
        }
    }

    /// Parse one KV value back into an event.
    pub fn decode(line: &str) -> Result<WalEvent, String> {
        let mut cur = Cur::new(line);
        let kind = cur.next()?;
        match kind {
            "submit" => Ok(WalEvent::Submitted { at: cur.time()?, spec: cur.spec()? }),
            "sfail" => {
                let at = cur.time()?;
                let reason = cur.tagged_hex('r')?;
                Ok(WalEvent::SubmitFailed { at, spec: cur.spec()?, reason })
            }
            "admit" => Ok(WalEvent::Admitted { at: cur.time()? }),
            "accrue" => Ok(WalEvent::Accrued { at: cur.time()? }),
            "dispatch" => Ok(WalEvent::Dispatched {
                at: cur.time()?,
                id: cur.job_id()?,
                attempt: cur.u32()?,
                slice: cur.slice()?,
            }),
            "launch" => Ok(WalEvent::Launched {
                at: cur.time()?,
                id: cur.job_id()?,
                attempt: cur.u32()?,
                planned: cur.time()?,
                result: dec_result(cur.next()?)?,
            }),
            "preempt" => Ok(WalEvent::Preempted { at: cur.time()?, id: cur.job_id()? }),
            "lost" => {
                let at = cur.time()?;
                let id = cur.job_id()?;
                Ok(WalEvent::Lost { at, id, reason: cur.tagged_hex('r')? })
            }
            "unlaunch" => Ok(WalEvent::Unlaunched { at: cur.time()?, id: cur.job_id()? }),
            "complete" => Ok(WalEvent::Completed {
                at: cur.time()?,
                id: cur.job_id()?,
                attempt: cur.u32()?,
            }),
            "fail" => {
                let at = cur.time()?;
                let id = cur.job_id()?;
                Ok(WalEvent::Failed { at, id, reason: cur.tagged_hex('r')? })
            }
            "scaleup" => Ok(WalEvent::ScaleUp { at: cur.time()? }),
            "scaledown" => Ok(WalEvent::ScaleDown { at: cur.time()? }),
            "arrcur" => Ok(WalEvent::ArrivalCursor {
                at: cur.time()?,
                cursor: cur.tagged_hex('c')?,
            }),
            other => Err(format!("unknown wal event kind: {other}")),
        }
    }
}

// ---------- replay ----------

/// Apply one logged event to a head being rebuilt. The head's journal
/// must be disabled during replay (a takeover builds the head with
/// journaling off and enables it afterwards), or replay would re-log
/// its own input.
pub fn apply(head: &mut Head, ev: &WalEvent) {
    match ev {
        WalEvent::Submitted { at, spec } => {
            // the quota machinery re-runs deterministically: queued,
            // deferred and rejected outcomes all reproduce, and a
            // rejection re-creates the failed record the live head's
            // driver wrote
            if let SubmitOutcome::Rejected { spec, reason } = head.submit(spec.clone(), *at) {
                head.record_terminal(JobRecord {
                    spec,
                    state: JobState::Failed { reason },
                    result: None,
                    queued_at: *at,
                    attempt: 0,
                    planned_duration: None,
                });
            }
        }
        WalEvent::SubmitFailed { at, spec, reason } => {
            head.record_terminal(JobRecord {
                spec: spec.clone(),
                state: JobState::Failed { reason: reason.clone() },
                result: None,
                queued_at: *at,
                attempt: 0,
                planned_duration: None,
            });
        }
        WalEvent::Admitted { .. } => {
            head.admit_deferred();
        }
        WalEvent::Accrued { at } => {
            head.accrue_usage(*at);
        }
        WalEvent::Dispatched { at, id, attempt, slice } => {
            head.wal_replay_dispatch(*id, *attempt, slice.clone(), *at);
        }
        WalEvent::Launched { id, planned, result, .. } => {
            if let Some(rec) = head.running.get_mut(id) {
                rec.planned_duration = Some(*planned);
                rec.result = *result;
            }
        }
        WalEvent::Preempted { at, id } => {
            head.preempt(*id, *at);
        }
        WalEvent::Lost { at, id, reason } => {
            head.handle_lost_job(*id, *at, reason);
        }
        WalEvent::Unlaunched { at, id } => {
            head.unlaunch(*id, *at);
        }
        WalEvent::Completed { at, id, attempt } => {
            // mirrors the cluster's job_done bookkeeping (the ledger
            // settlement is a separate Accrued entry just before this)
            if head.running.get(id).map(|r| r.attempt) == Some(*attempt) {
                if let Some(mut rec) = head.finish(*id) {
                    let started = match rec.state {
                        JobState::Running { started } => started,
                        _ => *at,
                    };
                    rec.state = JobState::Done { started, finished: *at };
                    head.record_terminal(rec);
                    head.first_failed_at.remove(id);
                }
            }
        }
        WalEvent::Failed { at: _, id, reason } => {
            head.fail(*id, reason.clone());
        }
        WalEvent::ScaleUp { at } => {
            head.last_scale_up = Some(*at);
        }
        WalEvent::ScaleDown { at } => {
            head.last_scale_down = Some(*at);
        }
        WalEvent::ArrivalCursor { cursor, .. } => {
            head.last_arrival_cursor = Some(cursor.clone());
        }
    }
}

/// Replay a sequence of events into `head`. Returns how many applied.
pub fn replay(head: &mut Head, events: &[WalEvent]) -> usize {
    for ev in events {
        apply(head, ev);
    }
    events.len()
}

// ---------- the durable log ----------

/// Append one event straight to the replicated WAL (used for
/// submissions that arrive while the head is down — the client's retry
/// lands in the log and the standby replays it at takeover).
pub(crate) fn append_direct(st: &mut ClusterState, ev: WalEvent) {
    if !st.ha.config.enabled {
        return;
    }
    let seq = st.ha.next_seq;
    st.ha.next_seq += 1;
    st.ha.appends_since_snapshot += 1;
    st.consul.submit(Command::Set { key: wal_key(seq), value: ev.encode() });
    st.metrics.inc("ha_wal_appends");
}

/// Drain the head's in-memory journal into the replicated WAL, then
/// snapshot if the log has grown past the configured threshold. Called
/// at the end of every engine event that mutated the head — nothing is
/// ever left buffered across events, so a head crash (which is itself
/// an event) can only lose mutations that were never applied.
///
/// The whole drain goes out as **one** KV write: the batch of events a
/// single engine event produced, newline-joined under a single
/// sequence number (the codec is one-line-per-event by construction —
/// free text is hex-armored). One raft submission per engine event
/// instead of one per mutation is the WAL's main throughput lever, and
/// it makes the engine-event boundary atomic in the log: replay sees
/// all of an event's mutations or none (a torn batch truncates at the
/// hole, see `failover::read_log`).
pub(crate) fn flush(st: &mut ClusterState) {
    let _t = crate::obs::profiling::scoped("wal_flush");
    if !st.ha.config.enabled {
        return;
    }
    let batch = st.head.take_journal();
    let flush_at = batch.last().map(|ev| ev.at()).unwrap_or(SimTime::ZERO);
    if !batch.is_empty() {
        let n = batch.len() as u64;
        let seq = st.ha.next_seq;
        st.ha.next_seq += 1;
        st.ha.appends_since_snapshot += n;
        let mut value = String::new();
        for (i, ev) in batch.iter().enumerate() {
            if i > 0 {
                value.push('\n');
            }
            value.push_str(&ev.encode());
        }
        st.consul.submit(Command::Set { key: wal_key(seq), value });
        // counted per event, not per write: the counter (and every
        // fingerprint built on it) means "durable log entries", which
        // batching must not change
        st.metrics.add("ha_wal_appends", n);
        if st.trace.enabled() {
            st.trace.emit(crate::obs::TraceEvent::WalFlush {
                at: flush_at,
                epoch: st.ha.epoch,
                events: n,
            });
        }
    }
    if st.ha.head_alive
        && st.ha.config.snapshot_every > 0
        && st.ha.appends_since_snapshot >= st.ha.config.snapshot_every
    {
        crate::ha::snapshot::write_snapshot(st, flush_at);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::JobId;

    fn spec(id: u32) -> JobSpec {
        JobSpec {
            id: JobId::new(id),
            name: format!("job {id} (weird name)"),
            ranks: 8,
            kind: JobKind::Synthetic { duration: SimTime::from_secs(30) },
            priority: -2,
            tenant: 7,
        }
    }

    fn jac_spec(id: u32) -> JobSpec {
        JobSpec {
            kind: JobKind::Jacobi { px: 4, py: 4, tile: 64, steps: 100 },
            ..spec(id)
        }
    }

    fn host(oct: u8, slots: u32) -> HostSlot {
        HostSlot { addr: Ipv4::new(10, 10, 0, oct), slots }
    }

    #[test]
    fn every_event_kind_roundtrips() {
        let t = SimTime::from_millis(1234);
        let events = vec![
            WalEvent::Submitted { at: t, spec: spec(0) },
            WalEvent::SubmitFailed {
                at: t,
                spec: jac_spec(1),
                reason: "too wide: needs 99".into(),
            },
            WalEvent::Admitted { at: t },
            WalEvent::Accrued { at: t },
            WalEvent::Dispatched {
                at: t,
                id: JobId::new(2),
                attempt: 3,
                slice: vec![host(2, 12), host(3, 4)],
            },
            WalEvent::Launched {
                at: t,
                id: JobId::new(2),
                attempt: 3,
                planned: SimTime::from_secs(60),
                result: Some((100, 1.25e-7)),
            },
            WalEvent::Launched {
                at: t,
                id: JobId::new(4),
                attempt: 0,
                planned: SimTime::from_secs(5),
                result: None,
            },
            WalEvent::Preempted { at: t, id: JobId::new(5) },
            WalEvent::Lost { at: t, id: JobId::new(6), reason: "node m3 died".into() },
            WalEvent::Unlaunched { at: t, id: JobId::new(7) },
            WalEvent::Completed { at: t, id: JobId::new(8), attempt: 1 },
            WalEvent::Failed { at: t, id: JobId::new(9), reason: "launch: boom".into() },
            WalEvent::ScaleUp { at: t },
            WalEvent::ScaleDown { at: t },
            WalEvent::ArrivalCursor {
                at: t,
                cursor: "arr1 12345 678 9 - 1 10:2:3:4:50".into(),
            },
        ];
        for ev in events {
            let line = ev.encode();
            let back = WalEvent::decode(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(back, ev, "roundtrip drift for {line}");
        }
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(WalEvent::decode("").is_err());
        assert!(WalEvent::decode("warp 9").is_err());
        assert!(WalEvent::decode("submit notanumber").is_err());
        assert!(WalEvent::decode("dispatch 1 2 3 1 nocolon").is_err());
        assert!(WalEvent::decode("lost 1 2 zzz").is_err(), "untagged reason must fail");
    }

    #[test]
    fn hex_roundtrips_arbitrary_text() {
        for s in ["", "plain", "with space", "emoji ✓ né", "r prefixed"] {
            assert_eq!(hex_dec(&hex_enc(s)).unwrap(), s);
        }
        assert!(hex_dec("abc").is_err(), "odd length");
        assert!(hex_dec("zz").is_err(), "non-hex");
    }

    #[test]
    fn wal_keys_sort_in_sequence_order() {
        let a = wal_key(9);
        let b = wal_key(10);
        let c = wal_key(100_000);
        assert!(a < b && b < c, "{a} {b} {c}");
        assert!(a.starts_with(WAL_PREFIX));
    }

    /// The core crash-consistency property at head level: a head rebuilt
    /// from the journaled events matches the live head's observable
    /// state (queue, running pool, attempts, ledger).
    #[test]
    fn replayed_head_matches_live_head() {
        let mut live = Head::new();
        live.enable_journal();
        live.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        let mut log: Vec<WalEvent> = Vec::new();

        live.submit(spec(0), SimTime::from_secs(1));
        live.submit(
            JobSpec { ranks: 4, ..spec(1) },
            SimTime::from_secs(1),
        );
        let s0 = live.start_next(SimTime::from_secs(2)).unwrap();
        assert_eq!(s0.spec.id, JobId::new(0));
        live.running.get_mut(&JobId::new(0)).unwrap().planned_duration =
            Some(SimTime::from_secs(30));
        log.append(&mut live.take_journal());
        log.push(WalEvent::Launched {
            at: SimTime::from_secs(2),
            id: JobId::new(0),
            attempt: 0,
            planned: SimTime::from_secs(30),
            result: None,
        });
        let s1 = live.start_next(SimTime::from_secs(3)).unwrap();
        assert_eq!(s1.spec.id, JobId::new(1));
        live.handle_lost_job(JobId::new(1), SimTime::from_secs(10), "node died");
        log.append(&mut live.take_journal());

        let mut rebuilt = Head::new();
        rebuilt.hostfile_text = live.hostfile_text.clone();
        replay(&mut rebuilt, &log);

        assert_eq!(rebuilt.queue.len(), live.queue.len());
        assert_eq!(
            rebuilt.queue.front().map(|(j, _)| j.id),
            live.queue.front().map(|(j, _)| j.id)
        );
        assert_eq!(rebuilt.running.len(), live.running.len());
        let lr = &live.running[&JobId::new(0)];
        let rr = &rebuilt.running[&JobId::new(0)];
        assert_eq!(rr.attempt, lr.attempt);
        assert_eq!(rr.planned_duration, lr.planned_duration);
        assert_eq!(rr.state, lr.state);
        assert_eq!(rebuilt.reserved_slots(), live.reserved_slots());
        assert_eq!(rebuilt.free_slots(), live.free_slots());
        assert_eq!(
            rebuilt.ledger.usage_at(7, SimTime::from_secs(10)),
            live.ledger.usage_at(7, SimTime::from_secs(10)),
            "replayed ledger must charge identically"
        );
        // the lost job's rerun dispatches at the same bumped attempt
        let a = rebuilt.start_next(SimTime::from_secs(11)).unwrap();
        let b = live.start_next(SimTime::from_secs(11)).unwrap();
        assert_eq!(a.spec.id, b.spec.id);
        assert_eq!(a.attempt, b.attempt);
        assert_eq!(a.attempt, 1, "the fault requeue must have bumped the generation");
    }
}
