//! Head-node high availability: crash-consistent failover via a
//! replicated scheduler WAL.
//!
//! The paper's cluster has a single head that owns the queue, the
//! hostfile and the autoscaling decisions — a single point of failure
//! the paper never addresses. This subsystem closes it with the pieces
//! the repo already has: the consul Raft quorum as the durable store,
//! attempt generations as the stale-event fence, and the deterministic
//! event engine as the replay substrate.
//!
//! * [`wal`] — an event-sourced write-ahead log of every head state
//!   mutation (submit, dispatch, launch, completion, failure,
//!   preemption, fault requeue, deferral admission, usage accrual),
//!   serialized through the replicated KV store, so the log survives
//!   exactly what the Raft quorum survives. Replay feeds the events
//!   back through the same `Head` methods, reproducing queue order,
//!   attempt generations, quota pens and ledger charges.
//! * [`snapshot`] — periodic compact snapshots of the full head state
//!   (including the decayed tenant ledger) with WAL truncation, so
//!   replay cost is bounded by the snapshot cadence, not cluster age.
//! * [`failover`] — the consul-session-style leadership lock (a TTL
//!   lease the active head refreshes every scheduler tick) and the
//!   standby takeover: rebuild from snapshot + WAL tail, fence the
//!   dead head's epoch, re-render the hostfile, re-arm completion
//!   timers. Running jobs keep running across the failover; no retry
//!   budget is charged and nothing requeues.
//!
//! HA is off by default ([`HaConfig::enabled`]) and costs nothing when
//! off: the head's journal stays `None` and no extra events are
//! scheduled, so every pre-HA scenario reproduces byte for byte.

pub mod failover;
pub mod snapshot;
pub mod wal;

pub use failover::{HaState, HEAD_LEASE};
pub use snapshot::HeadDump;
pub use wal::WalEvent;

use crate::cluster::head::{JobKind, JobState};
use crate::cluster::vcluster::VirtualCluster;
use crate::config::ClusterSpec;
use crate::faults::FaultPlan;
use crate::sim::SimTime;
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

/// Head-availability knobs (the `[ha]` config section).
#[derive(Debug, Clone, PartialEq)]
pub struct HaConfig {
    /// Off by default: the paper's single-head cluster, byte for byte.
    pub enabled: bool,
    /// Head lease TTL — how stale the lease must be before the standby
    /// may declare the head dead and take the lock. Detection latency
    /// is roughly `lock_ttl + standby_poll`.
    pub lock_ttl: SimTime,
    /// Standby monitor poll interval.
    pub standby_poll: SimTime,
    /// WAL appends between snapshots (0 = never snapshot; replay cost
    /// then grows with the full log).
    pub snapshot_every: u64,
    /// Standby heads monitoring the lease. With 1 (the default) the
    /// lone standby promotes directly, byte-for-byte the original
    /// failover path. With more, takeover goes through a
    /// compare-and-set race on the leadership record: every standby
    /// claims, the raft log picks exactly one winner, and the losers
    /// stay in monitoring.
    pub standbys: u32,
}

impl Default for HaConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            lock_ttl: SimTime::from_secs(5),
            standby_poll: SimTime::from_secs(1),
            snapshot_every: 256,
            standbys: 1,
        }
    }
}

impl HaConfig {
    /// HA on with the default lock/snapshot cadence.
    pub fn on() -> Self {
        Self { enabled: true, ..Self::default() }
    }
}

/// What an HA scenario run measured.
#[derive(Debug, Clone)]
pub struct HaOutcome {
    pub jobs_submitted: usize,
    /// Jobs that reached `Done` (every submitted job, when the failover
    /// lost nothing).
    pub jobs_completed: usize,
    /// Head crashes injected.
    pub head_crashes: u64,
    /// Standby takeovers performed.
    pub takeovers: u64,
    /// Head-failover MTTR (crash to takeover), mean/max seconds.
    pub failover_mean: f64,
    pub failover_max: f64,
    /// WAL events the last takeover replayed (bounded by the snapshot
    /// cadence when snapshotting is on).
    pub replayed_events: u64,
    /// Total WAL appends over the run.
    pub wal_appends: u64,
    /// Snapshots written.
    pub snapshots: u64,
    /// Fault requeues — stays 0 when only the head crashes: failover
    /// charges no retry budget.
    pub requeues: u64,
    pub makespan: f64,
    /// Stable counter snapshot (same-seed determinism checks).
    pub fingerprint: BTreeMap<String, u64>,
}

/// Drive a synthetic `(ranks, duration_secs)` trace through an
/// HA-enabled cluster, optionally crashing the head `crash_at` after
/// warm-up, and measure the failover. Mirrors
/// [`faults::run_chaos_trace`](crate::faults::run_chaos_trace) so HA
/// scenarios stay comparable with the chaos ones. Errors if the trace
/// has not fully drained after `deadline_secs` of virtual time — which
/// is exactly what a failover that loses submitted work looks like.
pub fn run_ha_trace(
    mut spec: ClusterSpec,
    trace: &[(u32, u64)],
    crash_at: Option<SimTime>,
    warmup_slots: u32,
    deadline_secs: u64,
) -> Result<(HaOutcome, VirtualCluster)> {
    spec.ha.enabled = true;
    let mut vc = VirtualCluster::new(spec)?;
    vc.start();
    ensure!(
        vc.advance_until(SimTime::from_secs(600), |st| {
            st.head.slots_available() >= warmup_slots
        }),
        "cluster never advertised {warmup_slots} slots"
    );
    if let Some(at) = crash_at {
        vc.inject_faults(&FaultPlan::head_crash(at));
    }
    for (i, (ranks, secs)) in trace.iter().enumerate() {
        vc.submit(
            &format!("ha-{i}"),
            *ranks,
            JobKind::Synthetic { duration: SimTime::from_secs(*secs) },
        );
    }
    let t0 = vc.now();
    let deadline = t0 + SimTime::from_secs(deadline_secs);
    while vc.now() < deadline && vc.completed_total() < trace.len() {
        vc.advance(SimTime::from_secs(1));
    }
    ensure!(
        vc.completed_total() == trace.len(),
        "ha trace never drained: {}/{} jobs accounted for after {deadline_secs}s \
         (work lost across the failover?)",
        vc.completed_total(),
        trace.len()
    );
    let mut completed = 0usize;
    let mut last_finish = SimTime::ZERO;
    for rec in vc.completed_jobs() {
        if let JobState::Done { finished, .. } = rec.state {
            completed += 1;
            last_finish = last_finish.max(finished);
        }
    }
    let metrics = vc.metrics();
    let (failover_mean, failover_max) = metrics
        .histogram("ha_failover_seconds")
        .map(|h| (h.mean(), h.max()))
        .unwrap_or((0.0, 0.0));
    let outcome = HaOutcome {
        jobs_submitted: trace.len(),
        jobs_completed: completed,
        head_crashes: metrics.counter("head_crashes"),
        takeovers: metrics.counter("ha_takeovers"),
        failover_mean,
        failover_max,
        replayed_events: vc.state.ha.last_replayed,
        wal_appends: metrics.counter("ha_wal_appends"),
        snapshots: metrics.counter("ha_snapshots"),
        requeues: metrics.counter("jobs_requeued"),
        makespan: last_finish.saturating_sub(t0).as_secs_f64(),
        fingerprint: metrics.counters_snapshot(),
    };
    Ok((outcome, vc))
}
