//! Compact head snapshots: bound WAL replay cost.
//!
//! The WAL alone is enough to rebuild the head, but replay cost grows
//! with the log. Every [`HaConfig::snapshot_every`](crate::ha::HaConfig)
//! appends, the active head serializes its complete dynamic state —
//! queue, deferral pens, running pool with reservations, completed
//! records, retry/attempt/progress maps and the decayed tenant ledger —
//! into the KV key `vhpc/ha/snapshot`, then deletes the WAL entries the
//! snapshot covers. A takeover loads the snapshot, replays only the
//! tail of the log, and is done: replay cost is bounded by the snapshot
//! cadence, not the age of the cluster.
//!
//! The encoding is deterministic (maps are emitted in sorted order,
//! floats as exact bit patterns), so two snapshots of identical state
//! are byte-identical — which is what lets the tests assert
//! dump → encode → decode → restore → dump round-trips exactly.

use crate::cluster::head::{JobRecord, JobSpec, JobState};
use crate::cluster::vcluster::ClusterState;
use crate::consul::raft::Command;
use crate::ha::wal::{
    dec_result, enc_result, enc_slice, enc_spec, hex_dec, hex_enc, wal_key, Cur, SNAPSHOT_KEY,
};
use crate::mpi::hostfile::HostSlot;
use crate::sim::SimTime;
use crate::util::ids::JobId;

/// A complete export of the head's dynamic state. Produced by
/// [`Head::dump`](crate::cluster::head::Head::dump), installed by
/// [`Head::restore`](crate::cluster::head::Head::restore). Config knobs
/// (policy, quotas, intervals) are deliberately absent — a standby gets
/// those from its own deployment configuration, not from the log.
#[derive(Debug, Clone, Default)]
pub struct HeadDump {
    /// Queue entries in dispatch order.
    pub queue: Vec<(JobSpec, SimTime)>,
    /// Deferral-pen entries, flattened in (tenant, FIFO) order.
    pub deferred: Vec<(u64, JobSpec, SimTime)>,
    /// Running records, sorted by job id.
    pub running: Vec<JobRecord>,
    /// Completed records in their recorded order.
    pub completed: Vec<JobRecord>,
    /// Per-job reserved hostfile slices, sorted by job id.
    pub reserved: Vec<(JobId, Vec<HostSlot>)>,
    /// Fault-retry budget spent, sorted by job id.
    pub retries: Vec<(JobId, u32)>,
    /// Attempt generations, sorted by job id.
    pub attempts: Vec<(JobId, u32)>,
    /// Credited Jacobi steps from prior attempts, sorted by job id.
    pub jacobi_progress: Vec<(JobId, usize)>,
    /// First-node-loss timestamps (MTTR anchors), sorted by job id.
    pub first_failed_at: Vec<(JobId, SimTime)>,
    /// The ledger accrual high-water mark.
    pub last_accrued: SimTime,
    /// Tenant ledger accounts `(tenant, decayed balance, as-of)`.
    pub ledger_accounts: Vec<(u64, f64, SimTime)>,
    /// Completed records dropped by the head's retention cap before
    /// this snapshot was taken (keeps completed totals monotonic
    /// across a failover).
    pub completed_trimmed: u64,
    /// The autoscaler's per-direction cooldown marks: when the pool
    /// last scaled up / last retired nodes. A takeover re-arms the
    /// standby's cooldowns from these.
    pub last_scale_up: Option<SimTime>,
    pub last_scale_down: Option<SimTime>,
    /// The tenant arrival generator's last journaled resume cursor
    /// (the HA arrival-stream resume point; absent on non-tenant runs).
    pub last_arrival_cursor: Option<String>,
}

fn enc_state(s: &JobState) -> String {
    match s {
        JobState::Queued => "queued".into(),
        JobState::Running { started } => format!("run:{}", started.as_nanos()),
        JobState::Done { started, finished } => {
            format!("done:{}:{}", started.as_nanos(), finished.as_nanos())
        }
        JobState::Failed { reason } => format!("fail:{}", hex_enc(reason)),
    }
}

fn dec_state(tok: &str) -> Result<JobState, String> {
    if tok == "queued" {
        return Ok(JobState::Queued);
    }
    if let Some(rest) = tok.strip_prefix("run:") {
        let ns: u64 = rest.parse().map_err(|_| format!("bad run state {tok}"))?;
        return Ok(JobState::Running { started: SimTime::from_nanos(ns) });
    }
    if let Some(rest) = tok.strip_prefix("done:") {
        let (a, b) = rest.split_once(':').ok_or_else(|| format!("bad done state {tok}"))?;
        let s: u64 = a.parse().map_err(|_| format!("bad done state {tok}"))?;
        let f: u64 = b.parse().map_err(|_| format!("bad done state {tok}"))?;
        return Ok(JobState::Done {
            started: SimTime::from_nanos(s),
            finished: SimTime::from_nanos(f),
        });
    }
    if let Some(rest) = tok.strip_prefix("fail:") {
        return Ok(JobState::Failed { reason: crate::ha::wal::hex_dec(rest)? });
    }
    Err(format!("unknown job state {tok}"))
}

fn enc_record(r: &JobRecord) -> String {
    let planned = match r.planned_duration {
        Some(d) => d.as_nanos().to_string(),
        None => "-".into(),
    };
    format!(
        "{} {} {} {} {} {}",
        r.queued_at.as_nanos(),
        r.attempt,
        enc_state(&r.state),
        planned,
        enc_result(&r.result),
        enc_spec(&r.spec)
    )
}

fn dec_record(cur: &mut Cur) -> Result<JobRecord, String> {
    let queued_at = cur.time()?;
    let attempt = cur.u32()?;
    let state = dec_state(cur.next()?)?;
    let planned_tok = cur.next()?;
    let planned_duration = if planned_tok == "-" {
        None
    } else {
        let ns: u64 = planned_tok
            .parse()
            .map_err(|_| format!("bad planned duration {planned_tok}"))?;
        Some(SimTime::from_nanos(ns))
    };
    let result = dec_result(cur.next()?)?;
    let spec = cur.spec()?;
    Ok(JobRecord { spec, state, result, queued_at, attempt, planned_duration })
}

fn enc_opt_time(t: Option<SimTime>) -> String {
    match t {
        Some(t) => t.as_nanos().to_string(),
        None => "-".into(),
    }
}

fn dec_opt_time(tok: &str) -> Result<Option<SimTime>, String> {
    if tok == "-" {
        return Ok(None);
    }
    let ns: u64 = tok.parse().map_err(|_| format!("bad optional time {tok}"))?;
    Ok(Some(SimTime::from_nanos(ns)))
}

/// Serialize a dump plus the WAL cursor it covers (replay resumes at
/// `start_seq`).
pub fn encode(dump: &HeadDump, start_seq: u64) -> String {
    let mut out = String::new();
    out.push_str("vhpc-ha-snapshot v1\n");
    out.push_str(&format!("seq {start_seq}\n"));
    out.push_str(&format!("last_accrued {}\n", dump.last_accrued.as_nanos()));
    out.push_str(&format!("trimmed {}\n", dump.completed_trimmed));
    out.push_str(&format!(
        "scale {} {}\n",
        enc_opt_time(dump.last_scale_up),
        enc_opt_time(dump.last_scale_down)
    ));
    if let Some(cursor) = &dump.last_arrival_cursor {
        out.push_str(&format!("arrcur {}\n", hex_enc(cursor)));
    }
    for (spec, at) in &dump.queue {
        out.push_str(&format!("q {} {}\n", at.as_nanos(), enc_spec(spec)));
    }
    for (tenant, spec, at) in &dump.deferred {
        out.push_str(&format!("d {tenant} {} {}\n", at.as_nanos(), enc_spec(spec)));
    }
    for rec in &dump.running {
        out.push_str(&format!("r {}\n", enc_record(rec)));
    }
    for rec in &dump.completed {
        out.push_str(&format!("c {}\n", enc_record(rec)));
    }
    for (id, slice) in &dump.reserved {
        out.push_str(&format!("res {} {}\n", id.raw(), enc_slice(slice)));
    }
    for (id, n) in &dump.retries {
        out.push_str(&format!("retry {} {n}\n", id.raw()));
    }
    for (id, n) in &dump.attempts {
        out.push_str(&format!("att {} {n}\n", id.raw()));
    }
    for (id, n) in &dump.jacobi_progress {
        out.push_str(&format!("jac {} {n}\n", id.raw()));
    }
    for (id, t) in &dump.first_failed_at {
        out.push_str(&format!("ff {} {}\n", id.raw(), t.as_nanos()));
    }
    for (tenant, usage, as_of) in &dump.ledger_accounts {
        out.push_str(&format!(
            "acct {tenant} {:016x} {}\n",
            usage.to_bits(),
            as_of.as_nanos()
        ));
    }
    out
}

/// Parse a snapshot back into a dump plus the WAL cursor to resume at.
pub fn decode(text: &str) -> Result<(HeadDump, u64), String> {
    let mut lines = text.lines();
    match lines.next() {
        Some("vhpc-ha-snapshot v1") => {}
        other => return Err(format!("bad snapshot header: {other:?}")),
    }
    let mut dump = HeadDump::default();
    let mut start_seq = 0u64;
    for line in lines {
        if line.trim().is_empty() {
            continue;
        }
        let mut cur = Cur::new(line);
        match cur.next()? {
            "seq" => start_seq = cur.u64()?,
            "last_accrued" => dump.last_accrued = cur.time()?,
            "trimmed" => dump.completed_trimmed = cur.u64()?,
            "scale" => {
                dump.last_scale_up = dec_opt_time(cur.next()?)?;
                dump.last_scale_down = dec_opt_time(cur.next()?)?;
            }
            "arrcur" => dump.last_arrival_cursor = Some(hex_dec(cur.next()?)?),
            "q" => {
                let at = cur.time()?;
                dump.queue.push((cur.spec()?, at));
            }
            "d" => {
                let tenant = cur.u64()?;
                let at = cur.time()?;
                dump.deferred.push((tenant, cur.spec()?, at));
            }
            "r" => dump.running.push(dec_record(&mut cur)?),
            "c" => dump.completed.push(dec_record(&mut cur)?),
            "res" => {
                let id = cur.job_id()?;
                dump.reserved.push((id, cur.slice()?));
            }
            "retry" => {
                let id = cur.job_id()?;
                dump.retries.push((id, cur.u32()?));
            }
            "att" => {
                let id = cur.job_id()?;
                dump.attempts.push((id, cur.u32()?));
            }
            "jac" => {
                let id = cur.job_id()?;
                dump.jacobi_progress.push((id, cur.usize()?));
            }
            "ff" => {
                let id = cur.job_id()?;
                dump.first_failed_at.push((id, cur.time()?));
            }
            "acct" => {
                let tenant = cur.u64()?;
                let bits = u64::from_str_radix(cur.next()?, 16)
                    .map_err(|_| format!("bad usage bits in: {line}"))?;
                let as_of = cur.time()?;
                dump.ledger_accounts.push((tenant, f64::from_bits(bits), as_of));
            }
            other => return Err(format!("unknown snapshot line kind {other}: {line}")),
        }
    }
    Ok((dump, start_seq))
}

/// Write a snapshot of the live head into the KV store and truncate the
/// WAL entries it covers. Called from the WAL flush path once the log
/// since the last snapshot reaches the configured length.
pub(crate) fn write_snapshot(st: &mut ClusterState, at: SimTime) {
    let seq = st.ha.next_seq;
    let text = encode(&st.head.dump(), seq);
    st.consul
        .submit(Command::Set { key: SNAPSHOT_KEY.into(), value: text });
    // the snapshot serializes after the appends it covers in the raft
    // log, so a reader never sees the truncation before the snapshot.
    // The truncated range holds exactly the events appended since the
    // last snapshot — counted in events, not batch keys, so the counter
    // (and the fingerprints over it) is invariant under WAL batching.
    let truncated = st.ha.appends_since_snapshot;
    for seq in st.ha.truncated_below..st.ha.next_seq {
        st.consul.submit(Command::Delete { key: wal_key(seq) });
    }
    st.ha.truncated_below = st.ha.next_seq;
    st.ha.appends_since_snapshot = 0;
    st.metrics.inc("ha_snapshots");
    st.metrics.add("ha_wal_truncated", truncated);
    if st.trace.enabled() {
        st.trace
            .emit(crate::obs::TraceEvent::SnapshotWritten { at, epoch: st.ha.epoch, seq });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::head::{Head, JobKind};
    use crate::sim::SimTime;
    use crate::util::ids::JobId;

    fn spec(id: u32, ranks: u32, tenant: u64) -> JobSpec {
        JobSpec {
            id: JobId::new(id),
            name: format!("snap {id}"),
            ranks,
            kind: JobKind::Synthetic { duration: SimTime::from_secs(40) },
            priority: 1,
            tenant,
        }
    }

    /// Drive a head through submissions, a dispatch, a loss and a
    /// completion, then prove dump → encode → decode → restore → dump
    /// reproduces the encoding byte for byte.
    #[test]
    fn dump_roundtrips_byte_identical() {
        let mut h = Head::new();
        h.hostfile_text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n".into();
        h.submit(spec(0, 16, 1), SimTime::from_secs(1));
        h.submit(spec(1, 4, 2), SimTime::from_secs(1));
        h.submit(spec(2, 8, 1), SimTime::from_secs(2));
        h.start_next(SimTime::from_secs(3)).unwrap();
        h.start_next(SimTime::from_secs(3)).unwrap();
        h.running.get_mut(&JobId::new(0)).unwrap().planned_duration =
            Some(SimTime::from_secs(40));
        h.handle_lost_job(JobId::new(0), SimTime::from_secs(10), "boom");
        h.accrue_usage(SimTime::from_secs(12));
        h.last_arrival_cursor = Some("arr1 77 88 9 - 0".into());
        if let Some(mut rec) = h.finish(JobId::new(1)) {
            rec.state = JobState::Done {
                started: SimTime::from_secs(3),
                finished: SimTime::from_secs(12),
            };
            h.completed.push(rec);
        }

        let dump = h.dump();
        let text = encode(&dump, 42);
        let (decoded, seq) = decode(&text).expect("snapshot must decode");
        assert_eq!(seq, 42);

        let mut restored = Head::new();
        restored.hostfile_text = h.hostfile_text.clone();
        restored.restore(decoded);
        let text2 = encode(&restored.dump(), 42);
        assert_eq!(text, text2, "restore must reproduce the dump exactly");

        // and the restored head behaves like the original: the lost job
        // is at the queue head with its bumped attempt
        let a = h.start_next(SimTime::from_secs(13)).unwrap();
        let b = restored.start_next(SimTime::from_secs(13)).unwrap();
        assert_eq!(a.spec.id, b.spec.id);
        assert_eq!(a.attempt, b.attempt);
        assert_eq!(a.attempt, 1);
    }

    #[test]
    fn decode_rejects_bad_headers_and_lines() {
        assert!(decode("").is_err());
        assert!(decode("not a snapshot\n").is_err());
        assert!(decode("vhpc-ha-snapshot v1\nwat 1 2\n").is_err());
        assert!(decode("vhpc-ha-snapshot v1\nseq notanumber\n").is_err());
    }

    #[test]
    fn empty_head_snapshot_roundtrips() {
        let h = Head::new();
        let text = encode(&h.dump(), 0);
        let (dump, seq) = decode(&text).unwrap();
        assert_eq!(seq, 0);
        assert!(dump.queue.is_empty());
        assert!(dump.running.is_empty());
        let mut restored = Head::new();
        restored.restore(dump);
        assert_eq!(encode(&restored.dump(), 0), text);
    }
}
