//! NIC models: the link technology determines the fabric cost model.

use crate::sim::SimTime;

/// A NIC / link technology profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NicSpec {
    pub name: &'static str,
    /// Line rate in bits per second.
    pub rate_bps: u64,
    /// One-way wire+stack latency for a minimal frame.
    pub base_latency: SimTime,
    /// Fixed per-message software overhead (driver + stack).
    pub per_msg_overhead: SimTime,
}

impl NicSpec {
    /// 10GbE — the paper's interconnect (Table I).
    pub fn ten_gbe() -> Self {
        Self {
            name: "10GbE",
            rate_bps: 10_000_000_000,
            base_latency: SimTime::from_micros(12),
            per_msg_overhead: SimTime::from_micros(2),
        }
    }

    /// Commodity gigabit ethernet (scale-down comparator).
    pub fn one_gbe() -> Self {
        Self {
            name: "1GbE",
            rate_bps: 1_000_000_000,
            base_latency: SimTime::from_micros(30),
            per_msg_overhead: SimTime::from_micros(5),
        }
    }

    /// FDR InfiniBand (the "faster interconnect" the conclusion muses on).
    pub fn infiniband_fdr() -> Self {
        Self {
            name: "IB-FDR",
            rate_bps: 54_000_000_000,
            base_latency: SimTime::from_nanos(700),
            per_msg_overhead: SimTime::from_nanos(300),
        }
    }

    /// Pure serialization time for `bytes` on this link.
    pub fn serialize_time(&self, bytes: u64) -> SimTime {
        SimTime::from_nanos((bytes as u128 * 8 * 1_000_000_000 / self.rate_bps as u128) as u64)
    }

    /// One-way message time: latency + overhead + serialization.
    pub fn message_time(&self, bytes: u64) -> SimTime {
        self.base_latency + self.per_msg_overhead + self.serialize_time(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialization_scales_with_rate() {
        let t10 = NicSpec::ten_gbe().serialize_time(1_000_000);
        let t1 = NicSpec::one_gbe().serialize_time(1_000_000);
        // 1 MB at 10 Gb/s = 0.8 ms; at 1 Gb/s = 8 ms.
        assert_eq!(t10.as_nanos(), 800_000);
        assert_eq!(t1.as_nanos(), 8_000_000);
    }

    #[test]
    fn ib_beats_ethernet_on_small_messages() {
        let ib = NicSpec::infiniband_fdr().message_time(64);
        let eth = NicSpec::ten_gbe().message_time(64);
        assert!(ib < eth);
    }

    #[test]
    fn zero_bytes_is_pure_latency() {
        let nic = NicSpec::ten_gbe();
        assert_eq!(
            nic.message_time(0),
            nic.base_latency + nic.per_msg_overhead
        );
    }
}
