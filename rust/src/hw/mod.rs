//! Physical substrate model: machines (blades), NICs, racks.
//!
//! The paper's testbed is three Dell PowerEdge M620 blades (Table I):
//! 2× Xeon E5-2630 @ 2.30 GHz (6 cores each), 64 GB RAM, SAS 146 GB,
//! 10GbE interconnect. `MachineSpec::dell_m620()` encodes exactly that.

pub mod machine;
pub mod nic;
pub mod rack;

pub use machine::{Machine, MachineError, MachineSpec, PowerState};
pub use nic::NicSpec;
pub use rack::{Plant, Rack};
