//! Machine (blade) model: spec, power state machine, core/memory ledger.

use super::nic::NicSpec;
use crate::sim::SimTime;
use crate::util::ids::MachineId;
use thiserror::Error;

/// Hardware spec of a physical machine (Table I of the paper).
#[derive(Debug, Clone)]
pub struct MachineSpec {
    pub model: String,
    pub sockets: u32,
    pub cores_per_socket: u32,
    pub clock_ghz: f64,
    pub memory_bytes: u64,
    pub disk_bytes: u64,
    pub disk_read_bps: u64,
    pub nic: NicSpec,
    /// Power-on → OS-up time.
    pub boot_time: SimTime,
}

impl MachineSpec {
    /// Dell PowerEdge M620: 2× Intel Xeon E5-2630 2.30 GHz (6C),
    /// 64 GB RAM, SAS 146 GB 10 krpm, 10GbE — the paper's Table I row.
    pub fn dell_m620() -> Self {
        Self {
            model: "Dell M620".to_string(),
            sockets: 2,
            cores_per_socket: 6,
            clock_ghz: 2.30,
            memory_bytes: 64 << 30,
            disk_bytes: 146 << 30,
            disk_read_bps: 150 << 20, // 10k rpm SAS streaming read
            nic: NicSpec::ten_gbe(),
            boot_time: SimTime::from_secs(90),
        }
    }

    pub fn total_cores(&self) -> u32 {
        self.sockets * self.cores_per_socket
    }
}

/// Power state machine: Off → Booting → On → Off.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PowerState {
    Off,
    Booting,
    On,
}

#[derive(Debug, Error, PartialEq)]
pub enum MachineError {
    #[error("machine {0} is not powered on")]
    NotOn(MachineId),
    #[error("machine {id}: insufficient cores (want {want}, free {free})")]
    NoCores { id: MachineId, want: u32, free: u32 },
    #[error("machine {id}: insufficient memory (want {want}, free {free})")]
    NoMemory { id: MachineId, want: u64, free: u64 },
    #[error("machine {0}: invalid power transition")]
    BadTransition(MachineId),
}

/// A physical machine with a resource ledger for containers.
#[derive(Debug, Clone)]
pub struct Machine {
    pub id: MachineId,
    pub hostname: String,
    pub spec: MachineSpec,
    pub power: PowerState,
    cores_used: u32,
    memory_used: u64,
}

impl Machine {
    pub fn new(id: MachineId, hostname: impl Into<String>, spec: MachineSpec) -> Self {
        Self {
            id,
            hostname: hostname.into(),
            spec,
            power: PowerState::Off,
            cores_used: 0,
            memory_used: 0,
        }
    }

    pub fn cores_free(&self) -> u32 {
        self.spec.total_cores() - self.cores_used
    }
    pub fn memory_free(&self) -> u64 {
        self.spec.memory_bytes - self.memory_used
    }
    pub fn cores_used(&self) -> u32 {
        self.cores_used
    }

    /// Begin booting. Returns the boot duration to schedule.
    pub fn power_on(&mut self) -> Result<SimTime, MachineError> {
        match self.power {
            PowerState::Off => {
                self.power = PowerState::Booting;
                Ok(self.spec.boot_time)
            }
            _ => Err(MachineError::BadTransition(self.id)),
        }
    }

    /// Boot finished (scheduled by the provisioner after `boot_time`).
    pub fn boot_complete(&mut self) -> Result<(), MachineError> {
        match self.power {
            PowerState::Booting => {
                self.power = PowerState::On;
                Ok(())
            }
            _ => Err(MachineError::BadTransition(self.id)),
        }
    }

    /// Hard power off; releases every allocation.
    pub fn power_off(&mut self) {
        self.power = PowerState::Off;
        self.cores_used = 0;
        self.memory_used = 0;
    }

    /// Reserve cores+memory for a container.
    pub fn allocate(&mut self, cores: u32, memory: u64) -> Result<(), MachineError> {
        if self.power != PowerState::On {
            return Err(MachineError::NotOn(self.id));
        }
        if self.cores_free() < cores {
            return Err(MachineError::NoCores {
                id: self.id,
                want: cores,
                free: self.cores_free(),
            });
        }
        if self.memory_free() < memory {
            return Err(MachineError::NoMemory {
                id: self.id,
                want: memory,
                free: self.memory_free(),
            });
        }
        self.cores_used += cores;
        self.memory_used += memory;
        Ok(())
    }

    /// Release a previous allocation.
    pub fn release(&mut self, cores: u32, memory: u64) {
        self.cores_used = self.cores_used.saturating_sub(cores);
        self.memory_used = self.memory_used.saturating_sub(memory);
    }

    /// Time to read `bytes` from local disk (image layer extraction).
    pub fn disk_read_time(&self, bytes: u64) -> SimTime {
        SimTime::from_nanos(
            (bytes as u128 * 1_000_000_000 / self.spec.disk_read_bps as u128) as u64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> Machine {
        Machine::new(MachineId::new(0), "blade01", MachineSpec::dell_m620())
    }

    #[test]
    fn table1_spec_values() {
        let s = MachineSpec::dell_m620();
        assert_eq!(s.model, "Dell M620");
        assert_eq!(s.total_cores(), 12);
        assert_eq!(s.clock_ghz, 2.30);
        assert_eq!(s.memory_bytes, 64 << 30);
        assert_eq!(s.disk_bytes, 146 << 30);
        assert_eq!(s.nic.name, "10GbE");
    }

    #[test]
    fn power_state_machine() {
        let mut m = m();
        assert_eq!(m.power, PowerState::Off);
        let boot = m.power_on().unwrap();
        assert_eq!(boot, SimTime::from_secs(90));
        assert_eq!(m.power, PowerState::Booting);
        assert_eq!(m.power_on(), Err(MachineError::BadTransition(m.id)));
        m.boot_complete().unwrap();
        assert_eq!(m.power, PowerState::On);
        assert!(m.boot_complete().is_err());
        m.power_off();
        assert_eq!(m.power, PowerState::Off);
    }

    #[test]
    fn allocation_requires_power() {
        let mut m = m();
        assert!(matches!(m.allocate(1, 1 << 30), Err(MachineError::NotOn(_))));
    }

    #[test]
    fn allocation_ledger() {
        let mut m = m();
        m.power_on().unwrap();
        m.boot_complete().unwrap();
        m.allocate(8, 32 << 30).unwrap();
        assert_eq!(m.cores_free(), 4);
        assert_eq!(m.memory_free(), 32 << 30);
        assert!(matches!(
            m.allocate(5, 1 << 30),
            Err(MachineError::NoCores { .. })
        ));
        assert!(matches!(
            m.allocate(1, 33 << 30),
            Err(MachineError::NoMemory { .. })
        ));
        m.release(8, 32 << 30);
        assert_eq!(m.cores_free(), 12);
    }

    #[test]
    fn power_off_releases_everything() {
        let mut m = m();
        m.power_on().unwrap();
        m.boot_complete().unwrap();
        m.allocate(12, 64 << 30).unwrap();
        m.power_off();
        assert_eq!(m.cores_used(), 0);
        assert_eq!(m.memory_free(), 64 << 30);
    }

    #[test]
    fn disk_read_time_scales() {
        let m = m();
        let t1 = m.disk_read_time(150 << 20);
        assert!((t1.as_secs_f64() - 1.0).abs() < 1e-9);
    }
}
