//! Rack topology: machines behind a top-of-rack switch.
//!
//! The paper's three blades share one chassis/ToR, so the default
//! topology is a single rack; multi-rack adds an inter-rack hop used by
//! the interconnect-study benches.

use super::machine::{Machine, MachineSpec};
use super::nic::NicSpec;
use crate::sim::SimTime;
use crate::util::ids::MachineId;

/// A rack: a ToR switch plus member machines.
#[derive(Debug, Clone)]
pub struct Rack {
    pub name: String,
    pub members: Vec<MachineId>,
    /// Per-hop switch forwarding delay.
    pub switch_delay: SimTime,
}

impl Rack {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            members: Vec::new(),
            switch_delay: SimTime::from_micros(1),
        }
    }
}

/// The whole physical plant: machines + racks.
#[derive(Debug, Clone, Default)]
pub struct Plant {
    pub machines: Vec<Machine>,
    pub racks: Vec<Rack>,
    /// Extra delay for crossing racks (spine hop).
    pub inter_rack_delay: SimTime,
}

impl Plant {
    pub fn new() -> Self {
        Self {
            machines: Vec::new(),
            racks: Vec::new(),
            inter_rack_delay: SimTime::from_micros(5),
        }
    }

    /// The paper's testbed: blade01..blade03, one rack, M620 spec.
    pub fn paper_testbed() -> Self {
        Self::uniform(3, MachineSpec::dell_m620(), 3)
    }

    /// `n` identical machines packed `per_rack` to a rack.
    pub fn uniform(n: usize, spec: MachineSpec, per_rack: usize) -> Self {
        let mut plant = Self::new();
        for i in 0..n {
            let id = MachineId::new(i as u32);
            let hostname = format!("blade{:02}", i + 1);
            plant.machines.push(Machine::new(id, hostname, spec.clone()));
            let rack_idx = i / per_rack;
            if plant.racks.len() <= rack_idx {
                plant.racks.push(Rack::new(format!("rack{rack_idx}")));
            }
            plant.racks[rack_idx].members.push(id);
        }
        plant
    }

    pub fn machine(&self, id: MachineId) -> &Machine {
        &self.machines[id.raw() as usize]
    }
    pub fn machine_mut(&mut self, id: MachineId) -> &mut Machine {
        &mut self.machines[id.raw() as usize]
    }

    pub fn rack_of(&self, id: MachineId) -> Option<usize> {
        self.racks.iter().position(|r| r.members.contains(&id))
    }

    /// Are two machines on the same rack?
    pub fn same_rack(&self, a: MachineId, b: MachineId) -> bool {
        match (self.rack_of(a), self.rack_of(b)) {
            (Some(x), Some(y)) => x == y,
            _ => false,
        }
    }

    /// Switch-path delay between two machines (0 if same machine).
    pub fn path_delay(&self, a: MachineId, b: MachineId) -> SimTime {
        if a == b {
            return SimTime::ZERO;
        }
        let tor = self
            .rack_of(a)
            .map(|r| self.racks[r].switch_delay)
            .unwrap_or(SimTime::from_micros(1));
        if self.same_rack(a, b) {
            tor
        } else {
            tor + self.inter_rack_delay + tor
        }
    }

    /// NIC of the slower endpoint (bottleneck link).
    pub fn link_nic(&self, a: MachineId, b: MachineId) -> NicSpec {
        let na = self.machine(a).spec.nic;
        let nb = self.machine(b).spec.nic;
        if na.rate_bps <= nb.rate_bps {
            na
        } else {
            nb
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_has_three_blades_one_rack() {
        let p = Plant::paper_testbed();
        assert_eq!(p.machines.len(), 3);
        assert_eq!(p.racks.len(), 1);
        assert_eq!(p.machines[0].hostname, "blade01");
        assert_eq!(p.machines[2].hostname, "blade03");
        assert!(p.same_rack(MachineId::new(0), MachineId::new(2)));
    }

    #[test]
    fn multi_rack_path_delay() {
        let p = Plant::uniform(6, MachineSpec::dell_m620(), 3);
        assert_eq!(p.racks.len(), 2);
        let same = p.path_delay(MachineId::new(0), MachineId::new(1));
        let cross = p.path_delay(MachineId::new(0), MachineId::new(5));
        assert!(cross > same);
        assert_eq!(
            p.path_delay(MachineId::new(2), MachineId::new(2)),
            SimTime::ZERO
        );
    }

    #[test]
    fn bottleneck_nic_is_slower_endpoint() {
        let mut p = Plant::uniform(2, MachineSpec::dell_m620(), 2);
        p.machines[1].spec.nic = NicSpec::one_gbe();
        let nic = p.link_nic(MachineId::new(0), MachineId::new(1));
        assert_eq!(nic.name, "1GbE");
    }
}
