//! NAT/port-forwarding table for `docker0`-mode cross-host traffic.
//!
//! With the stock bridge, a container is only reachable across hosts via
//! `hostIP:hostPort -> containerIP:containerPort` DNAT entries — which is
//! precisely why the paper builds `bridge0`. We model the table plus the
//! per-packet translation cost that shows up in Fig. 3-style benches.

use super::addr::Ipv4;
use crate::sim::SimTime;
use std::collections::HashMap;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum NatError {
    #[error("host port {0} already forwarded")]
    PortInUse(u16),
    #[error("no DNAT entry for host port {0}")]
    NoEntry(u16),
}

/// One DNAT rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Forward {
    pub host_port: u16,
    pub dst_ip: Ipv4,
    pub dst_port: u16,
}

/// Per-host NAT table.
#[derive(Debug, Clone, Default)]
pub struct NatTable {
    rules: HashMap<u16, Forward>,
    /// Translations performed (for the benches' per-packet accounting).
    pub translations: u64,
}

impl NatTable {
    /// Cost of one NAT traversal (conntrack lookup + header rewrite).
    pub const TRANSLATE_COST: SimTime = SimTime(1_500); // 1.5 us

    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_forward(&mut self, host_port: u16, dst_ip: Ipv4, dst_port: u16) -> Result<(), NatError> {
        if self.rules.contains_key(&host_port) {
            return Err(NatError::PortInUse(host_port));
        }
        self.rules.insert(host_port, Forward { host_port, dst_ip, dst_port });
        Ok(())
    }

    pub fn remove_forward(&mut self, host_port: u16) -> Result<Forward, NatError> {
        self.rules.remove(&host_port).ok_or(NatError::NoEntry(host_port))
    }

    /// Translate an inbound packet; counts the traversal and returns the
    /// destination.
    pub fn translate(&mut self, host_port: u16) -> Result<Forward, NatError> {
        let f = *self.rules.get(&host_port).ok_or(NatError::NoEntry(host_port))?;
        self.translations += 1;
        Ok(f)
    }

    pub fn len(&self) -> usize {
        self.rules.len()
    }
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_lifecycle() {
        let mut t = NatTable::new();
        let ip = Ipv4::new(172, 17, 0, 2);
        t.add_forward(2222, ip, 22).unwrap();
        assert_eq!(t.add_forward(2222, ip, 22), Err(NatError::PortInUse(2222)));
        let f = t.translate(2222).unwrap();
        assert_eq!(f.dst_ip, ip);
        assert_eq!(f.dst_port, 22);
        assert_eq!(t.translations, 1);
        t.remove_forward(2222).unwrap();
        assert_eq!(t.translate(2222), Err(NatError::NoEntry(2222)));
    }

    #[test]
    fn translation_counter_accumulates() {
        let mut t = NatTable::new();
        t.add_forward(1, Ipv4::new(10, 0, 0, 2), 80).unwrap();
        for _ in 0..10 {
            t.translate(1).unwrap();
        }
        assert_eq!(t.translations, 10);
    }
}
