//! IP address management: lease/release host addresses out of a subnet.
//!
//! Containers get "floating IPs assigned dynamically" (§III-C) — this is
//! the allocator behind that, one instance per bridge subnet.

use super::addr::{Cidr, Ipv4};
use std::collections::BTreeSet;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum IpamError {
    #[error("subnet {0} exhausted")]
    Exhausted(Cidr),
    #[error("{0} is not leased")]
    NotLeased(Ipv4),
    #[error("{0} is outside subnet {1}")]
    OutOfSubnet(Ipv4, Cidr),
}

/// Allocator over one CIDR block. The first `reserved` host addresses
/// (gateway etc.) are never handed out.
#[derive(Debug, Clone)]
pub struct Ipam {
    pub subnet: Cidr,
    reserved: u32,
    leased: BTreeSet<u32>, // offsets within the subnet
    next_hint: u32,
}

impl Ipam {
    /// `reserved` = number of low host addresses to hold back (≥1 keeps
    /// the conventional .1 gateway).
    pub fn new(subnet: Cidr, reserved: u32) -> Self {
        Self { subnet, reserved, leased: BTreeSet::new(), next_hint: 0 }
    }

    pub fn leased_count(&self) -> usize {
        self.leased.len()
    }

    fn capacity(&self) -> u32 {
        self.subnet.host_count() as u32
    }

    /// Lease the next free address (first-fit from a rotating hint, the
    /// same observable behaviour as dockerd's allocator).
    pub fn lease(&mut self) -> Result<Ipv4, IpamError> {
        let cap = self.capacity();
        let usable = cap.saturating_sub(self.reserved);
        if self.leased.len() as u32 >= usable {
            return Err(IpamError::Exhausted(self.subnet));
        }
        for k in 0..usable {
            let off = self.reserved + 1 + ((self.next_hint + k) % usable);
            if !self.leased.contains(&off) {
                self.leased.insert(off);
                self.next_hint = (self.next_hint + k + 1) % usable;
                return Ok(self.subnet.host(off));
            }
        }
        Err(IpamError::Exhausted(self.subnet))
    }

    /// Release a leased address.
    pub fn release(&mut self, ip: Ipv4) -> Result<(), IpamError> {
        if !self.subnet.contains(ip) {
            return Err(IpamError::OutOfSubnet(ip, self.subnet));
        }
        let off = ip.0 - self.subnet.base.0;
        if self.leased.remove(&off) {
            Ok(())
        } else {
            Err(IpamError::NotLeased(ip))
        }
    }

    pub fn is_leased(&self, ip: Ipv4) -> bool {
        self.subnet.contains(ip) && self.leased.contains(&(ip.0 - self.subnet.base.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ipam() -> Ipam {
        Ipam::new(Cidr::parse("172.17.0.0/24").unwrap(), 1)
    }

    #[test]
    fn leases_are_unique_and_in_subnet() {
        let mut a = ipam();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            let ip = a.lease().unwrap();
            assert!(a.subnet.contains(ip));
            assert!(seen.insert(ip), "duplicate lease {ip}");
        }
        assert_eq!(a.leased_count(), 100);
    }

    #[test]
    fn gateway_is_reserved() {
        let mut a = ipam();
        for _ in 0..50 {
            let ip = a.lease().unwrap();
            assert_ne!(ip.octets()[3], 1, "handed out the gateway");
            assert_ne!(ip.octets()[3], 0, "handed out the network addr");
        }
    }

    #[test]
    fn exhaustion_and_release_reuse() {
        let mut a = Ipam::new(Cidr::parse("10.0.0.0/29").unwrap(), 1);
        // /29 => 6 hosts, 1 reserved => 5 usable
        let ips: Vec<_> = (0..5).map(|_| a.lease().unwrap()).collect();
        assert_eq!(a.lease(), Err(IpamError::Exhausted(a.subnet)));
        a.release(ips[2]).unwrap();
        let again = a.lease().unwrap();
        assert_eq!(again, ips[2]);
    }

    #[test]
    fn release_errors() {
        let mut a = ipam();
        let outside = Ipv4::parse("192.168.1.1").unwrap();
        assert!(matches!(a.release(outside), Err(IpamError::OutOfSubnet(..))));
        let inside = Ipv4::parse("172.17.0.9").unwrap();
        assert_eq!(a.release(inside), Err(IpamError::NotLeased(inside)));
    }

    #[test]
    fn addresses_not_immediately_recycled() {
        // dockerd-style rotating hint: a released IP is not the very next
        // lease unless the pool wrapped around.
        let mut a = ipam();
        let first = a.lease().unwrap();
        a.release(first).unwrap();
        let next = a.lease().unwrap();
        assert_ne!(first, next);
    }
}
