//! Software bridges: stock `docker0` (NAT'd, host-local subnet) vs the
//! paper's `bridge0` (bound to the physical interface, cluster subnet).

use super::addr::{Cidr, Ipv4, Mac};
use super::ipam::{Ipam, IpamError};
use crate::util::ids::{ContainerId, IfaceId};
use std::collections::HashMap;

/// How a bridge attaches containers to the world (§III-B, Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BridgeMode {
    /// Default Docker bridge: per-host 172.17/16, NAT for egress,
    /// port-forwarding for ingress. Cross-host container traffic pays
    /// two NAT traversals and cannot address containers directly.
    Docker0,
    /// Customized bridge bound to a physical ethernet interface;
    /// containers join the host subnet and are directly addressable —
    /// the paper's design.
    Bridge0,
    /// Containers share the host network namespace (upper bound).
    Host,
}

impl BridgeMode {
    pub fn name(&self) -> &'static str {
        match self {
            BridgeMode::Docker0 => "docker0",
            BridgeMode::Bridge0 => "bridge0",
            BridgeMode::Host => "host",
        }
    }

    /// Default subnet convention for the mode on host index `h`.
    pub fn default_subnet(&self, h: u32) -> Cidr {
        match self {
            // every host reuses the same private range — that's the bug
            // the paper works around
            BridgeMode::Docker0 => Cidr::parse("172.17.0.0/16").unwrap(),
            // one flat, directly routable cluster network (10.10/16),
            // sharded as a disjoint /24 slice per host so the per-host
            // allocators never collide — the deployment discipline the
            // paper's custom bridge requires
            BridgeMode::Bridge0 => Cidr::new(Ipv4::new(10, 10, h as u8, 0), 24),
            BridgeMode::Host => Cidr::new(Ipv4::new(192, 168, h as u8, 0), 24),
        }
    }

    /// Does cross-host traffic require NAT?
    pub fn needs_nat(&self) -> bool {
        matches!(self, BridgeMode::Docker0)
    }

    /// Are container IPs routable from other hosts?
    pub fn directly_routable(&self) -> bool {
        !self.needs_nat()
    }
}

/// A veth endpoint attached to a bridge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Port {
    pub iface: IfaceId,
    pub mac: Mac,
    pub ip: Ipv4,
    pub owner: ContainerId,
}

/// A software bridge instance on one machine.
#[derive(Debug, Clone)]
pub struct Bridge {
    pub name: String,
    pub mode: BridgeMode,
    pub ipam: Ipam,
    ports: HashMap<ContainerId, Port>,
    next_iface: u32,
    /// Per-frame forwarding cost in nanoseconds (learned-table lookup).
    pub forward_cost_ns: u64,
}

impl Bridge {
    pub fn new(name: impl Into<String>, mode: BridgeMode, subnet: Cidr) -> Self {
        Self {
            name: name.into(),
            mode,
            ipam: Ipam::new(subnet, 1),
            ports: HashMap::new(),
            next_iface: 0,
            forward_cost_ns: 400,
        }
    }

    /// Attach a container: lease an IP, mint a veth + MAC.
    pub fn attach(&mut self, owner: ContainerId) -> Result<Port, IpamError> {
        let ip = self.ipam.lease()?;
        let iface = IfaceId::new(self.next_iface);
        let mac = Mac::from_index(self.next_iface);
        self.next_iface += 1;
        let port = Port { iface, mac, ip, owner };
        self.ports.insert(owner, port);
        Ok(port)
    }

    /// Detach and release the lease.
    pub fn detach(&mut self, owner: ContainerId) -> Option<Port> {
        let port = self.ports.remove(&owner)?;
        let _ = self.ipam.release(port.ip);
        Some(port)
    }

    pub fn port_of(&self, owner: ContainerId) -> Option<&Port> {
        self.ports.get(&owner)
    }

    pub fn ports(&self) -> impl Iterator<Item = &Port> {
        self.ports.values()
    }

    pub fn len(&self) -> usize {
        self.ports.len()
    }
    pub fn is_empty(&self) -> bool {
        self.ports.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_properties_match_the_paper() {
        assert!(BridgeMode::Docker0.needs_nat());
        assert!(!BridgeMode::Bridge0.needs_nat());
        assert!(BridgeMode::Bridge0.directly_routable());
        assert!(BridgeMode::Host.directly_routable());
        assert_eq!(BridgeMode::Bridge0.name(), "bridge0");
    }

    #[test]
    fn docker0_subnet_is_same_on_every_host() {
        // The collision that breaks cross-host addressing.
        assert_eq!(
            BridgeMode::Docker0.default_subnet(0),
            BridgeMode::Docker0.default_subnet(5)
        );
    }

    #[test]
    fn bridge0_subnets_are_disjoint_per_host() {
        // bridge0 shards 10.10/16 into per-host /24s: leases can never
        // collide across machines (unlike docker0).
        let s0 = BridgeMode::Bridge0.default_subnet(0);
        let s1 = BridgeMode::Bridge0.default_subnet(1);
        assert_ne!(s0, s1);
        let mut b0 = Bridge::new("bridge0", BridgeMode::Bridge0, s0);
        let mut b1 = Bridge::new("bridge0", BridgeMode::Bridge0, s1);
        let p0 = b0.attach(ContainerId::new(0)).unwrap();
        let p1 = b1.attach(ContainerId::new(1)).unwrap();
        assert_ne!(p0.ip, p1.ip);
        // both remain inside the flat routable 10.10/16
        let flat = Cidr::parse("10.10.0.0/16").unwrap();
        assert!(flat.contains(p0.ip));
        assert!(flat.contains(p1.ip));
    }

    #[test]
    fn attach_assigns_unique_ips_and_ifaces() {
        let mut b = Bridge::new(
            "bridge0",
            BridgeMode::Bridge0,
            Cidr::parse("10.10.0.0/24").unwrap(),
        );
        let p1 = b.attach(ContainerId::new(1)).unwrap();
        let p2 = b.attach(ContainerId::new(2)).unwrap();
        assert_ne!(p1.ip, p2.ip);
        assert_ne!(p1.iface, p2.iface);
        assert_ne!(p1.mac, p2.mac);
        assert_eq!(b.len(), 2);
        assert_eq!(b.port_of(ContainerId::new(1)).unwrap().ip, p1.ip);
    }

    #[test]
    fn detach_releases_the_lease() {
        let mut b = Bridge::new(
            "docker0",
            BridgeMode::Docker0,
            Cidr::parse("172.17.0.0/29").unwrap(),
        );
        let p = b.attach(ContainerId::new(9)).unwrap();
        assert!(b.ipam.is_leased(p.ip));
        b.detach(ContainerId::new(9)).unwrap();
        assert!(!b.ipam.is_leased(p.ip));
        assert!(b.is_empty());
    }
}
