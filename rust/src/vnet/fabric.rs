//! Fabric: the end-to-end communication cost model.
//!
//! Turns (src container, dst container, bytes) into virtual time, using
//! the machines' NICs, the rack path, the bridge mode (NAT or direct) and
//! the software-bridge forwarding cost. MPI and the consul gossip layer
//! both charge their traffic through this model, so the Fig. 3 / Ext-A
//! benches measure one consistent network.

use super::bridge::BridgeMode;
use super::nat::NatTable;
use crate::hw::rack::Plant;
use crate::hw::NicSpec;
use crate::sim::SimTime;
use crate::util::ids::{ContainerId, MachineId};
use std::collections::HashMap;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum FabricError {
    #[error("container {0} has no placement")]
    Unplaced(ContainerId),
}

/// What kind of path a message took (for accounting/debug).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PathKind {
    /// Same container (rank-to-self): memcpy.
    Local,
    /// Different containers, same machine: one bridge hop.
    IntraHost,
    /// Cross machine, directly routable (bridge0/host).
    CrossHost,
    /// Cross machine through NAT (docker0): two translations + proxy hop.
    CrossHostNat,
}

/// Cached affine one-way cost: `base_ns + bytes * num / den` ns.
#[derive(Debug, Clone, Copy)]
pub struct CostParams {
    pub kind: PathKind,
    pub base_ns: u64,
    pub num: u64,
    pub den: u64,
}

impl CostParams {
    #[inline]
    pub fn time(&self, bytes: u64) -> crate::sim::SimTime {
        crate::sim::SimTime::from_nanos(
            self.base_ns + (bytes as u128 * self.num as u128 / self.den as u128) as u64,
        )
    }
}

/// Lightweight topology snapshot + placement map.
#[derive(Debug, Clone)]
pub struct Fabric {
    pub mode: BridgeMode,
    nics: Vec<NicSpec>,
    path_delay: Vec<Vec<SimTime>>, // machine x machine switch delay
    placement: HashMap<ContainerId, MachineId>,
    /// Per-machine NAT tables (docker0 mode).
    pub nat: Vec<NatTable>,
    /// Software bridge per-frame forwarding cost.
    pub bridge_cost: SimTime,
    /// In-memory copy rate for rank-local transfers (bytes/sec).
    pub memcpy_bps: u64,
    /// Total bytes charged, by path kind.
    pub bytes_by_path: HashMap<PathKind, u64>,
    /// Total messages charged, by path kind.
    pub msgs_by_path: HashMap<PathKind, u64>,
}

impl Fabric {
    pub fn from_plant(plant: &Plant, mode: BridgeMode) -> Self {
        let n = plant.machines.len();
        let nics: Vec<NicSpec> = plant.machines.iter().map(|m| m.spec.nic).collect();
        let mut path_delay = vec![vec![SimTime::ZERO; n]; n];
        for a in 0..n {
            for b in 0..n {
                path_delay[a][b] =
                    plant.path_delay(MachineId::new(a as u32), MachineId::new(b as u32));
            }
        }
        Self {
            mode,
            nics,
            path_delay,
            placement: HashMap::new(),
            nat: vec![NatTable::new(); n],
            bridge_cost: SimTime::from_nanos(400),
            memcpy_bps: 8 << 30, // ~8 GiB/s single-stream copy
            bytes_by_path: HashMap::new(),
            msgs_by_path: HashMap::new(),
        }
    }

    /// Record that a container runs on a machine.
    pub fn place(&mut self, c: ContainerId, m: MachineId) {
        self.placement.insert(c, m);
    }

    pub fn unplace(&mut self, c: ContainerId) {
        self.placement.remove(&c);
    }

    pub fn machine_of(&self, c: ContainerId) -> Option<MachineId> {
        self.placement.get(&c).copied()
    }

    fn bottleneck_nic(&self, a: MachineId, b: MachineId) -> NicSpec {
        let na = self.nics[a.raw() as usize];
        let nb = self.nics[b.raw() as usize];
        if na.rate_bps <= nb.rate_bps {
            na
        } else {
            nb
        }
    }

    /// Classify the path between two containers.
    pub fn classify(
        &self,
        src: ContainerId,
        dst: ContainerId,
    ) -> Result<PathKind, FabricError> {
        if src == dst {
            return Ok(PathKind::Local);
        }
        let ms = self.machine_of(src).ok_or(FabricError::Unplaced(src))?;
        let md = self.machine_of(dst).ok_or(FabricError::Unplaced(dst))?;
        Ok(if ms == md {
            PathKind::IntraHost
        } else if self.mode.needs_nat() {
            PathKind::CrossHostNat
        } else {
            PathKind::CrossHost
        })
    }

    /// One-way transfer time for a `bytes`-sized message between two
    /// containers, charging the traffic counters.
    pub fn transfer_time(
        &mut self,
        src: ContainerId,
        dst: ContainerId,
        bytes: u64,
    ) -> Result<(SimTime, PathKind), FabricError> {
        let kind = self.classify(src, dst)?;
        let t = match kind {
            PathKind::Local => {
                SimTime::from_nanos((bytes as u128 * 1_000_000_000 / self.memcpy_bps as u128) as u64)
            }
            PathKind::IntraHost => {
                // veth -> bridge -> veth: two frame hops through the
                // software bridge, memory-speed copy.
                let copy = (bytes as u128 * 1_000_000_000 / self.memcpy_bps as u128) as u64;
                self.bridge_cost + self.bridge_cost + SimTime::from_nanos(copy)
            }
            PathKind::CrossHost => {
                let ms = self.machine_of(src).unwrap();
                let md = self.machine_of(dst).unwrap();
                let nic = self.bottleneck_nic(ms, md);
                self.bridge_cost
                    + nic.message_time(bytes)
                    + self.path_delay[ms.raw() as usize][md.raw() as usize]
                    + self.bridge_cost
            }
            PathKind::CrossHostNat => {
                let ms = self.machine_of(src).unwrap();
                let md = self.machine_of(dst).unwrap();
                let nic = self.bottleneck_nic(ms, md);
                // SNAT on egress + DNAT on ingress, plus userland proxy
                // copy on the destination host (docker-proxy).
                self.nat[ms.raw() as usize].translations += 1;
                self.nat[md.raw() as usize].translations += 1;
                let proxy_copy =
                    (bytes as u128 * 1_000_000_000 / self.memcpy_bps as u128) as u64;
                self.bridge_cost
                    + NatTable::TRANSLATE_COST
                    + nic.message_time(bytes)
                    + self.path_delay[ms.raw() as usize][md.raw() as usize]
                    + NatTable::TRANSLATE_COST
                    + SimTime::from_nanos(proxy_copy)
                    + self.bridge_cost
            }
        };
        *self.bytes_by_path.entry(kind).or_insert(0) += bytes;
        *self.msgs_by_path.entry(kind).or_insert(0) += 1;
        Ok((t, kind))
    }

    /// Affine cost model for a fixed (src, dst) pair: one-way time for a
    /// `b`-byte message is `base_ns + b * num / den` nanoseconds. MPI
    /// ranks cache this per destination so the steady-state send path
    /// never touches the fabric lock (§Perf).
    pub fn cost_params(
        &self,
        src: ContainerId,
        dst: ContainerId,
    ) -> Result<CostParams, FabricError> {
        let kind = self.classify(src, dst)?;
        let memcpy_num = 1_000_000_000u128;
        let memcpy_den = self.memcpy_bps as u128;
        Ok(match kind {
            PathKind::Local => CostParams { kind, base_ns: 0, num: memcpy_num as u64, den: memcpy_den as u64 },
            PathKind::IntraHost => CostParams {
                kind,
                base_ns: 2 * self.bridge_cost.as_nanos(),
                num: memcpy_num as u64,
                den: memcpy_den as u64,
            },
            PathKind::CrossHost | PathKind::CrossHostNat => {
                let ms = self.machine_of(src).unwrap();
                let md = self.machine_of(dst).unwrap();
                let nic = self.bottleneck_nic(ms, md);
                let mut base = (self.bridge_cost
                    + self.bridge_cost
                    + nic.message_time(0)
                    + self.path_delay[ms.raw() as usize][md.raw() as usize])
                .as_nanos();
                // serialization: bytes * 8e9 / rate ns
                let mut num = 8_000_000_000u64;
                let mut den = nic.rate_bps;
                if kind == PathKind::CrossHostNat {
                    base += 2 * NatTable::TRANSLATE_COST.as_nanos();
                    // + proxy memcpy: fold into per-byte term using a
                    // common denominator approximation
                    // t(b) = b*8e9/rate + b*1e9/memcpy
                    //      = b * (8e9*memcpy + 1e9*rate) / (rate*memcpy)
                    let n2 = 8_000_000_000u128 * self.memcpy_bps as u128
                        + 1_000_000_000u128 * nic.rate_bps as u128;
                    let d2 = nic.rate_bps as u128 * self.memcpy_bps as u128;
                    // scale down to keep u64 arithmetic exact enough
                    num = (n2 / 1_000_000) as u64;
                    den = (d2 / 1_000_000) as u64;
                }
                CostParams { kind, base_ns: base, num, den }
            }
        })
    }

    /// Machine-to-machine control-plane message time (consul gossip/raft;
    /// agents bind the host interface so NAT is not involved).
    pub fn control_msg_time(&self, a: MachineId, b: MachineId, bytes: u64) -> SimTime {
        if a == b {
            return SimTime::from_micros(5); // loopback + sched
        }
        let nic = self.bottleneck_nic(a, b);
        nic.message_time(bytes) + self.path_delay[a.raw() as usize][b.raw() as usize]
    }

    /// Effective bandwidth (bytes/sec) observed for a message size.
    pub fn effective_bandwidth(
        &mut self,
        src: ContainerId,
        dst: ContainerId,
        bytes: u64,
    ) -> Result<f64, FabricError> {
        let (t, _) = self.transfer_time(src, dst, bytes)?;
        Ok(bytes as f64 / t.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::MachineSpec;

    fn fabric(mode: BridgeMode) -> Fabric {
        let plant = Plant::paper_testbed();
        let mut f = Fabric::from_plant(&plant, mode);
        f.place(ContainerId::new(0), MachineId::new(0));
        f.place(ContainerId::new(1), MachineId::new(1));
        f.place(ContainerId::new(2), MachineId::new(0));
        f
    }

    #[test]
    fn classification() {
        let f = fabric(BridgeMode::Bridge0);
        let c0 = ContainerId::new(0);
        assert_eq!(f.classify(c0, c0).unwrap(), PathKind::Local);
        assert_eq!(
            f.classify(c0, ContainerId::new(2)).unwrap(),
            PathKind::IntraHost
        );
        assert_eq!(
            f.classify(c0, ContainerId::new(1)).unwrap(),
            PathKind::CrossHost
        );
        let f = fabric(BridgeMode::Docker0);
        assert_eq!(
            f.classify(ContainerId::new(0), ContainerId::new(1)).unwrap(),
            PathKind::CrossHostNat
        );
    }

    #[test]
    fn unplaced_is_an_error() {
        let f = fabric(BridgeMode::Bridge0);
        assert!(matches!(
            f.classify(ContainerId::new(0), ContainerId::new(99)),
            Err(FabricError::Unplaced(_))
        ));
    }

    #[test]
    fn nat_is_slower_than_bridge0_cross_host() {
        // The quantitative heart of Fig. 3.
        let mut nat = fabric(BridgeMode::Docker0);
        let mut direct = fabric(BridgeMode::Bridge0);
        for bytes in [64u64, 4096, 1 << 20, 16 << 20] {
            let (tn, _) = nat
                .transfer_time(ContainerId::new(0), ContainerId::new(1), bytes)
                .unwrap();
            let (td, _) = direct
                .transfer_time(ContainerId::new(0), ContainerId::new(1), bytes)
                .unwrap();
            assert!(tn > td, "bytes={bytes}: nat={tn} direct={td}");
        }
    }

    #[test]
    fn nat_gap_grows_with_message_size() {
        let mut nat = fabric(BridgeMode::Docker0);
        let mut direct = fabric(BridgeMode::Bridge0);
        let gap = |nat: &mut Fabric, direct: &mut Fabric, b: u64| {
            let (tn, _) = nat
                .transfer_time(ContainerId::new(0), ContainerId::new(1), b)
                .unwrap();
            let (td, _) = direct
                .transfer_time(ContainerId::new(0), ContainerId::new(1), b)
                .unwrap();
            tn.as_nanos() - td.as_nanos()
        };
        let small = gap(&mut nat, &mut direct, 64);
        let big = gap(&mut nat, &mut direct, 16 << 20);
        assert!(big > small);
    }

    #[test]
    fn intra_host_beats_cross_host() {
        let mut f = fabric(BridgeMode::Bridge0);
        let (intra, _) = f
            .transfer_time(ContainerId::new(0), ContainerId::new(2), 1 << 20)
            .unwrap();
        let (cross, _) = f
            .transfer_time(ContainerId::new(0), ContainerId::new(1), 1 << 20)
            .unwrap();
        assert!(intra < cross);
    }

    #[test]
    fn nat_translation_counters_tick() {
        let mut f = fabric(BridgeMode::Docker0);
        f.transfer_time(ContainerId::new(0), ContainerId::new(1), 100)
            .unwrap();
        assert_eq!(f.nat[0].translations, 1);
        assert_eq!(f.nat[1].translations, 1);
    }

    #[test]
    fn traffic_accounting() {
        let mut f = fabric(BridgeMode::Bridge0);
        f.transfer_time(ContainerId::new(0), ContainerId::new(1), 1000)
            .unwrap();
        f.transfer_time(ContainerId::new(0), ContainerId::new(1), 500)
            .unwrap();
        assert_eq!(f.bytes_by_path[&PathKind::CrossHost], 1500);
        assert_eq!(f.msgs_by_path[&PathKind::CrossHost], 2);
    }

    #[test]
    fn effective_bandwidth_approaches_line_rate() {
        // Large messages on 10GbE should see > 0.8 of line rate in
        // bridge0 mode, far less through NAT (the proxy copy).
        let mut direct = fabric(BridgeMode::Bridge0);
        let bw = direct
            .effective_bandwidth(ContainerId::new(0), ContainerId::new(1), 64 << 20)
            .unwrap();
        let line = 10_000_000_000.0 / 8.0;
        assert!(bw / line > 0.8, "bw={bw:.0}");
        let mut nat = fabric(BridgeMode::Docker0);
        let bwn = nat
            .effective_bandwidth(ContainerId::new(0), ContainerId::new(1), 64 << 20)
            .unwrap();
        assert!(bwn < bw);
    }

    #[test]
    fn cost_params_match_transfer_time_exactly() {
        // The cached affine model must reproduce the full model for
        // every path kind and size (§Perf cache correctness).
        for mode in [BridgeMode::Bridge0, BridgeMode::Docker0, BridgeMode::Host] {
            let mut f = fabric(mode);
            for (src, dst) in [(0u32, 0u32), (0, 2), (0, 1)] {
                let (s, d) = (ContainerId::new(src), ContainerId::new(dst));
                let params = f.cost_params(s, d).unwrap();
                for bytes in [0u64, 64, 4096, 1 << 20, 64 << 20] {
                    let (want, kind) = f.transfer_time(s, d, bytes).unwrap();
                    assert_eq!(params.kind, kind);
                    let got = params.time(bytes);
                    let err = (got.as_nanos() as i128 - want.as_nanos() as i128).abs();
                    assert!(
                        err <= 1 + want.as_nanos() as i128 / 1_000_000,
                        "mode={mode:?} {src}->{dst} bytes={bytes}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn slower_nic_is_the_bottleneck() {
        let mut plant = Plant::uniform(2, MachineSpec::dell_m620(), 2);
        plant.machines[1].spec.nic = crate::hw::NicSpec::one_gbe();
        let mut f = Fabric::from_plant(&plant, BridgeMode::Bridge0);
        f.place(ContainerId::new(0), MachineId::new(0));
        f.place(ContainerId::new(1), MachineId::new(1));
        let (t, _) = f
            .transfer_time(ContainerId::new(0), ContainerId::new(1), 1 << 20)
            .unwrap();
        // ~8.4 ms at 1 Gb/s, way above the 0.84 ms 10GbE serialization
        assert!(t.as_millis_f64() > 8.0);
    }
}
