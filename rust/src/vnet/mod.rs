//! Virtual network substrate.
//!
//! Models exactly the networking the paper manipulates (§III-B, Fig. 3):
//!
//! * `docker0` — the stock Docker bridge. Containers get a private
//!   172.17/16 address; cross-host traffic must be NAT-translated and
//!   port-forwarded through the host address, adding per-packet cost and
//!   preventing direct container↔container addressing.
//! * `bridge0` — the paper's customized bridge bound to a physical
//!   interface. Containers get addresses on the host subnet and talk
//!   across machines directly, no NAT.
//! * `host` — containers share the host stack (upper-bound baseline).
//!
//! `fabric::Fabric` turns a (src container, dst container, bytes) triple
//! into a virtual-time cost using the machine NICs, rack topology and
//! bridge mode; MPI charges its communication through it.

pub mod addr;
pub mod bridge;
pub mod fabric;
pub mod ipam;
pub mod nat;

pub use addr::{Cidr, Ipv4, Mac};
pub use bridge::{Bridge, BridgeMode};
pub use fabric::{Fabric, PathKind};
pub use ipam::Ipam;
pub use nat::NatTable;
