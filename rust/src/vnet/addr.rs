//! IPv4 addresses, CIDR subnets, MACs — enough for the simulator.

use std::fmt;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum AddrError {
    #[error("invalid IPv4 literal: {0}")]
    BadIp(String),
    #[error("invalid CIDR literal: {0}")]
    BadCidr(String),
}

/// An IPv4 address as a u32.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Ipv4(pub u32);

impl Ipv4 {
    pub fn new(a: u8, b: u8, c: u8, d: u8) -> Self {
        Self(u32::from_be_bytes([a, b, c, d]))
    }

    pub fn parse(s: &str) -> Result<Self, AddrError> {
        let parts: Vec<&str> = s.split('.').collect();
        if parts.len() != 4 {
            return Err(AddrError::BadIp(s.to_string()));
        }
        let mut o = [0u8; 4];
        for (i, p) in parts.iter().enumerate() {
            o[i] = p.parse().map_err(|_| AddrError::BadIp(s.to_string()))?;
        }
        Ok(Self(u32::from_be_bytes(o)))
    }

    pub fn octets(self) -> [u8; 4] {
        self.0.to_be_bytes()
    }
}

impl fmt::Display for Ipv4 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let o = self.octets();
        write!(f, "{}.{}.{}.{}", o[0], o[1], o[2], o[3])
    }
}

/// A CIDR subnet, e.g. 172.17.0.0/16.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Cidr {
    pub base: Ipv4,
    pub prefix: u8,
}

impl Cidr {
    pub fn new(base: Ipv4, prefix: u8) -> Self {
        assert!(prefix <= 32);
        // normalize the base to the network address
        let mask = Self::mask_of(prefix);
        Self { base: Ipv4(base.0 & mask), prefix }
    }

    pub fn parse(s: &str) -> Result<Self, AddrError> {
        let (ip, pre) = s.split_once('/').ok_or_else(|| AddrError::BadCidr(s.to_string()))?;
        let base = Ipv4::parse(ip)?;
        let prefix: u8 = pre.parse().map_err(|_| AddrError::BadCidr(s.to_string()))?;
        if prefix > 32 {
            return Err(AddrError::BadCidr(s.to_string()));
        }
        Ok(Self::new(base, prefix))
    }

    fn mask_of(prefix: u8) -> u32 {
        if prefix == 0 {
            0
        } else {
            u32::MAX << (32 - prefix)
        }
    }

    pub fn mask(&self) -> u32 {
        Self::mask_of(self.prefix)
    }

    pub fn contains(&self, ip: Ipv4) -> bool {
        (ip.0 & self.mask()) == self.base.0
    }

    /// Number of *usable host* addresses (network/broadcast excluded for
    /// prefixes < 31).
    pub fn host_count(&self) -> u64 {
        let total = 1u64 << (32 - self.prefix as u64);
        if self.prefix >= 31 {
            total
        } else {
            total - 2
        }
    }

    /// The i-th host address (1-based within the subnet).
    pub fn host(&self, i: u32) -> Ipv4 {
        Ipv4(self.base.0 + i)
    }
}

impl fmt::Display for Cidr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.base, self.prefix)
    }
}

/// A MAC address (simulated: derived from an interface counter).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Mac(pub u64);

impl Mac {
    /// Docker-style locally administered MAC: 02:42:xx:xx:xx:xx.
    pub fn from_index(i: u32) -> Self {
        Self(0x0242_0000_0000 | i as u64)
    }
}

impl fmt::Display for Mac {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.0.to_be_bytes();
        write!(
            f,
            "{:02x}:{:02x}:{:02x}:{:02x}:{:02x}:{:02x}",
            b[2], b[3], b[4], b[5], b[6], b[7]
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ip_parse_and_display() {
        let ip = Ipv4::parse("172.17.0.2").unwrap();
        assert_eq!(ip.to_string(), "172.17.0.2");
        assert_eq!(ip, Ipv4::new(172, 17, 0, 2));
        assert!(Ipv4::parse("1.2.3").is_err());
        assert!(Ipv4::parse("1.2.3.999").is_err());
    }

    #[test]
    fn cidr_contains() {
        let net = Cidr::parse("172.17.0.0/16").unwrap();
        assert!(net.contains(Ipv4::parse("172.17.255.1").unwrap()));
        assert!(!net.contains(Ipv4::parse("172.18.0.1").unwrap()));
        assert_eq!(net.to_string(), "172.17.0.0/16");
    }

    #[test]
    fn cidr_normalizes_base() {
        let net = Cidr::new(Ipv4::new(10, 0, 5, 77), 16);
        assert_eq!(net.base, Ipv4::new(10, 0, 0, 0));
    }

    #[test]
    fn host_count() {
        assert_eq!(Cidr::parse("10.0.0.0/24").unwrap().host_count(), 254);
        assert_eq!(Cidr::parse("10.0.0.0/30").unwrap().host_count(), 2);
        assert_eq!(Cidr::parse("10.0.0.0/31").unwrap().host_count(), 2);
    }

    #[test]
    fn mac_format() {
        assert_eq!(Mac::from_index(1).to_string(), "02:42:00:00:00:01");
    }
}
