//! Channel-backed [`SimCommunicator`] for shards running as threads of
//! one process.
//!
//! Topology: a full `n × n` matrix of mpsc channels (pair `(i, j)` is
//! the FIFO from rank `i` to rank `j`), so per-sender send order is
//! preserved by construction and no lock is shared between data paths.
//! The window barrier is the classic double barrier: ranks first rendez-
//! vous to close the send phase (after which every in-flight message is
//! in its destination channel), each rank drains its inboxes in sender-
//! rank order, and a second rendezvous keeps any rank from starting the
//! *next* window's sends before everyone has finished draining this one.
//! Without the second barrier a fast rank could race a message into a
//! channel a slow rank is still draining, smearing it across windows —
//! exactly the nondeterminism the one-window-latency contract forbids.

use super::SimCommunicator;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

/// One rank's endpoint of an `n`-rank local communicator group.
pub struct LocalCommunicator<M> {
    rank: usize,
    /// `to[j]` feeds rank `j`'s inbox from this rank.
    to: Vec<Sender<M>>,
    /// `from[i]` is this rank's inbox fed by rank `i`.
    from: Vec<Receiver<M>>,
    enter: Arc<Barrier>,
    exit: Arc<Barrier>,
}

impl<M: Send> LocalCommunicator<M> {
    /// Build a fully-connected group of `n` communicators; hand
    /// element `r` to the thread that will act as rank `r`.
    pub fn group(n: usize) -> Vec<LocalCommunicator<M>> {
        assert!(n > 0, "a communicator group needs at least one rank");
        let enter = Arc::new(Barrier::new(n));
        let exit = Arc::new(Barrier::new(n));
        // senders[i][j] / receivers[j][i]: the (i -> j) FIFO
        let mut senders: Vec<Vec<Option<Sender<M>>>> = Vec::new();
        let mut receivers: Vec<Vec<Option<Receiver<M>>>> = Vec::new();
        for _ in 0..n {
            senders.push((0..n).map(|_| None).collect());
            receivers.push((0..n).map(|_| None).collect());
        }
        for (i, row) in senders.iter_mut().enumerate() {
            for (j, slot) in row.iter_mut().enumerate() {
                let (tx, rx) = channel();
                *slot = Some(tx);
                receivers[j][i] = Some(rx);
            }
        }
        senders
            .into_iter()
            .zip(receivers)
            .enumerate()
            .map(|(rank, (tx_row, rx_row))| LocalCommunicator {
                rank,
                to: tx_row.into_iter().map(|s| s.expect("filled above")).collect(),
                from: rx_row.into_iter().map(|r| r.expect("filled above")).collect(),
                enter: Arc::clone(&enter),
                exit: Arc::clone(&exit),
            })
            .collect()
    }
}

impl<M: Send> SimCommunicator<M> for LocalCommunicator<M> {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.to.len()
    }

    fn send(&mut self, to: usize, msg: M) {
        // A closed channel means the peer thread exited mid-window —
        // the lock-step protocol never does that, so this is a bug in
        // the orchestrator, not a condition to paper over.
        self.to[to].send(msg).expect("peer rank exited mid-window");
    }

    fn exchange(&mut self) -> Vec<(usize, M)> {
        // close the send phase: after this, every message of the window
        // sits in its destination channel
        self.enter.wait();
        let mut inbox = Vec::new();
        for (from, rx) in self.from.iter().enumerate() {
            // drain, don't block: an empty channel is just a quiet peer
            while let Ok(msg) = rx.try_recv() {
                inbox.push((from, msg));
            }
        }
        // close the drain phase: nobody starts next-window sends until
        // every rank has taken its inbox
        self.exit.wait();
        inbox
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Every rank sends its rank to every rank (itself included); after
    /// one exchange each inbox holds all n messages in sender order.
    #[test]
    fn all_to_all_delivers_in_sender_rank_order() {
        let n = 4;
        let comms = LocalCommunicator::group(n);
        let inboxes: Vec<Vec<(usize, usize)>> = thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|mut c| {
                    s.spawn(move || {
                        for to in 0..c.size() {
                            c.send(to, c.rank());
                        }
                        c.exchange()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for inbox in inboxes {
            assert_eq!(inbox, (0..n).map(|i| (i, i)).collect::<Vec<_>>());
        }
    }

    /// Per-sender FIFO: a burst of messages from one rank arrives in
    /// send order, and messages sent after an exchange are not visible
    /// to that exchange (the double barrier holds the window boundary).
    #[test]
    fn windows_do_not_leak_and_fifo_holds() {
        let comms = LocalCommunicator::group(2);
        let results: Vec<Vec<Vec<(usize, u32)>>> = thread::scope(|s| {
            let handles: Vec<_> = comms
                .into_iter()
                .map(|mut c| {
                    s.spawn(move || {
                        let mut seen = Vec::new();
                        for window in 0..3u32 {
                            if c.rank() == 0 {
                                for k in 0..5u32 {
                                    c.send(1, window * 10 + k);
                                }
                            }
                            seen.push(c.exchange());
                        }
                        seen
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // rank 1 sees exactly window w's burst at exchange w, in order
        for (w, inbox) in results[1].iter().enumerate() {
            let expect: Vec<(usize, u32)> =
                (0..5).map(|k| (0, w as u32 * 10 + k)).collect();
            assert_eq!(inbox, &expect, "window {w}");
        }
        // rank 0 never receives anything
        assert!(results[0].iter().all(|i| i.is_empty()));
    }

    /// A rank's message to itself takes the same one-window hop.
    #[test]
    fn self_send_is_delivered_at_the_exchange() {
        let comms = LocalCommunicator::group(1);
        let mut c = comms.into_iter().next().unwrap();
        c.send(0, "loop");
        assert_eq!(c.exchange(), vec![(0, "loop")]);
        assert!(c.exchange().is_empty());
    }
}
