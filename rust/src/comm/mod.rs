//! Boundary-message transport for the partitioned simulation engine.
//!
//! The sharded engine (see [`crate::sim::partition`]) advances one
//! lock-step window at a time: every participant simulates `[t, t+W)`
//! against its private state, emits the cross-shard effects of that
//! window as *boundary messages*, and then meets the others at a
//! barrier where all messages are exchanged. A message sent in window
//! `W` is delivered at the start of window `W+1` — one window of
//! latency, for every message, on every backend, at every shard count.
//! That uniformity is what makes the shard merge deterministic: the
//! set of messages a participant sees in a window is a function of the
//! simulation alone, never of how the machines were partitioned.
//!
//! [`SimCommunicator`] is the narrow contract (rank, size, per-neighbor
//! send, barrier exchange), modeled on the `sim_communication` layer of
//! matsim's parallel qsim: the channel-backed [`LocalCommunicator`] is
//! the first backend, and the trait is shaped so an MPI world (rank =
//! process, send = `MPI_Isend`, exchange = neighbor all-to-all +
//! `MPI_Barrier`) could slot in without touching the orchestrator.

pub mod local;

pub use local::LocalCommunicator;

/// Per-window boundary-message transport between simulation partitions.
///
/// The contract every backend must keep:
///
/// * `send(to, msg)` may be called any number of times between two
///   `exchange()` calls, for any `to < size()` **including the sender's
///   own rank** — a partition's message to itself takes the same
///   one-window hop as everyone else's, which keeps delivery timing
///   independent of the partition layout.
/// * `exchange()` is a collective: every rank must call it once per
///   window, and it returns only after all ranks of the window have
///   sent everything they are going to send. It yields the messages
///   addressed to the caller as `(from_rank, message)` pairs, sorted
///   by sender rank with per-sender FIFO order preserved — a total
///   order that is identical run to run.
/// * No message crosses a window boundary in flight: everything sent
///   before an `exchange()` is delivered by that `exchange()`, and
///   nothing sent after it can leak into it.
pub trait SimCommunicator<M: Send> {
    /// This participant's rank in `[0, size)`.
    fn rank(&self) -> usize;
    /// Number of participants in the group.
    fn size(&self) -> usize;
    /// Queue a boundary message for delivery to `to` at the next
    /// `exchange()`. `to` may equal `rank()`.
    fn send(&mut self, to: usize, msg: M);
    /// Window barrier + delivery: blocks until every rank has entered,
    /// then returns this rank's inbox sorted by `(sender rank, send
    /// order)`.
    fn exchange(&mut self) -> Vec<(usize, M)>;
}
