//! Deterministic fault plans: what goes wrong, where, and when.
//!
//! A [`FaultPlan`] is a time-sorted list of [`FaultEvent`]s. Plans come
//! from three builders — scripted traces ([`FaultPlan::scripted`]),
//! per-machine MTBF crash draws ([`FaultPlan::from_mtbf`]) and a mixed
//! chaos generator covering every fault kind ([`FaultPlan::chaos_mix`]).
//! Every builder is seeded: the same seed always produces byte-identical
//! schedules, which is what makes chaos runs replayable and lets the
//! benches assert two same-seed runs behave identically.
//!
//! Event times are **offsets from the moment the plan is injected**
//! (`VirtualCluster::inject_faults`), not absolute sim times — a plan
//! built once can be replayed against clusters that took different
//! amounts of time to warm up.

use crate::sim::SimTime;
use crate::util::Rng;
use std::collections::BTreeMap;

/// One kind of injected failure. Machine 0 (the head) is never a valid
/// target — the injector ignores faults aimed at it.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Power loss: the container vanishes, the health check expires,
    /// and jobs holding slots on the machine abort immediately.
    Crash { machine: u32 },
    /// The machine stays alive (ranks keep computing) but its consul
    /// agent stops heartbeating for `duration`; the node drops out of
    /// the hostfile until the agent recovers and re-registers.
    Hang { machine: u32, duration: SimTime },
    /// `cycles` hang windows of `down`, separated by `up` of healthy
    /// operation — a flapping agent.
    Flap { machine: u32, down: SimTime, up: SimTime, cycles: u32 },
    /// Gossip split: the listed machines' agents can reach neither the
    /// rest of the agents nor the consul servers for `duration`, so
    /// only the majority side keeps refreshing health checks.
    Partition { machines: Vec<u32>, duration: SimTime },
    /// The next `failures` container-deploy attempts on the machine
    /// error out (image pull / start failure).
    DeployFail { machine: u32, failures: u32 },
    /// Correlated rack-level outage (PDU / ToR failure): every machine
    /// on the rack is hard-killed in the same tick. The injector
    /// resolves rack membership against the live plant, so one plan
    /// replays against any topology; the head's machine survives even
    /// if it shares the rack.
    RackOutage { rack: u32 },
    /// Partial partition: the listed machines' agents can reach only
    /// the listed consul servers for `duration`. Gossip keeps flowing,
    /// but TTL refreshes and registrations from those agents commit
    /// only while the raft leader is in the reachable set — so health
    /// flaps track quorum topology instead of a clean split, and the
    /// existing anti-entropy path re-registers reaped services once
    /// the window closes.
    PartialPartition { machines: Vec<u32>, servers: Vec<u32>, duration: SimTime },
    /// The head *process* crashes (machine 0 stays up): the in-memory
    /// scheduler state is lost and, when HA is enabled, the standby
    /// rebuilds it from the replicated WAL once the leadership lease
    /// expires. Ignored without HA — chaos never decapitates a cluster
    /// that has no standby.
    HeadCrash,
}

impl FaultKind {
    /// Stable label for histograms and determinism fingerprints.
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Crash { .. } => "crash",
            FaultKind::Hang { .. } => "hang",
            FaultKind::Flap { .. } => "flap",
            FaultKind::Partition { .. } => "partition",
            FaultKind::DeployFail { .. } => "deploy_fail",
            FaultKind::RackOutage { .. } => "rack_outage",
            FaultKind::PartialPartition { .. } => "partial_partition",
            FaultKind::HeadCrash => "head_crash",
        }
    }
}

/// A fault at a point in (relative) time.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    /// Offset from plan injection.
    pub at: SimTime,
    pub kind: FaultKind,
}

/// A deterministic, time-sorted fault schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// A hand-written trace (events are sorted by time for you).
    pub fn scripted(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| e.at);
        Self { events }
    }

    /// Per-machine MTBF draws: every compute machine (`1..machines`)
    /// crashes at exponentially distributed intervals with mean `mtbf`,
    /// over `horizon`. Machines draw from forked, per-machine streams,
    /// so the schedule is stable under iteration-order changes.
    pub fn from_mtbf(seed: u64, machines: u32, mtbf: SimTime, horizon: SimTime) -> Self {
        let mut root = Rng::new(seed ^ 0xFA17_5EED);
        let mut events = Vec::new();
        for machine in 1..machines {
            let mut rng = root.fork();
            let mut t = SimTime::ZERO;
            loop {
                t = t + SimTime::from_secs_f64(rng.gen_exp(mtbf.as_secs_f64()));
                if t > horizon {
                    break;
                }
                events.push(FaultEvent { at: t, kind: FaultKind::Crash { machine } });
            }
        }
        Self::scripted(events)
    }

    /// A single correlated rack outage: every machine on `rack` dies in
    /// the same tick, `at` after injection (ToR switch or PDU failure —
    /// the failure domain the topology-aware scheduler packs jobs
    /// into). Membership is resolved by the injector against the live
    /// plant at fire time.
    pub fn rack_outage(rack: u32, at: SimTime) -> Self {
        Self::scripted(vec![FaultEvent { at, kind: FaultKind::RackOutage { rack } }])
    }

    /// A single head-process crash, `at` after injection — the HA
    /// failover scenario's trigger.
    pub fn head_crash(at: SimTime) -> Self {
        Self::scripted(vec![FaultEvent { at, kind: FaultKind::HeadCrash }])
    }

    /// A single partial partition: `machines`' agents can reach only
    /// `servers` for `duration`, starting `at` after injection.
    pub fn partial_partition(
        machines: Vec<u32>,
        servers: Vec<u32>,
        at: SimTime,
        duration: SimTime,
    ) -> Self {
        Self::scripted(vec![FaultEvent {
            at,
            kind: FaultKind::PartialPartition { machines, servers, duration },
        }])
    }

    /// `faults` seeded events drawn over `horizon`, mixing every fault
    /// kind (crash-heavy, with hangs, flaps, deploy failures and
    /// single-machine partitions in the tail).
    pub fn chaos_mix(seed: u64, machines: u32, faults: usize, horizon: SimTime) -> Self {
        let mut rng = Rng::new(seed ^ 0xC4A0_5CED);
        let compute = machines.saturating_sub(1).max(1) as u64;
        let mut events = Vec::new();
        for _ in 0..faults {
            let at = SimTime::from_secs_f64(rng.gen_f64() * horizon.as_secs_f64());
            let machine = 1 + rng.gen_range(compute) as u32;
            let kind = match rng.gen_range(10) {
                0..=3 => FaultKind::Crash { machine },
                4..=6 => FaultKind::Hang {
                    machine,
                    duration: SimTime::from_secs(30 + rng.gen_range(60)),
                },
                7 => FaultKind::Flap {
                    machine,
                    down: SimTime::from_secs(20),
                    up: SimTime::from_secs(20),
                    cycles: 2 + rng.gen_range(2) as u32,
                },
                8 => FaultKind::DeployFail { machine, failures: 1 + rng.gen_range(2) as u32 },
                _ => FaultKind::Partition {
                    machines: vec![machine],
                    duration: SimTime::from_secs(45 + rng.gen_range(45)),
                },
            };
            events.push(FaultEvent { at, kind });
        }
        Self::scripted(events)
    }

    /// Lower the plan to primitive events: flaps become their individual
    /// hang windows. This is what the injector schedules.
    pub fn expanded(&self) -> Vec<FaultEvent> {
        let mut out = Vec::new();
        for ev in &self.events {
            match &ev.kind {
                FaultKind::Flap { machine, down, up, cycles } => {
                    let period = down.as_nanos() + up.as_nanos();
                    for c in 0..*cycles {
                        out.push(FaultEvent {
                            at: ev.at + SimTime::from_nanos(period * c as u64),
                            kind: FaultKind::Hang { machine: *machine, duration: *down },
                        });
                    }
                }
                other => out.push(FaultEvent { at: ev.at, kind: other.clone() }),
            }
        }
        out.sort_by_key(|e| e.at);
        out
    }

    /// Stable per-kind event histogram (for reports and same-seed
    /// determinism checks).
    pub fn kind_counts(&self) -> BTreeMap<&'static str, usize> {
        let mut counts = BTreeMap::new();
        for ev in &self.events {
            *counts.entry(ev.kind.label()).or_insert(0) += 1;
        }
        counts
    }

    /// Fold another plan in (events re-sorted).
    pub fn merged(mut self, mut other: FaultPlan) -> FaultPlan {
        self.events.append(&mut other.events);
        Self::scripted(self.events)
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let a = FaultPlan::from_mtbf(42, 8, SimTime::from_secs(300), SimTime::from_secs(3600));
        let b = FaultPlan::from_mtbf(42, 8, SimTime::from_secs(300), SimTime::from_secs(3600));
        assert_eq!(a, b, "MTBF plans must be deterministic in the seed");
        let c = FaultPlan::chaos_mix(7, 8, 20, SimTime::from_secs(3600));
        let d = FaultPlan::chaos_mix(7, 8, 20, SimTime::from_secs(3600));
        assert_eq!(c, d, "chaos mixes must be deterministic in the seed");
    }

    #[test]
    fn different_seeds_differ() {
        let a = FaultPlan::from_mtbf(1, 8, SimTime::from_secs(300), SimTime::from_secs(3600));
        let b = FaultPlan::from_mtbf(2, 8, SimTime::from_secs(300), SimTime::from_secs(3600));
        assert_ne!(a, b);
    }

    #[test]
    fn mtbf_plan_respects_horizon_and_targets_compute_machines_only() {
        let plan = FaultPlan::from_mtbf(9, 4, SimTime::from_secs(120), SimTime::from_secs(1000));
        assert!(!plan.is_empty(), "1000s horizon at 120s mtbf must draw failures");
        let mut last = SimTime::ZERO;
        for ev in &plan.events {
            assert!(ev.at <= SimTime::from_secs(1000));
            assert!(ev.at >= last, "plan must be time-sorted");
            last = ev.at;
            match &ev.kind {
                FaultKind::Crash { machine } => {
                    assert!((1..4).contains(machine), "machine {machine} out of range")
                }
                other => panic!("mtbf plan drew a non-crash fault: {other:?}"),
            }
        }
    }

    #[test]
    fn flap_expands_to_hang_windows() {
        let plan = FaultPlan::scripted(vec![FaultEvent {
            at: SimTime::from_secs(10),
            kind: FaultKind::Flap {
                machine: 2,
                down: SimTime::from_secs(5),
                up: SimTime::from_secs(15),
                cycles: 3,
            },
        }]);
        let expanded = plan.expanded();
        assert_eq!(expanded.len(), 3);
        for (i, ev) in expanded.iter().enumerate() {
            assert_eq!(ev.at, SimTime::from_secs(10 + 20 * i as u64));
            assert!(
                matches!(ev.kind, FaultKind::Hang { machine: 2, duration } if duration == SimTime::from_secs(5))
            );
        }
    }

    #[test]
    fn rack_outage_plan_is_a_single_labeled_event() {
        let plan = FaultPlan::rack_outage(1, SimTime::from_secs(30));
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.events[0].at, SimTime::from_secs(30));
        assert_eq!(plan.events[0].kind, FaultKind::RackOutage { rack: 1 });
        assert_eq!(plan.kind_counts().get("rack_outage"), Some(&1));
        // expansion passes the event through untouched
        assert_eq!(plan.expanded(), plan.events);
    }

    #[test]
    fn kind_counts_are_stable() {
        let plan = FaultPlan::chaos_mix(3, 6, 30, SimTime::from_secs(600));
        let counts = plan.kind_counts();
        assert_eq!(counts.values().sum::<usize>(), 30);
        assert_eq!(plan.kind_counts(), counts);
    }

    #[test]
    fn merged_plans_stay_sorted() {
        let a = FaultPlan::scripted(vec![FaultEvent {
            at: SimTime::from_secs(50),
            kind: FaultKind::Crash { machine: 1 },
        }]);
        let b = FaultPlan::scripted(vec![FaultEvent {
            at: SimTime::from_secs(10),
            kind: FaultKind::Crash { machine: 2 },
        }]);
        let m = a.merged(b);
        assert_eq!(m.len(), 2);
        assert!(m.events[0].at < m.events[1].at);
    }
}
