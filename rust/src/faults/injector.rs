//! The injector: compiles fault-plan events into mutations of the live
//! cluster state.
//!
//! `VirtualCluster::inject_faults` schedules one engine event per
//! (expanded) plan entry; each fires this module's [`apply`], which
//! drives the cluster's chaos hooks — the same `kill_machine` path an
//! operator uses, heartbeat muting, gossip partitions and deploy-fault
//! budgets. Everything runs inside the deterministic event engine, so a
//! seeded plan always replays the same way.

use crate::cluster::vcluster::{ClusterEvent, ClusterState, VirtualCluster};
use crate::faults::plan::FaultKind;
use crate::sim::Engine;
use crate::util::ids::MachineId;

/// Apply one fault to the cluster. Faults aimed at machine 0 (the head)
/// or out-of-range machines are ignored — chaos never decapitates the
/// control plane.
pub fn apply(st: &mut ClusterState, eng: &mut Engine<ClusterState, ClusterEvent>, kind: &FaultKind) {
    if st.trace.enabled() {
        st.trace.emit(crate::obs::TraceEvent::FaultInjected {
            at: eng.now(),
            epoch: st.ha.epoch,
            kind: kind.label().into(),
        });
    }
    match kind {
        FaultKind::Crash { machine } => {
            if target_ok(st, *machine) {
                VirtualCluster::kill_machine_at(st, eng.now(), MachineId::new(*machine));
            }
        }
        FaultKind::Hang { machine, duration } => {
            if target_ok(st, *machine) {
                VirtualCluster::chaos_hang(st, eng.now(), MachineId::new(*machine), *duration);
            }
        }
        // plans lower flaps to hang windows in `expanded()`; applying one
        // directly injects only its first down window
        FaultKind::Flap { machine, down, .. } => {
            if target_ok(st, *machine) {
                VirtualCluster::chaos_hang(st, eng.now(), MachineId::new(*machine), *down);
            }
        }
        FaultKind::Partition { machines, duration } => {
            let safe: Vec<u32> = machines.iter().copied().filter(|&m| m != 0).collect();
            if let Some(epoch) = VirtualCluster::chaos_partition(st, &safe) {
                // the heal timer carries the partition's epoch: if a later
                // partition replaces this split, the stale timer is a no-op
                // and the newer partition runs its full duration
                eng.schedule_after(*duration, ClusterEvent::HealPartition(epoch));
            }
        }
        FaultKind::DeployFail { machine, failures } => {
            if target_ok(st, *machine) {
                VirtualCluster::chaos_deploy_fail(st, MachineId::new(*machine), *failures);
            }
        }
        FaultKind::PartialPartition { machines, servers, duration } => {
            let safe: Vec<u32> = machines.iter().copied().filter(|&m| m != 0).collect();
            if let Some(epoch) = VirtualCluster::chaos_partial_partition(st, &safe, servers) {
                // epoch-guarded heal, exactly like the full partition: a
                // later partial partition invalidates this timer
                eng.schedule_after(*duration, ClusterEvent::HealPartialPartition(epoch));
            }
        }
        // the head *process* crash: machine 0 stays up, only the
        // scheduler state dies — a no-op unless a standby exists (HA)
        FaultKind::HeadCrash => {
            VirtualCluster::chaos_head_crash(st, eng.now());
        }
        // correlated failure domain: every machine on the rack dies in
        // this same tick (the head, machine 0, is spared — chaos never
        // decapitates the control plane)
        FaultKind::RackOutage { rack } => {
            let members: Vec<u32> = st
                .plant
                .racks
                .get(*rack as usize)
                .map(|r| r.members.iter().map(|m| m.raw()).collect())
                .unwrap_or_default();
            let mut killed = false;
            for m in members {
                if target_ok(st, m) {
                    VirtualCluster::kill_machine_at(st, eng.now(), MachineId::new(m));
                    killed = true;
                }
            }
            if killed {
                st.metrics.inc("rack_outages_injected");
            }
        }
    }
    // fault application is an engine-event boundary: drain the buffer
    // here like the scheduler/WAL paths do
    st.trace.flush();
}

fn target_ok(st: &ClusterState, machine: u32) -> bool {
    machine != 0 && (machine as usize) < st.node_states.len()
}
