//! End-to-end chaos scenario driver: one cluster, one job trace, one
//! fault plan — and the recovery metrics that matter (MTTR, wasted
//! work, goodput).
//!
//! This is the shared harness behind `vhpc chaos`, the
//! `chaos_recovery` example and the `ext_faults` bench, mirroring how
//! `cluster::mix::run_job_trace` backs the fault-free scenarios.

use crate::cluster::head::{JobKind, JobState};
use crate::cluster::vcluster::VirtualCluster;
use crate::config::ClusterSpec;
use crate::faults::plan::FaultPlan;
use crate::sim::SimTime;
use anyhow::{ensure, Result};
use std::collections::BTreeMap;

/// What a chaos run measured.
#[derive(Debug, Clone)]
pub struct ChaosOutcome {
    pub jobs_submitted: usize,
    /// Jobs that reached `Done` (possibly after several requeues).
    pub jobs_completed: usize,
    /// Jobs abandoned after exhausting their retry budget.
    pub jobs_abandoned: usize,
    /// Requeue events across all jobs.
    pub requeues: u64,
    /// Machines hard-killed by the plan.
    pub machines_killed: u64,
    /// Machines powered on after fault injection began (replacements
    /// plus demand-driven scale-ups).
    pub replacements_booted: u64,
    /// Mean/max time from a job's first node loss to its completion, in
    /// seconds (0 when no job ever lost a node).
    pub mttr_mean: f64,
    pub mttr_max: f64,
    /// Virtual work redone because it fell past the last checkpoint.
    /// Synthetic jobs checkpoint continuously (they resume at exactly
    /// their remaining duration), so on synthetic traces this is 0 by
    /// construction — nonzero waste comes from Jacobi jobs, whose
    /// restarts round down to `JACOBI_CHECKPOINT_STEPS`.
    pub wasted_seconds: f64,
    /// Useful slot-seconds delivered per second of makespan (an average
    /// "useful slots busy" figure — higher is better).
    pub goodput: f64,
    pub makespan: f64,
    /// Stable counter snapshot: two runs with the same seed must match.
    pub fingerprint: BTreeMap<String, u64>,
}

/// Drive `trace` (one synthetic `(ranks, duration_secs)` job each, all
/// submitted in one burst after warm-up) through a cluster while the
/// fault plan fires. Errors if the trace has not fully drained — every
/// job `Done` or abandoned — after `deadline_secs` of virtual time.
pub fn run_chaos_trace(
    spec: ClusterSpec,
    trace: &[(u32, u64)],
    plan: &FaultPlan,
    warmup_slots: u32,
    max_retries: u32,
    deadline_secs: u64,
) -> Result<(ChaosOutcome, VirtualCluster)> {
    let mut vc = VirtualCluster::new(spec)?;
    vc.state.head.max_retries = max_retries;
    vc.start();
    ensure!(
        vc.advance_until(SimTime::from_secs(600), |st| {
            st.head.slots_available() >= warmup_slots
        }),
        "cluster never advertised {warmup_slots} slots"
    );
    let booted_before = vc.metrics().counter("machines_powered_on");
    vc.inject_faults(plan);
    for (i, (ranks, secs)) in trace.iter().enumerate() {
        vc.submit(
            &format!("chaos-{i}"),
            *ranks,
            JobKind::Synthetic { duration: SimTime::from_secs(*secs) },
        );
    }
    let t0 = vc.now();
    let deadline = t0 + SimTime::from_secs(deadline_secs);
    while vc.now() < deadline && vc.completed_total() < trace.len() {
        // NOTE: unlike the fault-free trace driver, reservations may
        // transiently overbook between a hostfile shrink and the next
        // reaper tick — that window is exactly what the recovery
        // pipeline exists to close, so no overbooking assert here.
        vc.advance(SimTime::from_secs(1));
    }
    ensure!(
        vc.completed_total() == trace.len(),
        "trace never drained: {}/{} jobs accounted for after {deadline_secs}s",
        vc.completed_total(),
        trace.len()
    );

    let mut completed = 0usize;
    let mut useful_slot_seconds = 0f64;
    let mut last_finish = SimTime::ZERO;
    for rec in vc.completed_jobs() {
        if let JobState::Done { finished, .. } = rec.state {
            completed += 1;
            last_finish = last_finish.max(finished);
            // useful work is the job's *original* demand, independent of
            // how much was re-run: look it up from the trace by index
            if let Some(i) = rec
                .spec
                .name
                .strip_prefix("chaos-")
                .and_then(|s| s.parse::<usize>().ok())
            {
                if let Some((ranks, secs)) = trace.get(i) {
                    useful_slot_seconds += *ranks as f64 * *secs as f64;
                }
            }
        }
    }
    let makespan = last_finish.saturating_sub(t0).as_secs_f64();
    let metrics = vc.metrics();
    let (mttr_mean, mttr_max) = metrics
        .histogram("job_mttr_seconds")
        .map(|h| (h.mean(), h.max()))
        .unwrap_or((0.0, 0.0));
    let wasted_seconds = metrics
        .histogram("job_wasted_seconds")
        .map(|h| h.sum())
        .unwrap_or(0.0);
    let outcome = ChaosOutcome {
        jobs_submitted: trace.len(),
        jobs_completed: completed,
        jobs_abandoned: metrics.counter("jobs_lost") as usize,
        requeues: metrics.counter("jobs_requeued"),
        machines_killed: metrics.counter("machines_killed"),
        replacements_booted: metrics.counter("machines_powered_on") - booted_before,
        mttr_mean,
        mttr_max,
        wasted_seconds,
        goodput: useful_slot_seconds / makespan.max(1e-9),
        makespan,
        fingerprint: metrics.counters_snapshot(),
    };
    Ok((outcome, vc))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::plan::{FaultEvent, FaultKind};

    fn spec() -> ClusterSpec {
        let mut spec = ClusterSpec::paper_testbed();
        spec.machines = 4;
        spec.machine_spec.boot_time = SimTime::from_secs(5);
        spec.autoscale.min_nodes = 2;
        spec.autoscale.max_nodes = 3;
        spec.autoscale.interval = SimTime::from_secs(2);
        spec.autoscale.cooldown = SimTime::from_secs(4);
        spec.autoscale.idle_timeout = SimTime::from_secs(120);
        spec
    }

    #[test]
    fn fault_free_run_has_no_recovery_activity() {
        let trace = [(8u32, 20u64), (8, 20)];
        let (o, _) =
            run_chaos_trace(spec(), &trace, &FaultPlan::default(), 24, 3, 1200).unwrap();
        assert_eq!(o.jobs_completed, 2);
        assert_eq!(o.jobs_abandoned, 0);
        assert_eq!(o.requeues, 0);
        assert_eq!(o.machines_killed, 0);
        assert_eq!(o.mttr_max, 0.0);
        assert!(o.goodput > 0.0);
    }

    #[test]
    fn scripted_crash_recovers_every_job() {
        let trace = [(16u32, 90u64), (8, 30)];
        let plan = FaultPlan::scripted(vec![FaultEvent {
            at: SimTime::from_secs(20),
            kind: FaultKind::Crash { machine: 2 },
        }]);
        let (o, vc) = run_chaos_trace(spec(), &trace, &plan, 24, 3, 2400).unwrap();
        assert_eq!(o.machines_killed, 1);
        assert_eq!(o.jobs_completed, 2, "both jobs must survive one crash");
        assert_eq!(o.jobs_abandoned, 0);
        assert!(o.requeues >= 1, "the 16-rank job must have been requeued");
        assert!(o.mttr_max > 0.0 && o.mttr_max.is_finite());
        assert!(o.replacements_booted >= 1, "a replacement must boot");
        for rec in vc.completed_jobs() {
            assert!(matches!(rec.state, JobState::Done { .. }), "{:?}", rec.state);
        }
    }
}
