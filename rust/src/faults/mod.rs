//! Fault injection and self-healing — the cluster under adversity.
//!
//! The paper's consul pipeline already *removes* dead capacity: a node
//! that stops heartbeating goes critical, drops out of the catalog and
//! the hostfile re-renders without it (§IV, Fig. 5). This subsystem
//! closes the loop so the cluster also *recovers*:
//!
//! * [`plan`] — deterministic, seeded fault schedules: node crashes
//!   (per-machine MTBF draws or scripted), node hangs, flapping agents,
//!   consul gossip partitions and injected deploy failures.
//! * [`injector`] — compiles a plan into `sim::Engine` events that
//!   mutate the live [`ClusterState`](crate::cluster::vcluster): the
//!   `kill_machine` path, heartbeat muting, gossip splits, deploy-fault
//!   budgets.
//! * Recovery itself lives where the control loops live: the head
//!   cross-checks running reservations against the health-gated
//!   hostfile each scheduler tick and requeues lost jobs under a
//!   per-job retry budget with partial-progress credit
//!   (`Head::handle_lost_job`), while the autoscaler counts unhealthy
//!   nodes as capacity-to-replace and boots substitutes.
//! * [`scenario`] — the end-to-end harness (`run_chaos_trace`) behind
//!   `vhpc chaos`, `examples/chaos_recovery.rs` and
//!   `benches/ext_faults.rs`, reporting MTTR, wasted work and goodput.

pub mod injector;
pub mod plan;
pub mod scenario;

pub use plan::{FaultEvent, FaultKind, FaultPlan};
pub use scenario::{run_chaos_trace, ChaosOutcome};
