//! dockyard — the simulated container engine (the paper's "Docker").
//!
//! Implements the pieces the paper actually exercises (§II-B, §III-A,
//! Fig. 2): Dockerfile parsing, image building as a stack of
//! content-addressed layers with union-fs semantics (incl. whiteouts),
//! a Docker-Hub-like registry with layer-dedup push/pull, and a per-host
//! engine (`dockerd`) owning container lifecycle, cgroup limits and
//! network attachment.

pub mod cgroup;
pub mod container;
pub mod dockerfile;
pub mod engine;
pub mod image;
pub mod layer;
pub mod registry;

pub use container::{Container, ContainerState};
pub use dockerfile::{Dockerfile, Instruction};
pub use engine::Engine as DockerEngine;
pub use image::{Image, ImageStore};
pub use layer::{Digest, FileEntry, Layer};
pub use registry::Registry;
