//! The per-machine container engine ("dockerd").
//!
//! Owns the local image cache, the machine's software bridge and its
//! containers. `run()` is the paper's `docker run`: ensure the image is
//! local (pull), reserve machine resources, attach the bridge, start the
//! entrypoint — and report the virtual time each phase cost, which the
//! Fig. 6 bench decomposes.

use super::cgroup::Cgroup;
use super::container::{Container, ContainerError, ContainerState};
use super::image::ImageStore;
use super::registry::{Registry, RegistryError};
use crate::hw::machine::{Machine, MachineError};
use crate::sim::SimTime;
use crate::util::ids::{ContainerId, MachineId};
use crate::vnet::bridge::{Bridge, BridgeMode};
use crate::vnet::ipam::IpamError;
use std::collections::HashMap;
use thiserror::Error;

#[derive(Debug, Error)]
pub enum EngineError {
    #[error(transparent)]
    Registry(#[from] RegistryError),
    #[error(transparent)]
    Machine(#[from] MachineError),
    #[error(transparent)]
    Container(#[from] ContainerError),
    #[error(transparent)]
    Ipam(#[from] IpamError),
    #[error("no such container {0}")]
    NoContainer(ContainerId),
    #[error("cgroup: {0}")]
    Cgroup(#[from] super::cgroup::CgroupError),
}

/// Cost breakdown of a `docker run`.
#[derive(Debug, Clone, Default)]
pub struct RunReceipt {
    pub pull_time: SimTime,
    pub extract_time: SimTime,
    pub create_time: SimTime,
    pub start_time: SimTime,
    pub pulled_bytes: u64,
}

impl RunReceipt {
    pub fn total(&self) -> SimTime {
        self.pull_time + self.extract_time + self.create_time + self.start_time
    }
}

/// Requested container resources.
#[derive(Debug, Clone, Copy)]
pub struct RunSpec {
    pub cores: u32,
    pub memory: u64,
}

impl Default for RunSpec {
    fn default() -> Self {
        Self { cores: 1, memory: 1 << 30 }
    }
}

/// dockerd for one machine.
#[derive(Debug)]
pub struct Engine {
    pub machine: MachineId,
    pub images: ImageStore,
    pub bridge: Bridge,
    containers: HashMap<ContainerId, Container>,
    /// Fixed daemon overheads (fork/exec, netns setup).
    pub create_overhead: SimTime,
    pub start_overhead: SimTime,
}

impl Engine {
    pub fn new(machine: MachineId, mode: BridgeMode) -> Self {
        let subnet = mode.default_subnet(machine.raw());
        Self {
            machine,
            images: ImageStore::new(),
            bridge: Bridge::new(mode.name(), mode, subnet),
            containers: HashMap::new(),
            create_overhead: SimTime::from_millis(40),
            start_overhead: SimTime::from_millis(120),
        }
    }

    pub fn mode(&self) -> BridgeMode {
        self.bridge.mode
    }

    pub fn container(&self, id: ContainerId) -> Option<&Container> {
        self.containers.get(&id)
    }

    pub fn container_mut(&mut self, id: ContainerId) -> Option<&mut Container> {
        self.containers.get_mut(&id)
    }

    pub fn containers(&self) -> impl Iterator<Item = &Container> {
        self.containers.values()
    }

    pub fn running_count(&self) -> usize {
        self.containers.values().filter(|c| c.is_running()).count()
    }

    /// `docker run`: pull-if-needed, create, attach network, start.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &mut self,
        id: ContainerId,
        name: &str,
        image_ref: &str,
        spec: RunSpec,
        machine: &mut Machine,
        registry: &mut Registry,
    ) -> Result<RunReceipt, EngineError> {
        let mut receipt = RunReceipt::default();

        // 1. pull if the image is not cached locally
        if !self.images.contains(image_ref) {
            let pr = registry.pull(image_ref, &mut self.images, &machine.spec.nic)?;
            receipt.pull_time = pr.transfer_time;
            receipt.pulled_bytes = pr.bytes_transferred;
            // extracting layers to disk costs a disk write pass
            receipt.extract_time = machine.disk_read_time(pr.bytes_transferred);
        }
        let image = self.images.get(image_ref).expect("just ensured").clone();

        // 2. reserve machine resources; build the cgroup
        machine.allocate(spec.cores, spec.memory)?;
        let cgroup = Cgroup::new(spec.cores, spec.memory)?;
        let mut container = Container::new(id, name, image_ref, self.machine, cgroup);
        container.env = image.config.env.clone();
        container.cmd = image
            .config
            .entrypoint
            .clone()
            .or_else(|| image.config.cmd.clone())
            .unwrap_or_default();
        receipt.create_time = self.create_overhead;

        // 3. network attach
        let port = self.bridge.attach(id)?;
        container.ip = Some(port.ip);

        // 4. start the entrypoint
        container.start()?;
        receipt.start_time = self.start_overhead;

        self.containers.insert(id, container);
        Ok(receipt)
    }

    /// `docker ps` — the listing the paper's Fig. 6 screenshots show,
    /// one line per container on this machine.
    pub fn format_ps(&self) -> String {
        let mut rows: Vec<&Container> = self.containers.values().collect();
        rows.sort_by_key(|c| c.id);
        let mut out = format!(
            "{:<14} {:<32} {:<26} {:<10} {:<16}\n",
            "CONTAINER ID", "IMAGE", "COMMAND", "STATUS", "NAMES"
        );
        for c in rows {
            let cmd = if c.cmd.is_empty() { "-".to_string() } else { format!("\"{}\"", c.cmd.join(" ")) };
            let status = match c.state {
                ContainerState::Running => "Up".to_string(),
                ContainerState::Created => "Created".to_string(),
                ContainerState::Paused => "Paused".to_string(),
                ContainerState::Exited => {
                    format!("Exited ({})", c.exit_code.unwrap_or(0))
                }
            };
            out.push_str(&format!(
                "{:<14} {:<32} {:<26} {:<10} {:<16}\n",
                c.id.to_string(),
                c.image,
                cmd,
                status,
                c.name
            ));
        }
        out
    }

    /// `docker images` — local image cache listing.
    pub fn format_images(&self) -> String {
        let mut out = format!("{:<36} {:<14} {:<12}\n", "REPOSITORY:TAG", "IMAGE ID", "SIZE");
        for r in self.images.references() {
            let img = self.images.get(r).unwrap();
            out.push_str(&format!(
                "{:<36} {:<14} {:<12}\n",
                r,
                img.id().short(),
                crate::util::format_bytes(img.total_size())
            ));
        }
        out
    }

    /// `docker stop` (releases nothing until rm; matches docker).
    pub fn stop(&mut self, id: ContainerId, exit_code: i32) -> Result<(), EngineError> {
        self.containers
            .get_mut(&id)
            .ok_or(EngineError::NoContainer(id))?
            .stop(exit_code)?;
        Ok(())
    }

    /// `docker rm`: detach network and free machine resources.
    pub fn remove(
        &mut self,
        id: ContainerId,
        machine: &mut Machine,
    ) -> Result<Container, EngineError> {
        let container = self.containers.remove(&id).ok_or(EngineError::NoContainer(id))?;
        if container.state == ContainerState::Running {
            // docker rm -f semantics
        }
        self.bridge.detach(id);
        machine.release(container.cgroup.cpu_quota_cores, container.cgroup.memory_limit);
        Ok(container)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dockyard::dockerfile::Dockerfile;
    use crate::hw::MachineSpec;

    fn setup() -> (Engine, Machine, Registry) {
        let mut machine = Machine::new(MachineId::new(0), "blade01", MachineSpec::dell_m620());
        machine.power_on().unwrap();
        machine.boot_complete().unwrap();
        let mut registry = Registry::docker_hub();
        let mut builder = ImageStore::with_base_images();
        let df = Dockerfile::parse(Dockerfile::paper_compute_node()).unwrap();
        registry.push(builder.build(&df, "nchc/mpi-computenode:latest").unwrap());
        (Engine::new(MachineId::new(0), BridgeMode::Bridge0), machine, registry)
    }

    #[test]
    fn run_pulls_creates_attaches_starts() {
        let (mut eng, mut m, mut reg) = setup();
        let id = ContainerId::new(0);
        let r = eng
            .run(id, "node02", "nchc/mpi-computenode:latest", RunSpec { cores: 12, memory: 32 << 30 }, &mut m, &mut reg)
            .unwrap();
        assert!(r.pull_time > SimTime::ZERO);
        assert!(r.pulled_bytes > 0);
        let c = eng.container(id).unwrap();
        assert!(c.is_running());
        assert!(c.ip.is_some());
        assert_eq!(c.cmd, vec!["/usr/sbin/sshd", "-D"]);
        assert_eq!(m.cores_free(), 0);
        assert_eq!(eng.running_count(), 1);
    }

    #[test]
    fn second_run_skips_pull() {
        let (mut eng, mut m, mut reg) = setup();
        let spec = RunSpec { cores: 2, memory: 4 << 30 };
        let r1 = eng
            .run(ContainerId::new(0), "a", "nchc/mpi-computenode:latest", spec, &mut m, &mut reg)
            .unwrap();
        let r2 = eng
            .run(ContainerId::new(1), "b", "nchc/mpi-computenode:latest", spec, &mut m, &mut reg)
            .unwrap();
        assert!(r1.pull_time > SimTime::ZERO);
        assert_eq!(r2.pull_time, SimTime::ZERO);
        assert_eq!(r2.pulled_bytes, 0);
        assert!(r2.total() < r1.total());
    }

    #[test]
    fn over_allocation_fails() {
        let (mut eng, mut m, mut reg) = setup();
        let err = eng.run(
            ContainerId::new(0),
            "big",
            "nchc/mpi-computenode:latest",
            RunSpec { cores: 13, memory: 1 << 30 },
            &mut m,
            &mut reg,
        );
        assert!(matches!(err, Err(EngineError::Machine(MachineError::NoCores { .. }))));
    }

    #[test]
    fn remove_releases_resources_and_ip() {
        let (mut eng, mut m, mut reg) = setup();
        let id = ContainerId::new(0);
        let spec = RunSpec { cores: 4, memory: 8 << 30 };
        eng.run(id, "x", "nchc/mpi-computenode:latest", spec, &mut m, &mut reg)
            .unwrap();
        let ip = eng.container(id).unwrap().ip.unwrap();
        assert!(eng.bridge.ipam.is_leased(ip));
        eng.stop(id, 0).unwrap();
        eng.remove(id, &mut m).unwrap();
        assert!(!eng.bridge.ipam.is_leased(ip));
        assert_eq!(m.cores_free(), 12);
        assert!(eng.container(id).is_none());
    }

    #[test]
    fn ps_and_images_render_fig6_style() {
        let (mut eng, mut m, mut reg) = setup();
        let spec = RunSpec { cores: 2, memory: 4 << 30 };
        eng.run(ContainerId::new(0), "node02", "nchc/mpi-computenode:latest", spec, &mut m, &mut reg)
            .unwrap();
        let ps = eng.format_ps();
        assert!(ps.contains("CONTAINER ID"));
        assert!(ps.contains("node02"));
        assert!(ps.contains("nchc/mpi-computenode:latest"));
        assert!(ps.contains("Up"));
        assert!(ps.contains("/usr/sbin/sshd -D"));
        eng.stop(ContainerId::new(0), 137).unwrap();
        assert!(eng.format_ps().contains("Exited (137)"));
        let images = eng.format_images();
        assert!(images.contains("nchc/mpi-computenode:latest"));
        assert!(images.contains("MiB"));
    }

    #[test]
    fn unknown_image_fails() {
        let (mut eng, mut m, mut reg) = setup();
        assert!(matches!(
            eng.run(ContainerId::new(0), "x", "no:img", RunSpec::default(), &mut m, &mut reg),
            Err(EngineError::Registry(RegistryError::NotFound(_)))
        ));
    }
}
