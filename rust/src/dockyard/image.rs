//! Images: an ordered layer stack + runtime config, built from a
//! Dockerfile against a base-image store (§III-A).

use super::dockerfile::{Dockerfile, Instruction};
use super::layer::{resolve_union, Digest, FileEntry, Layer};
use std::collections::{BTreeMap, HashMap};
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum ImageError {
    #[error("unknown base image {0}:{1}")]
    UnknownBase(String, String),
    #[error("dockerfile has no FROM")]
    NoFrom,
    #[error("unknown image {0}")]
    Unknown(String),
}

/// Runtime config recorded by the build.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ImageConfig {
    pub env: Vec<(String, String)>,
    pub labels: Vec<(String, String)>,
    pub exposed_ports: Vec<u16>,
    pub workdir: Option<String>,
    pub user: Option<String>,
    pub entrypoint: Option<Vec<String>>,
    pub cmd: Option<Vec<String>>,
    pub maintainer: Option<String>,
}

/// An immutable image.
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub reference: String, // name:tag
    pub layers: Vec<Layer>,
    pub config: ImageConfig,
}

impl Image {
    pub fn id(&self) -> Digest {
        use sha2::{Digest as _, Sha256};
        let mut h = Sha256::new();
        for l in &self.layers {
            h.update(l.digest().0);
        }
        h.update(self.reference.as_bytes());
        Digest(h.finalize().into())
    }

    pub fn total_size(&self) -> u64 {
        self.layers.iter().map(|l| l.size_bytes()).sum()
    }

    /// Effective root filesystem after union resolution.
    pub fn rootfs(&self) -> BTreeMap<String, FileEntry> {
        resolve_union(&self.layers.iter().collect::<Vec<_>>())
    }
}

/// Synthetic footprint model for RUN commands: well-known package sizes
/// so image sizes are plausible and deterministic.
fn run_footprint(cmd: &str) -> Vec<(String, u64)> {
    let mut files = Vec::new();
    let table: &[(&str, &[(&str, u64)])] = &[
        (
            "openssh-server",
            &[
                ("/usr/sbin/sshd", 852_992),
                ("/etc/ssh/sshd_config", 4_361),
                ("/usr/lib64/libssh.so", 1_254_000),
            ],
        ),
        (
            "openmpi",
            &[
                ("/usr/lib64/openmpi/bin/mpirun", 712_480),
                ("/usr/lib64/openmpi/lib/libmpi.so", 2_913_120),
                ("/usr/lib64/openmpi/bin/orted", 215_340),
                ("/etc/openmpi-default-hostfile", 1_024),
            ],
        ),
        (
            "gcc",
            &[("/usr/bin/gcc", 912_336), ("/usr/lib/gcc/cc1", 14_221_320)],
        ),
    ];
    for (pkg, pkg_files) in table {
        if cmd.contains(pkg) {
            for (p, s) in *pkg_files {
                files.push((p.to_string(), *s));
            }
        }
    }
    if files.is_empty() {
        // generic command: a small synthetic artifact under /var
        let tag = Digest::of_bytes(cmd.as_bytes()).short();
        files.push((format!("/var/lib/run/{tag}"), 64 * 1024));
    }
    files
}

/// Sizes for ADD/COPY sources (the consul binaries the paper injects).
fn add_source_size(src: &str) -> u64 {
    match src {
        "consul" => 10_600_000,          // consul v0.5.2 static binary
        "consul-template" => 6_200_000,  // consul-template binary
        other => 128 * 1024 + other.len() as u64 * 1024,
    }
}

/// Image store: one per machine (local cache) and one inside the registry.
#[derive(Debug, Clone, Default)]
pub struct ImageStore {
    images: HashMap<String, Image>,
}

impl ImageStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Seed the well-known base images (the paper pulls centos:6).
    pub fn with_base_images() -> Self {
        let mut store = Self::new();
        for (name, tag, files) in [
            (
                "centos",
                "6",
                vec![
                    ("/bin/sh", 938_832u64),
                    ("/usr/bin/yum", 801_456),
                    ("/usr/lib64/libc.so.6", 1_926_520),
                    ("/etc/centos-release", 27),
                ],
            ),
            (
                "centos",
                "7",
                vec![
                    ("/bin/sh", 964_536),
                    ("/usr/bin/yum", 812_060),
                    ("/usr/lib64/libc.so.6", 2_156_240),
                    ("/etc/centos-release", 37),
                ],
            ),
        ] {
            let mut layer = Layer::new(format!("FROM scratch ({name}:{tag})"));
            for (p, s) in files {
                layer.add_file(p, s);
            }
            let reference = format!("{name}:{tag}");
            store.insert(Image {
                reference: reference.clone(),
                layers: vec![layer],
                config: ImageConfig::default(),
            });
        }
        store
    }

    pub fn insert(&mut self, image: Image) {
        self.images.insert(image.reference.clone(), image);
    }

    pub fn get(&self, reference: &str) -> Option<&Image> {
        self.images.get(reference)
    }

    pub fn contains(&self, reference: &str) -> bool {
        self.images.contains_key(reference)
    }

    pub fn references(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.images.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Build an image from a Dockerfile: every instruction that mutates
    /// the filesystem appends a layer (Docker's own layering rule);
    /// metadata instructions update the config only.
    pub fn build(
        &mut self,
        dockerfile: &Dockerfile,
        reference: impl Into<String>,
    ) -> Result<Image, ImageError> {
        let (base_name, base_tag) = dockerfile.base().ok_or(ImageError::NoFrom)?;
        let base_ref = format!("{base_name}:{base_tag}");
        let base = self
            .get(&base_ref)
            .ok_or_else(|| ImageError::UnknownBase(base_name.into(), base_tag.into()))?
            .clone();

        let mut layers = base.layers.clone();
        let mut config = base.config.clone();
        for inst in &dockerfile.instructions[1..] {
            match inst {
                Instruction::Run(cmd) => {
                    let mut layer = Layer::new(format!("RUN {cmd}"));
                    for (p, s) in run_footprint(cmd) {
                        layer.add_file(p, s);
                    }
                    layers.push(layer);
                }
                Instruction::Add { src, dst } | Instruction::Copy { src, dst } => {
                    let mut layer = Layer::new(format!("ADD {src} {dst}"));
                    layer.add_file(dst.clone(), add_source_size(src));
                    layers.push(layer);
                }
                Instruction::Env { key, value } => {
                    config.env.push((key.clone(), value.clone()))
                }
                Instruction::Label { key, value } => {
                    config.labels.push((key.clone(), value.clone()))
                }
                Instruction::Expose(p) => config.exposed_ports.push(*p),
                Instruction::Workdir(w) => config.workdir = Some(w.clone()),
                Instruction::User(u) => config.user = Some(u.clone()),
                Instruction::Volume(_) => {}
                Instruction::Cmd(c) => config.cmd = Some(c.clone()),
                Instruction::Entrypoint(e) => config.entrypoint = Some(e.clone()),
                Instruction::Maintainer(m) => config.maintainer = Some(m.clone()),
                Instruction::From { .. } => {}
            }
        }
        let image = Image { reference: reference.into(), layers, config };
        self.insert(image.clone());
        Ok(image)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build_paper_image() -> (ImageStore, Image) {
        let mut store = ImageStore::with_base_images();
        let df = Dockerfile::parse(Dockerfile::paper_compute_node()).unwrap();
        let img = store.build(&df, "nchc/mpi-computenode:latest").unwrap();
        (store, img)
    }

    #[test]
    fn build_layers_one_per_fs_instruction() {
        let (_, img) = build_paper_image();
        // base(1) + RUN(1) + ADD(2) = 4 layers; CMD/MAINTAINER are config
        assert_eq!(img.layers.len(), 4);
        assert_eq!(
            img.config.cmd,
            Some(vec!["/usr/sbin/sshd".into(), "-D".into()])
        );
        assert!(img.config.maintainer.as_deref().unwrap().contains("Yu"));
    }

    #[test]
    fn rootfs_contains_mpi_ssh_and_consul() {
        let (_, img) = build_paper_image();
        let fs = img.rootfs();
        assert!(fs.contains_key("/usr/sbin/sshd"));
        assert!(fs.contains_key("/usr/lib64/openmpi/bin/mpirun"));
        assert!(fs.contains_key("/usr/local/bin/consul"));
        assert!(fs.contains_key("/usr/local/bin/consul-template"));
        assert!(fs.contains_key("/bin/sh")); // from the base
    }

    #[test]
    fn unknown_base_errors() {
        let mut store = ImageStore::new();
        let df = Dockerfile::parse("FROM debian:8\nRUN x").unwrap();
        assert_eq!(
            store.build(&df, "t").unwrap_err(),
            ImageError::UnknownBase("debian".into(), "8".into())
        );
    }

    #[test]
    fn image_id_stable_and_size_positive() {
        let (_, a) = build_paper_image();
        let (_, b) = build_paper_image();
        assert_eq!(a.id(), b.id());
        assert!(a.total_size() > 20_000_000, "size={}", a.total_size());
    }

    #[test]
    fn builds_are_deterministic_layerwise() {
        let (_, a) = build_paper_image();
        let (_, b) = build_paper_image();
        let da: Vec<_> = a.layers.iter().map(|l| l.digest()).collect();
        let db: Vec<_> = b.layers.iter().map(|l| l.digest()).collect();
        assert_eq!(da, db);
    }

    #[test]
    fn base_images_seeded() {
        let store = ImageStore::with_base_images();
        assert!(store.contains("centos:6"));
        assert!(store.contains("centos:7"));
        assert_eq!(store.len(), 2);
    }
}
