//! Container: lifecycle state machine + runtime identity.
//!
//! States follow Docker's: Created → Running → (Paused ⇄ Running) →
//! Exited → (removed). Each container carries its image reference, the
//! cgroup, its network attachment and the env/cmd resolved at create
//! time.

use super::cgroup::Cgroup;
use crate::util::ids::{ContainerId, MachineId};
use crate::vnet::addr::Ipv4;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum ContainerError {
    #[error("container {0}: invalid transition {1:?} -> {2:?}")]
    BadTransition(ContainerId, ContainerState, ContainerState),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerState {
    Created,
    Running,
    Paused,
    Exited,
}

/// A container instance.
#[derive(Debug, Clone)]
pub struct Container {
    pub id: ContainerId,
    pub name: String,
    pub image: String,
    pub machine: MachineId,
    pub state: ContainerState,
    pub cgroup: Cgroup,
    pub ip: Option<Ipv4>,
    pub env: Vec<(String, String)>,
    pub cmd: Vec<String>,
    pub exit_code: Option<i32>,
}

impl Container {
    pub fn new(
        id: ContainerId,
        name: impl Into<String>,
        image: impl Into<String>,
        machine: MachineId,
        cgroup: Cgroup,
    ) -> Self {
        Self {
            id,
            name: name.into(),
            image: image.into(),
            machine,
            state: ContainerState::Created,
            cgroup,
            ip: None,
            env: Vec::new(),
            cmd: Vec::new(),
            exit_code: None,
        }
    }

    fn transition(
        &mut self,
        from: &[ContainerState],
        to: ContainerState,
    ) -> Result<(), ContainerError> {
        if from.contains(&self.state) {
            self.state = to;
            Ok(())
        } else {
            Err(ContainerError::BadTransition(self.id, self.state, to))
        }
    }

    pub fn start(&mut self) -> Result<(), ContainerError> {
        self.transition(&[ContainerState::Created, ContainerState::Exited], ContainerState::Running)
    }

    pub fn pause(&mut self) -> Result<(), ContainerError> {
        self.transition(&[ContainerState::Running], ContainerState::Paused)
    }

    pub fn unpause(&mut self) -> Result<(), ContainerError> {
        self.transition(&[ContainerState::Paused], ContainerState::Running)
    }

    pub fn stop(&mut self, exit_code: i32) -> Result<(), ContainerError> {
        self.transition(
            &[ContainerState::Running, ContainerState::Paused],
            ContainerState::Exited,
        )?;
        self.exit_code = Some(exit_code);
        Ok(())
    }

    pub fn is_running(&self) -> bool {
        self.state == ContainerState::Running
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c() -> Container {
        Container::new(
            ContainerId::new(0),
            "node02",
            "nchc/mpi-computenode:latest",
            MachineId::new(1),
            Cgroup::new(12, 60 << 30).unwrap(),
        )
    }

    #[test]
    fn normal_lifecycle() {
        let mut c = c();
        assert_eq!(c.state, ContainerState::Created);
        c.start().unwrap();
        assert!(c.is_running());
        c.pause().unwrap();
        c.unpause().unwrap();
        c.stop(0).unwrap();
        assert_eq!(c.state, ContainerState::Exited);
        assert_eq!(c.exit_code, Some(0));
        // restart from Exited is allowed (docker start)
        c.start().unwrap();
        assert!(c.is_running());
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut c = c();
        assert!(c.pause().is_err()); // can't pause Created
        assert!(c.unpause().is_err());
        assert!(c.stop(0).is_err()); // can't stop Created
        c.start().unwrap();
        assert!(c.start().is_err()); // double start
    }
}
