//! A Docker-Hub-like registry (§II-B: "posted and shared in the Docker
//! Hub"): push/pull with content-addressed layer dedup and a transfer
//! cost model, so provisioning benches charge realistic pull times.

use super::image::{Image, ImageStore};
use super::layer::Digest;
use crate::hw::NicSpec;
use crate::sim::SimTime;
use std::collections::HashSet;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum RegistryError {
    #[error("image {0} not in registry")]
    NotFound(String),
}

/// Result of a pull: the image plus what it cost.
#[derive(Debug, Clone)]
pub struct PullReceipt {
    pub image: Image,
    pub layers_fetched: usize,
    pub layers_cached: usize,
    pub bytes_transferred: u64,
    pub transfer_time: SimTime,
}

/// The registry.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    store: ImageStore,
    pub pushes: u64,
    pub pulls: u64,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// A registry pre-seeded with the public base images.
    pub fn docker_hub() -> Self {
        Self { store: ImageStore::with_base_images(), pushes: 0, pulls: 0 }
    }

    pub fn push(&mut self, image: Image) {
        self.pushes += 1;
        self.store.insert(image);
    }

    pub fn contains(&self, reference: &str) -> bool {
        self.store.contains(reference)
    }

    pub fn references(&self) -> Vec<&str> {
        self.store.references()
    }

    /// Pull `reference` into `local`, skipping layers already present in
    /// any locally cached image (content-addressed dedup), charging the
    /// WAN/LAN transfer at `nic` speed.
    pub fn pull(
        &mut self,
        reference: &str,
        local: &mut ImageStore,
        nic: &NicSpec,
    ) -> Result<PullReceipt, RegistryError> {
        let image = self
            .store
            .get(reference)
            .ok_or_else(|| RegistryError::NotFound(reference.to_string()))?
            .clone();
        self.pulls += 1;

        let cached: HashSet<Digest> = local
            .references()
            .iter()
            .filter_map(|r| local.get(r))
            .flat_map(|img| img.layers.iter().map(|l| l.digest()))
            .collect();

        let mut bytes = 0u64;
        let mut fetched = 0usize;
        let mut cached_n = 0usize;
        for layer in &image.layers {
            if cached.contains(&layer.digest()) {
                cached_n += 1;
            } else {
                fetched += 1;
                bytes += layer.size_bytes();
            }
        }
        // One HTTP round trip per fetched layer + manifest, then stream.
        let msgs = fetched as u64 + 1;
        let transfer_time = SimTime::from_nanos(
            nic.message_time(0).as_nanos() * msgs,
        ) + nic.serialize_time(bytes);

        local.insert(image.clone());
        Ok(PullReceipt {
            image,
            layers_fetched: fetched,
            layers_cached: cached_n,
            bytes_transferred: bytes,
            transfer_time,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dockyard::dockerfile::Dockerfile;

    fn hub_with_paper_image() -> Registry {
        let mut hub = Registry::docker_hub();
        let mut builder = ImageStore::with_base_images();
        let df = Dockerfile::parse(Dockerfile::paper_compute_node()).unwrap();
        let img = builder.build(&df, "nchc/mpi-computenode:latest").unwrap();
        hub.push(img);
        hub
    }

    #[test]
    fn pull_fetches_all_layers_cold() {
        let mut hub = hub_with_paper_image();
        let mut local = ImageStore::new();
        let r = hub
            .pull("nchc/mpi-computenode:latest", &mut local, &NicSpec::ten_gbe())
            .unwrap();
        assert_eq!(r.layers_fetched, 4);
        assert_eq!(r.layers_cached, 0);
        assert!(r.bytes_transferred > 20_000_000);
        assert!(r.transfer_time > SimTime::ZERO);
        assert!(local.contains("nchc/mpi-computenode:latest"));
    }

    #[test]
    fn pull_dedups_shared_base_layers() {
        let mut hub = hub_with_paper_image();
        let mut local = ImageStore::with_base_images(); // already has centos:6
        let r = hub
            .pull("nchc/mpi-computenode:latest", &mut local, &NicSpec::ten_gbe())
            .unwrap();
        assert_eq!(r.layers_cached, 1, "base layer should be cached");
        assert_eq!(r.layers_fetched, 3);
    }

    #[test]
    fn second_pull_is_fully_cached() {
        let mut hub = hub_with_paper_image();
        let mut local = ImageStore::new();
        hub.pull("nchc/mpi-computenode:latest", &mut local, &NicSpec::ten_gbe())
            .unwrap();
        let r2 = hub
            .pull("nchc/mpi-computenode:latest", &mut local, &NicSpec::ten_gbe())
            .unwrap();
        assert_eq!(r2.layers_fetched, 0);
        assert_eq!(r2.bytes_transferred, 0);
    }

    #[test]
    fn pull_unknown_errors() {
        let mut hub = Registry::docker_hub();
        let mut local = ImageStore::new();
        assert_eq!(
            hub.pull("nope:latest", &mut local, &NicSpec::ten_gbe())
                .unwrap_err(),
            RegistryError::NotFound("nope:latest".into())
        );
    }

    #[test]
    fn slower_nic_pulls_slower() {
        let mut hub = hub_with_paper_image();
        let mut l1 = ImageStore::new();
        let mut l2 = ImageStore::new();
        let t10 = hub
            .pull("nchc/mpi-computenode:latest", &mut l1, &NicSpec::ten_gbe())
            .unwrap()
            .transfer_time;
        let t1 = hub
            .pull("nchc/mpi-computenode:latest", &mut l2, &NicSpec::one_gbe())
            .unwrap()
            .transfer_time;
        assert!(t1 > t10);
    }
}
