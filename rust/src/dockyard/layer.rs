//! Content-addressed image layers with union-filesystem semantics.
//!
//! A layer is a sorted manifest of file entries (path, size, content
//! hash) plus whiteouts (deletions), digested with SHA-256 — the same
//! observable model as Docker's UnionFS stack (§II-B): layers are
//! immutable, shared between images, and resolve top-down.

use sha2::{Digest as _, Sha256};
use std::collections::BTreeMap;
use std::fmt;

/// A SHA-256 content digest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Digest(pub [u8; 32]);

impl Digest {
    pub fn of_bytes(data: &[u8]) -> Self {
        let mut h = Sha256::new();
        h.update(data);
        Self(h.finalize().into())
    }

    pub fn short(&self) -> String {
        self.0[..6].iter().map(|b| format!("{b:02x}")).collect()
    }
}

impl fmt::Display for Digest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "sha256:")?;
        for b in &self.0 {
            write!(f, "{b:02x}")?;
        }
        Ok(())
    }
}

/// One file inside a layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileEntry {
    pub size: u64,
    pub content: Digest,
}

/// A filesystem layer: file manifest + whiteouts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    /// What produced this layer (`RUN yum install ...`).
    pub created_by: String,
    pub files: BTreeMap<String, FileEntry>,
    /// Paths deleted relative to lower layers (`.wh.` markers).
    pub whiteouts: Vec<String>,
}

impl Layer {
    pub fn new(created_by: impl Into<String>) -> Self {
        Self { created_by: created_by.into(), files: BTreeMap::new(), whiteouts: Vec::new() }
    }

    /// Add a synthetic file whose content hash derives from path+size.
    pub fn add_file(&mut self, path: impl Into<String>, size: u64) -> &mut Self {
        let path = path.into();
        let content = Digest::of_bytes(format!("{path}:{size}").as_bytes());
        self.files.insert(path, FileEntry { size, content });
        self
    }

    pub fn add_whiteout(&mut self, path: impl Into<String>) -> &mut Self {
        self.whiteouts.push(path.into());
        self.whiteouts.sort();
        self
    }

    /// Total byte size of the layer (what a pull transfers).
    pub fn size_bytes(&self) -> u64 {
        self.files.values().map(|f| f.size).sum()
    }

    /// The layer digest: hash of the canonicalized manifest.
    pub fn digest(&self) -> Digest {
        let mut h = Sha256::new();
        h.update(self.created_by.as_bytes());
        h.update([0]);
        for (path, e) in &self.files {
            h.update(path.as_bytes());
            h.update(e.size.to_le_bytes());
            h.update(e.content.0);
        }
        for w in &self.whiteouts {
            h.update(b".wh.");
            h.update(w.as_bytes());
        }
        Digest(h.finalize().into())
    }
}

/// Resolve a stack of layers (bottom..top) into the effective root fs.
pub fn resolve_union(layers: &[&Layer]) -> BTreeMap<String, FileEntry> {
    let mut fs = BTreeMap::new();
    for layer in layers {
        for w in &layer.whiteouts {
            // a whiteout removes the path and everything under it
            let prefix = format!("{w}/");
            fs.retain(|p: &String, _| p != w && !p.starts_with(&prefix));
        }
        for (path, entry) in &layer.files {
            fs.insert(path.clone(), entry.clone());
        }
    }
    fs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_is_content_addressed() {
        let mut a = Layer::new("RUN x");
        a.add_file("/bin/sh", 100);
        let mut b = Layer::new("RUN x");
        b.add_file("/bin/sh", 100);
        assert_eq!(a.digest(), b.digest());
        b.add_file("/bin/ls", 50);
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn digest_depends_on_provenance() {
        let mut a = Layer::new("RUN x");
        a.add_file("/f", 1);
        let mut b = Layer::new("RUN y");
        b.add_file("/f", 1);
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn digest_format() {
        let d = Layer::new("x").digest();
        let s = d.to_string();
        assert!(s.starts_with("sha256:"));
        assert_eq!(s.len(), 7 + 64);
        assert_eq!(d.short().len(), 12);
    }

    #[test]
    fn upper_layer_shadows_lower() {
        let mut base = Layer::new("base");
        base.add_file("/etc/conf", 10).add_file("/bin/sh", 100);
        let mut top = Layer::new("top");
        top.add_file("/etc/conf", 99);
        let fs = resolve_union(&[&base, &top]);
        assert_eq!(fs["/etc/conf"].size, 99);
        assert_eq!(fs["/bin/sh"].size, 100);
    }

    #[test]
    fn whiteout_removes_path_and_subtree() {
        let mut base = Layer::new("base");
        base.add_file("/opt/tool/bin", 5)
            .add_file("/opt/tool/lib", 7)
            .add_file("/opt/other", 1);
        let mut top = Layer::new("top");
        top.add_whiteout("/opt/tool");
        let fs = resolve_union(&[&base, &top]);
        assert!(!fs.contains_key("/opt/tool/bin"));
        assert!(!fs.contains_key("/opt/tool/lib"));
        assert!(fs.contains_key("/opt/other"));
    }

    #[test]
    fn whiteout_then_readd_in_same_layer() {
        let mut base = Layer::new("base");
        base.add_file("/x", 1);
        let mut top = Layer::new("top");
        top.add_whiteout("/x");
        top.add_file("/x", 2);
        let fs = resolve_union(&[&base, &top]);
        assert_eq!(fs["/x"].size, 2);
    }

    #[test]
    fn size_sums_files() {
        let mut l = Layer::new("x");
        l.add_file("/a", 10).add_file("/b", 32);
        assert_eq!(l.size_bytes(), 42);
    }

    #[test]
    fn union_resolution_is_order_sensitive() {
        let mut a = Layer::new("a");
        a.add_file("/f", 1);
        let mut b = Layer::new("b");
        b.add_file("/f", 2);
        assert_eq!(resolve_union(&[&a, &b])["/f"].size, 2);
        assert_eq!(resolve_union(&[&b, &a])["/f"].size, 1);
    }
}
