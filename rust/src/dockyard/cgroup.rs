//! cgroup model: CPU shares/quota and memory limits per container.
//!
//! The LXC-era primitives Docker wraps (§II-B). The simulator uses these
//! to (a) cap how many MPI slots a container advertises and (b) enforce
//! memory limits at allocation time.

use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum CgroupError {
    #[error("cpu quota must be > 0")]
    BadQuota,
    #[error("memory limit must be > 0")]
    BadMemory,
    #[error("memory limit exceeded: used {used} + req {req} > limit {limit}")]
    OverMemory { used: u64, req: u64, limit: u64 },
}

/// Per-container resource controller.
#[derive(Debug, Clone, PartialEq)]
pub struct Cgroup {
    /// Relative CPU weight (docker --cpu-shares, default 1024).
    pub cpu_shares: u32,
    /// Hard cap in whole cores (docker --cpus).
    pub cpu_quota_cores: u32,
    /// Memory limit in bytes (docker -m).
    pub memory_limit: u64,
    memory_used: u64,
}

impl Cgroup {
    pub fn new(cpu_quota_cores: u32, memory_limit: u64) -> Result<Self, CgroupError> {
        if cpu_quota_cores == 0 {
            return Err(CgroupError::BadQuota);
        }
        if memory_limit == 0 {
            return Err(CgroupError::BadMemory);
        }
        Ok(Self { cpu_shares: 1024, cpu_quota_cores, memory_limit, memory_used: 0 })
    }

    /// Charge an allocation against the memory limit (OOM-kill semantics:
    /// the caller decides what to do with the error).
    pub fn charge_memory(&mut self, bytes: u64) -> Result<(), CgroupError> {
        if self.memory_used + bytes > self.memory_limit {
            return Err(CgroupError::OverMemory {
                used: self.memory_used,
                req: bytes,
                limit: self.memory_limit,
            });
        }
        self.memory_used += bytes;
        Ok(())
    }

    pub fn uncharge_memory(&mut self, bytes: u64) {
        self.memory_used = self.memory_used.saturating_sub(bytes);
    }

    pub fn memory_used(&self) -> u64 {
        self.memory_used
    }

    /// Fair CPU share given sibling weights (the kernel's CFS rule).
    pub fn cpu_fraction(&self, sibling_shares_total: u32) -> f64 {
        if sibling_shares_total == 0 {
            1.0
        } else {
            self.cpu_shares as f64 / sibling_shares_total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_zero_limits() {
        assert_eq!(Cgroup::new(0, 1).unwrap_err(), CgroupError::BadQuota);
        assert_eq!(Cgroup::new(1, 0).unwrap_err(), CgroupError::BadMemory);
    }

    #[test]
    fn memory_ledger_enforced() {
        let mut cg = Cgroup::new(4, 1000).unwrap();
        cg.charge_memory(600).unwrap();
        cg.charge_memory(400).unwrap();
        assert!(matches!(
            cg.charge_memory(1),
            Err(CgroupError::OverMemory { .. })
        ));
        cg.uncharge_memory(500);
        cg.charge_memory(500).unwrap();
        assert_eq!(cg.memory_used(), 1000);
    }

    #[test]
    fn cpu_fraction_is_weighted() {
        let cg = Cgroup::new(4, 1).unwrap();
        assert!((cg.cpu_fraction(2048) - 0.5).abs() < 1e-12);
        assert_eq!(cg.cpu_fraction(0), 1.0);
    }
}
