//! Dockerfile parser for the instruction subset the paper's Fig. 2 uses
//! (and the rest of the common set): FROM, MAINTAINER, LABEL, ENV, RUN,
//! ADD, COPY, WORKDIR, EXPOSE, CMD, ENTRYPOINT, USER, VOLUME.
//!
//! Comments (`# ...`), blank lines and `\` line continuations are
//! handled. CMD/ENTRYPOINT accept both shell form and JSON-array exec
//! form (`CMD ["/usr/sbin/sshd", "-D"]`).

use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum DockerfileError {
    #[error("line {0}: empty instruction")]
    Empty(usize),
    #[error("line {0}: unknown instruction {1}")]
    Unknown(usize, String),
    #[error("line {0}: {1} requires arguments")]
    MissingArgs(usize, String),
    #[error("line {0}: first instruction must be FROM")]
    FromNotFirst(usize),
    #[error("line {0}: malformed exec-form array")]
    BadExecForm(usize),
    #[error("line {0}: ENV/LABEL requires key=value")]
    BadKeyValue(usize),
    #[error("line {0}: EXPOSE requires port numbers")]
    BadPort(usize),
}

/// One parsed instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum Instruction {
    From { image: String, tag: String },
    Maintainer(String),
    Label { key: String, value: String },
    Env { key: String, value: String },
    Run(String),
    Add { src: String, dst: String },
    Copy { src: String, dst: String },
    Workdir(String),
    Expose(u16),
    User(String),
    Volume(String),
    Cmd(Vec<String>),
    Entrypoint(Vec<String>),
}

/// A parsed Dockerfile.
#[derive(Debug, Clone, PartialEq)]
pub struct Dockerfile {
    pub instructions: Vec<Instruction>,
}

/// Parse an exec-form array `["a", "b"]` or fall back to shell form.
fn parse_cmd_args(line_no: usize, rest: &str) -> Result<Vec<String>, DockerfileError> {
    let trimmed = rest.trim();
    if trimmed.starts_with('[') {
        if !trimmed.ends_with(']') {
            return Err(DockerfileError::BadExecForm(line_no));
        }
        let inner = &trimmed[1..trimmed.len() - 1];
        let mut out = Vec::new();
        for part in split_json_strings(inner) {
            match part {
                Some(s) => out.push(s),
                None => return Err(DockerfileError::BadExecForm(line_no)),
            }
        }
        if out.is_empty() {
            return Err(DockerfileError::BadExecForm(line_no));
        }
        Ok(out)
    } else {
        // shell form runs through sh -c
        Ok(vec!["/bin/sh".into(), "-c".into(), trimmed.to_string()])
    }
}

/// Split `"a", "b"` into string items; yields None on malformed items.
fn split_json_strings(inner: &str) -> Vec<Option<String>> {
    let mut out = Vec::new();
    for item in inner.split(',') {
        let item = item.trim();
        if item.len() >= 2 && item.starts_with('"') && item.ends_with('"') {
            out.push(Some(item[1..item.len() - 1].to_string()));
        } else if item.is_empty() {
            continue;
        } else {
            out.push(None);
        }
    }
    out
}

impl Dockerfile {
    /// Parse a full Dockerfile text.
    pub fn parse(text: &str) -> Result<Self, DockerfileError> {
        // Fold continuations first, remembering original line numbers.
        let mut logical: Vec<(usize, String)> = Vec::new();
        let mut pending: Option<(usize, String)> = None;
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = raw.trim_end();
            let (start, mut acc) = match pending.take() {
                Some((s, a)) => (s, a),
                None => {
                    let t = line.trim_start();
                    if t.is_empty() || t.starts_with('#') {
                        continue;
                    }
                    (line_no, String::new())
                }
            };
            let body = line.trim_start();
            if let Some(stripped) = body.strip_suffix('\\') {
                acc.push_str(stripped.trim_end());
                acc.push(' ');
                pending = Some((start, acc));
            } else {
                acc.push_str(body);
                logical.push((start, acc));
            }
        }
        if let Some((start, acc)) = pending {
            logical.push((start, acc)); // trailing continuation: accept
        }

        let mut instructions = Vec::new();
        for (line_no, line) in logical {
            let mut parts = line.splitn(2, char::is_whitespace);
            let keyword = parts.next().ok_or(DockerfileError::Empty(line_no))?;
            let rest = parts.next().unwrap_or("").trim();
            let upper = keyword.to_ascii_uppercase();
            if instructions.is_empty() && upper != "FROM" {
                return Err(DockerfileError::FromNotFirst(line_no));
            }
            if rest.is_empty() {
                return Err(DockerfileError::MissingArgs(line_no, upper));
            }
            let inst = match upper.as_str() {
                "FROM" => {
                    let (image, tag) = match rest.split_once(':') {
                        Some((i, t)) => (i.to_string(), t.to_string()),
                        None => (rest.to_string(), "latest".to_string()),
                    };
                    Instruction::From { image, tag }
                }
                "MAINTAINER" => Instruction::Maintainer(rest.to_string()),
                "LABEL" | "ENV" => {
                    let (k, v) = match rest.split_once('=') {
                        Some((k, v)) => (k.trim(), v.trim()),
                        None => rest
                            .split_once(char::is_whitespace)
                            .map(|(k, v)| (k.trim(), v.trim()))
                            .ok_or(DockerfileError::BadKeyValue(line_no))?,
                    };
                    let v = v.trim_matches('"').to_string();
                    if upper == "ENV" {
                        Instruction::Env { key: k.to_string(), value: v }
                    } else {
                        Instruction::Label { key: k.to_string(), value: v }
                    }
                }
                "RUN" => Instruction::Run(rest.to_string()),
                "ADD" | "COPY" => {
                    let mut it = rest.split_whitespace();
                    let src = it
                        .next()
                        .ok_or_else(|| DockerfileError::MissingArgs(line_no, upper.clone()))?
                        .to_string();
                    let dst = it
                        .next()
                        .ok_or_else(|| DockerfileError::MissingArgs(line_no, upper.clone()))?
                        .to_string();
                    if upper == "ADD" {
                        Instruction::Add { src, dst }
                    } else {
                        Instruction::Copy { src, dst }
                    }
                }
                "WORKDIR" => Instruction::Workdir(rest.to_string()),
                "USER" => Instruction::User(rest.to_string()),
                "VOLUME" => Instruction::Volume(rest.trim_matches(['[', ']', '"']).to_string()),
                "EXPOSE" => {
                    let port: u16 = rest
                        .split('/')
                        .next()
                        .unwrap()
                        .parse()
                        .map_err(|_| DockerfileError::BadPort(line_no))?;
                    Instruction::Expose(port)
                }
                "CMD" => Instruction::Cmd(parse_cmd_args(line_no, rest)?),
                "ENTRYPOINT" => Instruction::Entrypoint(parse_cmd_args(line_no, rest)?),
                _ => return Err(DockerfileError::Unknown(line_no, keyword.to_string())),
            };
            instructions.push(inst);
        }
        if instructions.is_empty() {
            return Err(DockerfileError::Empty(0));
        }
        Ok(Self { instructions })
    }

    /// The base image reference.
    pub fn base(&self) -> Option<(&str, &str)> {
        match self.instructions.first() {
            Some(Instruction::From { image, tag }) => Some((image, tag)),
            _ => None,
        }
    }

    /// The paper's Fig. 2 Dockerfile, verbatim in spirit.
    pub fn paper_compute_node() -> &'static str {
        "\
FROM centos:6
MAINTAINER Hsi-En Yu <yun@narlabs.org.tw>

#install software
RUN yum install -y openssh-server openmpi
#install consul-template
ADD consul-template /usr/local/bin/consul-template
ADD consul /usr/local/bin/consul

CMD [\"/usr/sbin/sshd\", \"-D\"]
"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_papers_dockerfile() {
        let df = Dockerfile::parse(Dockerfile::paper_compute_node()).unwrap();
        assert_eq!(df.base(), Some(("centos", "6")));
        assert_eq!(df.instructions.len(), 6);
        assert_eq!(
            df.instructions[1],
            Instruction::Maintainer("Hsi-En Yu <yun@narlabs.org.tw>".into())
        );
        assert!(matches!(df.instructions[2], Instruction::Run(_)));
        assert_eq!(
            df.instructions[3],
            Instruction::Add {
                src: "consul-template".into(),
                dst: "/usr/local/bin/consul-template".into()
            }
        );
        assert_eq!(
            df.instructions[5],
            Instruction::Cmd(vec!["/usr/sbin/sshd".into(), "-D".into()])
        );
    }

    #[test]
    fn from_must_be_first() {
        let err = Dockerfile::parse("RUN echo hi\nFROM x").unwrap_err();
        assert_eq!(err, DockerfileError::FromNotFirst(1));
    }

    #[test]
    fn default_tag_is_latest() {
        let df = Dockerfile::parse("FROM centos").unwrap();
        assert_eq!(df.base(), Some(("centos", "latest")));
    }

    #[test]
    fn line_continuations_fold() {
        let df = Dockerfile::parse("FROM a\nRUN yum install -y \\\n  openssh-server \\\n  openmpi").unwrap();
        match &df.instructions[1] {
            Instruction::Run(cmd) => {
                assert!(cmd.contains("openssh-server"));
                assert!(cmd.contains("openmpi"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn shell_form_cmd_wraps_in_sh() {
        let df = Dockerfile::parse("FROM a\nCMD sshd -D").unwrap();
        assert_eq!(
            df.instructions[1],
            Instruction::Cmd(vec!["/bin/sh".into(), "-c".into(), "sshd -D".into()])
        );
    }

    #[test]
    fn malformed_exec_form_errors() {
        assert_eq!(
            Dockerfile::parse("FROM a\nCMD [\"x\", nope]").unwrap_err(),
            DockerfileError::BadExecForm(2)
        );
        assert_eq!(
            Dockerfile::parse("FROM a\nCMD [\"x\"").unwrap_err(),
            DockerfileError::BadExecForm(2)
        );
    }

    #[test]
    fn env_and_label_forms() {
        let df = Dockerfile::parse("FROM a\nENV PATH=/usr/bin\nLABEL role hpc").unwrap();
        assert_eq!(
            df.instructions[1],
            Instruction::Env { key: "PATH".into(), value: "/usr/bin".into() }
        );
        assert_eq!(
            df.instructions[2],
            Instruction::Label { key: "role".into(), value: "hpc".into() }
        );
    }

    #[test]
    fn expose_parses_port() {
        let df = Dockerfile::parse("FROM a\nEXPOSE 22/tcp").unwrap();
        assert_eq!(df.instructions[1], Instruction::Expose(22));
        assert!(Dockerfile::parse("FROM a\nEXPOSE ssh").is_err());
    }

    #[test]
    fn unknown_instruction_errors() {
        assert!(matches!(
            Dockerfile::parse("FROM a\nFOO bar").unwrap_err(),
            DockerfileError::Unknown(2, _)
        ));
    }

    #[test]
    fn comments_and_blanks_skipped() {
        let df = Dockerfile::parse("# hi\n\nFROM a\n# mid\nRUN x\n").unwrap();
        assert_eq!(df.instructions.len(), 2);
    }
}
