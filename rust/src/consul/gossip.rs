//! SWIM-style gossip membership (what consul's LAN serf layer does).
//!
//! Every protocol period a member probes one random peer; a missing ack
//! triggers indirect probes through `k` relays, then suspicion, then
//! death. Membership updates (join/suspect/dead/alive) piggyback on all
//! probe traffic with a retransmit budget of `λ·log₂(n)`, giving the
//! O(log n) dissemination the Fig. 7 bench measures.
//!
//! Pure state machine: `tick`/`on_message` return `(to, Msg)` batches;
//! the driver owns delivery, delay and loss.

use crate::sim::SimTime;
use crate::util::ids::AgentId;
use crate::util::Rng;
use std::collections::HashMap;

/// Health state of a member, with SWIM incarnation numbers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemberState {
    Alive,
    Suspect,
    Dead,
}

/// A disseminated update.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Update {
    pub agent: AgentId,
    pub state: MemberState,
    pub incarnation: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    Ping { updates: Vec<Update> },
    Ack { updates: Vec<Update> },
    /// Ask `via` to probe `target` on our behalf.
    PingReq { target: AgentId, updates: Vec<Update> },
    /// Relay result for an indirect probe.
    IndirectAck { target: AgentId, updates: Vec<Update> },
    /// Push-pull anti-entropy (serf's periodic full state sync): the
    /// sender's complete membership view.
    SyncReq { state: Vec<Update> },
    SyncResp { state: Vec<Update> },
}

#[derive(Debug, Clone)]
struct MemberInfo {
    state: MemberState,
    incarnation: u64,
    /// When the member entered Suspect (for the suspicion timeout).
    suspect_since: SimTime,
}

#[derive(Debug, Clone)]
struct PendingProbe {
    target: AgentId,
    sent_at: SimTime,
    indirect: bool,
}

/// One gossip member (a consul agent).
pub struct GossipNode {
    pub id: AgentId,
    members: HashMap<AgentId, MemberInfo>,
    incarnation: u64,
    /// Updates queued for piggybacking: (update, remaining retransmits).
    outbox: Vec<(Update, u32)>,
    probe: Option<PendingProbe>,
    /// Indirect-probe relays we owe an answer: target -> requesters.
    pending_relays: HashMap<AgentId, Vec<AgentId>>,
    next_probe_at: SimTime,
    next_sync_at: SimTime,
    sync_round: u64,
    rng: Rng,
    pub protocol_period: SimTime,
    pub ack_timeout: SimTime,
    pub suspicion_timeout: SimTime,
    /// Push-pull full-state sync cadence (serf defaults to 30s; we use
    /// 10s — the paper-scale clusters are small).
    pub sync_interval: SimTime,
    pub indirect_relays: usize,
    /// λ in the retransmit budget λ·log2(n).
    pub retransmit_mult: u32,
}

impl GossipNode {
    pub fn new(id: AgentId, seed: u64) -> Self {
        Self {
            id,
            members: HashMap::new(),
            incarnation: 0,
            outbox: Vec::new(),
            probe: None,
            pending_relays: HashMap::new(),
            next_probe_at: SimTime::ZERO,
            next_sync_at: SimTime::ZERO,
            sync_round: 0,
            rng: Rng::new(seed ^ ((id.raw() as u64 + 1) * 0xA5A5)),
            protocol_period: SimTime::from_millis(1000),
            ack_timeout: SimTime::from_millis(300),
            suspicion_timeout: SimTime::from_millis(3000),
            sync_interval: SimTime::from_millis(10_000),
            indirect_relays: 3,
            retransmit_mult: 3,
        }
    }

    /// Members (including self is NOT tracked here).
    pub fn alive_members(&self) -> Vec<AgentId> {
        let mut v: Vec<AgentId> = self
            .members
            .iter() // lint: sorted
            .filter(|(_, m)| m.state == MemberState::Alive)
            .map(|(&a, _)| a)
            .collect();
        v.sort();
        v
    }

    pub fn member_state(&self, a: AgentId) -> Option<MemberState> {
        self.members.get(&a).map(|m| m.state)
    }

    pub fn member_count(&self) -> usize {
        self.members.len()
    }

    fn retransmit_budget(&self) -> u32 {
        let n = (self.members.len() + 1).max(2) as f64;
        self.retransmit_mult * (n.log2().ceil() as u32).max(1)
    }

    fn queue_update(&mut self, u: Update) {
        let budget = self.retransmit_budget();
        self.outbox.push((u, budget));
    }

    /// Take up to `max` piggyback updates, decrementing budgets.
    fn take_piggyback(&mut self, max: usize) -> Vec<Update> {
        let mut out = Vec::new();
        for (u, remaining) in self.outbox.iter_mut() {
            if out.len() >= max {
                break;
            }
            if *remaining > 0 {
                out.push(*u);
                *remaining -= 1;
            }
        }
        self.outbox.retain(|(_, r)| *r > 0);
        out
    }

    /// Join via a seed member: learn it, announce ourselves.
    pub fn join(&mut self, seed: AgentId, now: SimTime) -> Vec<(AgentId, Msg)> {
        self.members.insert(
            seed,
            MemberInfo { state: MemberState::Alive, incarnation: 0, suspect_since: SimTime::ZERO },
        );
        self.queue_update(Update {
            agent: self.id,
            state: MemberState::Alive,
            incarnation: self.incarnation,
        });
        self.next_probe_at = now; // probe immediately
        let updates = self.take_piggyback(8);
        vec![(seed, Msg::Ping { updates })]
    }

    /// Merge a received update per SWIM precedence rules.
    fn apply_update(&mut self, u: Update, now: SimTime) {
        if u.agent == self.id {
            // refute suspicion about ourselves with a higher incarnation
            if u.state != MemberState::Alive && u.incarnation >= self.incarnation {
                self.incarnation = u.incarnation + 1;
                self.queue_update(Update {
                    agent: self.id,
                    state: MemberState::Alive,
                    incarnation: self.incarnation,
                });
            }
            return;
        }
        let entry = self.members.get(&u.agent);
        let accept = match entry {
            None => true,
            Some(m) => {
                u.incarnation > m.incarnation
                    || (u.incarnation == m.incarnation && rank(u.state) > rank(m.state))
            }
        };
        fn rank(s: MemberState) -> u8 {
            match s {
                MemberState::Alive => 0,
                MemberState::Suspect => 1,
                MemberState::Dead => 2,
            }
        }
        if accept {
            let changed = entry.map(|m| (m.state, m.incarnation)) != Some((u.state, u.incarnation));
            self.members.insert(
                u.agent,
                MemberInfo {
                    state: u.state,
                    incarnation: u.incarnation,
                    suspect_since: now,
                },
            );
            if changed {
                self.queue_update(u); // keep disseminating
            }
        }
    }

    fn apply_updates(&mut self, updates: Vec<Update>, now: SimTime) {
        for u in updates {
            self.apply_update(u, now);
        }
    }

    fn random_member(&mut self, state: MemberState, exclude: &[AgentId]) -> Option<AgentId> {
        let mut candidates: Vec<AgentId> = self
            .members
            .iter() // lint: sorted
            .filter(|(a, m)| m.state == state && !exclude.contains(a))
            .map(|(&a, _)| a)
            .collect();
        // Sort before the seeded draw: hash order would make the pick
        // differ across processes even with identical RNG state.
        candidates.sort();
        self.rng.choose(&candidates).copied()
    }

    /// Periodic driver hook.
    pub fn tick(&mut self, now: SimTime) -> Vec<(AgentId, Msg)> {
        let mut out = Vec::new();

        // 1. expire suspicions
        let mut expired: Vec<AgentId> = self
            .members
            .iter() // lint: sorted
            .filter(|(_, m)| {
                m.state == MemberState::Suspect
                    && now.saturating_sub(m.suspect_since) >= self.suspicion_timeout
            })
            .map(|(&a, _)| a)
            .collect();
        expired.sort();
        for a in expired {
            let inc = self.members[&a].incarnation;
            self.apply_update(
                Update { agent: a, state: MemberState::Dead, incarnation: inc },
                now,
            );
        }

        // 2. probe timeout -> indirect probe, then suspicion
        if let Some(p) = self.probe.clone() {
            if now.saturating_sub(p.sent_at) >= self.ack_timeout {
                if !p.indirect {
                    // fan out ping-reqs through k relays
                    let mut relays = Vec::new();
                    for _ in 0..self.indirect_relays {
                        if let Some(r) =
                            self.random_member(MemberState::Alive, &[p.target])
                        {
                            if !relays.contains(&r) {
                                relays.push(r);
                            }
                        }
                    }
                    if relays.is_empty() {
                        self.suspect(p.target, now);
                        self.probe = None;
                    } else {
                        for r in relays {
                            let updates = self.take_piggyback(6);
                            out.push((r, Msg::PingReq { target: p.target, updates }));
                        }
                        self.probe = Some(PendingProbe { indirect: true, sent_at: now, ..p });
                    }
                } else {
                    self.suspect(p.target, now);
                    self.probe = None;
                }
            }
        }

        // 3. push-pull anti-entropy each sync interval
        if now >= self.next_sync_at {
            self.next_sync_at = now + self.sync_interval;
            self.sync_round += 1;
            if let Some(peer) = self.random_member(MemberState::Alive, &[]) {
                out.push((peer, Msg::SyncReq { state: self.full_state() }));
            }
            // serf-style reconnect: every few rounds, also push-pull with
            // a member we believe dead. A crashed member drops the probe;
            // a partitioned one answers once the network heals, and the
            // exchanged states re-merge the two sides (each side learns
            // it was declared dead and refutes with a higher incarnation).
            // Without this, two fully split halves would stay split
            // forever — neither side gossips toward "dead" members.
            if self.sync_round % 3 == 0 {
                if let Some(peer) = self.random_member(MemberState::Dead, &[]) {
                    out.push((peer, Msg::SyncReq { state: self.full_state() }));
                }
            }
        }

        // 4. new probe each protocol period
        if now >= self.next_probe_at {
            self.next_probe_at = now + self.protocol_period;
            if self.probe.is_none() {
                if let Some(target) = self.random_member(MemberState::Alive, &[]) {
                    self.probe = Some(PendingProbe { target, sent_at: now, indirect: false });
                    let updates = self.take_piggyback(6);
                    out.push((target, Msg::Ping { updates }));
                }
            }
        }
        out
    }

    /// Complete membership snapshot (incl. self) for push-pull sync.
    fn full_state(&self) -> Vec<Update> {
        let mut state: Vec<Update> = self
            .members
            .iter() // lint: sorted
            .map(|(&agent, m)| Update { agent, state: m.state, incarnation: m.incarnation })
            .collect();
        // Deterministic sync payload order regardless of hash seed.
        state.sort_by_key(|u| u.agent);
        state.push(Update {
            agent: self.id,
            state: MemberState::Alive,
            incarnation: self.incarnation,
        });
        state
    }

    fn suspect(&mut self, target: AgentId, now: SimTime) {
        if let Some(m) = self.members.get(&target) {
            if m.state == MemberState::Alive {
                let inc = m.incarnation;
                self.apply_update(
                    Update { agent: target, state: MemberState::Suspect, incarnation: inc },
                    now,
                );
            }
        }
    }

    pub fn on_message(&mut self, now: SimTime, from: AgentId, msg: Msg) -> Vec<(AgentId, Msg)> {
        // hearing from someone proves they are alive
        let heard = Update {
            agent: from,
            state: MemberState::Alive,
            incarnation: self
                .members
                .get(&from)
                .map(|m| m.incarnation)
                .unwrap_or(0),
        };
        self.apply_update(heard, now);
        match msg {
            Msg::Ping { updates } => {
                self.apply_updates(updates, now);
                let reply = self.take_piggyback(6);
                vec![(from, Msg::Ack { updates: reply })]
            }
            Msg::Ack { updates } => {
                self.apply_updates(updates, now);
                if let Some(p) = &self.probe {
                    if p.target == from {
                        self.probe = None;
                    }
                }
                // if we probed `from` on someone's behalf, relay the ack
                let mut out = Vec::new();
                if let Some(requesters) = self.pending_relays.remove(&from) {
                    for r in requesters {
                        let updates = self.take_piggyback(4);
                        out.push((r, Msg::IndirectAck { target: from, updates }));
                    }
                }
                out
            }
            Msg::PingReq { target, updates } => {
                self.apply_updates(updates, now);
                // probe the target on the requester's behalf; the
                // IndirectAck goes out only when the target acks us.
                self.pending_relays.entry(target).or_default().push(from);
                let fwd = self.take_piggyback(6);
                vec![(target, Msg::Ping { updates: fwd })]
            }
            Msg::IndirectAck { target, updates } => {
                self.apply_updates(updates, now);
                if let Some(p) = &self.probe {
                    if p.target == target {
                        self.probe = None;
                    }
                }
                Vec::new()
            }
            Msg::SyncReq { state } => {
                let mine = self.full_state();
                self.apply_updates(state, now);
                vec![(from, Msg::SyncResp { state: mine })]
            }
            Msg::SyncResp { state } => {
                self.apply_updates(state, now);
                Vec::new()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::VecDeque;

    /// Deterministic driver with uniform delay and optional per-agent drop.
    struct Net {
        nodes: Vec<GossipNode>,
        now: SimTime,
        inflight: VecDeque<(SimTime, AgentId, AgentId, Msg)>,
        delay: SimTime,
        dead: Vec<AgentId>, // crashed agents: drop all their traffic
        /// Partitioned agents: traffic crossing the split is dropped
        /// (both directions), same-side traffic flows.
        partition: Vec<AgentId>,
    }

    impl Net {
        fn new(n: u32, seed: u64) -> Self {
            let nodes = (0..n).map(|i| GossipNode::new(AgentId::new(i), seed)).collect();
            Self {
                nodes,
                now: SimTime::ZERO,
                inflight: VecDeque::new(),
                delay: SimTime::from_micros(200),
                dead: Vec::new(),
                partition: Vec::new(),
            }
        }

        fn boot_all_via_seed(&mut self) {
            for i in 1..self.nodes.len() {
                let now = self.now;
                let msgs = self.nodes[i].join(AgentId::new(0), now);
                self.send(AgentId::new(i as u32), msgs);
            }
        }

        fn send(&mut self, from: AgentId, msgs: Vec<(AgentId, Msg)>) {
            for (to, m) in msgs {
                self.inflight.push_back((self.now + self.delay, from, to, m));
            }
        }

        fn run(&mut self, steps: u32, step: SimTime) {
            for _ in 0..steps {
                self.now = self.now + step;
                let mut due = Vec::new();
                while let Some(&(at, ..)) = self.inflight.front() {
                    if at <= self.now {
                        due.push(self.inflight.pop_front().unwrap());
                    } else {
                        break;
                    }
                }
                for (_, from, to, msg) in due {
                    if self.dead.contains(&to) || self.dead.contains(&from) {
                        continue;
                    }
                    if self.partition.contains(&from) != self.partition.contains(&to) {
                        continue; // message crosses the split
                    }
                    let now = self.now;
                    let out = self.nodes[to.raw() as usize].on_message(now, from, msg);
                    self.send(to, out);
                }
                for i in 0..self.nodes.len() {
                    let id = AgentId::new(i as u32);
                    if self.dead.contains(&id) {
                        continue;
                    }
                    let now = self.now;
                    let out = self.nodes[i].tick(now);
                    self.send(id, out);
                }
            }
        }

        /// Does every live node see every other live node as Alive?
        fn converged(&self) -> bool {
            let live: Vec<AgentId> = (0..self.nodes.len() as u32)
                .map(AgentId::new)
                .filter(|a| !self.dead.contains(a))
                .collect();
            for &a in &live {
                let n = &self.nodes[a.raw() as usize];
                for &b in &live {
                    if a != b && n.member_state(b) != Some(MemberState::Alive) {
                        return false;
                    }
                }
            }
            true
        }
    }

    #[test]
    fn membership_converges() {
        for n in [3u32, 8, 16] {
            let mut net = Net::new(n, 42);
            net.boot_all_via_seed();
            net.run(30_000, SimTime::from_millis(10)); // 5 min sim
            assert!(net.converged(), "n={n} did not converge");
        }
    }

    #[test]
    fn convergence_time_scales_sublinearly() {
        // Fig. 7's shape: time-to-converge grows ~log n, not ~n.
        let time_to_converge = |n: u32| -> f64 {
            let mut net = Net::new(n, 7);
            net.boot_all_via_seed();
            for step in 0..60_000u32 {
                net.run(1, SimTime::from_millis(10));
                if net.converged() {
                    return (step as f64) * 0.01;
                }
            }
            panic!("n={n} never converged");
        };
        let t4 = time_to_converge(4);
        let t32 = time_to_converge(32);
        // SWIM disseminates in O(log n) protocol periods (1s each here):
        // 32 nodes should converge within ~2·λ·log2(32) periods, nowhere
        // near linear-in-n.
        assert!(t4 < 5.0, "t4={t4}s");
        assert!(t32 < 32.0, "t32={t32}s (linear or worse)");
    }

    #[test]
    fn crashed_member_is_eventually_dead() {
        let mut net = Net::new(6, 13);
        net.boot_all_via_seed();
        net.run(20_000, SimTime::from_millis(10));
        assert!(net.converged());
        let victim = AgentId::new(3);
        net.dead.push(victim);
        net.run(60_000, SimTime::from_millis(10));
        for i in 0..6u32 {
            if i == 3 {
                continue;
            }
            let st = net.nodes[i as usize].member_state(victim);
            assert!(
                matches!(st, Some(MemberState::Dead) | Some(MemberState::Suspect)),
                "node {i} still sees victim as {st:?}"
            );
        }
    }

    #[test]
    fn partitioned_halves_remerge_after_heal() {
        let mut net = Net::new(6, 17);
        net.boot_all_via_seed();
        net.run(20_000, SimTime::from_millis(10));
        assert!(net.converged());
        // split {4, 5} off; each side declares the other dead
        net.partition = vec![AgentId::new(4), AgentId::new(5)];
        net.run(60_000, SimTime::from_millis(10)); // 10 min split
        let a0 = &net.nodes[0];
        assert!(
            matches!(a0.member_state(AgentId::new(4)), Some(MemberState::Dead)),
            "majority side must declare the minority dead, got {:?}",
            a0.member_state(AgentId::new(4))
        );
        let a4 = &net.nodes[4];
        assert!(
            matches!(a4.member_state(AgentId::new(0)), Some(MemberState::Dead)),
            "minority side must declare the majority dead"
        );
        // heal: the periodic dead-member reconnect sync re-merges the
        // views (incarnation bumps refute the stale Dead declarations)
        net.partition.clear();
        net.run(30_000, SimTime::from_millis(10)); // 5 min to re-merge
        assert!(net.converged(), "halves never re-merged after the partition healed");
    }

    #[test]
    fn update_precedence_rules() {
        let mut n = GossipNode::new(AgentId::new(0), 1);
        let a = AgentId::new(1);
        let now = SimTime::from_secs(1);
        n.apply_update(Update { agent: a, state: MemberState::Alive, incarnation: 2 }, now);
        // older incarnation loses
        n.apply_update(Update { agent: a, state: MemberState::Dead, incarnation: 1 }, now);
        assert_eq!(n.member_state(a), Some(MemberState::Alive));
        // same incarnation: suspect beats alive
        n.apply_update(Update { agent: a, state: MemberState::Suspect, incarnation: 2 }, now);
        assert_eq!(n.member_state(a), Some(MemberState::Suspect));
        // higher incarnation alive refutes
        n.apply_update(Update { agent: a, state: MemberState::Alive, incarnation: 3 }, now);
        assert_eq!(n.member_state(a), Some(MemberState::Alive));
    }

    #[test]
    fn self_suspicion_is_refuted() {
        let mut n = GossipNode::new(AgentId::new(0), 1);
        let now = SimTime::from_secs(1);
        n.apply_update(
            Update { agent: AgentId::new(0), state: MemberState::Suspect, incarnation: 0 },
            now,
        );
        // incarnation bumped and an alive update queued
        assert_eq!(n.incarnation, 1);
        let pig = n.take_piggyback(8);
        assert!(pig
            .iter()
            .any(|u| u.agent == AgentId::new(0) && u.state == MemberState::Alive && u.incarnation == 1));
    }
}
