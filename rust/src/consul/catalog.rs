//! Service catalog: register/deregister/list service instances.
//!
//! Stored in the KV under `service/<name>/<node>` so it rides the Raft
//! replication for free (consul does the same internally). Entries
//! encode address/port/tags in a flat `k=v;` format — no serde offline.

use super::kv::KvStore;
use super::raft::Command;
use crate::vnet::addr::Ipv4;

/// One registered service instance.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceEntry {
    pub node: String,
    pub address: Ipv4,
    pub port: u16,
    /// MPI slots advertised by the node (meta field the hostfile uses).
    pub slots: u32,
    pub tags: Vec<String>,
}

impl ServiceEntry {
    fn encode(&self) -> String {
        format!(
            "addr={};port={};slots={};tags={}",
            self.address,
            self.port,
            self.slots,
            self.tags.join(",")
        )
    }

    fn decode(node: &str, s: &str) -> Option<Self> {
        let mut address = None;
        let mut port = None;
        let mut slots = 1u32;
        let mut tags = Vec::new();
        for part in s.split(';') {
            let (k, v) = part.split_once('=')?;
            match k {
                "addr" => address = Ipv4::parse(v).ok(),
                "port" => port = v.parse().ok(),
                "slots" => slots = v.parse().ok()?,
                "tags" => {
                    tags = v
                        .split(',')
                        .filter(|t| !t.is_empty())
                        .map(str::to_string)
                        .collect()
                }
                _ => {}
            }
        }
        Some(Self { node: node.to_string(), address: address?, port: port?, slots, tags })
    }
}

/// Catalog operations expressed as raft commands + kv reads.
pub struct Catalog;

impl Catalog {
    fn key(service: &str, node: &str) -> String {
        format!("service/{service}/{node}")
    }

    /// The command that registers an instance.
    pub fn register_cmd(service: &str, entry: &ServiceEntry) -> Command {
        Command::Set { key: Self::key(service, &entry.node), value: entry.encode() }
    }

    /// The command that deregisters an instance.
    pub fn deregister_cmd(service: &str, node: &str) -> Command {
        Command::Delete { key: Self::key(service, node) }
    }

    /// List instances of a service, sorted by node name.
    pub fn list(kv: &KvStore, service: &str) -> Vec<ServiceEntry> {
        let prefix = format!("service/{service}/");
        kv.list_prefix(&prefix)
            .into_iter()
            .filter_map(|(k, v)| {
                let node = &k[prefix.len()..];
                ServiceEntry::decode(node, v)
            })
            .collect()
    }

    /// Watch cursor for a service (changes when membership changes).
    pub fn watch_index(kv: &KvStore, service: &str) -> u64 {
        kv.prefix_index(&format!("service/{service}/"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(node: &str, last_octet: u8, slots: u32) -> ServiceEntry {
        ServiceEntry {
            node: node.into(),
            address: Ipv4::new(10, 10, 0, last_octet),
            port: 22,
            slots,
            tags: vec!["hpc".into(), "mpi".into()],
        }
    }

    #[test]
    fn register_list_roundtrip() {
        let mut kv = KvStore::new();
        kv.apply(&Catalog::register_cmd("hpc", &entry("node03", 3, 12)));
        kv.apply(&Catalog::register_cmd("hpc", &entry("node02", 2, 12)));
        let list = Catalog::list(&kv, "hpc");
        assert_eq!(list.len(), 2);
        assert_eq!(list[0].node, "node02"); // sorted
        assert_eq!(list[0].address, Ipv4::new(10, 10, 0, 2));
        assert_eq!(list[0].slots, 12);
        assert_eq!(list[0].tags, vec!["hpc", "mpi"]);
    }

    #[test]
    fn deregister_removes() {
        let mut kv = KvStore::new();
        kv.apply(&Catalog::register_cmd("hpc", &entry("node02", 2, 1)));
        kv.apply(&Catalog::deregister_cmd("hpc", "node02"));
        assert!(Catalog::list(&kv, "hpc").is_empty());
    }

    #[test]
    fn services_are_namespaced() {
        let mut kv = KvStore::new();
        kv.apply(&Catalog::register_cmd("hpc", &entry("a", 2, 1)));
        kv.apply(&Catalog::register_cmd("web", &entry("b", 3, 1)));
        assert_eq!(Catalog::list(&kv, "hpc").len(), 1);
        assert_eq!(Catalog::list(&kv, "web").len(), 1);
    }

    #[test]
    fn watch_index_bumps_on_membership_change() {
        let mut kv = KvStore::new();
        kv.apply(&Catalog::register_cmd("hpc", &entry("a", 2, 1)));
        let i1 = Catalog::watch_index(&kv, "hpc");
        kv.apply(&Catalog::register_cmd("web", &entry("x", 9, 1)));
        assert_eq!(Catalog::watch_index(&kv, "hpc"), i1, "other service must not wake hpc watchers");
        kv.apply(&Catalog::register_cmd("hpc", &entry("b", 3, 1)));
        assert!(Catalog::watch_index(&kv, "hpc") > i1);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(ServiceEntry::decode("n", "not-a-record").is_none());
        assert!(ServiceEntry::decode("n", "addr=999.1.1.1;port=22").is_none());
    }
}
