//! The replicated key/value store applied from the Raft log.
//!
//! Versioned like consul's: a global `ModifyIndex` bumps on every write,
//! and each key remembers the index of its last change. Watchers (the
//! template engine) poll `modify_index()` — consul's blocking query,
//! collapsed to its observable effect.

use super::raft::Command;
use std::collections::BTreeMap;

/// One stored value.
#[derive(Debug, Clone, PartialEq)]
pub struct KvEntry {
    pub value: String,
    pub modify_index: u64,
}

/// The state machine.
#[derive(Debug, Clone, Default)]
pub struct KvStore {
    data: BTreeMap<String, KvEntry>,
    modify_index: u64,
}

impl KvStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply a committed raft command.
    pub fn apply(&mut self, cmd: &Command) {
        match cmd {
            Command::Set { key, value } => {
                self.modify_index += 1;
                self.data.insert(
                    key.clone(),
                    KvEntry { value: value.clone(), modify_index: self.modify_index },
                );
            }
            Command::Delete { key } => {
                if self.data.remove(key).is_some() {
                    self.modify_index += 1;
                }
            }
            Command::Cas { key, expected, value } => {
                // applied on every replica in log order, so the same
                // single attempt wins everywhere
                let current = self.data.get(key).map(|e| e.value.as_str());
                if current == expected.as_deref() {
                    self.modify_index += 1;
                    self.data.insert(
                        key.clone(),
                        KvEntry { value: value.clone(), modify_index: self.modify_index },
                    );
                }
            }
            Command::Noop => {}
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.data.get(key).map(|e| e.value.as_str())
    }

    pub fn entry(&self, key: &str) -> Option<&KvEntry> {
        self.data.get(key)
    }

    /// All pairs under a prefix, sorted by key.
    pub fn list_prefix(&self, prefix: &str) -> Vec<(&str, &str)> {
        self.data
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(k, e)| (k.as_str(), e.value.as_str()))
            .collect()
    }

    /// Highest modify index under a prefix (watch cursor).
    pub fn prefix_index(&self, prefix: &str) -> u64 {
        self.data
            .range(prefix.to_string()..)
            .take_while(|(k, _)| k.starts_with(prefix))
            .map(|(_, e)| e.modify_index)
            .max()
            .unwrap_or(0)
    }

    /// Global modify index.
    pub fn modify_index(&self) -> u64 {
        self.modify_index
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(kv: &mut KvStore, k: &str, v: &str) {
        kv.apply(&Command::Set { key: k.into(), value: v.into() });
    }

    #[test]
    fn set_get_delete() {
        let mut kv = KvStore::new();
        set(&mut kv, "a", "1");
        assert_eq!(kv.get("a"), Some("1"));
        set(&mut kv, "a", "2");
        assert_eq!(kv.get("a"), Some("2"));
        kv.apply(&Command::Delete { key: "a".into() });
        assert_eq!(kv.get("a"), None);
    }

    #[test]
    fn modify_index_monotonic() {
        let mut kv = KvStore::new();
        set(&mut kv, "a", "1");
        let i1 = kv.modify_index();
        set(&mut kv, "b", "1");
        let i2 = kv.modify_index();
        assert!(i2 > i1);
        // delete of a missing key does NOT bump the index
        kv.apply(&Command::Delete { key: "zz".into() });
        assert_eq!(kv.modify_index(), i2);
        kv.apply(&Command::Noop);
        assert_eq!(kv.modify_index(), i2);
    }

    #[test]
    fn prefix_listing_sorted() {
        let mut kv = KvStore::new();
        set(&mut kv, "service/hpc/node03", "10.10.0.3");
        set(&mut kv, "service/hpc/node02", "10.10.0.2");
        set(&mut kv, "service/web/x", "1.2.3.4");
        let hpc = kv.list_prefix("service/hpc/");
        assert_eq!(
            hpc,
            vec![
                ("service/hpc/node02", "10.10.0.2"),
                ("service/hpc/node03", "10.10.0.3")
            ]
        );
    }

    fn cas(kv: &mut KvStore, k: &str, expected: Option<&str>, v: &str) {
        kv.apply(&Command::Cas {
            key: k.into(),
            expected: expected.map(String::from),
            value: v.into(),
        });
    }

    #[test]
    fn cas_applies_only_on_exact_match() {
        let mut kv = KvStore::new();
        // expected None = key must be absent
        cas(&mut kv, "lock", None, "holder-a");
        assert_eq!(kv.get("lock"), Some("holder-a"));
        // a second create-style CAS loses
        cas(&mut kv, "lock", None, "holder-b");
        assert_eq!(kv.get("lock"), Some("holder-a"));
        // wrong expected value loses, right one wins
        cas(&mut kv, "lock", Some("nope"), "holder-c");
        assert_eq!(kv.get("lock"), Some("holder-a"));
        cas(&mut kv, "lock", Some("holder-a"), "holder-d");
        assert_eq!(kv.get("lock"), Some("holder-d"));
    }

    #[test]
    fn racing_cas_batch_has_exactly_one_winner() {
        // the raft log totally orders commands; applying the same batch
        // on any replica leaves the first matching CAS as the winner
        let mut kv = KvStore::new();
        set(&mut kv, "lease", "epoch 0");
        let before = kv.modify_index();
        for s in 0..5 {
            cas(&mut kv, "lease", Some("epoch 0"), &format!("claim standby{s}"));
        }
        assert_eq!(kv.get("lease"), Some("claim standby0"));
        assert_eq!(kv.modify_index(), before + 1, "exactly one CAS may land");
    }

    #[test]
    fn failed_cas_does_not_bump_the_modify_index() {
        let mut kv = KvStore::new();
        set(&mut kv, "a", "1");
        let before = kv.modify_index();
        cas(&mut kv, "a", Some("2"), "3");
        assert_eq!(kv.modify_index(), before);
        assert_eq!(kv.get("a"), Some("1"));
    }

    #[test]
    fn prefix_index_tracks_changes_under_prefix_only() {
        let mut kv = KvStore::new();
        set(&mut kv, "service/hpc/a", "1");
        let before = kv.prefix_index("service/hpc/");
        set(&mut kv, "other/x", "1");
        assert_eq!(kv.prefix_index("service/hpc/"), before);
        set(&mut kv, "service/hpc/b", "1");
        assert!(kv.prefix_index("service/hpc/") > before);
    }
}
