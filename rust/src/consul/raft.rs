//! Raft: leader election + log replication for the consul server quorum.
//!
//! A pure state machine: `tick(now)` and `on_message(now, msg)` return
//! outbound messages; the driver (test harness or `service::ConsulCluster`)
//! owns delivery and time. Implements the core of the Raft paper —
//! randomized election timeouts, term/vote safety, AppendEntries
//! consistency check, commit-on-majority — enough to give the KV store
//! real HA semantics (leader failover included).

use crate::sim::SimTime;
use crate::util::Rng;

pub type NodeId = u32;
pub type Term = u64;

/// A replicated command (the KV layer's operations).
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    Set { key: String, value: String },
    Delete { key: String },
    /// Compare-and-set: write `value` only if the key currently holds
    /// exactly `expected` (`None` = key must be absent). Because the
    /// raft log totally orders commands, concurrent CAS attempts with
    /// the same `expected` resolve to exactly one winner on every
    /// replica — the primitive behind the multi-standby head lease.
    Cas { key: String, expected: Option<String>, value: String },
    Noop,
}

#[derive(Debug, Clone, PartialEq)]
pub struct LogEntry {
    pub term: Term,
    pub command: Command,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    RequestVote { term: Term, candidate: NodeId, last_log_index: u64, last_log_term: Term },
    VoteResponse { term: Term, granted: bool },
    AppendEntries {
        term: Term,
        leader: NodeId,
        prev_log_index: u64,
        prev_log_term: Term,
        entries: Vec<LogEntry>,
        leader_commit: u64,
    },
    AppendResponse { term: Term, success: bool, match_index: u64 },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Follower,
    Candidate,
    Leader,
}

/// One Raft server.
pub struct RaftNode {
    pub id: NodeId,
    pub peers: Vec<NodeId>,
    pub role: Role,
    pub term: Term,
    pub voted_for: Option<NodeId>,
    pub log: Vec<LogEntry>, // 1-based indexing via helpers
    pub commit_index: u64,
    last_applied: u64,
    // leader volatile state
    next_index: Vec<u64>,
    match_index: Vec<u64>,
    // candidate volatile state
    votes: u32,
    // timers
    election_deadline: SimTime,
    heartbeat_due: SimTime,
    rng: Rng,
    pub election_timeout_min: SimTime,
    pub election_timeout_max: SimTime,
    pub heartbeat_interval: SimTime,
}

impl RaftNode {
    pub fn new(id: NodeId, peers: Vec<NodeId>, seed: u64) -> Self {
        let mut node = Self {
            id,
            peers,
            role: Role::Follower,
            term: 0,
            voted_for: None,
            log: Vec::new(),
            commit_index: 0,
            last_applied: 0,
            next_index: Vec::new(),
            match_index: Vec::new(),
            votes: 0,
            election_deadline: SimTime::ZERO,
            heartbeat_due: SimTime::ZERO,
            rng: Rng::new(seed ^ (id as u64 + 1) * 0x9E37),
            election_timeout_min: SimTime::from_millis(150),
            election_timeout_max: SimTime::from_millis(300),
            heartbeat_interval: SimTime::from_millis(50),
        };
        node.reset_election_timer(SimTime::ZERO);
        node
    }

    fn last_log_index(&self) -> u64 {
        self.log.len() as u64
    }
    fn last_log_term(&self) -> Term {
        self.log.last().map(|e| e.term).unwrap_or(0)
    }
    fn term_at(&self, index: u64) -> Term {
        if index == 0 {
            0
        } else {
            self.log[(index - 1) as usize].term
        }
    }

    fn reset_election_timer(&mut self, now: SimTime) {
        let span = self
            .election_timeout_max
            .saturating_sub(self.election_timeout_min)
            .as_nanos();
        let jitter = if span == 0 { 0 } else { self.rng.gen_range(span) };
        self.election_deadline = now + self.election_timeout_min + SimTime::from_nanos(jitter);
    }

    fn become_follower(&mut self, term: Term, now: SimTime) {
        self.role = Role::Follower;
        if term > self.term {
            self.term = term;
            self.voted_for = None;
        }
        self.reset_election_timer(now);
    }

    fn become_leader(&mut self, now: SimTime) -> Vec<(NodeId, Message)> {
        self.role = Role::Leader;
        let n = self.peers.len();
        self.next_index = vec![self.last_log_index() + 1; n];
        self.match_index = vec![0; n];
        self.heartbeat_due = now; // heartbeat immediately
        self.broadcast_append(now)
    }

    fn start_election(&mut self, now: SimTime) -> Vec<(NodeId, Message)> {
        self.role = Role::Candidate;
        self.term += 1;
        self.voted_for = Some(self.id);
        self.votes = 1;
        self.reset_election_timer(now);
        if self.votes >= self.majority() {
            // single-node cluster: win immediately
            return self.become_leader(now);
        }
        let msg = Message::RequestVote {
            term: self.term,
            candidate: self.id,
            last_log_index: self.last_log_index(),
            last_log_term: self.last_log_term(),
        };
        self.peers.iter().map(|&p| (p, msg.clone())).collect()
    }

    fn append_for_peer(&self, peer_slot: usize) -> Message {
        let next = self.next_index[peer_slot];
        let prev_log_index = next - 1;
        let prev_log_term = self.term_at(prev_log_index);
        let entries: Vec<LogEntry> = self.log[(next as usize - 1).min(self.log.len())..].to_vec();
        Message::AppendEntries {
            term: self.term,
            leader: self.id,
            prev_log_index,
            prev_log_term,
            entries,
            leader_commit: self.commit_index,
        }
    }

    fn broadcast_append(&mut self, now: SimTime) -> Vec<(NodeId, Message)> {
        self.heartbeat_due = now + self.heartbeat_interval;
        (0..self.peers.len())
            .map(|i| (self.peers[i], self.append_for_peer(i)))
            .collect()
    }

    /// Majority size for the cluster (peers + self).
    fn majority(&self) -> u32 {
        (self.peers.len() as u32 + 1) / 2 + 1
    }

    /// Leader API: append a client command. Returns its log index, or
    /// None if this node is not the leader.
    pub fn propose(&mut self, command: Command, now: SimTime) -> Option<(u64, Vec<(NodeId, Message)>)> {
        if self.role != Role::Leader {
            return None;
        }
        self.log.push(LogEntry { term: self.term, command });
        let index = self.last_log_index();
        // single-node cluster commits immediately
        self.advance_commit();
        Some((index, self.broadcast_append(now)))
    }

    fn advance_commit(&mut self) {
        if self.role != Role::Leader {
            return;
        }
        for n in (self.commit_index + 1..=self.last_log_index()).rev() {
            if self.term_at(n) != self.term {
                continue;
            }
            let replicas = 1 + self
                .match_index
                .iter()
                .filter(|&&m| m >= n)
                .count() as u32;
            if replicas >= self.majority() {
                self.commit_index = n;
                break;
            }
        }
    }

    /// Timer-driven behaviour. Call regularly (e.g. every 10 ms).
    pub fn tick(&mut self, now: SimTime) -> Vec<(NodeId, Message)> {
        match self.role {
            Role::Leader => {
                if now >= self.heartbeat_due {
                    self.broadcast_append(now)
                } else {
                    Vec::new()
                }
            }
            _ => {
                if now >= self.election_deadline {
                    self.start_election(now)
                } else {
                    Vec::new()
                }
            }
        }
    }

    /// Message-driven behaviour.
    pub fn on_message(&mut self, now: SimTime, from: NodeId, msg: Message) -> Vec<(NodeId, Message)> {
        match msg {
            Message::RequestVote { term, candidate, last_log_index, last_log_term } => {
                if term > self.term {
                    self.become_follower(term, now);
                }
                let log_ok = last_log_term > self.last_log_term()
                    || (last_log_term == self.last_log_term()
                        && last_log_index >= self.last_log_index());
                let grant = term == self.term
                    && log_ok
                    && (self.voted_for.is_none() || self.voted_for == Some(candidate));
                if grant {
                    self.voted_for = Some(candidate);
                    self.reset_election_timer(now);
                }
                vec![(from, Message::VoteResponse { term: self.term, granted: grant })]
            }
            Message::VoteResponse { term, granted } => {
                if term > self.term {
                    self.become_follower(term, now);
                    return Vec::new();
                }
                if self.role == Role::Candidate && term == self.term && granted {
                    self.votes += 1;
                    if self.votes >= self.majority() {
                        return self.become_leader(now);
                    }
                }
                Vec::new()
            }
            Message::AppendEntries { term, leader: _, prev_log_index, prev_log_term, entries, leader_commit } => {
                if term > self.term || (term == self.term && self.role != Role::Follower) {
                    self.become_follower(term, now);
                }
                if term < self.term {
                    return vec![(
                        from,
                        Message::AppendResponse { term: self.term, success: false, match_index: 0 },
                    )];
                }
                self.reset_election_timer(now);
                // consistency check
                if prev_log_index > self.last_log_index()
                    || (prev_log_index > 0 && self.term_at(prev_log_index) != prev_log_term)
                {
                    return vec![(
                        from,
                        Message::AppendResponse { term: self.term, success: false, match_index: 0 },
                    )];
                }
                // append, truncating conflicts
                let mut idx = prev_log_index as usize;
                for e in entries {
                    if idx < self.log.len() {
                        if self.log[idx].term != e.term {
                            self.log.truncate(idx);
                            self.log.push(e);
                        }
                    } else {
                        self.log.push(e);
                    }
                    idx += 1;
                }
                if leader_commit > self.commit_index {
                    self.commit_index = leader_commit.min(self.last_log_index());
                }
                vec![(
                    from,
                    Message::AppendResponse {
                        term: self.term,
                        success: true,
                        match_index: self.last_log_index(),
                    },
                )]
            }
            Message::AppendResponse { term, success, match_index } => {
                if term > self.term {
                    self.become_follower(term, now);
                    return Vec::new();
                }
                if self.role != Role::Leader || term != self.term {
                    return Vec::new();
                }
                let slot = match self.peers.iter().position(|&p| p == from) {
                    Some(s) => s,
                    None => return Vec::new(),
                };
                if success {
                    self.match_index[slot] = self.match_index[slot].max(match_index);
                    self.next_index[slot] = self.match_index[slot] + 1;
                    self.advance_commit();
                    Vec::new()
                } else {
                    // back off and retry
                    self.next_index[slot] = self.next_index[slot].saturating_sub(1).max(1);
                    vec![(from, self.append_for_peer(slot))]
                }
            }
        }
    }

    /// Drain newly committed entries (apply to the state machine).
    pub fn take_applied(&mut self) -> Vec<LogEntry> {
        let mut out = Vec::new();
        while self.last_applied < self.commit_index {
            out.push(self.log[self.last_applied as usize].clone());
            self.last_applied += 1;
        }
        out
    }

    pub fn is_leader(&self) -> bool {
        self.role == Role::Leader
    }
}

#[cfg(test)]
pub(crate) mod harness {
    //! Deterministic in-memory raft cluster driver for tests.
    use super::*;
    use std::collections::VecDeque;

    pub struct Net {
        pub nodes: Vec<RaftNode>,
        pub now: SimTime,
        /// (deliver_at, from, to, msg)
        pub inflight: VecDeque<(SimTime, NodeId, NodeId, Message)>,
        pub delay: SimTime,
        /// Nodes currently partitioned away.
        pub down: Vec<NodeId>,
    }

    impl Net {
        pub fn new(n: u32, seed: u64) -> Self {
            let ids: Vec<NodeId> = (0..n).collect();
            let nodes = ids
                .iter()
                .map(|&id| {
                    let peers: Vec<NodeId> = ids.iter().copied().filter(|&p| p != id).collect();
                    RaftNode::new(id, peers, seed)
                })
                .collect();
            Self {
                nodes,
                now: SimTime::ZERO,
                inflight: VecDeque::new(),
                delay: SimTime::from_millis(5),
                down: Vec::new(),
            }
        }

        pub fn send_all(&mut self, from: NodeId, msgs: Vec<(NodeId, Message)>) {
            for (to, m) in msgs {
                self.inflight.push_back((self.now + self.delay, from, to, m));
            }
        }

        /// Advance time in `step` increments for `steps` iterations.
        pub fn run(&mut self, steps: u32, step: SimTime) {
            for _ in 0..steps {
                self.now = self.now + step;
                // deliver due messages
                let mut pending: Vec<(SimTime, NodeId, NodeId, Message)> = Vec::new();
                while let Some(&(at, ..)) = self.inflight.front() {
                    if at <= self.now {
                        pending.push(self.inflight.pop_front().unwrap());
                    } else {
                        break;
                    }
                }
                for (_, from, to, msg) in pending {
                    if self.down.contains(&to) || self.down.contains(&from) {
                        continue;
                    }
                    let now = self.now;
                    let out = self.nodes[to as usize].on_message(now, from, msg);
                    self.send_all(to, out);
                }
                // tick everyone
                for id in 0..self.nodes.len() as u32 {
                    if self.down.contains(&id) {
                        continue;
                    }
                    let now = self.now;
                    let out = self.nodes[id as usize].tick(now);
                    self.send_all(id, out);
                }
            }
        }

        pub fn leaders(&self) -> Vec<NodeId> {
            self.nodes
                .iter()
                .filter(|n| n.is_leader() && !self.down.contains(&n.id))
                .map(|n| n.id)
                .collect()
        }

        pub fn run_until_leader(&mut self) -> NodeId {
            for _ in 0..5000 {
                self.run(1, SimTime::from_millis(10));
                let l = self.leaders();
                if l.len() == 1 {
                    return l[0];
                }
            }
            panic!("no leader elected");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::harness::Net;
    use super::*;

    #[test]
    fn elects_exactly_one_leader() {
        for seed in [1u64, 7, 42, 99] {
            let mut net = Net::new(3, seed);
            let leader = net.run_until_leader();
            assert_eq!(net.leaders(), vec![leader]);
        }
    }

    #[test]
    fn leaders_per_term_unique() {
        // Election safety: run a while, track (term -> leader) pairs.
        let mut net = Net::new(5, 3);
        let mut seen: std::collections::HashMap<Term, NodeId> = Default::default();
        for _ in 0..2000 {
            net.run(1, SimTime::from_millis(10));
            for n in &net.nodes {
                if n.is_leader() {
                    if let Some(&prev) = seen.get(&n.term) {
                        assert_eq!(prev, n.id, "two leaders in term {}", n.term);
                    } else {
                        seen.insert(n.term, n.id);
                    }
                }
            }
        }
        assert!(!seen.is_empty());
    }

    #[test]
    fn replicates_and_commits() {
        let mut net = Net::new(3, 11);
        let leader = net.run_until_leader();
        let now = net.now;
        let (idx, msgs) = net.nodes[leader as usize]
            .propose(Command::Set { key: "k".into(), value: "v".into() }, now)
            .unwrap();
        net.send_all(leader, msgs);
        net.run(50, SimTime::from_millis(10));
        assert!(net.nodes[leader as usize].commit_index >= idx);
        // all live nodes applied it
        for n in &mut net.nodes {
            let applied = n.take_applied();
            assert!(applied
                .iter()
                .any(|e| matches!(&e.command, Command::Set { key, .. } if key == "k")));
        }
    }

    #[test]
    fn failover_elects_new_leader_and_preserves_log() {
        let mut net = Net::new(3, 5);
        let leader = net.run_until_leader();
        let now = net.now;
        let (_, msgs) = net.nodes[leader as usize]
            .propose(Command::Set { key: "a".into(), value: "1".into() }, now)
            .unwrap();
        net.send_all(leader, msgs);
        net.run(50, SimTime::from_millis(10));
        // kill the leader
        net.down.push(leader);
        let new_leader = net.run_until_leader();
        assert_ne!(new_leader, leader);
        // the committed entry must survive on the new leader
        assert!(net.nodes[new_leader as usize]
            .log
            .iter()
            .any(|e| matches!(&e.command, Command::Set { key, .. } if key == "a")));
        // and the new leader can commit new entries
        let now = net.now;
        let (idx2, msgs) = net.nodes[new_leader as usize]
            .propose(Command::Set { key: "b".into(), value: "2".into() }, now)
            .unwrap();
        net.send_all(new_leader, msgs);
        net.run(100, SimTime::from_millis(10));
        assert!(net.nodes[new_leader as usize].commit_index >= idx2);
    }

    #[test]
    fn follower_rejects_stale_term() {
        let mut n = RaftNode::new(0, vec![1], 1);
        n.term = 5;
        let out = n.on_message(
            SimTime::from_millis(1),
            1,
            Message::AppendEntries {
                term: 3,
                leader: 1,
                prev_log_index: 0,
                prev_log_term: 0,
                entries: vec![],
                leader_commit: 0,
            },
        );
        assert!(matches!(
            out[0].1,
            Message::AppendResponse { success: false, term: 5, .. }
        ));
    }

    #[test]
    fn log_consistency_check_rejects_gaps() {
        let mut n = RaftNode::new(0, vec![1], 1);
        let out = n.on_message(
            SimTime::from_millis(1),
            1,
            Message::AppendEntries {
                term: 1,
                leader: 1,
                prev_log_index: 7, // we have nothing
                prev_log_term: 1,
                entries: vec![LogEntry { term: 1, command: Command::Noop }],
                leader_commit: 0,
            },
        );
        assert!(matches!(out[0].1, Message::AppendResponse { success: false, .. }));
    }

    #[test]
    fn single_node_cluster_self_commits() {
        let mut n = RaftNode::new(0, vec![], 1);
        // immediately becomes candidate then leader on tick
        let mut now = SimTime::ZERO;
        for _ in 0..100 {
            now = now + SimTime::from_millis(10);
            n.tick(now);
            if n.is_leader() {
                break;
            }
        }
        assert!(n.is_leader());
        let (idx, _) = n.propose(Command::Noop, now).unwrap();
        assert_eq!(n.commit_index, idx);
    }
}
