//! consul-template: render templates from catalog/kv state, re-rendering
//! when the watched data changes (§IV, Fig. 5 — the hostfile pipeline).
//!
//! Grammar subset (all the paper's use case needs, plus kv lookups):
//!
//! ```text
//! {{range service "hpc"}}{{.Node}} {{.Address}} slots={{.Slots}}
//! {{end}}
//! {{key "config/mpi/btl"}}
//! ```

use super::catalog::Catalog;
use super::kv::KvStore;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum TemplateError {
    #[error("unterminated directive at byte {0}")]
    Unterminated(usize),
    #[error("unknown directive: {0}")]
    Unknown(String),
    #[error("{{end}} without open range")]
    StrayEnd,
    #[error("range not closed")]
    UnclosedRange,
    #[error("unknown field {0} (expected .Node/.Address/.Port/.Slots)")]
    UnknownField(String),
}

#[derive(Debug, Clone, PartialEq)]
enum Node {
    Text(String),
    Key(String),
    Range { service: String, body: Vec<RangeNode> },
}

#[derive(Debug, Clone, PartialEq)]
enum RangeNode {
    Text(String),
    Field(Field),
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Field {
    Node,
    Address,
    Port,
    Slots,
}

/// A compiled template.
#[derive(Debug, Clone, PartialEq)]
pub struct Template {
    nodes: Vec<Node>,
    source: String,
}

fn split_directives(text: &str) -> Result<Vec<Result<String, String>>, TemplateError> {
    // Ok(text-chunk) | Err(directive-content)
    let mut out = Vec::new();
    let mut rest = text;
    let mut offset = 0;
    while let Some(start) = rest.find("{{") {
        if start > 0 {
            out.push(Ok(rest[..start].to_string()));
        }
        let after = &rest[start + 2..];
        let end = after
            .find("}}")
            .ok_or(TemplateError::Unterminated(offset + start))?;
        out.push(Err(after[..end].trim().to_string()));
        offset += start + 2 + end + 2;
        rest = &after[end + 2..];
    }
    if !rest.is_empty() {
        out.push(Ok(rest.to_string()));
    }
    Ok(out)
}

fn parse_quoted(directive: &str, keyword: &str) -> Option<String> {
    let rest = directive.strip_prefix(keyword)?.trim();
    let rest = rest.strip_prefix('"')?;
    let end = rest.find('"')?;
    Some(rest[..end].to_string())
}

impl Template {
    /// Compile template text.
    pub fn parse(text: &str) -> Result<Self, TemplateError> {
        let parts = split_directives(text)?;
        let mut nodes = Vec::new();
        let mut open_range: Option<(String, Vec<RangeNode>)> = None;

        for part in parts {
            match part {
                Ok(text) => match &mut open_range {
                    Some((_, body)) => body.push(RangeNode::Text(text)),
                    None => nodes.push(Node::Text(text)),
                },
                Err(directive) => {
                    if let Some(service) = parse_quoted(&directive, "range service") {
                        if open_range.is_some() {
                            return Err(TemplateError::Unknown("nested range".into()));
                        }
                        open_range = Some((service, Vec::new()));
                    } else if directive == "end" {
                        let (service, body) =
                            open_range.take().ok_or(TemplateError::StrayEnd)?;
                        nodes.push(Node::Range { service, body });
                    } else if let Some(key) = parse_quoted(&directive, "key") {
                        match &mut open_range {
                            Some(_) => {
                                return Err(TemplateError::Unknown(
                                    "key inside range".into(),
                                ))
                            }
                            None => nodes.push(Node::Key(key)),
                        }
                    } else if let Some(field) = directive.strip_prefix('.') {
                        let f = match field {
                            "Node" => Field::Node,
                            "Address" => Field::Address,
                            "Port" => Field::Port,
                            "Slots" => Field::Slots,
                            other => return Err(TemplateError::UnknownField(other.into())),
                        };
                        match &mut open_range {
                            Some((_, body)) => body.push(RangeNode::Field(f)),
                            None => {
                                return Err(TemplateError::Unknown(format!(
                                    "field .{field} outside range"
                                )))
                            }
                        }
                    } else {
                        return Err(TemplateError::Unknown(directive));
                    }
                }
            }
        }
        if open_range.is_some() {
            return Err(TemplateError::UnclosedRange);
        }
        Ok(Self { nodes, source: text.to_string() })
    }

    /// The services this template watches (for change detection).
    pub fn watched_services(&self) -> Vec<&str> {
        self.nodes
            .iter()
            .filter_map(|n| match n {
                Node::Range { service, .. } => Some(service.as_str()),
                _ => None,
            })
            .collect()
    }

    /// Render against the KV/catalog state.
    pub fn render(&self, kv: &KvStore) -> String {
        let mut out = String::new();
        for node in &self.nodes {
            match node {
                Node::Text(t) => out.push_str(t),
                Node::Key(k) => out.push_str(kv.get(k).unwrap_or("")),
                Node::Range { service, body } => {
                    for entry in Catalog::list(kv, service) {
                        for rn in body {
                            match rn {
                                RangeNode::Text(t) => out.push_str(t),
                                RangeNode::Field(Field::Node) => out.push_str(&entry.node),
                                RangeNode::Field(Field::Address) => {
                                    out.push_str(&entry.address.to_string())
                                }
                                RangeNode::Field(Field::Port) => {
                                    out.push_str(&entry.port.to_string())
                                }
                                RangeNode::Field(Field::Slots) => {
                                    out.push_str(&entry.slots.to_string())
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The canonical MPI hostfile template from the paper's scheme.
    pub fn mpi_hostfile() -> Self {
        Self::parse("{{range service \"hpc\"}}{{.Address}} slots={{.Slots}}\n{{end}}")
            .expect("builtin template")
    }
}

/// A watching renderer: re-renders when the watch index moves.
#[derive(Debug, Clone)]
pub struct TemplateWatcher {
    pub template: Template,
    last_index: u64,
    pub renders: u64,
    pub last_output: String,
}

impl TemplateWatcher {
    pub fn new(template: Template) -> Self {
        Self { template, last_index: 0, renders: 0, last_output: String::new() }
    }

    /// Poll: returns Some(output) when the watched data changed.
    pub fn poll(&mut self, kv: &KvStore) -> Option<&str> {
        let idx = self
            .template
            .watched_services()
            .iter()
            .map(|s| Catalog::watch_index(kv, s))
            .max()
            .unwrap_or_else(|| kv.modify_index());
        if idx != self.last_index {
            self.last_index = idx;
            self.renders += 1;
            self.last_output = self.template.render(kv);
            Some(&self.last_output)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consul::catalog::ServiceEntry;
    use crate::consul::raft::Command;
    use crate::vnet::addr::Ipv4;

    fn kv_with_nodes(nodes: &[(&str, u8, u32)]) -> KvStore {
        let mut kv = KvStore::new();
        for (node, oct, slots) in nodes {
            let e = ServiceEntry {
                node: node.to_string(),
                address: Ipv4::new(10, 10, 0, *oct),
                port: 22,
                slots: *slots,
                tags: vec![],
            };
            kv.apply(&Catalog::register_cmd("hpc", &e));
        }
        kv
    }

    #[test]
    fn renders_the_papers_hostfile() {
        let kv = kv_with_nodes(&[("node02", 2, 12), ("node03", 3, 12)]);
        let t = Template::mpi_hostfile();
        assert_eq!(
            t.render(&kv),
            "10.10.0.2 slots=12\n10.10.0.3 slots=12\n"
        );
    }

    #[test]
    fn all_fields_render() {
        let kv = kv_with_nodes(&[("n1", 5, 4)]);
        let t = Template::parse(
            "{{range service \"hpc\"}}{{.Node}}|{{.Address}}|{{.Port}}|{{.Slots}}{{end}}",
        )
        .unwrap();
        assert_eq!(t.render(&kv), "n1|10.10.0.5|22|4");
    }

    #[test]
    fn key_directive_reads_kv() {
        let mut kv = KvStore::new();
        kv.apply(&Command::Set { key: "config/btl".into(), value: "tcp,self".into() });
        let t = Template::parse("btl={{key \"config/btl\"}} missing={{key \"nope\"}}.").unwrap();
        assert_eq!(t.render(&kv), "btl=tcp,self missing=.");
    }

    #[test]
    fn parse_errors() {
        assert_eq!(
            Template::parse("{{range service \"x\"}}no end").unwrap_err(),
            TemplateError::UnclosedRange
        );
        assert_eq!(Template::parse("{{end}}").unwrap_err(), TemplateError::StrayEnd);
        assert!(matches!(
            Template::parse("{{bogus}}").unwrap_err(),
            TemplateError::Unknown(_)
        ));
        assert!(matches!(
            Template::parse("{{.Node}}").unwrap_err(),
            TemplateError::Unknown(_)
        ));
        assert!(matches!(
            Template::parse("{{range service \"x\"}}{{.Nope}}{{end}}").unwrap_err(),
            TemplateError::UnknownField(_)
        ));
        assert!(matches!(
            Template::parse("{{oops").unwrap_err(),
            TemplateError::Unterminated(_)
        ));
    }

    #[test]
    fn watcher_rerenders_only_on_change() {
        let mut kv = kv_with_nodes(&[("node02", 2, 12)]);
        let mut w = TemplateWatcher::new(Template::mpi_hostfile());
        assert!(w.poll(&kv).is_some()); // first render
        assert!(w.poll(&kv).is_none()); // no change
        // unrelated service change must not re-render
        let e = ServiceEntry {
            node: "web1".into(),
            address: Ipv4::new(1, 2, 3, 4),
            port: 80,
            slots: 1,
            tags: vec![],
        };
        kv.apply(&Catalog::register_cmd("web", &e));
        assert!(w.poll(&kv).is_none());
        // hpc change re-renders
        let e2 = ServiceEntry {
            node: "node03".into(),
            address: Ipv4::new(10, 10, 0, 3),
            port: 22,
            slots: 12,
            tags: vec![],
        };
        kv.apply(&Catalog::register_cmd("hpc", &e2));
        let out = w.poll(&kv).unwrap();
        assert!(out.contains("10.10.0.3"));
        assert_eq!(w.renders, 2);
    }
}
