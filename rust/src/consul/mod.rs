//! consul — the service-discovery and configuration substrate (§III-C).
//!
//! The paper runs a distributed Consul service (3 servers, HA) with an
//! agent baked into every HPC container; containers self-register, and
//! the head node renders the MPI hostfile through consul-template. This
//! module implements the protocols behind that behaviour:
//!
//! * [`gossip`] — SWIM-style membership: periodic probe, indirect
//!   probe-req, suspicion, piggybacked dissemination.
//! * [`raft`] — leader election + replicated log for the server quorum.
//! * [`kv`] — the replicated key/value store (ModifyIndex versioning).
//! * [`catalog`] — service registry (register/deregister/list) over kv.
//! * [`health`] — TTL health checks gating catalog listings.
//! * [`template`] — consul-template: watch + render (the hostfile path).
//! * [`service`] — the facade tying servers + agents to the sim engine.

pub mod catalog;
pub mod gossip;
pub mod health;
pub mod kv;
pub mod raft;
pub mod service;
pub mod template;

pub use catalog::{Catalog, ServiceEntry};
pub use kv::KvStore;
pub use service::ConsulCluster;
pub use template::Template;
