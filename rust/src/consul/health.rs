//! TTL health checks: an agent must refresh its check within the TTL or
//! the instance goes critical and drops out of catalog listings — this is
//! what makes "power off a machine, the node leaves the hostfile" work.

use crate::sim::SimTime;
use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckStatus {
    Passing,
    Critical,
}

#[derive(Debug, Clone)]
struct Check {
    ttl: SimTime,
    last_refresh: SimTime,
}

/// Health-check registry (one per consul server cluster).
#[derive(Debug, Clone, Default)]
pub struct HealthRegistry {
    checks: HashMap<String, Check>, // key: node name
}

impl HealthRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or re-register) a TTL check for a node.
    pub fn register(&mut self, node: impl Into<String>, ttl: SimTime, now: SimTime) {
        self.checks.insert(node.into(), Check { ttl, last_refresh: now });
    }

    pub fn deregister(&mut self, node: &str) {
        self.checks.remove(node);
    }

    /// Agent heartbeat.
    pub fn refresh(&mut self, node: &str, now: SimTime) -> bool {
        match self.checks.get_mut(node) {
            Some(c) => {
                c.last_refresh = now;
                true
            }
            None => false,
        }
    }

    pub fn status(&self, node: &str, now: SimTime) -> Option<CheckStatus> {
        self.checks.get(node).map(|c| {
            if now.saturating_sub(c.last_refresh) <= c.ttl {
                CheckStatus::Passing
            } else {
                CheckStatus::Critical
            }
        })
    }

    /// Nodes currently passing.
    pub fn passing(&self, now: SimTime) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .checks
            .iter()
            .filter(|(_, c)| now.saturating_sub(c.last_refresh) <= c.ttl)
            .map(|(n, _)| n.as_str())
            .collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.checks.len()
    }
    pub fn is_empty(&self) -> bool {
        self.checks.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_until_ttl_expires() {
        let mut h = HealthRegistry::new();
        let ttl = SimTime::from_secs(10);
        h.register("node02", ttl, SimTime::ZERO);
        assert_eq!(h.status("node02", SimTime::from_secs(5)), Some(CheckStatus::Passing));
        assert_eq!(h.status("node02", SimTime::from_secs(10)), Some(CheckStatus::Passing));
        assert_eq!(h.status("node02", SimTime::from_secs(11)), Some(CheckStatus::Critical));
    }

    #[test]
    fn refresh_extends() {
        let mut h = HealthRegistry::new();
        h.register("n", SimTime::from_secs(10), SimTime::ZERO);
        assert!(h.refresh("n", SimTime::from_secs(9)));
        assert_eq!(h.status("n", SimTime::from_secs(18)), Some(CheckStatus::Passing));
        assert!(!h.refresh("ghost", SimTime::ZERO));
    }

    #[test]
    fn passing_list_filters_critical() {
        let mut h = HealthRegistry::new();
        h.register("a", SimTime::from_secs(10), SimTime::ZERO);
        h.register("b", SimTime::from_secs(10), SimTime::ZERO);
        h.refresh("b", SimTime::from_secs(20));
        assert_eq!(h.passing(SimTime::from_secs(25)), vec!["b"]);
    }

    #[test]
    fn deregister_removes() {
        let mut h = HealthRegistry::new();
        h.register("a", SimTime::from_secs(1), SimTime::ZERO);
        h.deregister("a");
        assert_eq!(h.status("a", SimTime::ZERO), None);
        assert!(h.is_empty());
    }
}
