//! TTL health checks: an agent must refresh its check within the TTL or
//! the instance goes critical and drops out of catalog listings — this is
//! what makes "power off a machine, the node leaves the hostfile" work.

use crate::sim::SimTime;
use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CheckStatus {
    Passing,
    Critical,
}

#[derive(Debug, Clone)]
struct Check {
    ttl: SimTime,
    last_refresh: SimTime,
}

/// Health-check registry (one per consul server cluster).
#[derive(Debug, Clone, Default)]
pub struct HealthRegistry {
    checks: HashMap<String, Check>, // key: node name
}

impl HealthRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or re-register) a TTL check for a node.
    pub fn register(&mut self, node: impl Into<String>, ttl: SimTime, now: SimTime) {
        self.checks.insert(node.into(), Check { ttl, last_refresh: now });
    }

    pub fn deregister(&mut self, node: &str) {
        self.checks.remove(node);
    }

    /// Agent heartbeat.
    pub fn refresh(&mut self, node: &str, now: SimTime) -> bool {
        match self.checks.get_mut(node) {
            Some(c) => {
                c.last_refresh = now;
                true
            }
            None => false,
        }
    }

    pub fn status(&self, node: &str, now: SimTime) -> Option<CheckStatus> {
        self.checks.get(node).map(|c| {
            if now.saturating_sub(c.last_refresh) <= c.ttl {
                CheckStatus::Passing
            } else {
                CheckStatus::Critical
            }
        })
    }

    /// Nodes currently passing, in natural (numeric-suffix-aware) order:
    /// `node2` sorts before `node11` and `node100` even when the names
    /// were padded for a smaller cluster.
    pub fn passing(&self, now: SimTime) -> Vec<&str> {
        let mut v: Vec<&str> = self
            .checks
            .iter() // lint: sorted
            .filter(|(_, c)| now.saturating_sub(c.last_refresh) <= c.ttl)
            .map(|(n, _)| n.as_str())
            .collect();
        // cached: natural_key allocates, so compute it once per element,
        // not once per comparison (this runs on the hostfile-render path)
        v.sort_by_cached_key(|n| natural_key(n));
        v
    }

    pub fn len(&self) -> usize {
        self.checks.len()
    }
    pub fn is_empty(&self) -> bool {
        self.checks.is_empty()
    }
}

/// Split a trailing ASCII-digit run off a name: `"node11"` -> `("node",
/// Some(11))`. Overlong digit runs that overflow `u64` fall back to `None`.
fn split_trailing_digits(s: &str) -> (&str, Option<u64>) {
    let digits = s.chars().rev().take_while(|c| c.is_ascii_digit()).count();
    let idx = s.len() - digits;
    if digits == 0 {
        return (s, None);
    }
    match s[idx..].parse::<u64>() {
        Ok(n) => (&s[..idx], Some(n)),
        Err(_) => (s, None),
    }
}

/// Sort key ordering node names numerically within a shared prefix
/// (`node2` < `node11` < `node100`), lexicographically across prefixes.
/// A key function (rather than a comparator) guarantees a total order —
/// mixed names like `a1b` cannot create comparison cycles.
pub(crate) fn natural_key(s: &str) -> (String, Option<u64>, String) {
    let (prefix, num) = split_trailing_digits(s);
    (prefix.to_string(), num, s.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_until_ttl_expires() {
        let mut h = HealthRegistry::new();
        let ttl = SimTime::from_secs(10);
        h.register("node02", ttl, SimTime::ZERO);
        assert_eq!(h.status("node02", SimTime::from_secs(5)), Some(CheckStatus::Passing));
        assert_eq!(h.status("node02", SimTime::from_secs(10)), Some(CheckStatus::Passing));
        assert_eq!(h.status("node02", SimTime::from_secs(11)), Some(CheckStatus::Critical));
    }

    #[test]
    fn refresh_extends() {
        let mut h = HealthRegistry::new();
        h.register("n", SimTime::from_secs(10), SimTime::ZERO);
        assert!(h.refresh("n", SimTime::from_secs(9)));
        assert_eq!(h.status("n", SimTime::from_secs(18)), Some(CheckStatus::Passing));
        assert!(!h.refresh("ghost", SimTime::ZERO));
    }

    #[test]
    fn passing_list_filters_critical() {
        let mut h = HealthRegistry::new();
        h.register("a", SimTime::from_secs(10), SimTime::ZERO);
        h.register("b", SimTime::from_secs(10), SimTime::ZERO);
        h.refresh("b", SimTime::from_secs(20));
        assert_eq!(h.passing(SimTime::from_secs(25)), vec!["b"]);
    }

    #[test]
    fn passing_list_orders_node_names_numerically() {
        let mut h = HealthRegistry::new();
        for name in ["node100", "node2", "node11", "head"] {
            h.register(name, SimTime::from_secs(10), SimTime::ZERO);
        }
        assert_eq!(
            h.passing(SimTime::from_secs(1)),
            vec!["head", "node2", "node11", "node100"],
            "node100 must not sort before node11"
        );
    }

    #[test]
    fn natural_key_orders_names_and_stays_total() {
        assert!(natural_key("node2") < natural_key("node11"));
        assert!(natural_key("node11") < natural_key("node100"));
        assert!(natural_key("node02") < natural_key("node2"), "ties break lexicographically");
        assert!(natural_key("a") < natural_key("b"));
        assert!(natural_key("alpha9") < natural_key("beta1"));
        assert_eq!(natural_key("n1"), natural_key("n1"));
        // the comparator-cycle shape that breaks pairwise orderings
        // (a2 < a11 numerically, a11 < a1b lexically, a1b < a2 lexically)
        // must sort deterministically and without panicking under a key
        let mut v = vec!["a1b", "a11", "a2"];
        v.sort_by_key(|n| natural_key(n));
        assert_eq!(v, vec!["a2", "a11", "a1b"]);
    }

    #[test]
    fn deregister_removes() {
        let mut h = HealthRegistry::new();
        h.register("a", SimTime::from_secs(1), SimTime::ZERO);
        h.deregister("a");
        assert_eq!(h.status("a", SimTime::ZERO), None);
        assert!(h.is_empty());
    }
}
