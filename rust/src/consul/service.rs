//! ConsulCluster: the running service — a Raft server quorum (HA, §III-C)
//! plus the gossip agent pool, driven by virtual time.
//!
//! The provisioner calls `advance(now)` whenever sim time moves; writes
//! (service registration) go through the Raft leader and become visible
//! in `kv()` once committed, exactly like consul's consistent reads.

use super::catalog::{Catalog, ServiceEntry};
use super::gossip::{GossipNode, Msg as GossipMsg};
use super::health::HealthRegistry;
use super::kv::KvStore;
use super::raft::{Command, Message as RaftMsg, RaftNode};
use crate::sim::SimTime;
use crate::util::ids::AgentId;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum ConsulError {
    #[error("no raft leader elected yet")]
    NoLeader,
    #[error("unknown agent {0}")]
    UnknownAgent(AgentId),
}

enum Wire {
    Raft { from: u32, to: u32, msg: RaftMsg },
    Gossip { from: AgentId, to: AgentId, msg: GossipMsg },
}

/// One consul server: raft + its applied kv replica.
pub struct Server {
    pub raft: RaftNode,
    pub kv: KvStore,
}

/// The whole consul deployment.
pub struct ConsulCluster {
    pub servers: Vec<Server>,
    agents: HashMap<AgentId, GossipNode>,
    queue: BinaryHeap<Reverse<(SimTime, u64, usize)>>,
    payloads: Vec<Option<Wire>>,
    free_slots: VecDeque<usize>,
    seq: u64,
    now: SimTime,
    next_tick: SimTime,
    /// Raft/gossip RPC one-way delay (set from the fabric by the cluster).
    pub rpc_delay: SimTime,
    /// Server/agent tick granularity.
    pub tick_interval: SimTime,
    pub health: HealthRegistry,
    /// Writes waiting for a leader.
    backlog: VecDeque<Command>,
    /// Agents currently cut off by a network partition: gossip crossing
    /// the split is dropped until [`heal_partition`](Self::heal_partition).
    partitioned: HashSet<AgentId>,
    /// Bumped by every `set_partition`, so a stale heal timer from an
    /// earlier partition cannot clear a newer one.
    partition_epoch: u64,
    /// Partial partitions: agents that can reach only the listed server
    /// ids. Gossip between agents is unaffected; server RPC (TTL
    /// refreshes, registrations) from a restricted agent succeeds only
    /// while its reachable set contains the current raft leader.
    restricted: HashMap<AgentId, Vec<u32>>,
    /// Epoch token for partial partitions (same stale-heal protection
    /// as `partition_epoch`).
    restricted_epoch: u64,
    /// Statistics.
    pub raft_msgs: u64,
    pub gossip_msgs: u64,
    /// Gossip messages dropped at a partition boundary.
    pub gossip_dropped: u64,
}

impl ConsulCluster {
    /// `n_servers` raft servers (the paper runs a 3-server HA quorum).
    pub fn new(n_servers: u32, seed: u64) -> Self {
        let ids: Vec<u32> = (0..n_servers).collect();
        let servers = ids
            .iter()
            .map(|&id| Server {
                raft: RaftNode::new(
                    id,
                    ids.iter().copied().filter(|&p| p != id).collect(),
                    seed,
                ),
                kv: KvStore::new(),
            })
            .collect();
        Self {
            servers,
            agents: HashMap::new(),
            queue: BinaryHeap::new(),
            payloads: Vec::new(),
            free_slots: VecDeque::new(),
            seq: 0,
            now: SimTime::ZERO,
            next_tick: SimTime::ZERO,
            rpc_delay: SimTime::from_micros(200),
            tick_interval: SimTime::from_millis(10),
            health: HealthRegistry::new(),
            backlog: VecDeque::new(),
            partitioned: HashSet::new(),
            partition_epoch: 0,
            restricted: HashMap::new(),
            restricted_epoch: 0,
            raft_msgs: 0,
            gossip_msgs: 0,
            gossip_dropped: 0,
        }
    }

    /// Split the gossip network: traffic between `agents` and everyone
    /// else is dropped until healed. One partition at a time — a new
    /// call replaces the previous split. Returns an epoch token for
    /// [`heal_partition_epoch`](Self::heal_partition_epoch), so a timer
    /// armed for an old partition cannot clear a newer one. The cluster
    /// driver also gates health refreshes from partitioned agents
    /// (their TTL updates can't reach the servers either).
    pub fn set_partition(&mut self, agents: impl IntoIterator<Item = AgentId>) -> u64 {
        self.partitioned = agents.into_iter().collect();
        self.partition_epoch += 1;
        self.partition_epoch
    }

    /// Add one agent to the active split (a container re-provisioned on
    /// a machine that is still on the minority side).
    pub fn partition_agent(&mut self, a: AgentId) {
        self.partitioned.insert(a);
    }

    /// Unconditionally clear the current partition (operator action).
    pub fn heal_partition(&mut self) {
        self.partitioned.clear();
    }

    /// Clear the partition only if `epoch` is still the active one —
    /// the form scheduled heal timers use. Returns true when it healed.
    pub fn heal_partition_epoch(&mut self, epoch: u64) -> bool {
        if self.partition_epoch == epoch {
            self.partitioned.clear();
            true
        } else {
            false
        }
    }

    pub fn is_partitioned(&self, a: AgentId) -> bool {
        self.partitioned.contains(&a)
    }

    /// Partial partition: restrict `agents` to reaching only `servers`
    /// (by raft server id). One partial partition at a time — a new
    /// call replaces the previous one. Returns an epoch token for
    /// [`heal_partial_partition_epoch`](Self::heal_partial_partition_epoch).
    pub fn set_partial_partition(
        &mut self,
        agents: impl IntoIterator<Item = AgentId>,
        servers: Vec<u32>,
    ) -> u64 {
        self.restricted = agents.into_iter().map(|a| (a, servers.clone())).collect();
        self.restricted_epoch += 1;
        self.restricted_epoch
    }

    /// Add one agent to the active partial partition (a container
    /// re-provisioned on a machine still inside the restricted window).
    pub fn restrict_agent(&mut self, a: AgentId, servers: Vec<u32>) {
        self.restricted.insert(a, servers);
    }

    /// Clear the partial partition only if `epoch` is still the active
    /// one. Returns true when it healed.
    pub fn heal_partial_partition_epoch(&mut self, epoch: u64) -> bool {
        if self.restricted_epoch == epoch {
            self.restricted.clear();
            true
        } else {
            false
        }
    }

    pub fn is_restricted(&self, a: AgentId) -> bool {
        self.restricted.contains_key(&a)
    }

    /// Can this agent's server writes (health refreshes, registrations)
    /// commit right now? Unrestricted agents always can; a restricted
    /// agent can only while the current raft leader is in its reachable
    /// set — reaching a minority follower is not enough to commit, the
    /// exact behavior of real consul under an asymmetric split.
    pub fn agent_reaches_leader(&self, a: AgentId) -> bool {
        match self.restricted.get(&a) {
            None => true,
            Some(servers) => self
                .leader_index()
                .map(|l| servers.contains(&(l as u32)))
                .unwrap_or(false),
        }
    }

    fn crosses_partition(&self, from: AgentId, to: AgentId) -> bool {
        !self.partitioned.is_empty()
            && self.partitioned.contains(&from) != self.partitioned.contains(&to)
    }

    pub fn now(&self) -> SimTime {
        self.now
    }

    fn push(&mut self, at: SimTime, wire: Wire) {
        let slot = match self.free_slots.pop_front() {
            Some(s) => {
                self.payloads[s] = Some(wire);
                s
            }
            None => {
                self.payloads.push(Some(wire));
                self.payloads.len() - 1
            }
        };
        self.queue.push(Reverse((at, self.seq, slot)));
        self.seq += 1;
    }

    fn send_raft(&mut self, from: u32, msgs: Vec<(u32, RaftMsg)>) {
        for (to, msg) in msgs {
            self.raft_msgs += 1;
            self.push(self.now + self.rpc_delay, Wire::Raft { from, to, msg });
        }
    }

    fn send_gossip(&mut self, from: AgentId, msgs: Vec<(AgentId, GossipMsg)>) {
        for (to, msg) in msgs {
            self.gossip_msgs += 1;
            self.push(self.now + self.rpc_delay, Wire::Gossip { from, to, msg });
        }
    }

    fn apply_committed(&mut self) {
        for s in &mut self.servers {
            for entry in s.raft.take_applied() {
                s.kv.apply(&entry.command);
            }
        }
    }

    /// Drive all protocol activity up to `to`.
    pub fn advance(&mut self, to: SimTime) {
        while self.now < to {
            // next interesting instant: message delivery or tick
            let next_msg = self.queue.peek().map(|Reverse((t, ..))| *t);
            let next = match next_msg {
                Some(t) if t <= self.next_tick => t,
                _ => self.next_tick,
            };
            if next > to {
                break;
            }
            self.now = next;

            // deliver everything due now
            while let Some(&Reverse((t, _, slot))) = self.queue.peek() {
                if t > self.now {
                    break;
                }
                self.queue.pop();
                let wire = self.payloads[slot].take().expect("payload");
                self.free_slots.push_back(slot);
                match wire {
                    Wire::Raft { from, to, msg } => {
                        if (to as usize) < self.servers.len() {
                            let now = self.now;
                            let out = self.servers[to as usize].raft.on_message(now, from, msg);
                            self.send_raft(to, out);
                        }
                    }
                    Wire::Gossip { from, to, msg } => {
                        if self.crosses_partition(from, to) {
                            self.gossip_dropped += 1;
                            continue;
                        }
                        if let Some(agent) = self.agents.get_mut(&to) {
                            let now = self.now;
                            let out = agent.on_message(now, from, msg);
                            self.send_gossip(to, out);
                        }
                    }
                }
            }

            // ticks
            if self.now >= self.next_tick {
                self.next_tick = self.now + self.tick_interval;
                for i in 0..self.servers.len() {
                    let now = self.now;
                    let out = self.servers[i].raft.tick(now);
                    self.send_raft(i as u32, out);
                }
                let mut ids: Vec<AgentId> = self.agents.keys().copied().collect(); // lint: sorted
                ids.sort();
                for id in ids {
                    let now = self.now;
                    let out = self.agents.get_mut(&id).unwrap().tick(now);
                    self.send_gossip(id, out);
                }
                // retry backlog once a leader exists
                if let Some(l) = self.leader_index() {
                    while let Some(cmd) = self.backlog.pop_front() {
                        let now = self.now;
                        if let Some((_, msgs)) = self.servers[l].raft.propose(cmd.clone(), now) {
                            self.send_raft(l as u32, msgs);
                        } else {
                            self.backlog.push_front(cmd);
                            break;
                        }
                    }
                }
            }
            self.apply_committed();
        }
        self.now = self.now.max(to);
        self.apply_committed();
    }

    /// Advance until a leader exists; returns the election time.
    pub fn advance_until_leader(&mut self, deadline: SimTime) -> Result<SimTime, ConsulError> {
        while self.now < deadline {
            if self.leader_index().is_some() {
                return Ok(self.now);
            }
            let next = self.now + self.tick_interval;
            self.advance(next);
        }
        self.leader_index().map(|_| self.now).ok_or(ConsulError::NoLeader)
    }

    pub fn leader_index(&self) -> Option<usize> {
        self.servers.iter().position(|s| s.raft.is_leader())
    }

    /// The consistent view (leader's kv; falls back to server 0).
    pub fn kv(&self) -> &KvStore {
        let idx = self.leader_index().unwrap_or(0);
        &self.servers[idx].kv
    }

    /// Submit a write (queued until a leader exists, like retry loops in
    /// real consul clients).
    pub fn submit(&mut self, cmd: Command) {
        match self.leader_index() {
            Some(l) => {
                let now = self.now;
                if let Some((_, msgs)) = self.servers[l].raft.propose(cmd.clone(), now) {
                    self.send_raft(l as u32, msgs);
                } else {
                    self.backlog.push_back(cmd);
                }
            }
            None => self.backlog.push_back(cmd),
        }
    }

    /// Register a service instance + its TTL health check.
    pub fn register_service(&mut self, service: &str, entry: &ServiceEntry, ttl: SimTime) {
        self.submit(Catalog::register_cmd(service, entry));
        self.health.register(entry.node.clone(), ttl, self.now);
    }

    pub fn deregister_service(&mut self, service: &str, node: &str) {
        self.submit(Catalog::deregister_cmd(service, node));
        self.health.deregister(node);
    }

    /// Healthy instances of a service (catalog ∩ passing checks).
    pub fn healthy_instances(&self, service: &str) -> Vec<ServiceEntry> {
        let passing: Vec<&str> = self.health.passing(self.now);
        Catalog::list(self.kv(), service)
            .into_iter()
            .filter(|e| passing.contains(&e.node.as_str()))
            .collect()
    }

    // ----- gossip agent pool -----

    /// Create an agent and join via a seed agent (or standalone if none).
    pub fn agent_join(&mut self, id: AgentId, seed_agent: Option<AgentId>, seed: u64) {
        let mut node = GossipNode::new(id, seed);
        if let Some(s) = seed_agent {
            let now = self.now;
            let msgs = node.join(s, now);
            self.agents.insert(id, node);
            self.send_gossip(id, msgs);
        } else {
            self.agents.insert(id, node);
        }
    }

    pub fn agent_remove(&mut self, id: AgentId) {
        self.agents.remove(&id);
    }

    pub fn agent(&self, id: AgentId) -> Option<&GossipNode> {
        self.agents.get(&id)
    }

    pub fn agent_count(&self) -> usize {
        self.agents.len()
    }

    /// Heartbeat an agent's health check. Returns false when no such
    /// check is registered — i.e. it was reaped while the agent was
    /// unreachable and the agent must re-register.
    pub fn refresh_health(&mut self, node: &str) -> bool {
        let now = self.now;
        self.health.refresh(node, now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vnet::addr::Ipv4;

    fn entry(node: &str, oct: u8) -> ServiceEntry {
        ServiceEntry {
            node: node.into(),
            address: Ipv4::new(10, 10, 0, oct),
            port: 22,
            slots: 12,
            tags: vec![],
        }
    }

    #[test]
    fn elects_leader_and_commits_registration() {
        let mut c = ConsulCluster::new(3, 42);
        let t = c.advance_until_leader(SimTime::from_secs(30)).unwrap();
        assert!(t < SimTime::from_secs(5), "election took {t}");
        c.register_service("hpc", &entry("node02", 2), SimTime::from_secs(30));
        c.advance(c.now() + SimTime::from_secs(1));
        let list = Catalog::list(c.kv(), "hpc");
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].node, "node02");
    }

    #[test]
    fn writes_before_leader_are_backlogged() {
        let mut c = ConsulCluster::new(3, 7);
        c.register_service("hpc", &entry("node02", 2), SimTime::from_secs(30));
        c.register_service("hpc", &entry("node03", 3), SimTime::from_secs(30));
        c.advance(SimTime::from_secs(10));
        assert_eq!(Catalog::list(c.kv(), "hpc").len(), 2);
    }

    #[test]
    fn replicas_converge() {
        let mut c = ConsulCluster::new(3, 9);
        c.advance_until_leader(SimTime::from_secs(30)).unwrap();
        c.register_service("hpc", &entry("a", 2), SimTime::from_secs(30));
        c.advance(c.now() + SimTime::from_secs(2));
        for s in &c.servers {
            assert_eq!(Catalog::list(&s.kv, "hpc").len(), 1, "replica divergence");
        }
    }

    #[test]
    fn health_gates_instances() {
        let mut c = ConsulCluster::new(1, 3);
        c.advance_until_leader(SimTime::from_secs(30)).unwrap();
        c.register_service("hpc", &entry("node02", 2), SimTime::from_secs(5));
        c.advance(c.now() + SimTime::from_secs(1));
        assert_eq!(c.healthy_instances("hpc").len(), 1);
        // stop heartbeating: after TTL the instance drops out
        c.advance(c.now() + SimTime::from_secs(10));
        assert_eq!(c.healthy_instances("hpc").len(), 0);
        // but a refresh brings it back
        c.refresh_health("node02");
        assert_eq!(c.healthy_instances("hpc").len(), 1);
    }

    #[test]
    fn agents_gossip_membership() {
        let mut c = ConsulCluster::new(1, 11);
        c.agent_join(AgentId::new(0), None, 11);
        c.agent_join(AgentId::new(1), Some(AgentId::new(0)), 11);
        c.agent_join(AgentId::new(2), Some(AgentId::new(0)), 11);
        c.advance(SimTime::from_secs(30));
        let a0 = c.agent(AgentId::new(0)).unwrap();
        assert_eq!(a0.alive_members().len(), 2);
        let a2 = c.agent(AgentId::new(2)).unwrap();
        assert!(a2.alive_members().contains(&AgentId::new(1)));
    }

    #[test]
    fn partition_blocks_gossip_until_healed() {
        use super::super::gossip::MemberState;
        let mut c = ConsulCluster::new(1, 21);
        c.agent_join(AgentId::new(0), None, 21);
        for i in 1..4 {
            c.agent_join(AgentId::new(i), Some(AgentId::new(0)), 21);
        }
        c.advance(SimTime::from_secs(30));
        assert_eq!(c.agent(AgentId::new(0)).unwrap().alive_members().len(), 3);
        // cut agent 3 off
        c.set_partition([AgentId::new(3)]);
        assert!(c.is_partitioned(AgentId::new(3)));
        c.advance(c.now() + SimTime::from_secs(60));
        let st = c.agent(AgentId::new(0)).unwrap().member_state(AgentId::new(3));
        assert!(
            matches!(st, Some(MemberState::Dead) | Some(MemberState::Suspect)),
            "partitioned agent still looks alive: {st:?}"
        );
        assert!(c.gossip_dropped > 0, "no traffic was dropped at the boundary");
        // heal: reconnect syncs re-merge the views
        c.heal_partition();
        c.advance(c.now() + SimTime::from_secs(300));
        assert_eq!(
            c.agent(AgentId::new(0)).unwrap().member_state(AgentId::new(3)),
            Some(MemberState::Alive),
            "agent 3 never rejoined after the heal"
        );
    }

    #[test]
    fn partial_partition_gates_writes_on_leader_reachability() {
        let mut c = ConsulCluster::new(3, 17);
        c.advance_until_leader(SimTime::from_secs(30)).unwrap();
        let leader = c.leader_index().unwrap() as u32;
        let others: Vec<u32> = (0..3).filter(|s| *s != leader).collect();
        let a = AgentId::new(9);
        assert!(c.agent_reaches_leader(a), "unrestricted agents always write");
        let epoch = c.set_partial_partition([a], others);
        assert!(c.is_restricted(a));
        assert!(
            !c.agent_reaches_leader(a),
            "reaching only minority followers must not commit writes"
        );
        // a reachable set containing the leader can write through the
        // partial partition
        c.restrict_agent(a, vec![leader]);
        assert!(c.agent_reaches_leader(a));
        assert!(c.heal_partial_partition_epoch(epoch));
        assert!(!c.is_restricted(a));
        // a stale heal timer cannot clear a newer partial partition
        let e2 = c.set_partial_partition([a], vec![]);
        assert!(!c.heal_partial_partition_epoch(e2.wrapping_sub(1)));
        assert!(c.is_restricted(a));
        assert!(!c.agent_reaches_leader(a), "an empty reachable set reaches no leader");
    }

    #[test]
    fn stale_heal_timer_cannot_clear_a_newer_partition() {
        let mut c = ConsulCluster::new(1, 5);
        let first = c.set_partition([AgentId::new(1)]);
        let second = c.set_partition([AgentId::new(2)]);
        assert_ne!(first, second);
        // the first partition's heal timer fires after its split was
        // already replaced: the active partition must survive
        assert!(!c.heal_partition_epoch(first));
        assert!(c.is_partitioned(AgentId::new(2)));
        assert!(c.heal_partition_epoch(second));
        assert!(!c.is_partitioned(AgentId::new(2)));
    }

    #[test]
    fn deregister_removes_from_catalog() {
        let mut c = ConsulCluster::new(3, 13);
        c.advance_until_leader(SimTime::from_secs(30)).unwrap();
        c.register_service("hpc", &entry("a", 2), SimTime::from_secs(30));
        c.advance(c.now() + SimTime::from_secs(1));
        c.deregister_service("hpc", "a");
        c.advance(c.now() + SimTime::from_secs(1));
        assert!(Catalog::list(c.kv(), "hpc").is_empty());
    }
}
