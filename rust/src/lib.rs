//! vhpc — a virtual HPC cluster with auto-scaling, built on a simulated
//! container runtime ("dockyard"), a SWIM+Raft service-discovery substrate
//! ("consul"), a virtual network fabric, and an MPI runtime whose per-rank
//! compute is AOT-compiled JAX/Pallas executed through PJRT.
//!
//! Reproduction of: Yu & Huang, "Building a Virtual HPC Cluster with Auto
//! Scaling by the Docker", CS.DC 2015.

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod consul;
pub mod dockyard;
pub mod faults;
pub mod ha;
pub mod hw;
pub mod lint;
pub mod mpi;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod tenancy;
pub mod util;
pub mod vnet;
pub mod workloads;
