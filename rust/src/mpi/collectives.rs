//! Analytic cost models for the collective algorithms in `comm`.
//!
//! Used by the benches to decompose measured job time into algorithmic
//! terms (tree depth × per-hop cost) and by DESIGN.md's roofline
//! estimates. The models match the implementations: binomial trees for
//! barrier/bcast/reduce, recursive doubling for power-of-two allreduce.

use crate::sim::SimTime;

/// ⌈log2 n⌉ — the binomial tree depth.
pub fn tree_depth(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as u32
    }
}

/// Predicted barrier time: two binomial phases of empty messages.
pub fn barrier_cost(n: usize, hop: SimTime) -> SimTime {
    SimTime::from_nanos(2 * tree_depth(n) as u64 * hop.as_nanos())
}

/// Predicted bcast time for a payload with one-way cost `msg`.
pub fn bcast_cost(n: usize, msg: SimTime) -> SimTime {
    SimTime::from_nanos(tree_depth(n) as u64 * msg.as_nanos())
}

/// Predicted allreduce (recursive doubling): log2(n) exchange rounds.
pub fn allreduce_cost(n: usize, msg: SimTime) -> SimTime {
    SimTime::from_nanos(tree_depth(n) as u64 * msg.as_nanos())
}

/// 5-point stencil halo-exchange volume per rank per step (bytes), for a
/// px×py decomposition of an H×W grid with f32 cells.
pub fn halo_bytes(h: usize, w: usize, px: usize, py: usize) -> u64 {
    let local_h = h / px;
    let local_w = w / py;
    // up to 4 edges; interior ranks exchange all 4
    (2 * (local_h + local_w) * 4) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_depth_values() {
        assert_eq!(tree_depth(1), 0);
        assert_eq!(tree_depth(2), 1);
        assert_eq!(tree_depth(3), 2);
        assert_eq!(tree_depth(4), 2);
        assert_eq!(tree_depth(16), 4);
        assert_eq!(tree_depth(17), 5);
    }

    #[test]
    fn costs_scale_with_depth() {
        let hop = SimTime::from_micros(15);
        assert!(barrier_cost(16, hop) > barrier_cost(4, hop));
        assert_eq!(bcast_cost(16, hop).as_nanos(), 4 * 15_000);
        assert_eq!(allreduce_cost(2, hop), hop);
    }

    #[test]
    fn halo_volume() {
        // 1024x256 grid on 16 ranks as 4x4: local 256x64 -> 2*(256+64)*4 B
        assert_eq!(halo_bytes(1024, 256, 4, 4), 2560);
    }
}
