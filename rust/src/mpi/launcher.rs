//! mpirun: place ranks onto containers per the hostfile and run the job
//! function on one thread per rank (§IV Fig. 8's `mpirun -np 16
//! --hostfile ...`).

use super::comm::{CommStats, MpiComm, MpiWorldBuilder};
use super::hostfile::Hostfile;
use crate::sim::SimTime;
use crate::util::ids::ContainerId;
use crate::vnet::addr::Ipv4;
use crate::vnet::fabric::Fabric;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
use thiserror::Error;

#[derive(Debug, Error)]
pub enum LaunchError {
    #[error("hostfile address {0} maps to no container")]
    UnknownHost(Ipv4),
    #[error("rank {rank} panicked")]
    RankPanic { rank: usize },
    #[error("built without the `pjrt` feature: real-compute jobs are unavailable")]
    ComputeUnavailable,
}

/// Everything mpirun needs.
pub struct LaunchPlan {
    pub hostfile: Hostfile,
    pub n_ranks: usize,
    /// container IP -> container id (from the cluster's bridge state).
    pub ip_to_container: HashMap<Ipv4, ContainerId>,
    pub fabric: Arc<Mutex<Fabric>>,
    pub eager_threshold: usize,
}

/// Per-rank result.
#[derive(Debug)]
pub struct RankOutcome<R> {
    pub rank: usize,
    pub container: ContainerId,
    pub result: R,
    pub stats: CommStats,
    pub wall: Duration,
}

/// Aggregate job report.
#[derive(Debug)]
pub struct JobReport<R> {
    pub ranks: Vec<RankOutcome<R>>,
    pub wall: Duration,
}

impl<R> JobReport<R> {
    /// Slowest rank's virtual communication clock.
    pub fn comm_time(&self) -> SimTime {
        self.ranks
            .iter()
            .map(|r| r.stats.comm_time)
            .max()
            .unwrap_or(SimTime::ZERO)
    }

    pub fn total_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.stats.bytes_sent).sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.ranks.iter().map(|r| r.stats.msgs_sent).sum()
    }
}

/// Run `job` across `plan.n_ranks` ranks. The closure receives the rank's
/// communicator; its return value is collected per rank.
pub fn mpirun<R, F>(plan: &LaunchPlan, job: F) -> Result<JobReport<R>, LaunchError>
where
    R: Send + 'static,
    F: Fn(&mut MpiComm) -> R + Send + Sync + Clone + 'static,
{
    // rank -> container via hostfile slot order
    let placement_ips = plan.hostfile.place(plan.n_ranks);
    let mut containers = Vec::with_capacity(plan.n_ranks);
    for ip in &placement_ips {
        let c = plan
            .ip_to_container
            .get(ip)
            .copied()
            .ok_or(LaunchError::UnknownHost(*ip))?;
        containers.push(c);
    }

    let comms = MpiWorldBuilder::new(plan.n_ranks)
        .containers(containers.clone())
        .fabric(plan.fabric.clone())
        .eager_threshold(plan.eager_threshold)
        .build();

    let started = Instant::now();
    let handles: Vec<_> = comms
        .into_iter()
        .map(|mut comm| {
            let job = job.clone();
            std::thread::Builder::new()
                .name(format!("mpi-rank-{}", comm.rank))
                .spawn(move || {
                    let t0 = Instant::now();
                    let result = job(&mut comm);
                    (comm.rank, comm.container(), result, comm.stats.clone(), t0.elapsed())
                })
                .expect("spawn rank thread")
        })
        .collect();

    let mut ranks = Vec::with_capacity(plan.n_ranks);
    for h in handles {
        match h.join() {
            Ok((rank, container, result, stats, wall)) => {
                ranks.push(RankOutcome { rank, container, result, stats, wall })
            }
            Err(_) => return Err(LaunchError::RankPanic { rank: usize::MAX }),
        }
    }
    ranks.sort_by_key(|r| r.rank);
    Ok(JobReport { ranks, wall: started.elapsed() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::rack::Plant;
    use crate::mpi::comm::ReduceOp;
    use crate::util::ids::MachineId;
    use crate::vnet::bridge::BridgeMode;

    fn plan(n_ranks: usize) -> LaunchPlan {
        // the paper's 2-container hostfile
        let hostfile = Hostfile::parse("10.10.0.2 slots=12\n10.10.0.3 slots=12\n").unwrap();
        let plant = Plant::paper_testbed();
        let mut fabric = Fabric::from_plant(&plant, BridgeMode::Bridge0);
        let c2 = ContainerId::new(0);
        let c3 = ContainerId::new(1);
        fabric.place(c2, MachineId::new(1));
        fabric.place(c3, MachineId::new(2));
        let mut ip_to_container = HashMap::new();
        ip_to_container.insert(Ipv4::parse("10.10.0.2").unwrap(), c2);
        ip_to_container.insert(Ipv4::parse("10.10.0.3").unwrap(), c3);
        LaunchPlan {
            hostfile,
            n_ranks,
            ip_to_container,
            fabric: Arc::new(Mutex::new(fabric)),
            eager_threshold: 64 * 1024,
        }
    }

    #[test]
    fn sixteen_rank_job_runs_and_reduces() {
        // Fig. 8's shape: 16 domains on 2 containers.
        let p = plan(16);
        let report = mpirun(&p, |c| {
            let mut v = vec![1.0f32];
            c.allreduce(ReduceOp::Sum, &mut v);
            v[0]
        })
        .unwrap();
        assert_eq!(report.ranks.len(), 16);
        for r in &report.ranks {
            assert_eq!(r.result, 16.0);
        }
        // 12 ranks on the first container, 4 on the second
        let on_c0 = report.ranks.iter().filter(|r| r.container == ContainerId::new(0)).count();
        assert_eq!(on_c0, 12);
        assert!(report.comm_time() > SimTime::ZERO);
        assert!(report.total_msgs() > 0);
    }

    #[test]
    fn unknown_host_fails_cleanly() {
        let mut p = plan(2);
        p.ip_to_container.clear();
        assert!(matches!(
            mpirun(&p, |_c| 0).unwrap_err(),
            LaunchError::UnknownHost(_)
        ));
    }

    #[test]
    fn rank_results_are_ordered() {
        let p = plan(8);
        let report = mpirun(&p, |c| c.rank * 10).unwrap();
        for (i, r) in report.ranks.iter().enumerate() {
            assert_eq!(r.rank, i);
            assert_eq!(r.result, i * 10);
        }
    }
}
