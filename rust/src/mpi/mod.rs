//! MPI runtime over the virtual fabric.
//!
//! Ranks are real OS threads exchanging real data through channels; every
//! message additionally *charges virtual communication time* against the
//! fabric model (NIC, bridge mode, NAT), so a job reports both its real
//! compute wall-clock and the interconnect time the paper's testbed would
//! have spent. Eager/rendezvous protocol switch, tree/ring collectives.

pub mod collectives;
pub mod comm;
pub mod hostfile;
pub mod launcher;

pub use comm::{CommStats, MpiComm, MpiWorldBuilder, ReduceOp};
pub use hostfile::{HostSlot, Hostfile};
pub use launcher::{mpirun, LaunchPlan, RankOutcome};
