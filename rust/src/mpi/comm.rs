//! Communicator: point-to-point + virtual-time accounting.
//!
//! Data moves through unbounded channels (threads never deadlock on
//! buffer space — MPI "eager" semantics); every message also carries the
//! virtual time at which it would have arrived over the modeled fabric.
//! A receive completes, in virtual time, at
//! `max(local_clock, sender_send_clock + transfer_time)` — conservative
//! PDES bookkeeping that is exact for blocking point-to-point programs.
//! Messages above the eager threshold pay an extra rendezvous RTT, as
//! OpenMPI's would.

use crate::sim::SimTime;
use crate::util::ids::ContainerId;
use crate::vnet::fabric::Fabric;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// Reduction operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
}

impl ReduceOp {
    fn apply(&self, acc: &mut [f32], x: &[f32]) {
        debug_assert_eq!(acc.len(), x.len());
        match self {
            ReduceOp::Sum => acc.iter_mut().zip(x).for_each(|(a, b)| *a += b),
            ReduceOp::Max => acc.iter_mut().zip(x).for_each(|(a, b)| *a = a.max(*b)),
            ReduceOp::Min => acc.iter_mut().zip(x).for_each(|(a, b)| *a = a.min(*b)),
        }
    }
}

#[derive(Debug)]
struct Packet {
    src: usize,
    tag: u64,
    data: Vec<u8>,
    arrival: SimTime,
}

/// Per-rank traffic statistics.
#[derive(Debug, Clone, Default)]
pub struct CommStats {
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    pub msgs_recv: u64,
    pub bytes_recv: u64,
    /// Virtual communication clock at the end of the run.
    pub comm_time: SimTime,
}

/// One rank's endpoint.
pub struct MpiComm {
    pub rank: usize,
    pub size: usize,
    containers: Arc<Vec<ContainerId>>,
    rx: Receiver<Packet>,
    txs: Vec<Sender<Packet>>,
    fabric: Arc<Mutex<Fabric>>,
    vtime: SimTime,
    stash: Vec<Packet>,
    coll_seq: u64,
    /// Per-destination affine cost cache (§Perf: the steady-state send
    /// path never takes the fabric lock).
    cost_cache: Vec<Option<crate::vnet::fabric::CostParams>>,
    /// Messages larger than this pay a rendezvous round trip.
    pub eager_threshold: usize,
    /// Software send/recv overhead per message.
    pub sw_overhead: SimTime,
    pub stats: CommStats,
}

/// Internal tag space for collectives.
const COLL_TAG_BASE: u64 = 1 << 32;

impl MpiComm {
    pub fn container(&self) -> ContainerId {
        self.containers[self.rank]
    }

    /// Current virtual communication clock.
    pub fn vtime(&self) -> SimTime {
        self.vtime
    }

    /// Advance the local virtual clock (e.g. to charge compute time into
    /// the same timeline when a bench wants a single clock).
    pub fn advance_vtime(&mut self, dt: SimTime) {
        self.vtime += dt;
    }

    fn transfer_cost(&mut self, dst: usize, bytes: usize) -> SimTime {
        let params = match self.cost_cache[dst] {
            Some(p) => p,
            None => {
                let fabric = self.fabric.lock().unwrap();
                let p = fabric
                    .cost_params(self.containers[self.rank], self.containers[dst])
                    .expect("ranks must be placed");
                drop(fabric);
                self.cost_cache[dst] = Some(p);
                p
            }
        };
        let mut t = params.time(bytes as u64);
        if bytes > self.eager_threshold {
            // rendezvous: RTS/CTS handshake before the payload moves
            let hs = params.time(0);
            t = t + hs + hs;
        }
        t
    }

    /// Post a send (returns immediately; eager buffering).
    pub fn send(&mut self, dst: usize, tag: u64, data: &[u8]) {
        assert!(dst < self.size, "rank {dst} out of range");
        self.vtime += self.sw_overhead;
        let cost = self.transfer_cost(dst, data.len());
        let arrival = self.vtime + cost;
        self.stats.msgs_sent += 1;
        self.stats.bytes_sent += data.len() as u64;
        self.txs[dst]
            .send(Packet { src: self.rank, tag, data: data.to_vec(), arrival })
            .expect("receiver hung up");
    }

    /// Blocking receive matching (src, tag).
    pub fn recv(&mut self, src: usize, tag: u64) -> Vec<u8> {
        // check the stash first
        if let Some(pos) = self.stash.iter().position(|p| p.src == src && p.tag == tag) {
            let p = self.stash.remove(pos);
            return self.complete_recv(p);
        }
        loop {
            let p = self.rx.recv().expect("world dropped");
            if p.src == src && p.tag == tag {
                return self.complete_recv(p);
            }
            self.stash.push(p);
        }
    }

    fn complete_recv(&mut self, p: Packet) -> Vec<u8> {
        self.vtime = self.vtime.max(p.arrival) + self.sw_overhead;
        self.stats.msgs_recv += 1;
        self.stats.bytes_recv += p.data.len() as u64;
        self.stats.comm_time = self.vtime;
        p.data
    }

    /// Send and receive in one call (exchange pattern, deadlock-free).
    pub fn sendrecv(
        &mut self,
        dst: usize,
        send_tag: u64,
        data: &[u8],
        src: usize,
        recv_tag: u64,
    ) -> Vec<u8> {
        self.send(dst, send_tag, data);
        self.recv(src, recv_tag)
    }

    // ---- f32 helpers ----

    pub fn send_f32(&mut self, dst: usize, tag: u64, data: &[f32]) {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.send(dst, tag, &bytes);
    }

    pub fn recv_f32(&mut self, src: usize, tag: u64) -> Vec<f32> {
        let bytes = self.recv(src, tag);
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    // ---- collectives (binomial trees / recursive doubling) ----

    fn next_coll_tag(&mut self) -> u64 {
        self.coll_seq += 1;
        COLL_TAG_BASE + self.coll_seq
    }

    /// Barrier: binomial-tree reduce to rank 0, then broadcast. Also
    /// synchronizes virtual clocks (all ranks leave at the global max).
    pub fn barrier(&mut self) {
        let tag = self.next_coll_tag();
        // reduce phase
        let mut mask = 1;
        while mask < self.size {
            if self.rank & mask != 0 {
                let dst = self.rank & !mask;
                self.send(dst, tag, &[]);
                break;
            } else if self.rank | mask < self.size {
                let src = self.rank | mask;
                self.recv(src, tag);
            }
            mask <<= 1;
        }
        // broadcast phase (binomial release from rank 0)
        let lowest_bit =
            if self.rank == 0 { usize::MAX } else { self.rank & self.rank.wrapping_neg() };
        if self.rank != 0 {
            let src = self.rank & !lowest_bit;
            self.recv(src, tag + 1);
        }
        let mut m = {
            let mut mm = 1;
            while mm < self.size {
                mm <<= 1;
            }
            mm >> 1
        };
        while m > 0 {
            if m < lowest_bit {
                let dst = self.rank | m;
                if dst != self.rank && dst < self.size {
                    self.send(dst, tag + 1, &[]);
                }
            }
            m >>= 1;
        }
        self.coll_seq += 1; // consumed tag+1 too
    }

    /// Broadcast `data` from `root` (binomial tree).
    pub fn bcast(&mut self, root: usize, data: &mut Vec<u8>) {
        let tag = self.next_coll_tag();
        // virtual rank with root mapped to 0
        let vrank = (self.rank + self.size - root) % self.size;
        // receive from parent (strip the lowest set bit)
        let lowest_bit = if vrank == 0 { usize::MAX } else { vrank & vrank.wrapping_neg() };
        if vrank != 0 {
            let vsrc = vrank & !lowest_bit;
            let src = (vsrc + root) % self.size;
            *data = self.recv(src, tag);
        }
        // forward to children vrank|m for m below our lowest set bit
        let mut m = {
            let mut mm = 1;
            while mm < self.size {
                mm <<= 1;
            }
            mm >> 1
        };
        while m > 0 {
            if m < lowest_bit {
                let vdst = vrank | m;
                if vdst != vrank && vdst < self.size {
                    let dst = (vdst + root) % self.size;
                    self.send(dst, tag, data);
                }
            }
            m >>= 1;
        }
    }

    /// Reduce element-wise into rank `root` (binomial tree). All ranks
    /// pass their contribution; only root's buffer holds the result.
    pub fn reduce(&mut self, root: usize, op: ReduceOp, data: &mut [f32]) {
        let tag = self.next_coll_tag();
        let vrank = (self.rank + self.size - root) % self.size;
        let mut mask = 1;
        while mask < self.size {
            if vrank & mask != 0 {
                let vdst = vrank & !mask;
                let dst = (vdst + root) % self.size;
                self.send_f32(dst, tag, data);
                break;
            } else if vrank | mask < self.size {
                let vsrc = vrank | mask;
                let src = (vsrc + root) % self.size;
                let contrib = self.recv_f32(src, tag);
                op.apply(data, &contrib);
            }
            mask <<= 1;
        }
    }

    /// Allreduce = reduce to 0 + bcast (general) — recursive doubling for
    /// power-of-two sizes.
    pub fn allreduce(&mut self, op: ReduceOp, data: &mut Vec<f32>) {
        if self.size.is_power_of_two() && self.size > 1 {
            let tag = self.next_coll_tag();
            let mut mask = 1;
            while mask < self.size {
                let partner = self.rank ^ mask;
                let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
                let theirs = self.sendrecv(partner, tag, &bytes, partner, tag);
                let theirs: Vec<f32> = theirs
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                op.apply(data, &theirs);
                mask <<= 1;
            }
        } else {
            self.reduce(0, op, data);
            let mut bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
            self.bcast(0, &mut bytes);
            *data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
        }
    }

    /// Gather variable-size buffers at root (linear).
    pub fn gather(&mut self, root: usize, data: &[u8]) -> Option<Vec<Vec<u8>>> {
        let tag = self.next_coll_tag();
        if self.rank == root {
            let mut out: Vec<Vec<u8>> = vec![Vec::new(); self.size];
            out[root] = data.to_vec();
            for src in 0..self.size {
                if src != root {
                    out[src] = self.recv(src, tag);
                }
            }
            Some(out)
        } else {
            self.send(root, tag, data);
            None
        }
    }

    /// Allgather = gather at 0 + bcast of the concatenation.
    pub fn allgather(&mut self, data: &[u8]) -> Vec<Vec<u8>> {
        let gathered = self.gather(0, data);
        let mut blob: Vec<u8> = Vec::new();
        if self.rank == 0 {
            let parts = gathered.unwrap();
            for p in &parts {
                blob.extend((p.len() as u64).to_le_bytes());
                blob.extend(p);
            }
        }
        self.bcast(0, &mut blob);
        // decode
        let mut out = Vec::with_capacity(self.size);
        let mut off = 0;
        while off < blob.len() {
            let len = u64::from_le_bytes(blob[off..off + 8].try_into().unwrap()) as usize;
            off += 8;
            out.push(blob[off..off + len].to_vec());
            off += len;
        }
        out
    }

    /// Personalized all-to-all (pairwise exchange).
    pub fn alltoall(&mut self, data: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        assert_eq!(data.len(), self.size);
        let tag = self.next_coll_tag();
        let mut out: Vec<Vec<u8>> = vec![Vec::new(); self.size];
        out[self.rank] = data[self.rank].clone();
        for step in 1..self.size {
            let partner = self.rank ^ step;
            if partner < self.size {
                out[partner] = self.sendrecv(partner, tag, &data[partner], partner, tag);
            }
        }
        out
    }
}

/// Builds a world of `n` connected ranks.
pub struct MpiWorldBuilder {
    n: usize,
    containers: Vec<ContainerId>,
    fabric: Option<Arc<Mutex<Fabric>>>,
    eager_threshold: usize,
}

impl MpiWorldBuilder {
    pub fn new(n: usize) -> Self {
        Self {
            n,
            containers: (0..n as u32).map(ContainerId::new).collect(),
            fabric: None,
            eager_threshold: 64 * 1024,
        }
    }

    /// rank -> container placement (defaults to rank i in container i).
    pub fn containers(mut self, c: Vec<ContainerId>) -> Self {
        assert_eq!(c.len(), self.n);
        self.containers = c;
        self
    }

    pub fn fabric(mut self, f: Arc<Mutex<Fabric>>) -> Self {
        self.fabric = Some(f);
        self
    }

    pub fn eager_threshold(mut self, bytes: usize) -> Self {
        self.eager_threshold = bytes;
        self
    }

    pub fn build(self) -> Vec<MpiComm> {
        let fabric = self.fabric.expect("fabric required");
        let containers = Arc::new(self.containers);
        let mut txs = Vec::with_capacity(self.n);
        let mut rxs = Vec::with_capacity(self.n);
        for _ in 0..self.n {
            let (tx, rx) = channel();
            txs.push(tx);
            rxs.push(rx);
        }
        rxs.into_iter()
            .enumerate()
            .map(|(rank, rx)| MpiComm {
                rank,
                size: self.n,
                containers: containers.clone(),
                rx,
                txs: txs.clone(),
                fabric: fabric.clone(),
                vtime: SimTime::ZERO,
                stash: Vec::new(),
                coll_seq: 0,
                cost_cache: vec![None; self.n],
                eager_threshold: self.eager_threshold,
                sw_overhead: SimTime::from_nanos(500),
                stats: CommStats::default(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::rack::Plant;
    use crate::util::ids::MachineId;
    use crate::vnet::bridge::BridgeMode;

    /// World of n ranks in n containers spread over the 3-blade testbed.
    fn world(n: usize, mode: BridgeMode) -> Vec<MpiComm> {
        let plant = Plant::paper_testbed();
        let mut fabric = Fabric::from_plant(&plant, mode);
        for i in 0..n {
            fabric.place(ContainerId::new(i as u32), MachineId::new((i % 3) as u32));
        }
        MpiWorldBuilder::new(n)
            .fabric(Arc::new(Mutex::new(fabric)))
            .build()
    }

    fn run_all<F, R>(comms: Vec<MpiComm>, f: F) -> Vec<R>
    where
        F: Fn(&mut MpiComm) -> R + Send + Sync + Clone + 'static,
        R: Send + 'static,
    {
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                let f = f.clone();
                std::thread::spawn(move || f(&mut c))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn p2p_delivers_in_order_with_tags() {
        let comms = world(2, BridgeMode::Bridge0);
        let out = run_all(comms, |c| {
            if c.rank == 0 {
                c.send(1, 7, b"hello");
                c.send(1, 8, b"world");
                Vec::new()
            } else {
                // receive out of tag order to exercise the stash
                let b = c.recv(0, 8);
                let a = c.recv(0, 7);
                vec![a, b]
            }
        });
        assert_eq!(out[1], vec![b"hello".to_vec(), b"world".to_vec()]);
    }

    #[test]
    fn recv_advances_virtual_clock() {
        let comms = world(2, BridgeMode::Bridge0);
        let out = run_all(comms, |c| {
            if c.rank == 0 {
                c.send_f32(1, 1, &[1.0; 1024]);
                c.vtime().as_nanos()
            } else {
                c.recv_f32(0, 1);
                c.vtime().as_nanos()
            }
        });
        assert!(out[1] > out[0], "receiver clock {} <= sender {}", out[1], out[0]);
    }

    #[test]
    fn allreduce_matches_serial_sum() {
        for n in [2usize, 3, 4, 8] {
            let comms = world(n, BridgeMode::Bridge0);
            let out = run_all(comms, move |c| {
                let mut v = vec![c.rank as f32 + 1.0, 10.0 * (c.rank as f32 + 1.0)];
                c.allreduce(ReduceOp::Sum, &mut v);
                v
            });
            let want0: f32 = (1..=n).map(|r| r as f32).sum();
            for o in &out {
                assert!((o[0] - want0).abs() < 1e-4, "n={n}: {o:?}");
                assert!((o[1] - 10.0 * want0).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn allreduce_max_min() {
        let comms = world(4, BridgeMode::Bridge0);
        let out = run_all(comms, |c| {
            let mut mx = vec![c.rank as f32];
            c.allreduce(ReduceOp::Max, &mut mx);
            let mut mn = vec![c.rank as f32];
            c.allreduce(ReduceOp::Min, &mut mn);
            (mx[0], mn[0])
        });
        for &(mx, mn) in &out {
            assert_eq!(mx, 3.0);
            assert_eq!(mn, 0.0);
        }
    }

    #[test]
    fn bcast_from_every_root() {
        for root in 0..3usize {
            let comms = world(3, BridgeMode::Bridge0);
            let out = run_all(comms, move |c| {
                let mut data = if c.rank == root {
                    vec![42u8, root as u8]
                } else {
                    Vec::new()
                };
                c.bcast(root, &mut data);
                data
            });
            for o in &out {
                assert_eq!(o, &vec![42u8, root as u8], "root={root}");
            }
        }
    }

    #[test]
    fn reduce_at_nonzero_root() {
        let comms = world(5, BridgeMode::Bridge0);
        let out = run_all(comms, |c| {
            let mut v = vec![1.0f32];
            c.reduce(2, ReduceOp::Sum, &mut v);
            (c.rank, v[0])
        });
        for (rank, v) in out {
            if rank == 2 {
                assert_eq!(v, 5.0);
            }
        }
    }

    #[test]
    fn gather_and_allgather() {
        let comms = world(4, BridgeMode::Bridge0);
        let out = run_all(comms, |c| {
            let mine = vec![c.rank as u8; c.rank + 1]; // variable sizes
            let g = c.allgather(&mine);
            g
        });
        for o in &out {
            assert_eq!(o.len(), 4);
            for (r, part) in o.iter().enumerate() {
                assert_eq!(part, &vec![r as u8; r + 1]);
            }
        }
    }

    #[test]
    fn alltoall_exchanges_pairwise() {
        let n = 4usize;
        let comms = world(n, BridgeMode::Bridge0);
        let out = run_all(comms, move |c| {
            let data: Vec<Vec<u8>> = (0..n).map(|d| vec![(c.rank * 10 + d) as u8]).collect();
            c.alltoall(data)
        });
        for (me, o) in out.iter().enumerate() {
            for (src, part) in o.iter().enumerate() {
                assert_eq!(part, &vec![(src * 10 + me) as u8], "me={me} src={src}");
            }
        }
    }

    #[test]
    fn barrier_synchronizes_clocks() {
        let comms = world(4, BridgeMode::Bridge0);
        let out = run_all(comms, |c| {
            if c.rank == 0 {
                // rank 0 does a lot of fake compute first
                c.advance_vtime(SimTime::from_millis(50));
            }
            c.barrier();
            c.vtime().as_nanos()
        });
        let max = *out.iter().max().unwrap();
        for &t in &out {
            assert!(t >= 50_000_000, "rank left barrier before slowest entered");
            assert!((max - t) < 5_000_000, "clocks diverged: {out:?}");
        }
    }

    #[test]
    fn nat_world_charges_more_comm_time() {
        let run = |mode| {
            let comms = world(2, mode);
            let out = run_all(comms, |c| {
                if c.rank == 0 {
                    c.send_f32(1, 1, &vec![0f32; 1 << 18]);
                    0
                } else {
                    c.recv_f32(0, 1);
                    c.vtime().as_nanos()
                }
            });
            out[1]
        };
        let nat = run(BridgeMode::Docker0);
        let direct = run(BridgeMode::Bridge0);
        assert!(nat > direct, "nat={nat} direct={direct}");
    }

    #[test]
    fn stats_account_traffic() {
        let comms = world(2, BridgeMode::Bridge0);
        let out = run_all(comms, |c| {
            if c.rank == 0 {
                c.send(1, 1, &[0u8; 100]);
                c.stats.clone()
            } else {
                c.recv(0, 1);
                c.stats.clone()
            }
        });
        assert_eq!(out[0].msgs_sent, 1);
        assert_eq!(out[0].bytes_sent, 100);
        assert_eq!(out[1].msgs_recv, 1);
        assert_eq!(out[1].bytes_recv, 100);
    }
}
