//! OpenMPI-style hostfile: the artifact consul-template renders (§IV,
//! Fig. 5) and mpirun consumes.
//!
//! ```text
//! 10.10.0.2 slots=12
//! 10.10.0.3 slots=12
//! ```

use crate::vnet::addr::Ipv4;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum HostfileError {
    #[error("line {0}: bad host address")]
    BadAddr(usize),
    #[error("line {0}: bad slots value")]
    BadSlots(usize),
    #[error("hostfile has no hosts")]
    Empty,
}

/// One host line.
#[derive(Debug, Clone, PartialEq)]
pub struct HostSlot {
    pub addr: Ipv4,
    pub slots: u32,
}

/// A parsed hostfile.
#[derive(Debug, Clone, PartialEq)]
pub struct Hostfile {
    pub hosts: Vec<HostSlot>,
}

impl Hostfile {
    pub fn parse(text: &str) -> Result<Self, HostfileError> {
        let mut hosts = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let addr = Ipv4::parse(parts.next().unwrap())
                .map_err(|_| HostfileError::BadAddr(i + 1))?;
            let mut slots = 1u32;
            for opt in parts {
                if let Some(v) = opt.strip_prefix("slots=") {
                    slots = v.parse().map_err(|_| HostfileError::BadSlots(i + 1))?;
                }
            }
            hosts.push(HostSlot { addr, slots });
        }
        if hosts.is_empty() {
            return Err(HostfileError::Empty);
        }
        Ok(Self { hosts })
    }

    pub fn total_slots(&self) -> u32 {
        self.hosts.iter().map(|h| h.slots).sum()
    }

    /// Map `n_ranks` onto hosts by-slot (OpenMPI's default fill order:
    /// fill each host's slots before moving on; wrap if oversubscribed).
    pub fn place(&self, n_ranks: usize) -> Vec<Ipv4> {
        let mut placement = Vec::with_capacity(n_ranks);
        'outer: loop {
            for h in &self.hosts {
                for _ in 0..h.slots {
                    if placement.len() == n_ranks {
                        break 'outer;
                    }
                    placement.push(h.addr);
                }
            }
            if self.hosts.is_empty() {
                break;
            }
        }
        placement
    }

    pub fn render(&self) -> String {
        self.hosts
            .iter()
            .map(|h| format!("{} slots={}\n", h.addr, h.slots))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_render_roundtrip() {
        let text = "10.10.0.2 slots=12\n10.10.0.3 slots=12\n";
        let hf = Hostfile::parse(text).unwrap();
        assert_eq!(hf.hosts.len(), 2);
        assert_eq!(hf.total_slots(), 24);
        assert_eq!(hf.render(), text);
    }

    #[test]
    fn comments_and_default_slots() {
        let hf = Hostfile::parse("# head\n10.10.0.2\n").unwrap();
        assert_eq!(hf.hosts[0].slots, 1);
    }

    #[test]
    fn errors() {
        assert_eq!(Hostfile::parse("not-an-ip slots=2").unwrap_err(), HostfileError::BadAddr(1));
        assert_eq!(Hostfile::parse("10.0.0.1 slots=x").unwrap_err(), HostfileError::BadSlots(1));
        assert_eq!(Hostfile::parse("# nothing\n").unwrap_err(), HostfileError::Empty);
    }

    #[test]
    fn placement_fills_hosts_in_order() {
        let hf = Hostfile::parse("10.0.0.1 slots=2\n10.0.0.2 slots=2\n").unwrap();
        let p = hf.place(3);
        assert_eq!(
            p,
            vec![
                Ipv4::parse("10.0.0.1").unwrap(),
                Ipv4::parse("10.0.0.1").unwrap(),
                Ipv4::parse("10.0.0.2").unwrap()
            ]
        );
    }

    #[test]
    fn oversubscription_wraps() {
        let hf = Hostfile::parse("10.0.0.1 slots=1\n10.0.0.2 slots=1\n").unwrap();
        let p = hf.place(5);
        assert_eq!(p.len(), 5);
        assert_eq!(p[4], Ipv4::parse("10.0.0.1").unwrap());
    }

    /// The paper's Fig. 8: a 16-domain job on 2 containers (12 slots
    /// each) puts 12 ranks on node02 and 4 on node03.
    #[test]
    fn fig8_placement() {
        let hf = Hostfile::parse("10.10.0.2 slots=12\n10.10.0.3 slots=12\n").unwrap();
        let p = hf.place(16);
        let on2 = p.iter().filter(|a| a.octets()[3] == 2).count();
        let on3 = p.iter().filter(|a| a.octets()[3] == 3).count();
        assert_eq!((on2, on3), (12, 4));
    }
}
