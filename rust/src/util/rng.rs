//! Deterministic xorshift64* PRNG.
//!
//! Every stochastic component of the simulator (gossip probe selection,
//! boot-time jitter, workload generators) draws from an explicitly seeded
//! [`Rng`], so whole-cluster runs are reproducible from a single seed.

/// xorshift64* generator. Not cryptographic; deterministic and fast.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. A zero seed is remapped (xorshift
    /// requires non-zero state).
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        // Lemire-style rejection-free enough for simulation purposes.
        self.next_u64() % n
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli trial with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element, or `None` if empty.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> Option<&'a T> {
        if xs.is_empty() {
            None
        } else {
            Some(&xs[self.gen_range(xs.len() as u64) as usize])
        }
    }

    /// Exponentially distributed sample with the given mean.
    pub fn gen_exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.gen_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Derive an independent child generator (for splitting streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() | 1)
    }

    /// The raw generator state — pair with [`Rng::from_state`] to
    /// checkpoint a stream mid-flight and resume it elsewhere (the HA
    /// arrival-cursor machinery ships this through the WAL).
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator at an exact checkpointed state. A seeded
    /// generator can never reach state 0, so zero gets the same remap
    /// as [`Rng::new`] rather than wedging the xorshift.
    pub fn from_state(state: u64) -> Self {
        Self::new(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000] {
            for _ in 0..200 {
                assert!(r.gen_range(n) < n);
            }
        }
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_checkpoint_resumes_the_exact_stream() {
        let mut a = Rng::new(99);
        for _ in 0..57 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn exp_mean_roughly_correct() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gen_exp(5.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.2, "mean={mean}");
    }
}
