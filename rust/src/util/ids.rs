//! Typed integer ids. Newtypes prevent cross-wiring a `MachineId` into an
//! API expecting a `ContainerId` — the simulator routes everything by id.

/// Declare a `u32` id newtype with `new/raw/Display`.
#[macro_export]
macro_rules! typed_id {
    ($(#[$doc:meta])* $name:ident, $prefix:expr) => {
        $(#[$doc])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
        pub struct $name(pub u32);

        impl $name {
            pub fn new(v: u32) -> Self {
                Self(v)
            }
            pub fn raw(self) -> u32 {
                self.0
            }
        }

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, "{}{}", $prefix, self.0)
            }
        }
    };
}

typed_id!(
    /// A physical machine (blade) in the simulated datacenter.
    MachineId,
    "m"
);
typed_id!(
    /// A container instance managed by a dockyard engine.
    ContainerId,
    "c"
);
typed_id!(
    /// A consul agent (one per container or server).
    AgentId,
    "a"
);
typed_id!(
    /// An MPI job submitted to the head node.
    JobId,
    "job"
);
typed_id!(
    /// A network interface (veth end, bridge port, NIC).
    IfaceId,
    "if"
);

/// Monotonic id allocator.
#[derive(Debug, Default, Clone)]
pub struct IdGen {
    next: u32,
}

impl IdGen {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn next(&mut self) -> u32 {
        let v = self.next;
        self.next += 1;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_uses_prefix() {
        assert_eq!(MachineId::new(3).to_string(), "m3");
        assert_eq!(ContainerId::new(0).to_string(), "c0");
        assert_eq!(JobId::new(12).to_string(), "job12");
    }

    #[test]
    fn idgen_is_monotonic() {
        let mut g = IdGen::new();
        assert_eq!(g.next(), 0);
        assert_eq!(g.next(), 1);
        assert_eq!(g.next(), 2);
    }

    #[test]
    fn ids_are_ordered_and_hashable() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(MachineId::new(1));
        assert!(s.contains(&MachineId::new(1)));
        assert!(MachineId::new(1) < MachineId::new(2));
    }
}
