//! Foundational utilities: deterministic RNG, typed ids, size formatting.

pub mod bytes;
pub mod ids;
pub mod rng;

pub use bytes::{format_bytes, parse_bytes};
pub use rng::Rng;
