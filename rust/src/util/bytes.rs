//! Byte-size formatting and parsing ("146GB", "1.5MiB").

/// Format a byte count with binary units, e.g. `65536 -> "64.0 KiB"`.
pub fn format_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1} {}", UNITS[u])
}

/// Parse a human size: `"64"`, `"64K"`, `"1.5MiB"`, `"10GB"` (case
/// insensitive; decimal and binary suffixes both map to binary multiples,
/// which is what the container world colloquially means).
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let idx = s
        .find(|c: char| !(c.is_ascii_digit() || c == '.'))
        .unwrap_or(s.len());
    let (num, suffix) = s.split_at(idx);
    let value: f64 = num.parse().ok()?;
    let mult: u64 = match suffix.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kb" | "kib" => 1 << 10,
        "m" | "mb" | "mib" => 1 << 20,
        "g" | "gb" | "gib" => 1 << 30,
        "t" | "tb" | "tib" => 1u64 << 40,
        _ => return None,
    };
    Some((value * mult as f64) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats_units() {
        assert_eq!(format_bytes(0), "0 B");
        assert_eq!(format_bytes(512), "512 B");
        assert_eq!(format_bytes(65536), "64.0 KiB");
        assert_eq!(format_bytes(64 * 1 << 30), "64.0 GiB");
    }

    #[test]
    fn parses_suffixes() {
        assert_eq!(parse_bytes("64"), Some(64));
        assert_eq!(parse_bytes("64K"), Some(65536));
        assert_eq!(parse_bytes("1.5MiB"), Some((1.5 * 1048576.0) as u64));
        assert_eq!(parse_bytes("10GB"), Some(10 << 30));
        assert_eq!(parse_bytes("bogus"), None);
        assert_eq!(parse_bytes("10X"), None);
    }

    #[test]
    fn round_trip_whole_units() {
        for n in [1u64 << 10, 1 << 20, 1 << 30] {
            let s = format_bytes(n);
            let num: f64 = s.split(' ').next().unwrap().parse().unwrap();
            assert_eq!(num, 1.0, "{s}");
        }
    }
}
