//! Tiny benchmark harness (criterion is not in the offline crate set).
//!
//! Used by the `rust/benches/*.rs` targets (all `harness = false`): each
//! bench regenerates one of the paper's tables/figures as aligned text.

use std::time::{Duration, Instant};

/// Summary statistics over repeated timings.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    pub p50: Duration,
    pub p99: Duration,
}

/// Time `f` for `iters` iterations after `warmup` discarded runs.
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let pick = |p: f64| samples[((p * (samples.len() - 1) as f64).round() as usize).min(samples.len() - 1)];
    Stats {
        iters,
        mean: total / iters as u32,
        min: samples[0],
        max: *samples.last().unwrap(),
        p50: pick(0.50),
        p99: pick(0.99),
    }
}

/// Print an aligned table: each column sized to its widest cell.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|h| h.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

/// Section banner for bench output.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_produces_ordered_stats() {
        let s = time(1, 20, || std::thread::sleep(Duration::from_micros(50)));
        assert_eq!(s.iters, 20);
        assert!(s.min <= s.p50);
        assert!(s.p50 <= s.p99);
        assert!(s.p99 <= s.max);
        assert!(s.mean >= Duration::from_micros(40));
    }

    #[test]
    fn table_renders_without_panic() {
        print_table(
            &["a", "long-header"],
            &[vec!["1".into(), "2".into()], vec!["333333".into(), "4".into()]],
        );
    }
}
