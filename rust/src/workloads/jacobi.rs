//! Distributed 2-D Jacobi heat diffusion.
//!
//! The global (H, W) grid is decomposed onto a (px, py) rank grid; every
//! rank owns an n×n tile (square — it must match a `jacobi_step_n`
//! artifact), exchanges halos with its 4 neighbours each step, applies
//! the Pallas step kernel through PJRT, and the ranks allreduce the
//! squared residual every `check_every` steps. Dirichlet boundary: the
//! global north wall is held at 1.0, the rest at 0.0.

#[cfg(feature = "pjrt")]
use crate::mpi::comm::{MpiComm, ReduceOp};
#[cfg(feature = "pjrt")]
use crate::mpi::launcher::{mpirun, JobReport};
use crate::mpi::launcher::{LaunchError, LaunchPlan};
use crate::runtime::Runtime;
use crate::sim::SimTime;
use std::path::PathBuf;
use std::time::Duration;

/// Problem description.
#[derive(Debug, Clone)]
pub struct JacobiSpec {
    /// Rank grid (px rows × py cols); px*py = n_ranks.
    pub px: usize,
    pub py: usize,
    /// Local tile edge (must have a jacobi_step_{n} artifact).
    pub tile: usize,
    /// Maximum steps.
    pub steps: usize,
    /// Residual check (allreduce) cadence.
    pub check_every: usize,
    /// Stop when global squared residual falls below this.
    pub tol: f32,
    /// Artifacts directory.
    pub artifacts: PathBuf,
}

impl JacobiSpec {
    /// The paper's Fig. 8 shape: 16 domains (4×4), 64² tiles.
    pub fn fig8() -> Self {
        Self {
            px: 4,
            py: 4,
            tile: 64,
            steps: 200,
            check_every: 20,
            tol: 1e-6,
            artifacts: Runtime::default_dir(),
        }
    }

    pub fn n_ranks(&self) -> usize {
        self.px * self.py
    }

    pub fn global_shape(&self) -> (usize, usize) {
        (self.px * self.tile, self.py * self.tile)
    }
}

/// Per-rank result.
#[derive(Debug, Clone)]
pub struct RankResult {
    /// Final local interior (row-major tile×tile).
    pub interior: Vec<f32>,
    pub compute_wall: Duration,
    pub steps_run: usize,
}

/// Whole-job report.
#[derive(Debug)]
pub struct JacobiReport {
    pub steps_run: usize,
    pub final_residual: f32,
    /// (step, global squared residual) at each check.
    pub residual_curve: Vec<(usize, f32)>,
    pub comm_time: SimTime,
    pub wall: Duration,
    pub compute_wall_max: Duration,
    pub total_bytes: u64,
    pub total_msgs: u64,
    pub ranks: Vec<RankResult>,
}

#[cfg(feature = "pjrt")]
const DIR_N: u64 = 0;
#[cfg(feature = "pjrt")]
const DIR_S: u64 = 1;
#[cfg(feature = "pjrt")]
const DIR_W: u64 = 2;
#[cfg(feature = "pjrt")]
const DIR_E: u64 = 3;

#[cfg(feature = "pjrt")]
struct RankGrid {
    tile: usize,
    padded: Vec<f32>, // (tile+2)^2
}

#[cfg(feature = "pjrt")]
impl RankGrid {
    fn new(tile: usize, is_north_edge: bool) -> Self {
        let w = tile + 2;
        let mut padded = vec![0f32; w * w];
        if is_north_edge {
            for j in 0..w {
                padded[j] = 1.0; // hot wall
            }
        }
        Self { tile, padded }
    }

    fn w(&self) -> usize {
        self.tile + 2
    }

    fn top_row(&self) -> Vec<f32> {
        self.padded[self.w() + 1..self.w() + 1 + self.tile].to_vec()
    }
    fn bottom_row(&self) -> Vec<f32> {
        let w = self.w();
        self.padded[self.tile * w + 1..self.tile * w + 1 + self.tile].to_vec()
    }
    fn left_col(&self) -> Vec<f32> {
        let w = self.w();
        (1..=self.tile).map(|i| self.padded[i * w + 1]).collect()
    }
    fn right_col(&self) -> Vec<f32> {
        let w = self.w();
        (1..=self.tile).map(|i| self.padded[i * w + self.tile]).collect()
    }

    fn set_north_halo(&mut self, row: &[f32]) {
        self.padded[1..1 + self.tile].copy_from_slice(row);
    }
    fn set_south_halo(&mut self, row: &[f32]) {
        let w = self.w();
        let off = (self.tile + 1) * w + 1;
        self.padded[off..off + self.tile].copy_from_slice(row);
    }
    fn set_west_halo(&mut self, col: &[f32]) {
        let w = self.w();
        for (i, v) in col.iter().enumerate() {
            self.padded[(i + 1) * w] = *v;
        }
    }
    fn set_east_halo(&mut self, col: &[f32]) {
        let w = self.w();
        for (i, v) in col.iter().enumerate() {
            self.padded[(i + 1) * w + self.tile + 1] = *v;
        }
    }

    fn write_interior(&mut self, interior: &[f32]) {
        let w = self.w();
        for i in 0..self.tile {
            let src = &interior[i * self.tile..(i + 1) * self.tile];
            self.padded[(i + 1) * w + 1..(i + 1) * w + 1 + self.tile].copy_from_slice(src);
        }
    }

    fn interior(&self) -> Vec<f32> {
        let w = self.w();
        let mut out = Vec::with_capacity(self.tile * self.tile);
        for i in 1..=self.tile {
            out.extend_from_slice(&self.padded[i * w + 1..i * w + 1 + self.tile]);
        }
        out
    }
}

#[cfg(feature = "pjrt")]
fn exchange_halos(comm: &mut MpiComm, grid: &mut RankGrid, px: usize, py: usize, step: usize) {
    let r = comm.rank;
    let (ri, rj) = (r / py, r % py);
    let north = (ri > 0).then(|| r - py);
    let south = (ri + 1 < px).then(|| r + py);
    let west = (rj > 0).then(|| r - 1);
    let east = (rj + 1 < py).then(|| r + 1);
    let base = (step as u64) << 3;

    // post all sends first (channels are non-blocking)
    if let Some(n) = north {
        comm.send_f32(n, base + DIR_N, &grid.top_row());
    }
    if let Some(s) = south {
        comm.send_f32(s, base + DIR_S, &grid.bottom_row());
    }
    if let Some(w) = west {
        comm.send_f32(w, base + DIR_W, &grid.left_col());
    }
    if let Some(e) = east {
        comm.send_f32(e, base + DIR_E, &grid.right_col());
    }
    // receive: my north halo is my north neighbour's SOUTH-facing send
    if let Some(n) = north {
        let row = comm.recv_f32(n, base + DIR_S);
        grid.set_north_halo(&row);
    }
    if let Some(s) = south {
        let row = comm.recv_f32(s, base + DIR_N);
        grid.set_south_halo(&row);
    }
    if let Some(w) = west {
        let col = comm.recv_f32(w, base + DIR_E);
        grid.set_west_halo(&col);
    }
    if let Some(e) = east {
        let col = comm.recv_f32(e, base + DIR_W);
        grid.set_east_halo(&col);
    }
}

/// Run the distributed solve on an existing launch plan. Without the
/// `pjrt` feature this reports a clean `ComputeUnavailable` error.
#[cfg(not(feature = "pjrt"))]
pub fn run_jacobi(_plan: &LaunchPlan, _spec: &JacobiSpec) -> Result<JacobiReport, LaunchError> {
    Err(LaunchError::ComputeUnavailable)
}

/// Run the distributed solve on an existing launch plan.
#[cfg(feature = "pjrt")]
pub fn run_jacobi(plan: &LaunchPlan, spec: &JacobiSpec) -> Result<JacobiReport, LaunchError> {
    assert_eq!(plan.n_ranks, spec.n_ranks(), "plan/spec rank mismatch");
    let spec = spec.clone();
    let report: JobReport<(RankResult, Vec<(usize, f32)>)> = mpirun(plan, move |comm| {
        let rt = Runtime::load(&spec.artifacts).expect("artifacts (run `make artifacts`)");
        let artifact = rt
            .jacobi_step_name(spec.tile)
            .unwrap_or_else(|| panic!("no jacobi_step_{} artifact", spec.tile));
        let (ri, _rj) = (comm.rank / spec.py, comm.rank % spec.py);
        let mut grid = RankGrid::new(spec.tile, ri == 0);
        let mut curve = Vec::new();
        let mut compute_wall = Duration::ZERO;
        let mut steps_run = 0;
        for step in 0..spec.steps {
            exchange_halos(comm, &mut grid, spec.px, spec.py, step);
            let t0 = std::time::Instant::now();
            let (interior, res_sq) = rt.jacobi_step(&artifact, &grid.padded).expect("step");
            compute_wall += t0.elapsed();
            grid.write_interior(&interior);
            steps_run = step + 1;
            if (step + 1) % spec.check_every == 0 || step + 1 == spec.steps {
                let mut g = vec![res_sq];
                comm.allreduce(ReduceOp::Sum, &mut g);
                curve.push((step + 1, g[0]));
                if g[0] < spec.tol {
                    break;
                }
            }
        }
        (
            RankResult { interior: grid.interior(), compute_wall, steps_run },
            curve,
        )
    })?;

    let curve = report.ranks[0].result.1.clone();
    let comm_time = report.comm_time();
    let total_bytes = report.total_bytes();
    let total_msgs = report.total_msgs();
    let compute_wall_max = report
        .ranks
        .iter()
        .map(|r| r.result.0.compute_wall)
        .max()
        .unwrap_or(Duration::ZERO);
    let steps_run = report.ranks[0].result.0.steps_run;
    let final_residual = curve.last().map(|&(_, r)| r).unwrap_or(f32::INFINITY);
    Ok(JacobiReport {
        steps_run,
        final_residual,
        residual_curve: curve,
        comm_time,
        wall: report.wall,
        compute_wall_max,
        total_bytes,
        total_msgs,
        ranks: report.ranks.into_iter().map(|r| r.result.0).collect(),
    })
}

/// Serial oracle: same math (0.25·(N+S+W+E), same op order as the
/// kernel), full global grid, pure Rust.
pub fn serial_jacobi(h: usize, w: usize, steps: usize) -> (Vec<f32>, f32) {
    let (ph, pw) = (h + 2, w + 2);
    let mut grid = vec![0f32; ph * pw];
    for j in 0..pw {
        grid[j] = 1.0; // hot north wall
    }
    let mut next = grid.clone();
    let mut res = 0f32;
    for _ in 0..steps {
        res = 0.0;
        for i in 1..=h {
            for j in 1..=w {
                let v = 0.25
                    * (grid[(i - 1) * pw + j]
                        + grid[(i + 1) * pw + j]
                        + grid[i * pw + j - 1]
                        + grid[i * pw + j + 1]);
                let d = v - grid[i * pw + j];
                res += d * d;
                next[i * pw + j] = v;
            }
        }
        std::mem::swap(&mut grid, &mut next);
    }
    // return interior
    let mut out = Vec::with_capacity(h * w);
    for i in 1..=h {
        out.extend_from_slice(&grid[i * pw + 1..i * pw + 1 + w]);
    }
    (out, res)
}

/// Stitch per-rank interiors back into the global grid (row-major).
pub fn stitch(ranks: &[RankResult], px: usize, py: usize, tile: usize) -> Vec<f32> {
    let w = py * tile;
    let mut global = vec![0f32; px * tile * w];
    for (r, rr) in ranks.iter().enumerate() {
        let (ri, rj) = (r / py, r % py);
        for i in 0..tile {
            let dst = (ri * tile + i) * w + rj * tile;
            global[dst..dst + tile]
                .copy_from_slice(&rr.interior[i * tile..(i + 1) * tile]);
        }
    }
    global
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_oracle_converges() {
        let (_, r10) = serial_jacobi(32, 32, 10);
        let (_, r200) = serial_jacobi(32, 32, 200);
        assert!(r200 < r10);
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod pjrt_tests {
    use super::*;
    use crate::hw::rack::Plant;
    use crate::mpi::hostfile::Hostfile;
    use crate::util::ids::{ContainerId, MachineId};
    use crate::vnet::addr::Ipv4;
    use crate::vnet::bridge::BridgeMode;
    use crate::vnet::fabric::Fabric;
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};

    fn have_artifacts() -> bool {
        Runtime::default_dir().join("manifest.txt").exists()
    }

    fn plan(n_ranks: usize) -> LaunchPlan {
        let hostfile = Hostfile::parse("10.10.0.2 slots=12\n10.10.0.3 slots=12\n").unwrap();
        let plant = Plant::paper_testbed();
        let mut fabric = Fabric::from_plant(&plant, BridgeMode::Bridge0);
        let c2 = ContainerId::new(0);
        let c3 = ContainerId::new(1);
        fabric.place(c2, MachineId::new(1));
        fabric.place(c3, MachineId::new(2));
        let mut ip_to_container = HashMap::new();
        ip_to_container.insert(Ipv4::parse("10.10.0.2").unwrap(), c2);
        ip_to_container.insert(Ipv4::parse("10.10.0.3").unwrap(), c3);
        LaunchPlan {
            hostfile,
            n_ranks,
            ip_to_container,
            fabric: Arc::new(Mutex::new(fabric)),
            eager_threshold: 64 * 1024,
        }
    }

    #[test]
    fn distributed_matches_serial_oracle() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let spec = JacobiSpec {
            px: 2,
            py: 2,
            tile: 32,
            steps: 10,
            check_every: 10,
            tol: 0.0,
            artifacts: Runtime::default_dir(),
        };
        let p = plan(4);
        let report = run_jacobi(&p, &spec).unwrap();
        let got = stitch(&report.ranks, 2, 2, 32);
        let (want, res_want) = serial_jacobi(64, 64, 10);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-5, "{g} vs {w}");
        }
        let res_got = report.final_residual;
        assert!(
            (res_got - res_want).abs() / res_want.max(1e-9) < 1e-3,
            "residual {res_got} vs {res_want}"
        );
    }

    #[test]
    fn residual_decreases_monotonically() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let spec = JacobiSpec {
            px: 2,
            py: 2,
            tile: 32,
            steps: 60,
            check_every: 20,
            tol: 0.0,
            artifacts: Runtime::default_dir(),
        };
        let p = plan(4);
        let report = run_jacobi(&p, &spec).unwrap();
        let curve = &report.residual_curve;
        assert!(curve.len() >= 3);
        for w in curve.windows(2) {
            assert!(w[1].1 < w[0].1, "residual rose: {curve:?}");
        }
        assert!(report.comm_time > SimTime::ZERO);
        assert!(report.total_bytes > 0);
    }

    #[test]
    fn fig8_shape_sixteen_ranks() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let mut spec = JacobiSpec::fig8();
        spec.steps = 20;
        spec.check_every = 20;
        let p = plan(16);
        let report = run_jacobi(&p, &spec).unwrap();
        assert_eq!(report.ranks.len(), 16);
        assert_eq!(report.steps_run, 20);
        assert!(report.final_residual.is_finite());
    }
}
