//! Distributed GEMM (replicated-B row decomposition): each rank owns a
//! block-row of A, B is broadcast, and every rank computes its block-row
//! of C through the Pallas matmul artifact. The compute-heavy, MXU-path
//! counterpart of the stencil workload.

use crate::mpi::launcher::{mpirun, LaunchError, LaunchPlan};
use crate::runtime::Runtime;
use crate::sim::SimTime;
use std::path::PathBuf;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct GemmSpec {
    /// Per-rank square tile edge (needs a `gemm_{n}` artifact).
    pub tile: usize,
    /// Multiply rounds (amortizes broadcast).
    pub rounds: usize,
    pub artifacts: PathBuf,
}

#[derive(Debug)]
pub struct GemmReport {
    pub gflops: f64,
    pub comm_time: SimTime,
    pub compute_wall_max: Duration,
    pub wall: Duration,
    /// Check value: sum over all ranks of sum(C) (for regression tests).
    pub checksum: f64,
}

pub fn run_gemm(plan: &LaunchPlan, spec: &GemmSpec) -> Result<GemmReport, LaunchError> {
    let spec_c = spec.clone();
    let report = mpirun(plan, move |comm| {
        let rt = Runtime::load(&spec_c.artifacts).expect("artifacts");
        let name = format!("gemm_{}", spec_c.tile);
        let n = spec_c.tile;
        // deterministic per-rank A; shared B broadcast from rank 0
        let a: Vec<f32> = (0..n * n)
            .map(|i| (((i + comm.rank * 31) % 13) as f32 - 6.0) * 0.1)
            .collect();
        let mut b_bytes: Vec<u8> = if comm.rank == 0 {
            (0..n * n)
                .map(|i| ((i % 7) as f32 - 3.0) * 0.1)
                .flat_map(|v| v.to_le_bytes())
                .collect()
        } else {
            Vec::new()
        };
        comm.bcast(0, &mut b_bytes);
        let b: Vec<f32> = b_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let mut compute = Duration::ZERO;
        let mut checksum = 0f64;
        for _ in 0..spec_c.rounds {
            let t0 = std::time::Instant::now();
            let c = rt.gemm(&name, &a, &b).expect("gemm");
            compute += t0.elapsed();
            checksum = c.iter().map(|&v| v as f64).sum();
        }
        (compute, checksum)
    })?;

    let n = spec.tile as f64;
    let flops = 2.0 * n * n * n * spec.rounds as f64 * plan.n_ranks as f64;
    let compute_wall_max = report
        .ranks
        .iter()
        .map(|r| r.result.0)
        .max()
        .unwrap_or(Duration::ZERO);
    let checksum = report.ranks.iter().map(|r| r.result.1).sum();
    Ok(GemmReport {
        gflops: flops / report.wall.as_secs_f64() / 1e9,
        comm_time: report.comm_time(),
        compute_wall_max,
        wall: report.wall,
        checksum,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::rack::Plant;
    use crate::mpi::hostfile::Hostfile;
    use crate::util::ids::{ContainerId, MachineId};
    use crate::vnet::addr::Ipv4;
    use crate::vnet::bridge::BridgeMode;
    use crate::vnet::fabric::Fabric;
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};

    #[test]
    fn gemm_runs_and_is_deterministic() {
        if !Runtime::default_dir().join("manifest.txt").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let hostfile = Hostfile::parse("10.10.0.2 slots=2\n10.10.0.3 slots=2\n").unwrap();
        let plant = Plant::paper_testbed();
        let mut fabric = Fabric::from_plant(&plant, BridgeMode::Bridge0);
        let c2 = ContainerId::new(0);
        let c3 = ContainerId::new(1);
        fabric.place(c2, MachineId::new(1));
        fabric.place(c3, MachineId::new(2));
        let mut ip_to_container = HashMap::new();
        ip_to_container.insert(Ipv4::parse("10.10.0.2").unwrap(), c2);
        ip_to_container.insert(Ipv4::parse("10.10.0.3").unwrap(), c3);
        let plan = LaunchPlan {
            hostfile,
            n_ranks: 4,
            ip_to_container,
            fabric: Arc::new(Mutex::new(fabric)),
            eager_threshold: 64 * 1024,
        };
        let spec = GemmSpec { tile: 128, rounds: 1, artifacts: Runtime::default_dir() };
        let r1 = run_gemm(&plan, &spec).unwrap();
        let r2 = run_gemm(&plan, &spec).unwrap();
        assert!(r1.gflops > 0.0);
        assert!((r1.checksum - r2.checksum).abs() < 1e-6 * r1.checksum.abs().max(1.0));
        assert!(r1.comm_time > SimTime::ZERO); // the B broadcast
    }
}
