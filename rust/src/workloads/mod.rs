//! MPI workloads that run on the virtual cluster.
//!
//! * [`jacobi`] — the paper's Fig. 8 "16-domain MPI job": a 2-D heat
//!   diffusion solve with domain decomposition; per-rank compute is the
//!   AOT Pallas kernel via PJRT, halo exchange is MPI over the fabric.
//! * [`ring`] — osu-style ping-pong latency/bandwidth microbenchmark
//!   (the Fig. 3 interconnect study).
//! * [`gemm`] — replicated-B distributed GEMM (the MXU-path workload).

#[cfg(feature = "pjrt")]
pub mod gemm;
pub mod jacobi;
pub mod ring;

pub use jacobi::{run_jacobi, JacobiReport, JacobiSpec};
pub use ring::{ping_pong, PingPongPoint};
