//! osu-style ping-pong microbenchmark: measures the *virtual* one-way
//! latency and effective bandwidth between rank 0 and rank 1 for a sweep
//! of message sizes. Fig. 3's quantitative backbone.

use crate::mpi::launcher::{mpirun, LaunchError, LaunchPlan};
use crate::sim::SimTime;

/// One point of the sweep.
#[derive(Debug, Clone)]
pub struct PingPongPoint {
    pub bytes: usize,
    /// Virtual one-way time.
    pub one_way: SimTime,
    /// Effective bandwidth in bytes/sec (payload / one-way).
    pub bandwidth: f64,
}

/// Ping-pong between ranks 0 and 1, `reps` round trips per size.
pub fn ping_pong(
    plan: &LaunchPlan,
    sizes: &[usize],
    reps: usize,
) -> Result<Vec<PingPongPoint>, LaunchError> {
    assert!(plan.n_ranks >= 2);
    let sizes_v = sizes.to_vec();
    let report = mpirun(plan, move |comm| {
        let mut out = Vec::new();
        for (si, &bytes) in sizes_v.iter().enumerate() {
            let tag_base = (si as u64) << 20;
            let payload = vec![0u8; bytes];
            let before = comm.vtime();
            for rep in 0..reps {
                let tag = tag_base + rep as u64;
                if comm.rank == 0 {
                    comm.send(1, tag, &payload);
                    comm.recv(1, tag);
                } else if comm.rank == 1 {
                    comm.recv(0, tag);
                    comm.send(0, tag, &payload);
                }
            }
            let elapsed = comm.vtime().saturating_sub(before);
            out.push(elapsed);
        }
        out
    })?;

    // rank 0's clock advanced by reps round trips per size
    let r0 = &report.ranks[0].result;
    Ok(sizes
        .iter()
        .zip(r0)
        .map(|(&bytes, &elapsed)| {
            let one_way_ns = elapsed.as_nanos() as f64 / (reps as f64 * 2.0);
            let one_way = SimTime::from_nanos(one_way_ns as u64);
            let bandwidth = bytes as f64 / (one_way_ns / 1e9);
            PingPongPoint { bytes, one_way, bandwidth }
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::rack::Plant;
    use crate::mpi::hostfile::Hostfile;
    use crate::util::ids::{ContainerId, MachineId};
    use crate::vnet::addr::Ipv4;
    use crate::vnet::bridge::BridgeMode;
    use crate::vnet::fabric::Fabric;
    use std::collections::HashMap;
    use std::sync::{Arc, Mutex};

    fn plan(mode: BridgeMode) -> LaunchPlan {
        let hostfile = Hostfile::parse("10.10.0.2 slots=1\n10.10.0.3 slots=1\n").unwrap();
        let plant = Plant::paper_testbed();
        let mut fabric = Fabric::from_plant(&plant, mode);
        let c2 = ContainerId::new(0);
        let c3 = ContainerId::new(1);
        fabric.place(c2, MachineId::new(1));
        fabric.place(c3, MachineId::new(2));
        let mut ip_to_container = HashMap::new();
        ip_to_container.insert(Ipv4::parse("10.10.0.2").unwrap(), c2);
        ip_to_container.insert(Ipv4::parse("10.10.0.3").unwrap(), c3);
        LaunchPlan {
            hostfile,
            n_ranks: 2,
            ip_to_container,
            fabric: Arc::new(Mutex::new(fabric)),
            eager_threshold: 64 * 1024,
        }
    }

    #[test]
    fn latency_grows_with_size_and_bw_saturates() {
        let p = plan(BridgeMode::Bridge0);
        let pts = ping_pong(&p, &[64, 4096, 1 << 20], 4).unwrap();
        assert!(pts[0].one_way < pts[2].one_way);
        // large-message bandwidth approaches 10GbE line rate
        let line = 10e9 / 8.0;
        assert!(pts[2].bandwidth / line > 0.5, "bw={}", pts[2].bandwidth);
    }

    #[test]
    fn nat_mode_is_slower_fig3() {
        let pn = ping_pong(&plan(BridgeMode::Docker0), &[1 << 20], 4).unwrap();
        let pd = ping_pong(&plan(BridgeMode::Bridge0), &[1 << 20], 4).unwrap();
        assert!(pn[0].one_way > pd[0].one_way);
        assert!(pn[0].bandwidth < pd[0].bandwidth);
    }
}
