//! Cluster configuration: a hand-rolled TOML-subset parser (no serde in
//! the offline crate set) plus the typed `ClusterSpec` the launcher and
//! examples consume.
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (`"x"`), integer, float, boolean and `["a", "b"]` string-list values,
//! `#` comments.

use crate::hw::{MachineSpec, NicSpec};
use crate::sim::SimTime;
use crate::util::bytes::parse_bytes;
use crate::vnet::bridge::BridgeMode;
use std::collections::BTreeMap;
use thiserror::Error;

#[derive(Debug, Error, PartialEq)]
pub enum ConfigError {
    #[error("line {0}: syntax error: {1}")]
    Syntax(usize, String),
    #[error("[{0}] {1}: {2}")]
    BadValue(String, String, String),
}

/// A parsed raw value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    List(Vec<String>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// section -> key -> value.
pub type RawConfig = BTreeMap<String, BTreeMap<String, Value>>;

fn parse_value(line_no: usize, s: &str) -> Result<Value, ConfigError> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('"') {
        let end = inner
            .rfind('"')
            .ok_or_else(|| ConfigError::Syntax(line_no, "unterminated string".into()))?;
        return Ok(Value::Str(inner[..end].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(ConfigError::Syntax(line_no, "unterminated list".into()));
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for item in inner.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let item = item
                .strip_prefix('"')
                .and_then(|i| i.strip_suffix('"'))
                .ok_or_else(|| ConfigError::Syntax(line_no, "list items must be strings".into()))?;
            items.push(item.to_string());
        }
        return Ok(Value::List(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ConfigError::Syntax(line_no, format!("cannot parse value: {s}")))
}

/// Parse raw config text.
pub fn parse(text: &str) -> Result<RawConfig, ConfigError> {
    let mut out: RawConfig = BTreeMap::new();
    let mut section = String::from("root");
    for (i, raw) in text.lines().enumerate() {
        let line_no = i + 1;
        let line = match raw.find('#') {
            // don't strip # inside strings — cheap check: only strip if no quote before it
            Some(pos) if !raw[..pos].contains('"') => &raw[..pos],
            _ => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| ConfigError::Syntax(line_no, "bad section header".into()))?;
            section = name.trim().to_string();
            out.entry(section.clone()).or_default();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| ConfigError::Syntax(line_no, "expected key = value".into()))?;
        let value = parse_value(line_no, v)?;
        out.entry(section.clone())
            .or_default()
            .insert(k.trim().to_string(), value);
    }
    Ok(out)
}

/// Autoscaling policy knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscaleConfig {
    pub enabled: bool,
    pub min_nodes: u32,
    pub max_nodes: u32,
    /// Seconds between scale decisions.
    pub interval: SimTime,
    /// Cooldown after any scaling action.
    pub cooldown: SimTime,
    /// Scale down after this long of sustained low utilization
    /// (demand's target node count below the ready pool).
    pub idle_timeout: SimTime,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            min_nodes: 2,
            max_nodes: 3,
            interval: SimTime::from_secs(5),
            cooldown: SimTime::from_secs(30),
            idle_timeout: SimTime::from_secs(300),
        }
    }
}

/// The full cluster description.
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub name: String,
    pub machines: u32,
    pub machine_spec: MachineSpec,
    pub bridge: BridgeMode,
    pub consul_servers: u32,
    pub image: String,
    pub dockerfile: String,
    /// MPI slots each compute container advertises.
    pub slots_per_node: u32,
    /// Rack count for the physical plant: 0 (default) keeps the legacy
    /// 16-machine chassis rows; an explicit count spreads the machines
    /// evenly across that many racks, giving topology-aware placement
    /// real boundaries to pack against.
    pub racks: u32,
    /// Jacobi restart-checkpoint interval in solver steps (partial
    /// progress credit on requeue/preemption rounds down to the last
    /// completed multiple). Decoupled from the residual cadence; the
    /// default preserves the historical behavior.
    pub jacobi_checkpoint_steps: usize,
    /// Cap on the head's in-memory completed-job history (and the HA
    /// snapshot's completed section). `0` = unlimited; the default
    /// keeps ~10k terminal records, far above any driver trace.
    pub completed_retention: usize,
    pub seed: u64,
    /// Structured trace output: when set, the cluster opens a JSON-lines
    /// [`TraceSink`](crate::obs::TraceSink) at this path and emits the
    /// full lifecycle event stream into it (`--trace FILE` on the
    /// drivers). `None` (the default) leaves the trace bus inert.
    pub trace_path: Option<String>,
    /// Metrics-recorder cadence: how often (virtual time) a traced run
    /// samples a gauge snapshot (queue depth, slots, node health,
    /// per-tenant top-K usage, autoscale target) into the trace as a
    /// `sample` event (`[cluster] sample_every`, seconds; `0` disables).
    /// Inert unless a trace sink is installed.
    pub sample_every: SimTime,
    pub autoscale: AutoscaleConfig,
    /// Per-tenant fair-share weight multipliers (`[tenant_weights]`
    /// section: `<tenant id> = <weight>`; a weight-2 tenant earns twice
    /// the fair share). Empty by default — all tenants equal.
    pub tenant_weights: Vec<(u64, f64)>,
    /// Head-node high-availability knobs (`[ha]` section). Disabled by
    /// default: the paper's single-head cluster, byte for byte.
    pub ha: crate::ha::HaConfig,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self::paper_testbed()
    }
}

impl ClusterSpec {
    /// The paper's exact deployment: 3 Dell M620 blades, bridge0,
    /// 3 consul servers, the Fig. 2 image, 12 slots per node.
    pub fn paper_testbed() -> Self {
        Self {
            name: "nchc-virtual-hpc".into(),
            machines: 3,
            machine_spec: MachineSpec::dell_m620(),
            bridge: BridgeMode::Bridge0,
            consul_servers: 3,
            image: "nchc/mpi-computenode:latest".into(),
            dockerfile: crate::dockyard::Dockerfile::paper_compute_node().to_string(),
            slots_per_node: 12,
            racks: 0,
            jacobi_checkpoint_steps: crate::cluster::head::JACOBI_CHECKPOINT_STEPS,
            completed_retention: crate::cluster::head::DEFAULT_COMPLETED_RETENTION,
            seed: 42,
            trace_path: None,
            sample_every: SimTime::from_secs(30),
            autoscale: AutoscaleConfig::default(),
            tenant_weights: Vec::new(),
            ha: crate::ha::HaConfig::default(),
        }
    }

    /// Most MPI slots the cluster can ever advertise: compute nodes are
    /// machines 1.., and with autoscaling enabled the pool is further
    /// capped by the policy bounds (manual provisioning past the policy
    /// cap would be scaled back down). Jobs wider than this can never
    /// run and are rejected at submit.
    pub fn max_advertisable_slots(&self) -> u32 {
        let physical = self.machines.saturating_sub(1);
        let nodes = if self.autoscale.enabled {
            physical.min(self.autoscale.max_nodes.max(self.autoscale.min_nodes))
        } else {
            physical
        };
        nodes * self.slots_per_node
    }

    /// Build from config text (missing keys fall back to the testbed).
    pub fn from_text(text: &str) -> Result<Self, ConfigError> {
        let raw = parse(text)?;
        let mut spec = Self::paper_testbed();
        if let Some(c) = raw.get("cluster") {
            if let Some(v) = c.get("name") {
                spec.name = req_str("cluster", "name", v)?;
            }
            if let Some(v) = c.get("machines") {
                spec.machines = req_int("cluster", "machines", v)? as u32;
            }
            if let Some(v) = c.get("bridge") {
                spec.bridge = match req_str("cluster", "bridge", v)?.as_str() {
                    "docker0" => BridgeMode::Docker0,
                    "bridge0" => BridgeMode::Bridge0,
                    "host" => BridgeMode::Host,
                    other => {
                        return Err(ConfigError::BadValue(
                            "cluster".into(),
                            "bridge".into(),
                            format!("unknown mode {other}"),
                        ))
                    }
                };
            }
            if let Some(v) = c.get("consul_servers") {
                spec.consul_servers = req_int("cluster", "consul_servers", v)? as u32;
            }
            if let Some(v) = c.get("slots_per_node") {
                spec.slots_per_node = req_int("cluster", "slots_per_node", v)? as u32;
            }
            if let Some(v) = c.get("racks") {
                spec.racks = req_int("cluster", "racks", v)? as u32;
            }
            if let Some(v) = c.get("jacobi_checkpoint_steps") {
                spec.jacobi_checkpoint_steps =
                    (req_int("cluster", "jacobi_checkpoint_steps", v)?.max(1)) as usize;
            }
            if let Some(v) = c.get("completed_retention") {
                spec.completed_retention =
                    req_int("cluster", "completed_retention", v)?.max(0) as usize;
            }
            if let Some(v) = c.get("seed") {
                spec.seed = req_int("cluster", "seed", v)? as u64;
            }
            if let Some(v) = c.get("image") {
                spec.image = req_str("cluster", "image", v)?;
            }
            if let Some(v) = c.get("trace_path") {
                spec.trace_path = Some(req_str("cluster", "trace_path", v)?);
            }
            if let Some(v) = c.get("sample_every") {
                spec.sample_every =
                    SimTime::from_secs(req_int("cluster", "sample_every", v)?.max(0) as u64);
            }
        }
        if let Some(m) = raw.get("machine") {
            if let Some(v) = m.get("memory") {
                let s = req_str("machine", "memory", v)?;
                spec.machine_spec.memory_bytes = parse_bytes(&s).ok_or_else(|| {
                    ConfigError::BadValue("machine".into(), "memory".into(), s)
                })?;
            }
            if let Some(v) = m.get("cores_per_socket") {
                spec.machine_spec.cores_per_socket =
                    req_int("machine", "cores_per_socket", v)? as u32;
            }
            if let Some(v) = m.get("sockets") {
                spec.machine_spec.sockets = req_int("machine", "sockets", v)? as u32;
            }
            if let Some(v) = m.get("boot_secs") {
                spec.machine_spec.boot_time =
                    SimTime::from_secs(req_int("machine", "boot_secs", v)? as u64);
            }
            if let Some(v) = m.get("nic") {
                spec.machine_spec.nic = match req_str("machine", "nic", v)?.as_str() {
                    "10GbE" => NicSpec::ten_gbe(),
                    "1GbE" => NicSpec::one_gbe(),
                    "IB-FDR" => NicSpec::infiniband_fdr(),
                    other => {
                        return Err(ConfigError::BadValue(
                            "machine".into(),
                            "nic".into(),
                            format!("unknown nic {other}"),
                        ))
                    }
                };
            }
        }
        if let Some(a) = raw.get("autoscale") {
            if let Some(v) = a.get("enabled") {
                spec.autoscale.enabled = v.as_bool().ok_or_else(|| {
                    ConfigError::BadValue("autoscale".into(), "enabled".into(), format!("{v:?}"))
                })?;
            }
            if let Some(v) = a.get("min_nodes") {
                spec.autoscale.min_nodes = req_int("autoscale", "min_nodes", v)? as u32;
            }
            if let Some(v) = a.get("max_nodes") {
                spec.autoscale.max_nodes = req_int("autoscale", "max_nodes", v)? as u32;
            }
            if let Some(v) = a.get("cooldown_secs") {
                spec.autoscale.cooldown =
                    SimTime::from_secs(req_int("autoscale", "cooldown_secs", v)? as u64);
            }
            if let Some(v) = a.get("idle_timeout_secs") {
                spec.autoscale.idle_timeout =
                    SimTime::from_secs(req_int("autoscale", "idle_timeout_secs", v)? as u64);
            }
        }
        if let Some(tw) = raw.get("tenant_weights") {
            for (k, v) in tw {
                let tenant: u64 = k.parse().map_err(|_| {
                    ConfigError::BadValue(
                        "tenant_weights".into(),
                        k.clone(),
                        "tenant id must be an integer".into(),
                    )
                })?;
                let weight = v.as_float().ok_or_else(|| {
                    ConfigError::BadValue(
                        "tenant_weights".into(),
                        k.clone(),
                        format!("{v:?} is not a number"),
                    )
                })?;
                if weight <= 0.0 || !weight.is_finite() {
                    return Err(ConfigError::BadValue(
                        "tenant_weights".into(),
                        k.clone(),
                        format!("weight must be a positive number, got {weight}"),
                    ));
                }
                spec.tenant_weights.push((tenant, weight));
            }
        }
        if let Some(h) = raw.get("ha") {
            if let Some(v) = h.get("enabled") {
                spec.ha.enabled = v.as_bool().ok_or_else(|| {
                    ConfigError::BadValue("ha".into(), "enabled".into(), format!("{v:?}"))
                })?;
            }
            if let Some(v) = h.get("lock_ttl_secs") {
                spec.ha.lock_ttl = SimTime::from_secs(req_int("ha", "lock_ttl_secs", v)? as u64);
            }
            if let Some(v) = h.get("standby_poll_secs") {
                spec.ha.standby_poll =
                    SimTime::from_secs(req_int("ha", "standby_poll_secs", v)? as u64);
            }
            if let Some(v) = h.get("snapshot_every") {
                spec.ha.snapshot_every = req_int("ha", "snapshot_every", v)? as u64;
            }
            if let Some(v) = h.get("standbys") {
                spec.ha.standbys = (req_int("ha", "standbys", v)? as u32).max(1);
            }
        }
        Ok(spec)
    }
}

fn req_str(section: &str, key: &str, v: &Value) -> Result<String, ConfigError> {
    v.as_str()
        .map(str::to_string)
        .ok_or_else(|| ConfigError::BadValue(section.into(), key.into(), format!("{v:?} is not a string")))
}

fn req_int(section: &str, key: &str, v: &Value) -> Result<i64, ConfigError> {
    v.as_int()
        .ok_or_else(|| ConfigError::BadValue(section.into(), key.into(), format!("{v:?} is not an int")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_values() {
        let raw = parse(
            "# comment\n[cluster]\nname = \"x\"\nmachines = 5\nratio = 1.5\non = true\nlist = [\"a\", \"b\"]\n",
        )
        .unwrap();
        let c = &raw["cluster"];
        assert_eq!(c["name"], Value::Str("x".into()));
        assert_eq!(c["machines"], Value::Int(5));
        assert_eq!(c["ratio"], Value::Float(1.5));
        assert_eq!(c["on"], Value::Bool(true));
        assert_eq!(c["list"], Value::List(vec!["a".into(), "b".into()]));
    }

    #[test]
    fn syntax_errors() {
        assert!(matches!(parse("[oops\n"), Err(ConfigError::Syntax(1, _))));
        assert!(matches!(parse("novalue\n"), Err(ConfigError::Syntax(1, _))));
        assert!(matches!(parse("k = \"open\n"), Err(ConfigError::Syntax(1, _))));
        assert!(matches!(parse("k = @@@\n"), Err(ConfigError::Syntax(1, _))));
    }

    #[test]
    fn paper_testbed_defaults() {
        let s = ClusterSpec::paper_testbed();
        assert_eq!(s.machines, 3);
        assert_eq!(s.consul_servers, 3);
        assert_eq!(s.slots_per_node, 12);
        assert_eq!(s.bridge, BridgeMode::Bridge0);
        assert_eq!(s.machine_spec.model, "Dell M620");
        assert_eq!(
            s.jacobi_checkpoint_steps,
            crate::cluster::head::JACOBI_CHECKPOINT_STEPS,
            "default must preserve the historical checkpoint cadence"
        );
    }

    #[test]
    fn spec_from_text_overrides() {
        let spec = ClusterSpec::from_text(
            "[cluster]\nmachines = 8\nbridge = \"docker0\"\nslots_per_node = 4\nracks = 2\n\
             jacobi_checkpoint_steps = 5\n\
             [machine]\nmemory = \"32GB\"\nnic = \"1GbE\"\nboot_secs = 10\n\
             [autoscale]\nmin_nodes = 1\nmax_nodes = 8\ncooldown_secs = 5\n",
        )
        .unwrap();
        assert_eq!(spec.machines, 8);
        assert_eq!(spec.racks, 2);
        assert_eq!(spec.jacobi_checkpoint_steps, 5);
        assert_eq!(spec.bridge, BridgeMode::Docker0);
        assert_eq!(spec.machine_spec.memory_bytes, 32 << 30);
        assert_eq!(spec.machine_spec.nic.name, "1GbE");
        assert_eq!(spec.machine_spec.boot_time, SimTime::from_secs(10));
        assert_eq!(spec.autoscale.min_nodes, 1);
        assert_eq!(spec.autoscale.max_nodes, 8);
        assert_eq!(spec.autoscale.cooldown, SimTime::from_secs(5));
    }

    #[test]
    fn max_advertisable_slots_honors_policy_and_physical_caps() {
        let mut s = ClusterSpec::paper_testbed();
        assert_eq!(s.max_advertisable_slots(), 24); // physical: 2 compute nodes
        s.machines = 8;
        assert_eq!(s.max_advertisable_slots(), 36); // policy: max_nodes = 3
        s.autoscale.enabled = false;
        assert_eq!(s.max_advertisable_slots(), 84); // manual provisioning can reach 7
    }

    #[test]
    fn tenant_weights_and_ha_sections_parse() {
        let spec = ClusterSpec::from_text(
            "[tenant_weights]\n1 = 2.0\n7 = 4\n\
             [ha]\nenabled = true\nlock_ttl_secs = 3\nstandby_poll_secs = 2\nsnapshot_every = 64\nstandbys = 3\n",
        )
        .unwrap();
        assert_eq!(spec.tenant_weights, vec![(1, 2.0), (7, 4.0)]);
        assert!(spec.ha.enabled);
        assert_eq!(spec.ha.lock_ttl, SimTime::from_secs(3));
        assert_eq!(spec.ha.standby_poll, SimTime::from_secs(2));
        assert_eq!(spec.ha.snapshot_every, 64);
        assert_eq!(spec.ha.standbys, 3);
        // defaults: no weights, HA off
        let d = ClusterSpec::paper_testbed();
        assert!(d.tenant_weights.is_empty());
        assert!(!d.ha.enabled);
        // bad weights error out
        assert!(matches!(
            ClusterSpec::from_text("[tenant_weights]\nbob = 2.0\n"),
            Err(ConfigError::BadValue(..))
        ));
        assert!(matches!(
            ClusterSpec::from_text("[tenant_weights]\n1 = -2.0\n"),
            Err(ConfigError::BadValue(..))
        ));
    }

    #[test]
    fn bad_enum_values_error() {
        assert!(matches!(
            ClusterSpec::from_text("[cluster]\nbridge = \"wat\"\n"),
            Err(ConfigError::BadValue(..))
        ));
        assert!(matches!(
            ClusterSpec::from_text("[machine]\nnic = \"token-ring\"\n"),
            Err(ConfigError::BadValue(..))
        ));
    }
}
