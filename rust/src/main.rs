//! vhpc CLI entrypoint (leader). Subcommands are wired in `cli`.
fn main() {
    std::process::exit(vhpc::cli::main());
}
