//! HLO-text loading, compilation cache and typed entry points.

use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// What an artifact computes (from the manifest's `kind` column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// One Jacobi step on a (n+2, n+2) padded grid -> ((n, n), scalar).
    JacobiStep,
    /// K fused steps on a padded grid -> (padded, scalar).
    JacobiSweep,
    /// (m, k) x (k, n) -> (m, n).
    Gemm,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "jacobi_step" => Self::JacobiStep,
            "jacobi_sweep" => Self::JacobiSweep,
            "gemm" => Self::Gemm,
            other => bail!("unknown artifact kind {other}"),
        })
    }
}

/// One manifest row.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    pub dims: Vec<usize>,
}

/// Thread-confined PJRT runtime with a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, Artifact>,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// Compilations performed (cache-miss counter).
    pub compiles: std::cell::Cell<u64>,
    /// Executions performed.
    pub executions: std::cell::Cell<u64>,
}

impl Runtime {
    /// Load the manifest in `dir` (produced by `make artifacts`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let mut manifest = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts.next().ok_or_else(|| anyhow!("bad manifest line: {line}"))?;
            let file = parts.next().ok_or_else(|| anyhow!("bad manifest line: {line}"))?;
            let kind = ArtifactKind::parse(
                parts.next().ok_or_else(|| anyhow!("bad manifest line: {line}"))?,
            )?;
            let dims: Vec<usize> = parts.map(|d| d.parse()).collect::<Result<_, _>>()?;
            manifest.insert(
                name.to_string(),
                Artifact { name: name.to_string(), file: file.to_string(), kind, dims },
            );
        }
        // hush the C++ client's INFO chatter (TfrtCpuClient created/…)
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        // One Runtime per MPI rank thread: multi-threaded Eigen inside
        // each client oversubscribes the host (pools of busy-spinning
        // workers per rank) for tiles this small. Single-thread the
        // intra-op execution — §Perf in EXPERIMENTS.md quantifies the win.
        if std::env::var_os("XLA_FLAGS").is_none() {
            std::env::set_var("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false");
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Self {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compiles: std::cell::Cell::new(0),
            executions: std::cell::Cell::new(0),
        })
    }

    /// Default artifacts directory (repo-root/artifacts or $VHPC_ARTIFACTS).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("VHPC_ARTIFACTS") {
            return PathBuf::from(d);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn artifact(&self, name: &str) -> Option<&Artifact> {
        self.manifest.get(name)
    }

    pub fn artifacts(&self) -> impl Iterator<Item = &Artifact> {
        self.manifest.values()
    }

    /// Pick the jacobi_step artifact for an n×n local domain.
    pub fn jacobi_step_name(&self, n: usize) -> Option<String> {
        let name = format!("jacobi_step_{n}");
        self.manifest.contains_key(&name).then_some(name)
    }

    /// Compile (or fetch from cache) an artifact.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let art = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named {name}"))?;
        let path = self.dir.join(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.compiles.set(self.compiles.get() + 1);
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn literal_grid(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        if data.len() != rows * cols {
            bail!("grid size mismatch: {} != {rows}x{cols}", data.len());
        }
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    /// One Jacobi step: padded (n+2)² grid in, (interior n², residual²) out.
    pub fn jacobi_step(&self, name: &str, padded: &[f32]) -> Result<(Vec<f32>, f32)> {
        let art = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("no artifact {name}"))?;
        anyhow::ensure!(art.kind == ArtifactKind::JacobiStep, "{name} is not jacobi_step");
        let n = art.dims[0];
        let exe = self.executable(name)?;
        let input = Self::literal_grid(padded, n + 2, n + 2)?;
        self.executions.set(self.executions.get() + 1);
        let result = exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let (new, res) = result.to_tuple2().map_err(|e| anyhow!("tuple2: {e:?}"))?;
        let new_v = new.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        let res_v = res
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("residual: {e:?}"))?;
        Ok((new_v, res_v))
    }

    /// K fused Jacobi steps: padded grid in -> (padded grid, residual²).
    pub fn jacobi_sweep(&self, name: &str, padded: &[f32]) -> Result<(Vec<f32>, f32)> {
        let art = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("no artifact {name}"))?;
        anyhow::ensure!(art.kind == ArtifactKind::JacobiSweep, "{name} is not jacobi_sweep");
        let n = art.dims[0];
        let exe = self.executable(name)?;
        let input = Self::literal_grid(padded, n + 2, n + 2)?;
        self.executions.set(self.executions.get() + 1);
        let result = exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let (grid, res) = result.to_tuple2().map_err(|e| anyhow!("tuple2: {e:?}"))?;
        Ok((
            grid.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?,
            res.get_first_element::<f32>()
                .map_err(|e| anyhow!("residual: {e:?}"))?,
        ))
    }

    /// GEMM: (n,n) x (n,n) -> (n,n).
    pub fn gemm(&self, name: &str, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let art = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("no artifact {name}"))?;
        anyhow::ensure!(art.kind == ArtifactKind::Gemm, "{name} is not gemm");
        let (m, k, n) = (art.dims[0], art.dims[1], art.dims[2]);
        let exe = self.executable(name)?;
        let la = Self::literal_grid(a, m, k)?;
        let lb = Self::literal_grid(b, k, n)?;
        self.executions.set(self.executions.get() + 1);
        let result = exe
            .execute::<xla::Literal>(&[la, lb])
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow!("tuple1: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = Runtime::default_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: no artifacts at {dir:?} (run `make artifacts`)");
            return None;
        }
        Some(Runtime::load(dir).unwrap())
    }

    /// Serial reference Jacobi step for validation.
    fn ref_jacobi(padded: &[f32], n: usize) -> (Vec<f32>, f32) {
        let w = n + 2;
        let mut out = vec![0f32; n * n];
        let mut res = 0f64;
        for i in 0..n {
            for j in 0..n {
                let c = padded[(i + 1) * w + (j + 1)];
                let v = 0.25
                    * (padded[i * w + (j + 1)]
                        + padded[(i + 2) * w + (j + 1)]
                        + padded[(i + 1) * w + j]
                        + padded[(i + 1) * w + (j + 2)]);
                out[i * n + j] = v;
                res += ((v - c) as f64) * ((v - c) as f64);
            }
        }
        (out, res as f32)
    }

    #[test]
    fn manifest_loads_and_lists() {
        let Some(rt) = runtime() else { return };
        assert!(rt.artifact("jacobi_step_64").is_some());
        assert!(rt.artifact("gemm_128").is_some());
        assert_eq!(rt.jacobi_step_name(64).as_deref(), Some("jacobi_step_64"));
        assert_eq!(rt.jacobi_step_name(63), None);
    }

    #[test]
    fn jacobi_step_matches_serial_reference() {
        let Some(rt) = runtime() else { return };
        let n = 32;
        let w = n + 2;
        let padded: Vec<f32> = (0..w * w).map(|i| ((i * 37) % 101) as f32 * 0.1).collect();
        let (got, res) = rt.jacobi_step("jacobi_step_32", &padded).unwrap();
        let (want, res_want) = ref_jacobi(&padded, n);
        assert_eq!(got.len(), n * n);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4, "{g} vs {w}");
        }
        assert!((res - res_want).abs() / res_want.max(1.0) < 1e-3);
    }

    #[test]
    fn executable_cache_hits() {
        let Some(rt) = runtime() else { return };
        let padded = vec![1.0f32; 34 * 34];
        rt.jacobi_step("jacobi_step_32", &padded).unwrap();
        rt.jacobi_step("jacobi_step_32", &padded).unwrap();
        rt.jacobi_step("jacobi_step_32", &padded).unwrap();
        assert_eq!(rt.compiles.get(), 1, "recompiled despite cache");
        assert_eq!(rt.executions.get(), 3);
    }

    #[test]
    fn gemm_matches_naive() {
        let Some(rt) = runtime() else { return };
        let n = 128;
        let a: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32) - 3.0).collect();
        let b: Vec<f32> = (0..n * n).map(|i| ((i % 5) as f32) * 0.5).collect();
        let got = rt.gemm("gemm_128", &a, &b).unwrap();
        // spot-check a few entries against the naive triple loop
        for &(i, j) in &[(0usize, 0usize), (17, 93), (127, 127), (64, 1)] {
            let mut want = 0f32;
            for k in 0..n {
                want += a[i * n + k] * b[k * n + j];
            }
            let g = got[i * n + j];
            assert!((g - want).abs() < 1e-2 * want.abs().max(1.0), "({i},{j}): {g} vs {want}");
        }
    }

    #[test]
    fn sweep_reduces_residual() {
        let Some(rt) = runtime() else { return };
        let n = 128;
        let w = n + 2;
        let mut padded = vec![0f32; w * w];
        for j in 0..w {
            padded[j] = 1.0; // hot north boundary
        }
        let (after, res) = rt.jacobi_sweep("jacobi_sweep_128_k100", &padded).unwrap();
        assert_eq!(after.len(), w * w);
        // boundary preserved
        assert_eq!(after[0], 1.0);
        assert_eq!(after[w - 1], 1.0);
        // interior warmed up
        assert!(after[w + 1] > 0.0);
        assert!(res > 0.0);
    }

    #[test]
    fn multiple_runtimes_across_threads() {
        // Each MPI rank thread builds its own Runtime — prove that works.
        let Some(_) = runtime() else { return };
        let dir = Runtime::default_dir();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let dir = dir.clone();
                std::thread::spawn(move || {
                    let rt = Runtime::load(dir).unwrap();
                    let padded = vec![1.0f32; 34 * 34];
                    let (out, _res) = rt.jacobi_step("jacobi_step_32", &padded).unwrap();
                    assert!(out.iter().all(|&v| (v - 1.0).abs() < 1e-6));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
