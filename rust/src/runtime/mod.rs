//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! The compile path (`make artifacts`) lowers the L2 JAX functions —
//! which call the L1 Pallas kernels — to HLO text; this module is the
//! only place the Rust side touches XLA. `Runtime` is thread-confined
//! (the `xla` crate wraps `Rc` internals): each MPI rank thread builds
//! its own, compiles lazily and caches per artifact name.

pub mod client;

pub use client::{Artifact, ArtifactKind, Runtime};
