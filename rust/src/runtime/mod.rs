//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! The compile path (`make artifacts`) lowers the L2 JAX functions —
//! which call the L1 Pallas kernels — to HLO text; this module is the
//! only place the Rust side touches XLA. `Runtime` is thread-confined
//! (the `xla` crate wraps `Rc` internals): each MPI rank thread builds
//! its own, compiles lazily and caches per artifact name.
//!
//! The whole XLA dependency sits behind the `pjrt` cargo feature (on by
//! default). `--no-default-features` builds swap in [`stub::Runtime`],
//! which keeps the control-plane surface (`default_dir`) and turns any
//! compute request into a clean "built without pjrt" error instead of a
//! link failure against the vendored toolchain.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub use client::{Artifact, ArtifactKind, Runtime};

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;
