//! Control-plane-only stand-in for the PJRT runtime, compiled when the
//! `pjrt` feature is off.
//!
//! Keeps the pieces the cluster control plane actually touches (the
//! artifacts-directory default) and fails loudly — but cleanly — the
//! moment real compute is requested.

use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

/// The error every compute entry point reports without the toolchain.
pub const NO_PJRT: &str = "vhpc was built without the `pjrt` feature: \
    real-compute jobs (Jacobi/GEMM) need the vendored xla toolchain — \
    rebuild with default features";

/// Feature-off `Runtime`: same name and constructor surface as
/// `client::Runtime`, no XLA behind it.
pub struct Runtime;

impl Runtime {
    /// Always errors: there is no PJRT client in this build.
    pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
        bail!(NO_PJRT)
    }

    /// Default artifacts directory (repo-root/artifacts or
    /// `$VHPC_ARTIFACTS`) — same resolution as the real runtime, so
    /// specs built in a control-plane binary stay portable.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("VHPC_ARTIFACTS") {
            return PathBuf::from(d);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_the_missing_feature() {
        let err = Runtime::load("/nonexistent").err().expect("stub must not load");
        assert!(err.to_string().contains("without the `pjrt` feature"), "{err}");
    }

    #[test]
    fn default_dir_still_resolves() {
        assert!(Runtime::default_dir().ends_with("artifacts"));
    }
}
