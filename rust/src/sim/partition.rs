//! Lock-step partitioned execution: split the simulated cluster into
//! shards, advance every shard in fixed time windows on its own thread,
//! and exchange boundary messages at a barrier between windows.
//!
//! The determinism contract this module upholds (and that
//! `tests/determinism.rs` pins at shard counts 1/2/4):
//!
//! * **The window grid is fixed.** Every participant advances the same
//!   `[k·W, (k+1)·W)` windows regardless of the shard count, so event
//!   clamping and message timing never shift when the partition does.
//! * **All cross-partition effects ride boundary messages** through a
//!   [`SimCommunicator`], with exactly one window of latency — for
//!   every message, including a partition's messages to itself.
//! * **Receivers apply messages in a fixed merge order** (the caller's
//!   merge key, not arrival order), so the same set of messages
//!   produces the same state no matter which shard produced which.
//!
//! Under those three rules, moving a machine between shards changes
//! which thread computes its events but not what they are, when they
//! are, or the order their cross-shard effects are applied in — which
//! is why the fingerprints stay byte-identical.

use crate::comm::{LocalCommunicator, SimCommunicator};
use crate::sim::SimTime;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// How a contiguous id range is carved into shards: near-equal
/// contiguous slices (the first `count % shards` slices get one extra),
/// so rack-adjacent machines land in the same shard and the map from
/// id to shard is a pure function both sides can compute.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    start: u32,
    ranges: Vec<Range<u32>>,
}

impl ShardPlan {
    /// Split `[start, end)` into `shards` contiguous ranges. `shards`
    /// is clamped to at least 1 and at most the number of ids, so no
    /// shard is ever empty (an empty shard would still cost a thread
    /// and a barrier slot).
    pub fn split(start: u32, end: u32, shards: usize) -> ShardPlan {
        let count = end.saturating_sub(start);
        let shards = (shards.max(1) as u32).min(count.max(1));
        let base = count / shards;
        let extra = count % shards;
        let mut ranges = Vec::with_capacity(shards as usize);
        let mut lo = start;
        for s in 0..shards {
            let len = base + u32::from(s < extra);
            ranges.push(lo..lo + len);
            lo += len;
        }
        ShardPlan { start, ranges }
    }

    /// Number of shards in the plan.
    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    /// The id range owned by shard `s`.
    pub fn range(&self, s: usize) -> Range<u32> {
        self.ranges[s].clone()
    }

    /// Which shard owns `id`. Ids outside the plan clamp to the nearest
    /// end shard (callers guard their ids; this keeps the map total).
    pub fn shard_of(&self, id: u32) -> usize {
        match self.ranges.iter().position(|r| r.contains(&id)) {
            Some(s) => s,
            None if id < self.start => 0,
            None => self.ranges.len() - 1,
        }
    }
}

/// Outbound boundary messages a participant emits during one window.
#[derive(Debug)]
pub struct Outbox<M> {
    msgs: Vec<(usize, M)>,
}

impl<M> Outbox<M> {
    fn new() -> Self {
        Self { msgs: Vec::new() }
    }

    /// Queue `msg` for delivery to participant `to` at the start of the
    /// next window (`to` may be the sender itself).
    pub fn send(&mut self, to: usize, msg: M) {
        self.msgs.push((to, msg));
    }

    fn drain(&mut self) -> Vec<(usize, M)> {
        std::mem::take(&mut self.msgs)
    }
}

/// One participant of a lock-step run: either the conductor (rank 0 in
/// the cluster engine) or a shard. Implementations own their slice of
/// simulation state and are moved onto a worker thread.
pub trait Partitioned: Send {
    /// The boundary-message type exchanged between participants.
    type Msg: Send;

    /// Advance this participant's state across `[start, end)`.
    /// `incoming` holds the messages addressed to it from the previous
    /// window, pre-sorted by the communicator's `(sender rank, send
    /// order)`; cross-window effects go into `out`. Return `true` to
    /// request that the whole run stop after this window — only the
    /// participant that owns termination (the conductor) ever should.
    fn window(
        &mut self,
        start: SimTime,
        end: SimTime,
        incoming: Vec<(usize, Self::Msg)>,
        out: &mut Outbox<Self::Msg>,
    ) -> bool;
}

/// Run every participant in lock-step `window`-sized time slices until
/// one of them requests a stop (or `max_windows` elapses — the
/// seatbelt against a conductor that never drains). Returns the
/// participants in rank order with their final state, plus the number
/// of windows executed.
///
/// Threading model: one worker thread per participant, all advancing
/// the same window grid. The stop flag is written by the requesting
/// participant *before* its exchange barrier and read by every thread
/// *after* that same barrier, so all threads observe it at the same
/// window boundary and exit together — nobody can leave a peer waiting
/// at a barrier that will never fill.
pub fn run_lockstep<P: Partitioned>(
    parts: Vec<P>,
    window: SimTime,
    max_windows: u64,
) -> (Vec<P>, u64) {
    assert!(window > SimTime::ZERO, "window must be positive");
    let n = parts.len();
    assert!(n > 0, "need at least one participant");
    let stop = AtomicBool::new(false);
    let windows = AtomicU64::new(0);
    let comms = LocalCommunicator::group(n);
    let finished = std::thread::scope(|scope| {
        let stop = &stop;
        let windows = &windows;
        let handles: Vec<_> = parts
            .into_iter()
            .zip(comms)
            .map(|(mut part, mut comm)| {
                scope.spawn(move || {
                    let mut start = SimTime::ZERO;
                    let mut incoming = Vec::new();
                    let mut out = Outbox::new();
                    let mut ran = 0u64;
                    loop {
                        let end = start + window;
                        if part.window(start, end, incoming, &mut out) {
                            stop.store(true, Ordering::SeqCst);
                        }
                        ran += 1;
                        if ran >= max_windows {
                            // every thread hits the same cap at the same
                            // window, so this exit is also collective
                            stop.store(true, Ordering::SeqCst);
                        }
                        for (to, msg) in out.drain() {
                            comm.send(to, msg);
                        }
                        incoming = comm.exchange();
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        start = end;
                    }
                    if comm.rank() == 0 {
                        windows.store(ran, Ordering::SeqCst);
                    }
                    part
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("lock-step worker panicked"))
            .collect::<Vec<P>>()
    });
    (finished, windows.load(Ordering::SeqCst))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_contiguous_and_balanced() {
        let plan = ShardPlan::split(1, 11, 4); // ids 1..11, 10 machines
        assert_eq!(plan.shards(), 4);
        assert_eq!(plan.range(0), 1..4);
        assert_eq!(plan.range(1), 4..7);
        assert_eq!(plan.range(2), 7..9);
        assert_eq!(plan.range(3), 9..11);
        for id in 1..11 {
            let s = plan.shard_of(id);
            assert!(plan.range(s).contains(&id), "id {id} -> shard {s}");
        }
    }

    #[test]
    fn split_clamps_excess_shards_and_never_leaves_one_empty() {
        let plan = ShardPlan::split(1, 4, 16); // 3 machines, 16 requested
        assert_eq!(plan.shards(), 3);
        assert!((0..3).all(|s| !plan.range(s).is_empty()));
        // degenerate but total: zero machines still yields one shard
        let empty = ShardPlan::split(5, 5, 4);
        assert_eq!(empty.shards(), 1);
        assert!(empty.range(0).is_empty());
        assert_eq!(empty.shard_of(2), 0);
        assert_eq!(empty.shard_of(9), 0);
    }

    /// Two counters ping-pong increments through the outbox: rank 0
    /// stops the run once its counter reaches a threshold, and both
    /// participants exit at the same window.
    struct PingPong {
        rank: usize,
        count: u64,
        windows: u64,
    }

    impl Partitioned for PingPong {
        type Msg = u64;
        fn window(
            &mut self,
            _start: SimTime,
            _end: SimTime,
            incoming: Vec<(usize, u64)>,
            out: &mut Outbox<u64>,
        ) -> bool {
            self.windows += 1;
            for (_, v) in incoming {
                self.count += v;
            }
            out.send(1 - self.rank, 1);
            self.rank == 0 && self.count >= 5
        }
    }

    #[test]
    fn lockstep_stops_collectively() {
        let parts = vec![
            PingPong { rank: 0, count: 0, windows: 0 },
            PingPong { rank: 1, count: 0, windows: 0 },
        ];
        let (done, windows) = run_lockstep(parts, SimTime::from_secs(1), 1000);
        assert_eq!(done[0].windows, done[1].windows, "collective exit");
        assert_eq!(done[0].windows, windows);
        assert!(done[0].count >= 5);
    }

    /// The seatbelt: a run whose conductor never stops is cut at
    /// `max_windows` on every thread at once.
    #[test]
    fn lockstep_honors_the_window_cap() {
        struct Forever;
        impl Partitioned for Forever {
            type Msg = ();
            fn window(
                &mut self,
                _s: SimTime,
                _e: SimTime,
                _i: Vec<(usize, ())>,
                _o: &mut Outbox<()>,
            ) -> bool {
                false
            }
        }
        let (_, windows) = run_lockstep(vec![Forever, Forever], SimTime::from_secs(1), 7);
        assert_eq!(windows, 7);
    }
}
