//! Event engine: a time-ordered queue of typed events over a state `S`.
//!
//! Events fire in `(time, insertion-seq)` order, so same-timestamp
//! events run FIFO and runs are fully deterministic. The queue behind
//! the engine is a [`CalendarQueue`] (ring of time buckets + overflow
//! map) rather than a binary heap: pushes inside the ring horizon are
//! an append, and each bucket is sorted once when the cursor reaches
//! it. The ordering contract is pinned byte-for-byte to the original
//! heap implementation, kept in [`crate::sim::reference`] and enforced
//! by the differential suite in `tests/event_engine.rs`.
//!
//! An event type implements [`SimEvent`]: a plain `enum` dispatched in
//! `fire`, so scheduling allocates nothing per event. The legacy
//! boxed-closure style is still available through the default event
//! type [`Thunk`] (used by small tests and one-off simulations);
//! production state machines (`cluster::vcluster`, `cluster::shard`)
//! define their own enums.

use super::calendar::CalendarQueue;
use super::time::SimTime;
use std::marker::PhantomData;

/// A schedulable event over state `S`. Implementors are typically
/// fieldful enums; `fire` consumes the event and may schedule more.
pub trait SimEvent<S>: Sized {
    /// Handle the event. The engine has already advanced `now` to the
    /// event's timestamp and counted it as fired.
    fn fire(self, state: &mut S, eng: &mut Engine<S, Self>);
}

/// The default event type: a boxed closure, one allocation per event.
/// This is the pre-enum engine's scheduling style, kept for tests and
/// one-off drivers where ergonomics beat throughput.
pub struct Thunk<S>(Box<dyn FnOnce(&mut S, &mut Engine<S, Thunk<S>>)>);

impl<S> Thunk<S> {
    /// Wrap a closure as a schedulable event.
    pub fn new<F>(f: F) -> Self
    where
        F: FnOnce(&mut S, &mut Engine<S, Thunk<S>>) + 'static,
    {
        Thunk(Box::new(f))
    }
}

impl<S> SimEvent<S> for Thunk<S> {
    fn fire(self, state: &mut S, eng: &mut Engine<S, Thunk<S>>) {
        (self.0)(state, eng)
    }
}

/// Discrete-event engine over state `S` with event type `E`.
pub struct Engine<S, E = Thunk<S>> {
    now: SimTime,
    seq: u64,
    fired: u64,
    queue: CalendarQueue<E>,
    _state: PhantomData<fn(&mut S)>,
}

impl<S, E> Default for Engine<S, E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S, E> Engine<S, E> {
    pub fn new() -> Self {
        Self {
            now: SimTime::ZERO,
            seq: 0,
            fired: 0,
            queue: CalendarQueue::new(),
            _state: PhantomData,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events fired so far.
    pub fn fired(&self) -> u64 {
        self.fired
    }

    /// Pending event count.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule an event at an absolute time (clamped to now if in the
    /// past).
    pub fn schedule_at(&mut self, at: SimTime, ev: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(at.as_nanos(), seq, ev);
    }

    /// Schedule an event after a delay from now.
    pub fn schedule_after(&mut self, delay: SimTime, ev: E) {
        self.schedule_at(self.now + delay, ev);
    }

    /// Time of the next pending event, if any. The partitioned runner
    /// uses this to tell an idle window from one with work left.
    /// `&mut self` because peeking may activate the next calendar
    /// bucket.
    pub fn next_event_at(&mut self) -> Option<SimTime> {
        self.queue.peek_key().map(|(t, _)| SimTime::from_nanos(t))
    }
}

impl<S> Engine<S, Thunk<S>> {
    /// Closure-flavored [`Engine::schedule_at`]: wraps `f` in a
    /// [`Thunk`].
    pub fn schedule_at_fn<F>(&mut self, at: SimTime, f: F)
    where
        F: FnOnce(&mut S, &mut Engine<S, Thunk<S>>) + 'static,
    {
        self.schedule_at(at, Thunk::new(f));
    }

    /// Closure-flavored [`Engine::schedule_after`].
    pub fn schedule_after_fn<F>(&mut self, delay: SimTime, f: F)
    where
        F: FnOnce(&mut S, &mut Engine<S, Thunk<S>>) + 'static,
    {
        self.schedule_after(delay, Thunk::new(f));
    }
}

impl<S, E: SimEvent<S>> Engine<S, E> {
    /// Fire the next event. Returns false when the queue is empty.
    pub fn step(&mut self, state: &mut S) -> bool {
        match self.queue.pop() {
            Some((at, _seq, ev)) => {
                let at = SimTime::from_nanos(at);
                debug_assert!(at >= self.now, "time went backwards");
                self.now = at;
                self.fired += 1;
                ev.fire(state, self);
                true
            }
            None => false,
        }
    }

    /// Run until the queue drains or `until` is reached. Events scheduled
    /// at exactly `until` still fire. Returns the number fired.
    pub fn run_until(&mut self, state: &mut S, until: SimTime) -> u64 {
        let mut n = 0;
        while let Some((t, _)) = self.queue.peek_key() {
            if t > until.as_nanos() {
                break;
            }
            self.step(state);
            n += 1;
        }
        // Advance the clock even if nothing fired at `until`.
        self.now = self.now.max(until);
        n
    }

    /// Advance one lock-step window: fire every event strictly before
    /// `end`, then set the clock to `end`. The strict bound is the
    /// window contract — an event scheduled exactly at `end` belongs to
    /// the *next* window, on every shard, at every shard count, so the
    /// window grid never double-fires or drops a boundary event.
    /// Returns the number fired.
    pub fn run_window(&mut self, state: &mut S, end: SimTime) -> u64 {
        let mut n = 0;
        while let Some((t, _)) = self.queue.peek_key() {
            if t >= end.as_nanos() {
                break;
            }
            self.step(state);
            n += 1;
        }
        self.now = self.now.max(end);
        n
    }

    /// Run until the queue is fully drained. Returns events fired.
    pub fn run_to_completion(&mut self, state: &mut S) -> u64 {
        let mut n = 0;
        while self.step(state) {
            n += 1;
        }
        n
    }

    /// Run until `pred(state)` holds (checked after each event) or the
    /// queue drains. Returns true if the predicate was satisfied.
    pub fn run_until_pred(
        &mut self,
        state: &mut S,
        mut pred: impl FnMut(&S) -> bool,
    ) -> bool {
        if pred(state) {
            return true;
        }
        while self.step(state) {
            if pred(state) {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut log = Vec::new();
        eng.schedule_at_fn(SimTime::from_millis(30), |s: &mut Vec<u32>, _| s.push(3));
        eng.schedule_at_fn(SimTime::from_millis(10), |s, _| s.push(1));
        eng.schedule_at_fn(SimTime::from_millis(20), |s, _| s.push(2));
        eng.run_to_completion(&mut log);
        assert_eq!(log, vec![1, 2, 3]);
        assert_eq!(eng.now(), SimTime::from_millis(30));
    }

    #[test]
    fn same_time_is_fifo() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut log = Vec::new();
        for i in 0..10 {
            eng.schedule_at_fn(SimTime::from_millis(5), move |s: &mut Vec<u32>, _| {
                s.push(i)
            });
        }
        eng.run_to_completion(&mut log);
        assert_eq!(log, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_reschedule() {
        struct St {
            count: u32,
        }
        fn tick(s: &mut St, eng: &mut Engine<St>) {
            s.count += 1;
            if s.count < 5 {
                eng.schedule_after_fn(SimTime::from_secs(1), tick);
            }
        }
        let mut eng = Engine::new();
        let mut st = St { count: 0 };
        eng.schedule_after_fn(SimTime::from_secs(1), tick);
        eng.run_to_completion(&mut st);
        assert_eq!(st.count, 5);
        assert_eq!(eng.now(), SimTime::from_secs(5));
    }

    #[test]
    fn run_until_stops_and_advances_clock() {
        let mut eng: Engine<u32> = Engine::new();
        let mut s = 0u32;
        eng.schedule_at_fn(SimTime::from_secs(1), |s: &mut u32, _| *s += 1);
        eng.schedule_at_fn(SimTime::from_secs(10), |s: &mut u32, _| *s += 1);
        let fired = eng.run_until(&mut s, SimTime::from_secs(5));
        assert_eq!(fired, 1);
        assert_eq!(s, 1);
        assert_eq!(eng.now(), SimTime::from_secs(5));
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        let mut eng: Engine<Vec<u64>> = Engine::new();
        let mut log = Vec::new();
        eng.schedule_at_fn(SimTime::from_secs(2), |s: &mut Vec<u64>, eng| {
            // scheduled "in the past" — must fire at now, not before
            eng.schedule_at_fn(SimTime::from_secs(1), |s2: &mut Vec<u64>, e2| {
                s2.push(e2.now().as_nanos());
            });
            s.push(eng.now().as_nanos());
        });
        eng.run_to_completion(&mut log);
        assert_eq!(log.len(), 2);
        assert_eq!(log[0], log[1]);
    }

    #[test]
    fn run_window_is_half_open() {
        let mut eng: Engine<Vec<u64>> = Engine::new();
        let mut log = Vec::new();
        eng.schedule_at_fn(SimTime::from_secs(1), |s: &mut Vec<u64>, _| s.push(1));
        eng.schedule_at_fn(SimTime::from_secs(2), |s: &mut Vec<u64>, _| s.push(2));
        eng.schedule_at_fn(SimTime::from_secs(3), |s: &mut Vec<u64>, _| s.push(3));
        assert_eq!(eng.next_event_at(), Some(SimTime::from_secs(1)));
        // [0, 2): the event at exactly 2s belongs to the next window
        let fired = eng.run_window(&mut log, SimTime::from_secs(2));
        assert_eq!(fired, 1);
        assert_eq!(log, vec![1]);
        assert_eq!(eng.now(), SimTime::from_secs(2));
        assert_eq!(eng.next_event_at(), Some(SimTime::from_secs(2)));
        // [2, 4): picks up the boundary event exactly once
        let fired = eng.run_window(&mut log, SimTime::from_secs(4));
        assert_eq!(fired, 2);
        assert_eq!(log, vec![1, 2, 3]);
        // an empty window still advances the clock
        assert_eq!(eng.run_window(&mut log, SimTime::from_secs(9)), 0);
        assert_eq!(eng.now(), SimTime::from_secs(9));
        assert_eq!(eng.next_event_at(), None);
    }

    #[test]
    fn run_until_pred_short_circuits() {
        let mut eng: Engine<u32> = Engine::new();
        let mut s = 0u32;
        for i in 1..=10u64 {
            eng.schedule_at_fn(SimTime::from_secs(i), |s: &mut u32, _| *s += 1);
        }
        let ok = eng.run_until_pred(&mut s, |s| *s == 3);
        assert!(ok);
        assert_eq!(s, 3);
        assert_eq!(eng.now(), SimTime::from_secs(3));
    }

    /// The typed-event path: a fieldful enum scheduled with no per-event
    /// allocation, dispatching through [`SimEvent::fire`].
    #[test]
    fn enum_events_fire_and_reschedule() {
        enum Ev {
            Add(u32),
            Tick,
        }
        struct St {
            sum: u32,
            ticks: u32,
        }
        impl SimEvent<St> for Ev {
            fn fire(self, st: &mut St, eng: &mut Engine<St, Ev>) {
                match self {
                    Ev::Add(n) => st.sum += n,
                    Ev::Tick => {
                        st.ticks += 1;
                        if st.ticks < 3 {
                            eng.schedule_after(SimTime::from_secs(1), Ev::Tick);
                        }
                    }
                }
            }
        }
        let mut eng: Engine<St, Ev> = Engine::new();
        let mut st = St { sum: 0, ticks: 0 };
        eng.schedule_at(SimTime::from_secs(1), Ev::Tick);
        eng.schedule_at(SimTime::from_secs(2), Ev::Add(5));
        eng.run_to_completion(&mut st);
        assert_eq!(st.sum, 5);
        assert_eq!(st.ticks, 3);
        assert_eq!(eng.now(), SimTime::from_secs(3));
        assert_eq!(eng.fired(), 4);
    }
}
