//! Event engine: a time-ordered queue of closures over a state `S`.
//!
//! Events fire in `(time, insertion-seq)` order, so same-timestamp events
//! run FIFO and runs are fully deterministic. Handlers receive
//! `(&mut S, &mut Engine<S>)` and may schedule further events.

use super::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

type Handler<S> = Box<dyn FnOnce(&mut S, &mut Engine<S>)>;

struct Entry<S> {
    at: SimTime,
    seq: u64,
    handler: Handler<S>,
}

impl<S> PartialEq for Entry<S> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<S> Eq for Entry<S> {}
impl<S> PartialOrd for Entry<S> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<S> Ord for Entry<S> {
    // Reverse order: BinaryHeap is a max-heap, we want earliest first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Discrete-event engine over state `S`.
pub struct Engine<S> {
    now: SimTime,
    seq: u64,
    fired: u64,
    queue: BinaryHeap<Entry<S>>,
}

impl<S> Default for Engine<S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S> Engine<S> {
    pub fn new() -> Self {
        Self { now: SimTime::ZERO, seq: 0, fired: 0, queue: BinaryHeap::new() }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events fired so far.
    pub fn fired(&self) -> u64 {
        self.fired
    }

    /// Pending event count.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule at an absolute time (clamped to now if in the past).
    pub fn schedule_at<F>(&mut self, at: SimTime, f: F)
    where
        F: FnOnce(&mut S, &mut Engine<S>) + 'static,
    {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Entry { at, seq, handler: Box::new(f) });
    }

    /// Schedule after a delay from now.
    pub fn schedule_after<F>(&mut self, delay: SimTime, f: F)
    where
        F: FnOnce(&mut S, &mut Engine<S>) + 'static,
    {
        self.schedule_at(self.now + delay, f);
    }

    /// Fire the next event. Returns false when the queue is empty.
    pub fn step(&mut self, state: &mut S) -> bool {
        match self.queue.pop() {
            Some(Entry { at, handler, .. }) => {
                debug_assert!(at >= self.now, "time went backwards");
                self.now = at;
                self.fired += 1;
                handler(state, self);
                true
            }
            None => false,
        }
    }

    /// Run until the queue drains or `until` is reached. Events scheduled
    /// at exactly `until` still fire. Returns the number fired.
    pub fn run_until(&mut self, state: &mut S, until: SimTime) -> u64 {
        let mut n = 0;
        while let Some(e) = self.queue.peek() {
            if e.at > until {
                break;
            }
            self.step(state);
            n += 1;
        }
        // Advance the clock even if nothing fired at `until`.
        self.now = self.now.max(until);
        n
    }

    /// Time of the next pending event, if any. The partitioned runner
    /// uses this to tell an idle window from one with work left.
    pub fn next_event_at(&self) -> Option<SimTime> {
        self.queue.peek().map(|e| e.at)
    }

    /// Advance one lock-step window: fire every event strictly before
    /// `end`, then set the clock to `end`. The strict bound is the
    /// window contract — an event scheduled exactly at `end` belongs to
    /// the *next* window, on every shard, at every shard count, so the
    /// window grid never double-fires or drops a boundary event.
    /// Returns the number fired.
    pub fn run_window(&mut self, state: &mut S, end: SimTime) -> u64 {
        let mut n = 0;
        while let Some(e) = self.queue.peek() {
            if e.at >= end {
                break;
            }
            self.step(state);
            n += 1;
        }
        self.now = self.now.max(end);
        n
    }

    /// Run until the queue is fully drained. Returns events fired.
    pub fn run_to_completion(&mut self, state: &mut S) -> u64 {
        let mut n = 0;
        while self.step(state) {
            n += 1;
        }
        n
    }

    /// Run until `pred(state)` holds (checked after each event) or the
    /// queue drains. Returns true if the predicate was satisfied.
    pub fn run_until_pred(
        &mut self,
        state: &mut S,
        mut pred: impl FnMut(&S) -> bool,
    ) -> bool {
        if pred(state) {
            return true;
        }
        while self.step(state) {
            if pred(state) {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut log = Vec::new();
        eng.schedule_at(SimTime::from_millis(30), |s: &mut Vec<u32>, _| s.push(3));
        eng.schedule_at(SimTime::from_millis(10), |s, _| s.push(1));
        eng.schedule_at(SimTime::from_millis(20), |s, _| s.push(2));
        eng.run_to_completion(&mut log);
        assert_eq!(log, vec![1, 2, 3]);
        assert_eq!(eng.now(), SimTime::from_millis(30));
    }

    #[test]
    fn same_time_is_fifo() {
        let mut eng: Engine<Vec<u32>> = Engine::new();
        let mut log = Vec::new();
        for i in 0..10 {
            eng.schedule_at(SimTime::from_millis(5), move |s: &mut Vec<u32>, _| {
                s.push(i)
            });
        }
        eng.run_to_completion(&mut log);
        assert_eq!(log, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_reschedule() {
        struct St {
            count: u32,
        }
        fn tick(s: &mut St, eng: &mut Engine<St>) {
            s.count += 1;
            if s.count < 5 {
                eng.schedule_after(SimTime::from_secs(1), tick);
            }
        }
        let mut eng = Engine::new();
        let mut st = St { count: 0 };
        eng.schedule_after(SimTime::from_secs(1), tick);
        eng.run_to_completion(&mut st);
        assert_eq!(st.count, 5);
        assert_eq!(eng.now(), SimTime::from_secs(5));
    }

    #[test]
    fn run_until_stops_and_advances_clock() {
        let mut eng: Engine<u32> = Engine::new();
        let mut s = 0u32;
        eng.schedule_at(SimTime::from_secs(1), |s: &mut u32, _| *s += 1);
        eng.schedule_at(SimTime::from_secs(10), |s: &mut u32, _| *s += 1);
        let fired = eng.run_until(&mut s, SimTime::from_secs(5));
        assert_eq!(fired, 1);
        assert_eq!(s, 1);
        assert_eq!(eng.now(), SimTime::from_secs(5));
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn past_schedules_clamp_to_now() {
        let mut eng: Engine<Vec<u64>> = Engine::new();
        let mut log = Vec::new();
        eng.schedule_at(SimTime::from_secs(2), |s: &mut Vec<u64>, eng| {
            // scheduled "in the past" — must fire at now, not before
            eng.schedule_at(SimTime::from_secs(1), |s2: &mut Vec<u64>, e2| {
                s2.push(e2.now().as_nanos());
            });
            s.push(eng.now().as_nanos());
        });
        eng.run_to_completion(&mut log);
        assert_eq!(log.len(), 2);
        assert_eq!(log[0], log[1]);
    }

    #[test]
    fn run_window_is_half_open() {
        let mut eng: Engine<Vec<u64>> = Engine::new();
        let mut log = Vec::new();
        eng.schedule_at(SimTime::from_secs(1), |s: &mut Vec<u64>, _| s.push(1));
        eng.schedule_at(SimTime::from_secs(2), |s: &mut Vec<u64>, _| s.push(2));
        eng.schedule_at(SimTime::from_secs(3), |s: &mut Vec<u64>, _| s.push(3));
        assert_eq!(eng.next_event_at(), Some(SimTime::from_secs(1)));
        // [0, 2): the event at exactly 2s belongs to the next window
        let fired = eng.run_window(&mut log, SimTime::from_secs(2));
        assert_eq!(fired, 1);
        assert_eq!(log, vec![1]);
        assert_eq!(eng.now(), SimTime::from_secs(2));
        assert_eq!(eng.next_event_at(), Some(SimTime::from_secs(2)));
        // [2, 4): picks up the boundary event exactly once
        let fired = eng.run_window(&mut log, SimTime::from_secs(4));
        assert_eq!(fired, 2);
        assert_eq!(log, vec![1, 2, 3]);
        // an empty window still advances the clock
        assert_eq!(eng.run_window(&mut log, SimTime::from_secs(9)), 0);
        assert_eq!(eng.now(), SimTime::from_secs(9));
        assert_eq!(eng.next_event_at(), None);
    }

    #[test]
    fn run_until_pred_short_circuits() {
        let mut eng: Engine<u32> = Engine::new();
        let mut s = 0u32;
        for i in 1..=10u64 {
            eng.schedule_at(SimTime::from_secs(i), |s: &mut u32, _| *s += 1);
        }
        let ok = eng.run_until_pred(&mut s, |s| *s == 3);
        assert!(ok);
        assert_eq!(s, 3);
        assert_eq!(eng.now(), SimTime::from_secs(3));
    }
}
