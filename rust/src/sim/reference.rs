//! Reference event engine: the original boxed-closure `BinaryHeap`
//! implementation, preserved byte-for-byte in behavior.
//!
//! [`crate::sim::Engine`] replaced this with a calendar queue over a
//! typed event enum; this copy stays as the executable specification
//! of the ordering contract — events fire in `(time, insertion-seq)`
//! order, same-timestamp events run FIFO. The differential suite in
//! `tests/event_engine.rs` drives both engines through seeded random
//! schedules and asserts identical pop order and fired counts, and the
//! `vhpc perf` harness measures the calendar engine's speedup against
//! this baseline.

use super::time::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

type Handler<S> = Box<dyn FnOnce(&mut S, &mut ClosureHeapEngine<S>)>;

struct Entry<S> {
    at: SimTime,
    seq: u64,
    handler: Handler<S>,
}

impl<S> PartialEq for Entry<S> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<S> Eq for Entry<S> {}
impl<S> PartialOrd for Entry<S> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<S> Ord for Entry<S> {
    // Reverse order: BinaryHeap is a max-heap, we want earliest first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The pre-calendar-queue discrete-event engine over state `S`:
/// a max-`BinaryHeap` of reverse-ordered boxed closures.
pub struct ClosureHeapEngine<S> {
    now: SimTime,
    seq: u64,
    fired: u64,
    queue: BinaryHeap<Entry<S>>,
}

impl<S> Default for ClosureHeapEngine<S> {
    fn default() -> Self {
        Self::new()
    }
}

impl<S> ClosureHeapEngine<S> {
    pub fn new() -> Self {
        Self { now: SimTime::ZERO, seq: 0, fired: 0, queue: BinaryHeap::new() }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events fired so far.
    pub fn fired(&self) -> u64 {
        self.fired
    }

    /// Pending event count.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule at an absolute time (clamped to now if in the past).
    pub fn schedule_at<F>(&mut self, at: SimTime, f: F)
    where
        F: FnOnce(&mut S, &mut ClosureHeapEngine<S>) + 'static,
    {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Entry { at, seq, handler: Box::new(f) });
    }

    /// Schedule after a delay from now.
    pub fn schedule_after<F>(&mut self, delay: SimTime, f: F)
    where
        F: FnOnce(&mut S, &mut ClosureHeapEngine<S>) + 'static,
    {
        self.schedule_at(self.now + delay, f);
    }

    /// Fire the next event. Returns false when the queue is empty.
    pub fn step(&mut self, state: &mut S) -> bool {
        match self.queue.pop() {
            Some(Entry { at, handler, .. }) => {
                debug_assert!(at >= self.now, "time went backwards");
                self.now = at;
                self.fired += 1;
                handler(state, self);
                true
            }
            None => false,
        }
    }

    /// Run until the queue drains or `until` is reached. Events scheduled
    /// at exactly `until` still fire. Returns the number fired.
    pub fn run_until(&mut self, state: &mut S, until: SimTime) -> u64 {
        let mut n = 0;
        while let Some(e) = self.queue.peek() {
            if e.at > until {
                break;
            }
            self.step(state);
            n += 1;
        }
        // Advance the clock even if nothing fired at `until`.
        self.now = self.now.max(until);
        n
    }

    /// Time of the next pending event, if any.
    pub fn next_event_at(&self) -> Option<SimTime> {
        self.queue.peek().map(|e| e.at)
    }

    /// Advance one lock-step window: fire every event strictly before
    /// `end`, then set the clock to `end`. Returns the number fired.
    pub fn run_window(&mut self, state: &mut S, end: SimTime) -> u64 {
        let mut n = 0;
        while let Some(e) = self.queue.peek() {
            if e.at >= end {
                break;
            }
            self.step(state);
            n += 1;
        }
        self.now = self.now.max(end);
        n
    }

    /// Run until the queue is fully drained. Returns events fired.
    pub fn run_to_completion(&mut self, state: &mut S) -> u64 {
        let mut n = 0;
        while self.step(state) {
            n += 1;
        }
        n
    }

    /// Run until `pred(state)` holds (checked after each event) or the
    /// queue drains. Returns true if the predicate was satisfied.
    pub fn run_until_pred(
        &mut self,
        state: &mut S,
        mut pred: impl FnMut(&S) -> bool,
    ) -> bool {
        if pred(state) {
            return true;
        }
        while self.step(state) {
            if pred(state) {
                return true;
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_in_time_order_with_fifo_ties() {
        let mut eng: ClosureHeapEngine<Vec<u32>> = ClosureHeapEngine::new();
        let mut log = Vec::new();
        eng.schedule_at(SimTime::from_millis(30), |s: &mut Vec<u32>, _| s.push(3));
        eng.schedule_at(SimTime::from_millis(10), |s, _| s.push(1));
        eng.schedule_at(SimTime::from_millis(10), |s, _| s.push(2));
        eng.run_to_completion(&mut log);
        assert_eq!(log, vec![1, 2, 3]);
        assert_eq!(eng.now(), SimTime::from_millis(30));
        assert_eq!(eng.fired(), 3);
    }
}
