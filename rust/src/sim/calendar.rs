//! Calendar queue: the event engine's priority queue, keyed on
//! `(time, insertion-seq)` with exact global ascending pop order.
//!
//! A classic binary heap pays `O(log n)` per operation and scatters
//! entries across the allocation; at million-event traces the engine
//! spends most of its time in heap sift and cache misses. A calendar
//! queue exploits the structure simulation schedules actually have —
//! most events land a short, bounded distance in the future — by
//! hashing each event into a ring of time buckets:
//!
//! * the **ring**: `2^k` unsorted buckets, each `2^shift` ns wide, so
//!   pushes within the ring's horizon are an append (`O(1)`, no
//!   comparisons);
//! * the **active bucket**: when the cursor reaches a non-empty
//!   bucket, its events are sorted *once* (descending, so pops are
//!   `Vec::pop` from the tail) — the classic "sort one day's events
//!   when you tear the page off the calendar";
//! * the **overflow map**: events beyond the ring's horizon go to a
//!   `BTreeMap` keyed on `(time, seq)`; when the ring drains, the
//!   cursor jumps straight to the earliest overflow bucket instead of
//!   scanning empty slots.
//!
//! Each ring slot holds events of exactly one absolute bucket at a
//! time (the cursor never advances past a non-empty slot, and pushes
//! land only inside the current horizon), so a slot never mixes
//! events from different wrap-arounds of the ring.
//!
//! Ties break on `seq` — the engine's global insertion counter — so
//! same-instant events pop FIFO, byte-identical to the binary-heap
//! engine this structure replaced (see `sim/reference.rs` and the
//! differential suite in `tests/event_engine.rs`).

use std::collections::BTreeMap;

/// Default bucket width exponent: `2^29` ns ≈ 0.54 s, on the order of
/// the engine's densest periodic rates (1 s scheduler ticks, sub-2 s
/// heartbeats).
const DEFAULT_SHIFT: u32 = 29;
/// Default ring size exponent: `2^9 = 512` buckets ≈ 275 s of horizon.
const DEFAULT_BUCKETS_LOG2: u32 = 9;

/// A monotonically-popped priority queue over `(t_ns, seq)` keys.
///
/// Contract (matched to the engine's use): keys pushed after a pop are
/// never smaller than the last popped key (the engine clamps schedule
/// times to `now` and `seq` grows monotonically), and every `(t, seq)`
/// key is unique. Under that contract `pop` yields keys in exact
/// ascending `(t, seq)` order.
pub struct CalendarQueue<T> {
    /// Bucket width is `2^shift` nanoseconds.
    shift: u32,
    /// `ring.len()` is a power of two; `mask = ring.len() - 1`.
    ring: Vec<Vec<(u64, u64, T)>>,
    mask: u64,
    /// Absolute bucket number the cursor has reached (its ring slot is
    /// already drained into `active`).
    cur_bucket: u64,
    /// Events currently resident in the ring.
    ring_count: usize,
    /// The activated bucket, sorted descending by `(t, seq)` so the
    /// next event pops from the tail. Late pushes at or before the
    /// cursor's bucket are merge-inserted here.
    active: Vec<(u64, u64, T)>,
    /// Events beyond the ring horizon, globally ordered.
    overflow: BTreeMap<(u64, u64), T>,
    len: usize,
}

impl<T> Default for CalendarQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> CalendarQueue<T> {
    /// A queue with the default geometry (512 buckets × ~0.54 s).
    pub fn new() -> Self {
        Self::with_geometry(DEFAULT_SHIFT, DEFAULT_BUCKETS_LOG2)
    }

    /// A queue with `2^buckets_log2` buckets of `2^shift` ns each.
    /// Exposed so the differential tests can shrink the horizon enough
    /// to force overflow jumps and ring wrap-around.
    pub fn with_geometry(shift: u32, buckets_log2: u32) -> Self {
        let shift = shift.min(48);
        let n = 1usize << buckets_log2.min(16);
        let mut ring = Vec::with_capacity(n);
        ring.resize_with(n, Vec::new);
        Self {
            shift,
            mask: (n as u64) - 1,
            ring,
            cur_bucket: 0,
            ring_count: 0,
            active: Vec::new(),
            overflow: BTreeMap::new(),
            len: 0,
        }
    }

    /// Number of queued events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no events are queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Queue `value` under key `(t_ns, seq)`.
    pub fn push(&mut self, t_ns: u64, seq: u64, value: T) {
        let bucket = t_ns >> self.shift;
        if bucket <= self.cur_bucket {
            // The cursor already tore this page off: merge-insert into
            // the sorted active bucket (descending, unique keys).
            let key = (t_ns, seq);
            let pos = self.active.partition_point(|&(t, s, _)| (t, s) > key);
            self.active.insert(pos, (t_ns, seq, value));
        } else if bucket < self.cur_bucket + (self.mask + 1) {
            self.ring[(bucket & self.mask) as usize].push((t_ns, seq, value));
            self.ring_count += 1;
        } else {
            self.overflow.insert((t_ns, seq), value);
        }
        self.len += 1;
    }

    /// Key of the earliest queued event, without removing it. Needs
    /// `&mut self`: peeking may tear off the next calendar page.
    pub fn peek_key(&mut self) -> Option<(u64, u64)> {
        if self.active.is_empty() {
            self.advance();
        }
        self.active.last().map(|&(t, s, _)| (t, s))
    }

    /// Remove and return the earliest event as `(t_ns, seq, value)`.
    pub fn pop(&mut self) -> Option<(u64, u64, T)> {
        if self.active.is_empty() {
            self.advance();
        }
        let popped = self.active.pop();
        if popped.is_some() {
            self.len -= 1;
        }
        popped
    }

    /// Move the cursor to the next non-empty bucket (ring or overflow,
    /// whichever is earlier) and sort it into `active`. No-op when
    /// nothing is queued beyond the (empty) active bucket.
    fn advance(&mut self) {
        if self.ring_count == 0 && self.overflow.is_empty() {
            return;
        }
        let overflow_bucket =
            self.overflow.keys().next().map(|&(t, _)| t >> self.shift);
        let mut next = match overflow_bucket {
            Some(b) if self.ring_count == 0 => b,
            _ => {
                // The ring holds at least one event, strictly inside
                // (cur_bucket, cur_bucket + ring_len): scan forward.
                // Bounded by the ring length.
                let mut b = self.cur_bucket + 1;
                while self.ring[(b & self.mask) as usize].is_empty() {
                    b += 1;
                }
                b
            }
        };
        if let Some(ob) = overflow_bucket {
            // An overflow event may predate everything in the ring
            // (pushed beyond an older, lower horizon).
            next = next.min(ob);
        }
        self.activate(next);
    }

    /// Tear off bucket `b`: take its ring slot plus any overflow
    /// entries falling inside it, and sort them descending.
    fn activate(&mut self, b: u64) {
        self.cur_bucket = b;
        let slot = &mut self.ring[(b & self.mask) as usize];
        self.ring_count -= slot.len();
        self.active = std::mem::take(slot);
        if !self.overflow.is_empty() {
            let lo = (b << self.shift, 0u64);
            // Inclusive upper key avoids the `(b + 1) << shift` wrap at
            // the top of the time domain.
            let hi = ((b << self.shift) | ((1u64 << self.shift) - 1), u64::MAX);
            let keys: Vec<(u64, u64)> =
                self.overflow.range(lo..=hi).map(|(&k, _)| k).collect();
            for k in keys {
                if let Some(v) = self.overflow.remove(&k) {
                    self.active.push((k.0, k.1, v));
                }
            }
        }
        self.active.sort_unstable_by(|a, b| (b.0, b.1).cmp(&(a.0, a.1)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut CalendarQueue<u32>) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        while let Some((t, s, _)) = q.pop() {
            out.push((t, s));
        }
        out
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = CalendarQueue::new();
        q.push(30, 0, 0);
        q.push(10, 1, 0);
        q.push(10, 2, 0);
        q.push(20, 3, 0);
        assert_eq!(q.len(), 4);
        assert_eq!(drain(&mut q), vec![(10, 1), (10, 2), (20, 3), (30, 0)]);
        assert!(q.is_empty());
    }

    #[test]
    fn far_future_goes_through_overflow_and_back() {
        // 2 buckets of 2 ns each: anything past 4 ns overflows.
        let mut q = CalendarQueue::with_geometry(1, 1);
        q.push(1_000_000, 0, 7);
        q.push(1, 1, 8);
        q.push(500, 2, 9);
        assert_eq!(drain(&mut q), vec![(1, 1), (500, 2), (1_000_000, 0)]);
    }

    #[test]
    fn ring_wraps_without_mixing_buckets() {
        // 4 buckets of 4 ns: buckets 0 and 4 share ring slot 0.
        let mut q = CalendarQueue::with_geometry(2, 2);
        q.push(1, 0, 0);
        q.push(6, 1, 0);
        assert_eq!(q.pop().map(|(t, s, _)| (t, s)), Some((1, 0)));
        assert_eq!(q.pop().map(|(t, s, _)| (t, s)), Some((6, 1)));
        // Cursor now at bucket 1, horizon [1, 5): t=17 (bucket 4)
        // lands in ring slot 0 — the slot bucket 0 vacated.
        q.push(17, 2, 0);
        q.push(9, 3, 0);
        assert_eq!(drain(&mut q), vec![(9, 3), (17, 2)]);
    }

    #[test]
    fn late_pushes_into_the_active_bucket_keep_order() {
        let mut q = CalendarQueue::with_geometry(4, 2);
        q.push(10, 0, 0);
        q.push(12, 1, 0);
        // Activate the bucket by peeking, then push into it.
        assert_eq!(q.peek_key(), Some((10, 0)));
        q.push(11, 2, 0);
        q.push(10, 3, 0);
        assert_eq!(drain(&mut q), vec![(10, 0), (10, 3), (11, 2), (12, 1)]);
    }

    #[test]
    fn overflow_predating_ring_entries_wins() {
        // 2 buckets of 2 ns. Push far future (overflow), advance the
        // cursor there, then push a ring event beyond it and an
        // overflow event between.
        let mut q = CalendarQueue::with_geometry(1, 1);
        q.push(100, 0, 0); // overflow (bucket 50)
        q.push(1, 1, 0); // ring
        assert_eq!(q.pop().map(|(t, s, _)| (t, s)), Some((1, 1)));
        // Cursor still at bucket 0: 100 is overflow, 3 is in-ring.
        q.push(3, 2, 0);
        assert_eq!(drain(&mut q), vec![(3, 2), (100, 0)]);
    }

    #[test]
    fn empty_queue_behaves() {
        let mut q: CalendarQueue<u32> = CalendarQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.peek_key(), None);
        assert_eq!(q.pop().map(|(t, s, _)| (t, s)), None);
    }

    #[test]
    fn interleaved_push_pop_stays_sorted() {
        let mut q = CalendarQueue::with_geometry(3, 3);
        let mut seq = 0u64;
        let mut push = |q: &mut CalendarQueue<u32>, t: u64, seq: &mut u64| {
            q.push(t, *seq, 0);
            *seq += 1;
        };
        push(&mut q, 5, &mut seq);
        push(&mut q, 900, &mut seq);
        assert_eq!(q.pop().map(|(t, _, _)| t), Some(5));
        // now >= 5: schedule same-tick and near-future events
        push(&mut q, 5, &mut seq);
        push(&mut q, 6, &mut seq);
        push(&mut q, 400, &mut seq);
        let order = drain(&mut q);
        assert_eq!(order, vec![(5, 2), (6, 3), (400, 4), (900, 1)]);
    }
}
