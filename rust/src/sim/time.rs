//! Virtual time: nanosecond ticks since simulation start.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point (or span) in virtual time, in nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub const fn from_nanos(ns: u64) -> Self {
        Self(ns)
    }
    pub const fn from_micros(us: u64) -> Self {
        Self(us * 1_000)
    }
    pub const fn from_millis(ms: u64) -> Self {
        Self(ms * 1_000_000)
    }
    pub const fn from_secs(s: u64) -> Self {
        Self(s * 1_000_000_000)
    }
    /// From fractional seconds (saturating at zero for negatives).
    pub fn from_secs_f64(s: f64) -> Self {
        Self((s.max(0.0) * 1e9) as u64)
    }

    pub fn as_nanos(self) -> u64 {
        self.0
    }
    pub fn as_micros_f64(self) -> f64 {
        self.0 as f64 / 1e3
    }
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1e6
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }

    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }
    pub fn min(self, other: SimTime) -> SimTime {
        SimTime(self.0.min(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ns = self.0;
        if ns < 1_000 {
            write!(f, "{ns}ns")
        } else if ns < 1_000_000 {
            write!(f, "{:.1}us", ns as f64 / 1e3)
        } else if ns < 1_000_000_000 {
            write!(f, "{:.2}ms", ns as f64 / 1e6)
        } else {
            write!(f, "{:.3}s", ns as f64 / 1e9)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(SimTime::from_micros(3).as_nanos(), 3_000);
        assert_eq!(SimTime::from_millis(2).as_nanos(), 2_000_000);
        assert_eq!(SimTime::from_secs(1).as_secs_f64(), 1.0);
        assert_eq!(SimTime::from_secs_f64(0.5).as_nanos(), 500_000_000);
        assert_eq!(SimTime::from_secs_f64(-1.0), SimTime::ZERO);
    }

    #[test]
    fn arithmetic_and_ordering() {
        let a = SimTime::from_millis(5);
        let b = SimTime::from_millis(3);
        assert_eq!((a + b).as_millis_f64(), 8.0);
        assert_eq!((a - b).as_millis_f64(), 2.0);
        assert_eq!(b.saturating_sub(a), SimTime::ZERO);
        assert!(b < a);
        assert_eq!(a.max(b), a);
        assert_eq!(a.min(b), b);
    }

    #[test]
    fn display_picks_unit() {
        assert_eq!(SimTime::from_nanos(12).to_string(), "12ns");
        assert_eq!(SimTime::from_micros(12).to_string(), "12.0us");
        assert_eq!(SimTime::from_millis(12).to_string(), "12.00ms");
        assert_eq!(SimTime::from_secs(12).to_string(), "12.000s");
    }
}
