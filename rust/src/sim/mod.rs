//! Discrete-event simulation core: virtual time + an event engine.
//!
//! The cluster's control plane (machine boot, image pulls, gossip, raft,
//! autoscaling) runs entirely on virtual time, so protocol benches are
//! deterministic and independent of host speed. See DESIGN.md §Time model.
//!
//! The engine is a calendar queue ([`calendar`]) over typed events
//! ([`engine::SimEvent`]); the original boxed-closure binary-heap
//! engine survives in [`reference`] as the executable ordering
//! specification the differential tests pin the rewrite to.

pub mod calendar;
pub mod engine;
pub mod partition;
pub mod reference;
pub mod time;

pub use calendar::CalendarQueue;
pub use engine::{Engine, SimEvent, Thunk};
pub use partition::{run_lockstep, Outbox, Partitioned, ShardPlan};
pub use reference::ClosureHeapEngine;
pub use time::SimTime;
