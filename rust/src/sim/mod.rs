//! Discrete-event simulation core: virtual time + an event engine.
//!
//! The cluster's control plane (machine boot, image pulls, gossip, raft,
//! autoscaling) runs entirely on virtual time, so protocol benches are
//! deterministic and independent of host speed. See DESIGN.md §Time model.

pub mod engine;
pub mod partition;
pub mod time;

pub use engine::Engine;
pub use partition::{run_lockstep, Outbox, Partitioned, ShardPlan};
pub use time::SimTime;
