//! Observability: the structured trace bus, phase profiling, and the
//! `vhpc acct` accounting surface.
//!
//! The engine emits typed [`events::TraceEvent`]s into a
//! [`writer::TraceBus`] owned by the cluster state; the bus buffers
//! them and drains to a [`writer::TraceSink`] at engine-event
//! boundaries (the same cadence as WAL batching). Sink failures
//! degrade to counted drops — observability may go dark, scheduling
//! never notices, and traced runs fingerprint identically to untraced
//! ones. [`profiling`] adds opt-in wall-clock phase timers for the
//! perf harness, and [`acct`] folds a trace or a replayed WAL into
//! per-job/per-tenant accounting.

pub mod acct;
pub mod events;
pub mod profiling;
pub mod writer;

pub use events::TraceEvent;
pub use writer::{FailAfterSink, FileSink, MemSink, TraceBus, TraceSink};
