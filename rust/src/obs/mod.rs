//! Observability: the structured trace bus, phase profiling, the
//! metrics recorder, and the `vhpc acct`/`vhpc trace` query surfaces.
//!
//! The engine emits typed [`events::TraceEvent`]s into a
//! [`writer::TraceBus`] owned by the cluster state; the bus buffers
//! them and drains to a [`writer::TraceSink`] at engine-event
//! boundaries (the same cadence as WAL batching). On the sharded
//! engine each rank owns a buffering bus and the conductor merges the
//! per-window batches in canonical order before writing, so a sharded
//! trace is byte-identical at any shard count. Sink failures degrade
//! to counted drops — observability may go dark, scheduling never
//! notices, and traced runs fingerprint identically to untraced ones.
//! [`profiling`] adds opt-in wall-clock phase timers for the perf
//! harness, [`record`] samples gauge time-series into the trace,
//! [`acct`] folds a trace or a replayed WAL into per-job/per-tenant
//! accounting, and [`analyze`] turns a trace into job timelines, a
//! scale-decision audit and exportable time-series.

pub mod acct;
pub mod analyze;
pub mod events;
pub mod profiling;
pub mod record;
pub mod writer;

pub use events::TraceEvent;
pub use record::{GaugeSnapshot, MetricsRecorder};
pub use writer::{FailAfterSink, FileSink, MemSink, TraceBus, TraceSink};
