//! The metrics recorder: periodic gauge snapshots into the trace.
//!
//! Counters ([`Metrics`](crate::cluster::metrics::Metrics)) tell you
//! what happened over a whole run; they cannot show how queue depth,
//! capacity or per-tenant share *evolved*. The recorder closes that gap
//! by sampling a [`GaugeSnapshot`] at a configurable sim-time cadence
//! (`[cluster] sample_every`, default 30 s) and emitting it as a
//! [`TraceEvent::Sample`] through the same bus, sink and flush contract
//! as every lifecycle event — so `vhpc trace --series` can export the
//! time-series from any trace file.
//!
//! Determinism posture: sampling is driven by virtual time only (the
//! scheduler tick on the live cluster, the window grid on the sharded
//! conductor), reads state, and writes nothing back — a sampled run's
//! counter fingerprint is byte-identical to an unsampled one, and the
//! sample stream itself is byte-identical at any shard count.

use super::events::TraceEvent;
use super::writer::TraceBus;
use crate::sim::SimTime;

/// How many tenants the `top_usage` field carries, ranked by decayed
/// usage descending (ties broken by tenant id ascending).
pub const TOP_USAGE_K: usize = 4;

/// One instant's demand/capacity gauges, assembled by whoever owns the
/// scheduler state (the live [`VirtualCluster`](crate::cluster::VirtualCluster)
/// or the sharded conductor) — the recorder itself never reaches into
/// cluster internals.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GaugeSnapshot {
    pub queued_jobs: u64,
    pub queued_slots: u64,
    pub running_jobs: u64,
    pub reserved_slots: u64,
    pub total_slots: u64,
    pub nodes_ready: u64,
    pub nodes_unhealthy: u64,
    pub nodes_provisioning: u64,
    /// Node count the autoscaler is converging to (ready +
    /// provisioning at sample time).
    pub scale_target: u64,
    /// `(tenant, decayed slot-seconds)`, descending by usage. The
    /// recorder truncates to [`TOP_USAGE_K`] and renders milli-slot-
    /// second integers so the trace codec stays exact.
    pub usage: Vec<(u64, f64)>,
}

/// Emits a [`TraceEvent::Sample`] whenever virtual time crosses the
/// next cadence boundary. `every == 0` disables sampling entirely.
#[derive(Debug, Clone)]
pub struct MetricsRecorder {
    every: SimTime,
    next_at: SimTime,
}

impl MetricsRecorder {
    pub fn new(every: SimTime) -> Self {
        Self { every, next_at: SimTime::ZERO }
    }

    /// A recorder that never samples.
    pub fn disabled() -> Self {
        Self::new(SimTime::ZERO)
    }

    /// True when a sample is owed at `now`. Callers check this (plus
    /// `bus.enabled()`) before paying to assemble a [`GaugeSnapshot`].
    pub fn due(&self, now: SimTime) -> bool {
        self.every > SimTime::ZERO && now >= self.next_at
    }

    /// Emit one sample and advance the cadence clock past `now`. The
    /// next sample is owed at the first cadence boundary after `now`,
    /// so a stalled caller (e.g. a long engine gap) yields one catch-up
    /// sample, not a burst.
    pub fn record(&mut self, now: SimTime, epoch: u64, g: &GaugeSnapshot, bus: &mut TraceBus) {
        if !self.due(now) {
            return;
        }
        while self.next_at <= now {
            self.next_at = self.next_at + self.every;
        }
        bus.emit(TraceEvent::Sample {
            at: now,
            epoch,
            queued_jobs: g.queued_jobs,
            queued_slots: g.queued_slots,
            running_jobs: g.running_jobs,
            reserved_slots: g.reserved_slots,
            total_slots: g.total_slots,
            nodes_ready: g.nodes_ready,
            nodes_unhealthy: g.nodes_unhealthy,
            nodes_provisioning: g.nodes_provisioning,
            scale_target: g.scale_target,
            top_usage: render_top_usage(&g.usage),
        });
    }
}

/// Rank the usage list (descending usage, tenant id tiebreak), keep the
/// top [`TOP_USAGE_K`], render `tenant:milli_slot_seconds` pairs. The
/// f64 usage is deterministic (the ledger sums in tenant order), so the
/// rounded integer — and therefore the trace byte stream — is too.
fn render_top_usage(usage: &[(u64, f64)]) -> String {
    let mut ranked: Vec<(u64, f64)> = usage.to_vec();
    ranked.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    ranked
        .iter()
        .take(TOP_USAGE_K)
        .map(|&(tenant, used)| format!("{tenant}:{}", (used * 1000.0).round() as u64))
        .collect::<Vec<_>>()
        .join(",")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::MemSink;

    fn snap(queued_jobs: u64) -> GaugeSnapshot {
        GaugeSnapshot { queued_jobs, ..GaugeSnapshot::default() }
    }

    #[test]
    fn disabled_recorder_never_samples() {
        let mut rec = MetricsRecorder::disabled();
        let mut bus = TraceBus::buffering();
        assert!(!rec.due(SimTime::from_secs(1_000_000)));
        rec.record(SimTime::from_secs(5), 0, &snap(1), &mut bus);
        assert!(bus.take_buffered().is_empty());
    }

    #[test]
    fn samples_land_on_the_cadence_grid_without_bursts() {
        let mut rec = MetricsRecorder::new(SimTime::from_secs(10));
        let mut bus = TraceBus::buffering();
        // tick cadence 1 s: samples at 0, 10, 20...
        for s in 0..25u64 {
            let now = SimTime::from_secs(s);
            if rec.due(now) {
                rec.record(now, 0, &snap(s), &mut bus);
            }
        }
        let evs = bus.take_buffered();
        let stamps: Vec<u64> = evs.iter().map(|e| e.at().as_nanos() / 1_000_000_000).collect();
        assert_eq!(stamps, vec![0, 10, 20]);
        // a long stall yields one catch-up sample, not a burst
        rec.record(SimTime::from_secs(95), 0, &snap(9), &mut bus);
        rec.record(SimTime::from_secs(96), 0, &snap(9), &mut bus);
        assert_eq!(bus.take_buffered().len(), 1, "no burst after a stall");
        assert!(rec.due(SimTime::from_secs(100)));
    }

    #[test]
    fn top_usage_ranks_truncates_and_roundtrips() {
        let usage = vec![(3, 1.5), (0, 42.25), (9, 42.25), (2, 7.0), (5, 0.0)];
        let s = render_top_usage(&usage);
        // descending usage, tenant-id tiebreak, K=4 cap
        assert_eq!(s, "0:42250,9:42250,2:7000,3:1500");
        let mut rec = MetricsRecorder::new(SimTime::from_secs(1));
        let sink = MemSink::new();
        let lines = sink.shared();
        let mut bus = TraceBus::with_sink(Box::new(sink));
        let g = GaugeSnapshot { usage, queued_jobs: 2, total_slots: 96, ..Default::default() };
        rec.record(SimTime::from_secs(3), 1, &g, &mut bus);
        bus.finish();
        let got = lines.lock().unwrap().clone();
        assert_eq!(got.len(), 1);
        let back = TraceEvent::parse_json_line(&got[0]).expect("sample parses");
        match back {
            TraceEvent::Sample { queued_jobs, total_slots, top_usage, epoch, .. } => {
                assert_eq!(queued_jobs, 2);
                assert_eq!(total_slots, 96);
                assert_eq!(epoch, 1);
                assert_eq!(top_usage, "0:42250,9:42250,2:7000,3:1500");
            }
            other => panic!("expected a sample, got {other:?}"),
        }
    }
}
