//! `sacct`-style accounting: fold a trace (or a replayed WAL) into
//! per-job and per-tenant history.
//!
//! The fold consumes [`TraceEvent`]s. Two front-ends feed it:
//!
//! * [`from_trace_lines`] — a JSON-lines trace written by a `--trace`
//!   run. Unparseable lines are **counted and skipped**
//!   (`skipped_lines`), so a truncated or corrupt file degrades to a
//!   partial report instead of erroring — the same posture as WAL
//!   replay's `decode_wal_listing`.
//! * [`from_wal`] — a decoded HA WAL, converted event-for-event via
//!   [`wal_to_trace`]. The WAL does not journal autoscale sizing,
//!   quota-admit counts, or backfill flags (it never needed them to
//!   rebuild scheduler state), so those fields degrade to defaults;
//!   everything billing-relevant — submits, dispatch attempts,
//!   completions, losses, preemptions — converts exactly.
//!
//! Charging rule: an attempt is charged `ranks x (end - start)` slot
//! time from its dispatch to its completion, loss, preemption, or
//! failure — interrupted attempts bill like the live tenant ledger
//! does. An attempt still running when the trace ends is *not*
//! charged (its end is unknown), and the job reports state `running`.

use super::events::{esc, TraceEvent};
use crate::ha::wal::WalEvent;
use crate::sim::SimTime;
use std::collections::BTreeMap;

/// Accounting history for one job across all its attempts.
#[derive(Debug, Clone, PartialEq)]
pub struct JobAcct {
    pub job: u32,
    pub tenant: u64,
    pub ranks: u32,
    /// Virtual time the submission reached the head (None when the
    /// trace starts mid-life, e.g. a WAL truncated by a snapshot).
    pub submitted: Option<SimTime>,
    /// First dispatch across all attempts.
    pub first_start: Option<SimTime>,
    /// Terminal timestamp (complete/fail/abandon/reject).
    pub finished: Option<SimTime>,
    /// Dispatch count — exact, every requeue and preemption rerun
    /// included.
    pub attempts: u32,
    pub preemptions: u32,
    /// Fault-driven requeues (node loss, unlaunched dispatch).
    pub requeues: u32,
    /// Virtual seconds spent queued before the first dispatch.
    pub wait_secs: f64,
    /// Charged runtime summed over ended attempts, virtual seconds.
    pub run_secs: f64,
    /// `ranks x run_secs` — the billing quantity.
    pub slot_seconds: f64,
    /// `completed | failed | abandoned | rejected | running | queued`.
    pub state: &'static str,
    /// Last event observed for the job (drives `--since`).
    pub last_event: SimTime,
}

/// Per-tenant rollup over the (filtered) job set.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantAcct {
    pub tenant: u64,
    pub jobs: u64,
    pub completed: u64,
    pub failed: u64,
    pub abandoned: u64,
    pub attempts: u64,
    pub preemptions: u64,
    pub slot_seconds: f64,
}

/// The folded report: jobs in id order plus the tenant rollup.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AcctReport {
    pub jobs: Vec<JobAcct>,
    pub tenants: Vec<TenantAcct>,
    /// Trace events consumed by the fold.
    pub events: u64,
    /// Input lines that failed to parse and were skipped (partial
    /// report when > 0).
    pub skipped_lines: u64,
}

/// Query filters for the `vhpc acct` surface. `Default` selects
/// everything.
#[derive(Debug, Clone, Default)]
pub struct AcctFilter {
    pub tenant: Option<u64>,
    pub state: Option<String>,
    /// Keep jobs still active at or after this virtual time (their
    /// last observed event is >= `since`).
    pub since: Option<SimTime>,
}

#[derive(Debug, Clone, Default)]
struct JobBuild {
    tenant: u64,
    ranks: u32,
    submitted: Option<SimTime>,
    first_start: Option<SimTime>,
    finished: Option<SimTime>,
    attempts: u32,
    preemptions: u32,
    requeues: u32,
    run_ns: u64,
    cur_start: Option<SimTime>,
    state: &'static str,
    last_event: SimTime,
}

impl JobBuild {
    fn touch(&mut self, at: SimTime) {
        self.last_event = self.last_event.max(at);
    }
    /// Charge the in-flight attempt up to `at` and clear it.
    fn end_attempt(&mut self, at: SimTime) {
        if let Some(start) = self.cur_start.take() {
            self.run_ns += at.saturating_sub(start).as_nanos();
        }
    }
}

/// Fold a stream of trace events into an accounting report.
pub fn fold_events<I: IntoIterator<Item = TraceEvent>>(events: I) -> AcctReport {
    let mut jobs: BTreeMap<u32, JobBuild> = BTreeMap::new();
    let mut n = 0u64;
    for ev in events {
        n += 1;
        let at = ev.at();
        match ev {
            TraceEvent::Submit { job, tenant, ranks, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.ranks = ranks;
                b.submitted = Some(at);
                b.state = "queued";
                b.touch(at);
            }
            TraceEvent::SubmitRejected { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.finished = Some(at);
                b.state = "rejected";
                b.touch(at);
            }
            TraceEvent::QuotaDefer { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.state = "queued";
                b.touch(at);
            }
            TraceEvent::Dispatch { job, tenant, ranks, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                if b.ranks == 0 {
                    b.ranks = ranks;
                }
                b.attempts += 1;
                b.first_start.get_or_insert(at);
                b.cur_start = Some(at);
                b.state = "running";
                b.touch(at);
            }
            TraceEvent::Complete { job, tenant, started, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                // a WAL truncated below the dispatch still bills the
                // final attempt: the event carries its start
                if b.cur_start.is_none() {
                    b.cur_start = Some(started);
                    b.first_start.get_or_insert(started);
                }
                b.end_attempt(at);
                b.finished = Some(at);
                b.state = "completed";
                b.touch(at);
            }
            TraceEvent::Fail { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.end_attempt(at);
                b.finished = Some(at);
                b.state = "failed";
                b.touch(at);
            }
            TraceEvent::Requeue { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.end_attempt(at);
                b.requeues += 1;
                b.state = "queued";
                b.touch(at);
            }
            TraceEvent::Abandon { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.end_attempt(at);
                b.finished = Some(at);
                b.state = "abandoned";
                b.touch(at);
            }
            TraceEvent::Preempt { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.end_attempt(at);
                b.preemptions += 1;
                b.state = "queued";
                b.touch(at);
            }
            // cluster-level events carry no per-job charge
            TraceEvent::Launch { .. }
            | TraceEvent::QuotaAdmit { .. }
            | TraceEvent::ScaleUp { .. }
            | TraceEvent::ScaleDown { .. }
            | TraceEvent::ScaleHold { .. }
            | TraceEvent::FaultInjected { .. }
            | TraceEvent::LeaseLost { .. }
            | TraceEvent::Takeover { .. }
            | TraceEvent::SnapshotWritten { .. }
            | TraceEvent::WalFlush { .. }
            | TraceEvent::Sample { .. } => {}
        }
    }

    let jobs: Vec<JobAcct> = jobs
        .into_iter()
        .map(|(id, b)| {
            let wait_secs = match (b.submitted, b.first_start) {
                (Some(sub), Some(start)) => start.saturating_sub(sub).as_secs_f64(),
                _ => 0.0,
            };
            let run_secs = b.run_ns as f64 / 1e9;
            JobAcct {
                job: id,
                tenant: b.tenant,
                ranks: b.ranks,
                submitted: b.submitted,
                first_start: b.first_start,
                finished: b.finished,
                attempts: b.attempts,
                preemptions: b.preemptions,
                requeues: b.requeues,
                wait_secs,
                run_secs,
                slot_seconds: b.ranks as f64 * run_secs,
                state: if b.state.is_empty() { "queued" } else { b.state },
                last_event: b.last_event,
            }
        })
        .collect();

    AcctReport { tenants: rollup(&jobs), jobs, events: n, skipped_lines: 0 }
}

fn rollup(jobs: &[JobAcct]) -> Vec<TenantAcct> {
    let mut map: BTreeMap<u64, TenantAcct> = BTreeMap::new();
    for j in jobs {
        let t = map.entry(j.tenant).or_insert_with(|| TenantAcct {
            tenant: j.tenant,
            jobs: 0,
            completed: 0,
            failed: 0,
            abandoned: 0,
            attempts: 0,
            preemptions: 0,
            slot_seconds: 0.0,
        });
        t.jobs += 1;
        match j.state {
            "completed" => t.completed += 1,
            "failed" | "rejected" => t.failed += 1,
            "abandoned" => t.abandoned += 1,
            _ => {}
        }
        t.attempts += j.attempts as u64;
        t.preemptions += j.preemptions as u64;
        t.slot_seconds += j.slot_seconds;
    }
    map.into_values().collect()
}

impl AcctReport {
    /// Apply query filters, recomputing the tenant rollup over the
    /// surviving jobs.
    pub fn filtered(&self, f: &AcctFilter) -> AcctReport {
        let jobs: Vec<JobAcct> = self
            .jobs
            .iter()
            .filter(|j| f.tenant.map_or(true, |t| j.tenant == t))
            .filter(|j| f.state.as_deref().map_or(true, |s| j.state == s))
            .filter(|j| f.since.map_or(true, |s| j.last_event >= s))
            .cloned()
            .collect();
        AcctReport {
            tenants: rollup(&jobs),
            jobs,
            events: self.events,
            skipped_lines: self.skipped_lines,
        }
    }
}

/// Parse a JSON-lines trace, skipping (and counting) lines that do not
/// parse — a truncated or corrupt trace yields a partial report.
pub fn from_trace_lines<'a, I: IntoIterator<Item = &'a str>>(lines: I) -> AcctReport {
    let mut events = Vec::new();
    let mut skipped = 0u64;
    for line in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match TraceEvent::parse_json_line(line) {
            Ok(ev) => events.push(ev),
            Err(_) => skipped += 1,
        }
    }
    let mut report = fold_events(events);
    report.skipped_lines = skipped;
    report
}

/// Convert decoded WAL events into the trace taxonomy (see module docs
/// for what the WAL does and does not journal). `Lost`/`Unlaunched`
/// convert to [`TraceEvent::Requeue`]: whether the live run's retry
/// budget then abandoned the job is visible as a job that never
/// re-dispatched.
pub fn wal_to_trace(events: &[WalEvent]) -> Vec<TraceEvent> {
    let mut meta: BTreeMap<u32, (u64, u32)> = BTreeMap::new(); // job -> (tenant, ranks)
    let mut cur: BTreeMap<u32, (SimTime, u32)> = BTreeMap::new(); // job -> (dispatch at, attempt)
    let mut out = Vec::with_capacity(events.len());
    for ev in events {
        match ev {
            WalEvent::Submitted { at, spec } => {
                meta.insert(spec.id.raw(), (spec.tenant, spec.ranks));
                out.push(TraceEvent::Submit {
                    at: *at,
                    epoch: 0,
                    job: spec.id,
                    tenant: spec.tenant,
                    ranks: spec.ranks,
                    priority: spec.priority,
                });
            }
            WalEvent::SubmitFailed { at, spec, reason } => {
                out.push(TraceEvent::SubmitRejected {
                    at: *at,
                    epoch: 0,
                    job: spec.id,
                    tenant: spec.tenant,
                    reason: reason.clone(),
                });
            }
            WalEvent::Dispatched { at, id, attempt, slice } => {
                let (tenant, ranks) = meta
                    .get(&id.raw())
                    .copied()
                    .unwrap_or((0, slice.len() as u32));
                cur.insert(id.raw(), (*at, *attempt));
                out.push(TraceEvent::Dispatch {
                    at: *at,
                    epoch: 0,
                    job: *id,
                    attempt: *attempt,
                    tenant,
                    ranks,
                    backfilled: false,
                });
            }
            WalEvent::Launched { at, id, attempt, planned, .. } => {
                out.push(TraceEvent::Launch {
                    at: *at,
                    epoch: 0,
                    job: *id,
                    attempt: *attempt,
                    planned: *planned,
                });
            }
            WalEvent::Preempted { at, id } => {
                let (tenant, _) = meta.get(&id.raw()).copied().unwrap_or((0, 0));
                cur.remove(&id.raw());
                out.push(TraceEvent::Preempt { at: *at, epoch: 0, job: *id, tenant });
            }
            WalEvent::Lost { at, id, .. } | WalEvent::Unlaunched { at, id } => {
                let (tenant, _) = meta.get(&id.raw()).copied().unwrap_or((0, 0));
                let (started, attempt) = cur.remove(&id.raw()).unwrap_or((*at, 0));
                out.push(TraceEvent::Requeue {
                    at: *at,
                    epoch: 0,
                    job: *id,
                    attempt,
                    tenant,
                    wasted: at.saturating_sub(started),
                });
            }
            WalEvent::Completed { at, id, attempt } => {
                let (tenant, _) = meta.get(&id.raw()).copied().unwrap_or((0, 0));
                let (started, _) = cur.remove(&id.raw()).unwrap_or((*at, *attempt));
                out.push(TraceEvent::Complete {
                    at: *at,
                    epoch: 0,
                    job: *id,
                    attempt: *attempt,
                    tenant,
                    started,
                });
            }
            WalEvent::Failed { at, id, reason } => {
                let (tenant, _) = meta.get(&id.raw()).copied().unwrap_or((0, 0));
                cur.remove(&id.raw());
                out.push(TraceEvent::Fail {
                    at: *at,
                    epoch: 0,
                    job: *id,
                    tenant,
                    reason: reason.clone(),
                });
            }
            // scheduler-internal bookkeeping with no accounting weight:
            // the WAL journals these to rebuild head state, not to bill
            WalEvent::Admitted { .. }
            | WalEvent::Accrued { .. }
            | WalEvent::ScaleUp { .. }
            | WalEvent::ScaleDown { .. }
            | WalEvent::ArrivalCursor { .. } => {}
        }
    }
    out
}

/// Fold a decoded WAL directly.
pub fn from_wal(events: &[WalEvent]) -> AcctReport {
    fold_events(wal_to_trace(events))
}

// ---------- rendering ----------

fn opt_secs(t: Option<SimTime>) -> String {
    match t {
        Some(t) => format!("{:.3}", t.as_secs_f64()),
        None => "null".into(),
    }
}

/// Render the report as one JSON object (jobs array, tenants array,
/// summary) for machine consumers.
pub fn render_json(r: &AcctReport) -> String {
    let mut s = String::from("{\n  \"jobs\": [\n");
    for (i, j) in r.jobs.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"job\":{},\"tenant\":{},\"ranks\":{},\"state\":\"{}\",\"submitted_s\":{},\"first_start_s\":{},\"finished_s\":{},\"wait_s\":{:.3},\"run_s\":{:.3},\"slot_seconds\":{:.3},\"attempts\":{},\"preemptions\":{},\"requeues\":{}}}{}\n",
            j.job,
            j.tenant,
            j.ranks,
            esc(j.state),
            opt_secs(j.submitted),
            opt_secs(j.first_start),
            opt_secs(j.finished),
            j.wait_secs,
            j.run_secs,
            j.slot_seconds,
            j.attempts,
            j.preemptions,
            j.requeues,
            if i + 1 < r.jobs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n  \"tenants\": [\n");
    for (i, t) in r.tenants.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"tenant\":{},\"jobs\":{},\"completed\":{},\"failed\":{},\"abandoned\":{},\"attempts\":{},\"preemptions\":{},\"slot_seconds\":{:.3}}}{}\n",
            t.tenant,
            t.jobs,
            t.completed,
            t.failed,
            t.abandoned,
            t.attempts,
            t.preemptions,
            t.slot_seconds,
            if i + 1 < r.tenants.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!(
        "  ],\n  \"summary\": {{\"jobs\":{},\"events\":{},\"skipped_lines\":{}}}\n}}\n",
        r.jobs.len(),
        r.events,
        r.skipped_lines
    ));
    s
}

/// Render the report as an `sacct`-style fixed-width table.
pub fn render_table(r: &AcctReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:>6} {:>6} {:>5} {:>10} {:>10} {:>10} {:>12} {:>8} {:>6} {:>4}\n",
        "JOB", "TENANT", "RANKS", "STATE", "WAIT_S", "RUN_S", "SLOT_SEC", "ATTEMPTS", "PREEMPT", "REQ"
    ));
    for j in &r.jobs {
        s.push_str(&format!(
            "{:>6} {:>6} {:>5} {:>10} {:>10.3} {:>10.3} {:>12.3} {:>8} {:>6} {:>4}\n",
            j.job,
            j.tenant,
            j.ranks,
            j.state,
            j.wait_secs,
            j.run_secs,
            j.slot_seconds,
            j.attempts,
            j.preemptions,
            j.requeues
        ));
    }
    s.push('\n');
    s.push_str(&format!(
        "{:>6} {:>6} {:>9} {:>6} {:>9} {:>8} {:>7} {:>12}\n",
        "TENANT", "JOBS", "COMPLETED", "FAILED", "ABANDONED", "ATTEMPTS", "PREEMPT", "SLOT_SEC"
    ));
    for t in &r.tenants {
        s.push_str(&format!(
            "{:>6} {:>6} {:>9} {:>6} {:>9} {:>8} {:>7} {:>12.3}\n",
            t.tenant, t.jobs, t.completed, t.failed, t.abandoned, t.attempts, t.preemptions, t.slot_seconds
        ));
    }
    if r.skipped_lines > 0 {
        s.push_str(&format!(
            "\nwarning: {} unparseable line(s) skipped — partial report\n",
            r.skipped_lines
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::JobId;

    fn secs(s: u64) -> SimTime {
        SimTime::from_secs(s)
    }

    /// j1: waits 10s, runs 20s on 4 ranks. j2: dispatched, lost at +5s,
    /// re-dispatched, completes after 10s more. j3: preempted once then
    /// abandoned.
    fn sample_events() -> Vec<TraceEvent> {
        let j1 = JobId::new(1);
        let j2 = JobId::new(2);
        let j3 = JobId::new(3);
        vec![
            TraceEvent::Submit { at: secs(0), epoch: 0, job: j1, tenant: 7, ranks: 4, priority: 0 },
            TraceEvent::Submit { at: secs(1), epoch: 0, job: j2, tenant: 7, ranks: 2, priority: 0 },
            TraceEvent::Submit { at: secs(2), epoch: 0, job: j3, tenant: 9, ranks: 1, priority: 0 },
            TraceEvent::Dispatch { at: secs(10), epoch: 0, job: j1, attempt: 0, tenant: 7, ranks: 4, backfilled: false },
            TraceEvent::Dispatch { at: secs(10), epoch: 0, job: j2, attempt: 0, tenant: 7, ranks: 2, backfilled: true },
            TraceEvent::Dispatch { at: secs(10), epoch: 0, job: j3, attempt: 0, tenant: 9, ranks: 1, backfilled: false },
            TraceEvent::Requeue { at: secs(15), epoch: 0, job: j2, attempt: 1, tenant: 7, wasted: secs(5) },
            TraceEvent::Preempt { at: secs(18), epoch: 0, job: j3, tenant: 9 },
            TraceEvent::Dispatch { at: secs(20), epoch: 0, job: j2, attempt: 1, tenant: 7, ranks: 2, backfilled: false },
            TraceEvent::Complete { at: secs(30), epoch: 0, job: j1, attempt: 0, tenant: 7, started: secs(10) },
            TraceEvent::Complete { at: secs(30), epoch: 0, job: j2, attempt: 1, tenant: 7, started: secs(20) },
            TraceEvent::Abandon { at: secs(31), epoch: 0, job: j3, tenant: 9 },
        ]
    }

    #[test]
    fn fold_charges_attempts_and_tracks_states() {
        let r = fold_events(sample_events());
        assert_eq!(r.jobs.len(), 3);
        let j1 = &r.jobs[0];
        assert_eq!((j1.state, j1.attempts, j1.preemptions), ("completed", 1, 0));
        assert_eq!(j1.wait_secs, 10.0);
        assert_eq!(j1.run_secs, 20.0);
        assert_eq!(j1.slot_seconds, 80.0);
        let j2 = &r.jobs[1];
        assert_eq!((j2.state, j2.attempts, j2.requeues), ("completed", 2, 1));
        // interrupted attempt (5s) bills alongside the final one (10s)
        assert_eq!(j2.run_secs, 15.0);
        assert_eq!(j2.slot_seconds, 30.0);
        let j3 = &r.jobs[2];
        assert_eq!((j3.state, j3.attempts, j3.preemptions), ("abandoned", 1, 1));
        assert_eq!(j3.run_secs, 8.0);

        assert_eq!(r.tenants.len(), 2);
        assert_eq!(r.tenants[0].tenant, 7);
        assert_eq!(r.tenants[0].completed, 2);
        assert_eq!(r.tenants[0].slot_seconds, 110.0);
        assert_eq!(r.tenants[1].abandoned, 1);
    }

    #[test]
    fn running_tail_is_not_charged() {
        let j = JobId::new(4);
        let r = fold_events(vec![
            TraceEvent::Submit { at: secs(0), epoch: 0, job: j, tenant: 1, ranks: 2, priority: 0 },
            TraceEvent::Dispatch { at: secs(5), epoch: 0, job: j, attempt: 0, tenant: 1, ranks: 2, backfilled: false },
        ]);
        assert_eq!(r.jobs[0].state, "running");
        assert_eq!(r.jobs[0].slot_seconds, 0.0);
        assert!(r.jobs[0].finished.is_none());
    }

    #[test]
    fn filters_select_by_tenant_state_and_since() {
        let r = fold_events(sample_events());
        let t7 = r.filtered(&AcctFilter { tenant: Some(7), ..Default::default() });
        assert_eq!(t7.jobs.len(), 2);
        assert_eq!(t7.tenants.len(), 1);
        let done = r.filtered(&AcctFilter { state: Some("abandoned".into()), ..Default::default() });
        assert_eq!(done.jobs.len(), 1);
        assert_eq!(done.jobs[0].job, 3);
        // j1 and j2 finish at 30s, j3 at 31s
        let late = r.filtered(&AcctFilter { since: Some(secs(31)), ..Default::default() });
        assert_eq!(late.jobs.len(), 1);
        assert_eq!(late.jobs[0].job, 3);
    }

    #[test]
    fn corrupt_lines_skip_to_a_partial_report() {
        let good: Vec<String> = sample_events().iter().map(|e| e.to_json_line()).collect();
        let mut lines: Vec<&str> = good.iter().map(|s| s.as_str()).collect();
        lines.insert(3, "{\"ev\":\"submit\",\"t_ns\":garbage");
        lines.push("half a li");
        let r = from_trace_lines(lines);
        assert_eq!(r.skipped_lines, 2);
        assert_eq!(r.jobs.len(), 3, "good lines still fold");
        assert_eq!(r.jobs[0].state, "completed");
    }

    #[test]
    fn wal_conversion_matches_the_native_fold_on_the_billing_columns() {
        use crate::cluster::head::{JobKind, JobSpec};
        let spec = |id: u32, tenant: u64, ranks: u32| JobSpec {
            id: JobId::new(id),
            name: format!("j{id}"),
            ranks,
            kind: JobKind::Synthetic { duration: secs(20) },
            priority: 0,
            tenant,
        };
        let wal = vec![
            WalEvent::Submitted { at: secs(0), spec: spec(1, 7, 4) },
            WalEvent::Dispatched { at: secs(10), id: JobId::new(1), attempt: 0, slice: Vec::new() },
            WalEvent::Lost { at: secs(15), id: JobId::new(1), reason: "node died".into() },
            WalEvent::Dispatched { at: secs(20), id: JobId::new(1), attempt: 1, slice: Vec::new() },
            WalEvent::Completed { at: secs(40), id: JobId::new(1), attempt: 1 },
        ];
        let r = from_wal(&wal);
        assert_eq!(r.jobs.len(), 1);
        let j = &r.jobs[0];
        assert_eq!(j.tenant, 7);
        assert_eq!(j.ranks, 4, "ranks come from the Submitted spec");
        assert_eq!(j.attempts, 2);
        assert_eq!(j.requeues, 1);
        assert_eq!(j.state, "completed");
        // 5s wasted attempt + 20s final attempt, x4 ranks
        assert_eq!(j.run_secs, 25.0);
        assert_eq!(j.slot_seconds, 100.0);
    }

    #[test]
    fn renderers_cover_the_report() {
        let r = from_trace_lines(
            sample_events()
                .iter()
                .map(|e| e.to_json_line())
                .collect::<Vec<_>>()
                .iter()
                .map(|s| s.as_str()),
        );
        let json = render_json(&r);
        assert!(json.contains("\"jobs\": ["));
        assert!(json.contains("\"slot_seconds\":80.000"));
        assert!(json.contains("\"summary\": {\"jobs\":3,\"events\":12,\"skipped_lines\":0}"));
        let table = render_table(&r);
        assert!(table.contains("JOB"));
        assert!(table.contains("completed"));
        assert!(!table.contains("partial report"));
        let mut partial = r.clone();
        partial.skipped_lines = 1;
        assert!(render_table(&partial).contains("partial report"));
    }
}
