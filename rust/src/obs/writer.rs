//! Buffered JSON-lines trace sinks and the bus the cluster emits into.
//!
//! The contract mirrors the WAL's batching posture (PR 8): event
//! handlers push typed events into an in-memory buffer as they run, and
//! the buffer drains to the sink at engine-event boundaries — the same
//! places `ha::wal::flush` runs — so serialization and I/O stay off the
//! per-mutation hot path.
//!
//! **Degradation rule:** a sink error never propagates into scheduling.
//! Events that could not be written are counted and dropped
//! (`obs_events_dropped`), the run continues, and — because the drop
//! counters live on the bus, not in [`Metrics`](crate::cluster::metrics::Metrics)
//! — a traced run's counter fingerprint stays byte-identical to an
//! untraced run no matter how the sink behaves.

use super::events::TraceEvent;
use std::io::Write;

/// A destination for rendered trace lines. Implementations may buffer;
/// `flush` pushes everything durable.
pub trait TraceSink {
    /// Write one JSON line (no trailing newline in `line`).
    fn write_line(&mut self, line: &str) -> Result<(), String>;
    /// Make previously written lines durable.
    fn flush(&mut self) -> Result<(), String>;
}

/// File-backed sink: buffered JSON lines, flushed at end of run.
pub struct FileSink {
    out: std::io::BufWriter<std::fs::File>,
}

impl FileSink {
    /// Create (truncating) the trace file. An unopenable path is a
    /// configuration error and reported to the caller — only *mid-run*
    /// write failures degrade to counted drops.
    pub fn create(path: &str) -> Result<Self, String> {
        let file = std::fs::File::create(path)
            .map_err(|e| format!("cannot create trace file {path}: {e}"))?;
        Ok(Self { out: std::io::BufWriter::new(file) })
    }
}

impl TraceSink for FileSink {
    fn write_line(&mut self, line: &str) -> Result<(), String> {
        writeln!(self.out, "{line}").map_err(|e| format!("trace write: {e}"))
    }
    fn flush(&mut self) -> Result<(), String> {
        self.out.flush().map_err(|e| format!("trace flush: {e}"))
    }
}

/// The shared line buffer a [`MemSink`] writes into. Kept behind an
/// `Arc` so a test (or `vhpc acct`) can hold a handle to the lines
/// while the boxed sink lives inside the bus.
pub type SharedLines = std::sync::Arc<std::sync::Mutex<Vec<String>>>;

/// In-memory sink (tests, programmatic consumers).
#[derive(Debug, Default)]
pub struct MemSink {
    lines: SharedLines,
}

impl MemSink {
    pub fn new() -> Self {
        Self::default()
    }
    /// A handle to the line buffer that outlives the boxed sink.
    pub fn shared(&self) -> SharedLines {
        self.lines.clone()
    }
}

impl TraceSink for MemSink {
    fn write_line(&mut self, line: &str) -> Result<(), String> {
        self.lines
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .push(line.to_string());
        Ok(())
    }
    fn flush(&mut self) -> Result<(), String> {
        Ok(())
    }
}

/// A sink that accepts the first `budget` writes and errors on every
/// write after that — the graceful-degradation test double (a full
/// disk, a dead pipe). Accepted lines stay readable.
#[derive(Debug, Default)]
pub struct FailAfterSink {
    budget: usize,
    accepted: Vec<String>,
}

impl FailAfterSink {
    pub fn new(budget: usize) -> Self {
        Self { budget, accepted: Vec::new() }
    }
    pub fn accepted(&self) -> &[String] {
        &self.accepted
    }
}

impl TraceSink for FailAfterSink {
    fn write_line(&mut self, line: &str) -> Result<(), String> {
        if self.accepted.len() >= self.budget {
            return Err("injected sink failure".into());
        }
        self.accepted.push(line.to_string());
        Ok(())
    }
    fn flush(&mut self) -> Result<(), String> {
        Ok(())
    }
}

/// The cluster's trace bus: buffers typed events between engine-event
/// boundaries and drains them to the configured sink. With no sink
/// installed (the default) `emit` is a single branch — untraced runs
/// pay nothing.
#[derive(Default)]
pub struct TraceBus {
    sink: Option<Box<dyn TraceSink>>,
    buf: Vec<TraceEvent>,
    written: u64,
    dropped: u64,
    /// Rank-local mode for the sharded engine: emits accumulate with no
    /// sink and are periodically taken by [`TraceBus::take_buffered`]
    /// for the conductor's canonical merge.
    buffering: bool,
}

impl TraceBus {
    /// The inert bus: no sink, every `emit` is a no-op.
    pub fn disabled() -> Self {
        Self::default()
    }

    /// A bus draining into `sink`.
    pub fn with_sink(sink: Box<dyn TraceSink>) -> Self {
        Self { sink: Some(sink), ..Self::default() }
    }

    /// A rank-local buffering bus (shard threads): no sink, but `emit`
    /// still buffers. The shard ships each window's batch to the
    /// conductor via [`TraceBus::take_buffered`]; the conductor's
    /// sink-backed bus writes the merged order.
    pub fn buffering() -> Self {
        Self { buffering: true, ..Self::default() }
    }

    /// True when emits are retained (a sink is installed, or the bus is
    /// in rank-local buffering mode). Emission sites that would allocate
    /// to build an event should check this first.
    pub fn enabled(&self) -> bool {
        self.sink.is_some() || self.buffering
    }

    /// Buffer one event (dropped silently when the bus is disabled).
    pub fn emit(&mut self, ev: TraceEvent) {
        if self.enabled() {
            self.buf.push(ev);
        }
    }

    /// Take the buffered events (rank-local buffering mode): the
    /// shard's per-window trace batch, in emission order.
    pub fn take_buffered(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.buf)
    }

    /// Drain the buffer to the sink. Write errors degrade to counted
    /// drops — never an `Err`, never a panic, nothing the caller has to
    /// handle on the scheduling path. A buffering bus keeps its events
    /// (they belong to the conductor's merge, not a sink).
    pub fn flush(&mut self) {
        let Some(sink) = self.sink.as_mut() else {
            if !self.buffering {
                self.buf.clear();
            }
            return;
        };
        for ev in self.buf.drain(..) {
            match sink.write_line(&ev.to_json_line()) {
                Ok(()) => self.written += 1,
                Err(_) => self.dropped += 1,
            }
        }
    }

    /// Flush the buffer and push the sink's own buffers durable. Called
    /// at end of run (and from `Drop`, so a bus going out of scope never
    /// strands buffered events).
    pub fn finish(&mut self) {
        self.flush();
        if let Some(sink) = self.sink.as_mut() {
            let _ = sink.flush();
        }
    }

    /// Events successfully written to the sink.
    pub fn events_written(&self) -> u64 {
        self.written
    }

    /// `obs_events_dropped`: events lost to sink errors. Reported next
    /// to the run outcome, never folded into the determinism
    /// fingerprint.
    pub fn events_dropped(&self) -> u64 {
        self.dropped
    }

    /// Take the sink back out (tests inspect MemSink contents).
    pub fn take_sink(&mut self) -> Option<Box<dyn TraceSink>> {
        self.finish();
        self.sink.take()
    }
}

impl Drop for TraceBus {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;
    use crate::util::ids::JobId;

    fn ev(n: u32) -> TraceEvent {
        TraceEvent::Submit {
            at: SimTime::from_secs(n as u64),
            epoch: 0,
            job: JobId::new(n),
            tenant: 0,
            ranks: 1,
            priority: 0,
        }
    }

    #[test]
    fn disabled_bus_buffers_and_writes_nothing() {
        let mut bus = TraceBus::disabled();
        assert!(!bus.enabled());
        bus.emit(ev(0));
        bus.flush();
        assert_eq!(bus.events_written(), 0);
        assert_eq!(bus.events_dropped(), 0);
    }

    #[test]
    fn events_buffer_until_flush_then_reach_the_sink() {
        let sink = MemSink::new();
        let lines = sink.shared();
        let mut bus = TraceBus::with_sink(Box::new(sink));
        bus.emit(ev(0));
        bus.emit(ev(1));
        assert_eq!(bus.events_written(), 0, "nothing written before the boundary");
        assert!(lines.lock().unwrap().is_empty());
        bus.flush();
        assert_eq!(bus.events_written(), 2);
        let got = lines.lock().unwrap().clone();
        assert_eq!(got.len(), 2);
        assert_eq!(TraceEvent::parse_json_line(&got[0]).unwrap(), ev(0));
        assert_eq!(TraceEvent::parse_json_line(&got[1]).unwrap(), ev(1));
    }

    #[test]
    fn sink_errors_degrade_to_counted_drops() {
        let mut bus = TraceBus::with_sink(Box::new(FailAfterSink::new(3)));
        for i in 0..10 {
            bus.emit(ev(i));
        }
        bus.flush();
        assert_eq!(bus.events_written(), 3);
        assert_eq!(bus.events_dropped(), 7);
        // the bus keeps accepting (and counting) after the sink died
        bus.emit(ev(99));
        bus.flush();
        assert_eq!(bus.events_dropped(), 8);
    }

    #[test]
    fn drop_flushes_the_tail() {
        let sink = MemSink::new();
        let lines = sink.shared();
        {
            let mut bus = TraceBus::with_sink(Box::new(sink));
            bus.emit(ev(7));
            // no explicit flush: the bus goes out of scope with a
            // buffered event, which Drop must not strand
        }
        assert_eq!(lines.lock().unwrap().len(), 1);
    }
}
