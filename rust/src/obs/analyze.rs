//! `vhpc trace` — timeline analysis over a structured trace.
//!
//! Where [`acct`](super::acct) answers "what does each tenant owe",
//! this module answers "what happened, when, and why":
//!
//! * **Per-job timelines** — every attempt's
//!   dispatch→launch→end span plus the job-level
//!   submit→first-dispatch→terminal instants, with the job's life
//!   split into wait (submit to first dispatch), run (dispatched
//!   attempt time) and requeue (re-queued between attempts) seconds,
//!   and the *critical attempt* — the one that reached the terminal
//!   state.
//! * **Scale-decision audit** — every autoscaler up/down/hold with its
//!   [`ScaleReason`] code and the demand signal sampled around it (the
//!   nearest [`TraceEvent::Sample`] at or before, and at or after, the
//!   decision), so a scaling decision can be checked against the
//!   demand that provoked it without replaying the run.
//! * **Time-series export** — the sampled gauge stream as CSV or JSON
//!   for plotting.
//!
//! Same torn-input posture as `vhpc acct`: unparseable lines are
//! counted and skipped, so a truncated or corrupt trace (e.g. from a
//! crashed run) degrades to a partial report, never an error.

use super::events::{esc, TraceEvent};
use crate::cluster::autoscaler::ScaleReason;
use crate::sim::SimTime;
use std::collections::BTreeMap;

/// One dispatch attempt's span within a job timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct AttemptSpan {
    pub attempt: u32,
    pub dispatched: SimTime,
    /// When the dispatcher pinned the planned duration (None when the
    /// trace truncates between dispatch and launch).
    pub launched: Option<SimTime>,
    pub planned: Option<SimTime>,
    /// When the attempt stopped running (completion, requeue,
    /// preemption or failure); None while still running at trace end.
    pub ended: Option<SimTime>,
    /// `completed | requeued | preempted | failed | running`.
    pub outcome: &'static str,
}

/// The reconstructed lifecycle of one job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobTimeline {
    pub job: u32,
    pub tenant: u64,
    pub ranks: u32,
    pub submitted: Option<SimTime>,
    pub first_dispatch: Option<SimTime>,
    /// Terminal timestamp (complete/fail/abandon/reject).
    pub finished: Option<SimTime>,
    /// `completed | failed | abandoned | rejected | running | queued`.
    pub state: &'static str,
    /// Submit → first dispatch, virtual seconds.
    pub wait_secs: f64,
    /// Dispatched attempt time summed over ended attempts.
    pub run_secs: f64,
    /// Re-queued time between attempts (after a requeue or preemption,
    /// before the next dispatch).
    pub requeue_secs: f64,
    pub attempts: Vec<AttemptSpan>,
    /// The attempt that reached the terminal state (None if the job
    /// never got there within the trace).
    pub critical_attempt: Option<u32>,
}

/// The demand/capacity signal at one sampled instant, as it relates to
/// a scale decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DemandPoint {
    pub at: SimTime,
    pub queued_slots: u64,
    pub nodes_ready: u64,
    pub scale_target: u64,
}

/// One autoscaler decision with the demand signal around it.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleDecision {
    pub at: SimTime,
    pub epoch: u64,
    /// `up | down | hold`.
    pub action: &'static str,
    /// Nodes acted on (0 for holds).
    pub nodes: u32,
    pub reason: ScaleReason,
    /// Nearest sample at or before the decision.
    pub before: Option<DemandPoint>,
    /// Nearest sample at or after the decision.
    pub after: Option<DemandPoint>,
}

/// One recorder sample, verbatim from the trace.
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesPoint {
    pub at: SimTime,
    pub epoch: u64,
    pub queued_jobs: u64,
    pub queued_slots: u64,
    pub running_jobs: u64,
    pub reserved_slots: u64,
    pub total_slots: u64,
    pub nodes_ready: u64,
    pub nodes_unhealthy: u64,
    pub nodes_provisioning: u64,
    pub scale_target: u64,
    pub top_usage: String,
}

/// The folded analysis: job timelines in id order, the scale audit and
/// the sample series in trace order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceReport {
    pub jobs: Vec<JobTimeline>,
    pub scale: Vec<ScaleDecision>,
    pub series: Vec<SeriesPoint>,
    /// Trace events consumed by the fold.
    pub events: u64,
    /// Input lines that failed to parse and were skipped (partial
    /// report when > 0).
    pub skipped_lines: u64,
}

#[derive(Debug, Default)]
struct TlBuild {
    tenant: u64,
    ranks: u32,
    submitted: Option<SimTime>,
    first_dispatch: Option<SimTime>,
    finished: Option<SimTime>,
    state: &'static str,
    attempts: Vec<AttemptSpan>,
    /// Set while the job sits in the queue after a requeue/preemption.
    requeued_since: Option<SimTime>,
    run_ns: u64,
    requeue_ns: u64,
    critical: Option<u32>,
}

impl TlBuild {
    /// Close the open attempt at `at` with `outcome`, charging its run
    /// time. Returns the closed attempt's id.
    fn end_attempt(&mut self, at: SimTime, outcome: &'static str) -> Option<u32> {
        let open = self.attempts.iter_mut().rev().find(|a| a.ended.is_none())?;
        open.ended = Some(at);
        open.outcome = outcome;
        self.run_ns += at.saturating_sub(open.dispatched).as_nanos();
        Some(open.attempt)
    }
}

/// Fold a stream of trace events into a timeline report.
pub fn fold_events<I: IntoIterator<Item = TraceEvent>>(events: I) -> TraceReport {
    let mut jobs: BTreeMap<u32, TlBuild> = BTreeMap::new();
    let mut scale: Vec<ScaleDecision> = Vec::new();
    let mut series: Vec<SeriesPoint> = Vec::new();
    let mut n = 0u64;
    for ev in events {
        n += 1;
        let at = ev.at();
        match ev {
            TraceEvent::Submit { job, tenant, ranks, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.ranks = ranks;
                b.submitted = Some(at);
                b.state = "queued";
            }
            TraceEvent::SubmitRejected { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.finished = Some(at);
                b.state = "rejected";
            }
            TraceEvent::QuotaDefer { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.state = "queued";
            }
            TraceEvent::Dispatch { job, attempt, tenant, ranks, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                if b.ranks == 0 {
                    b.ranks = ranks;
                }
                b.first_dispatch.get_or_insert(at);
                if let Some(since) = b.requeued_since.take() {
                    b.requeue_ns += at.saturating_sub(since).as_nanos();
                }
                b.attempts.push(AttemptSpan {
                    attempt,
                    dispatched: at,
                    launched: None,
                    planned: None,
                    ended: None,
                    outcome: "running",
                });
                b.state = "running";
            }
            TraceEvent::Launch { job, attempt, planned, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                if let Some(a) = b
                    .attempts
                    .iter_mut()
                    .rev()
                    .find(|a| a.attempt == attempt && a.ended.is_none())
                {
                    a.launched = Some(at);
                    a.planned = Some(planned);
                }
            }
            TraceEvent::Complete { job, attempt, tenant, started, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                // a trace truncated below the dispatch still shows the
                // final attempt: the event carries its start
                if !b.attempts.iter().any(|a| a.ended.is_none()) {
                    b.first_dispatch.get_or_insert(started);
                    b.attempts.push(AttemptSpan {
                        attempt,
                        dispatched: started,
                        launched: None,
                        planned: None,
                        ended: None,
                        outcome: "running",
                    });
                }
                b.end_attempt(at, "completed");
                b.critical = Some(attempt);
                b.finished = Some(at);
                b.state = "completed";
            }
            TraceEvent::Fail { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.critical = b.end_attempt(at, "failed").or(b.critical);
                b.finished = Some(at);
                b.state = "failed";
            }
            TraceEvent::Requeue { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.end_attempt(at, "requeued");
                b.requeued_since = Some(at);
                b.state = "queued";
            }
            TraceEvent::Abandon { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.finished = Some(at);
                b.state = "abandoned";
            }
            TraceEvent::Preempt { job, tenant, .. } => {
                let b = jobs.entry(job.raw()).or_default();
                b.tenant = tenant;
                b.end_attempt(at, "preempted");
                b.requeued_since = Some(at);
                b.state = "queued";
            }
            TraceEvent::ScaleUp { at, epoch, nodes, reason } => {
                scale.push(ScaleDecision {
                    at, epoch, action: "up", nodes, reason, before: None, after: None,
                });
            }
            TraceEvent::ScaleDown { at, epoch, nodes, reason } => {
                scale.push(ScaleDecision {
                    at, epoch, action: "down", nodes, reason, before: None, after: None,
                });
            }
            TraceEvent::ScaleHold { at, epoch, reason } => {
                scale.push(ScaleDecision {
                    at, epoch, action: "hold", nodes: 0, reason, before: None, after: None,
                });
            }
            TraceEvent::Sample {
                at,
                epoch,
                queued_jobs,
                queued_slots,
                running_jobs,
                reserved_slots,
                total_slots,
                nodes_ready,
                nodes_unhealthy,
                nodes_provisioning,
                scale_target,
                top_usage,
            } => {
                series.push(SeriesPoint {
                    at,
                    epoch,
                    queued_jobs,
                    queued_slots,
                    running_jobs,
                    reserved_slots,
                    total_slots,
                    nodes_ready,
                    nodes_unhealthy,
                    nodes_provisioning,
                    scale_target,
                    top_usage,
                });
            }
            // head-lifecycle and cluster bookkeeping with no timeline
            // weight
            TraceEvent::QuotaAdmit { .. }
            | TraceEvent::FaultInjected { .. }
            | TraceEvent::LeaseLost { .. }
            | TraceEvent::Takeover { .. }
            | TraceEvent::SnapshotWritten { .. }
            | TraceEvent::WalFlush { .. } => {}
        }
    }

    attach_demand(&mut scale, &series);

    let jobs: Vec<JobTimeline> = jobs
        .into_iter()
        .map(|(id, b)| {
            let wait_secs = match (b.submitted, b.first_dispatch) {
                (Some(sub), Some(start)) => start.saturating_sub(sub).as_secs_f64(),
                _ => 0.0,
            };
            JobTimeline {
                job: id,
                tenant: b.tenant,
                ranks: b.ranks,
                submitted: b.submitted,
                first_dispatch: b.first_dispatch,
                finished: b.finished,
                state: if b.state.is_empty() { "queued" } else { b.state },
                wait_secs,
                run_secs: b.run_ns as f64 / 1e9,
                requeue_secs: b.requeue_ns as f64 / 1e9,
                attempts: b.attempts,
                critical_attempt: b.critical,
            }
        })
        .collect();

    TraceReport { jobs, scale, series, events: n, skipped_lines: 0 }
}

/// Attach to every decision the nearest sample at or before it and the
/// nearest at or after it. Both vectors are in trace (time) order.
fn attach_demand(scale: &mut [ScaleDecision], series: &[SeriesPoint]) {
    let point = |s: &SeriesPoint| DemandPoint {
        at: s.at,
        queued_slots: s.queued_slots,
        nodes_ready: s.nodes_ready,
        scale_target: s.scale_target,
    };
    for d in scale.iter_mut() {
        d.before = series.iter().rev().find(|s| s.at <= d.at).map(point);
        d.after = series.iter().find(|s| s.at >= d.at).map(point);
    }
}

/// Parse a JSON-lines trace, skipping (and counting) lines that do not
/// parse — a truncated or corrupt trace yields a partial report.
pub fn from_trace_lines<'a, I: IntoIterator<Item = &'a str>>(lines: I) -> TraceReport {
    let mut events = Vec::new();
    let mut skipped = 0u64;
    for line in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        match TraceEvent::parse_json_line(line) {
            Ok(ev) => events.push(ev),
            Err(_) => skipped += 1,
        }
    }
    let mut report = fold_events(events);
    report.skipped_lines = skipped;
    report
}

impl TraceReport {
    /// Narrow the job-timeline section to one job (`--job J`); the
    /// scale audit and the series are cluster-level and stay.
    pub fn retain_job(&mut self, job: u64) {
        self.jobs.retain(|j| j.job as u64 == job);
    }
}

// ---------- rendering ----------

fn opt_secs(t: Option<SimTime>) -> String {
    match t {
        Some(t) => format!("{:.3}", t.as_secs_f64()),
        None => "null".into(),
    }
}

fn demand_json(p: &Option<DemandPoint>) -> String {
    match p {
        Some(p) => format!(
            "{{\"t_s\":{:.3},\"queued_slots\":{},\"nodes_ready\":{},\"scale_target\":{}}}",
            p.at.as_secs_f64(),
            p.queued_slots,
            p.nodes_ready,
            p.scale_target
        ),
        None => "null".into(),
    }
}

/// Render the full report as one JSON object (jobs, scale audit,
/// series, summary) for machine consumers.
pub fn render_json(r: &TraceReport) -> String {
    let mut s = String::from("{\n  \"jobs\": [\n");
    for (i, j) in r.jobs.iter().enumerate() {
        let attempts: Vec<String> = j
            .attempts
            .iter()
            .map(|a| {
                format!(
                    "{{\"attempt\":{},\"dispatched_s\":{:.3},\"launched_s\":{},\"ended_s\":{},\"outcome\":\"{}\"}}",
                    a.attempt,
                    a.dispatched.as_secs_f64(),
                    opt_secs(a.launched),
                    opt_secs(a.ended),
                    a.outcome
                )
            })
            .collect();
        s.push_str(&format!(
            "    {{\"job\":{},\"tenant\":{},\"ranks\":{},\"state\":\"{}\",\"submitted_s\":{},\"first_dispatch_s\":{},\"finished_s\":{},\"wait_s\":{:.3},\"run_s\":{:.3},\"requeue_s\":{:.3},\"critical_attempt\":{},\"attempts\":[{}]}}{}\n",
            j.job,
            j.tenant,
            j.ranks,
            esc(j.state),
            opt_secs(j.submitted),
            opt_secs(j.first_dispatch),
            opt_secs(j.finished),
            j.wait_secs,
            j.run_secs,
            j.requeue_secs,
            j.critical_attempt.map_or("null".into(), |a| a.to_string()),
            attempts.join(","),
            if i + 1 < r.jobs.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n  \"scale\": [\n");
    for (i, d) in r.scale.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"t_s\":{:.3},\"epoch\":{},\"action\":\"{}\",\"nodes\":{},\"reason\":\"{}\",\"before\":{},\"after\":{}}}{}\n",
            d.at.as_secs_f64(),
            d.epoch,
            d.action,
            d.nodes,
            d.reason.code(),
            demand_json(&d.before),
            demand_json(&d.after),
            if i + 1 < r.scale.len() { "," } else { "" }
        ));
    }
    s.push_str("  ],\n  \"series\": [\n");
    for (i, p) in r.series.iter().enumerate() {
        s.push_str(&format!(
            "    {}{}\n",
            series_point_json(p),
            if i + 1 < r.series.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!(
        "  ],\n  \"summary\": {{\"jobs\":{},\"scale_decisions\":{},\"samples\":{},\"events\":{},\"skipped_lines\":{}}}\n}}\n",
        r.jobs.len(),
        r.scale.len(),
        r.series.len(),
        r.events,
        r.skipped_lines
    ));
    s
}

fn series_point_json(p: &SeriesPoint) -> String {
    format!(
        "{{\"t_ns\":{},\"t_s\":{:.3},\"epoch\":{},\"queued_jobs\":{},\"queued_slots\":{},\"running_jobs\":{},\"reserved_slots\":{},\"total_slots\":{},\"nodes_ready\":{},\"nodes_unhealthy\":{},\"nodes_provisioning\":{},\"scale_target\":{},\"top_usage\":\"{}\"}}",
        p.at.as_nanos(),
        p.at.as_secs_f64(),
        p.epoch,
        p.queued_jobs,
        p.queued_slots,
        p.running_jobs,
        p.reserved_slots,
        p.total_slots,
        p.nodes_ready,
        p.nodes_unhealthy,
        p.nodes_provisioning,
        p.scale_target,
        esc(&p.top_usage)
    )
}

/// Render the per-job timelines and the scale audit as fixed-width
/// tables (the series is summarized; export it with `--series`).
pub fn render_table(r: &TraceReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:>6} {:>6} {:>5} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>9} {:>4}\n",
        "JOB", "TENANT", "RANKS", "STATE", "SUBMIT_S", "START_S", "WAIT_S", "RUN_S", "REQUEUE_S", "ATTEMPTS", "CRIT"
    ));
    for j in &r.jobs {
        s.push_str(&format!(
            "{:>6} {:>6} {:>5} {:>10} {:>10} {:>10} {:>10.3} {:>10.3} {:>10.3} {:>9} {:>4}\n",
            j.job,
            j.tenant,
            j.ranks,
            j.state,
            opt_secs(j.submitted),
            opt_secs(j.first_dispatch),
            j.wait_secs,
            j.run_secs,
            j.requeue_secs,
            j.attempts.len(),
            j.critical_attempt.map_or("-".into(), |a| a.to_string()),
        ));
        // attempt detail only where the lifecycle had more than one act
        if j.attempts.len() > 1 {
            for a in &j.attempts {
                s.push_str(&format!(
                    "       attempt {}: dispatched {:>10} launched {:>10} ended {:>10}  {}\n",
                    a.attempt,
                    format!("{:.3}", a.dispatched.as_secs_f64()),
                    opt_secs(a.launched),
                    opt_secs(a.ended),
                    a.outcome
                ));
            }
        }
    }
    s.push('\n');
    s.push_str(&format!(
        "{:>10} {:>6} {:>5} {:>14} {:>24} {:>24}\n",
        "T_S", "ACTION", "NODES", "REASON", "QUEUED_SLOTS(B->A)", "READY(B->A)"
    ));
    let fmt_demand = |p: &Option<DemandPoint>, f: fn(&DemandPoint) -> u64| -> String {
        p.as_ref().map_or("-".into(), |p| f(p).to_string())
    };
    for d in &r.scale {
        s.push_str(&format!(
            "{:>10.3} {:>6} {:>5} {:>14} {:>24} {:>24}\n",
            d.at.as_secs_f64(),
            d.action,
            d.nodes,
            d.reason.code(),
            format!(
                "{} -> {}",
                fmt_demand(&d.before, |p| p.queued_slots),
                fmt_demand(&d.after, |p| p.queued_slots)
            ),
            format!(
                "{} -> {}",
                fmt_demand(&d.before, |p| p.nodes_ready),
                fmt_demand(&d.after, |p| p.nodes_ready)
            ),
        ));
    }
    s.push_str(&format!(
        "\n{} sample(s){}; export the time-series with --series csv|json\n",
        r.series.len(),
        match (r.series.first(), r.series.last()) {
            (Some(a), Some(b)) => format!(
                " from {:.0}s to {:.0}s",
                a.at.as_secs_f64(),
                b.at.as_secs_f64()
            ),
            _ => String::new(),
        }
    ));
    if r.skipped_lines > 0 {
        s.push_str(&format!(
            "\nwarning: {} unparseable line(s) skipped — partial report\n",
            r.skipped_lines
        ));
    }
    s
}

/// Export the sampled gauge series as CSV (exact `t_ns` plus a
/// human-friendly `t_s`, one row per sample).
pub fn render_series_csv(r: &TraceReport) -> String {
    let mut s = String::from(
        "t_ns,t_s,epoch,queued_jobs,queued_slots,running_jobs,reserved_slots,total_slots,nodes_ready,nodes_unhealthy,nodes_provisioning,scale_target,top_usage\n",
    );
    for p in &r.series {
        s.push_str(&format!(
            "{},{:.3},{},{},{},{},{},{},{},{},{},{},\"{}\"\n",
            p.at.as_nanos(),
            p.at.as_secs_f64(),
            p.epoch,
            p.queued_jobs,
            p.queued_slots,
            p.running_jobs,
            p.reserved_slots,
            p.total_slots,
            p.nodes_ready,
            p.nodes_unhealthy,
            p.nodes_provisioning,
            p.scale_target,
            p.top_usage.replace('"', "\"\"")
        ));
    }
    s
}

/// Export the sampled gauge series as one JSON object.
pub fn render_series_json(r: &TraceReport) -> String {
    let mut s = String::from("{\n  \"series\": [\n");
    for (i, p) in r.series.iter().enumerate() {
        s.push_str(&format!(
            "    {}{}\n",
            series_point_json(p),
            if i + 1 < r.series.len() { "," } else { "" }
        ));
    }
    s.push_str(&format!(
        "  ],\n  \"summary\": {{\"samples\":{},\"events\":{},\"skipped_lines\":{}}}\n}}\n",
        r.series.len(),
        r.events,
        r.skipped_lines
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::JobId;

    fn secs(s: u64) -> SimTime {
        SimTime::from_secs(s)
    }

    fn sample(at: u64, queued_slots: u64, ready: u64) -> TraceEvent {
        TraceEvent::Sample {
            at: secs(at),
            epoch: 0,
            queued_jobs: queued_slots / 4,
            queued_slots,
            running_jobs: 1,
            reserved_slots: 4,
            total_slots: ready * 4,
            nodes_ready: ready,
            nodes_unhealthy: 0,
            nodes_provisioning: 0,
            scale_target: ready,
            top_usage: "7:1000".into(),
        }
    }

    /// j1 completes first try; j2 is requeued at +15s, re-dispatched at
    /// +20s and completes; a scale-up fires at +12s between samples at
    /// +10s and +40s.
    fn sample_events() -> Vec<TraceEvent> {
        let j1 = JobId::new(1);
        let j2 = JobId::new(2);
        vec![
            TraceEvent::Submit { at: secs(0), epoch: 0, job: j1, tenant: 7, ranks: 4, priority: 0 },
            TraceEvent::Submit { at: secs(1), epoch: 0, job: j2, tenant: 7, ranks: 2, priority: 0 },
            sample(10, 24, 2),
            TraceEvent::Dispatch { at: secs(10), epoch: 0, job: j1, attempt: 0, tenant: 7, ranks: 4, backfilled: false },
            TraceEvent::Launch { at: secs(10), epoch: 0, job: j1, attempt: 0, planned: secs(20) },
            TraceEvent::Dispatch { at: secs(10), epoch: 0, job: j2, attempt: 0, tenant: 7, ranks: 2, backfilled: false },
            TraceEvent::ScaleUp { at: secs(12), epoch: 0, nodes: 2, reason: ScaleReason::QueuedDemand },
            TraceEvent::Requeue { at: secs(15), epoch: 0, job: j2, attempt: 1, tenant: 7, wasted: secs(5) },
            TraceEvent::Dispatch { at: secs(20), epoch: 0, job: j2, attempt: 1, tenant: 7, ranks: 2, backfilled: false },
            TraceEvent::Complete { at: secs(30), epoch: 0, job: j1, attempt: 0, tenant: 7, started: secs(10) },
            TraceEvent::Complete { at: secs(30), epoch: 0, job: j2, attempt: 1, tenant: 7, started: secs(20) },
            sample(40, 0, 4),
            TraceEvent::ScaleHold { at: secs(50), epoch: 0, reason: ScaleReason::CooldownHeld },
        ]
    }

    #[test]
    fn timelines_split_wait_run_and_requeue() {
        let r = fold_events(sample_events());
        assert_eq!(r.jobs.len(), 2);
        let j1 = &r.jobs[0];
        assert_eq!((j1.state, j1.attempts.len()), ("completed", 1));
        assert_eq!(j1.wait_secs, 10.0);
        assert_eq!(j1.run_secs, 20.0);
        assert_eq!(j1.requeue_secs, 0.0);
        assert_eq!(j1.critical_attempt, Some(0));
        assert_eq!(j1.attempts[0].launched, Some(secs(10)));
        assert_eq!(j1.attempts[0].planned, Some(secs(20)));

        let j2 = &r.jobs[1];
        assert_eq!((j2.state, j2.attempts.len()), ("completed", 2));
        assert_eq!(j2.wait_secs, 9.0);
        // attempt 0 ran 10→15 (requeued), attempt 1 ran 20→30
        assert_eq!(j2.run_secs, 15.0);
        assert_eq!(j2.requeue_secs, 5.0);
        assert_eq!(j2.critical_attempt, Some(1));
        assert_eq!(j2.attempts[0].outcome, "requeued");
        assert_eq!(j2.attempts[1].outcome, "completed");
    }

    #[test]
    fn scale_audit_carries_the_surrounding_demand() {
        let r = fold_events(sample_events());
        assert_eq!(r.scale.len(), 2);
        let up = &r.scale[0];
        assert_eq!((up.action, up.nodes), ("up", 2));
        assert_eq!(up.reason, ScaleReason::QueuedDemand);
        assert_eq!(up.before.unwrap().queued_slots, 24);
        assert_eq!(up.after.unwrap().queued_slots, 0);
        // the hold at +50s has no later sample
        let hold = &r.scale[1];
        assert_eq!(hold.action, "hold");
        assert_eq!(hold.before.unwrap().at, secs(40));
        assert!(hold.after.is_none());
    }

    #[test]
    fn truncated_complete_still_builds_an_attempt() {
        let j = JobId::new(9);
        let r = fold_events(vec![TraceEvent::Complete {
            at: secs(30),
            epoch: 0,
            job: j,
            attempt: 3,
            tenant: 1,
            started: secs(20),
        }]);
        let tl = &r.jobs[0];
        assert_eq!(tl.state, "completed");
        assert_eq!(tl.run_secs, 10.0);
        assert_eq!(tl.critical_attempt, Some(3));
        assert_eq!(tl.attempts[0].dispatched, secs(20));
    }

    #[test]
    fn corrupt_lines_skip_to_a_partial_report() {
        let good: Vec<String> = sample_events().iter().map(|e| e.to_json_line()).collect();
        let mut lines: Vec<&str> = good.iter().map(|s| s.as_str()).collect();
        lines.insert(2, "{\"ev\":\"sample\",\"t_ns\":garbage");
        let r = from_trace_lines(lines);
        assert_eq!(r.skipped_lines, 1);
        assert_eq!(r.jobs.len(), 2, "good lines still fold");
        assert!(render_table(&r).contains("partial report"));
    }

    #[test]
    fn renderers_cover_the_report() {
        let mut r = fold_events(sample_events());
        let json = render_json(&r);
        assert!(json.contains("\"critical_attempt\":1"));
        assert!(json.contains("\"action\":\"up\""));
        assert!(json.contains("\"summary\": {\"jobs\":2,\"scale_decisions\":2,\"samples\":2,"));
        let table = render_table(&r);
        assert!(table.contains("JOB"));
        assert!(table.contains("attempt 1: "), "multi-attempt jobs get detail rows");
        assert!(table.contains("queued-demand"));

        let csv = render_series_csv(&r);
        assert_eq!(csv.lines().count(), 3, "header + 2 samples");
        assert!(csv.starts_with("t_ns,t_s,"));
        let sj = render_series_json(&r);
        assert!(sj.contains("\"queued_slots\":24"));
        assert!(sj.contains("\"summary\": {\"samples\":2,"));

        r.retain_job(2);
        assert_eq!(r.jobs.len(), 1);
        assert_eq!(r.scale.len(), 2, "scale audit is cluster-level and stays");
    }
}
