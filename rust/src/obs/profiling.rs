//! Scoped wall-clock phase timers for the perf harness.
//!
//! This is the **only** obs file on the `vhpc lint` R2 wall-clock
//! allowlist: every `Instant` read in the observability layer lives
//! here, behind an enable gate, and the measurements feed *reported
//! stats only* — nothing the simulation computes ever depends on them,
//! so determinism fingerprints are untouched whether profiling is on
//! or off.
//!
//! Usage: instrumented sites call [`scoped`] with a static phase name
//! (`policy_sort`, `wal_flush`, `gossip_tick`, `window_merge`,
//! `jacobi_sweep`); the returned guard records the elapsed wall time
//! into a global per-phase histogram when it drops. When profiling is
//! disabled (the default, and the case for every normal run) `scoped`
//! is a single relaxed atomic load — no clock read, no lock.
//!
//! The perf harness brackets a run with [`session`] + [`enable`] and
//! collects the result with [`drain`]. The session lock serializes
//! concurrent harness runs (parallel tests) so one run's drain cannot
//! steal another's samples.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Per-phase sample cap: enough for stable p99s without letting a
/// million-sweep perf run hoard memory. Count/total/max stay exact
/// beyond the cap; percentiles come from the first `SAMPLE_CAP`
/// samples.
const SAMPLE_CAP: usize = 8192;

static ENABLED: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<Option<BTreeMap<&'static str, Accum>>> = Mutex::new(None);
static SESSION: Mutex<()> = Mutex::new(());

#[derive(Debug, Clone, Default)]
struct Accum {
    count: u64,
    total_ns: u64,
    max_ns: u64,
    samples: Vec<u64>,
}

/// Exclusive profiling session (held by the perf harness for the
/// duration of an instrumented run).
pub struct Session {
    _guard: MutexGuard<'static, ()>,
}

/// Acquire the profiling session lock. Concurrent callers (parallel
/// perf tests) serialize here instead of corrupting each other's
/// histograms.
pub fn session() -> Session {
    Session { _guard: SESSION.lock().unwrap_or_else(|e| e.into_inner()) }
}

/// Reset the registry and start timing. Call under a [`session`].
pub fn enable() {
    let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    *reg = Some(BTreeMap::new());
    drop(reg);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stop timing and take the accumulated per-phase profiles, keyed and
/// sorted by phase name. Empty when nothing ran (or profiling was
/// never enabled).
pub fn drain() -> Vec<PhaseProfile> {
    ENABLED.store(false, Ordering::Relaxed);
    let map = {
        let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
        reg.take()
    };
    let Some(map) = map else { return Vec::new() };
    map.into_iter()
        .map(|(phase, mut a)| {
            a.samples.sort_unstable();
            let pct = |p: f64| -> f64 {
                if a.samples.is_empty() {
                    return 0.0;
                }
                let idx = ((p / 100.0) * (a.samples.len() - 1) as f64).round() as usize;
                a.samples[idx.min(a.samples.len() - 1)] as f64 / 1_000.0
            };
            PhaseProfile {
                phase: phase.to_string(),
                count: a.count,
                total_secs: a.total_ns as f64 / 1e9,
                mean_us: if a.count == 0 {
                    0.0
                } else {
                    a.total_ns as f64 / a.count as f64 / 1_000.0
                },
                p50_us: pct(50.0),
                p99_us: pct(99.0),
                max_us: a.max_ns as f64 / 1_000.0,
            }
        })
        .collect()
}

/// One phase's accumulated wall-time histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseProfile {
    pub phase: String,
    /// Times the phase ran.
    pub count: u64,
    /// Total wall time across all runs, seconds.
    pub total_secs: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    /// Largest single run, microseconds (exact even past the sample cap).
    pub max_us: f64,
}

/// A scoped timer: records the elapsed wall time for `phase` when it
/// drops. A no-op guard (no clock read) when profiling is disabled.
pub struct PhaseTimer {
    phase: &'static str,
    start: Option<Instant>,
}

/// Start timing `phase` until the returned guard drops.
pub fn scoped(phase: &'static str) -> PhaseTimer {
    if !ENABLED.load(Ordering::Relaxed) {
        return PhaseTimer { phase, start: None };
    }
    PhaseTimer { phase, start: Some(Instant::now()) }
}

impl Drop for PhaseTimer {
    fn drop(&mut self) {
        let Some(start) = self.start.take() else { return };
        let ns = start.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
        // the registry may have been drained while this guard was live
        // (another thread finishing the run): drop the sample quietly
        let Some(map) = reg.as_mut() else { return };
        let a = map.entry(self.phase).or_default();
        a.count += 1;
        a.total_ns = a.total_ns.saturating_add(ns);
        a.max_ns = a.max_ns.max(ns);
        if a.samples.len() < SAMPLE_CAP {
            a.samples.push(ns);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timers_record_nothing() {
        let _s = session();
        // not enabled: the guard must not touch the registry
        {
            let _t = scoped("phase_a");
        }
        assert!(drain().is_empty());
    }

    #[test]
    fn enabled_timers_accumulate_per_phase() {
        let _s = session();
        enable();
        for _ in 0..5 {
            let _t = scoped("phase_b");
        }
        {
            let _t = scoped("phase_a");
        }
        let profiles = drain();
        assert_eq!(profiles.len(), 2);
        // BTreeMap order: sorted by phase name
        assert_eq!(profiles[0].phase, "phase_a");
        assert_eq!(profiles[0].count, 1);
        assert_eq!(profiles[1].phase, "phase_b");
        assert_eq!(profiles[1].count, 5);
        assert!(profiles[1].max_us >= profiles[1].p50_us);
        // drained: later timers land nowhere
        {
            let _t = scoped("phase_b");
        }
        assert!(drain().is_empty(), "drain must reset the registry");
    }
}
