//! The trace-event taxonomy: every observable job/node/head lifecycle
//! transition as typed, serializable data.
//!
//! Events are emitted into the cluster's [`TraceBus`](super::writer::TraceBus)
//! at the site of the transition and stamped with the virtual time and
//! head epoch they happened under; job events additionally carry the
//! owning tenant and the attempt generation, so a trace line is enough
//! to attribute the transition without replaying anything. The JSON
//! codec is hand-rolled (no serde in the offline crate set), one object
//! per line with a fixed key order — the same greppable-and-parseable
//! posture as the WAL's text codec, and the input format `vhpc acct`
//! consumes.
//!
//! Free-text fields (failure reasons, fault labels) are JSON-escaped;
//! the parser is the exact inverse of the renderer, pinned by
//! roundtrip tests.

use crate::cluster::autoscaler::ScaleReason;
use crate::sim::SimTime;
use crate::util::ids::JobId;

/// One observable lifecycle transition. `at` is the virtual time the
/// transition happened; `epoch` is the head incarnation it happened
/// under (0 until a HA takeover bumps it).
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// A submission reached the head's queue (or its quota pens).
    Submit { at: SimTime, epoch: u64, job: JobId, tenant: u64, ranks: u32, priority: i32 },
    /// A submission was rejected before queueing (too wide, or an
    /// over-quota tenant under the reject policy).
    SubmitRejected { at: SimTime, epoch: u64, job: JobId, tenant: u64, reason: String },
    /// An over-quota submission was parked in the tenant's holding pen.
    QuotaDefer { at: SimTime, epoch: u64, job: JobId, tenant: u64 },
    /// Deferred jobs were re-admitted from the quota pens.
    QuotaAdmit { at: SimTime, epoch: u64, admitted: u64 },
    /// A queued job moved to the running pool on a reserved slice.
    Dispatch {
        at: SimTime,
        epoch: u64,
        job: JobId,
        attempt: u32,
        tenant: u64,
        ranks: u32,
        backfilled: bool,
    },
    /// The dispatcher pinned the attempt's planned virtual duration.
    Launch { at: SimTime, epoch: u64, job: JobId, attempt: u32, planned: SimTime },
    /// A running attempt completed.
    Complete {
        at: SimTime,
        epoch: u64,
        job: JobId,
        attempt: u32,
        tenant: u64,
        started: SimTime,
    },
    /// A job failed terminally (launch error or exhausted retries are
    /// reported separately as [`TraceEvent::Abandon`]).
    Fail { at: SimTime, epoch: u64, job: JobId, tenant: u64, reason: String },
    /// A running job lost a node and went back to the queue head.
    Requeue { at: SimTime, epoch: u64, job: JobId, attempt: u32, tenant: u64, wasted: SimTime },
    /// A lost job exhausted its retry budget.
    Abandon { at: SimTime, epoch: u64, job: JobId, tenant: u64 },
    /// A running job was checkpointed-and-requeued to make room for a
    /// higher-priority one.
    Preempt { at: SimTime, epoch: u64, job: JobId, tenant: u64 },
    /// The autoscaler powered `nodes` machines up.
    ScaleUp { at: SimTime, epoch: u64, nodes: u32, reason: ScaleReason },
    /// The autoscaler retired `nodes` machines.
    ScaleDown { at: SimTime, epoch: u64, nodes: u32, reason: ScaleReason },
    /// The autoscaler wanted to act but was held back (cooldown, or
    /// demand already capped at the policy ceiling).
    ScaleHold { at: SimTime, epoch: u64, reason: ScaleReason },
    /// One fault-plan entry fired through the injector.
    FaultInjected { at: SimTime, epoch: u64, kind: String },
    /// The standby observed the active head's lease expire.
    LeaseLost { at: SimTime, epoch: u64 },
    /// A standby promoted itself, replaying `replayed` WAL events.
    Takeover { at: SimTime, epoch: u64, replayed: u64 },
    /// The head wrote a snapshot truncating the WAL below `seq`.
    SnapshotWritten { at: SimTime, epoch: u64, seq: u64 },
    /// One engine event's journal batch reached the durable WAL.
    WalFlush { at: SimTime, epoch: u64, events: u64 },
    /// A periodic gauge snapshot from the metrics recorder: the
    /// cluster's demand and capacity signal at one instant, sampled at
    /// a configurable sim-time cadence (`[cluster] sample_every`).
    /// Every field is an exact integer so the codec roundtrips bit for
    /// bit; `top_usage` is the top-K tenants by decayed usage as
    /// `tenant:milli_slot_seconds` pairs, comma-joined, descending.
    Sample {
        at: SimTime,
        epoch: u64,
        queued_jobs: u64,
        queued_slots: u64,
        running_jobs: u64,
        reserved_slots: u64,
        total_slots: u64,
        nodes_ready: u64,
        nodes_unhealthy: u64,
        nodes_provisioning: u64,
        /// Node count the autoscaler is converging to (ready +
        /// provisioning at sample time).
        scale_target: u64,
        top_usage: String,
    },
}

impl TraceEvent {
    /// The event's virtual timestamp.
    pub fn at(&self) -> SimTime {
        match self {
            TraceEvent::Submit { at, .. }
            | TraceEvent::SubmitRejected { at, .. }
            | TraceEvent::QuotaDefer { at, .. }
            | TraceEvent::QuotaAdmit { at, .. }
            | TraceEvent::Dispatch { at, .. }
            | TraceEvent::Launch { at, .. }
            | TraceEvent::Complete { at, .. }
            | TraceEvent::Fail { at, .. }
            | TraceEvent::Requeue { at, .. }
            | TraceEvent::Abandon { at, .. }
            | TraceEvent::Preempt { at, .. }
            | TraceEvent::ScaleUp { at, .. }
            | TraceEvent::ScaleDown { at, .. }
            | TraceEvent::ScaleHold { at, .. }
            | TraceEvent::FaultInjected { at, .. }
            | TraceEvent::LeaseLost { at, .. }
            | TraceEvent::Takeover { at, .. }
            | TraceEvent::SnapshotWritten { at, .. }
            | TraceEvent::WalFlush { at, .. }
            | TraceEvent::Sample { at, .. } => *at,
        }
    }

    /// The `"ev"` discriminator this event renders with.
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::Submit { .. } => "submit",
            TraceEvent::SubmitRejected { .. } => "reject",
            TraceEvent::QuotaDefer { .. } => "defer",
            TraceEvent::QuotaAdmit { .. } => "admit",
            TraceEvent::Dispatch { .. } => "dispatch",
            TraceEvent::Launch { .. } => "launch",
            TraceEvent::Complete { .. } => "complete",
            TraceEvent::Fail { .. } => "fail",
            TraceEvent::Requeue { .. } => "requeue",
            TraceEvent::Abandon { .. } => "abandon",
            TraceEvent::Preempt { .. } => "preempt",
            TraceEvent::ScaleUp { .. } => "scale_up",
            TraceEvent::ScaleDown { .. } => "scale_down",
            TraceEvent::ScaleHold { .. } => "scale_hold",
            TraceEvent::FaultInjected { .. } => "fault",
            TraceEvent::LeaseLost { .. } => "lease_lost",
            TraceEvent::Takeover { .. } => "takeover",
            TraceEvent::SnapshotWritten { .. } => "snapshot",
            TraceEvent::WalFlush { .. } => "wal_flush",
            TraceEvent::Sample { .. } => "sample",
        }
    }

    /// Canonical within-window ordering key for the sharded trace
    /// merge: `(t_ns, kind rank, entity id)` — the same shape as
    /// `ShardMsg::merge_key`, extended by the emitting rank and the
    /// rank-local sequence at the merge site. The kind rank follows the
    /// enum declaration order; the entity id is the job where the event
    /// has one (cluster-level events use 0 — they are only ever emitted
    /// by one rank, so rank + sequence already orders them).
    pub fn sort_key(&self) -> (u64, u8, u64) {
        let t = self.at().as_nanos();
        match self {
            TraceEvent::Submit { job, .. } => (t, 0, job.raw() as u64),
            TraceEvent::SubmitRejected { job, .. } => (t, 1, job.raw() as u64),
            TraceEvent::QuotaDefer { job, .. } => (t, 2, job.raw() as u64),
            TraceEvent::QuotaAdmit { .. } => (t, 3, 0),
            TraceEvent::Dispatch { job, .. } => (t, 4, job.raw() as u64),
            TraceEvent::Launch { job, .. } => (t, 5, job.raw() as u64),
            TraceEvent::Complete { job, .. } => (t, 6, job.raw() as u64),
            TraceEvent::Fail { job, .. } => (t, 7, job.raw() as u64),
            TraceEvent::Requeue { job, .. } => (t, 8, job.raw() as u64),
            TraceEvent::Abandon { job, .. } => (t, 9, job.raw() as u64),
            TraceEvent::Preempt { job, .. } => (t, 10, job.raw() as u64),
            TraceEvent::ScaleUp { .. } => (t, 11, 0),
            TraceEvent::ScaleDown { .. } => (t, 12, 0),
            TraceEvent::ScaleHold { .. } => (t, 13, 0),
            TraceEvent::FaultInjected { .. } => (t, 14, 0),
            TraceEvent::LeaseLost { .. } => (t, 15, 0),
            TraceEvent::Takeover { .. } => (t, 16, 0),
            TraceEvent::SnapshotWritten { .. } => (t, 17, 0),
            TraceEvent::WalFlush { .. } => (t, 18, 0),
            TraceEvent::Sample { .. } => (t, 19, 0),
        }
    }

    /// Render the event as one JSON object (no trailing newline).
    /// Timestamps are exact virtual nanoseconds (`t_ns`), never floats,
    /// so a parsed trace reproduces the run's instants bit for bit.
    pub fn to_json_line(&self) -> String {
        let head = |ev: &str, at: &SimTime, epoch: &u64| {
            format!("{{\"ev\":\"{ev}\",\"t_ns\":{},\"epoch\":{epoch}", at.as_nanos())
        };
        let mut s = head(self.kind(), &self.at(), &self.epoch());
        match self {
            TraceEvent::Submit { job, tenant, ranks, priority, .. } => {
                s.push_str(&format!(
                    ",\"job\":{},\"tenant\":{tenant},\"ranks\":{ranks},\"prio\":{priority}",
                    job.raw()
                ));
            }
            TraceEvent::SubmitRejected { job, tenant, reason, .. } => {
                s.push_str(&format!(
                    ",\"job\":{},\"tenant\":{tenant},\"reason\":\"{}\"",
                    job.raw(),
                    esc(reason)
                ));
            }
            TraceEvent::QuotaDefer { job, tenant, .. } => {
                s.push_str(&format!(",\"job\":{},\"tenant\":{tenant}", job.raw()));
            }
            TraceEvent::QuotaAdmit { admitted, .. } => {
                s.push_str(&format!(",\"admitted\":{admitted}"));
            }
            TraceEvent::Dispatch { job, attempt, tenant, ranks, backfilled, .. } => {
                s.push_str(&format!(
                    ",\"job\":{},\"attempt\":{attempt},\"tenant\":{tenant},\"ranks\":{ranks},\"backfilled\":{backfilled}",
                    job.raw()
                ));
            }
            TraceEvent::Launch { job, attempt, planned, .. } => {
                s.push_str(&format!(
                    ",\"job\":{},\"attempt\":{attempt},\"planned_ns\":{}",
                    job.raw(),
                    planned.as_nanos()
                ));
            }
            TraceEvent::Complete { job, attempt, tenant, started, .. } => {
                s.push_str(&format!(
                    ",\"job\":{},\"attempt\":{attempt},\"tenant\":{tenant},\"started_ns\":{}",
                    job.raw(),
                    started.as_nanos()
                ));
            }
            TraceEvent::Fail { job, tenant, reason, .. } => {
                s.push_str(&format!(
                    ",\"job\":{},\"tenant\":{tenant},\"reason\":\"{}\"",
                    job.raw(),
                    esc(reason)
                ));
            }
            TraceEvent::Requeue { job, attempt, tenant, wasted, .. } => {
                s.push_str(&format!(
                    ",\"job\":{},\"attempt\":{attempt},\"tenant\":{tenant},\"wasted_ns\":{}",
                    job.raw(),
                    wasted.as_nanos()
                ));
            }
            TraceEvent::Abandon { job, tenant, .. } => {
                s.push_str(&format!(",\"job\":{},\"tenant\":{tenant}", job.raw()));
            }
            TraceEvent::Preempt { job, tenant, .. } => {
                s.push_str(&format!(",\"job\":{},\"tenant\":{tenant}", job.raw()));
            }
            TraceEvent::ScaleUp { nodes, reason, .. }
            | TraceEvent::ScaleDown { nodes, reason, .. } => {
                s.push_str(&format!(",\"nodes\":{nodes},\"reason\":\"{}\"", reason.code()));
            }
            TraceEvent::ScaleHold { reason, .. } => {
                s.push_str(&format!(",\"reason\":\"{}\"", reason.code()));
            }
            TraceEvent::FaultInjected { kind, .. } => {
                s.push_str(&format!(",\"kind\":\"{}\"", esc(kind)));
            }
            TraceEvent::LeaseLost { .. } => {}
            TraceEvent::Takeover { replayed, .. } => {
                s.push_str(&format!(",\"replayed\":{replayed}"));
            }
            TraceEvent::SnapshotWritten { seq, .. } => {
                s.push_str(&format!(",\"seq\":{seq}"));
            }
            TraceEvent::WalFlush { events, .. } => {
                s.push_str(&format!(",\"events\":{events}"));
            }
            TraceEvent::Sample {
                queued_jobs,
                queued_slots,
                running_jobs,
                reserved_slots,
                total_slots,
                nodes_ready,
                nodes_unhealthy,
                nodes_provisioning,
                scale_target,
                top_usage,
                ..
            } => {
                s.push_str(&format!(
                    ",\"queued_jobs\":{queued_jobs},\"queued_slots\":{queued_slots},\"running_jobs\":{running_jobs},\"reserved_slots\":{reserved_slots},\"total_slots\":{total_slots},\"nodes_ready\":{nodes_ready},\"nodes_unhealthy\":{nodes_unhealthy},\"nodes_provisioning\":{nodes_provisioning},\"scale_target\":{scale_target},\"top_usage\":\"{}\"",
                    esc(top_usage)
                ));
            }
        }
        s.push('}');
        s
    }

    /// The head epoch stamp.
    pub fn epoch(&self) -> u64 {
        match self {
            TraceEvent::Submit { epoch, .. }
            | TraceEvent::SubmitRejected { epoch, .. }
            | TraceEvent::QuotaDefer { epoch, .. }
            | TraceEvent::QuotaAdmit { epoch, .. }
            | TraceEvent::Dispatch { epoch, .. }
            | TraceEvent::Launch { epoch, .. }
            | TraceEvent::Complete { epoch, .. }
            | TraceEvent::Fail { epoch, .. }
            | TraceEvent::Requeue { epoch, .. }
            | TraceEvent::Abandon { epoch, .. }
            | TraceEvent::Preempt { epoch, .. }
            | TraceEvent::ScaleUp { epoch, .. }
            | TraceEvent::ScaleDown { epoch, .. }
            | TraceEvent::ScaleHold { epoch, .. }
            | TraceEvent::FaultInjected { epoch, .. }
            | TraceEvent::LeaseLost { epoch, .. }
            | TraceEvent::Takeover { epoch, .. }
            | TraceEvent::SnapshotWritten { epoch, .. }
            | TraceEvent::WalFlush { epoch, .. }
            | TraceEvent::Sample { epoch, .. } => *epoch,
        }
    }

    /// Parse one JSON trace line back into an event — the exact inverse
    /// of [`TraceEvent::to_json_line`]. Anything else errors (and `vhpc
    /// acct` counts-and-skips it rather than aborting the report).
    pub fn parse_json_line(line: &str) -> Result<TraceEvent, String> {
        let ev = str_field(line, "ev")?;
        let at = SimTime::from_nanos(u64_field(line, "t_ns")?);
        let epoch = u64_field(line, "epoch")?;
        let job = |l: &str| -> Result<JobId, String> {
            Ok(JobId::new(u64_field(l, "job")? as u32))
        };
        match ev.as_str() {
            "submit" => Ok(TraceEvent::Submit {
                at,
                epoch,
                job: job(line)?,
                tenant: u64_field(line, "tenant")?,
                ranks: u64_field(line, "ranks")? as u32,
                priority: i64_field(line, "prio")? as i32,
            }),
            "reject" => Ok(TraceEvent::SubmitRejected {
                at,
                epoch,
                job: job(line)?,
                tenant: u64_field(line, "tenant")?,
                reason: str_field(line, "reason")?,
            }),
            "defer" => Ok(TraceEvent::QuotaDefer {
                at,
                epoch,
                job: job(line)?,
                tenant: u64_field(line, "tenant")?,
            }),
            "admit" => Ok(TraceEvent::QuotaAdmit {
                at,
                epoch,
                admitted: u64_field(line, "admitted")?,
            }),
            "dispatch" => Ok(TraceEvent::Dispatch {
                at,
                epoch,
                job: job(line)?,
                attempt: u64_field(line, "attempt")? as u32,
                tenant: u64_field(line, "tenant")?,
                ranks: u64_field(line, "ranks")? as u32,
                backfilled: bool_field(line, "backfilled")?,
            }),
            "launch" => Ok(TraceEvent::Launch {
                at,
                epoch,
                job: job(line)?,
                attempt: u64_field(line, "attempt")? as u32,
                planned: SimTime::from_nanos(u64_field(line, "planned_ns")?),
            }),
            "complete" => Ok(TraceEvent::Complete {
                at,
                epoch,
                job: job(line)?,
                attempt: u64_field(line, "attempt")? as u32,
                tenant: u64_field(line, "tenant")?,
                started: SimTime::from_nanos(u64_field(line, "started_ns")?),
            }),
            "fail" => Ok(TraceEvent::Fail {
                at,
                epoch,
                job: job(line)?,
                tenant: u64_field(line, "tenant")?,
                reason: str_field(line, "reason")?,
            }),
            "requeue" => Ok(TraceEvent::Requeue {
                at,
                epoch,
                job: job(line)?,
                attempt: u64_field(line, "attempt")? as u32,
                tenant: u64_field(line, "tenant")?,
                wasted: SimTime::from_nanos(u64_field(line, "wasted_ns")?),
            }),
            "abandon" => Ok(TraceEvent::Abandon {
                at,
                epoch,
                job: job(line)?,
                tenant: u64_field(line, "tenant")?,
            }),
            "preempt" => Ok(TraceEvent::Preempt {
                at,
                epoch,
                job: job(line)?,
                tenant: u64_field(line, "tenant")?,
            }),
            "scale_up" => Ok(TraceEvent::ScaleUp {
                at,
                epoch,
                nodes: u64_field(line, "nodes")? as u32,
                reason: reason_field(line)?,
            }),
            "scale_down" => Ok(TraceEvent::ScaleDown {
                at,
                epoch,
                nodes: u64_field(line, "nodes")? as u32,
                reason: reason_field(line)?,
            }),
            "scale_hold" => Ok(TraceEvent::ScaleHold { at, epoch, reason: reason_field(line)? }),
            "fault" => Ok(TraceEvent::FaultInjected {
                at,
                epoch,
                kind: str_field(line, "kind")?,
            }),
            "lease_lost" => Ok(TraceEvent::LeaseLost { at, epoch }),
            "takeover" => Ok(TraceEvent::Takeover {
                at,
                epoch,
                replayed: u64_field(line, "replayed")?,
            }),
            "snapshot" => Ok(TraceEvent::SnapshotWritten {
                at,
                epoch,
                seq: u64_field(line, "seq")?,
            }),
            "wal_flush" => Ok(TraceEvent::WalFlush {
                at,
                epoch,
                events: u64_field(line, "events")?,
            }),
            "sample" => Ok(TraceEvent::Sample {
                at,
                epoch,
                queued_jobs: u64_field(line, "queued_jobs")?,
                queued_slots: u64_field(line, "queued_slots")?,
                running_jobs: u64_field(line, "running_jobs")?,
                reserved_slots: u64_field(line, "reserved_slots")?,
                total_slots: u64_field(line, "total_slots")?,
                nodes_ready: u64_field(line, "nodes_ready")?,
                nodes_unhealthy: u64_field(line, "nodes_unhealthy")?,
                nodes_provisioning: u64_field(line, "nodes_provisioning")?,
                scale_target: u64_field(line, "scale_target")?,
                top_usage: str_field(line, "top_usage")?,
            }),
            other => Err(format!("unknown trace event kind: {other}")),
        }
    }
}

// ---------- JSON helpers ----------
//
// The renderer always escapes `"` and `\` inside string values, so the
// literal byte sequence `"<key>":` can never occur inside a value —
// key scanning is unambiguous on well-formed lines.

/// Escape a free-text value for embedding in a JSON string.
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn unesc(s: &str) -> Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                let v = u32::from_str_radix(&hex, 16)
                    .map_err(|_| format!("bad \\u escape in: {s}"))?;
                out.push(char::from_u32(v).ok_or_else(|| format!("bad codepoint in: {s}"))?);
            }
            other => return Err(format!("bad escape \\{other:?} in: {s}")),
        }
    }
    Ok(out)
}

/// The raw text after `"key":`, up to (not including) the value's end.
fn raw_value<'a>(line: &'a str, key: &str) -> Result<&'a str, String> {
    let pat = format!("\"{key}\":");
    let start = line
        .find(&pat)
        .ok_or_else(|| format!("missing field {key} in: {line}"))?
        + pat.len();
    Ok(&line[start..])
}

fn u64_field(line: &str, key: &str) -> Result<u64, String> {
    let rest = raw_value(line, key)?;
    let end = rest
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(rest.len());
    rest[..end]
        .parse()
        .map_err(|_| format!("bad integer for {key} in: {line}"))
}

fn i64_field(line: &str, key: &str) -> Result<i64, String> {
    let rest = raw_value(line, key)?;
    let end = rest
        .char_indices()
        .find(|&(i, c)| !(c.is_ascii_digit() || (i == 0 && c == '-')))
        .map(|(i, _)| i)
        .unwrap_or(rest.len());
    rest[..end]
        .parse()
        .map_err(|_| format!("bad integer for {key} in: {line}"))
}

fn bool_field(line: &str, key: &str) -> Result<bool, String> {
    let rest = raw_value(line, key)?;
    if rest.starts_with("true") {
        Ok(true)
    } else if rest.starts_with("false") {
        Ok(false)
    } else {
        Err(format!("bad bool for {key} in: {line}"))
    }
}

fn str_field(line: &str, key: &str) -> Result<String, String> {
    let rest = raw_value(line, key)?;
    let rest = rest
        .strip_prefix('"')
        .ok_or_else(|| format!("{key} is not a string in: {line}"))?;
    // find the closing quote, skipping escaped ones
    let mut prev_backslash = false;
    for (i, c) in rest.char_indices() {
        match c {
            '\\' if !prev_backslash => prev_backslash = true,
            '"' if !prev_backslash => return unesc(&rest[..i]),
            _ => prev_backslash = false,
        }
    }
    Err(format!("unterminated string for {key} in: {line}"))
}

fn reason_field(line: &str) -> Result<ScaleReason, String> {
    let code = str_field(line, "reason")?;
    ScaleReason::from_code(&code).ok_or_else(|| format!("unknown scale reason: {code}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples() -> Vec<TraceEvent> {
        let t = SimTime::from_millis(1234);
        vec![
            TraceEvent::Submit {
                at: t,
                epoch: 0,
                job: JobId::new(1),
                tenant: 7,
                ranks: 8,
                priority: -2,
            },
            TraceEvent::SubmitRejected {
                at: t,
                epoch: 0,
                job: JobId::new(2),
                tenant: 7,
                reason: "too wide: needs 99 \"slots\"\nsecond line".into(),
            },
            TraceEvent::QuotaDefer { at: t, epoch: 0, job: JobId::new(3), tenant: 4 },
            TraceEvent::QuotaAdmit { at: t, epoch: 0, admitted: 2 },
            TraceEvent::Dispatch {
                at: t,
                epoch: 1,
                job: JobId::new(1),
                attempt: 2,
                tenant: 7,
                ranks: 8,
                backfilled: true,
            },
            TraceEvent::Launch {
                at: t,
                epoch: 1,
                job: JobId::new(1),
                attempt: 2,
                planned: SimTime::from_secs(30),
            },
            TraceEvent::Complete {
                at: t,
                epoch: 1,
                job: JobId::new(1),
                attempt: 2,
                tenant: 7,
                started: SimTime::from_secs(2),
            },
            TraceEvent::Fail {
                at: t,
                epoch: 0,
                job: JobId::new(4),
                tenant: 0,
                reason: "launch: boom".into(),
            },
            TraceEvent::Requeue {
                at: t,
                epoch: 0,
                job: JobId::new(5),
                attempt: 1,
                tenant: 3,
                wasted: SimTime::from_secs(12),
            },
            TraceEvent::Abandon { at: t, epoch: 0, job: JobId::new(5), tenant: 3 },
            TraceEvent::Preempt { at: t, epoch: 0, job: JobId::new(6), tenant: 2 },
            TraceEvent::ScaleUp {
                at: t,
                epoch: 0,
                nodes: 2,
                reason: ScaleReason::QueuedDemand,
            },
            TraceEvent::ScaleDown { at: t, epoch: 0, nodes: 1, reason: ScaleReason::LowUtil },
            TraceEvent::ScaleHold { at: t, epoch: 0, reason: ScaleReason::CooldownHeld },
            TraceEvent::FaultInjected { at: t, epoch: 0, kind: "crash".into() },
            TraceEvent::LeaseLost { at: t, epoch: 0 },
            TraceEvent::Takeover { at: t, epoch: 1, replayed: 42 },
            TraceEvent::SnapshotWritten { at: t, epoch: 1, seq: 9 },
            TraceEvent::WalFlush { at: t, epoch: 1, events: 3 },
            TraceEvent::Sample {
                at: t,
                epoch: 0,
                queued_jobs: 4,
                queued_slots: 48,
                running_jobs: 3,
                reserved_slots: 36,
                total_slots: 96,
                nodes_ready: 8,
                nodes_unhealthy: 1,
                nodes_provisioning: 2,
                scale_target: 10,
                top_usage: "7:125000,0:3100".into(),
            },
        ]
    }

    #[test]
    fn every_event_kind_roundtrips() {
        for ev in samples() {
            let line = ev.to_json_line();
            let back = TraceEvent::parse_json_line(&line)
                .unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(back, ev, "roundtrip drift for {line}");
        }
    }

    #[test]
    fn lines_start_with_the_pinned_header_keys() {
        for ev in samples() {
            let line = ev.to_json_line();
            assert!(
                line.starts_with(&format!("{{\"ev\":\"{}\",\"t_ns\":", ev.kind())),
                "header key order drifted: {line}"
            );
            assert!(line.ends_with('}'), "{line}");
        }
    }

    #[test]
    fn sort_key_is_time_major_and_distinct_per_kind() {
        let evs = samples();
        // kind ranks are distinct, so same-time same-entity events from
        // different kinds never tie in the shard merge
        let mut ranks: Vec<u8> = evs.iter().map(|e| e.sort_key().1).collect();
        ranks.sort_unstable();
        ranks.dedup();
        assert_eq!(ranks.len(), evs.len(), "duplicate kind rank");
        // time dominates: a later event of the lowest-ranked kind sorts
        // after an earlier event of the highest-ranked kind
        let early = TraceEvent::WalFlush { at: SimTime::from_secs(1), epoch: 0, events: 1 };
        let late = TraceEvent::Submit {
            at: SimTime::from_secs(2),
            epoch: 0,
            job: JobId::new(0),
            tenant: 0,
            ranks: 1,
            priority: 0,
        };
        assert!(early.sort_key() < late.sort_key());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(TraceEvent::parse_json_line("").is_err());
        assert!(TraceEvent::parse_json_line("not json").is_err());
        assert!(TraceEvent::parse_json_line("{\"ev\":\"warp\",\"t_ns\":1,\"epoch\":0}").is_err());
        assert!(
            TraceEvent::parse_json_line("{\"ev\":\"submit\",\"t_ns\":1,\"epoch\":0}").is_err(),
            "missing job fields must fail"
        );
    }

    #[test]
    fn escaping_keeps_key_scans_unambiguous() {
        let ev = TraceEvent::Fail {
            at: SimTime::from_secs(1),
            epoch: 0,
            job: JobId::new(1),
            tenant: 5,
            reason: "evil \"tenant\":999 injection".into(),
        };
        let line = ev.to_json_line();
        let back = TraceEvent::parse_json_line(&line).unwrap();
        assert_eq!(back, ev);
        // the tenant scan still finds the real field, not the payload
        if let TraceEvent::Fail { tenant, .. } = back {
            assert_eq!(tenant, 5);
        }
    }
}
