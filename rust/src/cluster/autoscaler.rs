//! Auto-scaling policy (the paper's headline feature): grow the node
//! pool when demand outruns capacity, shrink after sustained idleness —
//! with bounds, cooldown and hysteresis. Pure: `decide()` maps an
//! observation to an action; the cluster executes it.

use crate::config::AutoscaleConfig;
use crate::sim::SimTime;

/// What the policy sees each interval.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub now: SimTime,
    /// Nodes registered + passing health checks.
    pub ready_nodes: u32,
    /// Nodes between power-on and registration.
    pub provisioning_nodes: u32,
    /// Slots demanded by queued + running jobs.
    pub demanded_slots: u32,
    pub slots_per_node: u32,
}

/// The policy's verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    None,
    /// Power up `n` more machines.
    Up(u32),
    /// Retire `n` idle nodes.
    Down(u32),
}

/// Stateful policy wrapper (cooldown + idle tracking).
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub config: AutoscaleConfig,
    last_action_at: Option<SimTime>,
    idle_since: Option<SimTime>,
    /// Decisions taken (for the benches).
    pub actions: Vec<(SimTime, ScaleAction)>,
}

impl Autoscaler {
    pub fn new(config: AutoscaleConfig) -> Self {
        Self { config, last_action_at: None, idle_since: None, actions: Vec::new() }
    }

    /// Target node count for a demand level.
    pub fn target_nodes(&self, demanded_slots: u32, slots_per_node: u32) -> u32 {
        let needed = demanded_slots.div_ceil(slots_per_node.max(1));
        needed.clamp(self.config.min_nodes, self.config.max_nodes)
    }

    fn in_cooldown(&self, now: SimTime) -> bool {
        match self.last_action_at {
            Some(t) => now.saturating_sub(t) < self.config.cooldown,
            None => false,
        }
    }

    /// Evaluate the policy.
    pub fn decide(&mut self, obs: Observation) -> ScaleAction {
        if !self.config.enabled {
            return ScaleAction::None;
        }
        // idle tracking (demand == 0)
        if obs.demanded_slots == 0 {
            if self.idle_since.is_none() {
                self.idle_since = Some(obs.now);
            }
        } else {
            self.idle_since = None;
        }

        let target = self.target_nodes(obs.demanded_slots, obs.slots_per_node);
        let have = obs.ready_nodes + obs.provisioning_nodes;

        let action = if have < target {
            if self.in_cooldown(obs.now) {
                ScaleAction::None
            } else {
                ScaleAction::Up(target - have)
            }
        } else if obs.ready_nodes > target {
            // scale down only after sustained idleness (hysteresis)
            let idle_long_enough = self
                .idle_since
                .map(|t| obs.now.saturating_sub(t) >= self.config.idle_timeout)
                .unwrap_or(false);
            if idle_long_enough && !self.in_cooldown(obs.now) {
                ScaleAction::Down(obs.ready_nodes - target)
            } else {
                ScaleAction::None
            }
        } else {
            ScaleAction::None
        };

        if action != ScaleAction::None {
            self.last_action_at = Some(obs.now);
            self.actions.push((obs.now, action));
        }
        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn config() -> AutoscaleConfig {
        AutoscaleConfig {
            enabled: true,
            min_nodes: 1,
            max_nodes: 8,
            interval: SimTime::from_secs(5),
            cooldown: SimTime::from_secs(30),
            idle_timeout: SimTime::from_secs(120),
        }
    }

    fn obs(now_s: u64, ready: u32, prov: u32, demand: u32) -> Observation {
        Observation {
            now: SimTime::from_secs(now_s),
            ready_nodes: ready,
            provisioning_nodes: prov,
            demanded_slots: demand,
            slots_per_node: 12,
        }
    }

    #[test]
    fn scales_up_to_meet_demand() {
        let mut a = Autoscaler::new(config());
        // 40 slots / 12 per node => 4 nodes; have 1
        assert_eq!(a.decide(obs(0, 1, 0, 40)), ScaleAction::Up(3));
    }

    #[test]
    fn respects_max_bound() {
        let mut a = Autoscaler::new(config());
        assert_eq!(a.decide(obs(0, 0, 0, 12_000)), ScaleAction::Up(8));
    }

    #[test]
    fn respects_min_bound_on_idle() {
        let mut a = Autoscaler::new(config());
        // idle with 3 ready: wait for idle_timeout, then drop to min=1
        assert_eq!(a.decide(obs(0, 3, 0, 0)), ScaleAction::None);
        assert_eq!(a.decide(obs(60, 3, 0, 0)), ScaleAction::None);
        assert_eq!(a.decide(obs(121, 3, 0, 0)), ScaleAction::Down(2));
    }

    #[test]
    fn cooldown_suppresses_consecutive_actions() {
        let mut a = Autoscaler::new(config());
        assert_eq!(a.decide(obs(0, 1, 0, 40)), ScaleAction::Up(3));
        // still short: cooldown blocks another Up
        assert_eq!(a.decide(obs(5, 1, 1, 40)), ScaleAction::None);
        // after cooldown it fires again
        assert_eq!(a.decide(obs(31, 1, 1, 40)), ScaleAction::Up(2));
    }

    #[test]
    fn provisioning_nodes_count_toward_capacity() {
        let mut a = Autoscaler::new(config());
        assert_eq!(a.decide(obs(0, 1, 3, 40)), ScaleAction::None);
    }

    #[test]
    fn new_demand_resets_idle_clock() {
        let mut a = Autoscaler::new(config());
        a.decide(obs(0, 3, 0, 0));
        a.decide(obs(100, 3, 0, 24)); // burst arrives: idle reset
        assert_eq!(a.decide(obs(130, 3, 0, 0)), ScaleAction::None); // only 30s idle
        assert_eq!(a.decide(obs(260, 3, 0, 0)), ScaleAction::Down(2));
    }

    #[test]
    fn disabled_policy_never_acts() {
        let mut cfg = config();
        cfg.enabled = false;
        let mut a = Autoscaler::new(cfg);
        assert_eq!(a.decide(obs(0, 0, 0, 999)), ScaleAction::None);
    }

    /// Property: across random demand traces, (ready+provisioning) never
    /// targeted beyond [min, max], and actions never fire inside cooldown.
    #[test]
    fn prop_bounds_and_cooldown_hold() {
        use crate::util::Rng;
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let mut a = Autoscaler::new(config());
            let mut ready = 1u32;
            let mut prov = 0u32;
            let mut last_action: Option<SimTime> = None;
            for step in 0..200u64 {
                let now = SimTime::from_secs(step * 5);
                let demand = (rng.gen_range(20) * 10) as u32;
                let action = a.decide(Observation {
                    now,
                    ready_nodes: ready,
                    provisioning_nodes: prov,
                    demanded_slots: demand,
                    slots_per_node: 12,
                });
                match action {
                    ScaleAction::Up(n) => {
                        assert!(ready + prov + n <= a.config.max_nodes, "over max");
                        prov += n;
                    }
                    ScaleAction::Down(n) => {
                        assert!(ready - n >= a.config.min_nodes, "under min");
                        ready -= n;
                    }
                    ScaleAction::None => {}
                }
                if action != ScaleAction::None {
                    if let Some(t) = last_action {
                        assert!(
                            now.saturating_sub(t) >= a.config.cooldown,
                            "acted inside cooldown"
                        );
                    }
                    last_action = Some(now);
                }
                // provisioning completes stochastically
                if prov > 0 && rng.gen_bool(0.4) {
                    prov -= 1;
                    ready += 1;
                }
            }
        }
    }
}
