//! Auto-scaling policy (the paper's headline feature): grow the node
//! pool when demand outruns capacity, shrink after sustained low
//! utilization — with bounds, cooldown and hysteresis. Pure: `decide()`
//! maps an observation to an action; the cluster executes it.
//!
//! Scale-down is based on *utilization* (target nodes < ready nodes),
//! not on a strictly empty queue: a cluster that drops from 100
//! demanded slots to 1 shrinks once the low load is sustained. Cooldown
//! is per-direction: a recent `Down` never delays an urgent `Up`, while
//! `Down` waits out both directions (so the pool doesn't flap after a
//! burst). Queue demand arrives **priority-weighted** (see
//! [`Observation::queued_slots_weighted`]): a backlog of urgent jobs
//! provisions capacity harder than the same width of batch work.

use crate::config::AutoscaleConfig;
use crate::sim::SimTime;

/// What the policy sees each interval.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub now: SimTime,
    /// Nodes registered + passing health checks.
    pub ready_nodes: u32,
    /// Provisioned nodes whose health check is critical or reaped (hung
    /// agent, network partition): alive capacity the hostfile can no
    /// longer advertise. Not counted as ready — a replacement should
    /// boot — but their existence suppresses scale-down so recovery and
    /// retirement churn never compound.
    pub unhealthy_nodes: u32,
    /// Nodes between power-on and registration.
    pub provisioning_nodes: u32,
    /// Raw (unweighted) slots demanded by queued jobs. Informational:
    /// `decide()` scales on the weighted figure below; this one lets
    /// callers report how much of the demand is priority inflation.
    pub queued_slots: u32,
    /// Priority-weighted, tenant-share-capped queue demand
    /// ([`Head::weighted_queued_slots`](crate::cluster::head::Head::weighted_queued_slots)):
    /// equals `queued_slots` when everything queued is batch priority
    /// from one tenant; larger when urgent work is waiting (the pool
    /// provisions harder for a high-priority backlog); *smaller* than
    /// the raw figure when one tenant floods the queue far past its
    /// fair share — a single hog is provisioned for at most twice its
    /// equal share, so it cannot force unbounded scale-up.
    pub queued_slots_weighted: u32,
    /// Slots already reserved by running jobs. Kept separate from
    /// the queued counts so the policy never double-counts demand that
    /// is already being served by reserved capacity.
    pub reserved_slots: u32,
    pub slots_per_node: u32,
}

impl Observation {
    /// Total slot demand the policy scales on: priority-weighted
    /// queued plus reserved (running) slots.
    pub fn demanded_slots(&self) -> u32 {
        self.queued_slots_weighted + self.reserved_slots
    }
}

/// The policy's verdict.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    None,
    /// Power up `n` more machines.
    Up(u32),
    /// Retire `n` idle nodes.
    Down(u32),
}

/// Why the policy decided what it decided — the part of an autoscale
/// verdict that used to vanish. Surfaced as a trace-event field and as
/// the `autoscale_reason_*` counter family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleReason {
    /// `Up`: queued demand outran ready + provisioning capacity.
    QueuedDemand,
    /// `Up`: part of the powered pool is unhealthy, so a replacement
    /// boots even though enough machines are nominally on.
    UnhealthyReplacement,
    /// Held: raw queue demand wanted more nodes, but the tenant
    /// share cap trimmed the weighted figure — one hog cannot force
    /// unbounded scale-up.
    ShareCap,
    /// `Down`: sustained low utilization (hysteresis satisfied).
    LowUtil,
    /// Held: the policy wanted to act but a cooldown (or the
    /// scale-down hysteresis window) is still running.
    CooldownHeld,
    /// Nothing to do: capacity matches demand.
    Steady,
}

impl ScaleReason {
    /// The stable kebab-case code used in trace lines.
    pub fn code(self) -> &'static str {
        match self {
            ScaleReason::QueuedDemand => "queued-demand",
            ScaleReason::UnhealthyReplacement => "unhealthy-replacement",
            ScaleReason::ShareCap => "share-cap",
            ScaleReason::LowUtil => "low-util",
            ScaleReason::CooldownHeld => "cooldown-held",
            ScaleReason::Steady => "steady",
        }
    }

    /// Inverse of [`ScaleReason::code`] (trace parsing).
    pub fn from_code(code: &str) -> Option<ScaleReason> {
        match code {
            "queued-demand" => Some(ScaleReason::QueuedDemand),
            "unhealthy-replacement" => Some(ScaleReason::UnhealthyReplacement),
            "share-cap" => Some(ScaleReason::ShareCap),
            "low-util" => Some(ScaleReason::LowUtil),
            "cooldown-held" => Some(ScaleReason::CooldownHeld),
            "steady" => Some(ScaleReason::Steady),
            _ => None,
        }
    }

    /// The `Metrics` counter this reason increments, or `None` for
    /// `Steady` (an uneventful interval is not a decision worth a
    /// counter — the five named codes are).
    pub fn counter_name(self) -> Option<&'static str> {
        match self {
            ScaleReason::QueuedDemand => Some("autoscale_reason_queued_demand"),
            ScaleReason::UnhealthyReplacement => {
                Some("autoscale_reason_unhealthy_replacement")
            }
            ScaleReason::ShareCap => Some("autoscale_reason_share_cap"),
            ScaleReason::LowUtil => Some("autoscale_reason_low_util"),
            ScaleReason::CooldownHeld => Some("autoscale_reason_cooldown_held"),
            ScaleReason::Steady => None,
        }
    }
}

/// Stateful policy wrapper (per-direction cooldowns + low-utilization
/// tracking).
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub config: AutoscaleConfig,
    last_up_at: Option<SimTime>,
    last_down_at: Option<SimTime>,
    low_util_since: Option<SimTime>,
    /// Decisions taken (for the benches).
    pub actions: Vec<(SimTime, ScaleAction)>,
}

impl Autoscaler {
    pub fn new(config: AutoscaleConfig) -> Self {
        Self {
            config,
            last_up_at: None,
            last_down_at: None,
            low_util_since: None,
            actions: Vec::new(),
        }
    }

    /// Target node count for a demand level. Tolerates a misconfigured
    /// `min_nodes > max_nodes` by normalizing the bounds instead of
    /// panicking in `clamp`.
    pub fn target_nodes(&self, demanded_slots: u32, slots_per_node: u32) -> u32 {
        let needed = demanded_slots.div_ceil(slots_per_node.max(1));
        let lo = self.config.min_nodes.min(self.config.max_nodes);
        let hi = self.config.max_nodes.max(self.config.min_nodes);
        needed.clamp(lo, hi)
    }

    fn within(&self, now: SimTime, t: Option<SimTime>) -> bool {
        match t {
            Some(t) => now.saturating_sub(t) < self.config.cooldown,
            None => false,
        }
    }

    /// An `Up` is blocked only by a recent `Up`: a `Down` taken moments
    /// ago must not delay reacting to a fresh burst.
    fn up_in_cooldown(&self, now: SimTime) -> bool {
        self.within(now, self.last_up_at)
    }

    /// A `Down` waits out both directions (anti-flap).
    fn down_in_cooldown(&self, now: SimTime) -> bool {
        self.within(now, self.last_up_at) || self.within(now, self.last_down_at)
    }

    /// Evaluate the policy.
    pub fn decide(&mut self, obs: Observation) -> ScaleAction {
        self.decide_with_reason(obs).0
    }

    /// Evaluate the policy, returning both the action and *why* — the
    /// reason rides into trace events and the `autoscale_reason_*`
    /// counters. Behaviour is identical to [`Autoscaler::decide`] (which
    /// delegates here).
    pub fn decide_with_reason(&mut self, obs: Observation) -> (ScaleAction, ScaleReason) {
        if !self.config.enabled {
            return (ScaleAction::None, ScaleReason::Steady);
        }
        let target = self.target_nodes(obs.demanded_slots(), obs.slots_per_node);

        // Low-utilization tracking: over-provisioned whenever the ready
        // pool exceeds what current demand needs (not just on demand 0).
        // An unhealthy node resets the clock: while part of the pool is
        // hung or partitioned the cluster is mid-incident, not idle —
        // retiring healthy capacity then would stack churn on recovery.
        if obs.ready_nodes > target && obs.unhealthy_nodes == 0 {
            if self.low_util_since.is_none() {
                self.low_util_since = Some(obs.now);
            }
        } else {
            self.low_util_since = None;
        }

        let have = obs.ready_nodes + obs.provisioning_nodes;
        let (action, reason) = if have < target {
            if self.up_in_cooldown(obs.now) {
                (ScaleAction::None, ScaleReason::CooldownHeld)
            } else if obs.unhealthy_nodes > 0 {
                (ScaleAction::Up(target - have), ScaleReason::UnhealthyReplacement)
            } else {
                (ScaleAction::Up(target - have), ScaleReason::QueuedDemand)
            }
        } else if obs.ready_nodes > target {
            // scale down only after sustained low utilization (hysteresis)
            let low_long_enough = self
                .low_util_since
                .map(|t| obs.now.saturating_sub(t) >= self.config.idle_timeout)
                .unwrap_or(false);
            if low_long_enough && !self.down_in_cooldown(obs.now) {
                (ScaleAction::Down(obs.ready_nodes - target), ScaleReason::LowUtil)
            } else {
                (ScaleAction::None, ScaleReason::CooldownHeld)
            }
        } else {
            // capacity matches the *weighted* demand. If the raw queue
            // wanted more and the share cap trimmed it, that cap — not
            // satisfied demand — is what's holding the pool size.
            let raw_target =
                self.target_nodes(obs.queued_slots + obs.reserved_slots, obs.slots_per_node);
            if have < raw_target {
                (ScaleAction::None, ScaleReason::ShareCap)
            } else {
                (ScaleAction::None, ScaleReason::Steady)
            }
        };

        match action {
            ScaleAction::Up(_) => self.last_up_at = Some(obs.now),
            ScaleAction::Down(_) => self.last_down_at = Some(obs.now),
            ScaleAction::None => {}
        }
        if action != ScaleAction::None {
            self.actions.push((obs.now, action));
        }
        (action, reason)
    }

    /// Re-arm the per-direction cooldowns from WAL-replayed marks: a
    /// standby taking over mid-cooldown must keep honouring it, not
    /// grant itself a free scaling action. The low-utilization clock is
    /// deliberately cleared — idleness must be re-observed by the new
    /// head, never assumed from before the outage.
    pub fn restore_cooldowns(&mut self, last_up: Option<SimTime>, last_down: Option<SimTime>) {
        self.last_up_at = last_up;
        self.last_down_at = last_down;
        self.low_util_since = None;
    }

    /// The armed cooldown marks `(last_up, last_down)` — what a head
    /// snapshot carries across a failover.
    pub fn cooldown_marks(&self) -> (Option<SimTime>, Option<SimTime>) {
        (self.last_up_at, self.last_down_at)
    }

    /// The executor reports that the `Down` decided at `at` retired no
    /// nodes (every candidate was busy): un-arm the down cooldown so
    /// the next opportunity isn't delayed by a no-op, and drop the
    /// phantom entry from the action log.
    pub fn down_was_noop(&mut self, at: SimTime) {
        if self.last_down_at == Some(at) {
            self.last_down_at = None;
            if let Some(pos) = self
                .actions
                .iter()
                .rposition(|(t, a)| *t == at && matches!(a, ScaleAction::Down(_)))
            {
                self.actions.remove(pos);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn config() -> AutoscaleConfig {
        AutoscaleConfig {
            enabled: true,
            min_nodes: 1,
            max_nodes: 8,
            interval: SimTime::from_secs(5),
            cooldown: SimTime::from_secs(30),
            idle_timeout: SimTime::from_secs(120),
        }
    }

    fn obs(now_s: u64, ready: u32, prov: u32, queued: u32) -> Observation {
        obs_r(now_s, ready, prov, queued, 0)
    }

    fn obs_r(now_s: u64, ready: u32, prov: u32, queued: u32, reserved: u32) -> Observation {
        obs_u(now_s, ready, 0, prov, queued, reserved)
    }

    fn obs_u(
        now_s: u64,
        ready: u32,
        unhealthy: u32,
        prov: u32,
        queued: u32,
        reserved: u32,
    ) -> Observation {
        Observation {
            now: SimTime::from_secs(now_s),
            ready_nodes: ready,
            unhealthy_nodes: unhealthy,
            provisioning_nodes: prov,
            queued_slots: queued,
            queued_slots_weighted: queued,
            reserved_slots: reserved,
            slots_per_node: 12,
        }
    }

    #[test]
    fn scales_up_to_meet_demand() {
        let mut a = Autoscaler::new(config());
        // 40 slots / 12 per node => 4 nodes; have 1
        assert_eq!(a.decide(obs(0, 1, 0, 40)), ScaleAction::Up(3));
    }

    #[test]
    fn reserved_slots_count_as_served_demand() {
        let mut a = Autoscaler::new(config());
        // 36 reserved (running) + 0 queued on 3 ready nodes: perfectly
        // sized — no double-scaling on demand the pool already serves
        assert_eq!(a.decide(obs_r(0, 3, 0, 0, 36)), ScaleAction::None);
        // 12 queued on top: one more node
        assert_eq!(a.decide(obs_r(5, 3, 0, 12, 36)), ScaleAction::Up(1));
    }

    #[test]
    fn priority_weighted_backlog_provisions_harder() {
        // 24 batch slots -> 2 nodes (have 1: Up(1))
        let mut a = Autoscaler::new(config());
        assert_eq!(a.decide(obs(0, 1, 0, 24)), ScaleAction::Up(1));
        // the same 24 slots at high priority weigh 2x -> 4 nodes
        let mut b = Autoscaler::new(config());
        let mut o = obs(0, 1, 0, 24);
        o.queued_slots_weighted = 48;
        assert_eq!(b.decide(o), ScaleAction::Up(3));
    }

    #[test]
    fn respects_max_bound() {
        let mut a = Autoscaler::new(config());
        assert_eq!(a.decide(obs(0, 0, 0, 12_000)), ScaleAction::Up(8));
    }

    #[test]
    fn respects_min_bound_on_idle() {
        let mut a = Autoscaler::new(config());
        // idle with 3 ready: wait for idle_timeout, then drop to min=1
        assert_eq!(a.decide(obs(0, 3, 0, 0)), ScaleAction::None);
        assert_eq!(a.decide(obs(60, 3, 0, 0)), ScaleAction::None);
        assert_eq!(a.decide(obs(121, 3, 0, 0)), ScaleAction::Down(2));
    }

    #[test]
    fn sustained_low_demand_scales_down_without_full_idle() {
        let mut a = Autoscaler::new(config());
        // demand collapses from 96 slots (8 nodes) to 1 slot — never 0.
        // The old policy only armed its idle clock at demand == 0 and
        // kept 8 nodes forever; low utilization must shrink the pool.
        assert_eq!(a.decide(obs_r(0, 8, 0, 0, 96)), ScaleAction::None);
        assert_eq!(a.decide(obs_r(10, 8, 0, 0, 1)), ScaleAction::None); // clock arms
        assert_eq!(a.decide(obs_r(60, 8, 0, 0, 1)), ScaleAction::None);
        assert_eq!(a.decide(obs_r(131, 8, 0, 0, 1)), ScaleAction::Down(7));
    }

    #[test]
    fn cooldown_suppresses_consecutive_ups() {
        let mut a = Autoscaler::new(config());
        assert_eq!(a.decide(obs(0, 1, 0, 40)), ScaleAction::Up(3));
        // still short: cooldown blocks another Up
        assert_eq!(a.decide(obs(5, 1, 1, 40)), ScaleAction::None);
        // after cooldown it fires again
        assert_eq!(a.decide(obs(31, 1, 1, 40)), ScaleAction::Up(2));
    }

    #[test]
    fn down_cooldown_never_delays_an_urgent_up() {
        let mut a = Autoscaler::new(config());
        a.decide(obs(0, 3, 0, 0));
        assert_eq!(a.decide(obs(121, 3, 0, 0)), ScaleAction::Down(2));
        // a burst lands 5s after the Down: Up must fire immediately
        assert_eq!(a.decide(obs(126, 1, 0, 40)), ScaleAction::Up(3));
    }

    #[test]
    fn provisioning_nodes_count_toward_capacity() {
        let mut a = Autoscaler::new(config());
        assert_eq!(a.decide(obs(0, 1, 3, 40)), ScaleAction::None);
    }

    #[test]
    fn new_demand_resets_low_util_clock() {
        let mut a = Autoscaler::new(config());
        a.decide(obs(0, 3, 0, 0));
        a.decide(obs(100, 3, 0, 36)); // burst sized to the pool: clock resets
        assert_eq!(a.decide(obs(130, 3, 0, 0)), ScaleAction::None); // only 30s low
        assert_eq!(a.decide(obs(260, 3, 0, 0)), ScaleAction::Down(2));
    }

    #[test]
    fn noop_down_does_not_burn_cooldown() {
        let mut a = Autoscaler::new(config());
        a.decide(obs(0, 3, 0, 0));
        assert_eq!(a.decide(obs(121, 3, 0, 0)), ScaleAction::Down(2));
        // executor found every candidate node busy: nothing retired
        a.down_was_noop(SimTime::from_secs(121));
        assert!(
            !a.actions.iter().any(|(_, act)| matches!(act, ScaleAction::Down(_))),
            "phantom Down must leave the action log"
        );
        // the very next interval may retire freed nodes — no cooldown
        assert_eq!(a.decide(obs(126, 3, 0, 0)), ScaleAction::Down(2));
    }

    #[test]
    fn unhealthy_nodes_suppress_scale_down_and_demand_a_replacement() {
        let mut a = Autoscaler::new(config());
        // one of three idle nodes hangs: the pool must not ALSO retire
        // healthy nodes while the incident is live, no matter how long
        // the low utilization lasts
        assert_eq!(a.decide(obs_u(0, 2, 1, 0, 0, 0)), ScaleAction::None);
        assert_eq!(a.decide(obs_u(300, 2, 1, 0, 0, 0)), ScaleAction::None);
        // demand sized to 3 nodes: the hung node is not capacity, so a
        // replacement boots even though 3 machines are powered on
        assert_eq!(a.decide(obs_u(305, 2, 1, 0, 12, 24)), ScaleAction::Up(1));
        // incident over: the idle clock starts fresh from recovery
        assert_eq!(a.decide(obs_u(400, 3, 0, 0, 0, 0)), ScaleAction::None);
        assert_eq!(a.decide(obs_u(521, 3, 0, 0, 0, 0)), ScaleAction::Down(2));
    }

    #[test]
    fn restored_cooldowns_keep_blocking_after_takeover() {
        let mut a = Autoscaler::new(config());
        assert_eq!(a.decide(obs(0, 1, 0, 40)), ScaleAction::Up(3));
        let (up, down) = a.cooldown_marks();
        assert!(up.is_some());
        // a fresh policy (the standby's) with the marks restored still
        // honours the 30s Up cooldown armed before the "crash"...
        let mut b = Autoscaler::new(config());
        b.restore_cooldowns(up, down);
        assert_eq!(b.decide(obs(5, 1, 0, 40)), ScaleAction::None);
        // ...and scales again once it expires
        assert_eq!(b.decide(obs(31, 1, 0, 40)), ScaleAction::Up(3));
    }

    #[test]
    fn min_above_max_does_not_panic() {
        let mut cfg = config();
        cfg.min_nodes = 2;
        cfg.max_nodes = 1; // e.g. `--machines 2` shrinking max below min
        let mut a = Autoscaler::new(cfg);
        assert_eq!(a.decide(obs(0, 0, 0, 0)), ScaleAction::None);
        // demand clamps into the normalized [1, 2] band
        assert_eq!(a.decide(obs(5, 0, 0, 999)), ScaleAction::Up(2));
    }

    #[test]
    fn reasons_name_the_decision() {
        let mut a = Autoscaler::new(config());
        // scale-up for queued work
        assert_eq!(
            a.decide_with_reason(obs(0, 1, 0, 40)),
            (ScaleAction::Up(3), ScaleReason::QueuedDemand)
        );
        // same demand inside the Up cooldown: held
        assert_eq!(
            a.decide_with_reason(obs(5, 1, 1, 40)),
            (ScaleAction::None, ScaleReason::CooldownHeld)
        );

        // an unhealthy node demanding a replacement boot
        let mut b = Autoscaler::new(config());
        assert_eq!(
            b.decide_with_reason(obs_u(0, 2, 1, 0, 12, 24)),
            (ScaleAction::Up(1), ScaleReason::UnhealthyReplacement)
        );

        // share-capped demand: raw queue wants 5 nodes, weighted is
        // satisfied by the 2 we have — the cap is the binding reason
        let mut c = Autoscaler::new(config());
        let mut o = obs(0, 2, 0, 24);
        o.queued_slots = 60;
        assert_eq!(c.decide_with_reason(o), (ScaleAction::None, ScaleReason::ShareCap));

        // sustained low utilization names the Down; steady is steady
        let mut d = Autoscaler::new(config());
        assert_eq!(d.decide_with_reason(obs(0, 3, 0, 0)).1, ScaleReason::CooldownHeld);
        assert_eq!(
            d.decide_with_reason(obs(121, 3, 0, 0)),
            (ScaleAction::Down(2), ScaleReason::LowUtil)
        );
        let mut e = Autoscaler::new(config());
        assert_eq!(
            e.decide_with_reason(obs_r(0, 3, 0, 0, 36)),
            (ScaleAction::None, ScaleReason::Steady)
        );
    }

    #[test]
    fn reason_codes_roundtrip_and_map_to_counters() {
        let all = [
            ScaleReason::QueuedDemand,
            ScaleReason::UnhealthyReplacement,
            ScaleReason::ShareCap,
            ScaleReason::LowUtil,
            ScaleReason::CooldownHeld,
            ScaleReason::Steady,
        ];
        for r in all {
            assert_eq!(ScaleReason::from_code(r.code()), Some(r));
            match r {
                ScaleReason::Steady => assert!(r.counter_name().is_none()),
                _ => {
                    let name = r.counter_name().unwrap();
                    assert!(name.starts_with("autoscale_reason_"), "{name}");
                }
            }
        }
        assert_eq!(ScaleReason::from_code("nope"), None);
    }

    #[test]
    fn disabled_policy_never_acts() {
        let mut cfg = config();
        cfg.enabled = false;
        let mut a = Autoscaler::new(cfg);
        assert_eq!(a.decide(obs(0, 0, 0, 999)), ScaleAction::None);
    }

    /// Property: across random demand traces, targets never leave
    /// [min, max]; Up never fires inside the Up cooldown; Down never
    /// fires inside either cooldown.
    #[test]
    fn prop_bounds_and_cooldown_hold() {
        use crate::util::Rng;
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let mut a = Autoscaler::new(config());
            let mut ready = 1u32;
            let mut prov = 0u32;
            let mut last_up: Option<SimTime> = None;
            let mut last_any: Option<SimTime> = None;
            for step in 0..200u64 {
                let now = SimTime::from_secs(step * 5);
                let queued = (rng.gen_range(20) * 10) as u32;
                let reserved = (rng.gen_range(5) * 12) as u32;
                let action = a.decide(Observation {
                    now,
                    ready_nodes: ready,
                    unhealthy_nodes: 0,
                    provisioning_nodes: prov,
                    queued_slots: queued,
                    queued_slots_weighted: queued,
                    reserved_slots: reserved,
                    slots_per_node: 12,
                });
                match action {
                    ScaleAction::Up(n) => {
                        assert!(ready + prov + n <= a.config.max_nodes, "over max");
                        if let Some(t) = last_up {
                            assert!(
                                now.saturating_sub(t) >= a.config.cooldown,
                                "Up inside Up-cooldown"
                            );
                        }
                        prov += n;
                        last_up = Some(now);
                    }
                    ScaleAction::Down(n) => {
                        assert!(ready - n >= a.config.min_nodes, "under min");
                        if let Some(t) = last_any {
                            assert!(
                                now.saturating_sub(t) >= a.config.cooldown,
                                "Down inside cooldown"
                            );
                        }
                        ready -= n;
                    }
                    ScaleAction::None => {}
                }
                if action != ScaleAction::None {
                    last_any = Some(now);
                }
                // provisioning completes stochastically
                if prov > 0 && rng.gen_bool(0.4) {
                    prov -= 1;
                    ready += 1;
                }
            }
        }
    }
}
