//! Pluggable scheduling policy: what runs next, and on which hosts.
//!
//! The head's dispatcher used to hard-code FIFO order plus conservative
//! backfill; this module turns both decisions into a [`SchedulePolicy`]
//! value the head consults on every dispatch attempt:
//!
//! * [`PolicyKind::Fifo`] — strict submit order with **conservative
//!   backfill**: a younger job may overtake a blocked head-of-queue job
//!   only if all younger jobs together still leave the head job's full
//!   width claimable. No runtime knowledge needed; never delays the
//!   head job. This is the default and reproduces the pre-policy head
//!   exactly.
//! * [`PolicyKind::Easy`] — **EASY backfill**: the blocked head job
//!   gets a *reservation time* computed from the running jobs'
//!   predicted finishes (synthetic runtimes are known exactly; Jacobi
//!   uses a planning estimate). A younger job may jump ahead if it is
//!   predicted to finish before that reservation, or if it fits in the
//!   slots the head job will not need even then. Backfills far more
//!   aggressively than the conservative guard while still never moving
//!   the head job's reservation later (given honest estimates).
//! * [`PolicyKind::Priority`] — highest priority first (submit order
//!   breaks ties), with conservative backfill below the priority head
//!   and **optional preemption**: when enabled, a blocked
//!   high-priority job may checkpoint-and-requeue the lowest-priority
//!   running jobs — one per decision, re-evaluated after each — when
//!   that frees enough slots. Preempted jobs keep their
//!   partial-progress credit and do *not* lose fault-retry budget.
//! * [`PolicyKind::FairShare`] — max-min fairness over tenants: the
//!   job whose tenant has the lowest decayed ledger usage is the head
//!   (FIFO within a tenant), and blocked heads get the same EASY
//!   shadow-time reservation. The decision procedure lives in
//!   [`crate::tenancy::fairshare`].
//!
//! Orthogonally to dispatch order, [`SchedulePolicy::topo_aware`]
//! switches reservation carving from hostfile order (width-only) to
//! [`carve_topo`], which packs a job onto the fewest racks, then the
//! fewest hosts — cutting the cross-rack traffic the interconnect
//! benches charge for. [`SchedulePolicy::decide`] itself is pure: it
//! holds no state between calls, so a fault that kills a running job
//! implicitly invalidates any reservation derived from its predicted
//! finish — the next dispatch attempt sees the new truth. The *queue
//! view* handed to it is memoized by the head behind a dirty flag
//! (invalidated on every submit/dispatch/requeue/preempt/quota change,
//! with per-tenant usage refreshed in place when only the ledger or
//! the clock moved — see `Head::refresh_queue_view`); the memoized
//! view is bit-identical to the one the head historically rebuilt per
//! decision, so caching changes cost, never outcomes.

use crate::mpi::hostfile::HostSlot;
use crate::sim::SimTime;
use crate::util::ids::JobId;
use crate::vnet::addr::Ipv4;
use std::cmp::Reverse;
use std::collections::{BTreeMap, HashMap};

/// Which dispatch-order discipline the head runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    /// Submit order + conservative backfill (the default).
    #[default]
    Fifo,
    /// Submit order + EASY (reservation-based) backfill.
    Easy,
    /// Highest priority first, optional preemption.
    Priority,
    /// Lowest decayed per-tenant usage first + EASY-style backfill
    /// (see [`crate::tenancy::fairshare`]).
    FairShare,
}

impl PolicyKind {
    /// Stable lowercase name (CLI values and bench labels).
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::Easy => "easy",
            PolicyKind::Priority => "priority",
            PolicyKind::FairShare => "fairshare",
        }
    }
}

impl std::str::FromStr for PolicyKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "fifo" => Ok(PolicyKind::Fifo),
            "easy" => Ok(PolicyKind::Easy),
            "priority" => Ok(PolicyKind::Priority),
            "fairshare" => Ok(PolicyKind::FairShare),
            other => Err(format!(
                "unknown policy {other} (expected fifo|easy|priority|fairshare)"
            )),
        }
    }
}

/// The head's scheduling policy: dispatch order plus placement flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulePolicy {
    /// Dispatch-order discipline.
    pub kind: PolicyKind,
    /// Under [`PolicyKind::Priority`]: allow a blocked high-priority
    /// job to checkpoint-and-requeue lower-priority running jobs when
    /// that frees enough slots. Ignored by the other kinds.
    pub preemption: bool,
    /// Preemption cost model: among equally-low-priority candidates,
    /// prefer the victim closest to its last checkpoint (least
    /// [`RunningJob::preempt_waste`]), so a preemption redoes as little
    /// work as possible. Off reproduces the historical victim choice
    /// (lowest priority, then youngest).
    pub preempt_cost_aware: bool,
    /// Carve reservations rack-aware (fewest racks, then fewest hosts)
    /// instead of hostfile order.
    pub topo_aware: bool,
}

impl Default for SchedulePolicy {
    /// FIFO, no preemption, width-only carving — byte-for-byte the
    /// pre-policy scheduler, so existing benches reproduce (FIFO never
    /// preempts, so the cost model's default is moot here).
    fn default() -> Self {
        Self {
            kind: PolicyKind::Fifo,
            preemption: false,
            preempt_cost_aware: true,
            topo_aware: false,
        }
    }
}

impl SchedulePolicy {
    /// Policy for `kind` with its natural defaults (preemption on for
    /// [`PolicyKind::Priority`], cost-aware victim choice, width-only
    /// carving).
    pub fn new(kind: PolicyKind) -> Self {
        Self {
            kind,
            preemption: kind == PolicyKind::Priority,
            preempt_cost_aware: true,
            topo_aware: false,
        }
    }
    /// Builder-style toggle for topology-aware carving.
    pub fn with_topo_aware(mut self, on: bool) -> Self {
        self.topo_aware = on;
        self
    }
    /// Builder-style toggle for the preemption cost model (off = the
    /// historical lowest-priority / youngest-first victim choice; kept
    /// for comparisons).
    pub fn with_cost_aware(mut self, on: bool) -> Self {
        self.preempt_cost_aware = on;
        self
    }
    /// Shorthand for [`SchedulePolicy::new`] with [`PolicyKind::Fifo`].
    pub fn fifo() -> Self {
        Self::new(PolicyKind::Fifo)
    }
    /// Shorthand for [`SchedulePolicy::new`] with [`PolicyKind::Easy`].
    pub fn easy() -> Self {
        Self::new(PolicyKind::Easy)
    }
    /// Shorthand for [`SchedulePolicy::new`] with
    /// [`PolicyKind::Priority`] (preemption enabled).
    pub fn priority() -> Self {
        Self::new(PolicyKind::Priority)
    }
    /// Shorthand for [`SchedulePolicy::new`] with
    /// [`PolicyKind::FairShare`].
    pub fn fairshare() -> Self {
        Self::new(PolicyKind::FairShare)
    }
}

/// A queued job as the policy sees it.
#[derive(Debug, Clone, Copy)]
pub struct QueuedJob {
    pub id: JobId,
    pub ranks: u32,
    pub priority: i32,
    /// Planning estimate of the job's virtual runtime (exact for
    /// synthetic jobs, a heuristic for Jacobi).
    pub est: SimTime,
    /// Owning tenant (0 = untenanted system work).
    pub tenant: u64,
    /// The tenant's decayed ledger usage at decision time, normalized
    /// by its share weight (slot-seconds; what the fair-share policy
    /// orders by — 0 for fresh tenants).
    pub usage: f64,
}

/// A running job as the policy sees it.
#[derive(Debug, Clone, Copy)]
pub struct RunningJob {
    pub id: JobId,
    pub ranks: u32,
    pub priority: i32,
    /// When the dispatcher expects the job's slots back.
    pub predicted_finish: SimTime,
    /// Virtual work a preemption of this job would redo (its distance
    /// past the last checkpoint; 0 for synthetic jobs, which checkpoint
    /// continuously). The cost model ranks victims by this.
    pub preempt_waste: SimTime,
}

/// What the policy decided for one dispatch attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Dispatch the job at this queue index now.
    Start {
        /// Index into the queue view handed to `decide`.
        idx: usize,
        /// True when the job overtook a blocked head job (backfill).
        backfilled: bool,
    },
    /// Checkpoint-and-requeue this running job, then decide again
    /// (only emitted under [`PolicyKind::Priority`] with preemption).
    Preempt { victim: JobId },
    /// Nothing can start right now.
    Wait,
}

impl SchedulePolicy {
    /// Pick the next action for the current cluster state. `queue` is
    /// in submit order, `free` / `total` are advertised-slot counts.
    /// Pure: callers re-invoke after applying the returned action, so
    /// every decision is made against live state — there is no cached
    /// reservation to go stale when a fault removes a running job.
    pub fn decide(
        &self,
        now: SimTime,
        queue: &[QueuedJob],
        running: &[RunningJob],
        free: u32,
        total: u32,
    ) -> Decision {
        if queue.is_empty() {
            return Decision::Wait;
        }
        match self.kind {
            PolicyKind::Fifo => decide_fifo(queue, running, free, total),
            PolicyKind::Easy => decide_easy(now, queue, running, free),
            PolicyKind::Priority => decide_priority(
                self.preemption,
                self.preempt_cost_aware,
                queue,
                running,
                free,
                total,
            ),
            PolicyKind::FairShare => {
                crate::tenancy::fairshare::decide_fairshare(now, queue, running, free)
            }
        }
    }
}

/// FIFO + conservative backfill (the pre-policy dispatcher, verbatim):
/// younger jobs may never collectively hold more than
/// `total - head_ranks` slots, so the head job's width stays claimable.
fn decide_fifo(queue: &[QueuedJob], running: &[RunningJob], free: u32, total: u32) -> Decision {
    let head = &queue[0];
    if head.ranks <= free {
        return Decision::Start { idx: 0, backfilled: false };
    }
    let younger_held: u32 = running
        .iter()
        .filter(|r| r.id > head.id)
        .map(|r| r.ranks)
        .sum();
    for (i, j) in queue.iter().enumerate().skip(1) {
        let fits_claim = head
            .ranks
            .checked_add(younger_held)
            .and_then(|s| s.checked_add(j.ranks))
            .map(|s| s <= total)
            .unwrap_or(false);
        if j.ranks <= free && fits_claim {
            return Decision::Start { idx: i, backfilled: true };
        }
    }
    Decision::Wait
}

/// EASY backfill: reserve a start time for the blocked head job from
/// the running jobs' predicted finishes, and let younger jobs jump
/// ahead only if they are predicted to finish before that reservation
/// (or fit in the slots the head job leaves spare even then).
fn decide_easy(
    now: SimTime,
    queue: &[QueuedJob],
    running: &[RunningJob],
    free: u32,
) -> Decision {
    let head = &queue[0];
    if head.ranks <= free {
        return Decision::Start { idx: 0, backfilled: false };
    }
    match shadow_time(now, head.ranks, running, free) {
        Some((shadow, extra)) => {
            for (i, j) in queue.iter().enumerate().skip(1) {
                if j.ranks <= free && (now + j.est <= shadow || j.ranks <= extra) {
                    return Decision::Start { idx: i, backfilled: true };
                }
            }
            Decision::Wait
        }
        // Even a fully drained cluster cannot seat the head job — it is
        // waiting on scale-up, and there is no reservation to protect.
        // Keep the existing pool busy greedily: the moment capacity can
        // seat the head, the shadow re-forms and protects it again.
        None => {
            for (i, j) in queue.iter().enumerate().skip(1) {
                if j.ranks <= free {
                    return Decision::Start { idx: i, backfilled: true };
                }
            }
            Decision::Wait
        }
    }
}

/// When will `ranks` slots be free, assuming running jobs finish at
/// their predicted times and nothing new starts? Returns the shadow
/// time plus the slots left over for backfill at that moment, or
/// `None` when even draining everything cannot seat the job. Shared
/// with the fair-share policy (`tenancy/fairshare.rs`), which gives
/// its usage-ordered head the same reservation.
pub(crate) fn shadow_time(
    now: SimTime,
    ranks: u32,
    running: &[RunningJob],
    free: u32,
) -> Option<(SimTime, u32)> {
    if free >= ranks {
        return Some((now, free - ranks));
    }
    let mut finishes: Vec<(SimTime, u32)> = running
        .iter()
        .map(|r| (r.predicted_finish.max(now), r.ranks))
        .collect();
    finishes.sort();
    let mut acc = free;
    for (t, w) in finishes {
        acc += w;
        if acc >= ranks {
            return Some((t, acc - ranks));
        }
    }
    None
}

/// Priority order: (priority desc, submit order asc). The key sorts
/// ascending, so lower key = dispatched sooner.
fn priority_key(priority: i32, id: JobId) -> (Reverse<i32>, JobId) {
    (Reverse(priority), id)
}

/// Highest-priority-first with conservative backfill below the
/// priority head, plus optional preemption of lower-priority running
/// jobs when that is what it takes to seat the head. With
/// `cost_aware`, equally-low-priority victims are ranked by the work a
/// preemption would waste (distance past their last checkpoint), so
/// the scheduler evicts the job that loses the least.
fn decide_priority(
    preemption: bool,
    cost_aware: bool,
    queue: &[QueuedJob],
    running: &[RunningJob],
    free: u32,
    total: u32,
) -> Decision {
    let head_idx = (0..queue.len())
        .min_by_key(|&i| priority_key(queue[i].priority, queue[i].id))
        .expect("queue checked non-empty");
    let head = &queue[head_idx];
    if head.ranks <= free {
        // the priority head is the policy's head of queue, not a
        // backfill, even when it overtakes older submissions
        return Decision::Start { idx: head_idx, backfilled: false };
    }
    if preemption {
        // Preempt at most one victim per decision — the caller applies
        // it and asks again, so exactly as many jobs are preempted as
        // the head needs. Only strictly-lower-priority jobs are ever
        // victims, and only when the full victim set frees enough.
        let freeable: u32 = running
            .iter()
            .filter(|r| r.priority < head.priority)
            .map(|r| r.ranks)
            .sum();
        if free
            .checked_add(freeable)
            .map(|s| s >= head.ranks)
            .unwrap_or(true)
        {
            let victim = running
                .iter()
                .filter(|r| r.priority < head.priority)
                .min_by_key(|r| {
                    // cost model: cheapest checkpoint distance among the
                    // lowest-priority candidates; with it off, every
                    // candidate ties at zero and the historical
                    // youngest-first order decides
                    let waste = if cost_aware { r.preempt_waste } else { SimTime::ZERO };
                    (r.priority, waste, Reverse(r.id))
                });
            if let Some(v) = victim {
                return Decision::Preempt { victim: v.id };
            }
        }
    }
    // Conservative backfill relative to the priority head: jobs the
    // policy would dispatch after the head may start early only while
    // the head's full width stays claimable.
    let head_key = priority_key(head.priority, head.id);
    let younger_held: u32 = running
        .iter()
        .filter(|r| priority_key(r.priority, r.id) > head_key)
        .map(|r| r.ranks)
        .sum();
    let mut order: Vec<usize> = (0..queue.len()).filter(|&i| i != head_idx).collect();
    order.sort_by_key(|&i| priority_key(queue[i].priority, queue[i].id));
    for i in order {
        let j = &queue[i];
        let fits_claim = head
            .ranks
            .checked_add(younger_held)
            .and_then(|s| s.checked_add(j.ranks))
            .map(|s| s <= total)
            .unwrap_or(false);
        if j.ranks <= free && fits_claim {
            return Decision::Start { idx: i, backfilled: true };
        }
    }
    Decision::Wait
}

/// Demand weight of a queued job for the autoscaler: priority 0 (and
/// below) weighs 1.0; each priority level adds half a node-equivalent
/// of urgency, capped at 3x, so a backlog of urgent work scales the
/// pool up harder than the same slot count of batch work.
pub fn priority_weight(priority: i32) -> f64 {
    if priority <= 0 {
        1.0
    } else {
        (1.0 + 0.5 * priority as f64).min(3.0)
    }
}

/// Take `ranks` slots out of `free` (mutating it) preferring the
/// fewest racks, then the fewest hosts: racks are chosen best-fit
/// (the smallest rack that seats the whole remainder, else the
/// biggest rack consumed whole), and hosts inside a chosen rack fill
/// biggest-hole-first. Hosts missing from `rack_of` share one
/// "unknown" rack, so an unpopulated map degrades to width-only
/// behavior. Returns `None` when the free pool is too small.
pub fn carve_topo(
    free: &mut [HostSlot],
    ranks: u32,
    rack_of: &HashMap<Ipv4, usize>,
) -> Option<Vec<HostSlot>> {
    let total: u32 = free.iter().map(|h| h.slots).sum();
    if total < ranks {
        return None;
    }
    // group host indices by rack, in deterministic rack order
    let mut racks: BTreeMap<usize, (u32, Vec<usize>)> = BTreeMap::new();
    for (i, h) in free.iter().enumerate() {
        if h.slots == 0 {
            continue;
        }
        let r = rack_of.get(&h.addr).copied().unwrap_or(usize::MAX);
        let entry = racks.entry(r).or_insert((0, Vec::new()));
        entry.0 += h.slots;
        entry.1.push(i);
    }
    let mut remaining: Vec<(usize, u32, Vec<usize>)> = racks
        .into_iter()
        .map(|(r, (cap, hosts))| (r, cap, hosts))
        .collect();
    // pick racks until the job fits
    let mut chosen: Vec<usize> = Vec::new();
    let mut need_cap = ranks;
    while need_cap > 0 && !remaining.is_empty() {
        // best fit: the smallest rack that seats the whole remainder
        let mut pick: Option<usize> = None;
        for k in 0..remaining.len() {
            if remaining[k].1 >= need_cap {
                let better = match pick {
                    None => true,
                    Some(p) => (remaining[k].1, remaining[k].0) < (remaining[p].1, remaining[p].0),
                };
                if better {
                    pick = Some(k);
                }
            }
        }
        // no single rack fits: consume the biggest remaining rack whole
        if pick.is_none() {
            for k in 0..remaining.len() {
                let better = match pick {
                    None => true,
                    Some(p) => remaining[k].1 > remaining[p].1,
                };
                if better {
                    pick = Some(k);
                }
            }
        }
        let (_, cap, hosts) = remaining.remove(pick.expect("remaining is non-empty"));
        let mut by_slots = hosts;
        by_slots.sort_by(|&a, &b| free[b].slots.cmp(&free[a].slots).then(a.cmp(&b)));
        chosen.extend(by_slots);
        need_cap = need_cap.saturating_sub(cap);
    }
    // fill the chosen hosts, biggest holes first within each rack
    let mut need = ranks;
    let mut take = Vec::new();
    for idx in chosen {
        if need == 0 {
            break;
        }
        let h = &mut free[idx];
        let t = h.slots.min(need);
        if t > 0 {
            take.push(HostSlot { addr: h.addr, slots: t });
            h.slots -= t;
            need -= t;
        }
    }
    debug_assert_eq!(need, 0, "total >= ranks guarantees the fill completes");
    Some(take)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: u32, ranks: u32, pri: i32, est_secs: u64) -> QueuedJob {
        QueuedJob {
            id: JobId::new(id),
            ranks,
            priority: pri,
            est: SimTime::from_secs(est_secs),
            tenant: 0,
            usage: 0.0,
        }
    }

    fn r(id: u32, ranks: u32, pri: i32, finish_secs: u64) -> RunningJob {
        RunningJob {
            id: JobId::new(id),
            ranks,
            priority: pri,
            predicted_finish: SimTime::from_secs(finish_secs),
            preempt_waste: SimTime::ZERO,
        }
    }

    fn rw(id: u32, ranks: u32, pri: i32, finish_secs: u64, waste_secs: u64) -> RunningJob {
        RunningJob { preempt_waste: SimTime::from_secs(waste_secs), ..r(id, ranks, pri, finish_secs) }
    }

    fn host(last_octet: u8, slots: u32) -> HostSlot {
        HostSlot {
            addr: Ipv4::parse(&format!("10.0.0.{last_octet}")).unwrap(),
            slots,
        }
    }

    #[test]
    fn fifo_starts_head_when_it_fits() {
        let p = SchedulePolicy::fifo();
        let d = p.decide(SimTime::ZERO, &[q(0, 8, 0, 10)], &[], 12, 12);
        assert_eq!(d, Decision::Start { idx: 0, backfilled: false });
    }

    #[test]
    fn fifo_conservative_guard_blocks_overcommit() {
        let p = SchedulePolicy::fifo();
        // elder job0 (20 ranks, dispatched before the head) runs; the
        // head needs 24 of 32; job2 (10 ranks) fits the 12 free slots
        // but 24 + 10 > 32 would strand the head's claim
        let queue = [q(1, 24, 0, 60), q(2, 10, 0, 10)];
        let running = [r(0, 20, 0, 100)];
        assert_eq!(p.decide(SimTime::ZERO, &queue, &running, 12, 32), Decision::Wait);
        // an 8-rank job passes the guard (24 + 8 <= 32)
        let queue = [q(1, 24, 0, 60), q(2, 8, 0, 10)];
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &running, 12, 32),
            Decision::Start { idx: 1, backfilled: true }
        );
    }

    #[test]
    fn easy_backfills_jobs_that_finish_before_the_reservation() {
        let p = SchedulePolicy::easy();
        // job9 (20 ranks) finishes at t=100 -> head (24) reserved then,
        // with 32 - 24 = 8 slots spare at the shadow time
        let running = [r(9, 20, 0, 100)];
        // 10 ranks for 30s: violates the conservative guard (24+10>32)
        // but finishes before t=100 -> EASY admits it
        let queue = [q(0, 24, 0, 60), q(1, 10, 0, 30)];
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &running, 12, 32),
            Decision::Start { idx: 1, backfilled: true }
        );
        // 10 ranks for 200s: outlives the reservation and exceeds the
        // 8 spare slots -> must wait
        let queue = [q(0, 24, 0, 60), q(1, 10, 0, 200)];
        assert_eq!(p.decide(SimTime::ZERO, &queue, &running, 12, 32), Decision::Wait);
        // 8 ranks for 200s: outlives the reservation but fits the
        // 8 spare slots -> admitted
        let queue = [q(0, 24, 0, 60), q(1, 8, 0, 200)];
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &running, 12, 32),
            Decision::Start { idx: 1, backfilled: true }
        );
    }

    #[test]
    fn easy_keeps_pool_busy_while_head_waits_for_scale_up() {
        let p = SchedulePolicy::easy();
        // head needs 48 but draining everything frees only 32: no
        // reservation is computable (the head waits on scale-up), so a
        // fitting job starts greedily instead of idling the pool
        let queue = [q(1, 48, 0, 60), q(2, 8, 0, 500)];
        let running = [r(0, 20, 0, 100)];
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &running, 12, 32),
            Decision::Start { idx: 1, backfilled: true }
        );
    }

    #[test]
    fn easy_reservation_tracks_live_running_set() {
        let p = SchedulePolicy::easy();
        let queue = [q(0, 24, 0, 60), q(1, 10, 0, 150)];
        // while job9 is predicted to run until t=200, a 150s backfill
        // beats the reservation
        let running = [r(9, 20, 0, 200)];
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &running, 12, 32),
            Decision::Start { idx: 1, backfilled: true }
        );
        // job9 died (a fault removed it): the same decision recomputed
        // from the live state sees free capacity and seats the head —
        // nothing stale survives because nothing was cached
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &[], 32, 32),
            Decision::Start { idx: 0, backfilled: false }
        );
    }

    #[test]
    fn priority_head_jumps_the_queue() {
        let p = SchedulePolicy::priority();
        let queue = [q(0, 8, 0, 60), q(1, 8, 5, 30)];
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &[], 24, 24),
            Decision::Start { idx: 1, backfilled: false }
        );
    }

    #[test]
    fn priority_ties_break_by_submit_order() {
        let p = SchedulePolicy::priority();
        let queue = [q(0, 8, 2, 60), q(1, 8, 2, 30)];
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &[], 24, 24),
            Decision::Start { idx: 0, backfilled: false }
        );
    }

    #[test]
    fn priority_preempts_lowest_priority_victim_only_when_enough_frees() {
        let p = SchedulePolicy::priority();
        let queue = [q(5, 24, 5, 30)];
        // two low-priority jobs hold the cluster; preempting both (in
        // ascending priority order) frees enough -> victim is the
        // lowest-priority one first
        let running = [r(1, 12, 0, 300), r(2, 12, 1, 300)];
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &running, 0, 24),
            Decision::Preempt { victim: JobId::new(1) }
        );
        // equal-priority running jobs are never victims
        let running = [r(1, 12, 5, 300), r(2, 12, 5, 300)];
        assert_eq!(p.decide(SimTime::ZERO, &queue, &running, 0, 24), Decision::Wait);
        // preemption disabled: wait even though victims exist
        let mut np = SchedulePolicy::priority();
        np.preemption = false;
        let running = [r(1, 12, 0, 300), r(2, 12, 1, 300)];
        assert_eq!(np.decide(SimTime::ZERO, &queue, &running, 0, 24), Decision::Wait);
    }

    /// Cost model: among equally-low-priority victims the policy picks
    /// the one whose preemption wastes the least work; with the model
    /// off it falls back to the historical youngest-first choice.
    #[test]
    fn preemption_cost_model_picks_cheapest_victim() {
        let queue = [q(5, 12, 5, 30)];
        // the older job (id 1) is right at a checkpoint (waste 0); the
        // younger one (id 2) would redo 15s
        let running = [rw(1, 12, 0, 300, 0), rw(2, 12, 0, 300, 15)];
        let p = SchedulePolicy::priority();
        assert!(p.preempt_cost_aware, "cost model must be the default");
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &running, 0, 24),
            Decision::Preempt { victim: JobId::new(1) },
            "cost-aware preemption must evict the checkpointed job"
        );
        let old = SchedulePolicy::priority().with_cost_aware(false);
        assert_eq!(
            old.decide(SimTime::ZERO, &queue, &running, 0, 24),
            Decision::Preempt { victim: JobId::new(2) },
            "the historical choice preempts the youngest"
        );
        // priority still dominates the cost model: a cheap victim at a
        // higher priority is never chosen over an expensive lower one
        let running = [rw(1, 12, 1, 300, 0), rw(2, 12, 0, 300, 500)];
        assert_eq!(
            p.decide(SimTime::ZERO, &queue, &running, 0, 24),
            Decision::Preempt { victim: JobId::new(2) }
        );
    }

    #[test]
    fn priority_never_preempts_when_victims_cannot_free_enough() {
        let p = SchedulePolicy::priority();
        let queue = [q(5, 24, 5, 30)];
        // only 8 low-priority ranks running; 8 + 4 free < 24: a
        // pointless preemption must not happen
        let running = [r(1, 8, 0, 300), r(2, 12, 5, 300)];
        assert_eq!(p.decide(SimTime::ZERO, &queue, &running, 4, 24), Decision::Wait);
    }

    #[test]
    fn priority_weight_is_flat_for_batch_and_bounded_above() {
        assert_eq!(priority_weight(-3), 1.0);
        assert_eq!(priority_weight(0), 1.0);
        assert!(priority_weight(1) > 1.0);
        assert!(priority_weight(2) > priority_weight(1));
        assert_eq!(priority_weight(100), 3.0);
    }

    #[test]
    fn carve_topo_prefers_a_single_best_fit_rack() {
        let rack_of: HashMap<Ipv4, usize> = [
            (host(1, 0).addr, 0),
            (host(2, 0).addr, 0),
            (host(3, 0).addr, 1),
            (host(4, 0).addr, 1),
        ]
        .into_iter()
        .collect();
        // rack0 has 24 free, rack1 has 12: a 12-rank job best-fits
        // rack1 even though hostfile order would start in rack0
        let mut free = vec![host(1, 12), host(2, 12), host(3, 12), host(4, 0)];
        let take = carve_topo(&mut free, 12, &rack_of).unwrap();
        assert_eq!(take.len(), 1);
        assert_eq!(take[0].addr, host(3, 0).addr);
        assert_eq!(take[0].slots, 12);
        assert_eq!(free[2].slots, 0, "taken slots leave the free pool");
    }

    #[test]
    fn carve_topo_spans_fewest_racks_when_no_single_rack_fits() {
        let rack_of: HashMap<Ipv4, usize> = [
            (host(1, 0).addr, 0),
            (host(2, 0).addr, 1),
            (host(3, 0).addr, 1),
            (host(4, 0).addr, 2),
        ]
        .into_iter()
        .collect();
        // 30 ranks: rack1 (24) + best-fit remainder (6) from rack0 or
        // rack2 (both 12 -> rack0 wins the tie deterministically)
        let mut free = vec![host(1, 12), host(2, 12), host(3, 12), host(4, 12)];
        let take = carve_topo(&mut free, 30, &rack_of).unwrap();
        let total: u32 = take.iter().map(|h| h.slots).sum();
        assert_eq!(total, 30);
        let racks: std::collections::BTreeSet<usize> =
            take.iter().map(|h| rack_of[&h.addr]).collect();
        assert_eq!(racks.len(), 2, "two racks suffice: {take:?}");
        assert!(racks.contains(&1), "the biggest rack must anchor the slice");
    }

    #[test]
    fn carve_topo_beats_width_only_on_fragmented_pools() {
        // the discriminating shape: hostfile-order carving spans a rack
        // boundary (host2 in rack0 + host3 in rack1) where a whole rack
        // (rack1: host3 + host4) was available
        let rack_of: HashMap<Ipv4, usize> = [
            (host(2, 0).addr, 0),
            (host(3, 0).addr, 1),
            (host(4, 0).addr, 1),
        ]
        .into_iter()
        .collect();
        let mut width_free = vec![host(2, 12), host(3, 12), host(4, 12)];
        let mut topo_free = width_free.clone();
        let width = crate::cluster::head::carve_for_test(&mut width_free, 24).unwrap();
        let topo = carve_topo(&mut topo_free, 24, &rack_of).unwrap();
        let spread = |slice: &[HostSlot]| {
            slice
                .iter()
                .map(|h| rack_of[&h.addr])
                .collect::<std::collections::BTreeSet<_>>()
                .len()
        };
        assert_eq!(spread(&width), 2, "width-only crosses the rack boundary");
        assert_eq!(spread(&topo), 1, "topo-aware packs the job into rack1");
    }

    #[test]
    fn carve_topo_without_rack_map_degrades_to_width_only_order() {
        let mut free = vec![host(1, 12), host(2, 12)];
        let take = carve_topo(&mut free, 16, &HashMap::new()).unwrap();
        let total: u32 = take.iter().map(|h| h.slots).sum();
        assert_eq!(total, 16);
        assert!(carve_topo(&mut vec![host(1, 4)], 16, &HashMap::new()).is_none());
    }

    #[test]
    fn parse_policy_names() {
        assert_eq!("fifo".parse::<PolicyKind>().unwrap(), PolicyKind::Fifo);
        assert_eq!("easy".parse::<PolicyKind>().unwrap(), PolicyKind::Easy);
        assert_eq!("priority".parse::<PolicyKind>().unwrap(), PolicyKind::Priority);
        assert_eq!("fairshare".parse::<PolicyKind>().unwrap(), PolicyKind::FairShare);
        assert!("slurm".parse::<PolicyKind>().is_err());
        assert_eq!(PolicyKind::Easy.name(), "easy");
        assert_eq!(PolicyKind::FairShare.name(), "fairshare");
        assert!(SchedulePolicy::priority().preemption);
        assert!(!SchedulePolicy::easy().preemption);
        assert!(!SchedulePolicy::fairshare().preemption);
    }

    #[test]
    fn fairshare_policy_dispatches_lowest_usage_tenant_first() {
        let p = SchedulePolicy::fairshare();
        let hog = QueuedJob { tenant: 1, usage: 900.0, ..q(0, 8, 0, 30) };
        let fresh = QueuedJob { tenant: 2, usage: 0.0, ..q(1, 8, 0, 30) };
        assert_eq!(
            p.decide(SimTime::ZERO, &[hog, fresh], &[], 8, 24),
            Decision::Start { idx: 1, backfilled: false }
        );
    }
}
