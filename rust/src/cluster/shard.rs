//! Partitioned cluster simulation: the machine population is split by
//! id range into shards ([`ShardPlan`]), each advancing its own
//! [`Engine`] on its own thread in lock-step windows
//! ([`run_lockstep`]), while rank 0 — the **conductor** — owns the real
//! [`Head`], the [`Autoscaler`] and the [`Metrics`] sink.
//!
//! Division of labour:
//!
//! * **Shards** simulate everything machine-local: boot pipelines (with
//!   per-machine jittered boot completion), heartbeat + gossip traffic,
//!   health-TTL expiry after a crash, and per-job Jacobi compute (the
//!   f32 sweeps that make a 4-shard run finish wall-clock faster).
//! * **The conductor** makes every scheduling and scaling decision
//!   sequentially — submissions, dispatch, preemption, quota
//!   enforcement, crash handling, scale up/down — exactly like the
//!   single-threaded head, so policy behavior cannot depend on the
//!   shard count.
//!
//! Every cross-participant effect rides a [`ShardMsg`] with one window
//! of latency (including shard-to-itself gossip), and receivers apply
//! each window's batch sorted by [`ShardMsg::merge_key`] — never by
//! arrival order. Together with the fixed window grid this makes the
//! final [`Metrics::counters_snapshot`] fingerprint byte-identical at
//! any `--shards` count for the same seed, which `tests/determinism.rs`
//! pins at 1/2/4 shards for the mix, tenants and chaos drivers.
//!
//! **Tracing** rides the same machinery: every rank owns a
//! rank-local buffering [`TraceBus`]; shards ship each window's batch
//! to the conductor as a [`ShardMsg::Trace`], the conductor holds its
//! own emissions back one window so same-window batches meet at the
//! merge, and the merged batch is stable-sorted by
//! [`TraceEvent::sort_key`] before it reaches the sink. The write
//! order is therefore a pure function of virtual time — the trace
//! file is byte-identical at any shard count — and because `Trace`
//! messages only exist when a sink is configured, a traced run's
//! message stream (and counter fingerprint) is identical to an
//! untraced one.

use crate::cluster::autoscaler::{Autoscaler, Observation, ScaleAction, ScaleReason};
use crate::cluster::head::{
    Head, JobKind, JobRecord, JobSpec, JobState, LossOutcome, SubmitOutcome,
};
use crate::cluster::metrics::Metrics;
use crate::cluster::mix::JobReq;
use crate::cluster::policy::SchedulePolicy;
use crate::config::ClusterSpec;
use crate::obs::{FileSink, GaugeSnapshot, MetricsRecorder, TraceBus, TraceEvent};
use crate::sim::partition::{run_lockstep, Outbox, Partitioned, ShardPlan};
use crate::sim::{Engine, SimEvent, SimTime};
use crate::tenancy::arrivals::{stream_fingerprint, ArrivalGen, JobArrival, PopulationSpec};
use crate::tenancy::ledger::TenantQuotas;
use crate::util::ids::JobId;
use crate::util::rng::Rng;
use crate::vnet::addr::Ipv4;
use anyhow::{bail, Result};
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Gossip/heartbeat cadence on every live compute node.
const HEARTBEAT: SimTime = SimTime::from_secs(1);
/// How long after a crash the (simulated) health registry reaps the
/// node's TTL check.
const HEALTH_TTL: SimTime = SimTime::from_secs(5);
/// Max per-boot jitter, milliseconds (drawn from the machine's RNG).
const BOOT_JITTER_MS: u64 = 500;
/// Virtual-time budget for the cluster to advertise the warmup slots.
const WARMUP_DEADLINE: SimTime = SimTime::from_secs(600);
/// Quiet period before the first chaos kill can fire.
const CHAOS_GRACE_SECS: f64 = 30.0;

/// The deterministic address of compute machine `m` (machine 0 is the
/// head and never appears in a shard). A pure function, so every
/// participant derives the same ip without a directory exchange.
pub fn machine_addr(m: u32) -> Ipv4 {
    Ipv4::new(10, 42, (m >> 8) as u8, (m & 0xff) as u8)
}

/// Boundary messages between the conductor and the shards. Every
/// variant carries the virtual time the effect happened at; receivers
/// sort a window's batch by [`ShardMsg::merge_key`] before applying.
#[derive(Debug, Clone)]
pub enum ShardMsg {
    /// Conductor -> shard: power machine `machine` up. `generation`
    /// counts boots of this machine (reboots after a crash), and fences
    /// stale boot-completion events.
    Boot { at: SimTime, machine: u32, generation: u32 },
    /// Conductor -> shard: the machine crashed (chaos).
    Kill { at: SimTime, machine: u32 },
    /// Conductor -> shard: scale-down retired the machine.
    Retire { at: SimTime, machine: u32 },
    /// Conductor -> shard: a dispatched job's rank-0 landed on
    /// `machine`; simulate its compute there for `duration`.
    Launch {
        at: SimTime,
        id: JobId,
        attempt: u32,
        machine: u32,
        ranks: u32,
        duration: SimTime,
    },
    /// Conductor -> shard: stop simulating attempt `attempt` of job
    /// `id` (preempted or its node was lost).
    CancelJob { at: SimTime, id: JobId, attempt: u32 },
    /// Conductor -> shards: the workload has drained; stop heartbeating
    /// and report counters.
    Finish,
    /// Shard -> conductor: the machine finished booting and registered.
    Ready { at: SimTime, machine: u32 },
    /// Shard -> conductor: the machine completed retirement.
    Retired { at: SimTime, machine: u32 },
    /// Shard -> conductor: attempt `attempt` of job `id` ran to
    /// completion; `residual_bits` is the Jacobi grid probe (f32 bits),
    /// folded into the fingerprint so cross-shard compute divergence
    /// would break determinism loudly.
    Done { at: SimTime, id: JobId, attempt: u32, residual_bits: u32 },
    /// Shard -> shard (possibly itself): one gossip exchange. Routed by
    /// the *target* machine's owner; `from`'s shard counts the tx, the
    /// owner counts rx or drop depending on the target's liveness.
    Gossip { at: SimTime, from: u32, to: u32, bytes: u64 },
    /// Shard -> conductor: the shard's trace-event batch for the window
    /// it just executed, in emission order. Only ever sent on traced
    /// runs, so tracing cannot perturb the untraced message stream.
    Trace(Vec<TraceEvent>),
    /// Shard -> conductor: final counter totals, sent once after
    /// `Finish`. Merged additively, so ordering cannot matter.
    Counters(Vec<(String, u64)>),
}

impl ShardMsg {
    /// Total order a receiver applies a window's batch in:
    /// `(time, kind rank, entity id)`. The kind rank breaks same-time
    /// ties the same way on every shard layout (e.g. a Kill always
    /// applies before a same-instant Launch); the entity id orders
    /// same-kind same-time messages from different senders.
    pub fn merge_key(&self) -> (u64, u8, u64) {
        match self {
            ShardMsg::Boot { at, machine, .. } => (at.as_nanos(), 0, *machine as u64),
            ShardMsg::Kill { at, machine } => (at.as_nanos(), 1, *machine as u64),
            ShardMsg::Retire { at, machine } => (at.as_nanos(), 2, *machine as u64),
            ShardMsg::CancelJob { at, id, .. } => (at.as_nanos(), 3, id.raw() as u64),
            ShardMsg::Launch { at, id, .. } => (at.as_nanos(), 4, id.raw() as u64),
            ShardMsg::Gossip { at, from, to, .. } => {
                (at.as_nanos(), 5, ((*from as u64) << 32) | *to as u64)
            }
            ShardMsg::Ready { at, machine } => (at.as_nanos(), 6, *machine as u64),
            ShardMsg::Retired { at, machine } => (at.as_nanos(), 7, *machine as u64),
            ShardMsg::Done { at, id, .. } => (at.as_nanos(), 8, id.raw() as u64),
            // Trace batches, Finish and Counters close a window
            // exchange: they always apply after every timed message in
            // the same batch. Equal-key Trace batches keep sender-rank
            // order under the stable sort, which the conductor's merge
            // relies on.
            ShardMsg::Trace(_) => (u64::MAX, 253, 0),
            ShardMsg::Finish => (u64::MAX, 254, 0),
            ShardMsg::Counters(_) => (u64::MAX, 255, 0),
        }
    }
}

fn sort_batch(batch: &mut Vec<(usize, ShardMsg)>) {
    let _t = crate::obs::profiling::scoped("window_merge");
    // stable: same-key messages (trace batches) keep sender order
    batch.sort_by_key(|(_, m)| m.merge_key());
}

/// Per-rank profiling phase names. The profiling registry keys are
/// `&'static str`, so per-rank scopes come from fixed tables; runs
/// wider than the table clamp onto the last entry rather than losing
/// the samples.
const JACOBI_PHASES: [&str; 8] = [
    "jacobi_sweep_r1",
    "jacobi_sweep_r2",
    "jacobi_sweep_r3",
    "jacobi_sweep_r4",
    "jacobi_sweep_r5",
    "jacobi_sweep_r6",
    "jacobi_sweep_r7",
    "jacobi_sweep_r8",
];
const MERGE_PHASES: [&str; 8] = [
    "window_merge_r1",
    "window_merge_r2",
    "window_merge_r3",
    "window_merge_r4",
    "window_merge_r5",
    "window_merge_r6",
    "window_merge_r7",
    "window_merge_r8",
];

/// The table entry for 1-based shard rank `rank` (clamped).
fn per_rank_phase(table: &'static [&'static str], rank: usize) -> &'static str {
    table[rank.saturating_sub(1).min(table.len() - 1)]
}

/// Per-job synthetic compute load on the shards: each running job owns
/// a `grid`²-cell f32 Jacobi grid and performs `sweeps_per_tick` full
/// sweeps every window. Purely local, single-threaded per job — the
/// wall-clock work that sharding parallelizes.
#[derive(Debug, Clone, Copy)]
pub struct ComputeProfile {
    pub grid: usize,
    pub sweeps_per_tick: u32,
}

impl Default for ComputeProfile {
    fn default() -> Self {
        // small enough for tests/CI; the shard bench scales it up
        Self { grid: 24, sweeps_per_tick: 2 }
    }
}

/// Tuning knobs shared by all three sharded drivers.
#[derive(Debug, Clone, Copy)]
pub struct ShardRunConfig {
    /// Requested shard count (clamped to the compute-machine count).
    pub shards: usize,
    /// Lock-step window width. The window grid is part of the
    /// determinism contract: compare runs only at equal window sizes.
    pub window: SimTime,
    /// Slots that must be advertised before the workload starts.
    pub warmup_slots: u32,
    /// Virtual-time budget (after warmup) for the trace to drain.
    pub deadline_secs: u64,
    /// Cap on concurrently running jobs (`usize::MAX` = slot-limited).
    pub max_concurrent: usize,
    pub compute: ComputeProfile,
}

impl Default for ShardRunConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            window: SimTime::from_secs(1),
            warmup_slots: 1,
            deadline_secs: 3600,
            max_concurrent: usize::MAX,
            compute: ComputeProfile::default(),
        }
    }
}

/// What a sharded run measured. `fingerprint` is the merged counter
/// snapshot — the determinism witness compared across shard counts.
#[derive(Debug, Clone)]
pub struct ShardOutcome {
    /// Shards actually used (after clamping to the machine count).
    pub shards: usize,
    /// Lock-step windows executed.
    pub windows: u64,
    pub jobs_submitted: usize,
    pub jobs_completed: u64,
    /// Warmup-to-last-completion span, virtual seconds.
    pub makespan_secs: f64,
    /// Engine events fired across all shards (the bench's numerator).
    pub events: u64,
    /// Order-sensitive fingerprint of the synthesized arrival stream
    /// (tenants driver only; 0 for burst traces).
    pub arrivals_fingerprint: u64,
    /// Stable merged counter snapshot: byte-identical for the same
    /// seed at any shard count.
    pub fingerprint: BTreeMap<String, u64>,
    /// Trace events that reached the sink (0 on untraced runs).
    pub trace_events_written: u64,
    /// Trace events lost to sink errors — surfaced in every driver's
    /// end-of-run summary, never folded into the fingerprint.
    pub trace_events_dropped: u64,
}

// ---------------------------------------------------------------------
// Shard side
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NodeStatus {
    Booting,
    Up,
    Dead,
    Retired,
}

struct Node {
    status: NodeStatus,
    /// Boot generation (fences boot-completion and heartbeat events
    /// scheduled for an earlier life of the machine).
    generation: u32,
}

struct JobRun {
    attempt: u32,
    grid: Vec<f32>,
    n: usize,
}

/// The state one shard thread owns: its machines and the jobs homed on
/// them. All containers are ordered (`BTreeMap`) — iteration order
/// feeds event scheduling and must not depend on hashing.
struct ShardCore {
    plan: ShardPlan,
    /// This shard's 1-based participant rank (0 is the conductor);
    /// names the per-rank profiling scopes.
    rank: usize,
    seed: u64,
    total_machines: u32,
    boot_time: SimTime,
    window: SimTime,
    compute: ComputeProfile,
    nodes: BTreeMap<u32, Node>,
    jobs: BTreeMap<JobId, JobRun>,
    counters: BTreeMap<String, u64>,
    outgoing: Vec<(usize, ShardMsg)>,
    draining: bool,
    /// Rank-local trace buffer (buffering mode on traced runs, inert
    /// otherwise); drained to the conductor once per window.
    trace: TraceBus,
}

impl ShardCore {
    fn bump(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    fn send(&mut self, to_rank: usize, msg: ShardMsg) {
        self.outgoing.push((to_rank, msg));
    }

    /// Gossip peer of `machine` at heartbeat `seq`: a pure hash over
    /// the whole compute population, so the choice is identical no
    /// matter which shard computes it.
    fn gossip_peer(&self, machine: u32, seq: u64) -> Option<u32> {
        let peers = self.total_machines.saturating_sub(2); // all compute nodes but self
        if peers == 0 {
            return None;
        }
        let mut h = (machine as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(seq.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        h ^= h >> 29;
        h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h ^= h >> 32;
        let pick = (h % peers as u64) as u32;
        // map [0, peers) onto compute ids 1..total skipping `machine`
        let peer = 1 + pick;
        Some(if peer >= machine { peer + 1 } else { peer })
    }
}

/// Per-machine RNG, reseeded each boot so a machine's timing depends
/// only on (cluster seed, machine id, boot generation) — never on which
/// shard runs it or what its neighbors did.
fn node_rng(seed: u64, machine: u32, generation: u32) -> Rng {
    Rng::new(
        seed ^ (machine as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (generation as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
    )
}

/// Deterministic f32 grid seeded from the job id.
fn init_grid(id: JobId, n: usize) -> Vec<f32> {
    (0..n * n)
        .map(|i| {
            let mut h = id.raw().wrapping_mul(0x9E37_79B9) ^ (i as u32).wrapping_mul(0x85EB_CA6B);
            h ^= h >> 15;
            h = h.wrapping_mul(0x2C1B_3C6D);
            h ^= h >> 12;
            (h >> 8) as f32 / (1u32 << 24) as f32
        })
        .collect()
}

/// One in-place Gauss-Seidel sweep over the interior (fixed boundary).
fn sweep(grid: &mut [f32], n: usize) {
    for r in 1..n - 1 {
        for c in 1..n - 1 {
            let i = r * n + c;
            grid[i] = 0.25 * (grid[i - 1] + grid[i + 1] + grid[i - n] + grid[i + n]);
        }
    }
}

/// Shard-local engine events: the typed, allocation-free form of what
/// used to be boxed closures. Timer identity (machine + boot
/// generation, job + attempt) is carried in the variant fields so a
/// stale timer fences itself against the current state.
enum ShardEvent {
    /// Periodic heartbeat + gossip for `machine`, alive only while the
    /// node stays `Up` in the same boot `generation`.
    Heartbeat { machine: u32, generation: u32 },
    /// The boot pipeline finished (scheduled at boot time + jitter).
    BootDone { machine: u32, generation: u32 },
    /// Per-window Jacobi sweeps for attempt `attempt` of job `id`.
    ComputeTick { id: JobId, attempt: u32 },
    /// Attempt `attempt` of job `id` ran its full duration.
    JobDone { id: JobId, attempt: u32 },
    /// A crashed node's health TTL ran out.
    TtlExpired,
}

impl SimEvent<ShardCore> for ShardEvent {
    fn fire(self, core: &mut ShardCore, eng: &mut Engine<ShardCore, ShardEvent>) {
        match self {
            ShardEvent::Heartbeat { machine, generation } => {
                let _t = crate::obs::profiling::scoped("gossip_tick");
                if core.draining {
                    return;
                }
                let alive = core
                    .nodes
                    .get(&machine)
                    .map(|nd| nd.status == NodeStatus::Up && nd.generation == generation)
                    .unwrap_or(false);
                if !alive {
                    return;
                }
                let seq = eng.now().as_nanos() / HEARTBEAT.as_nanos().max(1);
                core.bump("gossip_tx", 1);
                if let Some(peer) = core.gossip_peer(machine, seq) {
                    let bytes = 64 + ((machine as u64) * 131 + seq * 17) % 192;
                    let to_rank = core.plan.shard_of(peer) + 1;
                    let at = eng.now();
                    core.send(to_rank, ShardMsg::Gossip { at, from: machine, to: peer, bytes });
                }
                eng.schedule_after(HEARTBEAT, ShardEvent::Heartbeat { machine, generation });
            }
            ShardEvent::BootDone { machine, generation } => {
                let now = eng.now();
                let up = match core.nodes.get_mut(&machine) {
                    Some(nd)
                        if nd.status == NodeStatus::Booting && nd.generation == generation =>
                    {
                        nd.status = NodeStatus::Up;
                        true
                    }
                    _ => false,
                };
                if up {
                    core.send(0, ShardMsg::Ready { at: now, machine });
                    eng.schedule_after(
                        HEARTBEAT,
                        ShardEvent::Heartbeat { machine, generation },
                    );
                }
            }
            ShardEvent::ComputeTick { id, attempt } => {
                let _t = crate::obs::profiling::scoped(per_rank_phase(&JACOBI_PHASES, core.rank));
                let sweeps = core.compute.sweeps_per_tick;
                let alive = match core.jobs.get_mut(&id) {
                    Some(run) if run.attempt == attempt => {
                        let n = run.n;
                        for _ in 0..sweeps {
                            sweep(&mut run.grid, n);
                        }
                        true
                    }
                    _ => false,
                };
                if alive {
                    core.bump("shard_sweeps", sweeps as u64);
                    let window = core.window;
                    eng.schedule_after(window, ShardEvent::ComputeTick { id, attempt });
                }
            }
            ShardEvent::JobDone { id, attempt } => {
                let now = eng.now();
                let done = match core.jobs.get(&id) {
                    Some(run) if run.attempt == attempt => {
                        let probe = run.grid[run.n * run.n / 2];
                        Some(probe.to_bits())
                    }
                    _ => None,
                };
                if let Some(residual_bits) = done {
                    core.jobs.remove(&id);
                    core.bump("shard_jobs_done", 1);
                    core.send(0, ShardMsg::Done { at: now, id, attempt, residual_bits });
                }
            }
            ShardEvent::TtlExpired => {
                core.bump("ttl_expired", 1);
            }
        }
    }
}

/// One shard: an [`Engine`] over [`ShardCore`], driven by
/// [`ShardEvent`]s.
struct ShardSim {
    core: ShardCore,
    eng: Engine<ShardCore, ShardEvent>,
    counters_sent: bool,
}

impl ShardSim {
    fn new(
        plan: ShardPlan,
        rank: usize,
        spec: &ClusterSpec,
        window: SimTime,
        compute: ComputeProfile,
        traced: bool,
    ) -> Self {
        Self {
            core: ShardCore {
                plan,
                rank,
                seed: spec.seed,
                total_machines: spec.machines,
                boot_time: spec.machine_spec.boot_time,
                window,
                compute,
                nodes: BTreeMap::new(),
                jobs: BTreeMap::new(),
                counters: BTreeMap::new(),
                outgoing: Vec::new(),
                draining: false,
                trace: if traced { TraceBus::buffering() } else { TraceBus::disabled() },
            },
            eng: Engine::new(),
            counters_sent: false,
        }
    }

    fn apply(&mut self, batch: Vec<(usize, ShardMsg)>) {
        for (_, msg) in batch {
            match msg {
                ShardMsg::Boot { at, machine, generation } => {
                    let mut rng = node_rng(self.core.seed, machine, generation);
                    let jitter = SimTime::from_millis(rng.gen_range(BOOT_JITTER_MS));
                    self.core
                        .nodes
                        .insert(machine, Node { status: NodeStatus::Booting, generation });
                    self.core.bump("nodes_booted", 1);
                    let done_at = at + self.core.boot_time + jitter;
                    self.eng
                        .schedule_at(done_at, ShardEvent::BootDone { machine, generation });
                }
                ShardMsg::Kill { at, machine } => {
                    if let Some(nd) = self.core.nodes.get_mut(&machine) {
                        if matches!(nd.status, NodeStatus::Booting | NodeStatus::Up) {
                            nd.status = NodeStatus::Dead;
                            self.core.bump("nodes_crashed_shard", 1);
                            self.eng.schedule_at(at + HEALTH_TTL, ShardEvent::TtlExpired);
                        }
                    }
                }
                ShardMsg::Retire { at, machine } => {
                    if let Some(nd) = self.core.nodes.get_mut(&machine) {
                        if nd.status == NodeStatus::Up {
                            nd.status = NodeStatus::Retired;
                            self.core.bump("nodes_retired_shard", 1);
                            self.core.send(0, ShardMsg::Retired { at, machine });
                        }
                    }
                }
                ShardMsg::Launch { at, id, attempt, machine: _, ranks: _, duration } => {
                    let n = self.core.compute.grid.max(4);
                    self.core
                        .jobs
                        .insert(id, JobRun { attempt, grid: init_grid(id, n), n });
                    self.core.bump("jobs_launched_shard", 1);
                    // the launch is the one lifecycle transition that
                    // happens *on* a shard: emitted here (not by the
                    // conductor) so the trace records where ranks run
                    self.core.trace.emit(TraceEvent::Launch {
                        at,
                        epoch: 0,
                        job: id,
                        attempt,
                        planned: duration,
                    });
                    self.eng.schedule_at(at, ShardEvent::ComputeTick { id, attempt });
                    self.eng
                        .schedule_at(at + duration, ShardEvent::JobDone { id, attempt });
                }
                ShardMsg::CancelJob { at: _, id, attempt } => {
                    let cancel = matches!(
                        self.core.jobs.get(&id), Some(run) if run.attempt == attempt
                    );
                    if cancel {
                        self.core.jobs.remove(&id);
                        self.core.bump("jobs_cancelled_shard", 1);
                    }
                }
                ShardMsg::Gossip { at: _, from: _, to, bytes } => {
                    let up = self
                        .core
                        .nodes
                        .get(&to)
                        .map(|nd| nd.status == NodeStatus::Up)
                        .unwrap_or(false);
                    if up {
                        self.core.bump("gossip_rx", 1);
                        self.core.bump("gossip_bytes", bytes);
                    } else {
                        self.core.bump("gossip_dropped", 1);
                    }
                }
                ShardMsg::Finish => {
                    self.core.draining = true;
                }
                // conductor-bound messages never reach a shard
                ShardMsg::Ready { .. }
                | ShardMsg::Retired { .. }
                | ShardMsg::Done { .. }
                | ShardMsg::Trace(_)
                | ShardMsg::Counters(_) => {}
            }
        }
    }
}

impl Partitioned for ShardSim {
    type Msg = ShardMsg;

    fn window(
        &mut self,
        _start: SimTime,
        end: SimTime,
        mut incoming: Vec<(usize, ShardMsg)>,
        out: &mut Outbox<ShardMsg>,
    ) -> bool {
        {
            let _t = crate::obs::profiling::scoped(per_rank_phase(&MERGE_PHASES, self.core.rank));
            incoming.sort_by_key(|(_, m)| m.merge_key());
        }
        self.apply(incoming);
        self.eng.run_window(&mut self.core, end);
        // ship this window's trace batch (traced runs only: an inert
        // bus buffers nothing, so no message materializes)
        let batch = self.core.trace.take_buffered();
        if !batch.is_empty() {
            self.core.send(0, ShardMsg::Trace(batch));
        }
        if self.core.draining && !self.counters_sent {
            self.counters_sent = true;
            self.core.bump("shard_events", self.eng.fired());
            let totals: Vec<(String, u64)> = self
                .core
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), *v))
                .collect();
            self.core.send(0, ShardMsg::Counters(totals));
        }
        for (to, msg) in std::mem::take(&mut self.core.outgoing) {
            out.send(to, msg);
        }
        false
    }
}

// ---------------------------------------------------------------------
// Conductor side
// ---------------------------------------------------------------------

enum Workload {
    /// Everything submitted in one burst once warmup completes.
    Burst { jobs: Vec<JobReq>, submitted: bool },
    /// Open-loop multi-tenant arrival stream for `horizon` of virtual
    /// time after warmup.
    Arrivals {
        gen: ArrivalGen,
        horizon: SimTime,
        next: Option<JobArrival>,
        log: Vec<JobArrival>,
    },
}

/// Rank 0: the sequential decision-maker. Owns the head, the
/// autoscaler and the metrics sink; shards only ever learn about its
/// decisions through messages.
struct Conductor {
    spec: ClusterSpec,
    plan: ShardPlan,
    head: Head,
    autoscaler: Autoscaler,
    metrics: Metrics,
    workload: Workload,
    /// Chaos kill schedule, ascending by time.
    kills: VecDeque<(SimTime, u32)>,
    /// Machine pools (disjoint; `off` holds never-booted + retired).
    off: BTreeSet<u32>,
    booting: BTreeSet<u32>,
    ready: BTreeSet<u32>,
    retiring: BTreeSet<u32>,
    dead: BTreeSet<u32>,
    /// Boot generation per machine.
    generations: BTreeMap<u32, u32>,
    ip_to_machine: BTreeMap<Ipv4, u32>,
    /// Live dispatches: job -> (attempt, home machine). Fences stale
    /// completions from cancelled attempts.
    running: BTreeMap<JobId, (u32, u32)>,
    started_at: Option<SimTime>,
    next_scale_at: SimTime,
    warmup_slots: u32,
    deadline: SimTime,
    max_slots: u32,
    next_id: u32,
    last_finish: SimTime,
    finish_sent: bool,
    counters_pending: usize,
    error: Option<String>,
    /// Sink-backed bus the canonical merged trace is written through
    /// (inert on untraced runs).
    trace: TraceBus,
    /// The conductor's own emissions for the in-flight window. Held
    /// back one window so they merge with the shard batches for the
    /// same logical window, which arrive one exchange later.
    own: TraceBus,
    /// Gauge sampler; fires on the window grid (shard-count-invariant).
    recorder: MetricsRecorder,
}

impl Conductor {
    fn new(
        spec: ClusterSpec,
        plan: ShardPlan,
        policy: SchedulePolicy,
        quotas: TenantQuotas,
        workload: Workload,
        kills: Vec<(SimTime, u32)>,
        cfg: &ShardRunConfig,
        trace: TraceBus,
    ) -> Self {
        let mut head = Head::new();
        head.policy = policy;
        head.quotas = quotas;
        head.max_concurrent = cfg.max_concurrent;
        head.checkpoint_every_steps = spec.jacobi_checkpoint_steps.max(1);
        head.completed_retention = spec.completed_retention;
        for &(tenant, weight) in &spec.tenant_weights {
            head.ledger.set_weight(tenant, weight);
        }
        let mut ip_to_machine = BTreeMap::new();
        let mut off = BTreeSet::new();
        for m in 1..spec.machines {
            ip_to_machine.insert(machine_addr(m), m);
            off.insert(m);
        }
        let shards = plan.shards();
        let own =
            if trace.enabled() { TraceBus::buffering() } else { TraceBus::disabled() };
        let recorder = if trace.enabled() {
            MetricsRecorder::new(spec.sample_every)
        } else {
            MetricsRecorder::disabled()
        };
        Self {
            autoscaler: Autoscaler::new(spec.autoscale.clone()),
            max_slots: spec.max_advertisable_slots().max(1),
            deadline: SimTime::from_secs(cfg.deadline_secs),
            warmup_slots: cfg.warmup_slots,
            spec,
            plan,
            head,
            metrics: Metrics::default(),
            workload,
            kills: kills.into(),
            off,
            booting: BTreeSet::new(),
            ready: BTreeSet::new(),
            retiring: BTreeSet::new(),
            dead: BTreeSet::new(),
            generations: BTreeMap::new(),
            ip_to_machine,
            running: BTreeMap::new(),
            started_at: None,
            next_scale_at: SimTime::ZERO,
            next_id: 0,
            last_finish: SimTime::ZERO,
            finish_sent: false,
            counters_pending: shards,
            error: None,
            trace,
            own,
            recorder,
        }
    }

    /// Merge this window's trace material — the conductor's held-back
    /// emissions plus every shard batch from the inbox (already in rank
    /// order: equal merge keys keep sender order under the stable
    /// sort) — into the canonical `(t_ns, kind, entity, rank, seq)`
    /// order and write it through the sink. Concatenating rank 0's
    /// batch before the shard batches and stable-sorting by
    /// [`TraceEvent::sort_key`] *is* that order: rank and sequence are
    /// exactly the ties the stable sort preserves.
    fn merge_trace_window(&mut self, shard_batches: Vec<Vec<TraceEvent>>) {
        if !self.trace.enabled() {
            return;
        }
        let _t = crate::obs::profiling::scoped("trace_merge");
        let mut merged = self.own.take_buffered();
        for batch in shard_batches {
            merged.extend(batch);
        }
        if merged.is_empty() {
            return;
        }
        merged.sort_by_key(|ev| ev.sort_key());
        for ev in merged {
            self.trace.emit(ev);
        }
        self.trace.flush();
    }

    /// Gauge snapshot + sample emission on the window grid. Mirrors the
    /// live cluster's scheduler-tick sampling; pool counts stand in for
    /// the consul health census (a dead machine leaves `ready`
    /// immediately here — the shards simulate the TTL lag locally).
    fn sample_gauges(&mut self, start: SimTime) {
        if !self.own.enabled() || !self.recorder.due(start) {
            return;
        }
        let usage: Vec<(u64, f64)> = self
            .head
            .ledger
            .export_accounts()
            .iter()
            .map(|&(tenant, _, _)| (tenant, self.head.ledger.usage_at(tenant, start)))
            .collect();
        let ready = self.ready.len() as u64;
        let provisioning = self.booting.len() as u64;
        let g = GaugeSnapshot {
            queued_jobs: self.head.queue.len() as u64,
            queued_slots: self.head.queued_slots() as u64,
            running_jobs: self.head.running.len() as u64,
            reserved_slots: self.head.reserved_slots() as u64,
            total_slots: ready * self.spec.slots_per_node as u64,
            nodes_ready: ready,
            nodes_unhealthy: self.dead.len() as u64,
            nodes_provisioning: provisioning,
            scale_target: ready + provisioning,
            usage,
        };
        self.recorder.record(start, 0, &g, &mut self.own);
    }

    /// End-of-run trace finalize: flush whatever the last window left
    /// behind and make the sink durable. Returns `(written, dropped)`.
    fn finish_trace(&mut self) -> (u64, u64) {
        self.merge_trace_window(Vec::new());
        self.trace.finish();
        (self.trace.events_written(), self.trace.events_dropped())
    }

    fn rank_of_machine(&self, m: u32) -> usize {
        self.plan.shard_of(m) + 1
    }

    /// Rack index of machine `m`: explicit racks spread evenly, the
    /// legacy default keeps 16-machine chassis rows.
    fn rack_of_machine(&self, m: u32) -> usize {
        let compute = self.spec.machines.saturating_sub(1).max(1);
        if self.spec.racks > 0 {
            ((m - 1) as usize * self.spec.racks as usize) / compute as usize
        } else {
            m as usize / 16
        }
    }

    /// Re-render the hostfile from the ready pool (ascending machine
    /// id, like the name-sorted catalog the live cluster renders from).
    fn render_hostfile(&mut self, at: SimTime) {
        let slots = self.spec.slots_per_node;
        let text: String = self
            .ready
            .iter()
            .map(|&m| format!("{} slots={}\n", machine_addr(m), slots))
            .collect();
        if text != self.head.hostfile_text {
            self.head.hostfile_text = text;
            self.head.hostfile_updated_at = at;
            self.head.hostfile_renders += 1;
            self.metrics.inc("hostfile_renders");
        }
    }

    fn apply(&mut self, batch: Vec<(usize, ShardMsg)>) {
        for (_, msg) in batch {
            match msg {
                ShardMsg::Ready { at, machine } => {
                    if self.booting.remove(&machine) {
                        self.ready.insert(machine);
                        let rack = self.rack_of_machine(machine);
                        self.head.rack_of.insert(machine_addr(machine), rack);
                        self.metrics.inc("nodes_ready");
                        self.render_hostfile(at);
                    }
                }
                ShardMsg::Retired { at: _, machine } => {
                    if self.retiring.remove(&machine) {
                        self.off.insert(machine);
                        self.metrics.inc("nodes_retired");
                    }
                }
                ShardMsg::Done { at, id, attempt, residual_bits } => {
                    let fresh = matches!(
                        self.running.get(&id), Some(&(a, _)) if a == attempt
                    );
                    if !fresh {
                        self.metrics.inc("stale_completions");
                        continue;
                    }
                    self.running.remove(&id);
                    self.head.accrue_usage(at);
                    if let Some(mut rec) = self.head.finish(id) {
                        let started = match rec.state {
                            JobState::Running { started } => started,
                            _ => at,
                        };
                        rec.state = JobState::Done { started, finished: at };
                        self.head.first_failed_at.remove(&id);
                        let wait = started.saturating_sub(rec.queued_at).as_secs_f64();
                        self.metrics.observe("job_wait_secs", wait);
                        self.own.emit(TraceEvent::Complete {
                            at,
                            epoch: 0,
                            job: id,
                            attempt,
                            tenant: rec.spec.tenant,
                            started,
                        });
                        self.head.record_terminal(rec);
                        self.metrics.inc("jobs_completed");
                        self.metrics.add("jacobi_residual_checksum", residual_bits as u64);
                        self.last_finish = self.last_finish.max(at);
                    }
                }
                ShardMsg::Counters(totals) => {
                    for (name, v) in totals {
                        self.metrics.add(&name, v);
                    }
                    self.counters_pending = self.counters_pending.saturating_sub(1);
                }
                // shard-bound messages never reach the conductor
                _ => {}
            }
        }
    }

    fn submit(&mut self, name: String, ranks: u32, duration: SimTime, priority: i32, tenant: u64, now: SimTime) {
        let spec = JobSpec {
            id: JobId::new(self.next_id),
            name,
            ranks: ranks.min(self.max_slots),
            kind: JobKind::Synthetic { duration },
            priority,
            tenant,
        };
        self.next_id += 1;
        let (id, tenant, ranks, priority) = (spec.id, spec.tenant, spec.ranks, spec.priority);
        let submit_ev =
            TraceEvent::Submit { at: now, epoch: 0, job: id, tenant, ranks, priority };
        match self.head.submit(spec, now) {
            SubmitOutcome::Queued => {
                self.metrics.inc("jobs_submitted");
                self.own.emit(submit_ev);
            }
            SubmitOutcome::Deferred => {
                self.metrics.inc("jobs_deferred_quota");
                self.own.emit(submit_ev);
                self.own.emit(TraceEvent::QuotaDefer { at: now, epoch: 0, job: id, tenant });
            }
            SubmitOutcome::Rejected { spec, reason } => {
                self.metrics.inc("jobs_rejected_quota");
                if self.own.enabled() {
                    self.own.emit(TraceEvent::SubmitRejected {
                        at: now,
                        epoch: 0,
                        job: id,
                        tenant,
                        reason: reason.clone(),
                    });
                }
                self.head.record_terminal(JobRecord {
                    spec,
                    state: JobState::Failed { reason },
                    result: None,
                    queued_at: now,
                    attempt: 0,
                    planned_duration: None,
                });
            }
        }
    }

    fn pump_workload(&mut self, start: SimTime) {
        let Some(t0) = self.started_at else { return };
        let rel = start.saturating_sub(t0);
        // collect first, submit after: `submit` needs `&mut self` and
        // must not alias the workload borrow
        let mut due: Vec<(String, u32, SimTime, i32, u64)> = Vec::new();
        match &mut self.workload {
            Workload::Burst { jobs, submitted } => {
                if !*submitted {
                    *submitted = true;
                    for (i, j) in jobs.iter().enumerate() {
                        due.push((
                            format!("mix-{i}"),
                            j.ranks,
                            SimTime::from_secs(j.secs),
                            j.priority,
                            0,
                        ));
                    }
                }
            }
            Workload::Arrivals { gen, horizon, next, log } => loop {
                let ready = matches!(next, Some(a) if a.at <= rel && a.at < *horizon);
                if !ready {
                    break;
                }
                let a = next.take().expect("checked above");
                *next = Some(gen.next());
                due.push((
                    format!("t{}-j{}", a.tenant, log.len()),
                    a.ranks,
                    a.duration,
                    a.priority,
                    a.tenant,
                ));
                log.push(a);
            },
        }
        for (name, ranks, duration, priority, tenant) in due {
            self.submit(name, ranks, duration, priority, tenant, start);
        }
    }

    fn workload_exhausted(&self) -> bool {
        match &self.workload {
            Workload::Burst { submitted, .. } => *submitted,
            Workload::Arrivals { horizon, next, .. } => match next {
                Some(a) => a.at >= *horizon,
                None => false,
            },
        }
    }

    fn process_kills(&mut self, end: SimTime, out: &mut Outbox<ShardMsg>) {
        while let Some(&(t, m)) = self.kills.front() {
            if t >= end {
                break;
            }
            self.kills.pop_front();
            if !self.ready.remove(&m) {
                // never came up (still off/booting/already gone): the
                // booting case still dies so the pool can't wedge
                if self.booting.remove(&m) {
                    self.dead.insert(m);
                    self.metrics.inc("machines_crashed");
                    if self.own.enabled() {
                        self.own.emit(TraceEvent::FaultInjected {
                            at: t,
                            epoch: 0,
                            kind: "crash".to_string(),
                        });
                    }
                    out.send(self.rank_of_machine(m), ShardMsg::Kill { at: t, machine: m });
                }
                continue;
            }
            self.dead.insert(m);
            self.metrics.inc("machines_crashed");
            if self.own.enabled() {
                self.own.emit(TraceEvent::FaultInjected {
                    at: t,
                    epoch: 0,
                    kind: "crash".to_string(),
                });
            }
            self.render_hostfile(t);
            out.send(self.rank_of_machine(m), ShardMsg::Kill { at: t, machine: m });
            let addr = machine_addr(m);
            for id in self.head.jobs_on_addr(addr) {
                let prior = self.running.remove(&id);
                let tenant =
                    self.head.running.get(&id).map(|r| r.spec.tenant).unwrap_or(0);
                match self.head.handle_lost_job(id, t, "node crashed") {
                    LossOutcome::Requeued { attempt, wasted, .. } => {
                        self.metrics.inc("jobs_requeued");
                        self.own.emit(TraceEvent::Requeue {
                            at: t,
                            epoch: 0,
                            job: id,
                            attempt,
                            tenant,
                            wasted,
                        });
                    }
                    LossOutcome::Abandoned { .. } => {
                        self.metrics.inc("jobs_abandoned");
                        self.own.emit(TraceEvent::Abandon {
                            at: t,
                            epoch: 0,
                            job: id,
                            tenant,
                        });
                    }
                    LossOutcome::NotRunning => {}
                }
                if let Some((attempt, home)) = prior {
                    // the attempt may live on another (healthy) machine
                    // in the slice — cancel it wherever it computes
                    out.send(
                        self.rank_of_machine(home),
                        ShardMsg::CancelJob { at: t, id, attempt },
                    );
                }
            }
        }
    }

    fn dispatch(&mut self, start: SimTime, out: &mut Outbox<ShardMsg>) {
        let deferred_before = self.head.deferred_jobs();
        while let Some(started) = self.head.start_next(start) {
            let id = started.spec.id;
            self.metrics.inc("jobs_dispatched");
            if started.backfilled {
                self.metrics.inc("backfill_starts");
            }
            for pid in &started.preempted {
                self.metrics.inc("jobs_preempted");
                if self.own.enabled() {
                    // the preempted job is already checkpointed back in
                    // the queue: attribute it from there
                    let tenant = self
                        .head
                        .queue
                        .iter()
                        .find(|(s, _)| s.id == *pid)
                        .map(|(s, _)| s.tenant)
                        .unwrap_or(0);
                    self.own.emit(TraceEvent::Preempt {
                        at: start,
                        epoch: 0,
                        job: *pid,
                        tenant,
                    });
                }
                if let Some((attempt, home)) = self.running.remove(pid) {
                    out.send(
                        self.rank_of_machine(home),
                        ShardMsg::CancelJob { at: start, id: *pid, attempt },
                    );
                }
            }
            self.own.emit(TraceEvent::Dispatch {
                at: start,
                epoch: 0,
                job: id,
                attempt: started.attempt,
                tenant: started.spec.tenant,
                ranks: started.spec.ranks,
                backfilled: started.backfilled,
            });
            let duration = started.spec.estimated_duration();
            if let Some(rec) = self.head.running.get_mut(&id) {
                rec.planned_duration = Some(duration);
            }
            let hosts = &started.hostfile_slice.hosts;
            if hosts.is_empty() {
                // cannot happen (a dispatched job always gets slots);
                // treat as immediately lost rather than wedge the run
                self.head.handle_lost_job(id, start, "empty slice");
                continue;
            }
            let addr = hosts[id.raw() as usize % hosts.len()].addr;
            let machine = self.ip_to_machine.get(&addr).copied().unwrap_or(1);
            self.running.insert(id, (started.attempt, machine));
            self.metrics.observe("concurrent_jobs", self.head.running.len() as f64);
            out.send(
                self.rank_of_machine(machine),
                ShardMsg::Launch {
                    at: start,
                    id,
                    attempt: started.attempt,
                    machine,
                    ranks: started.spec.ranks,
                    duration,
                },
            );
        }
        // quota re-admissions happen inside `start_next` (the head owns
        // the pens): surface them as the net pen drain this round
        let readmitted = deferred_before.saturating_sub(self.head.deferred_jobs());
        if readmitted > 0 {
            self.own.emit(TraceEvent::QuotaAdmit {
                at: start,
                epoch: 0,
                admitted: readmitted as u64,
            });
        }
    }

    fn autoscale(&mut self, start: SimTime, out: &mut Outbox<ShardMsg>) {
        if !self.spec.autoscale.enabled || start < self.next_scale_at {
            return;
        }
        while self.next_scale_at <= start {
            self.next_scale_at = self.next_scale_at + self.spec.autoscale.interval;
        }
        let obs = Observation {
            now: start,
            ready_nodes: self.ready.len() as u32,
            unhealthy_nodes: self.dead.len() as u32,
            provisioning_nodes: self.booting.len() as u32,
            queued_slots: self.head.queued_slots(),
            queued_slots_weighted: self.head.weighted_queued_slots(),
            reserved_slots: self.head.reserved_slots(),
            slots_per_node: self.spec.slots_per_node,
        };
        let (action, reason) = self.autoscaler.decide_with_reason(obs);
        if let Some(name) = reason.counter_name() {
            self.metrics.inc(name);
        }
        match action {
            ScaleAction::None => {
                // a held decision is observable; a steady interval is
                // noise and stays out of the trace
                if matches!(reason, ScaleReason::CooldownHeld | ScaleReason::ShareCap) {
                    self.own.emit(TraceEvent::ScaleHold { at: start, epoch: 0, reason });
                }
            }
            ScaleAction::Up(n) => {
                self.own.emit(TraceEvent::ScaleUp { at: start, epoch: 0, nodes: n, reason });
                let picks: Vec<u32> = self.off.iter().copied().take(n as usize).collect();
                if !picks.is_empty() {
                    self.head.note_scale_up(start);
                    self.metrics.inc("scale_ups");
                    self.metrics.add("scale_up_nodes", picks.len() as u64);
                }
                for m in picks {
                    self.off.remove(&m);
                    self.booting.insert(m);
                    let generation = self.generations.entry(m).or_insert(0);
                    *generation += 1;
                    let generation = *generation;
                    out.send(
                        self.rank_of_machine(m),
                        ShardMsg::Boot { at: start, machine: m, generation },
                    );
                }
            }
            ScaleAction::Down(n) => {
                self.own.emit(TraceEvent::ScaleDown { at: start, epoch: 0, nodes: n, reason });
                let held = self.head.reserved_per_host();
                let picks: Vec<u32> = self
                    .ready
                    .iter()
                    .rev()
                    .copied()
                    .filter(|&m| held.get(&machine_addr(m)).copied().unwrap_or(0) == 0)
                    .take(n as usize)
                    .collect();
                if !picks.is_empty() {
                    self.head.note_scale_down(start);
                    self.metrics.inc("scale_downs");
                    self.metrics.add("scale_down_nodes", picks.len() as u64);
                }
                for m in picks {
                    self.ready.remove(&m);
                    self.retiring.insert(m);
                    out.send(
                        self.rank_of_machine(m),
                        ShardMsg::Retire { at: start, machine: m },
                    );
                }
                self.render_hostfile(start);
            }
        }
    }

    fn drained(&self) -> bool {
        self.started_at.is_some()
            && self.workload_exhausted()
            && self.head.queue.is_empty()
            && self.head.deferred_jobs() == 0
            && self.running.is_empty()
            && self.booting.is_empty()
            && self.retiring.is_empty()
    }

    fn send_finish(&mut self, out: &mut Outbox<ShardMsg>) {
        if self.finish_sent {
            return;
        }
        self.finish_sent = true;
        for s in 0..self.plan.shards() {
            out.send(s + 1, ShardMsg::Finish);
        }
    }
}

impl Partitioned for Conductor {
    type Msg = ShardMsg;

    fn window(
        &mut self,
        start: SimTime,
        end: SimTime,
        mut incoming: Vec<(usize, ShardMsg)>,
        out: &mut Outbox<ShardMsg>,
    ) -> bool {
        sort_batch(&mut incoming);
        // peel this window's shard trace batches off the inbox *before*
        // applying — apply() emits new events that belong to the *next*
        // merge — then write the previous window's canonical merge
        let mut shard_batches: Vec<Vec<TraceEvent>> = Vec::new();
        incoming.retain_mut(|(_, m)| {
            if let ShardMsg::Trace(evs) = m {
                shard_batches.push(std::mem::take(evs));
                false
            } else {
                true
            }
        });
        self.merge_trace_window(shard_batches);
        self.apply(incoming);
        if self.finish_sent {
            // drain phase: only waiting for shard counter reports
            return self.counters_pending == 0;
        }
        // deadline / warmup-timeout watchdog
        if self.error.is_none() {
            match self.started_at {
                None if start > WARMUP_DEADLINE => {
                    self.error = Some(format!(
                        "cluster never advertised {} slots within {}s",
                        self.warmup_slots,
                        WARMUP_DEADLINE.as_secs_f64()
                    ));
                }
                Some(t0) if start.saturating_sub(t0) > self.deadline => {
                    self.error = Some(format!(
                        "sharded trace never drained within {}s (queue={}, running={})",
                        self.deadline.as_secs_f64(),
                        self.head.queue.len(),
                        self.running.len()
                    ));
                }
                _ => {}
            }
            if self.error.is_some() {
                self.send_finish(out);
                return false;
            }
        }
        self.process_kills(end, out);
        if self.started_at.is_none() && self.head.slots_available() >= self.warmup_slots {
            self.started_at = Some(start);
        }
        self.pump_workload(start);
        self.head.accrue_usage(start);
        self.dispatch(start, out);
        self.autoscale(start, out);
        self.sample_gauges(start);
        if self.drained() {
            self.send_finish(out);
        }
        false
    }
}

// ---------------------------------------------------------------------
// Drivers
// ---------------------------------------------------------------------

fn run_sharded(
    spec: ClusterSpec,
    policy: SchedulePolicy,
    quotas: TenantQuotas,
    workload: Workload,
    kills: Vec<(SimTime, u32)>,
    cfg: &ShardRunConfig,
) -> Result<ShardOutcome> {
    if spec.machines < 2 {
        bail!("a sharded run needs at least one compute machine");
    }
    let plan = ShardPlan::split(1, spec.machines, cfg.shards.max(1));
    let shards = plan.shards();
    let window = cfg.window;
    if window == SimTime::ZERO {
        bail!("window must be positive");
    }
    // an unopenable trace path is a configuration error (mirrors the
    // live cluster); mid-run write failures degrade to counted drops
    let trace = match &spec.trace_path {
        Some(path) => {
            let sink = FileSink::create(path).map_err(|e| anyhow::anyhow!(e))?;
            TraceBus::with_sink(Box::new(sink))
        }
        None => TraceBus::disabled(),
    };
    let traced = trace.enabled();
    let conductor = Conductor::new(
        spec.clone(),
        plan.clone(),
        policy,
        quotas,
        workload,
        kills,
        cfg,
        trace,
    );
    let mut parts: Vec<ClusterPart> = vec![ClusterPart::Conductor(Box::new(conductor))];
    for s in 0..shards {
        parts.push(ClusterPart::Shard(Box::new(ShardSim::new(
            plan.clone(),
            s + 1,
            &spec,
            window,
            cfg.compute,
            traced,
        ))));
    }
    // seatbelt: warmup + trace + drain handshake, in windows, plus slack
    let max_windows =
        (WARMUP_DEADLINE.as_nanos() + SimTime::from_secs(cfg.deadline_secs).as_nanos())
            / window.as_nanos().max(1)
            + 64;
    let (done, windows) = run_lockstep(parts, window, max_windows);
    let mut conductor = match done.into_iter().next() {
        Some(ClusterPart::Conductor(c)) => *c,
        _ => bail!("lock-step run lost its conductor"),
    };
    // finalize the trace before any early exit so even a failed run
    // leaves a flushed (torn but parseable) trace behind
    let (trace_events_written, trace_events_dropped) = conductor.finish_trace();
    if let Some(err) = conductor.error {
        bail!(err);
    }
    if !conductor.finish_sent || conductor.counters_pending != 0 {
        bail!("sharded run hit the window seatbelt before draining");
    }
    let (submitted, arrivals_fingerprint) = match &conductor.workload {
        Workload::Burst { .. } => (conductor.next_id as usize, 0),
        Workload::Arrivals { log, .. } => (log.len(), stream_fingerprint(log)),
    };
    let t0 = conductor.started_at.unwrap_or(SimTime::ZERO);
    Ok(ShardOutcome {
        shards,
        windows,
        jobs_submitted: submitted,
        jobs_completed: conductor.metrics.counter("jobs_completed"),
        makespan_secs: conductor.last_finish.saturating_sub(t0).as_secs_f64(),
        events: conductor.metrics.counter("shard_events"),
        arrivals_fingerprint,
        fingerprint: conductor.metrics.counters_snapshot(),
        trace_events_written,
        trace_events_dropped,
    })
}

/// Sharded counterpart of [`run_policy_trace`]
/// (crate::cluster::mix::run_policy_trace): one burst of `jobs` under
/// `policy`, partitioned across `cfg.shards` threads.
pub fn run_sharded_mix(
    spec: ClusterSpec,
    jobs: &[JobReq],
    policy: SchedulePolicy,
    cfg: &ShardRunConfig,
) -> Result<ShardOutcome> {
    run_sharded(
        spec,
        policy,
        TenantQuotas::default(),
        Workload::Burst { jobs: jobs.to_vec(), submitted: false },
        Vec::new(),
        cfg,
    )
}

/// Sharded counterpart of [`run_tenant_trace`]
/// (crate::cluster::mix::run_tenant_trace): an open-loop multi-tenant
/// arrival stream for `duration_secs` after warmup, then drain.
pub fn run_sharded_tenants(
    spec: ClusterSpec,
    pop: PopulationSpec,
    policy: SchedulePolicy,
    quotas: TenantQuotas,
    duration_secs: u64,
    cfg: &ShardRunConfig,
) -> Result<ShardOutcome> {
    let mut gen = ArrivalGen::new(pop);
    let next = Some(gen.next());
    run_sharded(
        spec,
        policy,
        quotas,
        Workload::Arrivals {
            gen,
            horizon: SimTime::from_secs(duration_secs),
            next,
            log: Vec::new(),
        },
        Vec::new(),
        cfg,
    )
}

/// Sharded chaos driver: the burst workload of [`run_sharded_mix`] plus
/// a seeded per-machine crash schedule (one exponential draw per
/// machine at mean `mtbf_secs`, after a grace period). Crashed
/// machines' jobs are requeued or abandoned by the head exactly like
/// the live fault pipeline, and the autoscaler boots replacements.
pub fn run_sharded_chaos(
    spec: ClusterSpec,
    jobs: &[JobReq],
    policy: SchedulePolicy,
    mtbf_secs: f64,
    cfg: &ShardRunConfig,
) -> Result<ShardOutcome> {
    let mut kills: Vec<(SimTime, u32)> = Vec::new();
    for m in 1..spec.machines {
        let mut rng = Rng::new(
            spec.seed ^ 0xC4A0_5C4A ^ (m as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let t = CHAOS_GRACE_SECS + rng.gen_exp(mtbf_secs.max(1.0));
        if t < cfg.deadline_secs as f64 {
            kills.push((SimTime::from_secs_f64(t), m));
        }
    }
    kills.sort_by_key(|&(t, m)| (t, m));
    run_sharded(
        spec,
        policy,
        TenantQuotas::default(),
        Workload::Burst { jobs: jobs.to_vec(), submitted: false },
        kills,
        cfg,
    )
}

/// The two participant roles behind one [`Partitioned`] impl, so the
/// lock-step runner sees a homogeneous `Vec`.
enum ClusterPart {
    Conductor(Box<Conductor>),
    Shard(Box<ShardSim>),
}

impl Partitioned for ClusterPart {
    type Msg = ShardMsg;

    fn window(
        &mut self,
        start: SimTime,
        end: SimTime,
        incoming: Vec<(usize, ShardMsg)>,
        out: &mut Outbox<ShardMsg>,
    ) -> bool {
        match self {
            ClusterPart::Conductor(c) => c.window(start, end, incoming, out),
            ClusterPart::Shard(s) => s.window(start, end, incoming, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::mix::{mix_spec, prioritized_trace};

    fn small_spec() -> ClusterSpec {
        let mut spec = mix_spec(SimTime::from_secs(5));
        spec.seed = 7;
        spec
    }

    fn cfg(shards: usize) -> ShardRunConfig {
        ShardRunConfig { shards, warmup_slots: 24, ..ShardRunConfig::default() }
    }

    #[test]
    fn sharded_mix_drains_and_is_shard_count_invariant() {
        let jobs = prioritized_trace(24, 20);
        let base = run_sharded_mix(small_spec(), &jobs, SchedulePolicy::default(), &cfg(1))
            .expect("1 shard");
        assert_eq!(base.jobs_submitted, 20);
        assert_eq!(base.jobs_completed, 20);
        assert!(base.makespan_secs > 0.0);
        assert!(base.events > 0);
        for shards in [2usize, 4] {
            let o = run_sharded_mix(small_spec(), &jobs, SchedulePolicy::default(), &cfg(shards))
                .expect("sharded");
            assert_eq!(o.shards, shards);
            assert_eq!(
                o.fingerprint, base.fingerprint,
                "{shards}-shard fingerprint must match the 1-shard run"
            );
            assert_eq!(o.windows, base.windows, "same drain window at {shards} shards");
        }
    }

    #[test]
    fn merge_key_orders_kills_before_same_instant_launches() {
        let at = SimTime::from_secs(3);
        let kill = ShardMsg::Kill { at, machine: 2 };
        let launch = ShardMsg::Launch {
            at,
            id: JobId::new(0),
            attempt: 0,
            machine: 2,
            ranks: 4,
            duration: SimTime::from_secs(1),
        };
        assert!(kill.merge_key() < launch.merge_key());
        let mut batch = vec![(1usize, launch), (1usize, kill)];
        sort_batch(&mut batch);
        assert!(matches!(batch[0].1, ShardMsg::Kill { .. }));
    }

    #[test]
    fn gossip_peer_never_picks_self_and_is_pure() {
        let core = ShardCore {
            plan: ShardPlan::split(1, 8, 2),
            rank: 1,
            seed: 1,
            total_machines: 8,
            boot_time: SimTime::from_secs(1),
            window: SimTime::from_secs(1),
            compute: ComputeProfile::default(),
            nodes: BTreeMap::new(),
            jobs: BTreeMap::new(),
            counters: BTreeMap::new(),
            outgoing: Vec::new(),
            draining: false,
            trace: TraceBus::disabled(),
        };
        for m in 1..8u32 {
            for seq in 0..50u64 {
                let p = core.gossip_peer(m, seq).expect("peers exist");
                assert_ne!(p, m, "machine {m} gossiped to itself at seq {seq}");
                assert!((1..8).contains(&p), "peer {p} out of range");
                assert_eq!(core.gossip_peer(m, seq), Some(p), "must be pure");
            }
        }
    }

    #[test]
    fn machine_addr_is_injective_over_the_id_space() {
        let mut seen = BTreeSet::new();
        for m in 1..2048u32 {
            assert!(seen.insert(machine_addr(m)), "address collision at {m}");
        }
    }
}
