//! Minimal metrics registry: counters, gauges and value histograms —
//! plus the multi-tenant aggregations ([`TenantBreakdown`],
//! [`jain_index`]) the tenancy layer reports fairness with.

use std::cell::RefCell;
use std::collections::BTreeMap;

/// A recorded distribution.
///
/// Percentile queries sort lazily: the sorted view is computed on first
/// use and cached until the next `record()`, so `render()` (which asks
/// for several percentiles per histogram) is O(n log n) once instead of
/// O(k·n log n).
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    values: Vec<f64>,
    sorted_cache: RefCell<Option<Vec<f64>>>,
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        self.values.push(v);
        *self.sorted_cache.get_mut() = None;
    }
    pub fn count(&self) -> usize {
        self.values.len()
    }
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            0.0
        } else {
            self.values.iter().sum::<f64>() / self.values.len() as f64
        }
    }
    /// Percentile in [0, 100]; 0.0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let mut cache = self.sorted_cache.borrow_mut();
        let sorted = cache.get_or_insert_with(|| {
            let mut s = self.values.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s
        });
        let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }
    /// Sum of all recorded values (0.0 when empty) — exact, unlike
    /// reconstructing it as `mean() * count()`.
    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }
    /// Largest recorded value; 0.0 (not `-inf`) on an empty histogram.
    pub fn max(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Jain's fairness index over a set of per-entity figures:
/// `(Σx)² / (n·Σx²)`. 1.0 means perfectly equal; `1/n` means one
/// entity has everything. Empty or all-zero inputs read 1.0 (no
/// evidence of unfairness).
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let sum_sq: f64 = xs.iter().map(|x| x * x).sum();
    if sum_sq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (n * sum_sq)
}

/// A histogram per tenant, in stable (tenant-id) order — the shape the
/// tenancy layer reports per-tenant wait and slowdown distributions
/// with, and the input to its Jain fairness figures. Kept outside the
/// flat [`Metrics`] registry: a 100k-tenant population must not mint
/// 100k metric names.
#[derive(Debug, Clone, Default)]
pub struct TenantBreakdown {
    per: BTreeMap<u64, Histogram>,
}

impl TenantBreakdown {
    pub fn observe(&mut self, tenant: u64, v: f64) {
        self.per.entry(tenant).or_default().record(v);
    }
    /// Tenants with at least one observation.
    pub fn tenants(&self) -> usize {
        self.per.len()
    }
    pub fn is_empty(&self) -> bool {
        self.per.is_empty()
    }
    pub fn histogram(&self, tenant: u64) -> Option<&Histogram> {
        self.per.get(&tenant)
    }
    /// Per-tenant means, in tenant-id order.
    pub fn means(&self) -> Vec<f64> {
        self.per.values().map(|h| h.mean()).collect()
    }
    /// Jain's fairness index over the per-tenant means.
    pub fn fairness(&self) -> f64 {
        jain_index(&self.means())
    }
}

/// The registry.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }
    pub fn add(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().record(v);
    }
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Stable snapshot of every counter. Two runs with the same seed
    /// must produce identical snapshots — the chaos benches use this as
    /// their determinism fingerprint.
    pub fn counters_snapshot(&self) -> BTreeMap<String, u64> {
        self.counters.clone()
    }

    /// Text dump (for the CLI's `metrics` subcommand).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("counter {k} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("gauge {k} {v}\n"));
        }
        for (k, h) in &self.histograms {
            out.push_str(&format!(
                "histogram {k} count={} mean={:.3} p50={:.3} p99={:.3} max={:.3}\n",
                h.count(),
                h.mean(),
                h.percentile(50.0),
                h.percentile(99.0),
                h.max()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = Metrics::new();
        m.inc("pulls");
        m.add("pulls", 2);
        assert_eq!(m.counter("pulls"), 3);
        assert_eq!(m.counter("missing"), 0);
        m.set_gauge("nodes", 3.0);
        assert_eq!(m.gauge("nodes"), 3.0);
    }

    #[test]
    fn histogram_stats() {
        let mut m = Metrics::new();
        for v in 1..=100 {
            m.observe("lat", v as f64);
        }
        let h = m.histogram("lat").unwrap();
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        assert_eq!(h.sum(), 5050.0);
        assert_eq!(Histogram::default().sum(), 0.0);
        assert!((49.0..=51.0).contains(&h.percentile(50.0)));
        assert_eq!(h.percentile(99.0), 99.0);
        assert_eq!(h.max(), 100.0);
    }

    #[test]
    fn empty_histogram_is_all_zeros_not_neg_inf() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.max(), 0.0, "empty max must be 0.0, not -inf");
        assert!(h.max().is_finite());
    }

    #[test]
    fn max_of_all_negative_values_is_the_true_max() {
        let mut h = Histogram::default();
        h.record(-7.0);
        h.record(-3.0);
        assert_eq!(h.max(), -3.0, "must not clamp negative maxima to 0");
    }

    #[test]
    fn percentile_cache_invalidates_on_record() {
        let mut h = Histogram::default();
        h.record(1.0);
        assert_eq!(h.percentile(100.0), 1.0); // populates the cache
        h.record(5.0);
        assert_eq!(h.percentile(100.0), 5.0, "stale sorted cache");
        assert_eq!(h.percentile(0.0), 1.0);
        // clones carry a consistent view too
        let c = h.clone();
        assert_eq!(c.percentile(100.0), 5.0);
    }

    #[test]
    fn observe_after_render_invalidates_the_registry_cache_too() {
        // render() warms every histogram's sorted cache through &self;
        // a later observe() on the registry must still invalidate it
        let mut m = Metrics::new();
        m.observe("lat", 1.0);
        assert!(m.render().contains("p99=1.000"));
        m.observe("lat", 9.0);
        assert_eq!(m.histogram("lat").unwrap().percentile(99.0), 9.0);
        assert!(m.render().contains("p99=9.000"), "{}", m.render());
        // same rule for the per-tenant breakdowns
        let mut b = TenantBreakdown::default();
        b.observe(7, 1.0);
        assert_eq!(b.histogram(7).unwrap().percentile(99.0), 1.0);
        b.observe(7, 4.0);
        assert_eq!(b.histogram(7).unwrap().percentile(99.0), 4.0);
    }

    #[test]
    fn counters_snapshot_is_stable_and_complete() {
        let mut m = Metrics::new();
        m.inc("b");
        m.add("a", 2);
        let snap = m.counters_snapshot();
        assert_eq!(snap.get("a"), Some(&2));
        assert_eq!(snap.get("b"), Some(&1));
        assert_eq!(m.counters_snapshot(), snap);
    }

    #[test]
    fn jain_index_spans_equal_to_concentrated() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
        // one entity hogs everything: index -> 1/n
        let concentrated = jain_index(&[12.0, 0.0, 0.0, 0.0]);
        assert!((concentrated - 0.25).abs() < 1e-12, "{concentrated}");
        // mild skew sits strictly between
        let mild = jain_index(&[1.0, 2.0, 1.0, 2.0]);
        assert!(mild > 0.25 && mild < 1.0, "{mild}");
    }

    #[test]
    fn tenant_breakdown_aggregates_per_tenant() {
        let mut b = TenantBreakdown::default();
        assert!(b.is_empty());
        assert_eq!(b.fairness(), 1.0, "no tenants = no unfairness");
        b.observe(1, 10.0);
        b.observe(1, 20.0);
        b.observe(2, 15.0);
        assert_eq!(b.tenants(), 2);
        assert_eq!(b.histogram(1).unwrap().count(), 2);
        assert_eq!(b.means(), vec![15.0, 15.0]);
        assert!((b.fairness() - 1.0).abs() < 1e-12, "equal means are fair");
        b.observe(3, 150.0);
        assert!(b.fairness() < 0.7, "an outlier tenant must drop the index");
    }

    #[test]
    fn render_contains_everything_even_when_a_histogram_is_empty() {
        let mut m = Metrics::new();
        m.inc("a");
        m.set_gauge("b", 2.0);
        m.observe("c", 1.0);
        let s = m.render();
        assert!(s.contains("counter a 1"));
        assert!(s.contains("gauge b 2"));
        assert!(s.contains("histogram c count=1"));
        assert!(!s.contains("inf"), "render must never print infinities: {s}");
    }
}
