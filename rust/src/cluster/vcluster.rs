//! VirtualCluster: the paper's system, end to end.
//!
//! Composes the physical plant, per-machine container engines, the
//! registry, the consul deployment and the head node, and drives the
//! whole control plane on the discrete-event engine (`sim::Engine`).
//! The provisioning pipeline for a node is exactly the paper's (§IV):
//!
//! ```text
//! power on ──boot──▶ dockerd up ──pull+extract──▶ container running
//!        ──agent join + register──▶ in catalog ──template──▶ hostfile
//! ```
//!
//! MPI jobs run with *real* PJRT compute on rank threads; their duration
//! (virtual comm + real compute) is charged back into virtual time.

use crate::cluster::autoscaler::{Autoscaler, Observation, ScaleAction, ScaleReason};
use crate::cluster::head::{
    Head, JobKind, JobRecord, JobSpec, JobState, LossOutcome, StartedJob, SubmitOutcome,
};
use crate::cluster::metrics::Metrics;
use crate::config::ClusterSpec;
use crate::consul::catalog::{Catalog, ServiceEntry};
use crate::consul::health::CheckStatus;
use crate::consul::ConsulCluster;
use crate::dockyard::engine::{Engine as DockerEngine, RunSpec};
use crate::dockyard::{Dockerfile, ImageStore, Registry};
use crate::hw::rack::Plant;
use crate::hw::PowerState;
use crate::mpi::hostfile::Hostfile;
use crate::mpi::launcher::LaunchPlan;
use crate::obs::{FileSink, GaugeSnapshot, MetricsRecorder, TraceBus, TraceEvent, TraceSink};
use crate::runtime::Runtime;
use crate::sim::{Engine, SimEvent, SimTime};
use crate::util::ids::{AgentId, ContainerId, JobId, MachineId};
use crate::vnet::addr::Ipv4;
use crate::vnet::fabric::Fabric;
use crate::workloads::jacobi::{run_jacobi, JacobiSpec};
use anyhow::{anyhow, Result};
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

/// Provisioning state of one machine slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    Off,
    Booting,
    StartingEngine,
    Deploying,
    Ready,
}

impl NodeState {
    pub fn is_provisioning(&self) -> bool {
        matches!(self, NodeState::Booting | NodeState::StartingEngine | NodeState::Deploying)
    }
}

/// Everything the event handlers mutate.
pub struct ClusterState {
    pub spec: ClusterSpec,
    pub plant: Plant,
    pub engines: Vec<DockerEngine>,
    pub registry: Registry,
    pub consul: ConsulCluster,
    pub fabric: Arc<Mutex<Fabric>>,
    pub head: Head,
    pub autoscaler: Autoscaler,
    pub metrics: Metrics,
    pub node_states: Vec<NodeState>,
    /// machine -> its compute (or head) container id.
    pub containers: Vec<Option<ContainerId>>,
    /// container ip -> container (for mpirun).
    pub ip_to_container: HashMap<Ipv4, ContainerId>,
    next_container: u32,
    next_job: u32,
    /// When each machine's provisioning began (for Fig. 6 timing).
    provision_started: Vec<Option<SimTime>>,
    /// Health-check TTL.
    pub health_ttl: SimTime,
    /// Artifacts dir for Jacobi jobs.
    pub artifacts: std::path::PathBuf,
    /// Chaos: per-machine "heartbeats muted until" marks (node hang —
    /// the machine is alive, its agent just stops refreshing).
    pub hang_until: Vec<SimTime>,
    /// Chaos: per-machine budget of deploy attempts that must fail.
    pub deploy_faults: Vec<u32>,
    /// Chaos: machines on the minority side of the active network
    /// partition. Keyed by machine (not agent) so a machine that is down
    /// at injection, or re-provisioned mid-window, is still cut off.
    pub partitioned_machines: Vec<bool>,
    /// Chaos: machines whose agents can currently reach only a subset
    /// of the consul servers (partial partition). Keyed by machine so a
    /// container re-provisioned mid-window inherits the restriction.
    pub partial_machines: Vec<bool>,
    /// The server subset reachable from partially partitioned machines.
    pub partial_servers: Vec<u32>,
    /// Head-availability runtime state (WAL cursor, lease, epoch).
    /// Inert when `spec.ha.enabled` is false.
    pub ha: crate::ha::HaState,
    /// Structured trace bus: lifecycle events buffer here and drain to
    /// the configured sink at engine-event boundaries (the same cadence
    /// as WAL batching). Inert — a single branch per emit — unless
    /// `spec.trace_path` (or [`VirtualCluster::set_trace_sink`])
    /// installed a sink. Its drop/write counters live on the bus, never
    /// in [`Metrics`], so traced and untraced runs fingerprint
    /// identically.
    pub trace: TraceBus,
    /// Gauge time-series sampler: emits `sample` trace events from the
    /// scheduler tick at the `spec.sample_every` cadence. Reads state,
    /// writes only into the trace bus — fingerprint-neutral like the
    /// bus itself.
    pub recorder: MetricsRecorder,
}

/// The facade: state + event engine.
pub struct VirtualCluster {
    pub state: ClusterState,
    engine: Engine<ClusterState, ClusterEvent>,
}

/// Every event the cluster's control plane schedules, as plain data:
/// the calendar-queue engine stores these inline (no per-event heap
/// allocation, unlike the boxed closures they replaced). Variants fire
/// the exact same handler functions the closures called, in the same
/// `(time, seq)` order, so every determinism fingerprint is unchanged.
#[derive(Debug, Clone)]
pub enum ClusterEvent {
    /// consul-template's periodic hostfile render.
    TemplatePoll,
    /// The head's 1 s scheduling tick (reap lost jobs, dispatch).
    SchedulerTick,
    /// The autoscaler's periodic observe/decide cycle.
    AutoscaleTick,
    /// Machine BIOS+kernel boot finished.
    BootDone(MachineId),
    /// dockerd is up on the machine.
    EngineUp(MachineId),
    /// The node container finished pull+start and has its address.
    ContainerUp { machine: MachineId, container: ContainerId, ip: Ipv4 },
    /// A node agent's TTL refresh.
    Heartbeat(MachineId),
    /// A running attempt's predicted completion (epoch-fenced).
    JobDone { id: JobId, attempt: u32, epoch: u64 },
    /// One expanded fault-plan entry firing through the injector.
    Fault(crate::faults::FaultKind),
    /// Heal timer for the gossip partition with this epoch token.
    HealPartition(u64),
    /// Heal timer for the partial partition with this epoch token.
    HealPartialPartition(u64),
    /// The HA standby's lease-watch poll.
    StandbyMonitor,
    /// One poll after a multi-standby CAS claim round: read the winner.
    ConcludeClaim,
}

impl SimEvent<ClusterState> for ClusterEvent {
    fn fire(self, st: &mut ClusterState, eng: &mut Ev) {
        match self {
            ClusterEvent::TemplatePoll => VirtualCluster::template_poll_event(st, eng),
            ClusterEvent::SchedulerTick => VirtualCluster::scheduler_event(st, eng),
            ClusterEvent::AutoscaleTick => VirtualCluster::autoscale_event(st, eng),
            ClusterEvent::BootDone(m) => VirtualCluster::boot_done(st, eng, m),
            ClusterEvent::EngineUp(m) => VirtualCluster::engine_up(st, eng, m),
            ClusterEvent::ContainerUp { machine, container, ip } => {
                VirtualCluster::container_up(st, eng, machine, container, ip)
            }
            ClusterEvent::Heartbeat(m) => {
                VirtualCluster::heartbeat(st, eng, m, m.raw() as usize)
            }
            ClusterEvent::JobDone { id, attempt, epoch } => {
                VirtualCluster::job_done(st, eng, id, attempt, epoch)
            }
            ClusterEvent::Fault(kind) => crate::faults::injector::apply(st, eng, &kind),
            ClusterEvent::HealPartition(epoch) => {
                VirtualCluster::chaos_heal_partition(st, epoch)
            }
            ClusterEvent::HealPartialPartition(epoch) => {
                VirtualCluster::chaos_heal_partial_partition(st, epoch)
            }
            ClusterEvent::StandbyMonitor => crate::ha::failover::standby_monitor(st, eng),
            ClusterEvent::ConcludeClaim => crate::ha::failover::conclude_claim(st, eng),
        }
    }
}

type Ev = Engine<ClusterState, ClusterEvent>;

impl VirtualCluster {
    pub fn new(spec: ClusterSpec) -> Result<Self> {
        if spec.machines == 0 {
            return Err(anyhow!("cluster spec needs at least 1 machine (the head), got 0"));
        }
        // racks = 0 keeps the legacy 16-machine chassis rows; an
        // explicit count spreads the machines evenly so topology-aware
        // placement has real rack boundaries to pack against
        let per_rack = match spec.racks {
            0 => 16,
            r => (spec.machines as usize).div_ceil(r as usize).max(1),
        };
        let plant = Plant::uniform(spec.machines as usize, spec.machine_spec.clone(), per_rack);
        let fabric = Arc::new(Mutex::new(Fabric::from_plant(&plant, spec.bridge)));

        // Build the image the paper's Dockerfile describes and push it.
        let mut registry = Registry::docker_hub();
        let df = Dockerfile::parse(&spec.dockerfile)
            .map_err(|e| anyhow!("dockerfile: {e}"))?;
        let mut builder = ImageStore::with_base_images();
        let image = builder
            .build(&df, spec.image.clone())
            .map_err(|e| anyhow!("image build: {e}"))?;
        registry.push(image);

        let engines = (0..spec.machines)
            .map(|i| DockerEngine::new(MachineId::new(i), spec.bridge))
            .collect();

        let mut consul = ConsulCluster::new(spec.consul_servers, spec.seed);
        // control-plane RPC delay from the fabric's machine-level model
        {
            let f = fabric.lock().unwrap_or_else(|e| e.into_inner());
            consul.rpc_delay = f.control_msg_time(MachineId::new(0), MachineId::new(1.min(spec.machines - 1)), 256);
        }

        let n = spec.machines as usize;
        let sample_every = spec.sample_every;
        let mut state = ClusterState {
            autoscaler: Autoscaler::new(spec.autoscale.clone()),
            ha: crate::ha::HaState::new(spec.ha.clone()),
            spec,
            plant,
            engines,
            registry,
            consul,
            fabric,
            head: Head::new(),
            metrics: Metrics::new(),
            node_states: vec![NodeState::Off; n],
            containers: vec![None; n],
            ip_to_container: HashMap::new(),
            next_container: 0,
            next_job: 0,
            provision_started: vec![None; n],
            health_ttl: SimTime::from_secs(30),
            artifacts: Runtime::default_dir(),
            hang_until: vec![SimTime::ZERO; n],
            deploy_faults: vec![0; n],
            partitioned_machines: vec![false; n],
            partial_machines: vec![false; n],
            partial_servers: Vec::new(),
            trace: TraceBus::disabled(),
            recorder: MetricsRecorder::new(sample_every),
        };
        if let Some(path) = state.spec.trace_path.clone() {
            // an unopenable trace path is a configuration error reported
            // up front; only mid-run sink failures degrade to drops
            let sink = FileSink::create(&path).map_err(|e| anyhow!(e))?;
            state.trace = TraceBus::with_sink(Box::new(sink));
        }
        let ckpt = state.spec.jacobi_checkpoint_steps.max(1);
        state.head.checkpoint_every_steps = ckpt;
        state.head.completed_retention = state.spec.completed_retention;
        if state.ha.config.enabled {
            state.head.enable_journal();
        }
        for &(tenant, weight) in &state.spec.tenant_weights {
            state.head.ledger.set_weight(tenant, weight);
        }
        Ok(Self { state, engine: Engine::new() })
    }

    pub fn now(&self) -> SimTime {
        self.engine.now()
    }

    /// Bring the cluster up: head on machine 0, plus the autoscaler's
    /// minimum node count on the following machines. Also starts the
    /// periodic control loops (template poll, scheduler, autoscaler).
    pub fn start(&mut self) {
        let min = self.state.spec.autoscale.min_nodes.min(self.state.spec.machines - 1);
        Self::provision_machine(&mut self.state, &mut self.engine, MachineId::new(0));
        for m in 1..=min {
            Self::provision_machine(&mut self.state, &mut self.engine, MachineId::new(m));
        }
        // control loops
        let poll = self.state.head.poll_interval;
        self.engine.schedule_after(poll, ClusterEvent::TemplatePoll);
        self.engine
            .schedule_after(SimTime::from_secs(1), ClusterEvent::SchedulerTick);
        let interval = self.state.spec.autoscale.interval;
        self.engine.schedule_after(interval, ClusterEvent::AutoscaleTick);
        if self.state.ha.config.enabled {
            // leadership lease + leader record + the standby's monitor
            crate::ha::failover::install(&mut self.state, &mut self.engine);
        }
    }

    /// Advance virtual time by `dt`, firing all due control-plane events.
    pub fn advance(&mut self, dt: SimTime) {
        let until = self.engine.now() + dt;
        self.engine.run_until(&mut self.state, until);
        self.state.consul.advance(until);
    }

    /// Advance until `pred` holds or `timeout` elapses. True on success.
    pub fn advance_until(
        &mut self,
        timeout: SimTime,
        mut pred: impl FnMut(&ClusterState) -> bool,
    ) -> bool {
        let deadline = self.engine.now() + timeout;
        while self.engine.now() < deadline {
            if pred(&self.state) {
                return true;
            }
            let step = SimTime::from_millis(100).min(deadline.saturating_sub(self.engine.now()));
            if step == SimTime::ZERO {
                break;
            }
            self.advance(step);
        }
        pred(&self.state)
    }

    // ---------- provisioning pipeline ----------

    fn provision_machine(st: &mut ClusterState, eng: &mut Ev, m: MachineId) {
        let idx = m.raw() as usize;
        if st.node_states[idx] != NodeState::Off {
            return;
        }
        let machine = st.plant.machine_mut(m);
        let boot = match machine.power_on() {
            Ok(b) => b,
            Err(_) => return,
        };
        st.node_states[idx] = NodeState::Booting;
        st.provision_started[idx] = Some(eng.now());
        st.metrics.inc("machines_powered_on");
        eng.schedule_after(boot, ClusterEvent::BootDone(m));
    }

    fn boot_done(st: &mut ClusterState, eng: &mut Ev, m: MachineId) {
        let idx = m.raw() as usize;
        // the machine may have been chaos-killed mid-boot
        if st.node_states[idx] != NodeState::Booting
            || st.plant.machine_mut(m).boot_complete().is_err()
        {
            return;
        }
        st.node_states[idx] = NodeState::StartingEngine;
        // dockerd startup
        eng.schedule_after(SimTime::from_secs(2), ClusterEvent::EngineUp(m));
    }

    fn engine_up(st: &mut ClusterState, eng: &mut Ev, m: MachineId) {
        let idx = m.raw() as usize;
        if st.node_states[idx] != NodeState::StartingEngine {
            return; // killed while dockerd was starting
        }
        st.node_states[idx] = NodeState::Deploying;
        if st.deploy_faults[idx] > 0 {
            // injected deploy failure: the pull/start step errors out and
            // the machine powers back off; the autoscaler retries later
            st.deploy_faults[idx] -= 1;
            st.metrics.inc("deploy_failures");
            st.metrics.inc("injected_deploy_failures");
            log::warn!("injected deploy failure on {m}");
            st.node_states[idx] = NodeState::Off;
            st.plant.machine_mut(m).power_off();
            return;
        }
        let cid = ContainerId::new(st.next_container);
        st.next_container += 1;
        let name = if idx == 0 {
            "head".to_string()
        } else {
            crate::cluster::node_name(idx, st.spec.machines)
        };
        let image = st.spec.image.clone();
        let cores = st.spec.slots_per_node.min(st.plant.machine(m).spec.total_cores());
        let spec = RunSpec { cores, memory: 32 << 30 };
        // split borrows: engine i vs machine m
        let machine = &mut st.plant.machines[idx];
        let receipt = match st.engines[idx].run(cid, &name, &image, spec, machine, &mut st.registry) {
            Ok(r) => r,
            Err(e) => {
                st.metrics.inc("deploy_failures");
                log::warn!("deploy on {m} failed: {e}");
                st.node_states[idx] = NodeState::Off;
                st.plant.machine_mut(m).power_off();
                return;
            }
        };
        st.metrics.add("bytes_pulled", receipt.pulled_bytes);
        st.metrics
            .observe("pull_seconds", receipt.pull_time.as_secs_f64());
        let Some(ip) = st.engines[idx].container(cid).and_then(|c| c.ip) else {
            // The engine accepted the run but the container has no lease —
            // treat it like any other deploy failure and park the node.
            st.metrics.inc("deploy_failures");
            log::warn!("deploy on {m}: container {cid} has no address, powering off");
            st.node_states[idx] = NodeState::Off;
            st.plant.machine_mut(m).power_off();
            return;
        };
        st.containers[idx] = Some(cid);
        st.ip_to_container.insert(ip, cid);
        st.fabric.lock().unwrap_or_else(|e| e.into_inner()).place(cid, m);
        eng.schedule_after(
            receipt.total(),
            ClusterEvent::ContainerUp { machine: m, container: cid, ip },
        );
    }

    fn container_up(st: &mut ClusterState, eng: &mut Ev, m: MachineId, cid: ContainerId, ip: Ipv4) {
        let idx = m.raw() as usize;
        if st.node_states[idx] != NodeState::Deploying {
            return; // killed while the container was starting
        }
        st.consul.advance(eng.now());
        // consul agent in the container joins gossip (seed: head agent 0)
        let agent = AgentId::new(cid.raw());
        let seed = if idx == 0 { None } else { Some(AgentId::new(st.containers[0].map(|c| c.raw()).unwrap_or(0))) };
        st.consul.agent_join(agent, seed, st.spec.seed ^ cid.raw() as u64);
        if st.partitioned_machines[idx] {
            // the machine came (back) up mid-partition: its fresh agent
            // is on the minority side too
            st.consul.partition_agent(agent);
        }
        if st.partial_machines[idx] {
            // likewise for a partial partition: the fresh agent inherits
            // the restricted server set
            st.consul.restrict_agent(agent, st.partial_servers.clone());
        }
        // record the host's rack for topology-aware placement and the
        // rack-spread metric (stale IPs are harmless: only addresses in
        // the live hostfile are ever looked up)
        st.head.rack_of.insert(ip, st.plant.rack_of(m).unwrap_or(0));
        // compute nodes register the hpc service; the head does not run
        // MPI ranks in the paper's deployment (head + node02/node03 do —
        // we register compute nodes only, matching Fig. 5's hostfile).
        if idx != 0 {
            Self::register_node_service(st, idx, ip);
        }
        st.node_states[idx] = NodeState::Ready;
        if let Some(t0) = st.provision_started[idx] {
            st.metrics
                .observe("provision_seconds", (eng.now().saturating_sub(t0)).as_secs_f64());
        }
        st.metrics.inc("nodes_ready");
        // heartbeat loop
        let ttl = st.health_ttl;
        eng.schedule_after(
            SimTime::from_nanos(ttl.as_nanos() / 3),
            ClusterEvent::Heartbeat(m),
        );
    }

    /// Register (or re-register) a compute node's `hpc` service entry
    /// and TTL health check — shared by first provisioning and the
    /// heartbeat's anti-entropy rejoin path.
    fn register_node_service(st: &mut ClusterState, idx: usize, ip: Ipv4) {
        let entry = ServiceEntry {
            node: crate::cluster::node_name(idx, st.spec.machines),
            address: ip,
            port: 22,
            slots: st.spec.slots_per_node,
            tags: vec!["hpc".into(), "mpi".into()],
        };
        let ttl = st.health_ttl;
        st.consul.register_service("hpc", &entry, ttl);
    }

    fn heartbeat(st: &mut ClusterState, eng: &mut Ev, m: MachineId, idx: usize) {
        if st.node_states[idx] != NodeState::Ready {
            return; // retired or dead: stop refreshing
        }
        if st.plant.machine(m).power != PowerState::On {
            return;
        }
        st.consul.advance(eng.now());
        // a hung agent is alive but mute; a partitioned one cannot reach
        // the servers — either way the TTL runs out and the node drops
        // from the hostfile until the condition clears. A *partially*
        // partitioned agent still gossips, but its TTL writes commit
        // only while it can reach the raft leader.
        let hung = eng.now() < st.hang_until[idx];
        let partitioned = st.partitioned_machines[idx];
        let leaderless = st.containers[idx]
            .map(|cid| !st.consul.agent_reaches_leader(AgentId::new(cid.raw())))
            .unwrap_or(false);
        if !hung && !partitioned && !leaderless {
            let node = crate::cluster::node_name(idx, st.spec.machines);
            if !st.consul.refresh_health(&node) && idx != 0 {
                // the check was reaped while the agent was unreachable
                // (health-gating deregisters critical instances): agent
                // anti-entropy re-registers the service, exactly like a
                // real consul agent rejoining after a flap
                if let Some(ip) = st.containers[idx]
                    .and_then(|cid| st.engines[idx].container(cid))
                    .and_then(|c| c.ip)
                {
                    Self::register_node_service(st, idx, ip);
                    st.metrics.inc("agent_reregistrations");
                }
            }
        }
        let ttl = st.health_ttl;
        eng.schedule_after(
            SimTime::from_nanos(ttl.as_nanos() / 3),
            ClusterEvent::Heartbeat(m),
        );
    }

    // ---------- control loops ----------

    fn template_poll_event(st: &mut ClusterState, eng: &mut Ev) {
        // consul-template runs on the head: a dead head renders nothing
        // (the standby re-renders through a fresh watcher at takeover)
        if !st.ha.head_down() {
            Self::refresh_hostfile(st, eng.now());
        }
        let poll = st.head.poll_interval;
        eng.schedule_after(poll, ClusterEvent::TemplatePoll);
    }

    pub(crate) fn refresh_hostfile(st: &mut ClusterState, now: SimTime) {
        st.consul.advance(now);
        // health-gate the catalog before rendering, consul-template style:
        // critical nodes must drop out of the hostfile.
        let healthy = st.consul.healthy_instances("hpc");
        let all = crate::consul::catalog::Catalog::list(st.consul.kv(), "hpc");
        for e in &all {
            if !healthy.iter().any(|h| h.node == e.node) {
                st.consul.deregister_service("hpc", &e.node);
            }
        }
        if let Some(output) = st.head.watcher.poll(st.consul.kv()) {
            st.head.hostfile_text = output.to_string();
            st.head.hostfile_updated_at = now;
            st.head.hostfile_renders += 1;
            st.metrics.inc("hostfile_renders");
        }
    }

    fn scheduler_event(st: &mut ClusterState, eng: &mut Ev) {
        st.consul.advance(eng.now());
        if st.ha.config.enabled {
            if !st.ha.head_alive {
                // the head process is down: nothing schedules until the
                // standby takes over, but the tick keeps itself armed so
                // the loop resumes on the replayed head
                eng.schedule_after(SimTime::from_secs(1), ClusterEvent::SchedulerTick);
                return;
            }
            // the active head's leadership lease: the refreshes stop the
            // moment the head dies, which is what the standby watches
            st.consul.refresh_health(crate::ha::failover::HEAD_LEASE);
        }
        Self::reap_lost_jobs(st, eng);
        Self::dispatch_jobs(st, eng);
        Self::sample_gauges(st, eng.now());
        crate::ha::wal::flush(st);
        st.trace.flush();
        eng.schedule_after(SimTime::from_secs(1), ClusterEvent::SchedulerTick);
    }

    /// Health-gated compute-node census: `(ready, unhealthy,
    /// provisioning)` — a Ready node whose check went critical is not
    /// usable capacity. Shared by the autoscaler's observation and the
    /// metrics recorder so both report the same signal.
    fn node_counts(st: &mut ClusterState, now: SimTime) -> (u32, u32, u32) {
        let mut ready = 0u32;
        let mut unhealthy = 0u32;
        let mut provisioning = 0u32;
        for (idx, s) in st.node_states.iter().enumerate().skip(1) {
            match s {
                NodeState::Ready => {
                    let node = crate::cluster::node_name(idx, st.spec.machines);
                    match st.consul.health.status(&node, now) {
                        Some(CheckStatus::Passing) => ready += 1,
                        _ => unhealthy += 1,
                    }
                }
                s if s.is_provisioning() => provisioning += 1,
                _ => {}
            }
        }
        (ready, unhealthy, provisioning)
    }

    /// Emit one `sample` trace event when the recorder's cadence is
    /// due. Reads scheduler/consul/ledger state, writes only into the
    /// trace bus — costs nothing on untraced runs.
    fn sample_gauges(st: &mut ClusterState, now: SimTime) {
        if !st.trace.enabled() || !st.recorder.due(now) {
            return;
        }
        let (ready, unhealthy, provisioning) = Self::node_counts(st, now);
        let usage: Vec<(u64, f64)> = st
            .head
            .ledger
            .export_accounts()
            .iter()
            .map(|&(tenant, _, _)| (tenant, st.head.ledger.usage_at(tenant, now)))
            .collect();
        let g = GaugeSnapshot {
            queued_jobs: st.head.queue.len() as u64,
            queued_slots: st.head.queued_slots() as u64,
            running_jobs: st.head.running.len() as u64,
            reserved_slots: st.head.reserved_slots() as u64,
            total_slots: ready as u64 * st.spec.slots_per_node as u64,
            nodes_ready: ready as u64,
            nodes_unhealthy: unhealthy as u64,
            nodes_provisioning: provisioning as u64,
            scale_target: (ready + provisioning) as u64,
            usage,
        };
        let epoch = st.ha.epoch;
        st.recorder.record(now, epoch, &g, &mut st.trace);
    }

    /// Recovery pipeline, detection step: cross-check every running
    /// reservation against the health-gated hostfile. A job whose slice
    /// references a host that dropped out (TTL expiry after a crash,
    /// hang or partition) is failed and requeued under its retry budget.
    fn reap_lost_jobs(st: &mut ClusterState, eng: &mut Ev) {
        // reversed: each requeue is a push_front, so processing youngest
        // first leaves the oldest lost job at the head of the queue
        for id in st.head.lost_jobs().into_iter().rev() {
            Self::job_lost(st, eng.now(), id, "reservation lost a node (host left the hostfile)");
        }
    }

    /// Recovery pipeline, bookkeeping step: route a lost job through the
    /// head's retry budget and record what happened. Also called by the
    /// HA takeover for jobs whose machine died during the head outage.
    pub(crate) fn job_lost(st: &mut ClusterState, now: SimTime, id: JobId, reason: &str) {
        // tenant attribution must be read before the head moves the
        // record out of the running pool
        let tenant = st.head.running.get(&id).map(|r| r.spec.tenant).unwrap_or(0);
        match st.head.handle_lost_job(id, now, reason) {
            LossOutcome::Requeued { attempt, wasted, .. } => {
                st.metrics.inc("jobs_requeued");
                st.metrics.observe("job_wasted_seconds", wasted.as_secs_f64());
                st.trace.emit(TraceEvent::Requeue {
                    at: now,
                    epoch: st.ha.epoch,
                    job: id,
                    attempt,
                    tenant,
                    wasted,
                });
            }
            LossOutcome::Abandoned { .. } => {
                st.metrics.inc("jobs_lost");
                st.trace
                    .emit(TraceEvent::Abandon { at: now, epoch: st.ha.epoch, job: id, tenant });
            }
            LossOutcome::NotRunning => {}
        }
    }

    /// Start every currently startable job (FIFO + conservative
    /// backfill), each on its own reserved hostfile slice.
    fn dispatch_jobs(st: &mut ClusterState, eng: &mut Ev) {
        let deferred_before = st.head.deferred_jobs();
        loop {
            let Some(started) = st.head.start_next(eng.now()) else { break };
            // preemptions already happened inside start_next — account
            // for them even if this job's launch aborts below
            if !started.preempted.is_empty() {
                st.metrics.add("jobs_preempted", started.preempted.len() as u64);
                st.metrics
                    .observe("preempt_wasted_seconds", started.preempt_wasted.as_secs_f64());
                for pid in &started.preempted {
                    // the preempted job is already checkpointed back in
                    // the queue: attribute it from there
                    let tenant = st
                        .head
                        .queue
                        .iter()
                        .find(|(s, _)| s.id == *pid)
                        .map(|(s, _)| s.tenant)
                        .unwrap_or(0);
                    st.trace.emit(TraceEvent::Preempt {
                        at: eng.now(),
                        epoch: st.ha.epoch,
                        job: *pid,
                        tenant,
                    });
                }
            }
            st.trace.emit(TraceEvent::Dispatch {
                at: eng.now(),
                epoch: st.ha.epoch,
                job: started.spec.id,
                attempt: started.attempt,
                tenant: started.spec.tenant,
                ranks: started.spec.ranks,
                backfilled: started.backfilled,
            });
            if !Self::launch_job(st, eng, started) {
                // launch aborted on a stale hostfile: wait for the next
                // tick so the quarantine deregistration can commit
                break;
            }
        }
        // quota re-admissions happen inside `start_next` (the head owns
        // the pens): surface them as the net pen drain this round
        let readmitted = deferred_before.saturating_sub(st.head.deferred_jobs());
        if readmitted > 0 {
            st.trace.emit(TraceEvent::QuotaAdmit {
                at: eng.now(),
                epoch: st.ha.epoch,
                admitted: readmitted as u64,
            });
        }
        st.metrics.set_gauge("running_jobs", st.head.running.len() as f64);
    }

    /// Returns false when the launch was aborted because a host in the
    /// job's slice is unreachable (the job is already back in the queue).
    fn launch_job(st: &mut ClusterState, eng: &mut Ev, started: StartedJob) -> bool {
        let id = started.spec.id;
        let t0 = eng.now();
        // mpirun would fail to reach a host whose container is gone (a
        // dead machine stays advertised until its TTL expires): abort the
        // launch, quarantine the host now rather than waiting out the
        // TTL, and requeue the job without charging its retry budget
        if let Some(bad) = started
            .hostfile_slice
            .hosts
            .iter()
            .find(|h| !st.ip_to_container.contains_key(&h.addr))
        {
            let bad_addr = bad.addr;
            st.head.unlaunch(id, t0);
            st.metrics.inc("launch_aborts");
            // mirrors the WAL's `Unlaunched`: the dispatch is undone and
            // the job is back at the queue head with nothing charged
            st.trace.emit(TraceEvent::Requeue {
                at: t0,
                epoch: st.ha.epoch,
                job: id,
                attempt: started.attempt,
                tenant: started.spec.tenant,
                wasted: SimTime::ZERO,
            });
            if let Some(entry) = Catalog::list(st.consul.kv(), "hpc")
                .into_iter()
                .find(|e| e.address == bad_addr)
            {
                st.consul.deregister_service("hpc", &entry.node);
            }
            Self::refresh_hostfile(st, t0);
            return false;
        }
        let duration = match &started.spec.kind {
            JobKind::Synthetic { duration } => *duration,
            JobKind::Jacobi { px, py, tile, steps } => {
                match Self::run_jacobi_job(st, &started.hostfile_slice, *px, *py, *tile, *steps) {
                    Ok((report_dur, steps_run, residual)) => {
                        if let Some(rec) = st.head.running.get_mut(&id) {
                            rec.result = Some((steps_run, residual));
                        }
                        report_dur
                    }
                    Err(e) => {
                        st.metrics.inc("jobs_failed");
                        let reason = e.to_string();
                        if st.head.journal_enabled() {
                            st.head.log_event(crate::ha::wal::WalEvent::Failed {
                                at: t0,
                                id,
                                reason: reason.clone(),
                            });
                        }
                        if st.trace.enabled() {
                            st.trace.emit(TraceEvent::Fail {
                                at: t0,
                                epoch: st.ha.epoch,
                                job: id,
                                tenant: started.spec.tenant,
                                reason: reason.clone(),
                            });
                        }
                        st.head.fail(id, reason);
                        return true;
                    }
                }
            }
        };
        if let Some(rec) = st.head.running.get_mut(&id) {
            rec.planned_duration = Some(duration);
        }
        if st.head.journal_enabled() {
            // pins the attempt's planned finish (and any launch-time
            // Jacobi result) so a takeover can re-arm the completion
            let result = st.head.running.get(&id).and_then(|r| r.result);
            st.head.log_event(crate::ha::wal::WalEvent::Launched {
                at: t0,
                id,
                attempt: started.attempt,
                planned: duration,
                result,
            });
        }
        st.trace.emit(TraceEvent::Launch {
            at: t0,
            epoch: st.ha.epoch,
            job: id,
            attempt: started.attempt,
            planned: duration,
        });
        st.metrics.inc("jobs_started");
        if started.backfilled {
            st.metrics.inc("backfill_starts");
        }
        // how many racks the reservation spans (1 = fully packed)
        let racks: HashSet<usize> = started
            .hostfile_slice
            .hosts
            .iter()
            .map(|h| st.head.rack_of.get(&h.addr).copied().unwrap_or(usize::MAX))
            .collect();
        st.metrics.observe("job_rack_spread", racks.len() as f64);
        st.metrics.observe(
            "job_queue_seconds",
            t0.saturating_sub(started.queued_at).as_secs_f64(),
        );
        st.metrics.observe("concurrent_jobs", st.head.running.len() as f64);
        let attempt = started.attempt;
        let epoch = st.ha.epoch;
        eng.schedule_after(duration, ClusterEvent::JobDone { id, attempt, epoch });
        true
    }

    pub(crate) fn job_done(st: &mut ClusterState, eng: &mut Ev, id: JobId, attempt: u32, epoch: u64) {
        // Epoch fence: a completion delivered to a dead head is dropped
        // (the standby re-arms its own timer at takeover), and a timer
        // armed by a dead head's epoch can never fire into the replayed
        // head — the failover analogue of the attempt guard below.
        if st.ha.config.enabled && (!st.ha.head_alive || epoch != st.ha.epoch) {
            st.metrics.inc("ha_dropped_completions");
            return;
        }
        // a completion event from an attempt that was since killed and
        // requeued must not complete the newer attempt early
        if st.head.running.get(&id).map(|r| r.attempt) != Some(attempt) {
            return;
        }
        // settle the finishing job's slot-seconds into its tenant's
        // ledger before it leaves the running pool
        st.head.accrue_usage(eng.now());
        if let Some(mut record) = st.head.finish(id) {
            let started = match record.state {
                JobState::Running { started } => started,
                _ => eng.now(),
            };
            record.state = JobState::Done { started, finished: eng.now() };
            st.metrics.inc("jobs_completed");
            st.trace.emit(TraceEvent::Complete {
                at: eng.now(),
                epoch: st.ha.epoch,
                job: id,
                attempt,
                tenant: record.spec.tenant,
                started,
            });
            st.head.record_terminal(record);
            if let Some(t0) = st.head.first_failed_at.remove(&id) {
                st.metrics
                    .observe("job_mttr_seconds", eng.now().saturating_sub(t0).as_secs_f64());
            }
            if st.head.journal_enabled() {
                st.head.log_event(crate::ha::wal::WalEvent::Completed {
                    at: eng.now(),
                    id,
                    attempt,
                });
            }
        }
        // freed slots: start waiting jobs now, not at the next tick
        Self::dispatch_jobs(st, eng);
        crate::ha::wal::flush(st);
        st.trace.flush();
    }

    fn run_jacobi_job(
        st: &mut ClusterState,
        hostfile_slice: &Hostfile,
        px: usize,
        py: usize,
        tile: usize,
        steps: usize,
    ) -> Result<(SimTime, usize, f32)> {
        if hostfile_slice.hosts.is_empty() {
            return Err(anyhow!("empty hostfile slice"));
        }
        let plan = LaunchPlan {
            hostfile: hostfile_slice.clone(),
            n_ranks: px * py,
            ip_to_container: st.ip_to_container.clone(),
            fabric: st.fabric.clone(),
            eager_threshold: 64 * 1024,
        };
        let spec = JacobiSpec {
            px,
            py,
            tile,
            steps,
            // residual cadence only — the restart checkpoint the
            // recovery pipeline resumes from is the head's (tunable)
            // `checkpoint_every_steps`, decoupled from the numerics
            check_every: crate::cluster::head::JACOBI_RESIDUAL_CHECK_STEPS.min(steps),
            tol: 1e-6,
            artifacts: st.artifacts.clone(),
        };
        let report = run_jacobi(&plan, &spec).map_err(|e| anyhow!("{e}"))?;
        let duration = report.comm_time + SimTime::from_secs_f64(report.compute_wall_max.as_secs_f64());
        st.metrics
            .observe("job_comm_seconds", report.comm_time.as_secs_f64());
        st.metrics.observe(
            "job_compute_seconds",
            report.compute_wall_max.as_secs_f64(),
        );
        st.metrics.add("job_bytes", report.total_bytes);
        Ok((duration, report.steps_run, report.final_residual))
    }

    fn autoscale_event(st: &mut ClusterState, eng: &mut Ev) {
        st.consul.advance(eng.now());
        if st.ha.head_down() {
            // the autoscaler reads the head's queue: with the head down
            // it has no demand signal, so decisions freeze until the
            // standby takes over (the loop keeps itself armed)
            let interval = st.spec.spec_autoscale_interval();
            eng.schedule_after(interval, ClusterEvent::AutoscaleTick);
            return;
        }
        // capacity is health-gated: a Ready node whose check went
        // critical (hung agent, partition) is not capacity the scheduler
        // can use — counting it separately lets the policy boot a
        // replacement while suppressing scale-down mid-incident
        let (ready, unhealthy, provisioning) = Self::node_counts(st, eng.now());
        let obs = Observation {
            now: eng.now(),
            ready_nodes: ready,
            unhealthy_nodes: unhealthy,
            provisioning_nodes: provisioning,
            queued_slots: st.head.queued_slots(),
            queued_slots_weighted: st.head.weighted_queued_slots(),
            reserved_slots: st.head.reserved_slots(),
            slots_per_node: st.spec.slots_per_node,
        };
        let (action, reason) = st.autoscaler.decide_with_reason(obs);
        // decision-level accounting: the reason counters fire whether or
        // not the executor below finds machines to act on (a Down that
        // retires nothing is still a low-util decision). Deterministic,
        // so part of the counter fingerprint by design.
        if let Some(name) = reason.counter_name() {
            st.metrics.inc(name);
        }
        match action {
            ScaleAction::Up(n) => {
                st.trace.emit(TraceEvent::ScaleUp {
                    at: eng.now(),
                    epoch: st.ha.epoch,
                    nodes: n,
                    reason,
                });
                let mut started = 0;
                for i in 1..st.spec.machines {
                    if started == n {
                        break;
                    }
                    if st.node_states[i as usize] == NodeState::Off {
                        Self::provision_machine(st, eng, MachineId::new(i));
                        started += 1;
                    }
                }
                // arm + journal the Up cooldown mark so a takeover
                // keeps honouring it
                st.head.note_scale_up(eng.now());
                st.metrics.add("scale_up_nodes", started as u64);
            }
            ScaleAction::Down(n) => {
                st.trace.emit(TraceEvent::ScaleDown {
                    at: eng.now(),
                    epoch: st.ha.epoch,
                    nodes: n,
                    reason,
                });
                // never retire a node whose slots are reserved by a
                // running job — a retired host would orphan its ranks
                let busy = st.head.reserved_addrs();
                let mut stopped = 0;
                for i in (1..st.spec.machines).rev() {
                    if stopped == n {
                        break;
                    }
                    let idx = i as usize;
                    if st.node_states[idx] != NodeState::Ready {
                        continue;
                    }
                    let node_busy = st.containers[idx]
                        .and_then(|cid| st.engines[idx].container(cid))
                        .and_then(|c| c.ip)
                        .map(|ip| busy.contains(&ip))
                        .unwrap_or(false);
                    if node_busy {
                        continue;
                    }
                    Self::retire_node(st, eng.now(), MachineId::new(i));
                    stopped += 1;
                }
                if stopped > 0 {
                    // re-render the hostfile immediately so no job is
                    // dispatched onto a just-retired host in the window
                    // before the next template poll
                    Self::refresh_hostfile(st, eng.now());
                    // only a Down that actually retired something arms
                    // (and journals) the cooldown — mirrors down_was_noop
                    st.head.note_scale_down(eng.now());
                } else {
                    // nothing was retirable: don't let the phantom Down
                    // burn a cooldown or pollute the action log
                    st.autoscaler.down_was_noop(eng.now());
                }
                st.metrics.add("scale_down_nodes", stopped as u64);
            }
            ScaleAction::None => {
                // a held decision is observable; a steady interval is
                // noise and stays out of the trace
                if matches!(reason, ScaleReason::CooldownHeld | ScaleReason::ShareCap) {
                    st.trace.emit(TraceEvent::ScaleHold {
                        at: eng.now(),
                        epoch: st.ha.epoch,
                        reason,
                    });
                }
            }
        }
        crate::ha::wal::flush(st);
        st.trace.flush();
        let interval = st.spec.spec_autoscale_interval();
        eng.schedule_after(interval, ClusterEvent::AutoscaleTick);
    }

    fn retire_node(st: &mut ClusterState, now: SimTime, m: MachineId) {
        let idx = m.raw() as usize;
        st.consul.advance(now);
        let node = crate::cluster::node_name(idx, st.spec.machines);
        st.consul.deregister_service("hpc", &node);
        if let Some(cid) = st.containers[idx].take() {
            let _ = st.engines[idx].stop(cid, 0);
            let machine = &mut st.plant.machines[idx];
            let _ = st.engines[idx].remove(cid, machine);
            st.consul.agent_remove(AgentId::new(cid.raw()));
            if let Some(ip) = st.ip_to_container.iter().find(|(_, c)| **c == cid).map(|(ip, _)| *ip) { // lint: allow(map-iter) unique reverse lookup
                st.ip_to_container.remove(&ip);
            }
            st.fabric.lock().unwrap_or_else(|e| e.into_inner()).unplace(cid);
        }
        st.plant.machine_mut(m).power_off();
        st.node_states[idx] = NodeState::Off;
        st.metrics.inc("nodes_retired");
    }

    // ---------- public operations ----------

    /// Submit a job to the head node at normal (batch) priority. A job
    /// wider than the cluster can ever advertise is rejected up front
    /// (recorded as `Failed`) — queueing it would wedge the FIFO head
    /// forever and the backfill guard would starve every job behind it.
    pub fn submit(&mut self, name: &str, ranks: u32, kind: JobKind) -> JobId {
        self.submit_with_priority(name, ranks, kind, 0)
    }

    /// [`VirtualCluster::submit`] with an explicit scheduling priority
    /// (higher runs sooner under the priority policy; every policy
    /// feeds it into the autoscaler's weighted demand signal).
    pub fn submit_with_priority(
        &mut self,
        name: &str,
        ranks: u32,
        kind: JobKind,
        priority: i32,
    ) -> JobId {
        self.submit_job(name, ranks, kind, priority, 0)
    }

    /// The general submit: priority plus tenant attribution. The job is
    /// charged to `tenant`'s usage ledger while it runs and counts
    /// against the tenant's quotas; an over-quota submission is
    /// rejected (recorded as `Failed`) or deferred per
    /// [`Head::quotas`](crate::cluster::head::Head).
    pub fn submit_job(
        &mut self,
        name: &str,
        ranks: u32,
        kind: JobKind,
        priority: i32,
        tenant: u64,
    ) -> JobId {
        let id = JobId::new(self.state.next_job);
        self.state.next_job += 1;
        let spec = JobSpec { id, name: name.to_string(), ranks, kind, priority, tenant };
        let now = self.engine.now();
        let max_slots = self.state.spec.max_advertisable_slots();
        if ranks > max_slots {
            let reason = format!(
                "job needs {ranks} slots but the cluster can advertise at most {max_slots}"
            );
            self.state.metrics.inc("jobs_rejected");
            if self.state.trace.enabled() {
                self.state.trace.emit(TraceEvent::SubmitRejected {
                    at: now,
                    epoch: self.state.ha.epoch,
                    job: id,
                    tenant,
                    reason: reason.clone(),
                });
            }
            if self.state.ha.head_down() {
                // no head to record the rejection: write it straight to
                // the WAL, the standby materializes the record at replay
                crate::ha::wal::append_direct(
                    &mut self.state,
                    crate::ha::wal::WalEvent::SubmitFailed { at: now, spec, reason },
                );
                self.state.trace.flush();
                return id;
            }
            if self.state.head.journal_enabled() {
                self.state.head.log_event(crate::ha::wal::WalEvent::SubmitFailed {
                    at: now,
                    spec: spec.clone(),
                    reason: reason.clone(),
                });
            }
            self.state.head.record_terminal(JobRecord {
                spec,
                state: JobState::Failed { reason },
                result: None,
                queued_at: now,
                attempt: 0,
                planned_duration: None,
            });
            crate::ha::wal::flush(&mut self.state);
            self.state.trace.flush();
            return id;
        }
        let submit_ev = TraceEvent::Submit {
            at: now,
            epoch: self.state.ha.epoch,
            job: id,
            tenant,
            ranks,
            priority,
        };
        if self.state.ha.head_down() {
            // the head is down: a client's retry loop lands the
            // submission in the replicated WAL and the standby replays
            // it at takeover — no submitted work is ever lost to a head
            // crash
            self.state.metrics.inc("jobs_submitted");
            self.state.trace.emit(submit_ev);
            crate::ha::wal::append_direct(
                &mut self.state,
                crate::ha::wal::WalEvent::Submitted { at: now, spec },
            );
            self.state.trace.flush();
            return id;
        }
        match self.state.head.submit(spec, now) {
            SubmitOutcome::Queued => {
                self.state.metrics.inc("jobs_submitted");
                self.state.trace.emit(submit_ev);
            }
            SubmitOutcome::Deferred => {
                self.state.metrics.inc("jobs_submitted");
                self.state.metrics.inc("jobs_deferred_quota");
                self.state.trace.emit(submit_ev);
                self.state.trace.emit(TraceEvent::QuotaDefer {
                    at: now,
                    epoch: self.state.ha.epoch,
                    job: id,
                    tenant,
                });
            }
            SubmitOutcome::Rejected { spec, reason } => {
                self.state.metrics.inc("jobs_rejected");
                self.state.metrics.inc("jobs_rejected_quota");
                if self.state.trace.enabled() {
                    self.state.trace.emit(TraceEvent::SubmitRejected {
                        at: now,
                        epoch: self.state.ha.epoch,
                        job: id,
                        tenant,
                        reason: reason.clone(),
                    });
                }
                self.state.head.record_terminal(JobRecord {
                    spec,
                    state: JobState::Failed { reason },
                    result: None,
                    queued_at: now,
                    attempt: 0,
                    planned_duration: None,
                });
            }
        }
        crate::ha::wal::flush(&mut self.state);
        self.state.trace.flush();
        id
    }

    /// Hard-kill a machine (power loss): the container vanishes, the
    /// health check expires and the node drops out of the hostfile.
    /// Jobs holding slots on the machine abort immediately — mpirun sees
    /// the connections die long before the TTL — and are requeued under
    /// their retry budget.
    pub fn kill_machine(&mut self, m: MachineId) {
        let now = self.engine.now();
        Self::kill_machine_at(&mut self.state, now, m);
    }

    /// Event-context version of [`kill_machine`] (the chaos injector
    /// calls this from inside engine events).
    pub(crate) fn kill_machine_at(st: &mut ClusterState, now: SimTime, m: MachineId) {
        let idx = m.raw() as usize;
        if idx >= st.node_states.len() {
            return;
        }
        if st.node_states[idx] == NodeState::Off {
            return; // nothing to kill: don't inflate machines_killed
        }
        let mut dead_ip = None;
        if let Some(cid) = st.containers[idx].take() {
            st.consul.agent_remove(AgentId::new(cid.raw()));
            if let Some(ip) = st
                .ip_to_container
                .iter() // lint: allow(map-iter) unique reverse lookup
                .find(|(_, c)| **c == cid)
                .map(|(ip, _)| *ip)
            {
                st.ip_to_container.remove(&ip);
                dead_ip = Some(ip);
            }
            st.fabric.lock().unwrap_or_else(|e| e.into_inner()).unplace(cid);
        }
        st.plant.machine_mut(m).power_off();
        st.node_states[idx] = NodeState::Off;
        st.hang_until[idx] = SimTime::ZERO;
        st.metrics.inc("machines_killed");
        if let Some(ip) = dead_ip {
            if st.ha.head_down() {
                // no head to observe the death: the takeover validates
                // every replayed reservation against the live container
                // map and fails these jobs over before re-arming any
                // completion, so the death is handled the instant a
                // head exists again
                return;
            }
            // reversed so the push_front requeues keep FIFO order among
            // the jobs lost to this machine
            for id in st.head.jobs_on_addr(ip).into_iter().rev() {
                Self::job_lost(st, now, id, &format!("machine {m} died under the job"));
            }
            crate::ha::wal::flush(st);
            st.trace.flush();
        }
    }

    // ---------- chaos hooks (driven by faults::injector) ----------

    /// Mute a machine's heartbeats for `duration` (node hang: the
    /// machine and its ranks stay alive, the agent just goes silent).
    pub(crate) fn chaos_hang(st: &mut ClusterState, now: SimTime, m: MachineId, duration: SimTime) {
        let idx = m.raw() as usize;
        if idx >= st.hang_until.len() {
            return;
        }
        st.hang_until[idx] = st.hang_until[idx].max(now + duration);
        st.metrics.inc("hangs_injected");
    }

    /// Make the next `failures` deploy attempts on a machine fail.
    pub(crate) fn chaos_deploy_fail(st: &mut ClusterState, m: MachineId, failures: u32) {
        let idx = m.raw() as usize;
        if idx < st.deploy_faults.len() {
            st.deploy_faults[idx] += failures;
        }
    }

    /// Cut the listed machines off from the rest of the gossip network
    /// (and from the consul servers, so their health checks expire).
    /// The split is keyed by machine: targets that are down now are cut
    /// off the moment they come up, and re-provisioned containers join
    /// the minority side. Returns the partition's epoch token when at
    /// least one machine was targeted; replaces any previous split.
    pub(crate) fn chaos_partition(st: &mut ClusterState, machines: &[u32]) -> Option<u64> {
        for flag in st.partitioned_machines.iter_mut() {
            *flag = false;
        }
        let mut agents = Vec::new();
        let mut flagged = false;
        for &mi in machines {
            let idx = mi as usize;
            if idx == 0 || idx >= st.partitioned_machines.len() {
                continue;
            }
            st.partitioned_machines[idx] = true;
            flagged = true;
            if let Some(cid) = st.containers[idx] {
                agents.push(AgentId::new(cid.raw()));
            }
        }
        if !flagged {
            return None;
        }
        let epoch = st.consul.set_partition(agents);
        st.metrics.inc("partitions_injected");
        Some(epoch)
    }

    /// Heal the partition identified by `epoch` (a later partition
    /// replaces the split and invalidates older heal timers).
    pub(crate) fn chaos_heal_partition(st: &mut ClusterState, epoch: u64) {
        if st.consul.heal_partition_epoch(epoch) {
            for flag in st.partitioned_machines.iter_mut() {
                *flag = false;
            }
        }
    }

    /// Restrict the listed machines' agents to reaching only the given
    /// consul servers (partial partition): gossip keeps flowing, but
    /// their health refreshes and registrations commit only while the
    /// raft leader is in the reachable set. One partial partition at a
    /// time; returns its epoch token, or None when nothing was targeted.
    pub(crate) fn chaos_partial_partition(
        st: &mut ClusterState,
        machines: &[u32],
        servers: &[u32],
    ) -> Option<u64> {
        for flag in st.partial_machines.iter_mut() {
            *flag = false;
        }
        st.partial_servers = servers.to_vec();
        let mut agents = Vec::new();
        let mut flagged = false;
        for &mi in machines {
            let idx = mi as usize;
            if idx == 0 || idx >= st.partial_machines.len() {
                continue;
            }
            st.partial_machines[idx] = true;
            flagged = true;
            if let Some(cid) = st.containers[idx] {
                agents.push(AgentId::new(cid.raw()));
            }
        }
        if !flagged {
            st.partial_servers.clear();
            return None;
        }
        let epoch = st.consul.set_partial_partition(agents, servers.to_vec());
        st.metrics.inc("partial_partitions_injected");
        Some(epoch)
    }

    /// Heal the partial partition identified by `epoch`.
    pub(crate) fn chaos_heal_partial_partition(st: &mut ClusterState, epoch: u64) {
        if st.consul.heal_partial_partition_epoch(epoch) {
            for flag in st.partial_machines.iter_mut() {
                *flag = false;
            }
            st.partial_servers.clear();
        }
    }

    /// Kill the head *process* (not machine 0): the in-memory scheduler
    /// state is gone, lease refreshes stop, and the standby takes over
    /// from the replicated WAL once the lease expires. A no-op without
    /// HA — chaos never decapitates a cluster that has no standby.
    pub(crate) fn chaos_head_crash(st: &mut ClusterState, now: SimTime) {
        if !st.ha.config.enabled {
            log::warn!("head-crash fault ignored: HA is not enabled (no standby)");
            st.metrics.inc("head_crashes_ignored");
            return;
        }
        if !st.ha.head_alive {
            return; // already down
        }
        st.ha.head_alive = false;
        st.ha.crashed_at = Some(now);
        // anything the dead head buffered but never flushed dies with
        // it (there is nothing between events by construction, but a
        // crash must not be able to leak state forward)
        let _ = st.head.take_journal();
        st.metrics.inc("head_crashes");
    }

    /// Install a fault plan: every fault becomes a deterministic engine
    /// event. Plan times are offsets from the moment of injection.
    pub fn inject_faults(&mut self, plan: &crate::faults::FaultPlan) {
        let events = plan.expanded();
        let n = events.len() as u64;
        for ev in events {
            self.engine.schedule_after(ev.at, ClusterEvent::Fault(ev.kind));
        }
        self.state.metrics.add("faults_scheduled", n);
    }

    /// Explicitly provision one more machine (manual scale-up).
    pub fn power_on(&mut self, m: MachineId) {
        Self::provision_machine(&mut self.state, &mut self.engine, m);
    }

    pub fn hostfile(&self) -> &str {
        &self.state.head.hostfile_text
    }

    pub fn ready_compute_nodes(&self) -> usize {
        self.state
            .node_states
            .iter()
            .skip(1)
            .filter(|s| **s == NodeState::Ready)
            .count()
    }

    pub fn node_state(&self, m: MachineId) -> NodeState {
        self.state.node_states[m.raw() as usize]
    }

    pub fn completed_jobs(&self) -> &[JobRecord] {
        &self.state.head.completed
    }

    /// Terminal jobs ever recorded, including records dropped by the
    /// completed-history retention cap — the progress counter driver
    /// wait loops should use instead of `completed_jobs().len()`.
    pub fn completed_total(&self) -> usize {
        self.state.head.completed_total()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.state.metrics
    }

    /// Install (or replace) a trace sink programmatically — the
    /// in-process equivalent of setting `spec.trace_path` (tests and
    /// embedders use a [`MemSink`](crate::obs::MemSink) here).
    pub fn set_trace_sink(&mut self, sink: Box<dyn TraceSink>) {
        self.state.trace = TraceBus::with_sink(sink);
    }

    /// Drain the trace bus and push the sink's buffers durable (end of
    /// run; also happens automatically when the cluster drops).
    pub fn finish_trace(&mut self) {
        self.state.trace.finish();
    }

    /// `(events_written, events_dropped)` on the trace bus. Drivers
    /// surface the drop count in their end-of-run summary — a non-zero
    /// value means the sink failed mid-run and the trace is partial.
    pub fn trace_io(&self) -> (u64, u64) {
        (
            self.state.trace.events_written(),
            self.state.trace.events_dropped(),
        )
    }

    /// Journal the tenant arrival generator's resume cursor into the
    /// replicated WAL (and remember it on the live head for snapshots),
    /// so a standby can continue the synthesized arrival stream exactly
    /// where this head left it. Durable even while the head is down —
    /// the cursor goes straight to the log, like client submissions.
    /// No-op without HA beyond the in-memory note.
    pub fn journal_arrival_cursor(&mut self, cursor: String) {
        let now = self.engine.now();
        self.state.head.last_arrival_cursor = Some(cursor.clone());
        crate::ha::wal::append_direct(
            &mut self.state,
            crate::ha::wal::WalEvent::ArrivalCursor { at: now, cursor },
        );
    }

    /// The last journaled arrival cursor — after a takeover this is the
    /// value the WAL replay (or snapshot restore) carried over.
    pub fn arrival_cursor(&self) -> Option<&str> {
        self.state.head.last_arrival_cursor.as_deref()
    }
}

impl ClusterSpec {
    fn spec_autoscale_interval(&self) -> SimTime {
        self.autoscale.interval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_spec(machines: u32) -> ClusterSpec {
        let mut spec = ClusterSpec::paper_testbed();
        spec.machines = machines;
        spec.machine_spec.boot_time = SimTime::from_secs(5);
        spec.autoscale.min_nodes = 2;
        spec.autoscale.max_nodes = machines - 1;
        spec.autoscale.interval = SimTime::from_secs(2);
        spec.autoscale.cooldown = SimTime::from_secs(4);
        spec.autoscale.idle_timeout = SimTime::from_secs(60);
        spec
    }

    #[test]
    fn cluster_comes_up_and_renders_hostfile() {
        let mut vc = VirtualCluster::new(fast_spec(3)).unwrap();
        vc.start();
        let ok = vc.advance_until(SimTime::from_secs(300), |st| {
            st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
        });
        assert!(ok, "hostfile never reached 2 nodes: {:?}", vc.hostfile());
        assert_eq!(vc.ready_compute_nodes(), 2);
        let hf = vc.state.head.hostfile().unwrap();
        assert_eq!(hf.total_slots(), 24);
        // the hostfile contains the containers' bridge0 IPs
        for h in &hf.hosts {
            assert!(vc.state.ip_to_container.contains_key(&h.addr));
        }
        assert!(vc.metrics().counter("hostfile_renders") >= 1);
        assert!(vc.metrics().counter("bytes_pulled") > 0);
    }

    #[test]
    fn synthetic_job_runs_to_completion() {
        let mut vc = VirtualCluster::new(fast_spec(3)).unwrap();
        vc.start();
        vc.submit(
            "hello",
            16,
            JobKind::Synthetic { duration: SimTime::from_secs(30) },
        );
        let ok = vc.advance_until(SimTime::from_secs(600), |st| !st.head.completed.is_empty());
        assert!(ok, "job never completed");
        let rec = &vc.completed_jobs()[0];
        assert!(matches!(rec.state, JobState::Done { .. }));
        if let JobState::Done { started, finished } = rec.state {
            assert_eq!(finished.saturating_sub(started), SimTime::from_secs(30));
        }
    }

    #[test]
    fn autoscaler_grows_for_demand_beyond_min() {
        let mut spec = fast_spec(5);
        spec.autoscale.min_nodes = 1;
        spec.autoscale.max_nodes = 4;
        let mut vc = VirtualCluster::new(spec).unwrap();
        vc.start();
        // demand 36 slots = 3 nodes; min is 1
        vc.submit(
            "big",
            36,
            JobKind::Synthetic { duration: SimTime::from_secs(10) },
        );
        let ok = vc.advance_until(SimTime::from_secs(600), |st| {
            st.node_states.iter().skip(1).filter(|s| **s == NodeState::Ready).count() >= 3
        });
        assert!(ok, "never scaled to 3 nodes");
        assert!(vc.metrics().counter("scale_up_nodes") >= 2);
        // and the job eventually runs
        let ok = vc.advance_until(SimTime::from_secs(600), |st| !st.head.completed.is_empty());
        assert!(ok, "queued job never ran after scale-up");
    }

    #[test]
    fn dead_machine_leaves_the_hostfile() {
        let mut spec = fast_spec(3);
        spec.autoscale.enabled = false; // no self-healing in this test
        let mut vc = VirtualCluster::new(spec).unwrap();
        vc.start();
        assert!(vc.advance_until(SimTime::from_secs(300), |st| {
            st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
        }));
        vc.kill_machine(MachineId::new(2));
        // after TTL expiry + template poll the node disappears
        let ok = vc.advance_until(SimTime::from_secs(120), |st| {
            st.head.hostfile().map(|h| h.hosts.len()) == Some(1)
        });
        assert!(ok, "dead node still in hostfile: {}", vc.hostfile());
    }

    #[test]
    fn autoscaler_replaces_dead_machine() {
        // With autoscaling on and min_nodes=2, a killed machine is
        // re-provisioned automatically (self-healing).
        let mut vc = VirtualCluster::new(fast_spec(3)).unwrap();
        vc.start();
        assert!(vc.advance_until(SimTime::from_secs(300), |st| {
            st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
        }));
        let powered_before = vc.metrics().counter("machines_powered_on");
        vc.kill_machine(MachineId::new(2));
        let ok = vc.advance_until(SimTime::from_secs(300), |st| {
            st.node_states[2] == NodeState::Ready
        });
        assert!(ok, "machine 2 never re-provisioned");
        assert!(vc.metrics().counter("machines_powered_on") > powered_before);
        assert!(vc.advance_until(SimTime::from_secs(60), |st| {
            st.head.hostfile().map(|h| h.hosts.len()) == Some(2)
        }));
    }

    /// Satellite bugfix regression: a killed machine used to leave the
    /// head's reservation held forever and the job would "complete" on
    /// dead slots when its timer fired. Now the job fails out of the
    /// running pool at kill time and is requeued.
    #[test]
    fn killed_machine_fails_the_running_job_instead_of_phantom_completion() {
        let mut spec = fast_spec(3);
        spec.autoscale.enabled = false;
        let mut vc = VirtualCluster::new(spec).unwrap();
        vc.start();
        assert!(vc.advance_until(SimTime::from_secs(300), |st| {
            st.head.slots_available() >= 24
        }));
        vc.submit("doomed", 16, JobKind::Synthetic { duration: SimTime::from_secs(60) });
        assert!(vc.advance_until(SimTime::from_secs(30), |st| st.head.running.len() == 1));
        vc.kill_machine(MachineId::new(2));
        // immediate detection: the reservation is released and the job
        // is back in the queue, not running on dead slots
        assert!(vc.state.head.running.is_empty(), "job still running on a dead node");
        assert!(vc.state.head.reserved_addrs().is_empty(), "reservation leaked");
        assert_eq!(vc.metrics().counter("jobs_requeued"), 1);
        // past the original completion time: the stale timer must not
        // mark the job Done (it needs 16 slots, only 12 remain)
        vc.advance(SimTime::from_secs(120));
        assert!(
            vc.completed_jobs().is_empty(),
            "job completed on dead slots: {:?}",
            vc.completed_jobs()[0].state
        );
        // manual recovery: the requeued job runs to completion
        vc.power_on(MachineId::new(2));
        assert!(vc.advance_until(SimTime::from_secs(600), |st| !st.head.completed.is_empty()));
        assert!(matches!(vc.completed_jobs()[0].state, JobState::Done { .. }));
        assert!(vc.metrics().histogram("job_mttr_seconds").map(|h| h.count()) == Some(1));
    }

    #[test]
    fn injected_deploy_failure_is_retried_until_the_node_comes_up() {
        let mut vc = VirtualCluster::new(fast_spec(3)).unwrap();
        vc.state.deploy_faults[2] = 1;
        vc.start();
        let ok = vc.advance_until(SimTime::from_secs(600), |st| {
            st.node_states[2] == NodeState::Ready
        });
        assert!(ok, "node never recovered from the injected deploy failure");
        assert_eq!(vc.metrics().counter("injected_deploy_failures"), 1);
        assert!(vc.metrics().counter("machines_powered_on") >= 4, "retry must re-power the machine");
    }

    #[test]
    fn zero_machine_spec_is_an_error_not_a_panic() {
        let mut spec = fast_spec(3);
        spec.machines = 0;
        spec.autoscale.min_nodes = 0;
        spec.autoscale.max_nodes = 0;
        let err = VirtualCluster::new(spec).err().expect("0 machines must fail");
        assert!(err.to_string().contains("at least 1 machine"), "{err}");
    }

    #[test]
    fn single_machine_cluster_boots_head_only() {
        let mut spec = ClusterSpec::paper_testbed();
        spec.machines = 1;
        spec.machine_spec.boot_time = SimTime::from_secs(5);
        let mut vc = VirtualCluster::new(spec).unwrap();
        vc.start();
        assert!(
            vc.advance_until(SimTime::from_secs(600), |st| {
                st.node_states[0] == NodeState::Ready
            }),
            "head machine never became ready"
        );
        vc.advance(SimTime::from_secs(30));
        assert_eq!(vc.node_state(MachineId::new(0)), NodeState::Ready);
        assert_eq!(vc.ready_compute_nodes(), 0);
        assert_eq!(vc.state.head.slots_available(), 0);
    }

    #[test]
    fn narrow_jobs_run_concurrently_on_spare_slots() {
        let mut vc = VirtualCluster::new(fast_spec(3)).unwrap();
        vc.start();
        assert!(vc.advance_until(SimTime::from_secs(300), |st| {
            st.head.slots_available() >= 24
        }));
        for i in 0..3 {
            vc.submit(
                &format!("narrow-{i}"),
                8,
                JobKind::Synthetic { duration: SimTime::from_secs(30) },
            );
        }
        let ok = vc.advance_until(SimTime::from_secs(30), |st| st.head.running.len() == 3);
        assert!(ok, "3x8 ranks must run concurrently on 24 slots");
        assert!(vc.state.head.overbooked_hosts().is_empty(), "slots double-booked");
        assert!(vc.advance_until(SimTime::from_secs(120), |st| st.head.completed.len() == 3));
        // all three overlapped: the batch drains in ~1 job's duration,
        // where the old serial head needed 3x30s back to back
        let mut first_start = SimTime::from_nanos(u64::MAX);
        let mut last_finish = SimTime::ZERO;
        for rec in vc.completed_jobs() {
            if let JobState::Done { started, finished } = rec.state {
                first_start = first_start.min(started);
                last_finish = last_finish.max(finished);
            } else {
                panic!("job not done: {:?}", rec.state);
            }
        }
        assert!(
            last_finish.saturating_sub(first_start) < SimTime::from_secs(60),
            "batch did not overlap: {first_start} .. {last_finish}"
        );
        assert!(vc.metrics().histogram("concurrent_jobs").unwrap().max() >= 3.0);
    }

    #[test]
    fn oversized_job_is_rejected_not_wedged() {
        let mut vc = VirtualCluster::new(fast_spec(3)).unwrap();
        vc.start();
        // max advertisable = 2 compute nodes x 12 slots = 24
        vc.submit("too-wide", 100, JobKind::Synthetic { duration: SimTime::from_secs(10) });
        vc.submit("ok", 8, JobKind::Synthetic { duration: SimTime::from_secs(10) });
        assert!(vc.advance_until(SimTime::from_secs(300), |st| st.head.completed.len() == 2));
        assert!(
            matches!(vc.completed_jobs()[0].state, JobState::Failed { .. }),
            "impossible job must be rejected up front"
        );
        assert!(
            matches!(vc.completed_jobs()[1].state, JobState::Done { .. }),
            "narrow job must not be wedged behind the impossible one"
        );
        assert_eq!(vc.metrics().counter("jobs_rejected"), 1);
    }

    #[test]
    fn busy_nodes_survive_scale_down() {
        let mut spec = fast_spec(4);
        spec.autoscale.min_nodes = 1;
        spec.autoscale.max_nodes = 3;
        spec.autoscale.idle_timeout = SimTime::from_secs(10);
        let mut vc = VirtualCluster::new(spec).unwrap();
        vc.start();
        // the wide job forces scale-up to 3 nodes; the narrow one then
        // pins a node's slots for a long time while the pool is idle
        vc.submit("wide", 36, JobKind::Synthetic { duration: SimTime::from_secs(5) });
        vc.submit("pinned", 4, JobKind::Synthetic { duration: SimTime::from_secs(500) });
        assert!(vc.advance_until(SimTime::from_secs(600), |st| {
            st.head.completed.len() == 1 && st.head.running.len() == 1
        }));
        // low utilization (4/36 slots) triggers scale-down, but the node
        // hosting the running job must never be retired mid-run
        vc.advance(SimTime::from_secs(200));
        assert_eq!(vc.state.head.running.len(), 1, "job was killed by scale-down");
        assert!(vc.state.head.overbooked_hosts().is_empty(), "reservation lost its host");
        assert!(vc.metrics().counter("nodes_retired") >= 1, "idle nodes must retire");
        assert!(vc.advance_until(SimTime::from_secs(600), |st| st.head.completed.len() == 2));
        for rec in vc.completed_jobs() {
            assert!(matches!(rec.state, JobState::Done { .. }), "{:?}", rec.state);
        }
    }

    #[test]
    fn scale_down_after_idle() {
        let mut spec = fast_spec(4);
        spec.autoscale.min_nodes = 1;
        spec.autoscale.max_nodes = 3;
        spec.autoscale.idle_timeout = SimTime::from_secs(30);
        let mut vc = VirtualCluster::new(spec).unwrap();
        vc.start();
        vc.submit(
            "burst",
            36,
            JobKind::Synthetic { duration: SimTime::from_secs(5) },
        );
        assert!(vc.advance_until(SimTime::from_secs(600), |st| !st.head.completed.is_empty()));
        // idle now: should fall back toward min_nodes
        let ok = vc.advance_until(SimTime::from_secs(600), |st| {
            st.node_states.iter().skip(1).filter(|s| **s == NodeState::Ready).count() == 1
        });
        assert!(ok, "never scaled down to min");
        assert!(vc.metrics().counter("nodes_retired") >= 1);
    }
}
